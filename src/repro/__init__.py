# repro — production-grade JAX framework implementing
# "Accelerating Data Generation for Neural Operators via Krylov Subspace
# Recycling" (SKR, ICLR 2024) as a first-class data-generation subsystem,
# plus the full training/serving substrate (model zoo, distributed runtime,
# fault tolerance, dry-run + roofline harness).
#
# Solvers require f64 on CPU for paper-parity tolerances (down to 1e-11).
# The LM stack always passes explicit (bf16/f32) dtypes, so enabling x64
# globally is safe and matches PETSc semantics.
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
