"""Fourier Neural Operator (Li et al. 2020) — the paper's canonical data
consumer (its Table 33 trains an FNO on SKR- vs GMRES-generated Darcy data
and shows identical training dynamics; examples/train_fno.py reproduces).

2-D FNO: lifting 1×1 conv → L spectral blocks (truncated-mode complex
multiply in rfft2 space + pointwise linear bypass + GELU) → projection head.
Pure jnp; batch shards over the mesh DP axes via shard_act.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act


@dataclasses.dataclass(frozen=True)
class FNOConfig:
    modes: int = 12          # retained Fourier modes per dim
    width: int = 32          # channel width
    n_blocks: int = 4
    in_channels: int = 3     # input field + 2 coordinate channels
    out_channels: int = 1


def _uniform(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def fno_init(key, cfg: FNOConfig):
    ks = jax.random.split(key, 2 * cfg.n_blocks + 3)
    w = cfg.width
    params = {
        "lift": _uniform(ks[0], (cfg.in_channels, w), 1 / cfg.in_channels),
        "lift_b": jnp.zeros((w,)),
        "blocks": [],
        "proj1": _uniform(ks[1], (w, 128), 1 / w),
        "proj1_b": jnp.zeros((128,)),
        "proj2": _uniform(ks[2], (128, cfg.out_channels), 1 / 128),
        "proj2_b": jnp.zeros((cfg.out_channels,)),
    }
    scale = 1.0 / (w * w)
    for i in range(cfg.n_blocks):
        k1, k2 = jax.random.split(ks[3 + i])
        params["blocks"].append({
            # complex spectral weights for the two retained-mode corners
            "wr1": _uniform(k1, (2, w, w, cfg.modes, cfg.modes), scale),
            "wi1": _uniform(k2, (2, w, w, cfg.modes, cfg.modes), scale),
            "wlin": _uniform(jax.random.fold_in(k1, 7), (w, w), 1 / w),
            "blin": jnp.zeros((w,)),
        })
    return params


def _spectral_conv(bp, x, modes: int):
    """x: (B, X, Y, C) real. Truncated-mode multiply in rfft2 space."""
    b, nx, ny, c = x.shape
    xf = jnp.fft.rfft2(x, axes=(1, 2))            # (B, X, Y//2+1, C) complex
    wc = bp["wr1"] + 1j * bp["wi1"]               # (2, C, C, m, m)
    out = jnp.zeros_like(xf)
    m = modes
    # low-positive and low-negative x-frequencies, low y-frequencies
    top = jnp.einsum("bxyc,cdxy->bxyd", xf[:, :m, :m, :], wc[0])
    bot = jnp.einsum("bxyc,cdxy->bxyd", xf[:, -m:, :m, :], wc[1])
    out = out.at[:, :m, :m, :].set(top)
    out = out.at[:, -m:, :m, :].set(bot)
    return jnp.fft.irfft2(out, s=(nx, ny), axes=(1, 2))


def fno_apply(params, cfg: FNOConfig, x):
    """x: (B, X, Y, in_channels) → (B, X, Y, out_channels)."""
    x = shard_act(x, ("dp", None, None, None))
    h = x @ params["lift"] + params["lift_b"]
    for bp in params["blocks"]:
        s = _spectral_conv(bp, h, cfg.modes)
        h = jax.nn.gelu(s + h @ bp["wlin"] + bp["blin"])
    h = jax.nn.gelu(h @ params["proj1"] + params["proj1_b"])
    return h @ params["proj2"] + params["proj2_b"]


def _grid_channels(b, nx, ny):
    """Normalized coordinate channels gx, gy, each (B, X, Y)."""
    gx = jnp.broadcast_to(jnp.linspace(0.0, 1.0, nx)[None, :, None],
                          (b, nx, ny))
    gy = jnp.broadcast_to(jnp.linspace(0.0, 1.0, ny)[None, None, :],
                          (b, nx, ny))
    return gx, gy


def add_coords(fields):
    """(B, X, Y) input field → (B, X, Y, 3) with normalized coordinates."""
    gx, gy = _grid_channels(*fields.shape)
    return jnp.stack([fields, gx, gy], axis=-1)


def relative_l2(pred, target):
    """Paper's metric: relative error under the two-norm."""
    num = jnp.sqrt(jnp.sum((pred - target) ** 2, axis=(1, 2, 3)))
    den = jnp.sqrt(jnp.sum(target ** 2, axis=(1, 2, 3))) + 1e-12
    return jnp.mean(num / den)


# ------------------------------------------------------- autoregressive FNO
# Time-dependent consumer path (pde/timedep.py trajectories): the FNO learns
# the one-step map u_t ↦ u_{t+1} conditioned on a static coefficient channel
# (e.g. K(·, 0) for heat), and inference ROLLS OUT autoregressively.

def add_rollout_channels(state, cond):
    """(B, X, Y) state u_t + (B, X, Y) static conditioning field →
    (B, X, Y, 4) input [u_t, cond, x, y] (use FNOConfig(in_channels=4))."""
    gx, gy = _grid_channels(*state.shape)
    return jnp.stack([state, cond, gx, gy], axis=-1)


def fno_rollout(params, cfg: FNOConfig, u0, cond, steps: int):
    """Autoregressive rollout: feed each prediction back as the next input
    state. u0, cond: (B, X, Y). Returns (B, steps, X, Y) — the predicted
    u_1..u_steps, aligned with `TrajResult.trajectories[:, 1:]`."""
    preds = []
    u = u0
    for _ in range(steps):
        u = fno_apply(params, cfg, add_rollout_channels(u, cond))[..., 0]
        preds.append(u)
    return jnp.stack(preds, axis=1)
