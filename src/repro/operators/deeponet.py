"""DeepONet (Lu et al. 2019) — second neural-operator family cited by the
paper. Branch net encodes the input function (sensor values = flattened
input field), trunk net encodes query coordinates; output is the inner
product of the two latent codes + bias."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DeepONetConfig:
    n_sensors: int            # flattened input-field size
    latent: int = 128
    hidden: int = 128
    depth: int = 3


def _mlp_init(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k = jax.random.fold_in(key, i)
        params.append({
            "w": jax.random.normal(k, (a, b)) * jnp.sqrt(2.0 / a),
            "b": jnp.zeros((b,)),
        })
    return params


def _mlp_apply(params, x):
    for i, lp in enumerate(params):
        x = x @ lp["w"] + lp["b"]
        if i + 1 < len(params):
            x = jnp.tanh(x)
    return x


def deeponet_init(key, cfg: DeepONetConfig):
    kb, kt = jax.random.split(key)
    branch_sizes = [cfg.n_sensors] + [cfg.hidden] * cfg.depth + [cfg.latent]
    trunk_sizes = [2] + [cfg.hidden] * cfg.depth + [cfg.latent]
    return {
        "branch": _mlp_init(kb, branch_sizes),
        "trunk": _mlp_init(kt, trunk_sizes),
        "bias": jnp.zeros(()),
    }


def deeponet_apply(params, cfg: DeepONetConfig, sensors, coords):
    """sensors (B, n_sensors); coords (Q, 2) → (B, Q)."""
    b = _mlp_apply(params["branch"], sensors)          # (B, L)
    t = _mlp_apply(params["trunk"], coords)            # (Q, L)
    return b @ t.T + params["bias"]


def grid_coords(nx: int, ny: int):
    gx, gy = jnp.meshgrid(jnp.linspace(0, 1, nx), jnp.linspace(0, 1, ny),
                          indexing="ij")
    return jnp.stack([gx.ravel(), gy.ravel()], axis=-1)   # (nx*ny, 2)
