from repro.operators.fno import (FNOConfig, add_rollout_channels, fno_apply,
                                 fno_init, fno_rollout)
from repro.operators.deeponet import DeepONetConfig, deeponet_apply, deeponet_init

__all__ = ["FNOConfig", "fno_init", "fno_apply",
           "add_rollout_channels", "fno_rollout",
           "DeepONetConfig", "deeponet_init", "deeponet_apply"]
