from repro.operators.fno import FNOConfig, fno_apply, fno_init
from repro.operators.deeponet import DeepONetConfig, deeponet_apply, deeponet_init

__all__ = ["FNOConfig", "fno_init", "fno_apply",
           "DeepONetConfig", "deeponet_init", "deeponet_apply"]
