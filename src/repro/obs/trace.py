"""Structured tracing: nested spans over the datagen pipeline, recorded to
an in-memory ring buffer.

The tracer is a process-global singleton toggled by `obs.enable()` /
`obs.disable()` (see `obs/__init__.py`). Disabled — the default — every
entry point degenerates to a `None` check returning a shared no-op object,
so instrumented hot loops (the per-cycle flag fetch of the lockstep solver)
pay one attribute load when tracing is off and NOTHING is allocated.

Spans carry (name, category, start, duration, thread id, attrs). The ring
buffer (`collections.deque(maxlen=...)`) bounds memory on long trajectory
runs: old events fall off the front, and `dropped` counts them so exports
are honest about truncation.

Two export formats:

* `to_jsonl(path)` — one JSON object per line, trivially greppable and
  stream-parsable (the "telemetry JSONL" CI artifact).
* `to_chrome_trace(path)` — the Chrome trace-event format: open the file in
  `chrome://tracing` or https://ui.perfetto.dev and the prefetch thread's
  `prepare_row` spans render on their OWN track, visually overlapped (or
  not!) with the main thread's `solve_dispatch` spans. Occupancy counter
  events render as a counter track, so lockstep utilization is inspectable
  on the same timeline.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Optional


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records itself into the tracer's ring on exit."""

    __slots__ = ("tracer", "name", "cat", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self.tracer._record({
            "ph": "X", "name": self.name, "cat": self.cat,
            "ts": self.t0, "dur": t1 - self.t0,
            "tid": threading.get_ident(),
        } | ({"args": self.args} if self.args else {}))
        return False


class Tracer:
    """Ring-buffered span/counter recorder (thread-safe appends)."""

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self.events: deque = deque(maxlen=self.capacity)
        self.dropped = 0
        self._lock = threading.Lock()
        self._tid_names: dict[int, str] = {}
        self.epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------- record
    def _record(self, ev: dict):
        tid = ev.get("tid")
        with self._lock:
            if tid is not None and tid not in self._tid_names:
                self._tid_names[tid] = threading.current_thread().name
            if len(self.events) == self.capacity:
                self.dropped += 1
            self.events.append(ev)

    def span(self, name: str, cat: str = "datagen", **args) -> _Span:
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "datagen", **args):
        self._record({"ph": "i", "name": name, "cat": cat,
                      "ts": time.perf_counter_ns(),
                      "tid": threading.get_ident()}
                     | ({"args": args} if args else {}))

    def counter(self, name: str, values: dict, cat: str = "datagen"):
        """A Chrome counter sample ("C" event) — e.g. the per-dispatch
        live/padded lockstep occupancy timeline."""
        self._record({"ph": "C", "name": name, "cat": cat,
                      "ts": time.perf_counter_ns(),
                      "tid": threading.get_ident(), "args": values})

    # ------------------------------------------------------------ analyze
    def snapshot(self) -> list[dict]:
        with self._lock:
            return list(self.events)

    def phase_seconds(self) -> dict[str, float]:
        """Total seconds per span name (complete spans only) — the
        time-per-phase breakdown of the run report."""
        acc: dict[str, float] = {}
        for ev in self.snapshot():
            if ev.get("ph") == "X":
                acc[ev["name"]] = acc.get(ev["name"], 0.0) \
                    + ev["dur"] / 1e9
        return acc

    # ------------------------------------------------------------- export
    def _export_events(self) -> list[dict]:
        evs = self.snapshot()
        out = []
        for ev in evs:
            e = dict(ev)
            e["pid"] = 0
            e["ts"] = (e["ts"] - self.epoch_ns) / 1e3      # µs since enable
            if "dur" in e:
                e["dur"] = e["dur"] / 1e3
            out.append(e)
        return out

    def to_jsonl(self, path: str):
        """One event per line; a leading meta line records drop counts so a
        truncated ring is visible to consumers."""
        with open(path, "w") as f:
            f.write(json.dumps({"meta": {"events": len(self.events),
                                         "dropped": self.dropped,
                                         "capacity": self.capacity}}) + "\n")
            for ev in self._export_events():
                f.write(json.dumps(ev) + "\n")

    def to_chrome_trace(self, path: str):
        """Chrome/Perfetto trace.json (load in chrome://tracing)."""
        events = self._export_events()
        with self._lock:
            tid_names = dict(self._tid_names)
        # thread-name metadata rows: the prefetch executor thread shows up
        # named, so the prefetch/solve overlap is readable at a glance
        for tid, tname in tid_names.items():
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tid, "args": {"name": tname}})
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms",
                       "otherData": {"dropped_events": self.dropped}}, f)
