"""Counters/gauges registry + lockstep-utilization accounting.

The registry is the scalar side of the telemetry layer: monotonically
increasing counters (dispatches, live/padded lockstep rows, Krylov
iterations) and last-value gauges (per-dispatch iteration imbalance). It is
what `SequenceStats.summary()` merges in when observability is enabled, and
what the future streaming scheduler will read live — the ">80% non-padded
rows" target of the ROADMAP's online-scheduler item is exactly
`utilization()` here.

Occupancy convention: every lockstep `solve_batch` dispatch records how many
chain rows were LIVE vs PADDED (zero-RHS fill: shorter chunks, sharding
fill, phase-masked finished chains). `utilization()` is the live fraction
over all dispatched rows — device work actually spent on real systems.
Iteration imbalance is max/mean Krylov iterations across the live chains of
one dispatch: 1.0 means perfect lockstep, large values mean one chain
dragged the whole SPMD program.
"""
from __future__ import annotations

import threading


class Registry:
    """Thread-safe counters + gauges (plain floats, no label sets — the
    datagen pipeline is one process; shard axes live in the values)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}

    def counter_add(self, name: str, value: float = 1.0):
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def gauge_set(self, name: str, value: float):
        with self._lock:
            self.gauges[name] = float(value)

    # --------------------------------------------- lockstep occupancy
    def record_dispatch(self, live: int, total: int, iters=None,
                        cycles: int = 0):
        """One lockstep solve_batch dispatch: `live` non-padded rows out of
        `total`; `iters` = per-LIVE-chain iteration counts (imbalance)."""
        with self._lock:
            c = self.counters
            c["lockstep.dispatches"] = c.get("lockstep.dispatches", 0.0) + 1
            c["lockstep.rows_live"] = c.get("lockstep.rows_live", 0.0) + live
            c["lockstep.rows_total"] = (c.get("lockstep.rows_total", 0.0)
                                        + total)
            c["krylov.cycles"] = c.get("krylov.cycles", 0.0) + cycles
        if iters is not None and len(iters) > 0:
            tot = float(sum(iters))
            mx = float(max(iters))
            self.counter_add("krylov.iterations", tot)
            mean = tot / len(iters)
            self.gauge_set("lockstep.iter_imbalance",
                           mx / mean if mean > 0 else 1.0)

    # ------------------------------------------- streaming occupancy
    def record_stream(self, queue_depth: int, occupied: int, slots: int):
        """One streaming-scheduler tick (core/serve.py): current request
        queue depth and slot occupancy. Gauges carry the live values; the
        tick counter gives the sample count."""
        self.gauge_set("stream.queue_depth", queue_depth)
        self.gauge_set("stream.slots_occupied", occupied)
        self.gauge_set("stream.slots_total", slots)
        self.counter_add("stream.ticks")

    def utilization(self) -> float:
        """Live fraction of all dispatched lockstep rows (1.0 = no padding;
        the streaming-scheduler target reads >0.8 here)."""
        with self._lock:
            total = self.counters.get("lockstep.rows_total", 0.0)
            live = self.counters.get("lockstep.rows_live", 0.0)
        return live / total if total > 0 else 1.0

    def snapshot(self) -> dict:
        with self._lock:
            out = {"counters": dict(self.counters),
                   "gauges": dict(self.gauges)}
        out["utilization"] = self.utilization()
        return out

    def reset(self):
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
