"""Human-readable run reports from the telemetry layer.

`render_report` turns one datagen run's signals — per-family
`SequenceStats`, the tracer's phase timings, and the registry's occupancy
counters — into the terminal summary `examples/datagen_report.py` prints:
time per pipeline phase, iterations cold vs recycled (the paper's headline
contrast), syncs per cycle, and lockstep utilization.

Everything here is duck-typed against `solvers.types.SequenceStats` (only
properties are read) so reports can also be rebuilt from deserialized
benchmark artifacts.
"""
from __future__ import annotations


def _fmt_s(sec: float) -> str:
    return f"{sec * 1e3:8.1f} ms" if sec < 1.0 else f"{sec:8.2f} s "


def phase_table(phase_seconds: dict) -> list[str]:
    """Time-per-phase lines, longest first, with share of traced time."""
    if not phase_seconds:
        return ["  (no spans recorded)"]
    total = sum(phase_seconds.values())
    lines = []
    for name, sec in sorted(phase_seconds.items(), key=lambda kv: -kv[1]):
        share = 100.0 * sec / total if total > 0 else 0.0
        lines.append(f"  {name:<24s} {_fmt_s(sec)}  {share:5.1f}%")
    return lines


def cold_vs_recycled(seq) -> tuple[float, float]:
    """(cold, recycled) mean iterations: the FIRST real solve of a sequence
    starts with an empty recycle space; later ones inherit the carry. The
    ratio is the per-sequence view of the paper's headline speedup."""
    solved = seq.solved
    if not solved:
        return 0.0, 0.0
    cold = float(solved[0].iterations)
    rest = solved[1:]
    warm = (sum(s.iterations for s in rest) / len(rest)) if rest else cold
    return cold, warm


def family_lines(name: str, seq) -> list[str]:
    """Per-family breakdown block (one PDE family / dataset sequence)."""
    s = seq.summary()
    cold, warm = cold_vs_recycled(seq)
    cyc = sum(st.cycles for st in seq.solved)
    sync_per_cycle = ((s["host_syncs"] - 2 * s["num"]) / cyc
                      if cyc > 0 else 0.0)
    total_rows = s["num"] + s["padded"]
    util = s["num"] / total_rows if total_rows > 0 else 1.0
    lines = [
        f"[{name}]",
        f"  systems solved          {s['num']:8d}"
        f"   (padded rows: {s['padded']})",
        f"  mean iterations         {s['mean_iterations']:8.1f}",
        f"  iters cold vs recycled  {cold:8.1f} -> {warm:.1f}"
        + (f"   ({cold / warm:.2f}x)" if warm > 0 else ""),
        f"  total wall time         {_fmt_s(s['total_time_s'])}",
        f"  host syncs / cycle      {sync_per_cycle:8.2f}",
        f"  lockstep utilization    {100.0 * util:7.1f}%",
    ]
    if s.get("outer_refinements", 0):
        lines.append(f"  fp32 refinement passes  "
                     f"{s['outer_refinements']:8d}"
                     f"   (fp64 fallbacks: {s['fp64_fallback']})")
    return lines


def render_report(families: dict, tracer=None, registry=None) -> str:
    """The full run report: per-family blocks + phase times + occupancy."""
    out = ["=== datagen telemetry report ==="]
    for name, seq in families.items():
        out.extend(family_lines(name, seq))
    if tracer is not None:
        out.append("[time per phase]")
        out.extend(phase_table(tracer.phase_seconds()))
        if tracer.dropped:
            out.append(f"  (ring dropped {tracer.dropped} events)")
    if registry is not None:
        snap = registry.snapshot()
        out.append("[lockstep occupancy]")
        c = snap["counters"]
        out.append(f"  dispatches              "
                   f"{int(c.get('lockstep.dispatches', 0)):8d}")
        out.append(f"  rows live / total       "
                   f"{int(c.get('lockstep.rows_live', 0)):8d} / "
                   f"{int(c.get('lockstep.rows_total', 0))}")
        out.append(f"  utilization             "
                   f"{100.0 * snap['utilization']:7.1f}%")
        imb = snap["gauges"].get("lockstep.iter_imbalance")
        if imb is not None:
            out.append(f"  iter imbalance (last)   {imb:8.2f}")
    return "\n".join(out)
