"""repro.obs — opt-in observability for the datagen pipeline.

One process-global switch gates three signal families:

* **spans** (`obs.span(...)`) — nested wall-time tracing over pipeline
  phases, ring-buffered, exportable as JSONL or a Chrome/Perfetto
  `trace.json` (`obs/trace.py`);
* **device Krylov telemetry** — per-cycle per-chain convergence rings the
  lockstep solver accumulates ON DEVICE and drains in its one finalize
  fetch (`obs/telemetry.py`; threaded through `solvers/batched.py`);
* **counters/gauges** (`obs.record_dispatch(...)`) — lockstep utilization
  and iteration-imbalance scalars merged into `SequenceStats.summary()`
  (`obs/metrics.py`).

Disabled (the default, and the state every import starts in) the
instrumentation compiles out: `span()` is a `None`-check returning a shared
no-op, `krylov_capacity()` returns 0 so the jitted cycle programs trace
WITHOUT telemetry buffers (identical jaxprs → bitwise-identical numerics,
zero extra dispatches — regression-tested in tests/test_obs.py), and
`record_dispatch` returns immediately.

Usage:

    from repro import obs
    obs.enable(delta_qc=True)
    ... run datagen ...
    obs.export_chrome_trace("results/TRACE_heat.json")
    print(obs.summary()["utilization"])
    obs.disable()
"""
from __future__ import annotations

from typing import Optional

from repro.obs.metrics import Registry
from repro.obs.telemetry import (KrylovTelemetry, TelemetryConfig,
                                 drain_chain, ring_order)
from repro.obs.trace import NULL_SPAN, Tracer

__all__ = [
    "enable", "disable", "enabled", "span", "instant", "counter",
    "counter_add", "gauge_set", "tracer", "registry", "record_dispatch",
    "record_stream", "krylov_capacity",
    "delta_enabled", "summary", "export_chrome_trace", "export_jsonl",
    "KrylovTelemetry", "TelemetryConfig", "drain_chain", "ring_order",
    "Tracer", "Registry",
]

_TRACER: Optional[Tracer] = None
_REGISTRY: Optional[Registry] = None
_KRYLOV: Optional[TelemetryConfig] = None


def enable(trace_capacity: int = 65536, krylov_capacity: int = 128,
           delta_qc: bool = False):
    """Turn observability ON (idempotent: re-enabling starts fresh buffers).

    krylov_capacity: device ring slots per chain for per-cycle convergence
    telemetry; it is a STATIC argument of the lockstep cycle programs, so
    the first telemetry-on solve per shape pays a retrace. 0 disables the
    device rings while keeping spans/counters live.
    delta_qc: also record the per-cycle δ(Q,C) recycle-refresh angle (adds
    one (k×k) SVD to the fused deflated-cycle program).
    """
    global _TRACER, _REGISTRY, _KRYLOV
    _TRACER = Tracer(capacity=trace_capacity)
    _REGISTRY = Registry()
    _KRYLOV = TelemetryConfig(capacity=max(int(krylov_capacity), 1),
                              delta_qc=bool(delta_qc)) \
        if krylov_capacity > 0 else None


def disable():
    """Turn observability OFF and drop all buffers."""
    global _TRACER, _REGISTRY, _KRYLOV
    _TRACER = None
    _REGISTRY = None
    _KRYLOV = None


def enabled() -> bool:
    return _TRACER is not None


# ---------------------------------------------------------------- tracing
def span(name: str, cat: str = "datagen", **args):
    """Context manager timing one phase; free no-op when disabled."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, cat, **args)


def instant(name: str, cat: str = "datagen", **args):
    t = _TRACER
    if t is not None:
        t.instant(name, cat, **args)


def counter(name: str, values: dict, cat: str = "datagen"):
    t = _TRACER
    if t is not None:
        t.counter(name, values, cat=cat)


def tracer() -> Optional[Tracer]:
    return _TRACER


# --------------------------------------------------------------- registry
def registry() -> Optional[Registry]:
    return _REGISTRY


def counter_add(name: str, value: float = 1.0):
    """Bump a registry counter; free no-op when disabled. The containment
    layer (core/robust.py, solvers/batched.py) reports retry / quarantine /
    fault events through this — e.g. `health.retries`,
    `health.quarantined`, `faults.nan_rhs`."""
    r = _REGISTRY
    if r is not None:
        r.counter_add(name, value)


def gauge_set(name: str, value: float):
    """Set a last-value registry gauge; free no-op when disabled. The
    label-expansion stage reports its headline rate through this
    (`expand.labels_per_second`)."""
    r = _REGISTRY
    if r is not None:
        r.gauge_set(name, value)


def record_dispatch(live: int, total: int, iters=None, cycles: int = 0):
    """Lockstep occupancy hook (see Registry.record_dispatch); also samples
    a Chrome counter track so utilization renders on the trace timeline."""
    r = _REGISTRY
    if r is None:
        return
    r.record_dispatch(live, total, iters=iters, cycles=cycles)
    t = _TRACER
    if t is not None:
        t.counter("lockstep_rows", {"live": live, "padded": total - live})


def record_stream(queue_depth: int, occupied: int, slots: int):
    """Streaming-scheduler occupancy hook (see Registry.record_stream);
    also samples a Chrome counter track so queue depth and slot occupancy
    render on the trace timeline next to `lockstep_rows`."""
    r = _REGISTRY
    if r is None:
        return
    r.record_stream(queue_depth, occupied, slots)
    t = _TRACER
    if t is not None:
        t.counter("stream", {"queue": queue_depth, "occupied": occupied,
                             "free": slots - occupied}, cat="serve")


# --------------------------------------------------- device Krylov config
def krylov_capacity() -> int:
    """Static ring capacity for the lockstep cycle programs (0 = compiled
    out: no buffers in the state dict, jaxpr identical to pre-telemetry)."""
    k = _KRYLOV
    return k.capacity if k is not None else 0


def delta_enabled() -> bool:
    k = _KRYLOV
    return k.delta_qc if k is not None else False


# ---------------------------------------------------------------- exports
def summary() -> dict:
    """Counters/gauges/utilization snapshot ({} when disabled)."""
    r = _REGISTRY
    return r.snapshot() if r is not None else {}


def export_chrome_trace(path: str) -> bool:
    t = _TRACER
    if t is None:
        return False
    t.to_chrome_trace(path)
    return True


def export_jsonl(path: str) -> bool:
    t = _TRACER
    if t is None:
        return False
    t.to_jsonl(path)
    return True
