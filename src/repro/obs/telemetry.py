"""Device-buffered Krylov convergence telemetry — types + drain helpers.

The lockstep engine (`solvers/batched.py`) runs whole GCRO-DR cycles as
fused device programs; the only blocking host traffic per cycle is a 4-bool
flag fetch, and that invariant (host_syncs = 2 + cycles, enforced by
tests/test_transfer_guard.py) must survive telemetry. So per-cycle signals
are NOT fetched per cycle: they accumulate in preallocated device ring
buffers threaded through the jitted cycle programs — per-chain residual
norm, stall flag, deflation-space dimension, and (behind
`TelemetryConfig.delta_qc`) the recycle-quality angle — and are drained in
the ONE finalize fetch the solver already pays.

Ring semantics: a static `capacity` bounds device memory; cycle c writes
slot c % capacity and a scalar cycle counter keeps the true total, so the
host can reconstruct chronological order and report exactly how many early
cycles fell off (`KrylovTelemetry.dropped`). Unwritten slots hold NaN.

δ(Q,C): `core/metrics.delta_subspace` defines the recycle-quality metric
δ = ‖(I − Π_C) Π_Q‖₂ (paper Eq. 5) between the recycled space C and a
target space Q. The per-cycle device proxy recorded here is δ between the
chain's recycle space BEFORE and AFTER the harmonic-Ritz refresh — both
orthonormal on device, so δ = sin θ_max = sqrt(1 − σ_min(C_oldᵀ C_new)²)
from one (k × k) SVD per chain per cycle. Small δ ⇒ the refresh barely
rotates the space ⇒ the chain is in the recycling steady state the sorting
is supposed to buy; δ jumping toward 1 flags a chain whose operators drift
too fast for its carry (the chain-assignment quality signal the streaming
scheduler will consume). It is OFF by default because the extra SVD rides
in the cycle's fused program. `core/metrics.delta_subspace` is the host
oracle the device formula is tested against.

The sequential solvers already touch host floats every cycle, so their
history is recorded host-side at zero extra cost (same dataclass).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Krylov-telemetry knobs (static at trace time — each distinct
    capacity compiles its own cycle executable, so pick one per run)."""

    capacity: int = 128       # device ring slots per chain (cycles kept)
    delta_qc: bool = False    # also record the δ(Q,C) refresh angle

    def __post_init__(self):
        assert self.capacity >= 1


@dataclasses.dataclass
class KrylovTelemetry:
    """Per-solve convergence history for ONE system/chain (chronological;
    at most `capacity` most-recent cycles — `dropped` counts older ones)."""

    res_hist: np.ndarray                     # (c,) residual norm per cycle
    stalled: Optional[np.ndarray] = None     # (c,) bool stall flag
    defl_dim: Optional[np.ndarray] = None    # (c,) recycle-space dimension
    delta_qc: Optional[np.ndarray] = None    # (c,) refresh angle (NaN = n/a)
    dropped: int = 0                         # cycles older than the ring
    kind: str = "cycle"                      # "cycle" | "outer" (IR passes)

    def to_dict(self) -> dict:
        """JSON-friendly form (NaN → None) for the telemetry JSONL."""
        def col(a):
            if a is None:
                return None
            return [None if (isinstance(v, float) and np.isnan(v)) else
                    (v.item() if hasattr(v, "item") else v)
                    for v in np.asarray(a).tolist()]

        return {"kind": self.kind, "dropped": self.dropped,
                "res_hist": col(self.res_hist),
                "stalled": col(self.stalled),
                "defl_dim": col(self.defl_dim),
                "delta_qc": col(self.delta_qc)}


def ring_order(count: int, capacity: int) -> tuple[np.ndarray, int]:
    """Chronological slot order for a ring written `count` times.

    Returns (slot indices oldest→newest, dropped) — the first `dropped`
    cycles are gone; slot (count-1) % capacity holds the newest entry."""
    if count <= capacity:
        return np.arange(count), 0
    newest = (count - 1) % capacity
    return (np.arange(newest + 1 - capacity, newest + 1) % capacity,
            count - capacity)


def drain_chain(bufs: dict, chain: int, count: int, capacity: int
                ) -> KrylovTelemetry:
    """Build one chain's `KrylovTelemetry` from fetched (B, capacity) ring
    buffers + the shared cycle count (the finalize-fetch payload)."""
    order, dropped = ring_order(int(count), capacity)
    pick = lambda key: (np.asarray(bufs[key])[chain][order]
                        if key in bufs else None)
    return KrylovTelemetry(
        res_hist=pick("tlm_res"),
        stalled=pick("tlm_stall"),
        defl_dim=pick("tlm_dim"),
        delta_qc=pick("tlm_delta"),
        dropped=dropped,
    )
