"""minicpm3-4b [dense]: Multi-head Latent Attention [hf:openbmb/MiniCPM3-4B; hf].
MLA compressed-KV cache (kv_lora_rank + rope dim per token)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448,
    use_mla=True, q_lora_rank=768, kv_lora_rank=256,
    qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64,
    tie_embeddings=True, rope_theta=10_000.0, act="silu",
    skip_shapes=("long_500k",),
)
