"""qwen2-vl-2b [vlm]: M-RoPE (t/h/w sections 16/24/24), dynamic-resolution
patch frontend STUBBED (input_specs provides position triples; patch embeds
enter as tokens) [arXiv:2409.12191; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128,
    qkv_bias=True, tie_embeddings=True,
    mrope_sections=(16, 24, 24), rope_theta=1_000_000.0, act="silu",
    skip_shapes=("long_500k",),
)
