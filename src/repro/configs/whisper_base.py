"""whisper-base [audio]: encoder-decoder backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356].
32k decoder shapes exceed Whisper's trained 448 positions — lowered
structurally per the assignment (DESIGN §3)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    is_encdec=True, n_enc_layers=6, enc_positions=1500,
    act="gelu",
    skip_shapes=("long_500k",),
)
