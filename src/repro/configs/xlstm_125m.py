"""xlstm-125m [ssm]: alternating mLSTM/sLSTM blocks, d_ff=0 (blocks carry
their own up/down projections) [arXiv:2405.04517]. O(1) recurrent state →
runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block_pattern=("mlstm", "slstm"),
)
