"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 2 recurrent blocks
per 1 local-attn block (Griffin) [arXiv:2402.19427; hf]. O(window + state)
memory ⇒ runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    d_rec=2560, conv_width=4, window=2048,
    rope_theta=10_000.0, act="gelu",
)
