"""Registry behind ``--arch``: full configs + reduced smoke variants.

Smoke variants keep the FAMILY structure (same block pattern, MoE/MLA/GQA
topology) at toy width so one train step + one decode step run on CPU in
seconds; full configs are only ever touched via ShapeDtypeStruct lowering.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES = {
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "whisper-base": "repro.configs.whisper_base",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small layers/width, few experts, tiny
    vocab — runs a forward/train step on CPU asserting shapes + no NaNs."""
    cfg = get_config(name)
    pat = len(cfg.block_pattern)
    reduced: Dict = dict(
        n_layers=max(2, pat) if cfg.n_layers % max(2, pat) == 0 or pat == 1
        else 2 * pat,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        attn_chunk=64,
        remat=False,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.is_moe:
        reduced.update(n_experts=4, top_k=2, moe_d_ff=64)
    if cfg.use_mla:
        reduced.update(q_lora_rank=32, kv_lora_rank=16,
                       qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    if cfg.is_encdec:
        reduced.update(n_enc_layers=2, enc_positions=24)
    if cfg.mrope_sections is not None:
        reduced.update(mrope_sections=(2, 3, 3))  # sums to head_dim 16 // 2
    if cfg.d_rec:
        reduced.update(d_rec=64)
    if cfg.window:
        reduced.update(window=32)
    # keep pattern length dividing n_layers for the scan path
    n_layers = reduced["n_layers"]
    if n_layers % pat:
        reduced["n_layers"] = pat * max(1, n_layers // pat)
    return dataclasses.replace(cfg, **reduced)
