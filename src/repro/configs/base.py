"""Config system: ModelConfig (architecture), ShapeConfig (workload cells)
and the registry behind ``--arch``.

Every assigned architecture gets one module in this package defining
``CONFIG``; ``registry.py`` exposes them plus reduced smoke variants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    window: Optional[int] = None     # sliding-window attention
    act: str = "silu"                # silu → SwiGLU MLP; gelu → plain MLP
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_impl: str = "dispatch"       # dispatch (capacity buckets) | dense
    capacity_factor: float = 1.25
    # --- MLA (minicpm3 / deepseek-style) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- recurrent / hybrid ---
    block_pattern: Tuple[str, ...] = ("attn",)  # attn|mlstm|slstm|rec
    d_rec: int = 0                  # RG-LRU width (recurrentgemma)
    conv_width: int = 4
    # --- encoder-decoder (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_positions: int = 1500        # stub frontend sequence length
    # --- VLM (qwen2-vl) ---
    mrope_sections: Optional[Tuple[int, int, int]] = None
    # --- numerics / lowering ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 512            # kv-chunk for the jnp flash path
    use_flash_kernel: bool = False   # Pallas kernel routing (TPU)
    # --- shape applicability (DESIGN §3) ---
    skip_shapes: Tuple[str, ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.use_mla:
            per_layer += d * self.q_lora_rank + self.q_lora_rank * nq * (
                self.qk_nope_head_dim + self.qk_rope_head_dim)
            per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            per_layer += self.kv_lora_rank * nq * (self.qk_nope_head_dim + self.v_head_dim)
            per_layer += nq * self.v_head_dim * d
        else:
            per_layer += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.is_moe:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
        elif self.d_ff > 0:
            mults = 3 if self.act == "silu" else 2
            per_layer += mults * d * self.d_ff
        n_attn_layers = self.n_layers
        return emb + per_layer * n_attn_layers

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * self.d_model * self.moe_d_ff
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
