"""kimi-k2-1t-a32b [moe]: trillion-parameter MoE, 384 experts top-8,
expert width 2048 [arXiv:2501.kimi2 paper-table]. EP over the model axis +
FSDP over data. Full attention → long_500k skipped."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=128,
    n_experts=384, top_k=8, moe_d_ff=2048,
    rope_theta=50_000.0, act="silu",
    skip_shapes=("long_500k",),
)
