"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention (4096)
[arXiv:2401.04088; hf]. SWA ⇒ O(window) cache ⇒ runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    n_experts=8, top_k=2, moe_d_ff=14336,
    window=4096, rope_theta=1_000_000.0, act="silu",
)
