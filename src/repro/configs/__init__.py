"""Architecture configs (one module per assigned arch) + registry."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import get_config, get_smoke_config, list_archs
