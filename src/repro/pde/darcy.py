"""Darcy-flow family (paper App. D.2.1): −∇·(K(x,y)∇h) = f on the unit square,
K = exp(GRF) (log-normal permeability), f ≡ 1, homogeneous Dirichlet BC —
the standard FNO benchmark setup. Finite-volume discretization with
harmonic-mean face transmissibilities keeps the operator an SPD 5-point
stencil. Sorting features: the GRF low-frequency latent (the NO parameters
themselves, per paper §6.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pde.dia import Stencil5
from repro.pde.grf import GRFSpec, sample_grf
from repro.pde.problems import LinearProblem, ProblemFamily


def harmonic(a: jax.Array, b: jax.Array) -> jax.Array:
    return 2.0 * a * b / (a + b)


def assemble_darcy_stencil(k_field: jax.Array, hx: float, hy: float) -> jax.Array:
    """Build (5, nx, ny) coeffs for −∇·(K∇·) with Dirichlet-0 BC.

    Face transmissibilities use harmonic means; boundary faces use the cell
    value itself (ghost cell with same K, u=0 at the wall)."""
    kx_face = harmonic(k_field[:-1, :], k_field[1:, :])  # (nx-1, ny) interior x-faces
    ky_face = harmonic(k_field[:, :-1], k_field[:, 1:])  # (nx, ny-1) interior y-faces

    # Pad with wall transmissibilities (ghost K = cell K, half-distance wall
    # handled by the same 1/h² scaling — standard cell-centred FV Dirichlet).
    kx_n = jnp.concatenate([2.0 * k_field[:1, :], kx_face], axis=0)   # face above row i
    kx_s = jnp.concatenate([kx_face, 2.0 * k_field[-1:, :]], axis=0)  # face below row i
    ky_w = jnp.concatenate([2.0 * k_field[:, :1], ky_face], axis=1)
    ky_e = jnp.concatenate([ky_face, 2.0 * k_field[:, -1:]], axis=1)

    cx = 1.0 / hx**2
    cy = 1.0 / hy**2
    n = -cx * kx_n
    s = -cx * kx_s
    w = -cy * ky_w
    e = -cy * ky_e
    c = -(n + s + w + e)
    # Off-grid legs don't appear in the matrix (u=0 outside): zero them but
    # keep their contribution in the diagonal (done above, since c sums the
    # wall transmissibilities too — that's the Dirichlet penalty).
    n = n.at[0, :].set(0.0)
    s = s.at[-1, :].set(0.0)
    w = w.at[:, 0].set(0.0)
    e = e.at[:, -1].set(0.0)
    return jnp.stack([c, n, s, w, e])


class DarcyFamily(ProblemFamily):
    name = "darcy"

    def __init__(self, nx: int = 64, ny: int = 64, alpha: float = 2.5, tau: float = 7.0,
                 sigma: float = 1.0, source: float = 1.0):
        super().__init__(nx, ny)
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=alpha, tau=tau, scale=nx**1.5)
        self.sigma = sigma
        self.source = source
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)

    def sample(self, key: jax.Array) -> LinearProblem:
        field, feats = sample_grf(self.spec, key)
        field = field / (jnp.std(field) + 1e-12)
        k_field = jnp.exp(self.sigma * field)
        coeffs = assemble_darcy_stencil(k_field, self.hx, self.hy)
        b = jnp.full((self.nx, self.ny), self.source, dtype=jnp.float64)
        return LinearProblem(
            op=Stencil5(coeffs),
            b=b,
            features=feats,
            no_input=k_field,
        )
