"""PDE substrate: problem families that assemble sequences of sparse linear
systems A^(i) x^(i) = b^(i) (paper Eq. 1) from parametrized PDEs.

All four paper datasets (Darcy, Thermal, Poisson, Helmholtz) discretize on
(masked) structured grids, so every operator is a 5-point stencil stored in
field form (`Stencil5`) or diagonal form (`DIA`) — the TPU-native layouts our
Pallas kernels consume (DESIGN.md §4.1).
"""
from repro.pde.dia import DIA, Stencil5, dia_matvec, stencil5_matvec
from repro.pde.problems import LinearProblem, ProblemFamily
from repro.pde.registry import (get_family, get_timedep_family,
                                list_families, list_timedep_families)
from repro.pde.timedep import TimeDepFamily, TrajectorySpec

__all__ = [
    "DIA",
    "Stencil5",
    "dia_matvec",
    "stencil5_matvec",
    "LinearProblem",
    "ProblemFamily",
    "TimeDepFamily",
    "TrajectorySpec",
    "get_family",
    "list_families",
    "get_timedep_family",
    "list_timedep_families",
]
