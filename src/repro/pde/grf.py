"""Gaussian random fields (GRF) — the parameter sampler behind the Darcy and
Helmholtz families (paper §6.1, App. D.2).

Spectral (Matérn-like) sampling: white noise shaped by the power spectrum
    sqrt_spec(k) ∝ scale * (4π²|k|² + τ²)^(−α/2)
via FFT. The white-noise tensor is the *latent*; its low-frequency block is
the sorting feature ("parameter matrix" P^(i) of Algorithm 1).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GRFSpec:
    nx: int
    ny: int
    alpha: float = 2.5
    tau: float = 7.0
    scale: float = 1.0
    feature_modes: int = 8  # low-frequency latent block kept for sorting


def _sqrt_spectrum(spec: GRFSpec, dtype=jnp.float64) -> jax.Array:
    kx = jnp.fft.fftfreq(spec.nx, d=1.0 / spec.nx).astype(dtype)
    ky = jnp.fft.fftfreq(spec.ny, d=1.0 / spec.ny).astype(dtype)
    k2 = (2 * jnp.pi) ** 2 * (kx[:, None] ** 2 + ky[None, :] ** 2)
    s = spec.scale * (k2 + spec.tau**2) ** (-spec.alpha / 2.0)
    return s.at[0, 0].set(0.0)  # zero-mean field


@partial(jax.jit, static_argnums=0)
def sample_grf(spec: GRFSpec, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (field (nx, ny) real f64, latent_features (2·m·m,)).

    The latent is the low-frequency complex spectrum (real/imag stacked):
    nearby latents ⇒ nearby fields, which is exactly the property the sorting
    pass exploits.
    """
    noise = jax.random.normal(key, (spec.nx, spec.ny), dtype=jnp.float64)
    coef = jnp.fft.fft2(noise) * _sqrt_spectrum(spec)
    field = jnp.real(jnp.fft.ifft2(coef))
    m = spec.feature_modes
    low = coef[:m, :m]
    feats = jnp.concatenate([jnp.real(low).ravel(), jnp.imag(low).ravel()])
    return field, feats


def sample_grf_batch(spec: GRFSpec, key: jax.Array, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: sample_grf(spec, k))(keys)
