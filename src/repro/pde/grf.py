"""Gaussian random fields (GRF) — the parameter sampler behind the Darcy and
Helmholtz families (paper §6.1, App. D.2), and the solution-space
perturbation source of the label-expansion stage (core/expand.py).

Spectral (Matérn-like) sampling: white noise shaped by the power spectrum
    sqrt_spec(k) ∝ scale * (4π²|k|² + τ²)^(−α/2)
via FFT. The white-noise tensor is the *latent*; its low-frequency block is
the sorting feature ("parameter matrix" P^(i) of Algorithm 1).

Key handling: batched draws derive per-draw keys with `jax.random.fold_in`
on the draw index, NOT `jax.random.split` on the batch size — so draw i of
`sample_grf_batch(spec, key, n)` depends only on (key, i), never on n.
That makes batched draws prefix-stable (the first m draws of a size-n
batch equal a size-m batch), identical whether the per-draw sampling runs
under `jax.vmap` or in a python loop, and lets consumers that fan keys out
themselves (the seeded expansion waves) reproduce any single draw from its
index alone.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GRFSpec:
    nx: int
    ny: int
    alpha: float = 2.5
    tau: float = 7.0
    scale: float = 1.0
    feature_modes: int = 8  # low-frequency latent block kept for sorting


def _sqrt_spectrum(spec: GRFSpec, dtype=jnp.float64) -> jax.Array:
    kx = jnp.fft.fftfreq(spec.nx, d=1.0 / spec.nx).astype(dtype)
    ky = jnp.fft.fftfreq(spec.ny, d=1.0 / spec.ny).astype(dtype)
    k2 = (2 * jnp.pi) ** 2 * (kx[:, None] ** 2 + ky[None, :] ** 2)
    s = spec.scale * (k2 + spec.tau**2) ** (-spec.alpha / 2.0)
    return s.at[0, 0].set(0.0)  # zero-mean field


@partial(jax.jit, static_argnums=(0, 2))
def sample_grf(spec: GRFSpec, key: jax.Array,
               dtype=jnp.float64) -> tuple[jax.Array, jax.Array]:
    """Returns (field (nx, ny) real, latent_features (2·m·m,)), both `dtype`.

    The latent is the low-frequency complex spectrum (real/imag stacked):
    nearby latents ⇒ nearby fields, which is exactly the property the sorting
    pass exploits. `dtype` selects the noise/spectrum precision — fp32 draws
    run the FFT in complex64 (the label-expansion waves perturb fp64 anchors
    but may sample perturbation fields in fp32).
    """
    noise = jax.random.normal(key, (spec.nx, spec.ny), dtype=dtype)
    coef = jnp.fft.fft2(noise) * _sqrt_spectrum(spec, dtype=dtype)
    field = jnp.real(jnp.fft.ifft2(coef))
    m = spec.feature_modes
    low = coef[:m, :m]
    feats = jnp.concatenate([jnp.real(low).ravel(), jnp.imag(low).ravel()])
    return field, feats


def batch_keys(key: jax.Array, n) -> jax.Array:
    """Per-draw keys for a batch: key i = fold_in(key, i). `n` may be an
    int or an index array (reproducing an arbitrary subset of draws)."""
    idx = jnp.arange(n) if isinstance(n, int) else jnp.asarray(n)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)


def sample_grf_batch(spec: GRFSpec, key: jax.Array, n: int,
                     dtype=jnp.float64):
    """n independent draws, vmapped. Draw i equals
    `sample_grf(spec, fold_in(key, i), dtype)` exactly — see the module
    docstring for the reproducibility contract."""
    return jax.vmap(lambda k: sample_grf(spec, k, dtype))(batch_keys(key, n))
