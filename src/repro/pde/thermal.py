"""Thermal family (paper App. D.2.2): steady-state heat equation ∂²T/∂x² +
∂²T/∂y² = 0 on an IRREGULAR domain (paper Fig. 6 uses a blob-shaped FEM mesh).

We carve an irregular star-shaped domain r(θ) = r0·(1 + ε·sin 3θ + ε₂·cos 5θ)
out of the unit square (embedded-boundary FDM): nodes outside the domain get
identity rows; interior nodes adjacent to the boundary absorb the Dirichlet
temperature into b. Left/right boundary temperatures are uniform random in
[-100, 0] / [0, 100] (the sorting features). The matrix is FIXED across the
sequence — only b varies — matching the paper's setup where recycling shines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.pde.dia import Stencil5
from repro.pde.problems import LinearProblem, ProblemFamily, interior_linspace


def _star_mask(nx: int, ny: int) -> np.ndarray:
    gx = np.asarray(interior_linspace(nx))
    gy = np.asarray(interior_linspace(ny))
    xx, yy = np.meshgrid(gx, gy, indexing="ij")
    dx, dy = xx - 0.5, yy - 0.5
    r = np.sqrt(dx**2 + dy**2)
    th = np.arctan2(dy, dx)
    r_b = 0.40 * (1.0 + 0.18 * np.sin(3 * th) + 0.08 * np.cos(5 * th))
    return r < r_b  # True = interior


class ThermalFamily(ProblemFamily):
    name = "thermal"

    def __init__(self, nx: int = 96, ny: int = 96):
        super().__init__(nx, ny)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)
        mask = _star_mask(nx, ny)
        self.mask = jnp.asarray(mask)

        cx, cy = 1.0 / self.hx**2, 1.0 / self.hy**2
        m = mask.astype(np.float64)
        # Neighbor interior indicators (0 at grid edge).
        up = np.zeros_like(m); up[1:, :] = m[:-1, :]
        dn = np.zeros_like(m); dn[:-1, :] = m[1:, :]
        lf = np.zeros_like(m); lf[:, 1:] = m[:, :-1]
        rt = np.zeros_like(m); rt[:, :-1] = m[:, 1:]

        c = np.where(mask, -2.0 * (cx + cy), 1.0)  # identity rows outside
        n = np.where(mask, cx * up, 0.0)
        s = np.where(mask, cx * dn, 0.0)
        w = np.where(mask, cy * lf, 0.0)
        e = np.where(mask, cy * rt, 0.0)
        self._coeffs = jnp.asarray(np.stack([c, n, s, w, e]))

        # b-template: for each interior node, the weight with which the
        # boundary temperature profile enters the RHS:
        #   b = -Σ_dir c_dir · T_bc(node)   over legs that exit the domain.
        n_ghost = np.where(mask, cx * (1.0 - up), 0.0)
        s_ghost = np.where(mask, cx * (1.0 - dn), 0.0)
        w_ghost = np.where(mask, cy * (1.0 - lf), 0.0)
        e_ghost = np.where(mask, cy * (1.0 - rt), 0.0)
        ghost_w = n_ghost + s_ghost + w_ghost + e_ghost  # total exiting weight
        gx = np.asarray(interior_linspace(nx))
        xhat = (gx[:, None] - gx.min()) / (gx.max() - gx.min())
        xhat = np.broadcast_to(xhat, (nx, ny))
        self._ghost_w = jnp.asarray(ghost_w)
        self._xhat = jnp.asarray(xhat)

    def sample(self, key: jax.Array) -> LinearProblem:
        kl, kr = jax.random.split(key)
        t_left = jax.random.uniform(kl, (), jnp.float64, -100.0, 0.0)
        t_right = jax.random.uniform(kr, (), jnp.float64, 0.0, 100.0)
        # Boundary temperature profile interpolates left→right across x.
        t_bc = t_left * (1.0 - self._xhat) + t_right * self._xhat
        b = -self._ghost_w * t_bc
        features = jnp.stack([t_left, t_right])
        return LinearProblem(
            op=Stencil5(self._coeffs),
            b=b,
            features=features,
            no_input=t_bc * self.mask,
        )
