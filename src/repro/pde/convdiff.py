"""Convection–diffusion family (beyond-paper, DESIGN.md §8): the paper
stresses that NO-generated systems are "typically non-symmetric", but its four
benchmark families all discretize to (skew-free) symmetric stencils. This
family supplies a genuinely nonsymmetric sequence to exercise the
GMRES/GCRO-DR nonsymmetric code paths end-to-end:

    −ν∇²u + v(x,y)·∇u = f,   v = rot(GRF stream function)  (divergence-free)

First-order upwinding keeps the M-matrix property; nonsymmetry scales with
the Péclet number."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pde.dia import Stencil5
from repro.pde.grf import GRFSpec, sample_grf
from repro.pde.problems import LinearProblem, ProblemFamily


class ConvDiffFamily(ProblemFamily):
    name = "convdiff"

    def __init__(self, nx: int = 64, ny: int = 64, nu: float = 1.0, vmax: float = 50.0):
        super().__init__(nx, ny)
        self.nu = nu
        self.vmax = vmax
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=3.0, tau=8.0, scale=nx**1.5)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)

    def sample(self, key: jax.Array) -> LinearProblem:
        field, feats = sample_grf(self.spec, key)
        psi = field / (jnp.std(field) + 1e-12)
        # v = (∂ψ/∂y, −∂ψ/∂x): divergence-free velocity.
        vx = (jnp.roll(psi, -1, 1) - jnp.roll(psi, 1, 1)) / (2 * self.hy) * 0.0 + \
             jnp.gradient(psi, self.hy, axis=1)
        vy = -jnp.gradient(psi, self.hx, axis=0)
        scale = self.vmax / (jnp.max(jnp.sqrt(vx**2 + vy**2)) + 1e-12)
        vx, vy = vx * scale, vy * scale

        cx = self.nu / self.hx**2
        cy = self.nu / self.hy**2
        # Upwind convection: coefficient of u_{i±1,j} depends on sign(vx).
        axp = jnp.maximum(vx, 0.0) / self.hx   # flow in +x: uses u_{i-1}
        axm = jnp.maximum(-vx, 0.0) / self.hx  # flow in -x: uses u_{i+1}
        ayp = jnp.maximum(vy, 0.0) / self.hy
        aym = jnp.maximum(-vy, 0.0) / self.hy

        n = -(cx + axp)
        s = -(cx + axm)
        w = -(cy + ayp)
        e = -(cy + aym)
        c = 2.0 * (cx + cy) + axp + axm + ayp + aym
        n = n.at[0, :].set(0.0)
        s = s.at[-1, :].set(0.0)
        w = w.at[:, 0].set(0.0)
        e = e.at[:, -1].set(0.0)
        coeffs = jnp.stack([c, n, s, w, e])
        b = jnp.ones((self.nx, self.ny), jnp.float64)
        return LinearProblem(op=Stencil5(coeffs), b=b, features=feats, no_input=psi)
