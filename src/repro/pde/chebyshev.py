"""Truncated Chebyshev polynomial samplers — the parameter source for the
Poisson family (paper App. D.2: boundary conditions on all four sides + the
source f generated from truncated Chebyshev series; the coefficients of the
five series are the sorting basis)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chebyshev_eval(coeffs: jax.Array, x: jax.Array) -> jax.Array:
    """Evaluate sum_k coeffs[..., k] T_k(x) with x in [-1, 1] via the
    Clenshaw-free direct recurrence (degree is small and static)."""
    deg = coeffs.shape[-1]
    t_prev = jnp.ones_like(x)
    out = coeffs[..., 0] * t_prev
    if deg == 1:
        return out
    t_cur = x
    out = out + coeffs[..., 1] * t_cur
    for k in range(2, deg):
        t_next = 2.0 * x * t_cur - t_prev
        out = out + coeffs[..., k] * t_next
        t_prev, t_cur = t_cur, t_next
    return out


def chebyshev_eval2d(cxy: jax.Array, gx: jax.Array, gy: jax.Array) -> jax.Array:
    """Tensor-product series sum_{k,l} cxy[k,l] T_k(gx) T_l(gy) on a grid."""
    deg = cxy.shape[-1]
    tx = _cheb_basis(gx, deg)  # (nx, deg)
    ty = _cheb_basis(gy, deg)  # (ny, deg)
    return jnp.einsum("kl,ik,jl->ij", cxy, tx, ty)


def _cheb_basis(x: jax.Array, deg: int) -> jax.Array:
    cols = [jnp.ones_like(x)]
    if deg > 1:
        cols.append(x)
    for _ in range(2, deg):
        cols.append(2.0 * x * cols[-1] - cols[-2])
    return jnp.stack(cols, axis=-1)


def sample_cheb_coeffs(key: jax.Array, shape, decay: float = 1.5) -> jax.Array:
    """Random coefficients with spectral decay k^(−decay) so low orders
    dominate — mirrors chebfun's smooth random functions (Driscoll et al.)."""
    c = jax.random.normal(key, shape, dtype=jnp.float64)
    deg = shape[-1]
    w = (1.0 + jnp.arange(deg, dtype=jnp.float64)) ** (-decay)
    if len(shape) == 2 and shape[0] == shape[1]:
        return c * w[:, None] * w[None, :]
    return c * w
