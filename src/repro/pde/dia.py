"""Sparse operator containers: DIA (diagonal) and Stencil5 (2-D 5-point field
form).

Why not CSR: the paper's PETSc implementation uses CSR SpMV, which needs
gathers — hostile to the TPU memory system. Every paper problem family lives
on a structured grid, so the matrix is banded; storing diagonals densely turns
SpMV into shifted elementwise multiplies (VPU) with unit-stride loads, and the
2-D stencil form tiles directly into VMEM blocks (see kernels/stencil_matvec).

Both containers are registered as pytrees so they pass through jit/vmap/scan;
`offsets` (static) ride in the treedef.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# leading-axis gather, staged so index normalization happens inside the
# trace (transfer-guard-clean; caches on shapes, so per-wave index VALUES
# never recompile)
_gather = jax.jit(lambda a, i: a[i])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DIA:
    """Diagonal sparse matrix: A[i, i + offsets[d]] = data[d, i].

    data rows are aligned to the *row* index i (PETSc/scipy "dia" uses column
    alignment; row alignment keeps the matvec branch-free).
    """

    offsets: Tuple[int, ...]  # static
    data: jax.Array  # (ndiag, n)

    @property
    def n(self) -> int:
        return self.data.shape[-1]

    @property
    def dtype(self):
        return self.data.dtype

    def tree_flatten(self):
        return (self.data,), self.offsets

    @classmethod
    def tree_unflatten(cls, offsets, children):
        return cls(offsets=offsets, data=children[0])

    def matvec(self, x: jax.Array) -> jax.Array:
        return dia_matvec(self, x)

    def take(self, idx) -> "DIA":
        """Select system(s) along the leading batch axis of `data` — the
        batched-engine companion of `Stencil5.take`."""
        return DIA(offsets=self.offsets, data=self.data[idx])

    def diagonal(self) -> jax.Array:
        d = self.offsets.index(0)
        return self.data[..., d, :]

    def to_dense(self) -> np.ndarray:
        """Dense numpy copy (test oracle only)."""
        n = self.n
        a = np.zeros((n, n), dtype=np.asarray(self.data).dtype)
        data = np.asarray(self.data)
        for d, off in enumerate(self.offsets):
            if off >= 0:
                idx = np.arange(n - off)
                a[idx, idx + off] = data[d, : n - off]
            else:
                idx = np.arange(-off, n)
                a[idx, idx + off] = data[d, -off:]
        return a

    def to_scipy(self):
        """scipy.sparse CSR copy (test/benchmark oracle only)."""
        import scipy.sparse as sp

        return sp.csr_matrix(self.to_dense())

    def transpose(self) -> "DIA":
        n = self.n
        new_offsets = tuple(-o for o in self.offsets)
        rows = []
        for d, off in enumerate(self.offsets):
            # A^T[j, j - off] = A[j - (-off)?]. A[i, i+off]=data[d,i] means
            # A^T[i+off, i] = data[d, i]; with r = i+off: A^T[r, r-off] =
            # data[d, r-off] -> shift row by +off.
            rows.append(_shift(self.data[d], off))
        return DIA(offsets=new_offsets, data=jnp.stack(rows))


def _shift(v: jax.Array, off: int) -> jax.Array:
    """v shifted so out[i] = v[i - off], zero-filled."""
    n = v.shape[-1]
    if off == 0:
        return v
    if off > 0:
        return jnp.concatenate([jnp.zeros((off,), v.dtype), v[: n - off]])
    return jnp.concatenate([v[-off:], jnp.zeros((-off,), v.dtype)])


def dia_matvec(a: DIA, x: jax.Array) -> jax.Array:
    """y[i] = sum_d data[d, i] * x[i + offsets[d]] (zero outside range).

    Supports batched data (…, ndiag, n) against x (…, n) via broadcasting of
    the leading dims.
    """
    n = a.n
    y = jnp.zeros(jnp.broadcast_shapes(a.data[..., 0, :].shape, x.shape), x.dtype)
    for d, off in enumerate(a.offsets):
        row = a.data[..., d, :]
        if off == 0:
            y = y + row * x
        elif off > 0:
            contrib = row[..., : n - off] * x[..., off:]
            y = y.at[..., : n - off].add(contrib)
        else:
            contrib = row[..., -off:] * x[..., : n + off]
            y = y.at[..., -off:].add(contrib)
    return y


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Stencil5:
    """2-D 5-point stencil in field form on an (nx, ny) grid.

    y[i,j] = c[i,j] x[i,j] + n[i,j] x[i-1,j] + s[i,j] x[i+1,j]
           + w[i,j] x[i,j-1] + e[i,j] x[i,j+1]          (zero outside grid)

    coeffs: (5, nx, ny) stacked as [c, n, s, w, e].
    """

    coeffs: jax.Array  # (5, nx, ny)

    C, N, S, W, E = 0, 1, 2, 3, 4

    @property
    def grid(self) -> Tuple[int, int]:
        return self.coeffs.shape[-2], self.coeffs.shape[-1]

    @property
    def n(self) -> int:
        nx, ny = self.grid
        return nx * ny

    @property
    def dtype(self):
        return self.coeffs.dtype

    def tree_flatten(self):
        return (self.coeffs,), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(coeffs=children[0])

    def matvec(self, x: jax.Array) -> jax.Array:
        return stencil5_matvec(self.coeffs, x)

    def take(self, idx) -> "Stencil5":
        """Batched indexing: coeffs may carry leading batch dims
        (B, 5, nx, ny); `take` selects chains/systems along the first one.
        `idx` may be an int or an index array (gathering a (B, 5, nx, ny)
        stacked operator for the lockstep solver from a dataset batch).
        Array gathers run jitted — this is the per-wave hot path of both
        the offline prefetch and the streaming scheduler, and staging it
        keeps index normalization off the eager dispatch path."""
        if getattr(idx, "ndim", 0):
            return Stencil5(coeffs=_gather(self.coeffs,
                                           jnp.asarray(np.asarray(idx))))
        return Stencil5(coeffs=self.coeffs[idx])

    def diagonal(self) -> jax.Array:
        return self.coeffs[..., self.C, :, :].reshape(*self.coeffs.shape[:-3], -1)

    def to_dia(self) -> DIA:
        """Row-major flattening: offsets (-ny, -1, 0, 1, ny)."""
        nx, ny = self.grid
        c = self.coeffs
        flat = lambda k: c[..., k, :, :].reshape(*c.shape[:-3], nx * ny)
        # Interior-edge wrap guard: W at j=0 and E at j=ny-1 are zero by
        # construction in every assembler (they multiply out-of-grid nodes).
        data = jnp.stack(
            [flat(self.N), flat(self.W), flat(self.C), flat(self.E), flat(self.S)],
            axis=-2,
        )
        return DIA(offsets=(-ny, -1, 0, 1, ny), data=data)

    def to_dense(self) -> np.ndarray:
        return self.to_dia().to_dense()


def stencil5_matvec(coeffs: jax.Array, x: jax.Array) -> jax.Array:
    """Pure-jnp stencil matvec; batched over leading dims of both args.

    coeffs: (..., 5, nx, ny); x: (..., nx, ny).
    """
    c = coeffs
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(1, 1), (1, 1)])
    up = xp[..., :-2, 1:-1]
    down = xp[..., 2:, 1:-1]
    left = xp[..., 1:-1, :-2]
    right = xp[..., 1:-1, 2:]
    return (
        c[..., Stencil5.C, :, :] * x
        + c[..., Stencil5.N, :, :] * up
        + c[..., Stencil5.S, :, :] * down
        + c[..., Stencil5.W, :, :] * left
        + c[..., Stencil5.E, :, :] * right
    )


def laplacian_stencil(nx: int, ny: int, dx: float, dy: float, dtype=jnp.float64) -> jax.Array:
    """Constant-coefficient 5-point Laplacian coeffs (Dirichlet-0 off-grid)."""
    cx = 1.0 / dx**2
    cy = 1.0 / dy**2
    c = jnp.full((nx, ny), -2.0 * (cx + cy), dtype)
    n = jnp.full((nx, ny), cx, dtype)
    s = jnp.full((nx, ny), cx, dtype)
    w = jnp.full((nx, ny), cy, dtype)
    e = jnp.full((nx, ny), cy, dtype)
    return jnp.stack([c, n, s, w, e])


def zero_boundary_neighbors(coeffs: jax.Array) -> jax.Array:
    """Zero the stencil legs that reach outside the grid (Dirichlet rows own
    their boundary contribution via the RHS)."""
    c = coeffs
    c = c.at[..., Stencil5.N, 0, :].set(0.0)
    c = c.at[..., Stencil5.S, -1, :].set(0.0)
    c = c.at[..., Stencil5.W, :, 0].set(0.0)
    c = c.at[..., Stencil5.E, :, -1].set(0.0)
    return c
