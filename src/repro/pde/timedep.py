"""Time-dependent PDE workloads: θ-scheme implicit time stepping over the
existing 5-point spatial operators (the trajectory-datagen subsystem).

Sequences of implicit time-stepping solves are the textbook sweet spot for
Krylov subspace recycling: within one trajectory the system matrix

    A_t = I + θ Δt L(t_{n+1})            (θ-scheme, mass matrix = I)

drifts SLOWLY with t (time-varying coefficients), so the GCRO-DR recycle
space carried from step n is near-invariant for step n+1 — no sorting needed
inside a trajectory, the physics already orders the systems. Each implicit
step solves

    (I + θ Δt L_{n+1}) u_{n+1} = (I − (1−θ) Δt L_n) u_n
                                 + Δt (θ f_{n+1} + (1−θ) f_n)

with θ = 1 (backward Euler, O(Δt)) or θ = 1/2 (Crank–Nicolson, O(Δt²)).
L(t) is any 5-point `Stencil5` spatial operator in the POSITIVE-definite
convention (L = −∇·(K∇·) + convection), so A_t is an M-matrix shifted by
identity — far better conditioned than L itself.

A `TimeDepFamily` plays the role `ProblemFamily` plays for steady systems:
it samples per-trajectory latents (`TrajectorySpec` pytrees: initial
condition, coefficient latents, sorting features) and exports each time step
as a `Stencil5`-operator linear system. Everything is vmap-safe, so the
lockstep engine in `core/trajectory.py` advances W trajectories through one
batched device program per step. Time enters `step_system` as a TRACED
scalar: one jitted step executable serves every step of every trajectory.

Families registered in `pde/registry.py`:
  heat        ∂u/∂t = ∇·(K(x,y,t)∇u),  K = exp(σ g(t)) with the GRF latent
              g(t) drifting linearly between two endpoint fields g₀ → g₁
  convdiff-t  ∂u/∂t = ν∇²u − v(x,y,t)·∇u, v = a rigidly ROTATING copy of a
              GRF-stream-function velocity field (first-order upwind —
              nonsymmetric A_t, M-matrix preserved)
  wave        M ∂²u/∂t² = ∇·(c(x,y)²∇u) in first-order form (u, v = u_t),
              compact 5-point mass matrix M ≠ I — each implicit step still
              exports ONE Stencil5 system (β₀²M + Δt²K) u_{n+1} = rhs

THE STEPPING STACK (beyond the fixed-Δt θ-scheme):

* Mass matrices: `MassMatrix` wraps an SPD 5-point stencil M (DIA export via
  `to_dia()`); the implicit step generalizes from I + θΔtL to β₀M + γΔtL.
  Families opt in via the `mass()` hook (None = identity, the historical
  path — kept bitwise-identical by routing, see `classic` below).
* BDF2: `integrator="bdf2"` uses the variable-step two-step formula
      (β₀ u_{n+1} − α₁ u_n + α₂ u_{n−1}) / Δtₙ = −L u_{n+1} + f,
      ρ = Δtₙ/Δtₙ₋₁, β₀ = (1+2ρ)/(1+ρ), α₁ = 1+ρ, α₂ = ρ²/(1+ρ)
  with a θ-scheme bootstrap on each trajectory's first step (θ = 1/2 keeps
  the global order at 2). O(Δt²) at ~the per-step cost of backward Euler.
* Adaptive Δt: `AdaptConfig` + `PIStepController` — an embedded local-error
  estimate (predictor–corrector difference: the implicit solution against
  the variable-step extrapolant of the method's order) drives a standard
  PI controller (accept/reject + step growth). Controller decisions are
  QUANTIZED to 2 significant digits so the ~1e-9 float-reassociation drift
  between the sequential and lockstep engines can never fork the Δt
  sequence: both engines take bitwise-identical step paths, which is what
  makes the phase-masked lockstep equivalence testable. Consecutive
  operators differ only through the Δtₙ drift — exactly the "inherent
  similarity" regime recycling targets — so the GCRO-DR carry rides across
  accepted AND rejected steps.

The generalized stack marches a `StepState` pytree (u, history, auxiliary
first-order state) through family hooks `build_step` / `step_eval`; the
fixed-Δt M = I θ-scheme (`classic` families) keeps the ORIGINAL
`step_system` code path untouched, bitwise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.pde.dia import (DIA, Stencil5, stencil5_matvec,
                           zero_boundary_neighbors)
from repro.pde.grf import GRFSpec, sample_grf
from repro.pde.problems import ProblemFamily


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TrajectorySpec:
    """One sampled trajectory: IC + coefficient latents + metadata.

    u0        : (nx, ny) initial condition
    latent    : family-specific pytree the spatial operator L(t) is built
                from (e.g. the two endpoint GRF fields of the heat drift)
    features  : (f,) sorting features at t = 0 — IC latent + operator
                latent, what `core/sorting.py` measures trajectory
                similarity on (adjacent trajectories share recycle spaces)
    no_input  : (nx, ny) static neural-operator conditioning channel
                (e.g. K(·, 0) for heat); the state u_t is the other channel
    """

    u0: jax.Array
    latent: Any
    features: jax.Array
    no_input: jax.Array

    def tree_flatten(self):
        return (self.u0, self.latent, self.features, self.no_input), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class MassMatrix:
    """SPD mass matrix in 5-point stencil field form (the M of M u_t = −Lu).

    Stored exactly like the spatial operators (Stencil5 coeffs, so the
    implicit-step system β₀M + γΔtL assembles as one stencil add and stays
    on the existing batched/sharded SpMV paths); `to_dia()` exports the DIA
    banded form for the dense/scipy test oracles. Constructors guarantee
    positive diagonal + weak diagonal dominance, so β₀M + γΔtL inherits the
    M-matrix-shifted conditioning story of the θ-scheme."""

    coeffs: jax.Array  # (5, nx, ny)

    def tree_flatten(self):
        return (self.coeffs,), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(coeffs=children[0])

    def matvec(self, x: jax.Array) -> jax.Array:
        return stencil5_matvec(self.coeffs, x)

    def as_stencil5(self) -> Stencil5:
        return Stencil5(self.coeffs)

    def to_dia(self) -> DIA:
        return Stencil5(self.coeffs).to_dia()

    @staticmethod
    def identity(nx: int, ny: int) -> "MassMatrix":
        c = jnp.zeros((5, nx, ny), jnp.float64).at[Stencil5.C].set(1.0)
        return MassMatrix(c)

    @staticmethod
    def compact(nx: int, ny: int) -> "MassMatrix":
        """The compact (Numerov-type) mass M = I + (hx²/12)Dxx + (hy²/12)Dyy
        — the standard 5-point consistent-mass surrogate (4th-order spatial
        pairing with the Laplacian). On a uniform grid the h² factors cancel
        against Dxx's 1/h² entries, so the stencil is spacing-free: center
        1 − 4/12 = 2/3, legs +1/12; eigenvalues in (1/3, 1): SPD,
        diagonally dominant, M ≠ I."""
        c = jnp.full((nx, ny), 1.0 - 4.0 / 12.0, jnp.float64)
        leg = jnp.full((nx, ny), 1.0 / 12.0, jnp.float64)
        return MassMatrix(zero_boundary_neighbors(
            jnp.stack([c, leg, leg, leg, leg])))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StepState:
    """Integrator state marched by the generalized stepping stack.

    u        : current field u(t) — the recorded label channel
    u_prev   : u one ACCEPTED step back (BDF2 history / linear predictor)
    u_pprev  : u two accepted steps back (quadratic predictor for the BDF2
               embedded error estimate)
    v        : auxiliary first-order state (wave: velocity u_t), zeros for
               parabolic families
    v_prev   : v one accepted step back (wave BDF2 history)

    All five slots are (nx, ny) fields (unused ones ride as zeros — tiny on
    these grids, and a uniform pytree is what lets ONE vmapped device select
    advance/reject every chain of a lockstep row)."""

    u: jax.Array
    u_prev: jax.Array
    u_pprev: jax.Array
    v: jax.Array
    v_prev: jax.Array

    def tree_flatten(self):
        return (self.u, self.u_prev, self.u_pprev, self.v, self.v_prev), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """PI-controller adaptive-Δt policy (per trajectory).

    step_tol  : relative local-error target per step — the embedded
                estimate ‖u_{n+1} − u_pred‖/‖u_{n+1}‖ a step must meet to
                be ACCEPTED (predictor constants are absorbed here)
    dt_init   : first trial step (None → the family's dt)
    dt_min/max: hard Δt clamps (dt_min also breaks rejection death spirals)
    safety    : classic headroom factor on the controller's step proposal
    fac_min/max: per-step growth/shrink clamps
    kp, ki    : PI gains, applied as exponents /(p+1) with p the method
                order (the textbook elementary PI controller)
    max_steps : per-trajectory solve budget (accepted + rejected); an
                exhausted trajectory freezes and its remaining save points
                repeat the last field (the lockstep engine masks it as a
                zero-RHS padded row from then on)
    """

    step_tol: float = 1e-3
    dt_init: Optional[float] = None
    dt_min: float = 1e-9
    dt_max: float = math.inf
    safety: float = 0.9
    fac_min: float = 0.2
    fac_max: float = 4.0
    kp: float = 0.4
    ki: float = 0.3
    max_steps: int = 10_000

    def __post_init__(self):
        assert 0.0 < self.step_tol < 1.0
        assert 0.0 < self.fac_min < 1.0 < self.fac_max
        assert self.max_steps >= 1


def quantize_sig(v: float, digits: int = 2) -> float:
    """Round to `digits` significant decimal digits.

    Controller inputs (error estimates) and outputs (step factors) are
    quantized so the ~1e-9 relative float-reassociation drift between the
    sequential and lockstep solvers cannot flip an accept/reject or fork
    the Δt sequence: a flip would need the exact value to sit within 1e-9
    of a 1e-2-spaced rounding boundary. Both engines therefore take
    bitwise-identical step paths (the property the phase-masked lockstep
    equivalence tests pin)."""
    if v == 0.0 or not math.isfinite(v):
        return v
    p = digits - 1 - math.floor(math.log10(abs(v)))
    return round(v, p)


class PIStepController:
    """Per-trajectory PI step-size controller over the embedded estimate.

    Pure host-float logic shared VERBATIM by the sequential and lockstep
    engines (one copy ⇒ identical decisions). The controller owns Δt
    bookkeeping: trial step proposal (clamped/stretched to land exactly on
    the uniform save grid), accept/reject, PI growth, and the accepted-step
    history (Δtₙ₋₁, Δtₙ₋₂) the variable-step BDF2 coefficients and the
    quadratic predictor need."""

    def __init__(self, cfg: AdaptConfig, order: int, dt0: float):
        self.cfg = cfg
        self.order = int(order)
        self.dt = float(min(max(cfg.dt_init or dt0, cfg.dt_min), cfg.dt_max))
        self.dt_prev = self.dt    # last ACCEPTED step (BDF2 ρ denominator)
        self.dt_pprev = self.dt   # one before (quadratic predictor gap)
        self.err_prev = 1.0       # previous accepted est/step_tol ratio
        self.naccept = 0          # accepted steps (drives bootstrap flags)
        self.nsolves = 0          # accepted + rejected (budget)
        self.dt_bad = math.inf    # smallest Δt REJECTED at the current
        #                           position (reset on accept): the error
        #                           estimate is deterministic per (state, t,
        #                           Δt), so re-trying a rejected size is
        #                           guaranteed futile

    # -- trial step ------------------------------------------------------
    def propose(self, remaining: float) -> float:
        """Trial Δt for this solve: the controller step, stretched up to
        1.25x (or clipped) to land EXACTLY on the next save time. The
        stretch never violates the dt_max hard cap (when the remaining
        interval exceeds dt_max the controller just steps dt and lands on
        the save boundary a step later), and never re-proposes a step the
        estimator already rejected at this position — without the `dt_bad`
        guard, a marginal rejection (shrink factor > 1/1.25) would be
        stretched straight back to the rejected size and the controller
        would livelock on the save boundary."""
        dt = self.dt
        if (1.25 * dt >= remaining and remaining <= self.cfg.dt_max
                and remaining < self.dt_bad):
            dt = remaining
        return dt

    # -- decision --------------------------------------------------------
    def decide(self, est: float, dt_used: float) -> bool:
        """Accept/reject `dt_used` given the embedded estimate; updates the
        controller state either way and returns the verdict. A failing step
        already at (or below) the dt_min floor is accepted anyway — the
        controller cannot do better, and rejecting it forever would only
        re-solve the identical system until the budget froze the trajectory
        (dt_min's documented death-spiral guard)."""
        c = self.cfg
        self.nsolves += 1
        est_q = quantize_sig(est)
        if not math.isfinite(est_q):      # solver blew up: halve and retry
            if dt_used <= c.dt_min:
                raise FloatingPointError(
                    "adaptive step produced a non-finite error estimate at "
                    "the dt_min floor")
            self.dt = max(0.5 * dt_used, c.dt_min)
            self.dt_bad = min(self.dt_bad, dt_used)
            return False
        e = max(est_q / c.step_tol, 1e-12)
        p1 = self.order + 1
        if e <= 1.0 or dt_used <= c.dt_min:
            fac = (c.safety * e ** (-(c.ki + c.kp) / p1)
                   * self.err_prev ** (c.kp / p1))
            fac = quantize_sig(min(max(fac, c.fac_min), c.fac_max))
            self.dt_pprev = self.dt_prev
            self.dt_prev = dt_used
            # growth base: the controller's own step, not a save-boundary
            # clip — a tiny landing step must not collapse the step size
            # (the clip carries no error information; the next full step
            # can jump straight back, and if the jump's BDF2 ratio is too
            # aggressive the estimate rejects it and halves, so accuracy
            # still owns the outcome)
            self.dt = min(max(max(dt_used, self.dt) * fac, c.dt_min),
                          c.dt_max)
            self.err_prev = e
            self.naccept += 1
            self.dt_bad = math.inf    # new position: old rejections void
            return True
        fac = quantize_sig(min(max(c.safety * e ** (-1.0 / p1), c.fac_min),
                               0.9))
        self.dt = max(dt_used * fac, c.dt_min)
        self.dt_bad = min(self.dt_bad, dt_used)
        return False

    @property
    def boot(self) -> bool:
        """True until the first accepted step: BDF2 runs its θ-scheme
        bootstrap, the predictor has no history."""
        return self.naccept == 0

    @property
    def exhausted(self) -> bool:
        return self.nsolves >= self.cfg.max_steps


def assemble_diffusion_stencil(k_field: jax.Array, hx: float, hy: float) -> jax.Array:
    """(5, nx, ny) coeffs of L = −∇·(K∇·) on the NODE-centred Dirichlet-0
    grid (x_i = i·hx, i = 1..nx): interior faces take harmonic-mean
    transmissibilities, wall faces the node's own K. With K ≡ 1 this reduces
    EXACTLY to the standard 5-point Laplacian — the property the θ-scheme
    order-of-accuracy test keys on (discrete sine eigenvectors)."""
    def harmonic(a, b):
        return 2.0 * a * b / (a + b)

    kx_face = harmonic(k_field[:-1, :], k_field[1:, :])
    ky_face = harmonic(k_field[:, :-1], k_field[:, 1:])
    kx_n = jnp.concatenate([k_field[:1, :], kx_face], axis=0)
    kx_s = jnp.concatenate([kx_face, k_field[-1:, :]], axis=0)
    ky_w = jnp.concatenate([k_field[:, :1], ky_face], axis=1)
    ky_e = jnp.concatenate([ky_face, k_field[:, -1:]], axis=1)

    cx = 1.0 / hx**2
    cy = 1.0 / hy**2
    n = -cx * kx_n
    s = -cx * kx_s
    w = -cy * ky_w
    e = -cy * ky_e
    c = -(n + s + w + e)
    return zero_boundary_neighbors(jnp.stack([c, n, s, w, e]))


def assemble_upwind_convection(vx: jax.Array, vy: jax.Array, nu: float,
                               hx: float, hy: float) -> jax.Array:
    """(5, nx, ny) coeffs of L = −ν∇² + v·∇ with first-order upwinding
    (M-matrix for any v; nonsymmetry scales with the Péclet number)."""
    cx = nu / hx**2
    cy = nu / hy**2
    axp = jnp.maximum(vx, 0.0) / hx
    axm = jnp.maximum(-vx, 0.0) / hx
    ayp = jnp.maximum(vy, 0.0) / hy
    aym = jnp.maximum(-vy, 0.0) / hy
    n = -(cx + axp)
    s = -(cx + axm)
    w = -(cy + ayp)
    e = -(cy + aym)
    c = 2.0 * (cx + cy) + axp + axm + ayp + aym
    return zero_boundary_neighbors(jnp.stack([c, n, s, w, e]))


class TimeDepFamily(ProblemFamily):
    """Base class for trajectory workloads (the time-dependent analogue of
    `ProblemFamily`). Subclasses implement `sample_spec` and
    `spatial_coeffs(latent, t)`; the θ-scheme export is shared.

    nt / dt / theta are trajectory-level constants. With the default
    fixed-Δt θ-scheme every trajectory marches the same nt steps of size dt
    (lockstep rows align for free); `integrator="bdf2"` and/or an
    `AdaptConfig` route through the generalized stepping stack instead —
    nt·dt then defines the UNIFORM SAVE GRID (labels stay (nt+1, nx, ny)
    and comparable across engines) while the internal steps float, and the
    lockstep engine phase-masks chains that stepped at different rates."""

    name = "timedep-base"

    def __init__(self, nx: int, ny: int, nt: int = 10, dt: float = 1e-3,
                 theta: float = 1.0, integrator: str = "theta",
                 adapt: Optional[AdaptConfig] = None):
        super().__init__(nx, ny)
        assert nt >= 1 and dt > 0.0 and 0.0 < theta <= 1.0
        assert integrator in ("theta", "bdf2")
        self.nt = int(nt)
        self.dt = float(dt)
        self.theta = float(theta)
        self.integrator = integrator
        self.adapt = adapt
        self._step1 = None
        self._stepB = None
        self._stepS = None
        self._build1 = None
        self._buildB = None
        self._eval1 = None
        self._evalB = None

    @property
    def order(self) -> int:
        """Temporal order of accuracy (drives the PI controller exponents
        and the embedded predictor's degree)."""
        if self.integrator == "bdf2":
            return 2
        return 2 if self.theta == 0.5 else 1

    @property
    def classic(self) -> bool:
        """True ⇒ the ORIGINAL fixed-Δt, M = I, θ-scheme code path is used
        (kept bitwise-identical to the pre-stepping-stack engine); any of
        BDF2 / mass matrix / adaptivity routes through the generalized
        stack."""
        return (self.integrator == "theta" and self.adapt is None
                and self.mass() is None)

    @property
    def t_end(self) -> float:
        return self.nt * self.dt

    # -- family hooks ----------------------------------------------------
    def sample_spec(self, key: jax.Array) -> TrajectorySpec:
        raise NotImplementedError

    def spatial_coeffs(self, latent, t) -> jax.Array:
        """(5, nx, ny) coeffs of L(t), positive-definite convention; `t` is
        a traced scalar (one jitted step serves all steps)."""
        raise NotImplementedError

    def source(self, latent, t) -> jax.Array:
        return jnp.zeros((self.nx, self.ny), jnp.float64)

    # -- shared θ-scheme export ------------------------------------------
    def sample_specs(self, key: jax.Array, num: int) -> TrajectorySpec:
        keys = jax.random.split(key, num)
        return jax.vmap(self.sample_spec)(keys)

    def step_system(self, latent, u_prev: jax.Array, t_old, t_new
                    ) -> Tuple[jax.Array, jax.Array]:
        """One implicit θ-step as a linear system.

        Returns (a_coeffs (5, nx, ny), b (nx, ny)) with
            A = I + θ Δt L(t_new)
            b = u − (1−θ) Δt L(t_old) u + Δt (θ f(t_new) + (1−θ) f(t_old)).
        """
        th = self.theta
        dt = t_new - t_old
        l_new = self.spatial_coeffs(latent, t_new)
        a = th * dt * l_new
        a = a.at[Stencil5.C].add(1.0)
        b = u_prev + dt * (th * self.source(latent, t_new)
                           + (1.0 - th) * self.source(latent, t_old))
        if th < 1.0:
            l_old = self.spatial_coeffs(latent, t_old)
            b = b - (1.0 - th) * dt * stencil5_matvec(l_old, u_prev)
        return a, b

    def step_fn(self):
        """Jitted single-trajectory step (cached on the instance, so repeated
        datagen runs over one family reuse the executable)."""
        if self._step1 is None:
            self._step1 = jax.jit(self.step_system)
        return self._step1

    def step_fn_batched(self):
        """Jitted vmapped step: (specs latent, u (W, nx, ny), t, t') — the
        lockstep engine's one-device-program-per-step builder."""
        if self._stepB is None:
            self._stepB = jax.jit(jax.vmap(self.step_system,
                                           in_axes=(0, 0, None, None)))
        return self._stepB

    def step_fn_streamed(self):
        """Like `step_fn_batched` but with the time endpoints batched too
        ((W,) t_old / t_new): the streaming scheduler's slots drift out of
        phase (each slot is mid-trajectory at its own step), so one
        dispatch must advance W slots at W different times. Cached on the
        instance like the other steppers — per-run jit wrappers would
        retrace every run."""
        if self._stepS is None:
            self._stepS = jax.jit(jax.vmap(self.step_system,
                                           in_axes=(0, 0, 0, 0)))
        return self._stepS

    # -- generalized stepping stack (mass / BDF2 / adaptive) --------------
    def mass(self) -> Optional[MassMatrix]:
        """Mass matrix M of M u_t = −L u + f; None ⇒ identity (and, for
        θ-scheme fixed-Δt families, the untouched historical code path)."""
        return None

    def init_state(self, spec: TrajectorySpec) -> StepState:
        z = jnp.zeros_like(spec.u0)
        return StepState(u=spec.u0, u_prev=spec.u0, u_pprev=spec.u0,
                         v=z, v_prev=z)

    def _two_step_coeffs(self, rho, boot):
        """(β₀, α₁, α₂, γ, δ) of the unified implicit step

            A = β₀ M + γ Δt L(t+Δt)
            b = M(α₁ u − α₂ u_prev) − δ Δt L(t) u + Δt (γ f_new + δ f_old)

        θ-scheme: (1, 1, 0, θ, 1−θ); variable-step BDF2: (β₀, α₁, α₂, 1, 0)
        with ρ = Δtₙ/Δtₙ₋₁. `boot` (traced, per chain) selects the θ-scheme
        bootstrap on a trajectory's first step."""
        th = self.theta
        if self.integrator != "bdf2":
            return 1.0, 1.0, 0.0, th, 1.0 - th
        b0 = jnp.where(boot, 1.0, (1.0 + 2.0 * rho) / (1.0 + rho))
        a1 = jnp.where(boot, 1.0, 1.0 + rho)
        a2 = jnp.where(boot, 0.0, rho * rho / (1.0 + rho))
        gam = jnp.where(boot, th, 1.0)
        dlt = jnp.where(boot, 1.0 - th, 0.0)
        return b0, a1, a2, gam, dlt

    def build_step(self, latent, state: StepState, t, dt, dt_prev, boot,
                   any_boot: bool = True) -> Tuple[jax.Array, jax.Array]:
        """One implicit step t → t+Δt of the generalized stack as a linear
        system (a_coeffs (5, nx, ny), b (nx, ny)). Every scalar (t, dt,
        dt_prev, boot) is traced, so ONE jitted builder serves every step
        of every chain at any phase — per-chain Δt included. `any_boot` is
        STATIC (the cached builders compile both variants): BDF2's
        bootstrap-only explicit L(t)u term multiplies a runtime zero on
        every non-boot step, so once no chain is booting the False variant
        skips the second operator assembly + SpMV outright (its
        contribution is an exact 0, so both variants are bitwise-equal)."""
        rho = dt / jnp.maximum(dt_prev, 1e-300)
        b0, a1, a2, gam, dlt = self._two_step_coeffs(rho, boot)
        t_new = t + dt
        l_new = self.spatial_coeffs(latent, t_new)
        a = gam * dt * l_new
        mass = self.mass()
        hist = a1 * state.u - a2 * state.u_prev
        if mass is None:
            a = a.at[Stencil5.C].add(b0)
        else:
            a = a + b0 * mass.coeffs
            hist = mass.matvec(hist)
        b = hist + dt * (gam * self.source(latent, t_new)
                         + dlt * self.source(latent, t))
        if self.theta < 1.0 and (self.integrator != "bdf2" or any_boot):
            l_old = self.spatial_coeffs(latent, t)
            b = b - dlt * dt * stencil5_matvec(l_old, state.u)
        return a, b

    def advance_state(self, latent, state: StepState, x, t, dt, dt_prev,
                      boot) -> StepState:
        """Candidate post-step state from the solver solution x = u(t+Δt)
        (parabolic default: shift the history)."""
        return StepState(u=x, u_prev=state.u, u_pprev=state.u_prev,
                         v=state.v, v_prev=state.v)

    def step_eval(self, latent, state: StepState, x, t, dt, dt_prev,
                  dt_pprev, boot, have2):
        """Candidate state + embedded local-error estimate, one dispatch.

        The estimate is the predictor–corrector difference: the implicit
        solution x against the variable-step extrapolant through the
        accepted history, degree matched to the method order (linear for
        order 1, quadratic for order 2 once two accepted steps exist —
        `have2`). On the bootstrap step (no history) the zeroth-order
        predictor u(t) makes the estimate conservative: the controller
        starts small and grows, the classic safe start."""
        cand = self.advance_state(latent, state, x, t, dt, dt_prev, boot)
        r1 = dt / jnp.maximum(dt_prev, 1e-300)
        lin = (1.0 + r1) * state.u - r1 * state.u_prev
        if self.order >= 2:
            s1 = dt + dt_prev
            s2 = s1 + dt_pprev
            c0 = s1 * s2 / jnp.maximum(dt_prev * (dt_prev + dt_pprev), 1e-300)
            c1 = -dt * s2 / jnp.maximum(dt_prev * dt_pprev, 1e-300)
            c2 = dt * s1 / jnp.maximum((dt_prev + dt_pprev) * dt_pprev,
                                       1e-300)
            quad = c0 * state.u + c1 * state.u_prev + c2 * state.u_pprev
            pred = jnp.where(have2, quad, lin)
        else:
            pred = lin
        pred = jnp.where(boot, state.u, pred)
        est = (jnp.linalg.norm(x - pred)
               / jnp.maximum(jnp.linalg.norm(x), 1e-300))
        return cand, est

    def build_fn(self):
        """Jitted single-chain generalized step builder (cached; `any_boot`
        is static — at most two compiled variants)."""
        if self._build1 is None:
            self._build1 = jax.jit(self.build_step, static_argnums=6)
        return self._build1

    def build_fn_batched(self):
        """Jitted vmapped builder with PER-CHAIN scalars (t, Δt, Δt_prev,
        boot) — one SPMD dispatch assembles every chain's system at its own
        phase, the device half of the phase-masked lockstep. The trailing
        `any_boot` flag is static and unbatched."""
        if self._buildB is None:
            self._buildB = jax.jit(
                jax.vmap(self.build_step,
                         in_axes=(0, 0, 0, 0, 0, 0, None)),
                static_argnums=6)
        return self._buildB

    def eval_fn(self):
        if self._eval1 is None:
            self._eval1 = jax.jit(self.step_eval)
        return self._eval1

    def eval_fn_batched(self):
        if self._evalB is None:
            self._evalB = jax.jit(jax.vmap(self.step_eval))
        return self._evalB


class HeatTimeFamily(TimeDepFamily):
    """Heat / diffusion trajectories with DRIFTING log-normal conductivity:

        ∂u/∂t = ∇·(K(x,y,t)∇u),  K(t) = exp(σ g(t)),
        g(t) = (1 − t/T) g₀ + (t/T) g₁   (two endpoint GRFs)

    σ = 0 degenerates to the constant-coefficient heat equation (K ≡ 1) —
    the analytically solvable case the order-of-accuracy test uses. The
    slow K-drift is exactly the A_t perturbation regime recycling targets.
    """

    name = "heat"

    def __init__(self, nx: int = 32, ny: int = 32, nt: int = 10,
                 dt: float = 2e-3, theta: float = 1.0, sigma: float = 0.8,
                 alpha: float = 2.5, tau: float = 7.0, ic_amp: float = 1.0,
                 integrator: str = "theta",
                 adapt: Optional[AdaptConfig] = None):
        super().__init__(nx, ny, nt=nt, dt=dt, theta=theta,
                         integrator=integrator, adapt=adapt)
        self.sigma = float(sigma)
        self.ic_amp = float(ic_amp)
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=alpha, tau=tau, scale=nx**1.5)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)

    def sample_spec(self, key: jax.Array) -> TrajectorySpec:
        k0, k1, kic = jax.random.split(key, 3)
        g0, f0 = sample_grf(self.spec, k0)
        g1, f1 = sample_grf(self.spec, k1)
        g0 = g0 / (jnp.std(g0) + 1e-12)
        g1 = g1 / (jnp.std(g1) + 1e-12)
        ic, fic = sample_grf(self.spec, kic)
        u0 = self.ic_amp * ic / (jnp.std(ic) + 1e-12)
        feats = jnp.concatenate([fic, f0])  # IC + t=0 operator latents
        return TrajectorySpec(
            u0=u0,
            latent=(g0, g1),
            features=feats,
            no_input=jnp.exp(self.sigma * g0),
        )

    def spatial_coeffs(self, latent, t) -> jax.Array:
        g0, g1 = latent
        s = t / self.t_end
        k_field = jnp.exp(self.sigma * ((1.0 - s) * g0 + s * g1))
        return assemble_diffusion_stencil(k_field, self.hx, self.hy)


class ConvDiffTimeFamily(TimeDepFamily):
    """Convection–diffusion trajectories with a ROTATING velocity field:

        ∂u/∂t = ν∇²u − v(x,y,t)·∇u,
        v(t) = R(ω t) v₀,  v₀ = rot(GRF stream function), first-order upwind

    The pointwise rigid rotation of v₀ slowly reshapes the (nonsymmetric)
    upwind stencil every step — the nonsymmetric drift workload. (Rotation
    of the components does not preserve ∇·v = 0 exactly; upwinding keeps the
    M-matrix property for ANY v, so stability is unaffected.)
    """

    name = "convdiff-t"

    def __init__(self, nx: int = 32, ny: int = 32, nt: int = 10,
                 dt: float = 2e-3, theta: float = 1.0, nu: float = 1.0,
                 vmax: float = 30.0, omega: float = jnp.pi / 4,
                 ic_amp: float = 1.0, integrator: str = "theta",
                 adapt: Optional[AdaptConfig] = None):
        super().__init__(nx, ny, nt=nt, dt=dt, theta=theta,
                         integrator=integrator, adapt=adapt)
        self.nu = float(nu)
        self.vmax = float(vmax)
        self.omega = float(omega)
        self.ic_amp = float(ic_amp)
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=3.0, tau=8.0, scale=nx**1.5)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)

    def sample_spec(self, key: jax.Array) -> TrajectorySpec:
        kv, kic = jax.random.split(key)
        psi, fpsi = sample_grf(self.spec, kv)
        psi = psi / (jnp.std(psi) + 1e-12)
        vx = jnp.gradient(psi, self.hy, axis=1)
        vy = -jnp.gradient(psi, self.hx, axis=0)
        scale = self.vmax / (jnp.max(jnp.sqrt(vx**2 + vy**2)) + 1e-12)
        ic, fic = sample_grf(self.spec, kic)
        u0 = self.ic_amp * ic / (jnp.std(ic) + 1e-12)
        feats = jnp.concatenate([fic, fpsi])
        return TrajectorySpec(
            u0=u0,
            latent=(vx * scale, vy * scale),
            features=feats,
            no_input=psi,
        )

    def spatial_coeffs(self, latent, t) -> jax.Array:
        vx0, vy0 = latent
        c, s = jnp.cos(self.omega * t), jnp.sin(self.omega * t)
        vx = c * vx0 - s * vy0
        vy = s * vx0 + c * vy0
        return assemble_upwind_convection(vx, vy, self.nu, self.hx, self.hy)


class WaveTimeFamily(TimeDepFamily):
    """Second-order wave trajectories with a heterogeneous speed field and a
    NON-identity mass matrix, in first-order form:

        M ∂u/∂t = M v,   M ∂v/∂t = −K u,   K = −∇·(c(x,y)²∇·),
        c = exp(σ_c g) (log-normal GRF wave speed),  M = compact 5-point mass

    Eliminating v turns each implicit step into ONE Stencil5 system — the
    θ-scheme gives (M + θ²Δt²K) u_{n+1} = M(u_n + Δt v_n) − θ(1−θ)Δt²K u_n,
    variable-step BDF2 gives (β₀²M + Δt²K) u_{n+1} = M(β₀ĥ_u + Δt ĥ_v) with
    ĥ = α₁(·)_n − α₂(·)_{n−1} — so the wave family rides the existing
    batched/sharded solver paths unchanged, M ≠ I and all. The velocity is
    recovered explicitly after each solve and carried in `StepState.v`.

    θ = 1/2 (the default) is the trapezoid rule: it conserves the discrete
    energy E = ½(vᵀMv + uᵀKu) exactly up to solver tolerance (the
    energy-boundedness test pins this); BDF2 is mildly dissipative. K is
    time-independent, so consecutive operators differ only through the
    Δt drift — under adaptive stepping exactly the paper's "inherent
    similarity" regime, and under fixed Δt the recycling best case."""

    name = "wave"

    def __init__(self, nx: int = 32, ny: int = 32, nt: int = 10,
                 dt: float = 2e-3, theta: float = 0.5, sigma_c: float = 0.3,
                 alpha: float = 2.5, tau: float = 7.0, ic_amp: float = 1.0,
                 integrator: str = "theta",
                 adapt: Optional[AdaptConfig] = None):
        super().__init__(nx, ny, nt=nt, dt=dt, theta=theta,
                         integrator=integrator, adapt=adapt)
        assert theta > 0.0, "wave elimination needs an implicit share"
        self.sigma_c = float(sigma_c)
        self.ic_amp = float(ic_amp)
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=alpha, tau=tau, scale=nx**1.5)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)
        self._mass = MassMatrix.compact(nx, ny)

    def mass(self) -> MassMatrix:
        return self._mass

    def sample_spec(self, key: jax.Array) -> TrajectorySpec:
        kc, kic = jax.random.split(key)
        g, fg = sample_grf(self.spec, kc)
        g = g / (jnp.std(g) + 1e-12)
        ic, fic = sample_grf(self.spec, kic)
        u0 = self.ic_amp * ic / (jnp.std(ic) + 1e-12)
        feats = jnp.concatenate([fic, fg])
        return TrajectorySpec(
            u0=u0,
            latent=g,
            features=feats,
            no_input=jnp.exp(self.sigma_c * g),
        )

    def spatial_coeffs(self, latent, t) -> jax.Array:
        # time-independent stiffness K (t traced for API uniformity)
        c2 = jnp.exp(2.0 * self.sigma_c * latent)
        return assemble_diffusion_stencil(c2, self.hx, self.hy)

    def build_step(self, latent, state: StepState, t, dt, dt_prev, boot,
                   any_boot: bool = True) -> Tuple[jax.Array, jax.Array]:
        # any_boot accepted for builder-signature uniformity; the wave
        # elimination has no bootstrap-only assembly worth skipping
        th = self.theta
        k = self.spatial_coeffs(latent, t + dt)
        m = self._mass.coeffs
        # forcing enters the elimination with the same substitution:
        # θ-step picks up θΔt²(θf_new + (1−θ)f_old), BDF2 Δt²f_new
        f_theta = th * dt * dt * (th * self.source(latent, t + dt)
                                  + (1.0 - th) * self.source(latent, t))
        if self.integrator == "bdf2":
            rho = dt / jnp.maximum(dt_prev, 1e-300)
            b0, a1, a2, _, _ = self._two_step_coeffs(rho, boot)
            hist_u = a1 * state.u - a2 * state.u_prev
            hist_v = a1 * state.v - a2 * state.v_prev
            p = jnp.where(boot, 1.0, b0 * b0)
            q = jnp.where(boot, (th * dt) ** 2, dt * dt)
            s = jnp.where(boot, -th * (1.0 - th) * dt * dt, 0.0)
            hb = jnp.where(boot, state.u + dt * state.v,
                           b0 * hist_u + dt * hist_v)
            f = jnp.where(boot, f_theta,
                          dt * dt * self.source(latent, t + dt))
        else:
            p = 1.0
            q = (th * dt) ** 2
            s = -th * (1.0 - th) * dt * dt
            hb = state.u + dt * state.v
            f = f_theta
        a = p * m + q * k
        b = stencil5_matvec(m, hb) + s * stencil5_matvec(k, state.u) + f
        return a, b

    def advance_state(self, latent, state: StepState, x, t, dt, dt_prev,
                      boot) -> StepState:
        th = self.theta
        v_theta = ((x - state.u) / (th * dt)
                   - ((1.0 - th) / th) * state.v)
        if self.integrator == "bdf2":
            rho = dt / jnp.maximum(dt_prev, 1e-300)
            b0, a1, a2, _, _ = self._two_step_coeffs(rho, boot)
            hist_u = a1 * state.u - a2 * state.u_prev
            v_new = jnp.where(boot, v_theta, (b0 * x - hist_u) / dt)
        else:
            v_new = v_theta
        return StepState(u=x, u_prev=state.u, u_pprev=state.u_prev,
                         v=v_new, v_prev=state.v)

    def energy(self, latent, state: StepState) -> jax.Array:
        """Discrete energy ½(vᵀMv + uᵀKu) — the trapezoid invariant."""
        k = self.spatial_coeffs(latent, 0.0)
        return 0.5 * (jnp.vdot(state.v, stencil5_matvec(self._mass.coeffs,
                                                        state.v))
                      + jnp.vdot(state.u, stencil5_matvec(k, state.u)))
