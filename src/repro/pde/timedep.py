"""Time-dependent PDE workloads: θ-scheme implicit time stepping over the
existing 5-point spatial operators (the trajectory-datagen subsystem).

Sequences of implicit time-stepping solves are the textbook sweet spot for
Krylov subspace recycling: within one trajectory the system matrix

    A_t = I + θ Δt L(t_{n+1})            (θ-scheme, mass matrix = I)

drifts SLOWLY with t (time-varying coefficients), so the GCRO-DR recycle
space carried from step n is near-invariant for step n+1 — no sorting needed
inside a trajectory, the physics already orders the systems. Each implicit
step solves

    (I + θ Δt L_{n+1}) u_{n+1} = (I − (1−θ) Δt L_n) u_n
                                 + Δt (θ f_{n+1} + (1−θ) f_n)

with θ = 1 (backward Euler, O(Δt)) or θ = 1/2 (Crank–Nicolson, O(Δt²)).
L(t) is any 5-point `Stencil5` spatial operator in the POSITIVE-definite
convention (L = −∇·(K∇·) + convection), so A_t is an M-matrix shifted by
identity — far better conditioned than L itself.

A `TimeDepFamily` plays the role `ProblemFamily` plays for steady systems:
it samples per-trajectory latents (`TrajectorySpec` pytrees: initial
condition, coefficient latents, sorting features) and exports each time step
as a `Stencil5`-operator linear system. Everything is vmap-safe, so the
lockstep engine in `core/trajectory.py` advances W trajectories through one
batched device program per step. Time enters `step_system` as a TRACED
scalar: one jitted step executable serves every step of every trajectory.

Families registered in `pde/registry.py`:
  heat        ∂u/∂t = ∇·(K(x,y,t)∇u),  K = exp(σ g(t)) with the GRF latent
              g(t) drifting linearly between two endpoint fields g₀ → g₁
  convdiff-t  ∂u/∂t = ν∇²u − v(x,y,t)·∇u, v = a rigidly ROTATING copy of a
              GRF-stream-function velocity field (first-order upwind —
              nonsymmetric A_t, M-matrix preserved)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.pde.dia import Stencil5, stencil5_matvec, zero_boundary_neighbors
from repro.pde.grf import GRFSpec, sample_grf
from repro.pde.problems import ProblemFamily


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TrajectorySpec:
    """One sampled trajectory: IC + coefficient latents + metadata.

    u0        : (nx, ny) initial condition
    latent    : family-specific pytree the spatial operator L(t) is built
                from (e.g. the two endpoint GRF fields of the heat drift)
    features  : (f,) sorting features at t = 0 — IC latent + operator
                latent, what `core/sorting.py` measures trajectory
                similarity on (adjacent trajectories share recycle spaces)
    no_input  : (nx, ny) static neural-operator conditioning channel
                (e.g. K(·, 0) for heat); the state u_t is the other channel
    """

    u0: jax.Array
    latent: Any
    features: jax.Array
    no_input: jax.Array

    def tree_flatten(self):
        return (self.u0, self.latent, self.features, self.no_input), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


def assemble_diffusion_stencil(k_field: jax.Array, hx: float, hy: float) -> jax.Array:
    """(5, nx, ny) coeffs of L = −∇·(K∇·) on the NODE-centred Dirichlet-0
    grid (x_i = i·hx, i = 1..nx): interior faces take harmonic-mean
    transmissibilities, wall faces the node's own K. With K ≡ 1 this reduces
    EXACTLY to the standard 5-point Laplacian — the property the θ-scheme
    order-of-accuracy test keys on (discrete sine eigenvectors)."""
    def harmonic(a, b):
        return 2.0 * a * b / (a + b)

    kx_face = harmonic(k_field[:-1, :], k_field[1:, :])
    ky_face = harmonic(k_field[:, :-1], k_field[:, 1:])
    kx_n = jnp.concatenate([k_field[:1, :], kx_face], axis=0)
    kx_s = jnp.concatenate([kx_face, k_field[-1:, :]], axis=0)
    ky_w = jnp.concatenate([k_field[:, :1], ky_face], axis=1)
    ky_e = jnp.concatenate([ky_face, k_field[:, -1:]], axis=1)

    cx = 1.0 / hx**2
    cy = 1.0 / hy**2
    n = -cx * kx_n
    s = -cx * kx_s
    w = -cy * ky_w
    e = -cy * ky_e
    c = -(n + s + w + e)
    return zero_boundary_neighbors(jnp.stack([c, n, s, w, e]))


def assemble_upwind_convection(vx: jax.Array, vy: jax.Array, nu: float,
                               hx: float, hy: float) -> jax.Array:
    """(5, nx, ny) coeffs of L = −ν∇² + v·∇ with first-order upwinding
    (M-matrix for any v; nonsymmetry scales with the Péclet number)."""
    cx = nu / hx**2
    cy = nu / hy**2
    axp = jnp.maximum(vx, 0.0) / hx
    axm = jnp.maximum(-vx, 0.0) / hx
    ayp = jnp.maximum(vy, 0.0) / hy
    aym = jnp.maximum(-vy, 0.0) / hy
    n = -(cx + axp)
    s = -(cx + axm)
    w = -(cy + ayp)
    e = -(cy + aym)
    c = 2.0 * (cx + cy) + axp + axm + ayp + aym
    return zero_boundary_neighbors(jnp.stack([c, n, s, w, e]))


class TimeDepFamily(ProblemFamily):
    """Base class for trajectory workloads (the time-dependent analogue of
    `ProblemFamily`). Subclasses implement `sample_spec` and
    `spatial_coeffs(latent, t)`; the θ-scheme export is shared.

    nt / dt / theta are trajectory-level constants: every trajectory in a
    dataset marches the same nt steps of size dt (what keeps the lockstep
    rows of `core/trajectory.py` aligned across chunks)."""

    name = "timedep-base"

    def __init__(self, nx: int, ny: int, nt: int = 10, dt: float = 1e-3,
                 theta: float = 1.0):
        super().__init__(nx, ny)
        assert nt >= 1 and dt > 0.0 and 0.0 < theta <= 1.0
        self.nt = int(nt)
        self.dt = float(dt)
        self.theta = float(theta)
        self._step1 = None
        self._stepB = None

    @property
    def t_end(self) -> float:
        return self.nt * self.dt

    # -- family hooks ----------------------------------------------------
    def sample_spec(self, key: jax.Array) -> TrajectorySpec:
        raise NotImplementedError

    def spatial_coeffs(self, latent, t) -> jax.Array:
        """(5, nx, ny) coeffs of L(t), positive-definite convention; `t` is
        a traced scalar (one jitted step serves all steps)."""
        raise NotImplementedError

    def source(self, latent, t) -> jax.Array:
        return jnp.zeros((self.nx, self.ny), jnp.float64)

    # -- shared θ-scheme export ------------------------------------------
    def sample_specs(self, key: jax.Array, num: int) -> TrajectorySpec:
        keys = jax.random.split(key, num)
        return jax.vmap(self.sample_spec)(keys)

    def step_system(self, latent, u_prev: jax.Array, t_old, t_new
                    ) -> Tuple[jax.Array, jax.Array]:
        """One implicit θ-step as a linear system.

        Returns (a_coeffs (5, nx, ny), b (nx, ny)) with
            A = I + θ Δt L(t_new)
            b = u − (1−θ) Δt L(t_old) u + Δt (θ f(t_new) + (1−θ) f(t_old)).
        """
        th = self.theta
        dt = t_new - t_old
        l_new = self.spatial_coeffs(latent, t_new)
        a = th * dt * l_new
        a = a.at[Stencil5.C].add(1.0)
        b = u_prev + dt * (th * self.source(latent, t_new)
                           + (1.0 - th) * self.source(latent, t_old))
        if th < 1.0:
            l_old = self.spatial_coeffs(latent, t_old)
            b = b - (1.0 - th) * dt * stencil5_matvec(l_old, u_prev)
        return a, b

    def step_fn(self):
        """Jitted single-trajectory step (cached on the instance, so repeated
        datagen runs over one family reuse the executable)."""
        if self._step1 is None:
            self._step1 = jax.jit(self.step_system)
        return self._step1

    def step_fn_batched(self):
        """Jitted vmapped step: (specs latent, u (W, nx, ny), t, t') — the
        lockstep engine's one-device-program-per-step builder."""
        if self._stepB is None:
            self._stepB = jax.jit(jax.vmap(self.step_system,
                                           in_axes=(0, 0, None, None)))
        return self._stepB


class HeatTimeFamily(TimeDepFamily):
    """Heat / diffusion trajectories with DRIFTING log-normal conductivity:

        ∂u/∂t = ∇·(K(x,y,t)∇u),  K(t) = exp(σ g(t)),
        g(t) = (1 − t/T) g₀ + (t/T) g₁   (two endpoint GRFs)

    σ = 0 degenerates to the constant-coefficient heat equation (K ≡ 1) —
    the analytically solvable case the order-of-accuracy test uses. The
    slow K-drift is exactly the A_t perturbation regime recycling targets.
    """

    name = "heat"

    def __init__(self, nx: int = 32, ny: int = 32, nt: int = 10,
                 dt: float = 2e-3, theta: float = 1.0, sigma: float = 0.8,
                 alpha: float = 2.5, tau: float = 7.0, ic_amp: float = 1.0):
        super().__init__(nx, ny, nt=nt, dt=dt, theta=theta)
        self.sigma = float(sigma)
        self.ic_amp = float(ic_amp)
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=alpha, tau=tau, scale=nx**1.5)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)

    def sample_spec(self, key: jax.Array) -> TrajectorySpec:
        k0, k1, kic = jax.random.split(key, 3)
        g0, f0 = sample_grf(self.spec, k0)
        g1, f1 = sample_grf(self.spec, k1)
        g0 = g0 / (jnp.std(g0) + 1e-12)
        g1 = g1 / (jnp.std(g1) + 1e-12)
        ic, fic = sample_grf(self.spec, kic)
        u0 = self.ic_amp * ic / (jnp.std(ic) + 1e-12)
        feats = jnp.concatenate([fic, f0])  # IC + t=0 operator latents
        return TrajectorySpec(
            u0=u0,
            latent=(g0, g1),
            features=feats,
            no_input=jnp.exp(self.sigma * g0),
        )

    def spatial_coeffs(self, latent, t) -> jax.Array:
        g0, g1 = latent
        s = t / self.t_end
        k_field = jnp.exp(self.sigma * ((1.0 - s) * g0 + s * g1))
        return assemble_diffusion_stencil(k_field, self.hx, self.hy)


class ConvDiffTimeFamily(TimeDepFamily):
    """Convection–diffusion trajectories with a ROTATING velocity field:

        ∂u/∂t = ν∇²u − v(x,y,t)·∇u,
        v(t) = R(ω t) v₀,  v₀ = rot(GRF stream function), first-order upwind

    The pointwise rigid rotation of v₀ slowly reshapes the (nonsymmetric)
    upwind stencil every step — the nonsymmetric drift workload. (Rotation
    of the components does not preserve ∇·v = 0 exactly; upwinding keeps the
    M-matrix property for ANY v, so stability is unaffected.)
    """

    name = "convdiff-t"

    def __init__(self, nx: int = 32, ny: int = 32, nt: int = 10,
                 dt: float = 2e-3, theta: float = 1.0, nu: float = 1.0,
                 vmax: float = 30.0, omega: float = jnp.pi / 4,
                 ic_amp: float = 1.0):
        super().__init__(nx, ny, nt=nt, dt=dt, theta=theta)
        self.nu = float(nu)
        self.vmax = float(vmax)
        self.omega = float(omega)
        self.ic_amp = float(ic_amp)
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=3.0, tau=8.0, scale=nx**1.5)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)

    def sample_spec(self, key: jax.Array) -> TrajectorySpec:
        kv, kic = jax.random.split(key)
        psi, fpsi = sample_grf(self.spec, kv)
        psi = psi / (jnp.std(psi) + 1e-12)
        vx = jnp.gradient(psi, self.hy, axis=1)
        vy = -jnp.gradient(psi, self.hx, axis=0)
        scale = self.vmax / (jnp.max(jnp.sqrt(vx**2 + vy**2)) + 1e-12)
        ic, fic = sample_grf(self.spec, kic)
        u0 = self.ic_amp * ic / (jnp.std(ic) + 1e-12)
        feats = jnp.concatenate([fic, fpsi])
        return TrajectorySpec(
            u0=u0,
            latent=(vx * scale, vy * scale),
            features=feats,
            no_input=psi,
        )

    def spatial_coeffs(self, latent, t) -> jax.Array:
        vx0, vy0 = latent
        c, s = jnp.cos(self.omega * t), jnp.sin(self.omega * t)
        vx = c * vx0 - s * vy0
        vy = s * vx0 + c * vy0
        return assemble_upwind_convection(vx, vy, self.nu, self.hx, self.hy)
