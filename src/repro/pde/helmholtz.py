"""Helmholtz family (paper App. D.2.4): ∇²u + k(x,y)²u = f on the unit square.

The wavenumber field k is GRF-derived (paper: "k is derived using the GRF
method; the parameters inherent to the GRF serve as the foundation for our
sort scheme"). The operator is symmetric **indefinite** once k² exceeds the
smallest Laplacian eigenvalue — the hardest of the four families for plain
GMRES and where the paper sees its best speed-ups (up to 13.9×).

A fixed smooth source drives the problem so solutions vary only through k."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pde.dia import Stencil5, laplacian_stencil, zero_boundary_neighbors
from repro.pde.grf import GRFSpec, sample_grf
from repro.pde.problems import LinearProblem, ProblemFamily, interior_linspace


class HelmholtzFamily(ProblemFamily):
    name = "helmholtz"

    def __init__(self, nx: int = 64, ny: int = 64, k0: float = 12.0,
                 k_sigma: float = 0.15, alpha: float = 3.0, tau: float = 9.0):
        super().__init__(nx, ny)
        self.k0 = k0
        self.k_sigma = k_sigma
        self.spec = GRFSpec(nx=nx, ny=ny, alpha=alpha, tau=tau, scale=nx**1.5)
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)
        lap = zero_boundary_neighbors(laplacian_stencil(nx, ny, self.hx, self.hy))
        self._lap = lap
        gx = interior_linspace(nx)
        gy = interior_linspace(ny)
        xx, yy = jnp.meshgrid(gx, gy, indexing="ij")
        self._source = 100.0 * jnp.exp(-((xx - 0.5) ** 2 + (yy - 0.5) ** 2) / 0.02)

    def sample(self, key: jax.Array) -> LinearProblem:
        field, feats = sample_grf(self.spec, key)
        field = field / (jnp.std(field) + 1e-12)
        k_field = self.k0 * (1.0 + self.k_sigma * field)
        coeffs = self._lap.at[Stencil5.C].add(k_field**2)
        return LinearProblem(
            op=Stencil5(coeffs),
            b=self._source,
            features=feats,
            no_input=k_field,
        )
