"""Problem-family abstraction: step 1-3 of the paper's Figure 1 pipeline
(sample NO parameters → export PDE → discretize to a linear system)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.pde.dia import Stencil5


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LinearProblem:
    """One sampled system A x = b plus its metadata.

    op        : Stencil5 operator (field form; .to_dia() for flat form)
    b         : (nx, ny) RHS in field form
    features  : (f,) the "parameter matrix" P^(i) of Algorithm 1, flattened —
                what the sorting pass measures distances on
    no_input  : (nx, ny) the neural-operator input channel (e.g. permeability
                K for Darcy); the solution x is the training label
    """

    op: Stencil5
    b: jax.Array
    features: jax.Array
    no_input: jax.Array

    def tree_flatten(self):
        return (self.op, self.b, self.features, self.no_input), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    @property
    def grid(self):
        return self.b.shape[-2], self.b.shape[-1]


class ProblemFamily:
    """Base class. Subclasses implement `sample(key) -> LinearProblem`;
    everything is vmap-safe (static masks / grids), so `sample_batch` stacks
    a whole dataset's systems into leading-axis arrays — the layout the
    chunk-parallel SKR driver shards over the `data` mesh axis."""

    name: str = "base"

    def __init__(self, nx: int, ny: int):
        self.nx = int(nx)
        self.ny = int(ny)

    @property
    def n(self) -> int:
        return self.nx * self.ny

    def sample(self, key: jax.Array) -> LinearProblem:
        raise NotImplementedError

    def sample_batch(self, key: jax.Array, num: int) -> LinearProblem:
        keys = jax.random.split(key, num)
        return jax.vmap(self.sample)(keys)

    # -- hooks the solver layer uses ------------------------------------
    def matvec_fn(self) -> Callable:
        """Returns apply(op_coeffs, x_field) -> y_field; overridden by
        families whose operator is not a plain stencil."""
        from repro.pde.dia import stencil5_matvec

        return stencil5_matvec


def interior_linspace(n: int, lo: float = 0.0, hi: float = 1.0) -> jax.Array:
    """n interior nodes of a uniform grid on [lo, hi] (Dirichlet layout)."""
    h = (hi - lo) / (n + 1)
    return lo + h * jnp.arange(1, n + 1, dtype=jnp.float64)
