"""Problem-family registry: name → constructor, with the paper's default
grid sizes scaled down to CPU-friendly defaults (overridable everywhere)."""
from __future__ import annotations

from typing import Dict, Type

from repro.pde.convdiff import ConvDiffFamily
from repro.pde.darcy import DarcyFamily
from repro.pde.helmholtz import HelmholtzFamily
from repro.pde.poisson import PoissonFamily
from repro.pde.problems import ProblemFamily
from repro.pde.thermal import ThermalFamily
from repro.pde.timedep import (ConvDiffTimeFamily, HeatTimeFamily,
                               TimeDepFamily, WaveTimeFamily)

_FAMILIES: Dict[str, Type[ProblemFamily]] = {
    "darcy": DarcyFamily,
    "thermal": ThermalFamily,
    "poisson": PoissonFamily,
    "helmholtz": HelmholtzFamily,
    "convdiff": ConvDiffFamily,  # beyond-paper nonsymmetric family
}

# Time-dependent trajectory workloads (pde/timedep.py): θ-scheme implicit
# steppers consumed by core/trajectory.py rather than core/skr.py.
_TIMEDEP_FAMILIES: Dict[str, Type[TimeDepFamily]] = {
    "heat": HeatTimeFamily,
    "convdiff-t": ConvDiffTimeFamily,
    "wave": WaveTimeFamily,  # M ≠ I mass matrix, first-order form
}


def list_families():
    return sorted(_FAMILIES)


def list_timedep_families():
    return sorted(_TIMEDEP_FAMILIES)


def get_family(name: str, **kwargs) -> ProblemFamily:
    if name not in _FAMILIES:
        raise KeyError(f"unknown problem family {name!r}; have {list_families()}")
    return _FAMILIES[name](**kwargs)


def get_timedep_family(name: str, **kwargs) -> TimeDepFamily:
    if name not in _TIMEDEP_FAMILIES:
        raise KeyError(f"unknown time-dependent family {name!r}; "
                       f"have {list_timedep_families()}")
    return _TIMEDEP_FAMILIES[name](**kwargs)
