"""Problem-family registry: name → constructor, with the paper's default
grid sizes scaled down to CPU-friendly defaults (overridable everywhere)."""
from __future__ import annotations

from typing import Dict, Type

from repro.pde.convdiff import ConvDiffFamily
from repro.pde.darcy import DarcyFamily
from repro.pde.helmholtz import HelmholtzFamily
from repro.pde.poisson import PoissonFamily
from repro.pde.problems import ProblemFamily
from repro.pde.thermal import ThermalFamily

_FAMILIES: Dict[str, Type[ProblemFamily]] = {
    "darcy": DarcyFamily,
    "thermal": ThermalFamily,
    "poisson": PoissonFamily,
    "helmholtz": HelmholtzFamily,
    "convdiff": ConvDiffFamily,  # beyond-paper nonsymmetric family
}


def list_families():
    return sorted(_FAMILIES)


def get_family(name: str, **kwargs) -> ProblemFamily:
    if name not in _FAMILIES:
        raise KeyError(f"unknown problem family {name!r}; have {list_families()}")
    return _FAMILIES[name](**kwargs)
