"""Poisson family (paper App. D.2.3): ∇²u = f on the unit square.

Boundary values on all four sides and the source f are truncated Chebyshev
series; the coefficients of those five series ARE the sorting features
(paper: "The coefficients of these five Chebyshev polynomials are the basis
for our sorting"). A is the fixed 5-point Laplacian; only b varies across the
sequence — the regime where recycling pays off maximally."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pde.chebyshev import chebyshev_eval, chebyshev_eval2d, sample_cheb_coeffs
from repro.pde.dia import Stencil5, laplacian_stencil, zero_boundary_neighbors
from repro.pde.problems import LinearProblem, ProblemFamily, interior_linspace


class PoissonFamily(ProblemFamily):
    name = "poisson"

    def __init__(self, nx: int = 64, ny: int = 64, degree: int = 5, amp: float = 50.0):
        super().__init__(nx, ny)
        self.degree = degree
        self.amp = amp
        self.hx = 1.0 / (nx + 1)
        self.hy = 1.0 / (ny + 1)
        self.gx = interior_linspace(nx)  # grid in [0,1]
        self.gy = interior_linspace(ny)
        coeffs = laplacian_stencil(nx, ny, self.hx, self.hy)
        self._coeffs = zero_boundary_neighbors(coeffs)

    def sample(self, key: jax.Array) -> LinearProblem:
        kf, kl, kr, kb, kt = jax.random.split(key, 5)
        d = self.degree
        cf = sample_cheb_coeffs(kf, (d, d)) * self.amp
        cl = sample_cheb_coeffs(kl, (d,))
        cr = sample_cheb_coeffs(kr, (d,))
        cb = sample_cheb_coeffs(kb, (d,))
        ct = sample_cheb_coeffs(kt, (d,))

        tx = 2.0 * self.gx - 1.0  # map [0,1] -> [-1,1]
        ty = 2.0 * self.gy - 1.0
        f = chebyshev_eval2d(cf, tx, ty)

        # Dirichlet boundary values along each side.
        u_left = chebyshev_eval(cl, tx)   # x varies along the left edge (j=0)
        u_right = chebyshev_eval(cr, tx)
        u_bottom = chebyshev_eval(cb, ty)  # y varies along the bottom edge (i=0)
        u_top = chebyshev_eval(ct, ty)

        cx = 1.0 / self.hx**2
        cy = 1.0 / self.hy**2
        b = f
        b = b.at[0, :].add(-cx * u_bottom)
        b = b.at[-1, :].add(-cx * u_top)
        b = b.at[:, 0].add(-cy * u_left)
        b = b.at[:, -1].add(-cy * u_right)

        features = jnp.concatenate([cf.ravel(), cl, cr, cb, ct])
        return LinearProblem(
            op=Stencil5(self._coeffs),
            b=b,
            features=features,
            no_input=f,
        )
