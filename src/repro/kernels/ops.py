"""jit'd dispatch wrappers for the Pallas kernels.

Every op has a pure-jnp reference path (ref.py) — the default on CPU — and a
Pallas path (`use_kernel=True`) compiled for TPU and validated on CPU via
`interpret=True`. The solver/model layers call THESE wrappers so the kernel
routing is a config flag, not a code change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref


def stencil5_matvec(coeffs: jax.Array, x: jax.Array, *, use_kernel: bool = False,
                    interpret: bool = True) -> jax.Array:
    """(…, 5, nx, ny) coeffs × (…, nx, ny) field → (…, nx, ny)."""
    if use_kernel:
        from repro.kernels.stencil_matvec import stencil5_matvec_pallas

        fn = functools.partial(stencil5_matvec_pallas, interpret=interpret)
        if x.ndim > 2:  # batched: map over leading dims
            for _ in range(x.ndim - 2):
                fn = jax.vmap(fn)
        return fn(coeffs, x)
    return ref.stencil5_matvec(coeffs, x)


def dia_spmv(dia, x: jax.Array, *, use_kernel: bool = False,
             interpret: bool = True, op_stride: int | None = None,
             op_index: jax.Array | None = None) -> jax.Array:
    """DIA sparse matvec on flat (…, n) vectors.

    A matched batch (data (B, ndiag, n) against x (B, n)) routes through the
    single-launch batched kernel — one explicit dispatch for all B operators.
    NOTE: this branch fires only for direct matched-batch calls at this
    boundary; inside `jax.vmap` (the lockstep solver's cycles) tracer shapes
    are per-chain, and it is Pallas's own vmap batching rule that lifts the
    single kernel to an equivalent batched grid.

    Broadcastable operator stacks (a SMALLER data (A, ndiag, n) against a
    LARGER x (B, n), the label-expansion fan-out) never materialize per-row
    operator copies:
      op_stride=s  uniform fan-out, B = A·s, y[b] = data[b // s] @ x[b]
                   (index arithmetic in the kernel's BlockSpec; the ref
                   path broadcasts a (A, 1, …) reshape)
      op_index     arbitrary (B,) int assignment, y[b] = data[op_index[b]]
                   @ x[b] (in-kernel dynamic slice; ref path gathers)
    The two are mutually exclusive; with neither, shapes must match or
    broadcast as before.
    """
    if op_stride is not None and op_index is not None:
        raise ValueError("op_stride and op_index are mutually exclusive")
    if use_kernel:
        from repro.kernels.dia_spmv import (dia_spmv_batched_pallas,
                                            dia_spmv_gather_pallas,
                                            dia_spmv_pallas,
                                            dia_spmv_strided_pallas)

        data = dia.data
        if op_stride is not None:
            return dia_spmv_strided_pallas(dia.offsets, data, x,
                                           op_stride=op_stride,
                                           interpret=interpret)
        if op_index is not None:
            return dia_spmv_gather_pallas(dia.offsets, data, x, op_index,
                                          interpret=interpret)
        if data.ndim == 3 and x.ndim == 2 and data.shape[0] == x.shape[0]:
            return dia_spmv_batched_pallas(dia.offsets, data, x,
                                           interpret=interpret)
        fn = functools.partial(dia_spmv_pallas, dia.offsets, interpret=interpret)
        if x.ndim > 1:
            for _ in range(x.ndim - 1):
                fn = jax.vmap(fn)
        return fn(data, x)
    if op_stride is not None:
        nops = dia.data.shape[0]
        n = dia.data.shape[-1]
        y = ref.dia_spmv(dia.offsets, dia.data[:, None],
                         x.reshape(nops, op_stride, n))
        return y.reshape(nops * op_stride, n)
    if op_index is not None:
        return ref.dia_spmv(dia.offsets, dia.data[op_index], x)
    return ref.dia_spmv(dia.offsets, dia.data, x)


def fused_orthog(v_basis: jax.Array, w: jax.Array, mask: jax.Array, *,
                 use_kernel: bool = False, interpret: bool = True,
                 acc_dtype=None):
    """CGS2 projection: orthogonalize w against the masked rows of v_basis.

    Returns (w_orth, h) with h the combined projection coefficients —
    the Arnoldi inner-loop hot spot after the matvec (DESIGN §4.4).
    Dtype-polymorphic: runs in the storage dtype of (v_basis, w); pass
    acc_dtype (e.g. jnp.float64 under fp32 storage) to widen ONLY the
    accumulation (KrylovConfig.cgs2_acc="float64").
    """
    if use_kernel:
        from repro.kernels.fused_orthog import fused_orthog_pallas

        return fused_orthog_pallas(v_basis, w, mask, interpret=interpret,
                                   acc_dtype=acc_dtype)
    return ref.fused_orthog(v_basis, w, mask, acc_dtype=acc_dtype)


def arnoldi_step(coeffs: jax.Array, inv_diag: jax.Array, c_rows: jax.Array,
                 v_basis: jax.Array, vin: jax.Array, mask: jax.Array, *,
                 use_kernel: bool = False, interpret: bool = True,
                 acc_dtype=None):
    """One fused (deflated) Arnoldi inner iteration: Jacobi apply + 5-point
    stencil matvec + C-projection + CGS2 as ONE launch (the lockstep hot
    loop's whole inner body — see kernels/arnoldi_step.py).

    Returns (w_orth (n,), hcol (m+1,), bj (k,)). k = 0 (plain GMRES) is
    handled by zero-row padding inside the kernel wrapper."""
    if use_kernel:
        from repro.kernels.arnoldi_step import arnoldi_step_pallas

        return arnoldi_step_pallas(coeffs, inv_diag, c_rows, v_basis, vin,
                                   mask, interpret=interpret,
                                   acc_dtype=acc_dtype)
    return ref.arnoldi_step(coeffs, inv_diag, c_rows, v_basis, vin, mask,
                            acc_dtype=acc_dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    use_kernel: bool = False, interpret: bool = True) -> jax.Array:
    """Chunked-softmax attention (beyond-paper LM hot spot).

    q: (B, Hq, Tq, D), k/v: (B, Hkv, Tk, D) — GQA broadcast when Hq > Hkv.
    """
    if use_kernel:
        from repro.kernels.flash_attention import flash_attention_pallas

        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=interpret)
    return ref.flash_attention(q, k, v, causal=causal, window=window)
