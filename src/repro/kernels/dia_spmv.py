"""Pallas TPU kernel: DIA (diagonal-format) SpMV.

General banded companion to the stencil kernel (used for flattened /
non-stencil operators). The wrapper pre-pads x by the maximum |offset| so
every in-kernel load is in range: per output tile the kernel reads one
aligned x slice per diagonal and accumulates coeff·slice — unit-stride VPU
work, no gather (DESIGN §4.1).

Dtype-polymorphic: the accumulator and output carry
result_type(data, x) — fp32 operands stay fp32 end to end (the
mixed-precision inner cycles), nothing assumes f64. Ragged n is padded up
to a multiple of the block size with zero diagonals/entries (masked tail)
instead of shrinking the block to a divisor of n, which degraded to a
one-element grid step for prime-ish n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MAX_GRID_STEPS = 65536
_LANE = 128


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def padded_tiles(n: int, block_n: int, what: str, steps_factor: int = 1):
    """(bn, n_pad, nt) for a padded 1-D tiling of n — never a degenerate
    divisor fallback; fails loudly past the grid-step sanity cap. Shared by
    every 1-D-tiled kernel (here and fused_orthog); `steps_factor` is the
    kernel's grid steps per tile (e.g. 3 phases)."""
    bn = min(block_n, _round_up(n, _LANE))
    n_pad = _round_up(n, bn)
    nt = n_pad // bn
    if nt * steps_factor > _MAX_GRID_STEPS:
        raise ValueError(f"{what} grid of {nt} steps (n={n}, block_n={bn}) "
                         f"exceeds the sanity cap {_MAX_GRID_STEPS}")
    return bn, n_pad, nt


def _kernel(data_ref, xpad_ref, o_ref, *, offsets, pad, bn):
    t = pl.program_id(0)
    acc = jnp.zeros((bn,), o_ref.dtype)
    base = t * bn
    for d, off in enumerate(offsets):
        xs = pl.load(xpad_ref, (pl.dslice(base + pad + off, bn),))
        acc = acc + data_ref[d, :] * xs
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("offsets", "interpret", "block_n"))
def dia_spmv_pallas(offsets, data: jax.Array, x: jax.Array, *,
                    interpret: bool = True, block_n: int = 1024) -> jax.Array:
    """offsets: static tuple; data (ndiag, n); x (n,) → y (n,).

    Zero-padding by max|offset| encodes the boundary (matches DIA semantics:
    contributions from out-of-range columns vanish). Out-of-range data
    entries must already be zero — true for all assemblers in pde/.
    """
    n = x.shape[0]
    pad = max(1, max(abs(o) for o in offsets))
    bn, n_pad, nt = padded_tiles(n, block_n, "dia_spmv")
    if n_pad != n:
        data = jnp.pad(data, ((0, 0), (0, n_pad - n)))
    xpad = jnp.pad(x, (pad, pad + (n_pad - n)))
    out_dtype = jnp.result_type(data.dtype, x.dtype)

    y = pl.pallas_call(
        functools.partial(_kernel, offsets=tuple(offsets), pad=pad, bn=bn),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((len(offsets), bn), lambda t: (0, t)),
            # full padded x resident in VMEM (solver vectors are ≤ O(100k))
            pl.BlockSpec((n_pad + 2 * pad,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), out_dtype),
        interpret=interpret,
    )(data, xpad)
    return y[:n]


def _kernel_batched(data_ref, xpad_ref, o_ref, *, offsets, pad, bn):
    t = pl.program_id(1)
    acc = jnp.zeros((1, bn), o_ref.dtype)
    base = t * bn
    for d, off in enumerate(offsets):
        xs = pl.load(xpad_ref, (pl.dslice(0, 1),
                                pl.dslice(base + pad + off, bn)))
        acc = acc + data_ref[0, d, :] * xs
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("offsets", "interpret", "block_n"))
def dia_spmv_batched_pallas(offsets, data: jax.Array, x: jax.Array, *,
                            interpret: bool = True,
                            block_n: int = 1024) -> jax.Array:
    """B stencil/band operators applied in ONE kernel launch.

    offsets: static tuple shared by the batch; data (B, ndiag, n);
    x (B, n) → y (B, n). The grid is (B, n∕bn): dimension 0 walks the
    independent operators, dimension 1 the output tiles — same unit-stride
    VPU body as the single kernel, amortizing the launch across the whole
    batch instead of issuing B separate dispatches. This is the explicit
    single-launch form of what Pallas's vmap batching rule produces when the
    lockstep solver vmaps the single kernel; use it for direct matched-batch
    SpMV at the ops boundary. Zero-padding semantics match
    `dia_spmv_pallas`.
    """
    bsz, _, n = data.shape
    pad = max(1, max(abs(o) for o in offsets))
    bn, n_pad, nt = padded_tiles(n, block_n, "dia_spmv_batched")
    if bsz * nt > _MAX_GRID_STEPS:
        raise ValueError(f"dia_spmv_batched grid of {bsz}x{nt} steps exceeds "
                         f"the sanity cap {_MAX_GRID_STEPS}")
    if n_pad != n:
        data = jnp.pad(data, ((0, 0), (0, 0), (0, n_pad - n)))
    xpad = jnp.pad(x, ((0, 0), (pad, pad + (n_pad - n))))
    out_dtype = jnp.result_type(data.dtype, x.dtype)

    y = pl.pallas_call(
        functools.partial(_kernel_batched, offsets=tuple(offsets), pad=pad,
                          bn=bn),
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec((1, len(offsets), bn), lambda b, t: (b, 0, t)),
            pl.BlockSpec((1, n_pad + 2 * pad), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, t: (b, t)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_pad), out_dtype),
        interpret=interpret,
    )(data, xpad)
    return y[:, :n]


@functools.partial(jax.jit, static_argnames=("offsets", "op_stride",
                                             "interpret", "block_n"))
def dia_spmv_strided_pallas(offsets, data: jax.Array, x: jax.Array, *,
                            op_stride: int, interpret: bool = True,
                            block_n: int = 1024) -> jax.Array:
    """A operators, each applied to `op_stride` consecutive x rows.

    offsets: static tuple; data (A, ndiag, n); x (A·op_stride, n) →
    y (A·op_stride, n), with y[b] = data[b // op_stride] @ x[b]. The
    label-expansion shape: one anchor operator re-labels its K+1 perturbed
    solutions without `DIA.take` ever materializing K+1 operator copies —
    the broadcast is PURE INDEX ARITHMETIC in the BlockSpec index_map
    (`b // op_stride`), so the same (1, ndiag, bn) operator block is simply
    fetched for each of its op_stride batch rows and the kernel body is the
    matched-batch body unchanged. Zero-padding semantics match
    `dia_spmv_pallas`.
    """
    nops, _, n = data.shape
    bsz = x.shape[0]
    if bsz != nops * op_stride:
        raise ValueError(f"strided batch mismatch: {nops} operators x "
                         f"stride {op_stride} != {bsz} vectors")
    pad = max(1, max(abs(o) for o in offsets))
    bn, n_pad, nt = padded_tiles(n, block_n, "dia_spmv_strided")
    if bsz * nt > _MAX_GRID_STEPS:
        raise ValueError(f"dia_spmv_strided grid of {bsz}x{nt} steps exceeds "
                         f"the sanity cap {_MAX_GRID_STEPS}")
    if n_pad != n:
        data = jnp.pad(data, ((0, 0), (0, 0), (0, n_pad - n)))
    xpad = jnp.pad(x, ((0, 0), (pad, pad + (n_pad - n))))
    out_dtype = jnp.result_type(data.dtype, x.dtype)

    y = pl.pallas_call(
        functools.partial(_kernel_batched, offsets=tuple(offsets), pad=pad,
                          bn=bn),
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec((1, len(offsets), bn),
                         lambda b, t: (b // op_stride, 0, t)),
            pl.BlockSpec((1, n_pad + 2 * pad), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, t: (b, t)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_pad), out_dtype),
        interpret=interpret,
    )(data, xpad)
    return y[:, :n]


def _kernel_gather(idx_ref, data_ref, xpad_ref, o_ref, *, offsets, pad, bn):
    b, t = pl.program_id(0), pl.program_id(1)
    i = idx_ref[b]
    acc = jnp.zeros((1, bn), o_ref.dtype)
    base = t * bn
    for d, off in enumerate(offsets):
        xs = pl.load(xpad_ref, (pl.dslice(0, 1),
                                pl.dslice(base + pad + off, bn)))
        row = pl.load(data_ref, (pl.dslice(i, 1), pl.dslice(d, 1),
                                 pl.dslice(base, bn)))
        acc = acc + row[0] * xs
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("offsets", "interpret",
                                             "block_n"))
def dia_spmv_gather_pallas(offsets, data: jax.Array, x: jax.Array,
                           op_index: jax.Array, *, interpret: bool = True,
                           block_n: int = 1024) -> jax.Array:
    """Arbitrary operator-per-vector assignment: y[b] = data[op_index[b]] @
    x[b].

    offsets: static tuple; data (A, ndiag, n); x (B, n); op_index (B,)
    int32 — the general companion of the strided path for non-uniform
    fan-out (ragged expansion waves, mixed re-label batches). The operator
    stack stays fully VMEM-resident ((A, ndiag, n_pad) block, A is small:
    one operator per anchor) and each grid step dynamically slices its
    assigned operator's rows with `pl.ds` — on production TPU the idiomatic
    form moves `op_index` into `PrefetchScalarGridSpec` scalar prefetch so
    the index feeds the data BlockSpec's index_map instead; the dynamic
    in-kernel slice below is the portable/interpret form of the same
    access. Zero-padding semantics match `dia_spmv_pallas`.
    """
    nops, ndiag, n = data.shape
    bsz = x.shape[0]
    pad = max(1, max(abs(o) for o in offsets))
    bn, n_pad, nt = padded_tiles(n, block_n, "dia_spmv_gather")
    if bsz * nt > _MAX_GRID_STEPS:
        raise ValueError(f"dia_spmv_gather grid of {bsz}x{nt} steps exceeds "
                         f"the sanity cap {_MAX_GRID_STEPS}")
    if n_pad != n:
        data = jnp.pad(data, ((0, 0), (0, 0), (0, n_pad - n)))
    xpad = jnp.pad(x, ((0, 0), (pad, pad + (n_pad - n))))
    out_dtype = jnp.result_type(data.dtype, x.dtype)
    op_index = op_index.astype(jnp.int32)

    y = pl.pallas_call(
        functools.partial(_kernel_gather, offsets=tuple(offsets), pad=pad,
                          bn=bn),
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec((bsz,), lambda b, t: (0,)),
            pl.BlockSpec((nops, ndiag, n_pad), lambda b, t: (0, 0, 0)),
            pl.BlockSpec((1, n_pad + 2 * pad), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, t: (b, t)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_pad), out_dtype),
        interpret=interpret,
    )(op_index, data, xpad)
    return y[:, :n]
