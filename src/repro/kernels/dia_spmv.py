"""Pallas TPU kernel: DIA (diagonal-format) SpMV.

General banded companion to the stencil kernel (used for flattened /
non-stencil operators). The wrapper pre-pads x by the maximum |offset| so
every in-kernel load is in range: per output tile the kernel reads one
aligned x slice per diagonal and accumulates coeff·slice — unit-stride VPU
work, no gather (DESIGN §4.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(data_ref, xpad_ref, o_ref, *, offsets, pad, bn):
    t = pl.program_id(0)
    acc = jnp.zeros((bn,), o_ref.dtype)
    base = t * bn
    for d, off in enumerate(offsets):
        xs = pl.load(xpad_ref, (pl.dslice(base + pad + off, bn),))
        acc = acc + data_ref[d, :] * xs
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("offsets", "interpret", "block_n"))
def dia_spmv_pallas(offsets, data: jax.Array, x: jax.Array, *,
                    interpret: bool = True, block_n: int = 1024) -> jax.Array:
    """offsets: static tuple; data (ndiag, n); x (n,) → y (n,).

    Zero-padding by max|offset| encodes the boundary (matches DIA semantics:
    contributions from out-of-range columns vanish). Out-of-range data
    entries must already be zero — true for all assemblers in pde/.
    """
    n = x.shape[0]
    pad = max(1, max(abs(o) for o in offsets))
    bn = min(block_n, n)
    while n % bn:
        bn -= 1
    nt = n // bn
    xpad = jnp.pad(x, (pad, pad))

    return pl.pallas_call(
        functools.partial(_kernel, offsets=tuple(offsets), pad=pad, bn=bn),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((len(offsets), bn), lambda t: (0, t)),
            # full padded x resident in VMEM (solver vectors are ≤ O(100k))
            pl.BlockSpec((n + 2 * pad,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(data, xpad)
