"""Pallas TPU kernel: DIA (diagonal-format) SpMV.

General banded companion to the stencil kernel (used for flattened /
non-stencil operators). The wrapper pre-pads x by the maximum |offset| so
every in-kernel load is in range: per output tile the kernel reads one
aligned x slice per diagonal and accumulates coeff·slice — unit-stride VPU
work, no gather (DESIGN §4.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(data_ref, xpad_ref, o_ref, *, offsets, pad, bn):
    t = pl.program_id(0)
    acc = jnp.zeros((bn,), o_ref.dtype)
    base = t * bn
    for d, off in enumerate(offsets):
        xs = pl.load(xpad_ref, (pl.dslice(base + pad + off, bn),))
        acc = acc + data_ref[d, :] * xs
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("offsets", "interpret", "block_n"))
def dia_spmv_pallas(offsets, data: jax.Array, x: jax.Array, *,
                    interpret: bool = True, block_n: int = 1024) -> jax.Array:
    """offsets: static tuple; data (ndiag, n); x (n,) → y (n,).

    Zero-padding by max|offset| encodes the boundary (matches DIA semantics:
    contributions from out-of-range columns vanish). Out-of-range data
    entries must already be zero — true for all assemblers in pde/.
    """
    n = x.shape[0]
    pad = max(1, max(abs(o) for o in offsets))
    bn = min(block_n, n)
    while n % bn:
        bn -= 1
    nt = n // bn
    xpad = jnp.pad(x, (pad, pad))

    return pl.pallas_call(
        functools.partial(_kernel, offsets=tuple(offsets), pad=pad, bn=bn),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((len(offsets), bn), lambda t: (0, t)),
            # full padded x resident in VMEM (solver vectors are ≤ O(100k))
            pl.BlockSpec((n + 2 * pad,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(data, xpad)


def _kernel_batched(data_ref, xpad_ref, o_ref, *, offsets, pad, bn):
    t = pl.program_id(1)
    acc = jnp.zeros((1, bn), o_ref.dtype)
    base = t * bn
    for d, off in enumerate(offsets):
        xs = pl.load(xpad_ref, (pl.dslice(0, 1),
                                pl.dslice(base + pad + off, bn)))
        acc = acc + data_ref[0, d, :] * xs
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("offsets", "interpret", "block_n"))
def dia_spmv_batched_pallas(offsets, data: jax.Array, x: jax.Array, *,
                            interpret: bool = True,
                            block_n: int = 1024) -> jax.Array:
    """B stencil/band operators applied in ONE kernel launch.

    offsets: static tuple shared by the batch; data (B, ndiag, n);
    x (B, n) → y (B, n). The grid is (B, n∕bn): dimension 0 walks the
    independent operators, dimension 1 the output tiles — same unit-stride
    VPU body as the single kernel, amortizing the launch across the whole
    batch instead of issuing B separate dispatches. This is the explicit
    single-launch form of what Pallas's vmap batching rule produces when the
    lockstep solver vmaps the single kernel; use it for direct matched-batch
    SpMV at the ops boundary. Zero-padding semantics match
    `dia_spmv_pallas`.
    """
    bsz, _, n = data.shape
    pad = max(1, max(abs(o) for o in offsets))
    bn = min(block_n, n)
    while n % bn:
        bn -= 1
    nt = n // bn
    xpad = jnp.pad(x, ((0, 0), (pad, pad)))

    return pl.pallas_call(
        functools.partial(_kernel_batched, offsets=tuple(offsets), pad=pad,
                          bn=bn),
        grid=(bsz, nt),
        in_specs=[
            pl.BlockSpec((1, len(offsets), bn), lambda b, t: (b, 0, t)),
            pl.BlockSpec((1, n + 2 * pad), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, t: (b, t)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), x.dtype),
        interpret=interpret,
    )(data, xpad)
