"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernel
tests assert against, and the default CPU execution path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stencil5_matvec(coeffs: jax.Array, x: jax.Array) -> jax.Array:
    """y[i,j] = c·x[i,j] + n·x[i-1,j] + s·x[i+1,j] + w·x[i,j-1] + e·x[i,j+1]."""
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(1, 1), (1, 1)])
    return (
        coeffs[..., 0, :, :] * x
        + coeffs[..., 1, :, :] * xp[..., :-2, 1:-1]
        + coeffs[..., 2, :, :] * xp[..., 2:, 1:-1]
        + coeffs[..., 3, :, :] * xp[..., 1:-1, :-2]
        + coeffs[..., 4, :, :] * xp[..., 1:-1, 2:]
    )


def dia_spmv(offsets, data: jax.Array, x: jax.Array) -> jax.Array:
    """y[i] = Σ_d data[d, i] · x[i + offsets[d]], zero-padded."""
    n = data.shape[-1]
    y = jnp.zeros(jnp.broadcast_shapes(data[..., 0, :].shape, x.shape), x.dtype)
    for d, off in enumerate(offsets):
        row = data[..., d, :]
        if off == 0:
            y = y + row * x
        elif off > 0:
            y = y.at[..., : n - off].add(row[..., : n - off] * x[..., off:])
        else:
            y = y.at[..., -off:].add(row[..., -off:] * x[..., : n + off])
    return y


def fused_orthog(v_basis: jax.Array, w: jax.Array, mask: jax.Array,
                 acc_dtype=None):
    """Two-pass classical Gram-Schmidt (CGS2) against masked rows of v_basis.

    v_basis: (m, n) row basis (rows beyond the active count are arbitrary,
    masked out); w: (n,); mask: (m,) float {0,1}.
    acc_dtype: None accumulates in the storage dtype; a wider dtype (e.g.
    jnp.float64 under fp32 storage) widens ONLY the dot-product
    accumulation (operands stay in storage dtype — the same semantics as
    the Pallas kernel's widened h scratch) and casts the results back —
    the mixed-precision robustness knob.
    Returns (w_orth, h_total) — h_total: (m,) combined coefficients.
    """
    if acc_dtype is not None and jnp.dtype(acc_dtype) != w.dtype:
        acc = jnp.dtype(acc_dtype)
        h1 = mask.astype(acc) * jnp.matmul(v_basis, w,
                                           preferred_element_type=acc)
        w1 = w - v_basis.T @ h1.astype(w.dtype)
        h2 = mask.astype(acc) * jnp.matmul(v_basis, w1,
                                           preferred_element_type=acc)
        w2 = w1 - v_basis.T @ h2.astype(w.dtype)
        return w2, (h1 + h2).astype(w.dtype)
    h1 = mask * (v_basis @ w)
    w1 = w - v_basis.T @ h1
    h2 = mask * (v_basis @ w1)
    w2 = w1 - v_basis.T @ h2
    return w2, h1 + h2


def arnoldi_step(coeffs: jax.Array, inv_diag: jax.Array, c_rows: jax.Array,
                 v_basis: jax.Array, vin: jax.Array, mask: jax.Array,
                 acc_dtype=None):
    """One (deflated) Arnoldi inner iteration, unfused: Jacobi apply →
    stencil matvec → C-deflation projection → CGS2. The composition the
    fused kernel (arnoldi_step.py) replaces with a single launch.

    Returns (w_orth (n,), hcol (m+1,), bj (k,))."""
    nx, ny = coeffs.shape[-2:]
    u = inv_diag * vin
    w = stencil5_matvec(coeffs, u.reshape(nx, ny)).reshape(-1)
    bj = c_rows @ w
    w = w - c_rows.T @ bj
    w, h = fused_orthog(v_basis, w, mask, acc_dtype=acc_dtype)
    return w, h, bj


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None) -> jax.Array:
    """Naive full-materialization attention oracle with GQA broadcast.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D). Tq may be < Tk (decode), in
    which case query position i is at absolute position Tk - Tq + i.
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kq) / jnp.sqrt(d).astype(q.dtype)
    tk = k.shape[2]
    qpos = jnp.arange(tq) + (tk - tq)
    kpos = jnp.arange(tk)
    m = jnp.ones((tq, tk), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(m[None, None], scores, jnp.finfo(scores.dtype).min)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq)
