"""Pallas TPU kernel: variable-coefficient 5-point stencil matvec.

The paper's inner-loop hot spot (>90% of solve time is SpMV +
orthogonalization). TPU adaptation of CSR SpMV (DESIGN §4.1): the operator
lives in field form (5, nx, ny); the matvec is 5 shifted elementwise
multiplies — pure VPU work, unit-stride, no gather.

Tiling: grid over row-tiles (bx, ny). Halo rows come from neighbor-tile
input blocks selected by a clamped index_map; the first/last tiles mask the
out-of-range halo. The whole working set per step is (5+3)·bx·ny elements —
sized to sit comfortably in VMEM (bx chosen so ≤ ~2 MB at f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, x_ref, xup_ref, xdn_ref, o_ref, *, nx_tiles: int):
    t = pl.program_id(0)
    c = c_ref[...]          # (5, bx, ny)
    x = x_ref[...]          # (bx, ny)
    bx, ny = x.shape

    # north neighbor of row r is x[r-1]; row 0 needs the last row of the
    # previous tile (zero for the first tile).
    prev_last = jnp.where(t > 0, xup_ref[bx - 1, :], jnp.zeros_like(x[0]))
    up = jnp.concatenate([prev_last[None, :], x[:-1, :]], axis=0)

    next_first = jnp.where(t < nx_tiles - 1, xdn_ref[0, :], jnp.zeros_like(x[0]))
    down = jnp.concatenate([x[1:, :], next_first[None, :]], axis=0)

    zcol = jnp.zeros((bx, 1), x.dtype)
    left = jnp.concatenate([zcol, x[:, :-1]], axis=1)
    right = jnp.concatenate([x[:, 1:], zcol], axis=1)

    o_ref[...] = (c[0] * x + c[1] * up + c[2] * down + c[3] * left + c[4] * right)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def stencil5_matvec_pallas(coeffs: jax.Array, x: jax.Array, *,
                           interpret: bool = True, block_rows: int = 64) -> jax.Array:
    """coeffs (5, nx, ny) × x (nx, ny) → (nx, ny).

    Dtype-polymorphic: output/accumulation carry result_type(coeffs, x) —
    fp32 operands (mixed-precision inner cycles) never silently widen."""
    nx, ny = x.shape
    bx = min(block_rows, nx)
    while nx % bx:
        bx -= 1  # largest divisor ≤ block_rows (grids here are powers of two)
    nt = nx // bx
    out_dtype = jnp.result_type(coeffs.dtype, x.dtype)

    return pl.pallas_call(
        functools.partial(_kernel, nx_tiles=nt),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((5, bx, ny), lambda t: (0, t, 0)),
            pl.BlockSpec((bx, ny), lambda t: (t, 0)),
            # clamped neighbor tiles supply the halo rows
            pl.BlockSpec((bx, ny), lambda t: (jnp.maximum(t - 1, 0), 0)),
            pl.BlockSpec((bx, ny), lambda t: (jnp.minimum(t + 1, nt - 1), 0)),
        ],
        out_specs=pl.BlockSpec((bx, ny), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny), out_dtype),
        interpret=interpret,
    )(coeffs, x, x, x)
