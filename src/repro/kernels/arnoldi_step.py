"""Pallas TPU kernel: one fused (deflated) Arnoldi inner iteration.

The lockstep hot loop runs, per Arnoldi step: Jacobi preconditioner apply →
5-point stencil matvec → C-deflation projection → two-pass CGS2 against the
growing basis. Unfused, that is four kernel launches with w round-tripping
through HBM between each. This kernel is the whole step as ONE launch: a
5-phase sequential grid over row tiles (the multi-phase scratch pattern of
`fused_orthog` composed with the clamped neighbor-halo blocks of
`stencil_matvec`), with the intermediate vector held in the output block
and every reduction (Cᴴw, the two CGS2 coefficient passes) accumulated in
VMEM scratch:

  phase 0: u = D⁻¹·vin (self + halo tiles); w0[tile] = stencil(c, u);
           cacc += C[:, tile] · w0[tile]
  phase 1: w1[tile] = w0[tile] − Cᵀ[tile] · cacc;
           h1 += mask · (V[:, tile] · w1[tile])
  phase 2: w2[tile] = w1[tile] − Vᵀ[tile] · h1
  phase 3: h2 += mask · (V[:, tile] · w2[tile])
  phase 4: w3[tile] = w2[tile] − Vᵀ[tile] · h2; emit h = h1 + h2, b = cacc

(`fused_orthog` overlaps its phases 2/3 into one; here they are split
because the h2 accumulation must see the FULLY updated w2 of its own tile
only — same dependency structure, one more pass over the tile in VMEM,
still zero extra HBM traffic.)

The deflation block C may be empty (k = 0, plain GMRES): the wrapper pads
it to one ZERO row, whose projection is an exact no-op.

The norm/breakdown/Givens tail of the Arnoldi step stays outside — it is
O(m) scalar work on the small Hessenberg column, not worth a launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dot2(a, b):
    """Reduce the trailing (bx, ny) tile axes: (r, bx, ny)·(bx, ny) → (r,)."""
    return jax.lax.dot_general(
        a.reshape(a.shape[0], -1), b.reshape(-1),
        (((1,), (0,)), ((), ())), preferred_element_type=None)


def _kernel(c5_ref, idg_ref, idg_up_ref, idg_dn_ref, vin_ref, vin_up_ref,
            vin_dn_ref, crows_ref, v_ref, mask_ref, wout_ref, h_ref, b_ref,
            cacc_s, h1_s, h2_s, *, nx_tiles: int):
    phase = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(jnp.logical_and(phase == 0, t == 0))
    def _init():
        cacc_s[...] = jnp.zeros_like(cacc_s)
        h1_s[...] = jnp.zeros_like(h1_s)
        h2_s[...] = jnp.zeros_like(h2_s)

    @pl.when(phase == 0)
    def _p0():
        c = c5_ref[...]                      # (5, bx, ny)
        u = idg_ref[...] * vin_ref[...]      # Jacobi apply, this tile
        bx, ny = u.shape
        # halo rows are preconditioned on the fly from the neighbor tiles
        # (clamped index_map; first/last tiles mask the out-of-range halo)
        prev = jnp.where(t > 0, idg_up_ref[bx - 1, :] * vin_up_ref[bx - 1, :],
                         jnp.zeros_like(u[0]))
        nxt = jnp.where(t < nx_tiles - 1, idg_dn_ref[0, :] * vin_dn_ref[0, :],
                        jnp.zeros_like(u[0]))
        up = jnp.concatenate([prev[None, :], u[:-1, :]], axis=0)
        down = jnp.concatenate([u[1:, :], nxt[None, :]], axis=0)
        zcol = jnp.zeros((bx, 1), u.dtype)
        left = jnp.concatenate([zcol, u[:, :-1]], axis=1)
        right = jnp.concatenate([u[:, 1:], zcol], axis=1)
        w0 = (c[0] * u + c[1] * up + c[2] * down + c[3] * left + c[4] * right)
        wout_ref[...] = w0
        cacc_s[...] += _dot2(crows_ref[...], w0).astype(cacc_s.dtype)

    @pl.when(phase == 1)
    def _p1():
        cr = crows_ref[...]                  # (k1, bx, ny)
        w1 = wout_ref[...] - jnp.tensordot(cacc_s[...].astype(cr.dtype), cr,
                                           axes=([0], [0]))
        wout_ref[...] = w1
        h1_s[...] += (mask_ref[...] * _dot2(v_ref[...], w1)).astype(h1_s.dtype)

    @pl.when(phase == 2)
    def _p2():
        v = v_ref[...]                       # (m1, bx, ny)
        wout_ref[...] = wout_ref[...] - jnp.tensordot(
            h1_s[...].astype(v.dtype), v, axes=([0], [0]))

    @pl.when(phase == 3)
    def _p3():
        h2_s[...] += (mask_ref[...]
                      * _dot2(v_ref[...], wout_ref[...])).astype(h2_s.dtype)

    @pl.when(phase == 4)
    def _p4():
        v = v_ref[...]
        wout_ref[...] = wout_ref[...] - jnp.tensordot(
            h2_s[...].astype(v.dtype), v, axes=([0], [0]))

        @pl.when(t == nt - 1)
        def _emit():
            h_ref[...] = (h1_s[...] + h2_s[...]).astype(h_ref.dtype)
            b_ref[...] = cacc_s[...].astype(b_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows",
                                             "acc_dtype"))
def arnoldi_step_pallas(coeffs: jax.Array, inv_diag: jax.Array,
                        c_rows: jax.Array, v_basis: jax.Array,
                        vin: jax.Array, mask: jax.Array, *,
                        interpret: bool = True, block_rows: int = 64,
                        acc_dtype=None):
    """One fused Arnoldi inner iteration.

    coeffs  : (5, nx, ny) stencil fields
    inv_diag: (n,) Jacobi inverse diagonal (pass ones for precond=None)
    c_rows  : (k, n) deflation rows Cᴴ (k = 0 → padded to one zero row)
    v_basis : (m+1, n) Krylov basis rows (inactive rows masked)
    vin     : (n,) current basis vector v_j
    mask    : (m+1,) float {0,1} — rows 0..j active
    acc_dtype: widen ONLY the CGS2 coefficient scratch (fp32 storage / fp64
    accumulate — KrylovConfig.cgs2_acc); w, b stay in storage dtype.

    Returns (w_orth (n,), hcol (m+1,), bj (k,)) — exactly the unfused
    `precond → matvec → C-projection → fused_orthog` composition.
    """
    nx, ny = coeffs.shape[-2:]
    m1 = v_basis.shape[0]
    k = c_rows.shape[0]
    k1 = max(k, 1)
    dt = vin.dtype
    if k == 0:
        c_rows = jnp.zeros((1, nx * ny), dt)
    bx = min(block_rows, nx)
    while nx % bx:
        bx -= 1  # largest divisor ≤ block_rows (grids here are powers of two)
    nt = nx // bx
    acc = jnp.dtype(acc_dtype) if acc_dtype is not None else dt

    wout, h, bj = pl.pallas_call(
        functools.partial(_kernel, nx_tiles=nt),
        grid=(5, nt),
        in_specs=[
            pl.BlockSpec((5, bx, ny), lambda p, t: (0, t, 0)),
            pl.BlockSpec((bx, ny), lambda p, t: (t, 0)),
            # clamped neighbor tiles supply the halo rows (phase 0 only)
            pl.BlockSpec((bx, ny), lambda p, t: (jnp.maximum(t - 1, 0), 0)),
            pl.BlockSpec((bx, ny), lambda p, t: (jnp.minimum(t + 1, nt - 1), 0)),
            pl.BlockSpec((bx, ny), lambda p, t: (t, 0)),
            pl.BlockSpec((bx, ny), lambda p, t: (jnp.maximum(t - 1, 0), 0)),
            pl.BlockSpec((bx, ny), lambda p, t: (jnp.minimum(t + 1, nt - 1), 0)),
            pl.BlockSpec((k1, bx, ny), lambda p, t: (0, t, 0)),
            pl.BlockSpec((m1, bx, ny), lambda p, t: (0, t, 0)),
            pl.BlockSpec((m1,), lambda p, t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bx, ny), lambda p, t: (t, 0)),
            pl.BlockSpec((m1,), lambda p, t: (0,)),
            pl.BlockSpec((k1,), lambda p, t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nx, ny), dt),
            jax.ShapeDtypeStruct((m1,), dt),
            jax.ShapeDtypeStruct((k1,), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((k1,), dt),
            pltpu.VMEM((m1,), acc),
            pltpu.VMEM((m1,), acc),
        ],
        interpret=interpret,
    )(coeffs,
      inv_diag.reshape(nx, ny), inv_diag.reshape(nx, ny),
      inv_diag.reshape(nx, ny),
      vin.reshape(nx, ny), vin.reshape(nx, ny), vin.reshape(nx, ny),
      c_rows.reshape(k1, nx, ny), v_basis.reshape(m1, nx, ny), mask)
    return wout.reshape(-1), h, bj[:k]
