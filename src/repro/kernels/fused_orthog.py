"""Pallas TPU kernel: fused CGS2 orthogonalization (the Arnoldi inner loop's
second hot spot after the matvec).

TPU adaptation (DESIGN §4.4): paper-faithful MGS is a chain of m dependent
dot/axpy pairs — latency-bound. CGS2 reshapes the work into two matmul pairs
(h = V·w; w −= Vᵀ·h, twice) with equivalent robustness (Giraud et al. 2005).
This kernel fuses both passes into ONE launch: a 3-phase sequential grid
with the projection coefficients held in VMEM scratch, so the intermediate
half-orthogonalized vector never round-trips to HBM.

  phase 0: accumulate h1 += V[:, tile] · w[tile]         (per column tile)
  phase 1: w1[tile] = w[tile] − Vᵀh1; accumulate h2 += V · w1
  phase 2: w2[tile] = w1[tile] − Vᵀh2; emit h = h1 + h2
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, w_ref, mask_ref, wout_ref, h_ref, h1_s, h2_s):
    phase = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    v = v_ref[...]        # (m1, bn) storage dtype
    mask = mask_ref[...]  # (m1,)
    acc = h1_s.dtype      # accumulation dtype (== storage unless widened)

    @pl.when(jnp.logical_and(phase == 0, t == 0))
    def _init():
        h1_s[...] = jnp.zeros_like(h1_s)
        h2_s[...] = jnp.zeros_like(h2_s)

    @pl.when(phase == 0)
    def _p0():
        h1_s[...] += (mask * (v @ w_ref[...])).astype(acc)

    @pl.when(phase == 1)
    def _p1():
        w1 = w_ref[...] - v.T @ h1_s[...].astype(v.dtype)
        wout_ref[...] = w1
        h2_s[...] += (mask * (v @ w1)).astype(acc)

    @pl.when(phase == 2)
    def _p2():
        wout_ref[...] = wout_ref[...] - v.T @ h2_s[...].astype(v.dtype)
        @pl.when(t == nt - 1)
        def _emit():
            h_ref[...] = (h1_s[...] + h2_s[...]).astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n",
                                             "acc_dtype"))
def fused_orthog_pallas(v_basis: jax.Array, w: jax.Array, mask: jax.Array, *,
                        interpret: bool = True, block_n: int = 2048,
                        acc_dtype=None):
    """v_basis (m1, n), w (n,), mask (m1,) → (w_orth (n,), h (m1,)).

    Ragged n is handled by padding up to a multiple of the block size with
    ZERO columns (a masked tail): zero basis columns contribute nothing to
    h, and the padded slice of w_orth is discarded. This keeps the block
    size at the requested tile (the old fallback shrank bn until it divided
    n — degrading to bn = 1, one grid step per element, for prime-ish n).

    acc_dtype: widen ONLY the h accumulation scratch (fp32 storage / fp64
    accumulate); outputs stay in w.dtype.
    """
    from repro.kernels.dia_spmv import padded_tiles

    m1, n = v_basis.shape
    bn, n_pad, nt = padded_tiles(n, block_n, "fused_orthog", steps_factor=3)
    if n_pad != n:
        v_basis = jnp.pad(v_basis, ((0, 0), (0, n_pad - n)))
        w = jnp.pad(w, (0, n_pad - n))
    acc = jnp.dtype(acc_dtype) if acc_dtype is not None else w.dtype

    wout, h = pl.pallas_call(
        _kernel,
        grid=(3, nt),
        in_specs=[
            pl.BlockSpec((m1, bn), lambda p, t: (0, t)),
            pl.BlockSpec((bn,), lambda p, t: (t,)),
            pl.BlockSpec((m1,), lambda p, t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda p, t: (t,)),
            pl.BlockSpec((m1,), lambda p, t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), w.dtype),
            jax.ShapeDtypeStruct((m1,), w.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((m1,), acc),
            pltpu.VMEM((m1,), acc),
        ],
        interpret=interpret,
    )(v_basis, w, mask)
    return wout[:n], h
