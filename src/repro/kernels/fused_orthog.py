"""Pallas TPU kernel: fused CGS2 orthogonalization (the Arnoldi inner loop's
second hot spot after the matvec).

TPU adaptation (DESIGN §4.4): paper-faithful MGS is a chain of m dependent
dot/axpy pairs — latency-bound. CGS2 reshapes the work into two matmul pairs
(h = V·w; w −= Vᵀ·h, twice) with equivalent robustness (Giraud et al. 2005).
This kernel fuses both passes into ONE launch: a 3-phase sequential grid
with the projection coefficients held in VMEM scratch, so the intermediate
half-orthogonalized vector never round-trips to HBM.

  phase 0: accumulate h1 += V[:, tile] · w[tile]         (per column tile)
  phase 1: w1[tile] = w[tile] − Vᵀh1; accumulate h2 += V · w1
  phase 2: w2[tile] = w1[tile] − Vᵀh2; emit h = h1 + h2
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(v_ref, w_ref, mask_ref, wout_ref, h_ref, h1_s, h2_s):
    phase = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    v = v_ref[...]        # (m1, bn)
    mask = mask_ref[...]  # (m1,)

    @pl.when(jnp.logical_and(phase == 0, t == 0))
    def _init():
        h1_s[...] = jnp.zeros_like(h1_s)
        h2_s[...] = jnp.zeros_like(h2_s)

    @pl.when(phase == 0)
    def _p0():
        h1_s[...] += mask * (v @ w_ref[...])

    @pl.when(phase == 1)
    def _p1():
        w1 = w_ref[...] - v.T @ h1_s[...]
        wout_ref[...] = w1
        h2_s[...] += mask * (v @ w1)

    @pl.when(phase == 2)
    def _p2():
        wout_ref[...] = wout_ref[...] - v.T @ h2_s[...]
        @pl.when(t == nt - 1)
        def _emit():
            h_ref[...] = h1_s[...] + h2_s[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def fused_orthog_pallas(v_basis: jax.Array, w: jax.Array, mask: jax.Array, *,
                        interpret: bool = True, block_n: int = 2048):
    """v_basis (m1, n), w (n,), mask (m1,) → (w_orth (n,), h (m1,))."""
    m1, n = v_basis.shape
    bn = min(block_n, n)
    while n % bn:
        bn -= 1
    nt = n // bn

    wout, h = pl.pallas_call(
        _kernel,
        grid=(3, nt),
        in_specs=[
            pl.BlockSpec((m1, bn), lambda p, t: (0, t)),
            pl.BlockSpec((bn,), lambda p, t: (t,)),
            pl.BlockSpec((m1,), lambda p, t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda p, t: (t,)),
            pl.BlockSpec((m1,), lambda p, t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((m1,), w.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((m1,), w.dtype),
            pltpu.VMEM((m1,), w.dtype),
        ],
        interpret=interpret,
    )(v_basis, w, mask)
    return wout, h
