"""Pallas TPU kernels for the compute hot spots (DESIGN §4):

  stencil_matvec — variable-coefficient 5-point stencil SpMV (solver inner loop)
  dia_spmv       — banded/diagonal-format SpMV (general flattened operators)
  fused_orthog   — fused CGS2 Gram-Schmidt (Arnoldi orthogonalization)
  flash_attention— tiled online-softmax attention (LM prefill; beyond-paper)

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling, a jit'd
dispatch wrapper in ops.py, and a pure-jnp oracle in ref.py. TPU is the
compile target; CPU validation runs interpret=True (tests/test_kernels.py
sweeps shapes × dtypes against the oracles).
"""
