"""Pallas TPU kernel: flash attention (online-softmax, tiled), with causal
masking, optional sliding window (Mixtral/RecurrentGemma) and GQA head
mapping — the LM stack's prefill hot spot (beyond-paper kernel, DESIGN §2).

Grid (B, Hq, Tq/bq, Tk/bk) with the key axis innermost-sequential; running
(max, denom, acc) live in VMEM scratch across key steps, so scores never
materialize in HBM: O(T²) compute, O(T) memory. The GQA mapping happens in
the K/V index_map (query head h reads kv head h // group) — no repeat of
K/V in memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            bq: int, bk: int, tq: int, tk: int, causal: bool, window):
    qt = pl.program_id(2)
    kt = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kt == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)       # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    d = q.shape[-1]

    s = (q @ k.T) * (1.0 / jnp.sqrt(jnp.float32(d)))   # (bq, bk)
    qpos = qt * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (tk - tq)
    kpos = kt * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_s[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + p @ v
    m_s[...] = m_new

    @pl.when(kt == nk - 1)
    def _emit():
        denom = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret",
                                    "block_q", "block_k"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           interpret: bool = True,
                           block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q (B, Hq, Tq, D); k, v (B, Hkv, Tk, D) → (B, Hq, Tq, D)."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv

    bq = min(block_q, tq)
    while tq % bq:
        bq -= 1
    bk = min(block_k, tk)
    while tk % bk:
        bk -= 1

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, tq=tq, tk=tk,
                          causal=causal, window=window),
        grid=(b, hq, tq // bq, tk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qt, kt: (b_, h, qt, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qt, kt: (b_, h // group, kt, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qt, kt: (b_, h // group, kt, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, qt, kt: (b_, h, qt, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
