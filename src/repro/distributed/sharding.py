"""Sharding rules: logical activation/parameter axes → mesh PartitionSpecs.

Two consumers live here:

  * the TRAINING/SERVING stack (launch/mesh.py meshes) — logical
    activation/parameter axes resolved against the ACTIVE abstract mesh
    (`logical_to_spec`, `shard_act`, `param_specs`);
  * the DATAGEN pipeline (core/pipeline.py) — solver-array specs for the
    lockstep batched GCRO-DR engine, resolved against an EXPLICIT 1-D
    `data` mesh (`datagen_mesh`, `ChainSharding`): arrays with a leading
    chain axis (right-hand sides, residuals, per-chain recycle carries
    U_k/C_k, batched operator/preconditioner leaves) shard on "dp"; the
    small stacked eigen/LS factors are computed ON-DEVICE per cycle
    (solvers/devlinalg.py) — they are (B, m, m)-small, chain-leading like
    everything else, and never gathered to host between cycles.

Mesh layout (launch/mesh.py):
    single-pod : (data=16, model=16)
    multi-pod  : (pod=2, data=16, model=16)

Logical axes used by the model code:
    "dp"    batch                 → ("pod","data") when a pod axis exists
    "tp"    heads / ffn / experts / vocab → "model"
    "fsdp"  weight-shard axis     → "data" (ZeRO-style parameter sharding)
    "sp"    sequence (long-context KV) → "model" where chosen per-arch
    None    replicated

The model code never names raw mesh axes — it calls shard_act(x, spec) with
logical names, resolved against the active (abstract) mesh at trace time, so
the same model lowers on any mesh (including single-device CPU smoke tests,
where the constraint is a no-op).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _active_mesh_axes() -> Tuple[str, ...]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return ()
    if mesh is None or getattr(mesh, "empty", False):
        return ()
    return tuple(mesh.axis_names)


def batch_axes() -> Optional[Tuple[str, ...]]:
    names = _active_mesh_axes()
    axes = tuple(a for a in ("pod", "data") if a in names)
    return axes or None


def logical_to_spec(logical: Sequence) -> Optional[P]:
    """Map a tuple of logical axis names to a PartitionSpec under the active
    mesh; returns None when no mesh is active (smoke tests)."""
    names = _active_mesh_axes()
    if not names:
        return None
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        elif ax == "dp":
            out.append(batch_axes())
        elif ax == "tp":
            out.append("model" if "model" in names else None)
        elif ax in ("fsdp", "sp"):
            out.append("data" if "data" in names else None)
        elif ax == "sq":   # sequence-parallel attention (heads don't divide
            out.append("model" if "model" in names else None)  # the TP axis)
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*out)


def axis_size(name: str) -> int:
    """Size of a mesh axis under the active (abstract) mesh; 1 if absent."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return 1
    if mesh is None or getattr(mesh, "empty", False):
        return 1
    return dict(mesh.shape).get(name, 1)


def shard_act(x: jax.Array, logical: Sequence):
    """with_sharding_constraint under logical names; no-op without a mesh."""
    spec = logical_to_spec(logical)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # shapes not compatible with mesh (tiny smoke configs)


# --------------------------------------------------------------------------
# Parameter sharding rules: match on parameter-path suffixes.
# Conventions (models/*): weights are dicts; names below are leaf key names.
# Megatron TP + ZeRO/FSDP hybrid:
#   column-parallel (output dim sharded over model):  wq wk wv w_up w_gate
#   row-parallel    (input dim sharded over model):   wo w_down
#   experts:        leading expert dim over model (EP), ffn dim over fsdp
#   embeddings/head: vocab over model
# --------------------------------------------------------------------------

_RULES = [
    # (suffix, spec builder: takes ndim -> logical tuple)
    ("embed", lambda nd: ("tp", None)),
    ("lm_head", lambda nd: (None, "tp")),
    ("w_experts_up", lambda nd: ("tp", None, "fsdp")),
    ("w_experts_gate", lambda nd: ("tp", None, "fsdp")),
    ("w_experts_down", lambda nd: ("tp", "fsdp", None)),
    ("w_router", lambda nd: (None, None)),
    ("wq", lambda nd: ("fsdp", "tp")),
    ("wk", lambda nd: ("fsdp", "tp")),
    ("wv", lambda nd: ("fsdp", "tp")),
    ("wo", lambda nd: ("tp", "fsdp")),
    ("w_gate", lambda nd: ("fsdp", "tp")),
    ("w_up", lambda nd: ("fsdp", "tp")),
    ("w_down", lambda nd: ("tp", "fsdp")),
    # MLA low-rank factors
    ("wq_a", lambda nd: ("fsdp", None)),
    ("wq_b", lambda nd: (None, "tp")),
    ("wkv_a", lambda nd: ("fsdp", None)),
    ("wkv_b", lambda nd: (None, "tp")),
    # recurrent / conv blocks: shard the channel dim over model
    ("w_rec_in", lambda nd: ("fsdp", "tp")),
    ("w_rec_out", lambda nd: ("tp", "fsdp")),
]


def _spec_for_path(path: str, ndim: int) -> Tuple:
    for suffix, fn in _RULES:
        if path.endswith(suffix):
            logical = fn(ndim)
            if len(logical) > ndim:  # stacked-per-layer leading dim
                logical = logical[:ndim]
            if len(logical) < ndim:  # leading scan/stack dims replicate
                logical = (None,) * (ndim - len(logical)) + tuple(logical)
            return tuple(logical)
    return (None,) * ndim  # biases, norms, small tables: replicated


def _axis_sizes() -> dict:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return {}
    if mesh is None or getattr(mesh, "empty", False):
        return {}
    return dict(mesh.shape)


def _validate_divisibility(spec: P, shape) -> P:
    """Drop mesh-axis assignments that don't divide the dim size (e.g.
    Whisper's 51865 vocab cannot shard over a 16-wide model axis — such
    tables replicate; Megatron would pad, we keep configs exact)."""
    sizes = _axis_sizes()
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        out.append(entry if (i < len(shape) and shape[i] % total == 0)
                   else None)
    return P(*out)


def param_specs(params_shape_tree) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree for a parameter (shape) tree, by path suffix.

    Works on trees of ShapeDtypeStruct (jax.eval_shape output) or arrays.
    Dims whose size doesn't divide the assigned mesh axes fall back to
    replicated (validated against the active abstract mesh).
    """

    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = "/".join(str(k) for k in keys if k is not None)
        logical = _spec_for_path(name, len(leaf.shape))
        spec = logical_to_spec(logical)
        if spec is None:
            return P()
        return _validate_divisibility(spec, tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(visit, params_shape_tree)


# --------------------------------------------------------------------------
# Datagen solver-array sharding: the lockstep batched GCRO-DR engine
# (solvers/batched.py) advances B independent recycle chains; the chains
# never exchange Krylov information, so the leading chain axis is a pure
# data-parallel ("dp") axis. `ChainSharding` is the spec table the solver
# consults: shard the chain axis of every large device array over a 1-D
# `data` mesh, keep everything else (scalars, small host factors) replicated.
# --------------------------------------------------------------------------


def datagen_mesh(max_shards: Optional[int] = None) -> Optional[Mesh]:
    """1-D (data,) mesh over the available devices for chunk-chain sharding.

    Returns None on a single device (the sharded engine then degenerates to
    the plain batched engine — no mesh, no resharding cost). Test sharding
    on CPU with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    devs = jax.devices()
    n = len(devs) if max_shards is None else min(len(devs), int(max_shards))
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), ("data",))


class ChainSharding:
    """Solver-array specs for lockstep chunk-chain sharding.

    Logical rule (the datagen analogue of the "dp" activation axis): any
    solver array whose LEADING axis is the chain axis — right-hand sides
    (B, n), running solutions/residuals (B, n), Krylov bases (B, m+1, n),
    per-chain recycle carries U_k/C_k (B, n, k), batched operator and
    preconditioner leaves (B, ...) — shards that axis over the `data` mesh
    axis. The stacked O(m³) eigen/LS cleanup also carries the chain axis
    (solvers/devlinalg.py) and runs inside the same sharded dispatch; only
    the per-cycle continuation flags cross to host.

    Arrays whose leading dim does not divide the shard count fall back to
    replicated (the pipeline pads the chain count so the hot arrays always
    divide)."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    @property
    def num_shards(self) -> int:
        return int(dict(self.mesh.shape)["data"])

    def spec(self, ndim: int) -> P:
        """PartitionSpec sharding only the leading (chain) axis on "dp"."""
        return P("data", *((None,) * (ndim - 1)))

    def put(self, x):
        """device_put one solver array with the chain axis sharded; arrays
        that cannot shard (scalars, non-divisible leading dim) replicate."""
        x = jnp.asarray(x)
        if x.ndim == 0 or x.shape[0] % self.num_shards != 0:
            spec = P()
        else:
            spec = self.spec(x.ndim)
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def put_tree(self, tree):
        """Shard every array leaf of an operator/preconditioner pytree
        (batched leaves all carry the leading chain axis)."""
        return jax.tree_util.tree_map(self.put, tree)
