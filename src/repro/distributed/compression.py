"""Gradient compression for the cross-pod (DCN) all-reduce, with error
feedback (DESIGN §5).

At 2+ pods the "pod" axis all-reduce crosses data-center network, ~10×
slower than ICI — the classic mitigation is compress-before-reduce with an
error-feedback accumulator so the bias is corrected on later steps
(1-bit Adam / EF-SGD lineage).

Two codecs:
  * int8_ef  — per-tensor symmetric int8 quantization (32→8 bits, 4×)
  * topk_ef  — magnitude top-k sparsification (k fraction kept)

Both satisfy the error-feedback invariant tested by hypothesis in
tests/test_compression.py:  decode(encode(g + e)) + e' == g + e  (exactly:
residual carries what was dropped), so the compressed-SGD iterates track
the uncompressed ones within O(lr·‖e‖).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- int8 EF

def int8_encode(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_ef_step(g: jax.Array, err: jax.Array):
    """Returns (decoded gradient to apply, new error residual)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = int8_encode(corrected)
    dec = int8_decode(q, scale)
    return dec, corrected - dec


# ----------------------------------------------------------------- topk EF

def topk_ef_step(g: jax.Array, err: jax.Array, frac: float = 0.1):
    corrected = g.astype(jnp.float32) + err
    flat = corrected.ravel()
    k = max(int(flat.size * frac), 1)
    thresh = jnp.sort(jnp.abs(flat))[-k]
    mask = (jnp.abs(corrected) >= thresh).astype(jnp.float32)
    dec = corrected * mask
    return dec, corrected - dec


# ------------------------------------------------------------- tree level

def compress_tree(grads, err_tree, codec: str = "int8", frac: float = 0.1):
    """Apply EF compression leaf-wise. Returns (grads', err')."""
    if codec == "none":
        return grads, err_tree

    def leaf(g, e):
        if codec == "int8":
            d, ne = int8_ef_step(g, e)
        elif codec == "topk":
            d, ne = topk_ef_step(g, e, frac)
        else:
            raise ValueError(codec)
        return d.astype(g.dtype), ne

    pairs = jax.tree_util.tree_map(leaf, grads, err_tree)
    outer = jax.tree_util.tree_structure(grads)
    dec = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    del outer
    return dec, err


def init_error_tree(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
