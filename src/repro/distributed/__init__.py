"""Distributed runtime: mesh-aware sharding rules, logical-axis helpers,
datagen chunk-chain sharding and gradient compression."""
from repro.distributed.sharding import (ChainSharding, batch_axes,
                                        datagen_mesh, logical_to_spec,
                                        param_specs, shard_act)

__all__ = ["ChainSharding", "batch_axes", "datagen_mesh", "logical_to_spec",
           "param_specs", "shard_act"]
