"""Distributed runtime: mesh-aware sharding rules, logical-axis helpers and
gradient compression."""
from repro.distributed.sharding import (batch_axes, logical_to_spec,
                                        param_specs, shard_act)

__all__ = ["batch_axes", "logical_to_spec", "param_specs", "shard_act"]
