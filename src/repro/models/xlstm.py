"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, fully
parallelizable) and sLSTM (scalar memory with exponential gating).

d_ff = 0 in the assigned config: the blocks carry their own up/down
projections (pre-up-projection architecture, §4 of the paper), so there is
no separate MLP.

Decode is O(1)-state: mLSTM carries (C (H,dh,dh), n (H,dh), m (H)); sLSTM
carries (c, n, m, h_prev) — no KV cache at any context length, which is why
xlstm-125m runs the long_500k cell.

Training/prefill runs a chunked recurrence: lax.scan over chunks with the
exact sequential update inside (simple, correct; the chunkwise-parallel
formulation is a documented TODO — FLOP structure is identical).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.common import dense_init, rmsnorm, rmsnorm_init


def _heads(cfg):
    h = cfg.n_heads
    dh = (cfg.d_model * 2) // h  # blocks operate at 2× up-projected width
    return h, dh


def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    h, dh = _heads(cfg)
    du = h * dh
    ks = jax.random.split(key, 8)
    return {
        "w_rec_in": dense_init(ks[0], d, 2 * du, dtype),   # up-proj (x, gate)
        "wq": dense_init(ks[1], du, du, dtype),
        "wk": dense_init(ks[2], du, du, dtype),
        "wv": dense_init(ks[3], du, du, dtype),
        "w_if": dense_init(ks[4], du, 2 * h, dtype),       # input/forget gates
        "skip_scale": jnp.ones((du,), dtype),
        "out_norm": rmsnorm_init(du, dtype),
        "w_rec_out": dense_init(ks[5], du, d, dtype),
    }


def _mlstm_step(q, k, v, i_g, f_g, state):
    """One timestep of mLSTM. q,k,v (B,H,dh); i_g,f_g (B,H); state
    (C (B,H,dh,dh), n (B,H,dh), m (B,H))."""
    c, n, m = state
    log_f = -jax.nn.softplus(-f_g)          # log σ(f)
    m_new = jnp.maximum(log_f + m, i_g)
    i_sc = jnp.exp(i_g - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    c = f_sc[..., None, None] * c + i_sc[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_sc[..., None] * n + i_sc[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_new))
    h_t = jnp.einsum("bhd,bhde->bhe", q, c) / denom[..., None]
    return (c, n, m_new), h_t


def mlstm_apply(p, cfg, x, *, mode: str = "train", cache=None, chunk: int = 256):
    b, t, d = x.shape
    h, dh = _heads(cfg)
    du = h * dh
    up = x @ p["w_rec_in"]
    u, z = up[..., :du], up[..., du:]
    u = shard_act(u, ("dp", None, "tp"))
    q = (u @ p["wq"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3) / jnp.sqrt(
        jnp.asarray(dh, x.dtype))
    k = (u @ p["wk"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = (u @ p["wv"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    gf = (u @ p["w_if"]).astype(jnp.float32).reshape(b, t, 2, h)
    i_g, f_g = gf[:, :, 0], gf[:, :, 1]

    if cache is None:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))
    else:
        state = (cache["c"], cache["n"], cache["m"])

    if mode == "decode":
        state, h_t = _mlstm_step(q[:, :, 0].astype(jnp.float32),
                                 k[:, :, 0].astype(jnp.float32),
                                 v[:, :, 0].astype(jnp.float32),
                                 i_g[:, 0], f_g[:, 0], state)
        hs = h_t[:, None]                                   # (B,1,H,dh)
        hs = hs.transpose(0, 1, 2, 3).reshape(b, 1, du).astype(x.dtype)
    else:
        def step(st, inp):
            qt, kt, vt, it, ft = inp
            st, ht = _mlstm_step(qt, kt, vt, it, ft, st)
            return st, ht

        xs = (q.transpose(2, 0, 1, 3).astype(jnp.float32),
              k.transpose(2, 0, 1, 3).astype(jnp.float32),
              v.transpose(2, 0, 1, 3).astype(jnp.float32),
              i_g.transpose(1, 0, 2), f_g.transpose(1, 0, 2))
        state, hs = jax.lax.scan(step, state, xs)           # hs (T,B,H,dh)
        hs = hs.transpose(1, 0, 2, 3).reshape(b, t, du).astype(x.dtype)

    new_cache = {"c": state[0], "n": state[1], "m": state[2]}
    out = rmsnorm(p["out_norm"], hs) + u * p["skip_scale"]
    out = out * jax.nn.silu(z)
    return (out @ p["w_rec_out"]), new_cache


def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    h, dh = _heads(cfg)
    du = h * dh
    ks = jax.random.split(key, 4)
    return {
        "w_rec_in": dense_init(ks[0], d, du, dtype),
        "w_gates": dense_init(ks[1], du, 4 * du, dtype),   # z i f o
        "r_gates": dense_init(ks[2], du, 4 * du, dtype),   # recurrent weights
        "out_norm": rmsnorm_init(du, dtype),
        "w_rec_out": dense_init(ks[3], du, d, dtype),
    }


def _slstm_step(p, u_t, state):
    c, n, m, h_prev = state
    g = (u_t @ p["w_gates"] + h_prev @ p["r_gates"]).astype(jnp.float32)
    z, i, f, o = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = -jax.nn.softplus(-f)
    m_new = jnp.maximum(log_f + m, i)
    i_sc = jnp.exp(i - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    c = f_sc * c + i_sc * z
    n = f_sc * n + i_sc
    h_new = o * (c / jnp.maximum(n, 1e-6))
    return (c, n, m_new, h_new.astype(u_t.dtype))


def slstm_apply(p, cfg, x, *, mode: str = "train", cache=None):
    b, t, d = x.shape
    h, dh = _heads(cfg)
    du = h * dh
    u = x @ p["w_rec_in"]
    u = shard_act(u, ("dp", None, "tp"))
    if cache is None:
        state = (jnp.zeros((b, du), jnp.float32), jnp.zeros((b, du), jnp.float32),
                 jnp.full((b, du), -1e30, jnp.float32), jnp.zeros((b, du), x.dtype))
    else:
        state = (cache["c"], cache["n"], cache["m"], cache["h"])

    if mode == "decode":
        state = _slstm_step(p, u[:, 0], state)
        hs = state[3][:, None]
    else:
        def step(st, u_t):
            st = _slstm_step(p, u_t, st)
            return st, st[3]

        state, hs = jax.lax.scan(step, state, u.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)

    new_cache = {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}
    out = rmsnorm(p["out_norm"], hs)
    return out @ p["w_rec_out"], new_cache


def make_xlstm_cache(cfg, kind: str, batch: int, dtype):
    h, dh = _heads(cfg)
    du = h * dh
    if kind == "mlstm":
        return {"c": jnp.zeros((batch, h, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, h, dh), jnp.float32),
                "m": jnp.full((batch, h), -1e30, jnp.float32)}
    return {"c": jnp.zeros((batch, du), jnp.float32),
            "n": jnp.zeros((batch, du), jnp.float32),
            "m": jnp.full((batch, du), -1e30, jnp.float32),
            "h": jnp.zeros((batch, du), dtype)}
