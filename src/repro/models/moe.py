"""Mixture-of-Experts layer (Mixtral 8×top-2, Kimi-K2 384×top-8).

Two implementations, selectable via cfg.moe_impl (the §Perf MoE hillclimb
compares them):

  "dense"    — reference: every expert runs on every token, outputs combined
               by the (T,E) gate matrix. Correct, simple, FLOP cost inflated
               by E/top_k — the roofline baseline.
  "dispatch" — production: capacity-bucketed scatter → per-expert batched
               matmul → gather. Experts shard over the `model` axis (EP);
               the token→expert scatter is where GSPMD inserts the all-to-
               all. FLOP cost ∝ top_k (+ capacity slack).

Routing: softmax-after-topk gates (Mixtral convention) + load-balance
auxiliary loss (Switch-style) returned for the train loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.common import dense_init


def moe_init(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "w_router": dense_init(ks[0], d, e, jnp.float32),  # router in f32
        "w_experts_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                           * scale).astype(dtype),
        "w_experts_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                         * scale).astype(dtype),
        "w_experts_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                           * (1.0 / jnp.sqrt(f))).astype(dtype),
    }


def _route(p, cfg, xf):
    """xf (N,d) → gates (N,k), idx (N,k), aux load-balance loss."""
    logits = xf.astype(jnp.float32) @ p["w_router"]       # (N,E)
    gates_k, idx = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(gates_k, axis=-1)              # Mixtral: softmax over top-k
    # Switch aux loss: E · Σ_e f_e · P_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.zeros((cfg.n_experts,), jnp.float32)
    frac = frac.at[idx.reshape(-1)].add(1.0) / (idx.size)
    aux = cfg.n_experts * jnp.sum(frac * probs.mean(0))
    return gates, idx, aux


def moe_apply(p, cfg, x):
    """x (B,T,d) → (y (B,T,d), aux_loss)."""
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    gates, idx, aux = _route(p, cfg, xf)

    if cfg.moe_impl == "dense":
        y = _dense_moe(p, cfg, xf, gates, idx)
    else:
        y = _dispatch_moe(p, cfg, xf, gates, idx)
    return y.reshape(b, t, d).astype(x.dtype), aux


def _ep_spec(e: int):
    """Expert-parallel activation spec: experts over the model axis when
    divisible (kimi: 384/16); otherwise shard the capacity dim over model
    (mixtral: 8 experts < 16-wide axis — replicating experts and gathering
    the (E,cap,f) hidden costs ~600 GB/chip, EXPERIMENTS.md §Perf iter 3)."""
    from repro.distributed.sharding import axis_size

    tp = axis_size("model")
    if tp <= 1 or e % tp == 0:
        return ("tp", None, None)
    return (None, "sq", None)


def _expert_mlp(p, h):
    """h (E,C,d) → (E,C,d): per-expert SwiGLU, batched over experts.

    The hidden keeps f sharded over the fsdp axis (matching the expert
    weights) — pinning f replicated forced a 300 GB/chip gather
    (EXPERIMENTS.md §Perf iter 3b)."""
    e_spec = _ep_spec(h.shape[0])
    hidden_spec = (e_spec[0], e_spec[1], "fsdp")
    up = jnp.einsum("ecd,edf->ecf", h, p["w_experts_up"])
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["w_experts_gate"]))
    hidden = shard_act(up * gate, hidden_spec)
    return jnp.einsum("ecf,efd->ecd", hidden, p["w_experts_down"])


def _dense_moe(p, cfg, xf, gates, idx):
    n, d = xf.shape
    e = cfg.n_experts
    # combine (N,E): gate where selected, 0 elsewhere
    comb = jnp.zeros((n, e), gates.dtype).at[
        jnp.arange(n)[:, None], idx].set(gates)
    # every expert on every token
    h = jnp.broadcast_to(xf[None], (e, n, d))
    out = _expert_mlp(p, h.astype(xf.dtype))               # (E,N,d)
    return jnp.einsum("ne,end->nd", comb, out.astype(jnp.float32))


def _bucket_positions(flat_e, e: int):
    """Rank of each (token, choice) within its expert bucket.

    Sort-based (argsort + searchsorted): O(N log N) and ~275× fewer
    HLO-counted flops than the one-hot + cumsum formulation, which also
    materializes an (N·k, E) int32 tensor (EXPERIMENTS.md §Perf iter 2)."""
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_sorted = jnp.arange(nk) - first[sorted_e]
    return jnp.zeros(nk, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def _dispatch_moe(p, cfg, xf, gates, idx):
    n, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(n * k / e * cfg.capacity_factor), 4)

    flat_e = idx.reshape(-1)                                # (N·k,)
    pos_in_e = _bucket_positions(flat_e, e)
    keep = pos_in_e < cap
    flat_gate = gates.reshape(-1) * keep                    # dropped → 0 gate

    buf = jnp.zeros((e, cap, d), xf.dtype)
    tok = jnp.repeat(jnp.arange(n), k)
    buf = buf.at[flat_e, jnp.where(keep, pos_in_e, cap - 1)].add(
        xf[tok] * keep[:, None].astype(xf.dtype))
    buf = shard_act(buf, _ep_spec(e))       # EP (or bucket-slot) sharding

    out_buf = _expert_mlp(p, buf)                           # (E,cap,d)

    y = out_buf[flat_e, jnp.where(keep, pos_in_e, cap - 1)]  # (N·k, d)
    y = y.astype(jnp.float32) * flat_gate[:, None]
    return jax.ops.segment_sum(y, tok, num_segments=n)
