"""Uniform model API consumed by the launcher, dry-run, trainer and server:

    init_params(cfg, key)                  → params
    loss_fn(params, cfg, batch)            → scalar loss
    prefill_fn(params, cfg, batch)         → (logits, cache, [enc_out])
    decode_fn(params, cfg, batch, cache)   → (logits, cache)
    input_specs(cfg, shape, mesh=None)     → ShapeDtypeStruct pytrees
                                             (weak-type-correct, shardable,
                                             NO device allocation)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer


def init_params(cfg: ModelConfig, key):
    if cfg.is_encdec:
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def loss_fn(params, cfg: ModelConfig, batch):
    if cfg.is_encdec:
        return encdec.loss_fn(params, cfg, batch)
    return transformer.loss_fn(params, cfg, batch)


def abstract_params(cfg: ModelConfig, key=None):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda kk: init_params(cfg, kk), k)


# ----------------------------------------------------------- input specs

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": _sds((b, t), jnp.int32),
            "labels": _sds((b, t), jnp.int32),
        }
        if cfg.mrope_sections is not None:
            batch["positions"] = _sds((3, b, t), jnp.int32)
        if cfg.is_encdec:
            batch["enc_embeds"] = _sds((b, cfg.enc_positions, cfg.d_model),
                                       jnp.dtype(cfg.dtype))
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, t), jnp.int32)}
        if cfg.mrope_sections is not None:
            batch["positions"] = _sds((3, b, t), jnp.int32)
        if cfg.is_encdec:
            batch["enc_embeds"] = _sds((b, cfg.enc_positions, cfg.d_model),
                                       jnp.dtype(cfg.dtype))
        return batch
    # decode: one new token against a seq_len cache
    batch = {
        "token": _sds((b, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }
    if cfg.mrope_sections is not None:
        batch["positions"] = _sds((3, b, 1), jnp.int32)
    if cfg.is_encdec:
        batch["enc_out"] = _sds((b, cfg.enc_positions, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return batch


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int):
    if cfg.is_encdec:
        return jax.eval_shape(
            lambda: jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *encdec.init_dec_cache(cfg, batch, seq_len)))
    return jax.eval_shape(lambda: transformer.init_cache(cfg, batch, seq_len))


# ------------------------------------------------------- step functions

def make_train_step(cfg: ModelConfig, optimizer=None):
    """Returns train_step(state, batch) → (state, metrics). With no
    optimizer, a plain SGD update keeps the dry-run graph faithful."""
    from repro.train.optim import sgd_fallback

    opt = optimizer or sgd_fallback(1e-3)

    def train_step(state, batch):
        params, opt_state, step = state
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch))(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
        return (params, opt_state, step + 1), {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: Optional[int] = None):
    """cache_len > prompt length leaves decode head-room (serving); the
    default sizes the cache to the prompt (the dry-run prefill cells)."""
    def prefill_step(params, batch):
        if cfg.is_encdec:
            logits, cache, enc_out = encdec.prefill(
                params, cfg, batch["tokens"], batch["enc_embeds"],
                cache_len=cache_len)
            return logits, cache, enc_out
        return transformer.prefill(params, cfg, batch["tokens"],
                                   batch.get("positions"),
                                   cache_len=cache_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch, cache):
        if cfg.is_encdec:
            return encdec.decode_step(params, cfg, batch["token"],
                                      batch["pos"], cache, batch["enc_out"])
        return transformer.decode_step(params, cfg, batch["token"],
                                       batch["pos"], cache,
                                       batch.get("positions"))

    return decode_step
