"""Encoder-decoder backbone (whisper-base). The audio conv frontend is a
STUB per the assignment: input_specs() provides precomputed frame embeddings
(B, enc_positions, d_model); this module implements the transformer backbone
(bidirectional encoder, causal decoder with cross-attention).

Whisper uses learned absolute positions; the decoder position table is sized
to the requested seq_len (32k decode shapes exceed Whisper's trained 448 —
lowered structurally as the assignment specifies, DESIGN §3)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from repro.models import attention as attn
from repro.models.common import (cross_entropy, dense_init, embed_apply,
                                 embed_init, layernorm, layernorm_init,
                                 mlp_apply, mlp_init)


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": layernorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(ks[0], cfg, dtype),
        "ln2": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": layernorm_init(cfg.d_model, dtype),
        "self_attn": attn.attn_init(ks[0], cfg, dtype),
        "ln_x": layernorm_init(cfg.d_model, dtype),
        "cross_attn": attn.attn_init(ks[1], cfg, dtype, cross=True),
        "ln2": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    keys = jax.random.split(key, n_enc + cfg.n_layers + 4)
    enc = [_enc_layer_init(keys[i], cfg, dtype) for i in range(n_enc)]
    dec = [_dec_layer_init(keys[n_enc + i], cfg, dtype)
           for i in range(cfg.n_layers)]

    def stack(layers):
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)

    return {
        "enc_blocks": stack(enc),
        "dec_blocks": stack(dec),
        "embed": embed_init(keys[-1], cfg.vocab, cfg.d_model, dtype),
        "enc_ln": layernorm_init(cfg.d_model, dtype),
        "dec_ln": layernorm_init(cfg.d_model, dtype),
    }


def _sinusoid(t: int, d: int, dtype):
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def encode(params, cfg: ModelConfig, enc_embeds):
    """enc_embeds (B, S, d): the stub frontend output."""
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
    x = shard_act(x, ("dp", None, None))

    def body(x, lp):
        h = layernorm(lp["ln1"], x)
        y, _ = attn.attn_apply(lp["attn"], cfg, h, positions=None,
                               mode="train", causal=False, use_rope=False)
        x = x + y
        x = x + mlp_apply(lp["mlp"], layernorm(lp["ln2"], x), "gelu")
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:  # unrolled (roofline FD calibration path, launch/dryrun.py)
        n_enc = cfg.n_enc_layers or cfg.n_layers
        for i in range(n_enc):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["enc_blocks"])
            x, _ = body(x, lp)
    return layernorm(params["enc_ln"], x)


def _dec_block(lp, cfg, x, enc_out, *, mode, cache):
    h = layernorm(lp["ln1"], x)
    pos = cache["pos"] if (cache is not None and "pos" in cache) else None
    y, self_cache = attn.attn_apply(
        lp["self_attn"], cfg, h,
        positions=pos if pos is not None else jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], x.shape[:2]),
        mode=mode, cache=None if cache is None else cache["self"],
        use_rope=False)
    x = x + y
    h = layernorm(lp["ln_x"], x)
    y, _ = attn.attn_apply(lp["cross_attn"], cfg, h, positions=None,
                           mode="train", kv_source=enc_out, causal=False,
                           use_rope=False)
    x = x + y
    x = x + mlp_apply(lp["mlp"], layernorm(lp["ln2"], x), "gelu")
    new_cache = None if cache is None else {"self": self_cache, "pos": pos}
    return x, new_cache


def decode_forward(params, cfg: ModelConfig, tokens, enc_out, *, mode="train",
                   caches=None, pos=None):
    x = embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    t = tokens.shape[1]
    if mode == "decode":
        x = x + jax.lax.dynamic_slice_in_dim(
            _sinusoid(caches[0]["self"]["k"].shape[2] + 1, cfg.d_model,
                      x.dtype), pos, 1, 0)[None]
    else:
        x = x + _sinusoid(t, cfg.d_model, x.dtype)[None]
    x = shard_act(x, ("dp", None, None))

    if mode == "decode":
        new_caches = []
        n_layers = cfg.n_layers
        for i in range(n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["dec_blocks"])
            cache_i = dict(caches[i])
            cache_i["pos"] = jnp.broadcast_to(pos[None, None], tokens.shape)
            x, nc = _dec_block(lp, cfg, x, enc_out, mode="decode",
                               cache=cache_i)
            new_caches.append(nc)
    else:
        def body(x, xs):
            lp, cache_i = xs
            x, nc = _dec_block(lp, cfg, x, enc_out, mode=mode, cache=cache_i)
            return x, nc

        if not cfg.scan_layers:  # unrolled (roofline FD calibration path)
            new_caches = [] if caches is not None else None
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i],
                                            params["dec_blocks"])
                cache_i = (jax.tree_util.tree_map(lambda a: a[i], caches)
                           if caches is not None else None)
                x, nc = body(x, (lp, cache_i))
                if new_caches is not None:
                    new_caches.append(nc)
        elif caches is None:
            x, _ = jax.lax.scan(lambda c, lp: body(c, (lp, None)), x,
                                params["dec_blocks"])
            new_caches = None
        else:
            x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"],
                                                   tuple_to_stacked(caches)))
    x = layernorm(params["dec_ln"], x)
    logits = x @ params["embed"].T  # whisper ties the decoder head
    return shard_act(logits, ("dp", None, "tp")), new_caches


def tuple_to_stacked(caches):
    return caches  # prefill path builds stacked caches directly


def loss_fn(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["enc_embeds"])
    logits, _ = decode_forward(params, cfg, batch["tokens"], enc_out,
                               mode="train")
    return cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                         batch.get("mask"))


def init_dec_cache(cfg: ModelConfig, batch: int, seq_len: int):
    dtype = jnp.dtype(cfg.dtype)
    return [{"self": attn.make_empty_cache(cfg, batch, seq_len, dtype)}
            for _ in range(cfg.n_layers)]


def prefill(params, cfg: ModelConfig, tokens, enc_embeds, cache_len=None):
    enc_out = encode(params, cfg, enc_embeds)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *init_dec_cache(cfg, tokens.shape[0],
                        cache_len or tokens.shape[1]))
    logits, caches = decode_forward(params, cfg, tokens, enc_out,
                                    mode="prefill", caches=stacked)
    return logits[:, -1:], caches, enc_out


def decode_step(params, cfg: ModelConfig, token, pos, caches, enc_out):
    # caches: list (loop mode) of {"self": {...}} — unstack if stacked
    if not isinstance(caches, list):
        caches = [jax.tree_util.tree_map(lambda a: a[i], caches)
                  for i in range(cfg.n_layers)]
    logits, new_caches = decode_forward(params, cfg, token, enc_out,
                                        mode="decode", caches=caches, pos=pos)
    return logits, new_caches
