"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries go through a low-rank bottleneck (q_lora_rank); keys/values are
compressed into a small latent c_kv (kv_lora_rank) plus a shared rotary key
k_rope. The decode cache stores ONLY (c_kv, k_rope) — (r + dr) floats/token
instead of 2·H·D — MLA's serving superpower, preserved here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.common import dense_init, rmsnorm, rmsnorm_init
from repro.models.rope import apply_rope

_NEG = -1e30


def mla_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, r_q, dtype),
        "q_a_norm": rmsnorm_init(r_q, dtype),
        "wq_b": dense_init(ks[1], r_q, h * (dn + dr), dtype),
        "wkv_a": dense_init(ks[2], d, r_kv + dr, dtype),
        "kv_a_norm": rmsnorm_init(r_kv, dtype),
        "wkv_b": dense_init(ks[3], r_kv, h * (dn + dv), dtype),
        "wo": dense_init(ks[4], h * dv, d, dtype),
    }


def _project_kv(p, cfg, c_kv):
    """latent (B,S,r) → k_nope (B,H,S,dn), v (B,H,S,dv)."""
    h, dn, dv = cfg.n_heads, cfg.qk_nope_head_dim, cfg.v_head_dim
    kv = c_kv @ p["wkv_b"]
    b, s, _ = kv.shape
    kv = kv.reshape(b, s, h, dn + dv).transpose(0, 2, 1, 3)
    return kv[..., :dn], kv[..., dn:]


def mla_apply(p, cfg, x, *, positions, mode: str = "train", cache=None):
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b, t, _ = x.shape

    q = rmsnorm(p["q_a_norm"], x @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(b, t, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)
    q_nope = shard_act(q_nope, ("dp", "tp", None, None))

    kv_a = x @ p["wkv_a"]                       # (B,T,r+dr)
    c_kv = rmsnorm(p["kv_a_norm"], kv_a[..., : cfg.kv_lora_rank])
    k_rope = kv_a[..., cfg.kv_lora_rank:]       # (B,T,dr) shared across heads
    k_rope = apply_rope(k_rope[:, None], positions[:, None, :],
                        cfg.rope_theta)         # (B,1,T,dr)

    new_cache = cache
    if mode == "decode":
        pos = positions.reshape(-1)[0]
        z = jnp.zeros((), pos.dtype)
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (z, pos, z))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, 0].astype(cache["k_rope"].dtype),
            (z, pos, z))
        new_cache = {"c_kv": cc, "k_rope": cr}
        k_nope, v = _project_kv(p, cfg, cc)     # (B,H,S,·) from latent
        kr = cr[:, None]                        # (B,1,S,dr)
        s_len = cc.shape[1]
        mask = (jnp.arange(s_len) <= pos)[None, None, None, :]
        scale_fix = jnp.sqrt(jnp.float32(dn + dr))
        s = (jnp.einsum("bhqd,bhkd->bhqk", q_nope.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
             + jnp.einsum("bhqd,bukd->bhqk", q_rope.astype(jnp.float32),
                          kr.astype(jnp.float32))) / scale_fix
        s = jnp.where(mask, s, _NEG)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", w,
                         v.astype(jnp.float32)).astype(x.dtype)
    else:
        k_nope, v = _project_kv(p, cfg, c_kv)
        if mode == "prefill":
            if cache is not None:
                from repro.models.attention import store_prefill

                new_cache = {
                    "c_kv": store_prefill(cache["c_kv"], c_kv, 1),
                    "k_rope": store_prefill(cache["k_rope"], k_rope[:, 0], 1),
                }
            else:
                new_cache = {"c_kv": c_kv, "k_rope": k_rope[:, 0]}
        # Fold the shared rotary key into per-head keys and route through the
        # memory-O(T·chunk) flash path (dk = dn+dr, dv independent).
        from repro.models.attention import flash_jnp

        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)        # (B,H,T,dn+dr)
        kr_b = jnp.broadcast_to(k_rope, (b, h, t, dr))
        k_full = jnp.concatenate([k_nope, kr_b], axis=-1)
        out = flash_jnp(q_full, k_full, v, causal=True, window=None,
                        chunk=cfg.attn_chunk)

    out = out.transpose(0, 2, 1, 3).reshape(b, t, h * dv)
    return shard_act(out @ p["wo"], ("dp", None, None)), new_cache


def make_mla_cache(cfg, batch: int, seq_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim), dtype),
    }
