"""Decoder-only LM assembly: config-driven block stacking (attention / MoE /
mLSTM / sLSTM / RG-LRU patterns), scan-over-layers lowering, KV-cache
serving paths.

Layer iteration strategy (DESIGN §6): when n_layers divides by the block
pattern, per-pattern-position params are STACKED and the stack is lax.scan'd
(small HLO, fast compile — what the dry-run lowers). Otherwise a python loop
unrolls (hybrid archs with ragged patterns).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from repro.models import attention as attn
from repro.models import mla, moe, rglru, xlstm
from repro.models.common import (cross_entropy, dense_init, embed_apply,
                                 embed_init, mlp_apply, mlp_init, rmsnorm,
                                 rmsnorm_init)

AUX_COEF = 0.01


# ------------------------------------------------------------------ blocks

def block_init(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.use_mla:
            p["attn"] = mla.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attn.attn_init(ks[0], cfg, dtype)
        if cfg.is_moe:
            p["moe"] = moe.moe_init(ks[1], cfg, dtype)
            p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        elif cfg.d_ff > 0:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
            p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
    elif kind == "mlstm":
        p["core"] = xlstm.mlstm_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["core"] = xlstm.slstm_init(ks[0], cfg, dtype)
    elif kind == "rec":
        p["core"] = rglru.rglru_init(ks[0], cfg, dtype)
        if cfg.d_ff > 0:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
            p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def block_apply(p, cfg: ModelConfig, kind: str, x, *, positions, mode,
                cache):
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x)
    if kind == "attn":
        if cfg.use_mla:
            y, new_cache = mla.mla_apply(p["attn"], cfg, h, positions=positions,
                                         mode=mode, cache=cache)
        else:
            y, new_cache = attn.attn_apply(p["attn"], cfg, h, positions=positions,
                                           mode=mode, cache=cache)
        x = x + y
        if cfg.is_moe:
            m, aux = moe.moe_apply(p["moe"], cfg, rmsnorm(p["ln2"], x))
            x = x + m
        elif cfg.d_ff > 0:
            x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg.act)
    elif kind in ("mlstm", "slstm"):
        fn = xlstm.mlstm_apply if kind == "mlstm" else xlstm.slstm_apply
        y, new_cache = fn(p["core"], cfg, h, mode=mode, cache=cache)
        x = x + y
    elif kind == "rec":
        y, new_cache = rglru.rglru_apply(p["core"], cfg, h, mode=mode, cache=cache)
        x = x + y
        if cfg.d_ff > 0:
            x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg.act)
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def make_block_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                     dtype):
    if kind == "attn":
        if cfg.use_mla:
            return mla.make_mla_cache(cfg, batch, seq_len, dtype)
        return attn.make_empty_cache(cfg, batch, seq_len, dtype)
    if kind in ("mlstm", "slstm"):
        return xlstm.make_xlstm_cache(cfg, kind, batch, dtype)
    if kind == "rec":
        return rglru.make_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------- assembly

def _layer_kinds(cfg: ModelConfig):
    pat = cfg.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def _use_scan(cfg: ModelConfig) -> bool:
    return cfg.scan_layers and cfg.n_layers % len(cfg.block_pattern) == 0


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)

    if _use_scan(cfg):
        pat = cfg.block_pattern
        n_rep = cfg.n_layers // len(pat)
        blocks = []
        for pos, kind in enumerate(pat):
            per_rep = [block_init(keys[2 + r * len(pat) + pos], cfg, kind, dtype)
                       for r in range(n_rep)]
            blocks.append(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per_rep))
        params["blocks"] = blocks
    else:
        kinds = _layer_kinds(cfg)
        params["layers"] = [block_init(keys[2 + i], cfg, kinds[i], dtype)
                            for i in range(cfg.n_layers)]
    return params


def _forward(params, cfg: ModelConfig, tokens, *, positions, mode,
             caches=None):
    """Shared forward: returns (hidden (B,T,d), new_caches, aux)."""
    x = embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = shard_act(x, ("dp", None, None))
    aux_total = jnp.zeros((), jnp.float32)

    if _use_scan(cfg):
        pat = cfg.block_pattern
        n_rep = cfg.n_layers // len(pat)

        def rep_body(carry, xs):
            x, aux = carry
            layer_params, layer_caches = xs
            new_caches = []
            for pos, kind in enumerate(pat):
                cache_p = layer_caches[pos] if layer_caches is not None else None
                fn = partial(block_apply, cfg=cfg, kind=kind,
                             positions=positions, mode=mode)
                if cfg.remat and mode == "train":
                    fn = jax.checkpoint(
                        lambda p_, x_, c_, _f=fn: _f(p_, x=x_, cache=c_))
                    x, nc, a = fn(layer_params[pos], x, cache_p)
                else:
                    x, nc, a = fn(layer_params[pos], x=x, cache=cache_p)
                aux = aux + a
                new_caches.append(nc)
            return (x, aux), tuple(new_caches)

        xs = (tuple(params["blocks"]),
              tuple(caches) if caches is not None else None)
        if caches is None:
            # scan needs a concrete xs pytree: params only
            (x, aux_total), _ = jax.lax.scan(
                lambda c, lp: rep_body(c, (lp, None)), (x, aux_total),
                tuple(params["blocks"]))
            new_caches = None
        else:
            (x, aux_total), new_caches = jax.lax.scan(
                rep_body, (x, aux_total), xs)
    else:
        kinds = _layer_kinds(cfg)
        new_caches = []
        for i, kind in enumerate(kinds):
            cache_i = caches[i] if caches is not None else None
            fn = partial(block_apply, cfg=cfg, kind=kind,
                         positions=positions, mode=mode)
            if cfg.remat and mode == "train":
                # mirror the scanned path so unrolled calibration lowers the
                # same per-layer graph (roofline FD, launch/dryrun.py)
                fn = jax.checkpoint(
                    lambda p_, x_, c_, _f=fn: _f(p_, x=x_, cache=c_))
                x, nc, a = fn(params["layers"][i], x, cache_i)
            else:
                x, nc, a = fn(params["layers"][i], x=x, cache=cache_i)
            aux_total = aux_total + a
            new_caches.append(nc)
        if caches is None:
            new_caches = None

    x = rmsnorm(params["final_norm"], x)
    return x, new_caches, aux_total


def _logits(params, cfg: ModelConfig, hidden):
    if cfg.tie_embeddings:
        logits = hidden @ params["embed"].T
    else:
        logits = hidden @ params["lm_head"]
    return shard_act(logits, ("dp", None, "tp"))


# ------------------------------------------------------------- public API

def loss_fn(params, cfg: ModelConfig, batch) -> jax.Array:
    tokens = batch["tokens"]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                                     tokens.shape)
    hidden, _, aux = _forward(params, cfg, tokens, positions=positions,
                              mode="train")
    # chunked CE: never materializes the (B,T,V) f32 logits (§Perf iter 5)
    from repro.models.common import chunked_cross_entropy

    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ce = chunked_cross_entropy(hidden[:, :-1], head,
                               batch["labels"][:, 1:], batch.get("mask"))
    return ce + AUX_COEF * aux


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    dtype = jnp.dtype(cfg.dtype)
    kinds = _layer_kinds(cfg)
    if _use_scan(cfg):
        pat = cfg.block_pattern
        n_rep = cfg.n_layers // len(pat)
        caches = []
        for kind in pat:
            one = make_block_cache(cfg, kind, batch, seq_len, dtype)
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n_rep,) + a.shape).copy(), one))
        return tuple(caches)
    return [make_block_cache(cfg, k, batch, seq_len, dtype) for k in kinds]


def prefill(params, cfg: ModelConfig, tokens, positions=None,
            cache_len=None):
    """Prefill: forward over the prompt, returning (last-token logits, cache).
    cache_len > prompt length leaves head-room for subsequent decode."""
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                                     tokens.shape)
    caches = init_cache(cfg, tokens.shape[0], cache_len or tokens.shape[1])
    hidden, new_caches, _ = _forward(params, cfg, tokens, positions=positions,
                                     mode="prefill", caches=caches)
    return _logits(params, cfg, hidden[:, -1:]), new_caches


def decode_step(params, cfg: ModelConfig, token, pos, caches, positions=None):
    """One decode step. token (B,1); pos scalar int32; caches from
    init_cache/prefill. Returns (logits (B,1,V), new caches)."""
    if positions is None:
        positions = jnp.broadcast_to(pos[None, None], token.shape)
    hidden, new_caches, _ = _forward(params, cfg, token, positions=positions,
                                     mode="decode", caches=caches)
    return _logits(params, cfg, hidden), new_caches
