"""RecurrentGemma / Griffin recurrent block (arXiv:2402.19427): temporal
conv1d + RG-LRU (Real-Gated Linear Recurrent Unit).

The RG-LRU recurrence h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t) is a
DIAGONAL linear recurrence → training/prefill uses jax.lax.associative_scan
(log-depth, TPU-friendly); decode is one elementwise update on a (B, d_rec)
state + a (B, conv_width, d) conv ring — why recurrentgemma-2b runs the
long_500k cell with O(1) memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.common import dense_init

_C = 8.0  # Griffin's fixed recurrence sharpness


def rglru_init(key, cfg, dtype):
    d = cfg.d_model
    dr = cfg.d_rec or d
    w = cfg.conv_width
    ks = jax.random.split(key, 6)
    return {
        "w_rec_in": dense_init(ks[0], d, 2 * dr, dtype),   # (x branch, gate branch)
        "conv_w": (jax.random.normal(ks[1], (w, dr), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_input_gate": dense_init(ks[2], dr, dr, dtype),
        "w_a_gate": dense_init(ks[3], dr, dr, dtype),
        "a_param": jnp.log(jnp.expm1(  # softplus⁻¹ so σ-param init ≈ 0.95^c
            jnp.full((dr,), 0.65, jnp.float32))),
        "w_rec_out": dense_init(ks[4], dr, d, dtype),
    }


def _conv1d(p, x, state=None):
    """Causal depthwise conv, width w. x (B,T,dr). state (B,w-1,dr) carries
    the last w-1 inputs for decode."""
    w = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * p["conv_w"][i] for i in range(w))
    new_state = xp[:, -(w - 1):]
    return out + p["conv_b"], new_state


def _gates(p, xc):
    i_t = jax.nn.sigmoid(xc @ p["w_input_gate"])
    r_t = jax.nn.sigmoid(xc @ p["w_a_gate"]).astype(jnp.float32)
    log_a = -_C * r_t * jax.nn.softplus(p["a_param"])       # log a_t ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return i_t, a, beta


def rglru_apply(p, cfg, x, *, mode: str = "train", cache=None):
    b, t, d = x.shape
    dr = cfg.d_rec or d
    up = x @ p["w_rec_in"]
    xb, gb = up[..., :dr], up[..., dr:]
    xb = shard_act(xb, ("dp", None, "tp"))

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _conv1d(p, xb, conv_state)
    i_t, a, beta = _gates(p, xc)
    gated = (i_t * xc).astype(jnp.float32) * beta

    h0 = cache["h"] if cache is not None else jnp.zeros((b, dr), jnp.float32)
    if mode == "decode":
        h = a[:, 0] * h0 + gated[:, 0]
        hs = h[:, None]
        new_h = h
    else:
        # associative scan over the diagonal recurrence (log-depth)
        def combine(c1, c2):
            a1, y1 = c1
            a2, y2 = c2
            return a1 * a2, a2 * y1 + y2

        _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
        # fold the carried state h0 into every step: h_t += (∏_{s≤t} a_s)·h0
        a_cum = jnp.cumprod(a, axis=1)
        hs = hs + a_cum * h0[:, None]
        new_h = hs[:, -1]

    out = hs.astype(x.dtype) * jax.nn.gelu(gb)
    y = out @ p["w_rec_out"]
    new_cache = {"h": new_h, "conv": new_conv.astype(jnp.float32)}
    return shard_act(y, ("dp", None, None)), new_cache


def make_rglru_cache(cfg, batch: int, dtype):
    dr = cfg.d_rec or cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), jnp.float32)}
