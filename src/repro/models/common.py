"""Shared model primitives (functional, framework-free: params are nested
dicts of arrays; every module is init(key,…) → params + apply(params,…))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype):
    return jnp.ones((d,), dtype)


def rmsnorm(w, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"] + p["bias"]


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if act == "silu":  # SwiGLU
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(p, x, act: str):
    h = x @ p["w_up"]
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = shard_act(h, ("dp", None, "tp"))
    return h @ p["w_down"]


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed_apply(table, tokens):
    return jnp.take(table, tokens, axis=0)


def cross_entropy(logits, labels, mask=None):
    """Token-level CE; logits (B,T,V) possibly vocab-sharded (reduction over
    V is a local op + the usual psum GSPMD inserts)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_cross_entropy(hidden, head_w, labels, mask=None,
                          chunk: int = 256):
    """CE WITHOUT materializing the (B,T,V) f32 logits: lax.scan over
    sequence chunks computes (B,chunk,V) logits, reduces to per-token NLL,
    and rematerializes the chunk in the backward pass (jax.checkpoint).

    Cuts the train-cell memory term — the f32 logit tensor is the largest
    live buffer for big-vocab archs (EXPERIMENTS.md §Perf iter 5). hidden:
    (B,T,d); head_w: (d,V); labels: (B,T)."""
    b, t, d = hidden.shape
    nc = t // chunk
    rem = t - nc * chunk

    @jax.checkpoint
    def chunk_nll(h_c, y_c):
        logits = (h_c @ head_w).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return lse - gold

    parts = []
    if nc > 0:
        h_main = hidden[:, : nc * chunk].reshape(b, nc, chunk, d)
        y_main = labels[:, : nc * chunk].reshape(b, nc, chunk)

        def body(_, xs):
            h_c, y_c = xs
            return None, chunk_nll(h_c, y_c)

        _, nll_main = jax.lax.scan(
            body, None, (h_main.transpose(1, 0, 2, 3),
                         y_main.transpose(1, 0, 2)))
        parts.append(nll_main.transpose(1, 0, 2).reshape(b, nc * chunk))
    if rem:
        parts.append(chunk_nll(hidden[:, nc * chunk:],
                               labels[:, nc * chunk:]))
    nll = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
