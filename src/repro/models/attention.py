"""GQA attention (optional QKV bias, sliding window, M-RoPE, cross-attn)
with train / prefill / decode paths and a memory-O(T·chunk) jnp flash path
(the XLA lowering twin of kernels/flash_attention.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.kernels import ops as kops
from repro.models.common import dense_init
from repro.models.rope import apply_mrope, apply_rope

_NEG = -1e30


def attn_init(key, cfg, dtype, cross: bool = False):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _split_heads(x, n_heads, hd):
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)  # (B,H,T,D)


def _merge_heads(x):
    b, h, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * d)


def flash_jnp(q, k, v, *, causal: bool, window, chunk: int,
              head_spec=("dp", "tp", None, None)):
    """Blockwise online-softmax attention in pure jnp (lax.scan over KV
    chunks): O(Tq·chunk) live memory — the 32k-prefill lowering path.

    q/k/v arrive with EQUAL head counts (GQA k/v pre-expanded by the caller:
    the grouped (hkv, group) einsum form shards catastrophically when the
    factored dims don't divide the TP axis — EXPERIMENTS.md §Perf iter 1).
    """
    b, hq, tq, d = q.shape
    tk, dk, dv = k.shape[2], k.shape[3], v.shape[3]
    if tk <= chunk:
        return kops.flash_attention(q, k, v, causal=causal, window=window)

    tk_pad = -(-tk // chunk) * chunk
    if tk_pad != tk:  # ragged tail (e.g. Whisper's 1500 encoder positions):
        pad = [(0, 0), (0, 0), (0, tk_pad - tk), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nc = tk_pad // chunk
    kc = k.reshape(b, hq, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hq, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qpos = (jnp.arange(tq) + (tk - tq))[:, None]
    qf = q.astype(jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        i, kb, vb = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                       kb.astype(jnp.float32)) * scale
        s = shard_act(s, head_spec)
        kpos = i * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.broadcast_to(kpos < tk, (tq, chunk))  # mask ragged pad
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, _NEG)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p,
                                       vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, tq, 1), _NEG, jnp.float32)
    l0 = jnp.zeros_like(m0)
    a0 = jnp.zeros((b, hq, tq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nc), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)


def store_prefill(dst, src, axis: int):
    """Write prefill-computed k/v (length t along `axis`) into the allocated
    cache `dst` (length s). s > t leaves head-room for decode; s < t is the
    ring/window case (keep the last s positions at slot = pos % s)."""
    t, s = src.shape[axis], dst.shape[axis]
    if s == t:
        return src.astype(dst.dtype)
    if s < t:
        sl = [slice(None)] * src.ndim
        sl[axis] = slice(t - s, None)
        last = src[tuple(sl)].astype(dst.dtype)
        return jnp.roll(last, t % s, axis=axis)
    idx = (jnp.zeros((), jnp.int32),) * src.ndim
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)


def attn_apply(p, cfg, x, *, positions=None, mode: str = "train",
               cache=None, kv_source=None, causal: bool = True,
               use_rope: bool = True):
    """x (B,T,d). kv_source: encoder states for cross-attention (no cache
    mutation, no rope). Returns (y, new_cache)."""
    hd = cfg.resolved_head_dim
    kv_in = kv_source if kv_source is not None else x

    q = x @ p["wq"]
    k = kv_in @ p["wk"]
    v = kv_in @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _split_heads(q, cfg.n_heads, hd)
    k = _split_heads(k, cfg.n_kv_heads, hd)
    v = _split_heads(v, cfg.n_kv_heads, hd)
    # Sharding strategy (EXPERIMENTS.md §Perf iter 1): head-TP when the
    # query-head count divides the model axis, else sequence-parallel
    # attention (q seq dim over "model", full k/v per shard).
    from repro.distributed.sharding import axis_size
    tp = axis_size("model")
    head_tp = tp <= 1 or cfg.n_heads % tp == 0
    qkv_spec = (("dp", "tp", None, None) if head_tp
                else ("dp", None, "sq", None))
    kv_full_spec = (("dp", "tp", None, None) if head_tp
                    else ("dp", None, None, None))
    q = shard_act(q, qkv_spec)

    if use_rope and kv_source is None:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions[:, :, None, :], cfg.rope_theta,
                            cfg.mrope_sections)
            k = apply_mrope(k, positions[:, :, None, :], cfg.rope_theta,
                            cfg.mrope_sections)
        else:
            q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
            k = apply_rope(k, positions[:, None, :], cfg.rope_theta)

    new_cache = cache
    if mode == "decode" and kv_source is None:
        # cache: {"k","v"} (B,Hkv,S,D). S == seq_len for full attention, or
        # the window size (RING buffer) for sliding-window archs.
        pos = positions.reshape(-1)[0]  # lockstep batch decode position
        s_len = cache["k"].shape[2]
        is_ring = cfg.window is not None and s_len <= cfg.window
        wp = jnp.where(is_ring, pos % s_len, pos)
        z = jnp.zeros((), wp.dtype)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (z, z, wp, z))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (z, z, wp, z))
        new_cache = {"k": ck, "v": cv}
        s = jnp.einsum("bhqd,bhkd->bhqk",
                       q.astype(jnp.float32),
                       _gqa_expand(ck, cfg).astype(jnp.float32))
        s = s / jnp.sqrt(jnp.float32(hd))
        kpos = jnp.arange(s_len)
        if is_ring:
            # ring slots hold exactly the last s_len positions; before the
            # ring fills, slots beyond wp are empty
            mask = (pos >= s_len) | (kpos <= wp)
        else:
            mask = kpos <= pos
            if cfg.window is not None:
                mask = mask & (kpos > pos - cfg.window)
        s = jnp.where(mask[None, None, None, :], s, _NEG)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", w,
                         _gqa_expand(cv, cfg).astype(jnp.float32)).astype(x.dtype)
    else:
        # expand GQA k/v to full query heads BEFORE the attention math: the
        # grouped (hkv, group) form cannot shard over a TP axis the factors
        # don't divide (§Perf iter 1); the cache still stores hkv heads.
        ke = shard_act(_gqa_expand(k, cfg), kv_full_spec)
        ve = shard_act(_gqa_expand(v, cfg), kv_full_spec)
        if cfg.use_flash_kernel:
            out = kops.flash_attention(q, ke, ve, causal=causal,
                                       window=cfg.window, use_kernel=True)
        else:
            out = flash_jnp(q, ke, ve, causal=causal,
                            window=cfg.window if kv_source is None else None,
                            chunk=cfg.attn_chunk,
                            head_spec=(("dp", "tp", None, None) if head_tp
                                       else ("dp", None, "sq", None)))
        if mode == "prefill" and kv_source is None:
            if cache is not None:
                new_cache = {"k": store_prefill(cache["k"], k, 2),
                             "v": store_prefill(cache["v"], v, 2)}
            else:
                new_cache = {"k": k, "v": v}

    y = _merge_heads(out) @ p["wo"]
    return shard_act(y, ("dp", None, None)), new_cache


def _gqa_expand(kv, cfg):
    group = cfg.n_heads // cfg.n_kv_heads
    if group == 1:
        return kv
    return jnp.repeat(kv, group, axis=1)


def make_empty_cache(cfg, batch: int, seq_len: int, dtype):
    hd = cfg.resolved_head_dim
    s = min(seq_len, cfg.window) if cfg.window else seq_len
    shape = (batch, cfg.n_kv_heads, s, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
