"""Rotary position embeddings: standard RoPE + Qwen2-VL M-RoPE (3-D
temporal/height/width sections, arXiv:2409.12191)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return inv.astype(dtype)  # (half,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., T, D) with positions (..., T) → rotated x. Pairing is
    (x[..., :D/2], x[..., D/2:]) halves (NeoX / llama convention)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., T, half)
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """M-RoPE: positions3 (3, ..., T) = (t, h, w) indices; the frequency
    bands split into `sections` (in half-dim units), each band using the
    position of its modality axis."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(d, theta)  # (half,)
    # pick which modality drives each frequency band
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)  # (half,)
    pos = jnp.take(positions3, sec_id, axis=0)  # (half, ..., T) gathered per band
    pos = jnp.moveaxis(pos, 0, -1)              # (..., T, half)
    ang = pos.astype(jnp.float32) * inv
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
