"""Model zoo: the 10 assigned LM architectures (decoder-only, MoE, MLA,
xLSTM, RG-LRU hybrid, enc-dec) + shared layers. Uniform API in api.py."""
