import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Tests may shrink the placeholder fleet:
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

# Multi-pod dry-run (deliverable e): .lower().compile() every
# (architecture × input-shape × mesh) cell on the production meshes and
# record memory_analysis / cost_analysis / collective schedule for §Roofline.
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
#   python -m repro.launch.dryrun --all --mesh both --out-dir results/dryrun

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro import compat
from repro.configs.base import SHAPES
from repro.configs.registry import get_config, list_archs
from repro.launch.mesh import (make_debug_mesh, make_production_mesh,
                               mesh_num_chips)
from repro.launch.roofline import (HBM_PER_CHIP, compute_roofline,
                                   extrapolate_linear, model_flops_for)
from repro.launch.steps import lower_cell


def _is_scanned(cfg) -> bool:
    from repro.models.transformer import _use_scan
    if cfg.is_encdec:
        return cfg.scan_layers
    return _use_scan(cfg)


def _reduced_cfg(cfg, r: int):
    """Unrolled r-repetition variant for roofline FD calibration."""
    pat = len(cfg.block_pattern)
    kw = dict(n_layers=pat * r, scan_layers=False)
    if cfg.is_encdec:
        assert cfg.n_enc_layers == cfg.n_layers, \
            "FD calibration assumes enc/dec layer counts match"
        kw["n_enc_layers"] = r
    return dataclasses.replace(cfg, **kw)


def _calibrated_costs(cfg, shape, mesh, optimizer):
    """(flops_per_chip, bytes_per_chip, meta): XLA counts while bodies once,
    so lower UNROLLED variants at n_rep∈{1,2} and extrapolate linearly."""
    n_rep_full = cfg.n_layers // len(cfg.block_pattern)
    pts = []
    for r in (1, 2):
        lo, _ = lower_cell(_reduced_cfg(cfg, r), shape, mesh,
                           optimizer=optimizer)
        cost = compat.cost_analysis(lo.compile())
        pts.append((r, float(cost.get("flops", 0.0) or 0.0),
                    float(cost.get("bytes accessed", 0.0) or 0.0)))
    (n1, f1, b1), (n2, f2, b2) = pts
    flops = extrapolate_linear(n1, f1, n2, f2, n_rep_full)
    byts = extrapolate_linear(n1, b1, n2, b2, n_rep_full)
    meta = {"method": "fd_unrolled", "points": pts, "n_rep_full": n_rep_full}
    return flops, byts, meta


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    shape = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        return False, "config skip_shapes (full attention at 500k / enc-dec)"
    return True, ""


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             optimizer: str = "adamw", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = mesh_num_chips(mesh)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": chips, "kind": shape.kind,
                 "optimizer": optimizer if shape.kind == "train" else None}
    ok, why = applicable(cfg, shape_name)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    t0 = time.perf_counter()
    lowered, plan = lower_cell(cfg, shape, mesh, optimizer=optimizer)
    rec["lower_s"] = round(time.perf_counter() - t0, 2)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t1, 2)

    mem = _memory_dict(compiled)
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    raw_flops = float(cost.get("flops", 0.0) or 0.0)
    raw_bytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    if _is_scanned(cfg):
        flops, byts, calib = _calibrated_costs(cfg, shape, mesh, optimizer)
        calib["raw_full_compile"] = {"flops": raw_flops, "bytes": raw_bytes}
    else:
        flops, byts = raw_flops, raw_bytes
        calib = {"method": "direct_unrolled"}
    mf = model_flops_for(cfg, shape)
    roof = compute_roofline(flops, byts, hlo, chips, model_flops=mf,
                            calibration=calib)

    state_per_chip = plan.state_bytes / chips
    arg_per_chip = mem.get("argument_size_in_bytes", 0)
    temp_per_chip = mem.get("temp_size_in_bytes", 0)
    rec.update({
        "status": "ok",
        "memory_analysis": mem,
        "state_bytes_total": plan.state_bytes,
        "state_bytes_per_chip_fully_sharded": state_per_chip,
        "bytes_per_chip": arg_per_chip + temp_per_chip,
        "fits_v5e_hbm": bool((arg_per_chip + temp_per_chip) <= HBM_PER_CHIP)
        if mem else None,
        "roofline": roof.as_dict(),
    })
    if verbose:
        print(f"--- {arch} × {shape_name} × {mesh_name} "
              f"({chips} chips, {shape.kind}) ---")
        print("memory_analysis:", json.dumps(mem))
        print("cost_analysis(per-chip, calibrated): flops=%.3e bytes=%.3e" %
              (roof.flops_per_chip, roof.bytes_per_chip))
        print("roofline: compute=%.3es memory=%.3es collective=%.3es "
              "dominant=%s useful_flops=%.2f" %
              (roof.compute_s, roof.memory_s, roof.collective_s,
               roof.dominant, roof.useful_flops_ratio or float("nan")))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both", "debug",
                             "debug-multi"])
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd"])
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args(argv)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))
    if args.mesh == "debug":
        meshes.append(("debug2x4", make_debug_mesh(multi_pod=False)))
    if args.mesh == "debug-multi":
        meshes.append(("debug2x2x2", make_debug_mesh(multi_pod=True)))

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    results, failures = [], 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   optimizer=args.optimizer)
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"!!! {arch} × {shape_name} × {mesh_name} FAILED:",
                          rec["error"], file=sys.stderr)
                    if args.fail_fast:
                        traceback.print_exc()
                        return 1
                results.append(rec)
                if args.out_dir:
                    os.makedirs(args.out_dir, exist_ok=True)
                    fn = f"{arch.replace('.', '_')}__{shape_name}__{mesh_name}.json"
                    with open(os.path.join(args.out_dir, fn), "w") as f:
                        json.dump(rec, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {failures} failed, "
          f"{len(results)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
