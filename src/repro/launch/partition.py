"""Cell partitioning: build the in/out sharding trees for every step kind
(train / prefill / decode) of an (architecture × shape × mesh) cell.

Parameter sharding comes from distributed/sharding.py path rules (Megatron
TP + FSDP hybrid).  KV/recurrent caches use a per-leaf heuristic:

    dim == global_batch            → ("pod","data")     (DP)
    largest remaining dim % model  → "model"            (seq- or channel-
                                                         sharded cache)

which covers GQA KV caches whose kv-head count (8, 4, 2, 1) does NOT divide
the 16-wide model axis — there the 32k sequence dim shards instead (the
vLLM-on-TPU posture), and recurrent states shard on channels.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import param_specs
from repro.launch.mesh import mesh_batch_axes


def _dp_size(mesh) -> int:
    n = 1
    for a in mesh_batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def cache_leaf_spec(shape: tuple, global_batch: int, mesh) -> P:
    """Heuristic cache-leaf partition (see module docstring)."""
    dp_axes = mesh_batch_axes(mesh)
    dp = _dp_size(mesh)
    model = mesh.shape.get("model", 1)
    spec: list = [None] * len(shape)
    used = set()
    # 1) batch dim → dp
    if dp > 1:
        for i, s in enumerate(shape):
            if s == global_batch and s % dp == 0:
                spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                used.add(i)
                break
    # 2) largest remaining dim divisible by the model axis → "model"
    if model > 1:
        cands = [(s, i) for i, s in enumerate(shape)
                 if i not in used and s % model == 0 and s >= model]
        if cands:
            _, i = max(cands)
            spec[i] = "model"
    return P(*spec)


def cache_specs(cache_abs, global_batch: int, mesh):
    return jax.tree_util.tree_map(
        lambda l: cache_leaf_spec(tuple(l.shape), global_batch, mesh),
        cache_abs)


def batch_specs(batch_abs, global_batch: int, mesh):
    """Input-batch sharding: shard any dim equal to global_batch over DP."""
    dp_axes = mesh_batch_axes(mesh)
    dp = _dp_size(mesh)

    def leaf(l):
        spec = [None] * len(l.shape)
        if dp > 1:
            for i, s in enumerate(l.shape):
                if s == global_batch and s % dp == 0:
                    spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                    break
        return P(*spec)

    return jax.tree_util.tree_map(leaf, batch_abs)


def opt_specs_like(opt_abs, p_specs):
    """Optimizer-state specs: mu/nu mirror the parameter specs; scalars
    replicate. Works for train/optim.py adamw and sgd_fallback states."""
    out = {}
    for k, v in opt_abs.items():
        if k in ("mu", "nu"):
            out[k] = p_specs
        else:
            out[k] = jax.tree_util.tree_map(lambda _: P(), v)
    return out


def to_named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))


def logits_spec(mesh, global_batch: int, vocab: int) -> P:
    dp_axes = mesh_batch_axes(mesh)
    dp = _dp_size(mesh)
    dp_entry = None
    if dp_axes and dp > 1 and global_batch % dp == 0:
        dp_entry = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    model = None
    msize = mesh.shape.get("model", 1)
    if msize > 1 and vocab % msize == 0:
        model = "model"
    return P(dp_entry, None, model)


def tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))
