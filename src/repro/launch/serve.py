"""Batched serving driver: prefill + decode loop with a request queue
(continuous-batching-lite): ``python -m repro.launch.serve --arch <id>``.

Requests arrive with different prompt lengths; the scheduler right-pads to
the cache length, batches up to --max-batch, prefetches the next wave while
decoding, and retires sequences on EOS/max-tokens (slot recycling). On the
production mesh the same step functions lower sharded (see dryrun decode
cells); here it runs the smoke config end-to-end on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new: int = 16
    out: Optional[List[int]] = None


class BatchServer:
    """Fixed-slot batch server: B slots, each slot holds one active request;
    prefill fills all slots at once (padded), decode advances all slots one
    token per step."""

    def __init__(self, cfg, params, batch: int, cache_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        # prefill allocates the FULL cache_len cache so decode has head-room
        self._prefill = jax.jit(api.make_prefill_step(cfg,
                                                      cache_len=cache_len))
        self._decode = jax.jit(api.make_decode_step(cfg))

    def _make_batch(self, requests: List[Request]):
        b = self.batch
        lens = [len(r.prompt) for r in requests]
        t = max(lens)
        toks = np.zeros((b, t), np.int32)
        for i, r in enumerate(requests):
            toks[i, -len(r.prompt):] = r.prompt   # left-pad (causal decode)
        return jnp.asarray(toks), t

    def serve(self, requests: List[Request], eos: int = -1):
        assert len(requests) <= self.batch
        pad = self.batch - len(requests)
        live = list(requests) + [Request(-1, np.zeros(1, np.int32), 0)
                                 for _ in range(pad)]
        for r in live:
            r.out = []
        tokens, t0 = self._make_batch(live)
        batch = {"tokens": tokens}
        if self.cfg.mrope_sections is not None:
            pos = np.broadcast_to(np.arange(t0)[None], (self.batch, t0))
            batch["positions"] = jnp.asarray(
                np.broadcast_to(pos[None], (3, self.batch, t0)).astype(np.int32))
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.zeros(
                (self.batch, self.cfg.enc_positions, self.cfg.d_model),
                jnp.float32)

        out = self._prefill(self.params, batch)
        if self.cfg.is_encdec:
            logits, cache = out[0], out[1]
        else:
            logits, cache = out
        next_tok = jnp.argmax(logits[:, -1], axis=-1)

        max_new = max(r.max_new for r in requests)
        done = np.zeros(self.batch, bool)
        for step in range(max_new):
            for i, r in enumerate(live):
                if r.rid >= 0 and not done[i]:
                    tok = int(next_tok[i])
                    r.out.append(tok)
                    if tok == eos or len(r.out) >= r.max_new:
                        done[i] = True
            if done[: len(requests)].all():
                break
            db = {"token": next_tok[:, None].astype(jnp.int32),
                  "pos": jnp.asarray(t0 + step, jnp.int32)}
            if self.cfg.mrope_sections is not None:
                db["positions"] = jnp.full((3, self.batch, 1), t0 + step,
                                           jnp.int32)
            if self.cfg.is_encdec:
                db["enc_out"] = jnp.zeros(
                    (self.batch, self.cfg.enc_positions, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            logits, cache = self._decode(self.params, db, cache)
            next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return [r.out for r in live[: len(requests)]]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, batch=args.batch, cache_len=256)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, min(cfg.vocab, 100),
                                    size=rng.integers(4, 12)).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    # the paper's sorting pass reused as a batching locality optimizer:
    # waves of similar prompt lengths minimize left-pad waste (DESIGN §3)
    from repro.core.sorting import sort_features

    feats = np.array([[len(r.prompt)] for r in reqs], dtype=np.float64)
    reqs = [reqs[i] for i in sort_features(feats, "greedy")]
    t0 = time.perf_counter()
    outputs = []
    for w in range(0, len(reqs), args.batch):      # wave scheduling
        outputs += server.serve(reqs[w: w + args.batch])
    dt = time.perf_counter() - t0
    ntok = sum(len(o) for o in outputs)
    print(f"served {len(reqs)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s)")
    for r, o in zip(reqs, outputs):
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} out={o}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
