"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Two drivers behind one CLI:
  * LM archs (--arch): reduced ("smoke") or full config, synthetic token
    stream, fault-tolerant Trainer, optional debug mesh;
  * --arch fno: the paper's end-to-end story — generate Darcy data with SKR,
    train an FNO on it (examples/train_fno.py wraps this).

CPU-safe by default (smoke config, small steps); the same driver scales to
the production mesh by passing --mesh single|multi on a real fleet.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.models import api
from repro.train.optim import adamw, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def synthetic_lm_batches(cfg, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic token stream (structured so loss can fall:
    next-token = (token + 1) mod K over a small alphabet)."""
    K = min(cfg.vocab, 128)

    def get(i):
        rng = np.random.default_rng(seed + i)
        start = rng.integers(0, K, size=(batch, 1))
        toks = (start + np.arange(seq)[None, :]) % K
        toks = toks.astype(np.int32)
        b = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        if cfg.mrope_sections is not None:
            pos = np.broadcast_to(np.arange(seq)[None], (batch, seq))
            b["positions"] = jnp.asarray(
                np.broadcast_to(pos[None], (3, batch, seq)).astype(np.int32))
        if cfg.is_encdec:
            b["enc_embeds"] = jnp.zeros((batch, cfg.enc_positions,
                                         cfg.d_model), jnp.float32)
        return b

    return get


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list_archs() + ["fno"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args(argv)

    if args.arch == "fno":
        from examples.train_fno import run_fno  # examples own the FNO loop
        run_fno(steps=args.steps)
        return 0

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"(smoke={args.smoke})")

    sched = warmup_cosine(args.lr, max(args.steps // 10, 1), args.steps)
    trainer = Trainer(
        loss_fn=lambda p, b: api.loss_fn(p, cfg, b),
        params=params,
        optimizer=adamw(sched),
        cfg=TrainerConfig(ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every,
                          compression=args.compression,
                          micro_batches=args.micro_batches,
                          log_every=max(args.steps // 10, 1)),
    )
    if args.resume:
        step = trainer.maybe_resume()
        print(f"resumed at step {step}")
    batches = synthetic_lm_batches(cfg, args.batch, args.seq)
    _, history = trainer.run(batches, args.steps, fail_at=args.fail_at)
    print(f"loss: first={history[0]:.4f} last={history[-1]:.4f}")
    return 0 if history[-1] < history[0] else 1


if __name__ == "__main__":
    sys.exit(main())
