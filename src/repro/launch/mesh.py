"""Production mesh builders (DESIGN §5).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. The dry-run (and only the dry-run) forces 512
placeholder host devices before first jax init.

Target hardware: TPU v5e pods, 16×16 = 256 chips/pod, 2 pods = 512 chips.
Axes:
    pod    inter-pod data parallelism (DCN-connected; gradient all-reduce)
    data   intra-pod data parallel / FSDP weight-shard axis
    model  tensor / expert / sequence parallel axis
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """8-device mesh for CPU integration tests (2×2×2 or 2×4)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
