"""Roofline terms from a compiled dry-run artifact (DESIGN §6).

This container is CPU-only; TPU v5e is the TARGET. We therefore derive the
three roofline terms structurally from the compiled module:

    compute_s    = FLOPs_per_chip     / PEAK_FLOPS
    memory_s     = bytes_per_chip     / HBM_BW
    collective_s = coll_bytes_per_chip / LINK_BW

Two XLA cost-analysis gotchas are handled here (verified experimentally,
see EXPERIMENTS.md §Dry-run):

  1. post-SPMD ``compiled.cost_analysis()`` reports PER-DEVICE numbers
     (a (1024,2048)@(2048,512) matmul on 4 devices reports flops/4);
  2. ``lax.scan``/while bodies are counted ONCE, not ×trip-count. We fix
     flops/bytes by finite-difference calibration (lower the same cell at
     n_rep=1 and n_rep=2 and extrapolate the linear model
     cost(n) = base + body·n), and collective bytes by structurally parsing
     the HLO: per-computation collective bytes, with while-body bytes
     multiplied by the trip count recovered from the loop condition.

Hardware constants (TPU v5e): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link
HBM_PER_CHIP = 16e9     # v5e HBM capacity

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
# computation header:  `%region_0.52 (p: ...) -> ... {`  or  `ENTRY %main ...`
# (parameter lists may contain nested parens/tuples — match greedily)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip()) if not line.startswith(" ") else None
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _collective_lines(lines: List[str]) -> Tuple[Dict[str, int], Dict[str, int]]:
    bytes_by: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in lines:
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # async pair: count once on -start
        nbytes = _shape_bytes(m.group(1))
        if m.group(3) == "-start" and m.group(1).startswith("("):
            nbytes //= 2  # async-start tuple carries (operand, result)
        bytes_by[m.group(2)] += nbytes
        counts[m.group(2)] += 1
    return bytes_by, counts


def _trip_count(cond_lines: List[str]) -> int:
    """Trip count of a scan-lowered while: the bound constant compared
    against the induction variable in the condition computation."""
    consts = [int(m.group(1)) for line in cond_lines
              for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


def collective_bytes_structural(hlo_text: str) -> Tuple[Dict[str, int],
                                                        Dict[str, int], dict]:
    """Per-device collective bytes with while-bodies ×trip-count."""
    comps = _split_computations(hlo_text)
    # computation -> multiplier (default 1; while bodies get trip count)
    mult: Dict[str, int] = {name: 1 for name in comps}
    whiles = []
    for name, lines in comps.items():
        for line in lines:
            w = _WHILE_RE.search(line)
            if w:
                whiles.append((name, w.group(1), w.group(2)))
    # propagate: body multiplier = parent multiplier × trip count
    for _ in range(4):  # few passes handle nesting
        for parent, cond, body in whiles:
            trip = _trip_count(comps.get(cond, []))
            if body in mult:
                mult[body] = mult.get(parent, 1) * trip
            if cond in mult:
                mult[cond] = mult.get(parent, 1) * trip
    total_bytes: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    total_counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for name, lines in comps.items():
        b, c = _collective_lines(lines)
        for k in COLLECTIVE_OPS:
            total_bytes[k] += b[k] * mult.get(name, 1)
            total_counts[k] += c[k] * mult.get(name, 1)
    meta = {"whiles": [{"body": b, "trip": _trip_count(comps.get(c, []))}
                       for _, c, b in whiles]}
    return total_bytes, total_counts, meta


@dataclasses.dataclass
class RooflineTerms:
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_breakdown: Dict[str, int]
    collective_counts: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_flops_ratio: Optional[float] = None   # MODEL_FLOPS / (chips·flops)
    calibration: Optional[dict] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def compute_roofline(flops_per_chip: float, bytes_per_chip: float,
                     hlo_text: str, chips: int,
                     model_flops: Optional[float] = None,
                     calibration: Optional[dict] = None) -> RooflineTerms:
    coll_bytes, coll_counts, _ = collective_bytes_structural(hlo_text)
    coll_total = float(sum(coll_bytes.values()))
    compute_s = flops_per_chip / PEAK_FLOPS
    memory_s = bytes_per_chip / HBM_BW
    collective_s = coll_total / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_flops = flops_per_chip * chips
    ratio = (model_flops / total_flops) if (model_flops and total_flops) else None
    return RooflineTerms(
        chips=chips, flops_per_chip=flops_per_chip,
        bytes_per_chip=bytes_per_chip,
        collective_bytes_per_chip=coll_total,
        collective_breakdown={k: v for k, v in coll_bytes.items() if v},
        collective_counts={k: v for k, v in coll_counts.items() if v},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_flops_ratio=ratio, calibration=calibration)


def extrapolate_linear(n1: int, c1: float, n2: int, c2: float,
                       n_full: int) -> float:
    """cost(n) = base + body·n fitted at (n1,c1),(n2,c2) → cost(n_full)."""
    if n1 == n2:
        return c1
    body = (c2 - c1) / (n2 - n1)
    base = c1 - body * n1
    return max(base + body * n_full, 0.0)


def model_flops_for(cfg, shape) -> Optional[float]:
    """6·N·D (dense) / 6·N_active·D (MoE) per step; decode D = new tokens."""
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d          # forward only
    d = shape.global_batch * 1      # decode: one token per sequence
    return 2.0 * n * d
