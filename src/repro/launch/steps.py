"""Step builders for one (architecture × shape × mesh) cell: the jitted
callable, its abstract inputs (ShapeDtypeStructs — no allocation), and the
in/out sharding trees. Consumed by dryrun.py, train.py and serve.py."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import param_specs
from repro.launch.partition import (batch_specs, cache_specs, logits_spec,
                                    opt_specs_like, to_named, tree_bytes)
from repro.models import api
from repro.train import optim


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one cell."""
    step_fn: Callable
    abstract_args: tuple          # ShapeDtypeStruct pytrees
    in_shardings: tuple           # NamedSharding pytrees (same structure)
    out_shardings: Any
    donate_argnums: tuple
    state_bytes: int              # params (+opt +cache) logical bytes
    kind: str


def _named(tree, mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree,
                                  is_leaf=lambda x: isinstance(x, P))


def make_cell_plan(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   optimizer: str = "adamw") -> CellPlan:
    """Build the step + shardings for a cell. Must run under
    ``compat.set_mesh(mesh)`` so logical-axis resolution sees the mesh."""
    b, t = shape.global_batch, shape.seq_len
    params_abs = api.abstract_params(cfg)
    p_specs = param_specs(params_abs)
    batch_abs = api.input_specs(cfg, shape)
    b_specs = batch_specs(batch_abs, b, mesh)

    if shape.kind == "train":
        opt = optim.adamw(3e-4) if optimizer == "adamw" else optim.sgd_fallback()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_specs = opt_specs_like(opt_abs, p_specs)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        state_abs = (params_abs, opt_abs, step_abs)
        state_specs = (p_specs, o_specs, P())
        train_step = api.make_train_step(cfg, opt)
        out_specs = (state_specs, {"loss": P()})
        return CellPlan(
            step_fn=train_step,
            abstract_args=(state_abs, batch_abs),
            in_shardings=(_named(state_specs, mesh), _named(b_specs, mesh)),
            out_shardings=_named(out_specs, mesh),
            donate_argnums=(0,),
            state_bytes=tree_bytes(params_abs) + tree_bytes(opt_abs),
            kind="train",
        )

    if shape.kind == "prefill":
        prefill_step = api.make_prefill_step(cfg)
        out_abs = jax.eval_shape(prefill_step, params_abs, batch_abs)
        # out = (logits, cache[, enc_out]) — cache-heuristic specs for the
        # non-logit outputs
        rest_specs = tuple(cache_specs(o, b, mesh) for o in out_abs[1:])
        out_specs = (logits_spec(mesh, b, cfg.vocab),) + rest_specs
        return CellPlan(
            step_fn=prefill_step,
            abstract_args=(params_abs, batch_abs),
            in_shardings=(_named(p_specs, mesh), _named(b_specs, mesh)),
            out_shardings=_named(out_specs, mesh),
            donate_argnums=(),
            state_bytes=tree_bytes(params_abs) + tree_bytes(out_abs[1]),
            kind="prefill",
        )

    # decode: one new token against a seq_len KV cache (serve_step)
    cache_abs = api.abstract_cache(cfg, b, t)
    c_specs = cache_specs(cache_abs, b, mesh)
    decode_step = api.make_decode_step(cfg)

    def serve_step(params, batch, cache):
        logits, new_cache = decode_step(params, batch, cache)
        return logits, new_cache

    # output cache structure can differ from the input one (enc-dec decode
    # unstacks the layer dim) — derive output specs from the actual out tree
    out_abs = jax.eval_shape(serve_step, params_abs, batch_abs, cache_abs)
    out_c_specs = cache_specs(out_abs[1], b, mesh)
    out_specs = (logits_spec(mesh, b, cfg.vocab), out_c_specs)
    return CellPlan(
        step_fn=serve_step,
        abstract_args=(params_abs, batch_abs, cache_abs),
        in_shardings=(_named(p_specs, mesh), _named(b_specs, mesh),
                      _named(c_specs, mesh)),
        out_shardings=_named(out_specs, mesh),
        donate_argnums=(2,),
        state_bytes=tree_bytes(params_abs) + tree_bytes(cache_abs),
        kind="decode",
    )


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               optimizer: str = "adamw"):
    """Lower (no compile) one cell under the mesh. Returns (lowered, plan)."""
    with compat.set_mesh(mesh):
        plan = make_cell_plan(cfg, shape, mesh, optimizer=optimizer)
        jitted = jax.jit(plan.step_fn,
                         in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings,
                         donate_argnums=plan.donate_argnums)
        lowered = jitted.lower(*plan.abstract_args)
    return lowered, plan
