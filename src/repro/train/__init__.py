"""Training substrate: optimizers, fault-tolerant loop, data pipeline."""
