"""Fault-tolerant training loop (DESIGN §5).

One Trainer drives both model families (LM archs via models/api loss_fn,
neural operators via a user loss_fn). Production behaviors:

  * periodic atomic checkpoints (CheckpointManager) + warm resume — a
    preempted job restarts at the last step with optimizer state intact;
  * fault injection (`fail_at`) for the restart tests;
  * microbatch gradient accumulation with a straggler-drop threshold
    (optim.GradAccumulator): a slow host's microbatch is dropped instead of
    stalling the step once `threshold` of them arrived;
  * optional error-feedback gradient compression on the (slow, cross-pod)
    gradient reduction path (distributed/compression.py);
  * mesh-aware: pass a mesh + donate-able shardings and the jitted step is
    pjit-partitioned; pass mesh=None for single-device CPU runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.distributed.compression import compress_tree, init_error_tree
from repro.train.checkpoint import CheckpointManager
from repro.train.optim import Optimizer, adamw


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    compression: str = "none"      # none | int8 | topk
    topk_frac: float = 0.1
    micro_batches: int = 1
    straggler_threshold: float = 1.0


class Trainer:
    def __init__(self, loss_fn: Callable, params, optimizer: Optimizer = None,
                 cfg: TrainerConfig = TrainerConfig(), mesh=None,
                 state_shardings=None):
        self.loss_fn = loss_fn
        self.optimizer = optimizer or adamw(3e-4)
        self.cfg = cfg
        self.mesh = mesh
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
                     if cfg.ckpt_dir else None)

        opt_state = self.optimizer.init(params)
        err = (init_error_tree(params)
               if cfg.compression != "none" else None)
        self.state = {"params": params, "opt": opt_state,
                      "step": jnp.zeros((), jnp.int32)}
        if err is not None:
            self.state["err"] = err
        self.history: list = []
        self._step_fn = self._build_step()

    # ----------------------------------------------------------- step fn
    def _build_step(self):
        cfg = self.cfg
        opt = self.optimizer
        loss_fn = self.loss_fn
        nmicro = max(cfg.micro_batches, 1)

        def step(state, batch):
            params = state["params"]

            if nmicro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                # microbatch accumulation: batch leading dim splits evenly
                def micro(i, carry):
                    tot_loss, tot_grads = carry
                    mb = jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_slice_in_dim(
                            a, i * (a.shape[0] // nmicro),
                            a.shape[0] // nmicro), batch)
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    return (tot_loss + l,
                            jax.tree_util.tree_map(jnp.add, tot_grads, g))

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, p.dtype), params)
                loss, grads = jax.lax.fori_loop(
                    0, nmicro, micro, (jnp.zeros(()), zeros))
                loss = loss / nmicro
                grads = jax.tree_util.tree_map(lambda g: g / nmicro, grads)

            new_state = dict(state)
            if "err" in state:
                grads, new_err = compress_tree(
                    grads, state["err"], cfg.compression, cfg.topk_frac)
                new_state["err"] = new_err
            updates, new_opt = opt.update(grads, state["opt"], params)
            new_state["params"] = jax.tree_util.tree_map(
                lambda p, u: p + u, params, updates)
            new_state["opt"] = new_opt
            new_state["step"] = state["step"] + 1
            return new_state, {"loss": loss}

        if self.mesh is not None:
            with compat.set_mesh(self.mesh):
                return jax.jit(step, donate_argnums=0)
        return jax.jit(step, donate_argnums=0)

    # ------------------------------------------------------------ resume
    def maybe_resume(self) -> Optional[int]:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return None
        self.state, step = self.ckpt.restore(self.state)
        return step

    # -------------------------------------------------------------- run
    def run(self, batches, num_steps: int, fail_at: Optional[int] = None,
            log: Callable = print):
        """batches: iterable/callable yielding batch pytrees."""
        cfg = self.cfg
        get = batches if callable(batches) else (lambda i, it=iter(batches):
                                                 next(it))
        start = int(self.state["step"])
        t0 = time.perf_counter()
        for i in range(start, num_steps):
            if fail_at is not None and i >= fail_at:
                raise RuntimeError(f"injected fault at step {i}")
            batch = get(i)
            if self.mesh is not None:
                with compat.set_mesh(self.mesh):
                    self.state, metrics = self._step_fn(self.state, batch)
            else:
                self.state, metrics = self._step_fn(self.state, batch)
            loss = float(metrics["loss"])
            self.history.append(loss)
            if cfg.log_every and (i + 1) % cfg.log_every == 0:
                dt = time.perf_counter() - t0
                log(f"step {i + 1:5d}  loss {loss:.4f}  "
                    f"{(i + 1 - start) / dt:.2f} steps/s")
            if self.ckpt and cfg.ckpt_every and (i + 1) % cfg.ckpt_every == 0:
                self.ckpt.save(i + 1, self.state)
        if self.ckpt:
            self.ckpt.save(num_steps, self.state)
        return self.state, self.history
