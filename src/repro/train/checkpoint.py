"""Fault-tolerant checkpointing (DESIGN §5).

Design points for the 1000-node posture:
  * atomic publish: write to a tmp dir, fsync, then os.replace the manifest —
    a preempted writer never corrupts the latest valid checkpoint;
  * mesh-agnostic: arrays are saved UNSHARDED (gathered) with their tree
    paths; restore re-shards onto whatever mesh the restarted job brings up —
    elastic rescale (256 → 512 chips or down to 8-chip debug) is a restore,
    not a migration;
  * keep-last-k retention with best-effort GC;
  * save/restore roundtrip is bitwise (tested in tests/test_checkpoint.py).

On a real multi-host fleet the np.savez writes become per-host shard files
with a rendezvous barrier; the manifest/commit protocol is identical.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


_UINT_OF_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _encode_array(a: np.ndarray) -> np.ndarray:
    """npz cannot store extension dtypes (bfloat16, fp8, …) — view them as
    same-width uints; the restore path views back using the target dtype."""
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        return a.view(_UINT_OF_SIZE[a.dtype.itemsize])
    return a


def _decode_array(a: np.ndarray, target_dtype) -> np.ndarray:
    td = np.dtype(target_dtype)
    if a.dtype != td and (td.kind == "V" or td.name not in np.sctypeDict):
        return a.view(td)
    return a


def _flatten_with_paths(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_token(p) for p in path)
        flat[key] = _encode_array(np.asarray(leaf))
    return flat


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"k:{p.name}"
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- paths
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "MANIFEST.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    # -------------------------------------------------------------- save
    def save(self, step: int, state, metadata: Optional[dict] = None):
        """Atomic: tmpdir → arrays.npz + MANIFEST.json → rename."""
        flat = _flatten_with_paths(state)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_save_")
        try:
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
            manifest = {
                "step": int(step),
                "keys": sorted(flat),
                "treedef": _treedef_repr(state),
                "metadata": metadata or {},
            }
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return self._step_dir(step)

    def _gc(self):
        steps = sorted(
            int(_STEP_RE.match(n).group(1))
            for n in os.listdir(self.directory) if _STEP_RE.match(n))
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------ restore
    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). Pass `shardings` (same structure) to place leaves
        sharded — the elastic-rescale path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = self._step_dir(step)
        z = np.load(os.path.join(d, "arrays.npz"))
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        flat_shardings = (jax.tree_util.tree_leaves(shardings)
                          if shardings is not None else [None] * len(paths))
        for (path, leaf), shd in zip(paths, flat_shardings):
            key = "/".join(_path_token(p) for p in path)
            if key not in z:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = _decode_array(z[key], leaf.dtype)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), step


def _treedef_repr(tree) -> str:
    return str(jax.tree_util.tree_structure(tree))
