"""Optimizers (optax-free: the framework owns its substrate per the scope
rules): AdamW with decoupled weight decay, global-norm clipping, warmup +
cosine schedules, gradient accumulation."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * (step + 1) / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw(lr: float | Callable = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        gf = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state["mu"], gf)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state["nu"], gf)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) +
                         weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, params, mu, nu)
        return updates, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init=init, update=update)


def sgd_fallback(lr: float = 1e-3) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        updates = jax.tree_util.tree_map(
            lambda g, p: (-lr * g.astype(jnp.float32)).astype(p.dtype),
            grads, params)
        return updates, {"step": state["step"] + 1}

    return Optimizer(init=init, update=update)


@dataclasses.dataclass
class GradAccumulator:
    """Microbatch gradient accumulation with a straggler-tolerance knob:
    `threshold` < 1.0 averages over however many microbatches contributed
    (the barrier-free drop-slowest-k posture, DESIGN §5)."""

    num_micro: int
    threshold: float = 1.0

    def run(self, grad_fn, params, microbatches, arrived_mask=None):
        total = None
        count = 0.0
        for i, mb in enumerate(microbatches):
            if arrived_mask is not None and not arrived_mask[i]:
                continue  # straggler dropped
            g = grad_fn(params, mb)
            total = g if total is None else jax.tree_util.tree_map(
                jnp.add, total, g)
            count += 1.0
        need = max(int(self.num_micro * self.threshold), 1)
        if count < need:
            raise RuntimeError(
                f"only {int(count)}/{self.num_micro} microbatches arrived "
                f"(< threshold {need})")
        return jax.tree_util.tree_map(lambda g: g / count, total), int(count)
