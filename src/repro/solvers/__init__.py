"""Krylov solver layer: GMRES (baseline) + GCRO-DR (recycling) +
TPU-adapted preconditioners."""
from repro.solvers.gcrodr import GCRODRSolver, solve_gcrodr
from repro.solvers.gmres import gmres_solve, solve_gmres
from repro.solvers.operator import (DIAOp, PreconditionedOp, StencilOp,
                                    apply_op, as_operator)
from repro.solvers.precond import PRECONDITIONERS, make_preconditioner
from repro.solvers.types import KrylovConfig, SequenceStats, SolveStats

__all__ = [
    "GCRODRSolver",
    "solve_gcrodr",
    "gmres_solve",
    "solve_gmres",
    "DIAOp",
    "PreconditionedOp",
    "StencilOp",
    "apply_op",
    "as_operator",
    "PRECONDITIONERS",
    "make_preconditioner",
    "KrylovConfig",
    "SequenceStats",
    "SolveStats",
]
