"""Krylov solver layer: GMRES (baseline) + GCRO-DR (recycling, sequential
and lockstep-batched) + TPU-adapted preconditioners."""
from repro.solvers.batched import BatchedGCRODRSolver
from repro.solvers.gcrodr import GCRODRSolver, solve_gcrodr
from repro.solvers.gmres import gmres_solve, solve_gmres
from repro.solvers.operator import (DIAOp, PreconditionedOp, StencilOp,
                                    apply_op, as_operator)
from repro.solvers.precond import (PRECONDITIONERS, make_preconditioner,
                                   make_preconditioner_batched)
from repro.solvers.types import KrylovConfig, SequenceStats, SolveStats

__all__ = [
    "BatchedGCRODRSolver",
    "GCRODRSolver",
    "solve_gcrodr",
    "gmres_solve",
    "solve_gmres",
    "DIAOp",
    "PreconditionedOp",
    "StencilOp",
    "apply_op",
    "as_operator",
    "PRECONDITIONERS",
    "make_preconditioner",
    "make_preconditioner_batched",
    "KrylovConfig",
    "SequenceStats",
    "SolveStats",
]
