"""Small host-side dense linear algebra between Arnoldi cycles (m ≲ 200:
microseconds on host, no TPU-side nonsymmetric eig exists — DESIGN §4.3)."""
from __future__ import annotations

import numpy as np
import scipy.linalg


def hessenberg_lstsq(h: np.ndarray, beta: float) -> np.ndarray:
    """argmin_y ‖β e₁ − H y‖ for the (j+1, j) Hessenberg block."""
    g = np.zeros(h.shape[0])
    g[0] = beta
    y, *_ = np.linalg.lstsq(h, g, rcond=None)
    return y


def real_spanning_basis(evals: np.ndarray, evecs: np.ndarray, k: int) -> np.ndarray:
    """k-column real orthonormal basis spanning the invariant subspace of the
    eigenvectors with SMALLEST |λ| (harmonic Ritz selection, Alg. 2 l.14/29).

    Complex conjugate pairs contribute their real/imag parts; rank-revealing
    pivoted QR picks k independent directions. Returns (n, k_eff), k_eff ≤ k.
    """
    finite = np.isfinite(evals)
    evals = np.where(finite, evals, np.inf)
    order = np.argsort(np.abs(evals))
    cand = []
    for idx in order[: 2 * k + 2]:
        if not np.isfinite(evals[idx]):
            continue
        v = evecs[:, idx]
        cand.append(np.real(v))
        if abs(np.imag(evals[idx])) > 1e-12 * max(1.0, abs(evals[idx])):
            cand.append(np.imag(v))
        if len(cand) >= 2 * k:
            break
    if not cand:
        return np.zeros((evecs.shape[0], 0))
    m = np.stack(cand, axis=1)
    q, r, _ = scipy.linalg.qr(m, mode="economic", pivoting=True)
    diag = np.abs(np.diag(r))
    rank = int(np.sum(diag > 1e-12 * max(diag[0], 1e-300)))
    return q[:, : min(k, rank)]


def _first_cycle_pencil(h: np.ndarray, j: int):
    """(H_m + h²_{m+1,m} H_m⁻ᴴ e_m e_mᴴ) — the fresh-cycle harmonic-Ritz
    pencil (Alg. 2 line 14); None when H_m is singular."""
    hm = h[:j, :j]
    h2 = h[j, j - 1] ** 2
    em = np.zeros((j, 1))
    em[-1, 0] = 1.0
    try:
        corr = h2 * np.linalg.solve(hm.T, em)  # H⁻ᵀ e_m (real arithmetic)
    except np.linalg.LinAlgError:
        return None
    return hm + corr @ em.T


def harmonic_ritz_first_cycle(h: np.ndarray, j: int, k: int) -> np.ndarray:
    """Harmonic Ritz vectors from a fresh GMRES cycle (Alg. 2 line 14):
    eig of (H_m + h²_{m+1,m} H_m⁻ᴴ e_m e_mᴴ). Returns P (j, k_eff)."""
    a = _first_cycle_pencil(h, j)
    if a is None:
        return np.zeros((j, 0))
    evals, evecs = np.linalg.eig(a)
    return real_spanning_basis(evals, evecs, k)


def harmonic_ritz_deflated(g: np.ndarray, whv: np.ndarray, k: int) -> np.ndarray:
    """Harmonic Ritz from a deflated cycle (Alg. 2 line 29):
    Ĝᴴ Ĝ z = θ Ĝᴴ Ŵᴴ V̂ z. Returns P (k+j, k_eff)."""
    a1 = g.T @ g
    a2 = g.T @ whv
    try:
        evals, evecs = scipy.linalg.eig(a1, a2)
    except (scipy.linalg.LinAlgError, ValueError):
        return np.zeros((g.shape[1], 0))
    return real_spanning_basis(evals, evecs, k)


def right_tri_solve(u: np.ndarray, r: np.ndarray) -> np.ndarray:
    """U R⁻¹ for upper-triangular R (Alg. 2: U_k = Ỹ_k R⁻¹)."""
    return scipy.linalg.solve_triangular(r.T, u.T, lower=True).T


# --------------------------------------------------------------------------
# Stacked (multi-chain) variants — the host half of the batched lockstep
# engine (solvers/batched.py). Each takes B chains' small blocks at per-chain
# EFFECTIVE widths j[i] and uses one LAPACK call on the whole stack whenever
# the widths agree (the lockstep common case: every unconverged chain ran a
# full cycle); ragged widths fall back to a per-chain loop. B is the worker
# count (≲ dozens), the blocks are m ≲ 200 — host microseconds either way,
# but the stacked path keeps BLAS calls O(1) per lockstep cycle.
# --------------------------------------------------------------------------


def _stack_well_conditioned(r: np.ndarray, rtol: float = 1e-12) -> bool:
    """True when every R factor in a stacked QR is safely invertible —
    gate for the fast solve path (lstsq fallback handles the rest)."""
    diag = np.abs(np.diagonal(r, axis1=-2, axis2=-1))
    return bool(np.all(diag.min(axis=-1) >
                       rtol * np.maximum(diag.max(axis=-1), 1e-300)))


def hessenberg_lstsq_stacked(h: np.ndarray, j: np.ndarray,
                             beta: np.ndarray) -> np.ndarray:
    """Stacked argmin_y ‖β_i e₁ − H_i y‖ over B chains.

    h: (B, m+1, m) raw Hessenbergs; j: (B,) effective widths (0 = frozen
    chain); beta: (B,) residual norms. Returns y (B, m), zero-padded — rows
    with j[i] == 0 stay zero (the padded-update no-op convention).
    """
    h = np.asarray(h)
    j = np.asarray(j, dtype=int)
    beta = np.asarray(beta, dtype=float)
    bsz, _, m = h.shape
    y = np.zeros((bsz, m))
    act = np.nonzero(j > 0)[0]
    if act.size == 0:
        return y
    ji = int(j[act[0]])
    if np.all(j[act] == ji):
        blocks = h[act][:, : ji + 1, :ji]
        q, r = np.linalg.qr(blocks)               # one stacked QR
        if _stack_well_conditioned(r):
            rhs = q[:, 0, :] * beta[act, None]    # Qᵀ(β e₁) = β·(first row)
            y[act[:, None], np.arange(ji)[None, :]] = \
                np.linalg.solve(r, rhs[..., None])[..., 0]
            return y
        # near-breakdown column somewhere in the stack → per-chain lstsq
    for i in act:
        ji = int(j[i])
        y[i, :ji] = hessenberg_lstsq(h[i, : ji + 1, :ji], beta[i])
    return y


def lstsq_stacked(a_list: list, b_list: list) -> list:
    """Per-chain min‖b_i − A_i y‖ (entries may be None = frozen chain).

    One stacked QR + triangular solve when every live block has the same
    shape; ragged or rank-deficient stacks fall back to per-chain lstsq.
    """
    out = [None] * len(a_list)
    live = [i for i, a in enumerate(a_list) if a is not None]
    if not live:
        return out
    shape0 = a_list[live[0]].shape
    if all(a_list[i].shape == shape0 for i in live):
        stack = np.stack([a_list[i] for i in live])
        rhs = np.stack([b_list[i] for i in live])
        q, r = np.linalg.qr(stack)
        if _stack_well_conditioned(r):
            ys = np.linalg.solve(
                r, np.einsum("bij,bi->bj", q, rhs)[..., None])[..., 0]
            for t, i in enumerate(live):
                out[i] = ys[t]
            return out
    for i in live:
        out[i], *_ = np.linalg.lstsq(a_list[i], b_list[i], rcond=None)
    return out


def harmonic_ritz_first_cycle_stacked(h: np.ndarray, j: np.ndarray,
                                      k: int) -> list:
    """Fresh-cycle harmonic-Ritz bases for B chains: list of P_i
    ((j_i, k_eff_i) arrays; None where j_i < 2 or the pencil is singular).

    Uniform-width stacks share ONE np.linalg.eig call over the stacked
    pencils; the per-chain basis selection (real spans of complex pairs +
    rank-revealing QR) stays a loop — it is O(k²·j) bookkeeping.
    """
    h = np.asarray(h)
    j = np.asarray(j, dtype=int)
    bsz = h.shape[0]
    out = [None] * bsz
    act = [i for i in range(bsz) if min(k, int(j[i]) - 1) >= 1]
    if not act:
        return out
    ji = int(j[act[0]])
    if all(int(j[i]) == ji for i in act):
        pencils, ok_idx = [], []
        for i in act:
            a = _first_cycle_pencil(h[i], ji)
            if a is not None:
                pencils.append(a)
                ok_idx.append(i)
        if ok_idx:
            evals, evecs = np.linalg.eig(np.stack(pencils))  # stacked eig
            for t, i in enumerate(ok_idx):
                out[i] = real_spanning_basis(evals[t], evecs[t],
                                             min(k, ji - 1))
        return out
    for i in act:
        out[i] = harmonic_ritz_first_cycle(h[i], int(j[i]),
                                           min(k, int(j[i]) - 1))
    return out


def harmonic_ritz_deflated_stacked(g_list: list, whv_list: list,
                                   k: int) -> list:
    """Deflated-cycle harmonic Ritz per chain (None entries pass through).

    The generalized pencil Ĝᴴ Ĝ z = θ Ĝᴴ Ŵᴴ V̂ z has no stacked LAPACK
    driver — this is the one per-chain eig loop left in the lockstep engine.
    """
    return [None if g is None else harmonic_ritz_deflated(g, whv, k)
            for g, whv in zip(g_list, whv_list)]
