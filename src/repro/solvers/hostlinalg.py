"""Small host-side dense linear algebra between Arnoldi cycles (m ≲ 200:
microseconds on host, no TPU-side nonsymmetric eig exists — DESIGN §4.3)."""
from __future__ import annotations

import numpy as np
import scipy.linalg


def hessenberg_lstsq(h: np.ndarray, beta: float) -> np.ndarray:
    """argmin_y ‖β e₁ − H y‖ for the (j+1, j) Hessenberg block."""
    g = np.zeros(h.shape[0])
    g[0] = beta
    y, *_ = np.linalg.lstsq(h, g, rcond=None)
    return y


def real_spanning_basis(evals: np.ndarray, evecs: np.ndarray, k: int) -> np.ndarray:
    """k-column real orthonormal basis spanning the invariant subspace of the
    eigenvectors with SMALLEST |λ| (harmonic Ritz selection, Alg. 2 l.14/29).

    Complex conjugate pairs contribute their real/imag parts; rank-revealing
    pivoted QR picks k independent directions. Returns (n, k_eff), k_eff ≤ k.
    """
    finite = np.isfinite(evals)
    evals = np.where(finite, evals, np.inf)
    order = np.argsort(np.abs(evals))
    cand = []
    for idx in order[: 2 * k + 2]:
        if not np.isfinite(evals[idx]):
            continue
        v = evecs[:, idx]
        cand.append(np.real(v))
        if abs(np.imag(evals[idx])) > 1e-12 * max(1.0, abs(evals[idx])):
            cand.append(np.imag(v))
        if len(cand) >= 2 * k:
            break
    if not cand:
        return np.zeros((evecs.shape[0], 0))
    m = np.stack(cand, axis=1)
    q, r, _ = scipy.linalg.qr(m, mode="economic", pivoting=True)
    diag = np.abs(np.diag(r))
    rank = int(np.sum(diag > 1e-12 * max(diag[0], 1e-300)))
    return q[:, : min(k, rank)]


def harmonic_ritz_first_cycle(h: np.ndarray, j: int, k: int) -> np.ndarray:
    """Harmonic Ritz vectors from a fresh GMRES cycle (Alg. 2 line 14):
    eig of (H_m + h²_{m+1,m} H_m⁻ᴴ e_m e_mᴴ). Returns P (j, k_eff)."""
    hm = h[:j, :j]
    h2 = h[j, j - 1] ** 2
    em = np.zeros((j, 1))
    em[-1, 0] = 1.0
    try:
        corr = h2 * np.linalg.solve(hm.T, em)  # H⁻ᵀ e_m (real arithmetic)
    except np.linalg.LinAlgError:
        return np.zeros((j, 0))
    evals, evecs = np.linalg.eig(hm + corr @ em.T)
    return real_spanning_basis(evals, evecs, k)


def harmonic_ritz_deflated(g: np.ndarray, whv: np.ndarray, k: int) -> np.ndarray:
    """Harmonic Ritz from a deflated cycle (Alg. 2 line 29):
    Ĝᴴ Ĝ z = θ Ĝᴴ Ŵᴴ V̂ z. Returns P (k+j, k_eff)."""
    a1 = g.T @ g
    a2 = g.T @ whv
    try:
        evals, evecs = scipy.linalg.eig(a1, a2)
    except (scipy.linalg.LinAlgError, ValueError):
        return np.zeros((g.shape[1], 0))
    return real_spanning_basis(evals, evecs, k)


def right_tri_solve(u: np.ndarray, r: np.ndarray) -> np.ndarray:
    """U R⁻¹ for upper-triangular R (Alg. 2: U_k = Ỹ_k R⁻¹)."""
    return scipy.linalg.solve_triangular(r.T, u.T, lower=True).T
