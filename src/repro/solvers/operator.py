"""Linear-operator pytrees for the Krylov layer.

Why pytrees instead of closures: the inner Arnoldi cycle is jitted once and
reused across the THOUSANDS of systems in a dataset sequence. A fresh Python
closure per system would force a retrace per system; a pytree operator with
static structure (offsets, kind tags in the treedef) retraces once per
(family, grid, m, k) and streams the rest.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.pde.dia import DIA, Stencil5, dia_matvec
from repro.kernels import ops as kops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StencilOp:
    """5-point stencil operator on flat (n,) vectors."""

    coeffs: jax.Array  # (5, nx, ny)
    use_kernel: bool = False  # route matvec through the Pallas kernel

    def tree_flatten(self):
        return (self.coeffs,), self.use_kernel

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(coeffs=children[0], use_kernel=aux)

    @property
    def n(self) -> int:
        return self.coeffs.shape[-2] * self.coeffs.shape[-1]

    @property
    def grid(self) -> Tuple[int, int]:
        return self.coeffs.shape[-2], self.coeffs.shape[-1]

    def apply(self, v: jax.Array) -> jax.Array:
        nx, ny = self.grid
        field = v.reshape(*v.shape[:-1], nx, ny)
        out = kops.stencil5_matvec(self.coeffs, field, use_kernel=self.use_kernel)
        return out.reshape(*v.shape[:-1], nx * ny)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DIAOp:
    """Diagonal-format operator on flat (n,) vectors."""

    dia: DIA
    use_kernel: bool = False

    def tree_flatten(self):
        return (self.dia,), self.use_kernel

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(dia=children[0], use_kernel=aux)

    @property
    def n(self) -> int:
        return self.dia.n

    def apply(self, v: jax.Array) -> jax.Array:
        return kops.dia_spmv(self.dia, v, use_kernel=self.use_kernel)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PreconditionedOp:
    """Right-preconditioned operator v ↦ A(M⁻¹ v).

    Solvers run in z-space (A M⁻¹ z = b) and recover x = M⁻¹ z at the end, so
    the tracked residual is the TRUE residual of A x = b.
    """

    base: object   # StencilOp | DIAOp
    precond: object  # a Preconditioner pytree from precond.py (or None)

    def tree_flatten(self):
        return (self.base, self.precond), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.base.n

    def apply(self, v: jax.Array) -> jax.Array:
        if self.precond is None:
            return self.base.apply(v)
        return self.base.apply(self.precond.apply(v))

    def from_z(self, z: jax.Array) -> jax.Array:
        """Map z-space solution to x-space."""
        if self.precond is None:
            return z
        return self.precond.apply(z)


def apply_op(op, v: jax.Array) -> jax.Array:
    """Module-level dispatch (stable jit identity)."""
    return op.apply(v)


def cast_operator(op, dtype):
    """Cast every floating leaf of an operator/preconditioner pytree.

    The precision-policy layer builds the fp32 twin of a PreconditionedOp
    with this: static structure (offsets, kind tags, degrees) rides in the
    treedef and is untouched, so the casted twin shares jit caches keyed on
    treedef + (shape, dtype) and retraces exactly once per precision."""
    dtype = jnp.dtype(dtype)

    def _cast(leaf):
        a = jnp.asarray(leaf)
        if jnp.issubdtype(a.dtype, jnp.floating) and a.dtype != dtype:
            return a.astype(dtype)
        return leaf

    return jax.tree_util.tree_map(_cast, op)


def as_operator(problem_op, use_kernel: bool = False):
    """Stencil5 | DIA → solver operator."""
    if isinstance(problem_op, Stencil5):
        return StencilOp(problem_op.coeffs, use_kernel=use_kernel)
    if isinstance(problem_op, DIA):
        return DIAOp(problem_op, use_kernel=use_kernel)
    raise TypeError(f"unsupported operator {type(problem_op)}")
