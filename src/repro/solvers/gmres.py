"""Restarted GMRES — the paper's baseline (PETSc KSPGMRES semantics:
relative-residual tolerance, restart length m, right preconditioning so the
tracked residual is the true residual).

Precision policy: `cfg.inner_dtype="float32"` routes through
`_gmres_solve_mixed` — an fp64 outer iterative-refinement loop whose
correction systems are solved by THIS solver on the fp32-casted operator
(`cast_operator`). The fp64 default takes the historical code path
unchanged (bitwise regression-tested)."""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs.telemetry import KrylovTelemetry
from repro.solvers.arnoldi import arnoldi_cycle
from repro.solvers.hostlinalg import hessenberg_lstsq
from repro.solvers.operator import (PreconditionedOp, apply_op, as_operator,
                                    cast_operator)
from repro.solvers.types import KrylovConfig, SolveStats


@jax.jit
def _residual_norms(op, b, z):
    """Initial residual AND both norms in ONE dispatch (the x0 path used to
    pay two host syncs before the first cycle; warm-started solves now issue
    a single device round-trip)."""
    r = b - apply_op(op, z)
    return r, jnp.linalg.norm(b), jnp.linalg.norm(r)


@jax.jit
def _fused_update(op, b, z, v, y):
    """z += Vᵀy (y zero-padded to the cycle width) + true residual — one
    dispatch instead of a host V copy + host matmul + residual dispatch."""
    z = z + v[:-1].T @ y
    r = b - apply_op(op, z)
    return z, r, jnp.linalg.norm(r)


@jax.jit
def _ir_accum(base, b, x, d):
    """Outer refinement step: x += d (upcast) and the TRUE fp64 residual of
    the UNpreconditioned operator — one dispatch per outer pass."""
    x = x + d.astype(b.dtype)
    r = b - apply_op(base, x)
    return x, r, jnp.linalg.norm(r)


_downcast32 = jax.jit(lambda r: r.astype(jnp.float32))


def gmres_solve(op: PreconditionedOp, b, cfg: KrylovConfig, x0=None,
                use_kernel: bool = False, stall_break: bool = False):
    """Returns (x, SolveStats). `op` must be a PreconditionedOp; `b` flat.

    stall_break: break out (instead of spinning to maxiter) when full cycles
    at the restart cap stop reducing the residual — used by the
    mixed-precision outer loop for its inner fp32 correction solves, where
    the fp32 round-off floor is an expected exit, not a failure.
    """
    if cfg.inner_dtype == "float32":
        return _gmres_solve_mixed(op, b, cfg, x0=x0, use_kernel=use_kernel)
    t0 = time.perf_counter()
    n = int(b.shape[0])
    b = jnp.asarray(b)
    z = jnp.zeros(n, b.dtype) if x0 is None else jnp.asarray(x0)
    stats = SolveStats()
    if x0 is None:
        r = b
        bnorm = rnorm = float(jnp.linalg.norm(b))   # one sync, not two
    else:
        r, bn, rn = _residual_norms(op, b, z)
        bnorm, rnorm = (float(v) for v in jax.device_get((bn, rn)))
    stats.host_syncs += 1
    stats.dispatches += 1
    if bnorm == 0.0:
        return np.zeros(n), SolveStats(converged=True, rel_residual=0.0,
                                       wall_time_s=time.perf_counter() - t0)
    tol_abs = cfg.tol * bnorm
    empty_c = jnp.zeros((0, n), b.dtype)

    # Adaptive restart (anti-stagnation): restarted GMRES at a FIXED m can
    # stall on indefinite operators (Helmholtz) — the restart discards the
    # small-eigenvalue information every cycle. When a full cycle reduces the
    # residual by less than 2× we double m up to m_cap; each growth retraces
    # the jitted cycle once (new static shape), which converged runs never pay.
    m = cfg.m
    m_cap = min(n, cfg.m_max if cfg.m_max else 8 * cfg.m)
    no_prog = 0
    # free per-cycle telemetry: rnorm is already a host float every cycle
    hist = [] if obs.enabled() else None
    while True:
        if rnorm <= tol_abs:
            stats.converged = True
            break
        if stats.iterations >= cfg.maxiter:
            break
        cyc = arnoldi_cycle(op, empty_c, r, tol_abs, m=m,
                            orthog=cfg.orthog, use_kernel=use_kernel,
                            h_acc=cfg.cgs2_acc)
        j = int(cyc.j_used)
        stats.host_syncs += 2      # j_used + Hessenberg pull
        stats.dispatches += 1      # arnoldi_cycle
        if j == 0:
            break  # stagnation
        h = np.asarray(cyc.h)[: j + 1, :j]
        y = np.zeros(m, dtype=h.dtype)   # device-dtype padded factor
        y[:j] = hessenberg_lstsq(h, rnorm)
        rprev = rnorm
        z, r, rn = _fused_update(op, b, z, cyc.v, jnp.asarray(y))
        rnorm = float(rn)
        if hist is not None:
            hist.append(rnorm)
        stats.host_syncs += 2      # rn + breakdown flag
        stats.dispatches += 1
        stats.iterations += j
        stats.matvecs += j + 1
        stats.cycles += 1
        stats.breakdown = bool(cyc.breakdown)
        if stats.breakdown and rnorm > tol_abs:
            break  # exact breakdown but not converged: stop honestly
        grew = j == m and rnorm > tol_abs and rnorm > 0.5 * rprev and m < m_cap
        if grew:
            m = min(2 * m, m_cap)
        if stall_break:
            no_prog = no_prog + 1 if rnorm > 0.99 * rprev else 0
            if grew:
                no_prog = 0  # a longer cycle deserves a fresh shot
            elif no_prog >= 3:
                break  # round-off floor reached — hand back to the outer loop

    x = np.asarray(op.from_z(z))
    stats.host_syncs += 1
    stats.dispatches += 1
    stats.rel_residual = rnorm / bnorm
    stats.wall_time_s = time.perf_counter() - t0
    if hist is not None:
        stats.telemetry = KrylovTelemetry(res_hist=np.asarray(hist))
    return x, stats


def _ir_refine(op: PreconditionedOp, b, cfg: KrylovConfig, solve32, solve64,
               x0=None):
    """The fp64 iterative-refinement outer loop shared by the mixed GMRES
    and GCRO-DR drivers (the lockstep engine has its own per-chain-masked
    variant in solvers/batched.py).

    Invariants: `b`, the accumulated solution `x`, and every residual of
    record are fp64; each outer pass solves the correction system A·d = r
    through a callback — `solve32(r, tol_rel, iter_budget)` on the
    fp32-casted operator, or `solve64(...)` in full precision once fp32
    stagnates (a pass reducing ‖r‖ by < 2×, an overflow rollback, or
    `ir_max_outer` exhausted) — then re-derives the TRUE fp64 residual, so
    `cfg.tol` is always reachable. Callbacks own everything solver-specific
    (operator twins, recycle-carry transplants).
    """
    t0 = time.perf_counter()
    n = int(b.shape[0])
    b = jnp.asarray(b, jnp.float64)
    stats = SolveStats()
    if x0 is None:
        x = jnp.zeros(n, b.dtype)
        r = b
        bnorm = rnorm = float(jnp.linalg.norm(b))
        stats.host_syncs += 1
        stats.dispatches += 1
    else:
        # x0 follows the plain-path contract (z-space guess): x = M⁻¹ x0
        x = jnp.asarray(op.from_z(jnp.asarray(x0)))
        r, bn, rn = _residual_norms(op, b, jnp.asarray(x0))
        bnorm, rnorm = (float(v) for v in jax.device_get((bn, rn)))
        stats.host_syncs += 1
        stats.dispatches += 1
    if bnorm == 0.0:
        return np.zeros(n), SolveStats(converged=True, rel_residual=0.0,
                                       wall_time_s=time.perf_counter() - t0)
    tol_abs = cfg.tol * bnorm
    fallback = False
    # outer-pass telemetry (kind="outer"): the TRUE fp64 residual after
    # each refinement pass — already host floats, so recording is free
    hist = [] if obs.enabled() else None

    while rnorm > tol_abs and stats.iterations < cfg.maxiter:
        budget = cfg.maxiter - stats.iterations
        if not fallback and stats.outer_refinements < cfg.ir_max_outer:
            # ---- fp32 correction pass --------------------------------------
            tol_i = min(0.5, max(cfg.inner_tol, 0.25 * tol_abs / rnorm))
            d, st_in = solve32(r, tol_i, budget)
            stats.outer_refinements += 1
        else:
            # ---- fp64 fallback: finish the job in full precision -----------
            tol_i = min(0.5, max(0.5 * tol_abs / rnorm, 1e-14))
            d, st_in = solve64(r, tol_i, budget)
            stats.fp64_fallback = True
        stats.merge_inner(st_in)
        rprev, x_prev, r_prev = rnorm, x, r
        x, r, rn = _ir_accum(op.base, b, x, jnp.asarray(d))
        stats.matvecs += 1
        rnorm = float(rn)
        stats.host_syncs += 1      # outer residual norm
        stats.dispatches += 2      # _ir_accum + the d upcast transfer
        if not np.isfinite(rnorm) or rnorm > rprev:
            # fp32 overflow OR a diverging correction (finite but worse —
            # near-singular operators can blow up the inner solve): roll the
            # pass back so the next pass solves against the clean residual
            # instead of chasing the corrupted one with a tol scaled by it
            x, r, rnorm = x_prev, r_prev, rprev
        if hist is not None:
            hist.append(rnorm)
        if not (rnorm <= 0.5 * rprev):   # pass made no real progress
            if fallback or stats.fp64_fallback:
                break                    # fp64 cycles are stuck too — stop
            fallback = True              # fp32 stagnated → switch to fp64

    stats.converged = rnorm <= tol_abs
    stats.rel_residual = rnorm / bnorm
    stats.wall_time_s = time.perf_counter() - t0
    if hist is not None:
        stats.telemetry = KrylovTelemetry(res_hist=np.asarray(hist),
                                          kind="outer")
    return np.asarray(x), stats


def _gmres_solve_mixed(op: PreconditionedOp, b, cfg: KrylovConfig, x0=None,
                       use_kernel: bool = False):
    """fp64 iterative refinement over fp32 restarted-GMRES correction
    solves (`_ir_refine` with plain-GMRES callbacks)."""
    op32 = cast_operator(op, jnp.float32)

    def solve32(r, tol_i, budget):
        cfg_in = dataclasses.replace(cfg, inner_dtype="float64", tol=tol_i,
                                     maxiter=budget)
        return gmres_solve(op32, _downcast32(r), cfg_in,
                           use_kernel=use_kernel, stall_break=True)

    def solve64(r, tol_i, budget):
        cfg_in = dataclasses.replace(cfg, inner_dtype="float64", tol=tol_i,
                                     maxiter=budget)
        return gmres_solve(op, r, cfg_in, use_kernel=use_kernel)

    return _ir_refine(op, jnp.asarray(b), cfg, solve32, solve64, x0=x0)


def solve_gmres(problem_op, b_field, cfg: KrylovConfig, precond=None,
                use_kernel: bool = False):
    """Convenience wrapper over field-form problems (Stencil5 + (nx,ny) b)."""
    base = as_operator(problem_op, use_kernel=use_kernel)
    op = PreconditionedOp(base, precond)
    x, stats = gmres_solve(op, jnp.asarray(b_field).reshape(-1), cfg)
    return x.reshape(b_field.shape), stats
