"""Restarted GMRES — the paper's baseline (PETSc KSPGMRES semantics:
relative-residual tolerance, restart length m, right preconditioning so the
tracked residual is the true residual)."""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.solvers.arnoldi import arnoldi_cycle
from repro.solvers.hostlinalg import hessenberg_lstsq
from repro.solvers.operator import PreconditionedOp, apply_op, as_operator
from repro.solvers.types import KrylovConfig, SolveStats


@jax.jit
def _residual(op, b, z):
    return b - apply_op(op, z)


@jax.jit
def _fused_update(op, b, z, v, y):
    """z += Vᵀy (y zero-padded to the cycle width) + true residual — one
    dispatch instead of a host V copy + host matmul + residual dispatch."""
    z = z + v[:-1].T @ y
    r = b - apply_op(op, z)
    return z, r, jnp.linalg.norm(r)


def gmres_solve(op: PreconditionedOp, b, cfg: KrylovConfig, x0=None,
                use_kernel: bool = False):
    """Returns (x, SolveStats). `op` must be a PreconditionedOp; `b` flat."""
    t0 = time.perf_counter()
    n = int(b.shape[0])
    b = jnp.asarray(b)
    z = jnp.zeros(n, b.dtype) if x0 is None else jnp.asarray(x0)
    bnorm = float(jnp.linalg.norm(b))
    if bnorm == 0.0:
        return np.zeros(n), SolveStats(converged=True, rel_residual=0.0,
                                       wall_time_s=time.perf_counter() - t0)
    tol_abs = cfg.tol * bnorm
    r = _residual(op, b, z) if x0 is not None else b
    empty_c = jnp.zeros((0, n), b.dtype)

    stats = SolveStats()
    rnorm = float(jnp.linalg.norm(r))
    # Adaptive restart (anti-stagnation): restarted GMRES at a FIXED m can
    # stall on indefinite operators (Helmholtz) — the restart discards the
    # small-eigenvalue information every cycle. When a full cycle reduces the
    # residual by less than 2× we double m up to m_cap; each growth retraces
    # the jitted cycle once (new static shape), which converged runs never pay.
    m = cfg.m
    m_cap = min(n, cfg.m_max if cfg.m_max else 8 * cfg.m)
    while True:
        if rnorm <= tol_abs:
            stats.converged = True
            break
        if stats.iterations >= cfg.maxiter:
            break
        cyc = arnoldi_cycle(op, empty_c, r, tol_abs, m=m,
                            orthog=cfg.orthog, use_kernel=use_kernel)
        j = int(cyc.j_used)
        if j == 0:
            break  # stagnation
        h = np.asarray(cyc.h)[: j + 1, :j]
        y = np.zeros(m)
        y[:j] = hessenberg_lstsq(h, rnorm)
        rprev = rnorm
        z, r, rn = _fused_update(op, b, z, cyc.v, jnp.asarray(y))
        rnorm = float(rn)
        stats.iterations += j
        stats.matvecs += j + 1
        stats.cycles += 1
        stats.breakdown = bool(cyc.breakdown)
        if stats.breakdown and rnorm > tol_abs:
            break  # exact breakdown but not converged: stop honestly
        if j == m and rnorm > tol_abs and rnorm > 0.5 * rprev and m < m_cap:
            m = min(2 * m, m_cap)

    x = np.asarray(op.from_z(z))
    stats.rel_residual = rnorm / bnorm
    stats.wall_time_s = time.perf_counter() - t0
    return x, stats


def solve_gmres(problem_op, b_field, cfg: KrylovConfig, precond=None,
                use_kernel: bool = False):
    """Convenience wrapper over field-form problems (Stencil5 + (nx,ny) b)."""
    base = as_operator(problem_op, use_kernel=use_kernel)
    op = PreconditionedOp(base, precond)
    x, stats = gmres_solve(op, jnp.asarray(b_field).reshape(-1), cfg)
    return x.reshape(b_field.shape), stats
