"""GCRO-DR — Generalized Conjugate Residual with inner Orthogonalization and
Deflated Restarting (Parks et al. 2006; paper App. B.2 Algorithm 2), the
recycling engine of SKR.

The solver is STATEFUL across a sequence of systems: after system i it keeps
Ỹ_k = U_k (the approximate invariant subspace of the smallest harmonic Ritz
values) and re-biorthogonalizes it against A^(i+1) (Alg. 2 lines 2-7 /
App. B.1). GMRES is exactly the k=0 special case — asserted in tests.

Device/host split (§Perf iter 4): Arnoldi cycles AND all O(m·n) update
algebra run as fused jitted dispatches with PADDED static shapes (y, P, Q
zero-padded to the full cycle width, so early-exit cycles reuse the same
executable); only the O(m³) eigen/LS/QR cleanup runs on host — the same
split PETSc uses, but with ~4 device round-trips per cycle instead of ~15.

The padded static shapes are also what makes the fused steps below vmap
cleanly: `solvers/batched.py` lifts each of them over a leading chain axis
to advance B independent recycling chains in lockstep (App. E.2.2).

Precision policy: `cfg.inner_dtype="float32"` routes `solve` through an
fp64 outer iterative-refinement loop (`_solve_mixed`): every Arnoldi cycle,
preconditioner apply and recycle-space update runs in fp32 on the casted
operator while the operator/RHS of record — and the emitted labels — stay
fp64. The recycle carry U_k is STORED fp32 (half the checkpoint/HBM
footprint; it only seeds the next search space, accuracy is owned by the
outer loop). The fp64 default takes the historical code path unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs.telemetry import KrylovTelemetry
from repro.solvers.arnoldi import arnoldi_cycle
from repro.solvers.gmres import (_downcast32, _ir_refine, _residual_norms,
                                 gmres_solve)
from repro.solvers.hostlinalg import (harmonic_ritz_deflated,
                                      harmonic_ritz_first_cycle,
                                      hessenberg_lstsq, right_tri_solve)
from repro.solvers.operator import (PreconditionedOp, apply_op, as_operator,
                                    cast_operator)
from repro.solvers.types import KrylovConfig, SolveStats

_apply_cols = jax.jit(jax.vmap(apply_op, in_axes=(None, 1), out_axes=1))


# --------------------------------------------------------------------------
# fused device steps (shapes static per (n, m, k) — compiled once/sequence)
# --------------------------------------------------------------------------

@jax.jit
def _warm_start(u, au_q, z, r):
    """Alg. 2 lines 6-7 given Q from qr(A·U_old): project the initial
    residual onto range(C)ᶜ and absorb the correction into z."""
    ctr = au_q.T @ r
    z = z + u @ ctr
    r = r - au_q @ ctr
    return z, r, jnp.linalg.norm(r)


@jax.jit
def _fresh_update(op, b, z, v, y):
    """z += Vᵀy (y zero-padded to m); recompute the true residual."""
    z = z + v[:-1].T @ y
    r = b - apply_op(op, z)
    return z, r, jnp.linalg.norm(r)


@jax.jit
def _fresh_cu(v, h, p, q):
    """First recycle space: Ỹ = V P, C = V_{m+1} Q (P, Q zero-padded)."""
    yk = v[:-1].T @ p
    c = v.T @ q
    return c, yk


@jax.jit
def _rhs_and_dnorm(c, u, v, r):
    """Ŵᴴr pieces + ‖U columns‖ for the host-side LS solve."""
    return c.T @ r, v @ r, jnp.linalg.norm(u, axis=0)


@jax.jit
def _deflated_update(op, b, z, ut, v, y_k, y_m):
    """z += Û y_k + V y_m (zero-padded); true residual + Ŵᴴ V̂ pencil."""
    z = z + ut @ y_k + v[:-1].T @ y_m
    r = b - apply_op(op, z)
    # Ŵ = [C V_{m+1}] is produced by the caller as (c, v); the pencil
    # Ŵᴴ V̂ is assembled on host from these small blocks.
    return z, r, jnp.linalg.norm(r)


@jax.jit
def _whv_blocks(c, ut, v):
    """Small blocks of Ŵᴴ V̂: Ŵ = [c, Vrows], V̂ = [ut, Vrows[:-1]]."""
    cu = c.T @ ut                      # (k, k)
    cv = c.T @ v[:-1].T                # (k, m)
    vu = v @ ut                        # (m+1, k)
    vv = v @ v[:-1].T                  # (m+1, m)
    return cu, cv, vu, vv


@jax.jit
def _next_cu(ut, v, c, p_k, p_m, q_c, q_v):
    """C' = Ŵ Q, Ỹ = V̂ P from padded host factors."""
    yk = ut @ p_k + v[:-1].T @ p_m
    c_new = c @ q_c + v.T @ q_v
    return c_new, yk


class GCRODRSolver:
    """Sequence-stateful GCRO-DR. One instance per sorted sequence.

    Usage:
        solver = GCRODRSolver(cfg)
        for problem in sorted_sequence:
            x, stats = solver.solve(op_i, b_i)
    """

    def __init__(self, cfg: KrylovConfig, use_kernel: bool = False,
                 stall_break: bool = False):
        self.cfg = cfg
        self.use_kernel = use_kernel
        # stall_break: break out of no-progress cycles instead of spinning to
        # maxiter — set by the mixed-precision outer loop on its inner fp32
        # solvers, where hitting the fp32 round-off floor is an expected exit
        self.stall_break = stall_break
        self.u_carry: np.ndarray | None = None  # (n, k) recycle space
        self.systems_solved = 0
        self._inner: GCRODRSolver | None = None   # fp32 correction solver
        self._inner64: GCRODRSolver | None = None  # fp64 fallback solver

    # -- resumable-datagen support (core/skr.py checkpoints this) --------
    def state_dict(self) -> dict:
        return {"u_carry": self.u_carry, "systems_solved": self.systems_solved}

    def load_state_dict(self, state: dict):
        self.u_carry = state["u_carry"]
        self.systems_solved = int(state["systems_solved"])

    def reset(self):
        self.u_carry = None
        self.systems_solved = 0
        self._inner = None
        self._inner64 = None

    # --------------------------------------------------------------------
    def _refresh_space(self, last_cycle, k: int, mi: int, stats=None):
        """Harmonic-Ritz recycle-space refresh from a deflated cycle
        (Alg. 2 lines 29-33). Returns (C', U') or None on rank trouble."""
        j, g, ut, cyc, c_dev = last_cycle
        if stats is not None:  # 4 block pulls + _whv_blocks/_next_cu launches
            stats.host_syncs += 4
            stats.dispatches += 2
        cu, cv, vu, vv = [np.asarray(a)
                          for a in _whv_blocks(c_dev, ut, cyc.v)]
        whv = np.zeros((k + j + 1, k + j))
        whv[:k, :k] = cu
        whv[:k, k:] = cv[:, :j]
        whv[k:, :k] = vu[: j + 1]
        whv[k:, k:] = vv[: j + 1, :j]
        p = harmonic_ritz_deflated(g, whv, k)
        if p.shape[1] != k:
            return None
        q, rr = np.linalg.qr(g @ p)
        diag = np.abs(np.diag(rr))
        if diag.min() <= 1e-12 * max(diag.max(), 1e-300):
            return None
        # host factors ship in the DEVICE dtype (fp32 inner cycles must not
        # silently re-widen the recycle space; f64 path: no-op casts)
        dt = ut.dtype
        p_m = np.zeros((mi, k))
        p_m[:j] = p[k:]
        q_v = np.zeros((mi + 1, k))
        q_v[: j + 1] = q[k:]
        c_new, yk = _next_cu(ut, cyc.v, c_dev,
                             jnp.asarray(p[:k], dt), jnp.asarray(p_m, dt),
                             jnp.asarray(q[:k], dt), jnp.asarray(q_v, dt))
        return c_new, yk @ jnp.asarray(np.linalg.inv(rr), dt)

    def _solve_mixed(self, op: PreconditionedOp, b, x0=None):
        """fp64 iterative refinement over fp32 GCRO-DR correction solves
        (`_ir_refine` with recycling callbacks).

        The fp32 inner solver keeps the sequence-stateful recycle carry —
        in fp32, across passes AND across systems; an fp64-fallback pass
        borrows the carry upcast and hands its refreshed space back
        downcast, so the chain survives precision switches.
        """
        cfg = self.cfg
        op32 = cast_operator(op, jnp.float32)
        if self._inner is None:
            self._inner = GCRODRSolver(cfg, use_kernel=self.use_kernel,
                                       stall_break=True)
        inner = self._inner
        # the carry rides the PUBLIC u_carry (checkpointed by core/skr.py),
        # STORED fp32 — downcast whatever precision last produced it
        inner.u_carry = (np.asarray(self.u_carry, np.float32)
                         if self.u_carry is not None else None)

        def solve32(r, tol_i, budget):
            inner.cfg = dataclasses.replace(cfg, inner_dtype="float64",
                                            tol=tol_i, maxiter=budget)
            return inner.solve(op32, _downcast32(r))

        def solve64(r, tol_i, budget):
            if self._inner64 is None:
                self._inner64 = GCRODRSolver(cfg, use_kernel=self.use_kernel)
            self._inner64.cfg = dataclasses.replace(
                cfg, inner_dtype="float64", tol=tol_i, maxiter=budget)
            self._inner64.u_carry = (np.asarray(inner.u_carry, np.float64)
                                     if inner.u_carry is not None else None)
            d, st_in = self._inner64.solve(op, r)
            if self._inner64.u_carry is not None:
                inner.u_carry = np.asarray(self._inner64.u_carry, np.float32)
            return d, st_in

        x, stats = _ir_refine(op, jnp.asarray(b), cfg, solve32, solve64,
                              x0=x0)
        if inner.u_carry is not None:
            self.u_carry = np.asarray(inner.u_carry, np.float32)
        self.systems_solved += 1
        return x, stats

    def solve(self, op: PreconditionedOp, b, x0=None):
        cfg = self.cfg
        if cfg.k == 0:
            x, stats = gmres_solve(op, b, cfg, x0=x0,
                                   use_kernel=self.use_kernel,
                                   stall_break=self.stall_break)
            self.systems_solved += 1
            return x, stats
        if cfg.inner_dtype == "float32":
            return self._solve_mixed(op, b, x0=x0)

        t0 = time.perf_counter()
        n = int(b.shape[0])
        b = jnp.asarray(b)
        z = jnp.zeros(n, b.dtype) if x0 is None else jnp.asarray(x0)
        stats = SolveStats()
        if x0 is None:
            r = b
            bnorm = rnorm = float(jnp.linalg.norm(b))  # ONE host sync
            stats.host_syncs += 1
            stats.dispatches += 1
        else:
            r, bn_d, rn_d = _residual_norms(op, b, z)  # one fused dispatch
            bnorm, rnorm = (float(v) for v in jax.device_get((bn_d, rn_d)))
            stats.host_syncs += 1
            stats.dispatches += 1
        if bnorm == 0.0:
            stats.converged = True
            stats.rel_residual = 0.0
            stats.wall_time_s = time.perf_counter() - t0
            self.systems_solved += 1
            return np.zeros(n), stats
        tol_abs = cfg.tol * bnorm

        c_dev = None  # (n, k) device
        u_dev = None
        k = cfg.k

        # ---- warm start: re-biorthogonalize the carried recycle space ----
        if self.u_carry is not None and self.u_carry.shape[1] == k \
                and rnorm > tol_abs:
            u_old = jnp.asarray(self.u_carry)
            au = _apply_cols(op, u_old)                      # (n, k)
            stats.matvecs += k
            q, rr = jnp.linalg.qr(au)                        # reduced QR
            rr_np = np.asarray(rr)
            stats.host_syncs += 1          # R factor pull
            stats.dispatches += 2          # _apply_cols + qr
            diag = np.abs(np.diag(rr_np))
            if diag.min() > 1e-12 * max(diag.max(), 1e-300):
                c_dev = q
                u_dev = u_old @ jnp.asarray(
                    np.linalg.inv(rr_np))                    # U R⁻¹
                z, r, rn = _warm_start(u_dev, c_dev, z, r)
                rnorm = float(rn)
                stats.host_syncs += 1
                stats.dispatches += 1

        empty_c = jnp.zeros((0, n), b.dtype)
        dt = b.dtype        # host factors ship back in the device dtype
        last_cycle = None   # (j, g, ut, cyc, c) of the latest deflated cycle
        no_prog = 0         # consecutive no-progress cycles (stall_break)
        # per-cycle convergence telemetry is FREE here: the sequential
        # driver already pulls rnorm to host every cycle (contrast the
        # lockstep engine's device rings in solvers/batched.py)
        hist = [] if obs.enabled() else None
        dims = [] if hist is not None else None

        while True:
            if rnorm <= tol_abs:
                stats.converged = True
                break
            if stats.iterations >= cfg.maxiter:
                break
            if self.stall_break and no_prog >= 3:
                break  # round-off floor — hand back to the outer IR loop
            rprev = rnorm

            if c_dev is None:
                # ---- fresh GMRES(m) cycle + first recycle space (l.9-18) --
                m = cfg.m
                cyc = arnoldi_cycle(op, empty_c, r, tol_abs, m=m,
                                    orthog=cfg.orthog, use_kernel=self.use_kernel,
                                    h_acc=cfg.cgs2_acc)
                j = int(cyc.j_used)
                stats.host_syncs += 2      # j_used + Hessenberg pull
                stats.dispatches += 1      # arnoldi_cycle
                if j == 0:
                    break
                h = np.asarray(cyc.h)                       # (m+1, m) small
                y = np.zeros(m, dtype=h.dtype)
                y[:j] = hessenberg_lstsq(h[: j + 1, :j], rnorm)
                z, r, rn = _fresh_update(op, b, z, cyc.v, jnp.asarray(y))
                rnorm = float(rn)
                stats.host_syncs += 1
                stats.dispatches += 1
                stats.iterations += j
                stats.matvecs += j + 1
                stats.cycles += 1
                no_prog = no_prog + 1 if rnorm > 0.99 * rprev else 0
                k_eff = min(k, j - 1)
                if k_eff >= 1:
                    p = harmonic_ritz_first_cycle(h, j, k_eff)
                    if p.shape[1] == k:
                        q, rr = np.linalg.qr(h[: j + 1, :j] @ p)
                        diag = np.abs(np.diag(rr))
                        if diag.min() > 1e-12 * max(diag.max(), 1e-300):
                            p_pad = np.zeros((m, k), dtype=h.dtype)
                            p_pad[:j] = p
                            q_pad = np.zeros((m + 1, k), dtype=h.dtype)
                            q_pad[: j + 1] = q
                            stats.dispatches += 1
                            c_dev, yk = _fresh_cu(cyc.v, cyc.h,
                                                  jnp.asarray(p_pad),
                                                  jnp.asarray(q_pad))
                            u_dev = yk @ jnp.asarray(np.linalg.inv(rr), dt)
                if hist is not None:
                    hist.append(rnorm)
                    dims.append(k if c_dev is not None else 0)
                continue

            # ---- deflated cycle (Alg. 2 lines 19-33) ----------------------
            mi = cfg.m - k
            cyc = arnoldi_cycle(op, c_dev.T, r, tol_abs, m=mi,
                                orthog=cfg.orthog, use_kernel=self.use_kernel,
                                h_acc=cfg.cgs2_acc)
            j = int(cyc.j_used)
            stats.host_syncs += 1
            stats.dispatches += 1          # arnoldi_cycle
            if j == 0:
                break
            ctr, vr, dnorm = _rhs_and_dnorm(c_dev, u_dev, cyc.v, r)
            stats.host_syncs += 5          # h, b, dnorm, ctr, vr pulls
            stats.dispatches += 1
            h = np.asarray(cyc.h)[: j + 1, :j]               # effective block
            bb = np.asarray(cyc.b)[:, :j]
            dnorm_np = np.maximum(np.asarray(dnorm, np.float64), 1e-300)
            ut = u_dev / dnorm                               # device Ũ_k

            # host pencil at the EFFECTIVE width j (padded columns would
            # feed spurious θ≈0 null directions to the harmonic-Ritz eig);
            # host LS runs in f64 regardless — factors ship back in dt
            g = np.zeros((k + j + 1, k + j))
            g[:k, :k] = np.diag(1.0 / dnorm_np)
            g[:k, k:] = bb
            g[k:, k:] = h
            rhs = np.concatenate([np.asarray(ctr),
                                  np.asarray(vr)[: j + 1]])
            y, *_ = np.linalg.lstsq(g, rhs, rcond=None)
            y_m = np.zeros(mi)
            y_m[:j] = y[k:]

            z, r, rn = _deflated_update(op, b, z, ut, cyc.v,
                                        jnp.asarray(y[:k], dt),
                                        jnp.asarray(y_m, dt))
            rnorm = float(rn)
            stats.host_syncs += 2          # rn + breakdown flag below
            stats.dispatches += 1          # _deflated_update
            stats.iterations += j
            stats.matvecs += j + 1
            stats.cycles += 1
            no_prog = no_prog + 1 if rnorm > 0.99 * rprev else 0

            # next recycle space from the harmonic Ritz pencil — either
            # every cycle (paper-faithful) or deferred to the last cycle
            last_cycle = (j, g, ut, cyc, c_dev)
            if cfg.ritz_refresh == "cycle":
                refreshed = self._refresh_space(last_cycle, k, mi, stats)
                if refreshed is not None:
                    c_dev, u_dev = refreshed
            if hist is not None:
                hist.append(rnorm)
                dims.append(k)
            if bool(cyc.breakdown) and rnorm > tol_abs:
                break

        if cfg.ritz_refresh == "final" and last_cycle is not None:
            refreshed = self._refresh_space(last_cycle, k, cfg.m - k, stats)
            if refreshed is not None:
                _, u_dev = refreshed

        x = np.asarray(op.from_z(z))
        stats.host_syncs += 1
        stats.dispatches += 1
        stats.rel_residual = rnorm / bnorm
        stats.wall_time_s = time.perf_counter() - t0
        if hist is not None:
            stats.telemetry = KrylovTelemetry(
                res_hist=np.asarray(hist),
                defl_dim=np.asarray(dims, np.int32))
        # carry Ỹ_k = U_k to the next system (Alg. 2 line 34)
        if u_dev is not None:
            self.u_carry = np.asarray(u_dev)
            stats.host_syncs += 1
        self.systems_solved += 1
        return x, stats


def solve_gcrodr(problem_op, b_field, cfg: KrylovConfig, precond=None,
                 solver: GCRODRSolver | None = None, use_kernel: bool = False):
    """Field-form convenience wrapper; pass a shared `solver` to recycle."""
    solver = solver or GCRODRSolver(cfg, use_kernel=use_kernel)
    base = as_operator(problem_op, use_kernel=use_kernel)
    op = PreconditionedOp(base, precond)
    x, stats = solver.solve(op, jnp.asarray(b_field).reshape(-1))
    return x.reshape(b_field.shape), stats, solver
