"""Shared solver types and stats (the paper's two metrics: wall time and
iteration count, tracked per system and per sequence)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class SolveStats:
    iterations: int = 0       # Krylov (Arnoldi) steps — the paper's "iter"
    matvecs: int = 0          # total operator applications (incl. recycle QR)
    cycles: int = 0           # restart cycles
    converged: bool = False
    rel_residual: float = np.inf
    wall_time_s: float = 0.0
    breakdown: bool = False
    # mixed-precision accounting (inner_dtype="float32" runs only):
    outer_refinements: int = 0  # fp64 iterative-refinement passes taken
    fp64_fallback: bool = False  # fp32 cycles stagnated → finished in fp64
    # lockstep-engine padding accounting: True marks a zero-RHS padding row
    # (shorter chunk / sharding fill / phase-masked finished chain) — it
    # costs nothing (0 iterations, wall_time_s = 0.0) and is EXCLUDED from
    # SequenceStats aggregates so iteration/time totals compare cleanly
    # across engines
    padded: bool = False
    # adaptive-Δt accounting: True marks a solve whose step the error
    # controller REJECTED — real work (kept in every aggregate; the cycles
    # also updated the recycle carry, which is what makes the retry cheap),
    # flagged so accepted-step efficiency can be derived
    rejected: bool = False
    # dispatch-overhead accounting: every host↔device boundary the solver
    # crossed for THIS system. `host_syncs` counts blocking device→host
    # fetches (`device_get` / `np.asarray` on a device array / `float(...)`
    # of a device scalar); `dispatches` counts jitted device programs
    # launched. Lockstep engines report the SHARED batch totals on every
    # non-padded chain (like wall_time_s) — the per-cycle sync budget is
    # the claim the trajectory_recycle benchmark tracks.
    host_syncs: int = 0
    dispatches: int = 0
    # failure-containment accounting (core/robust.py): `retries` counts
    # escalation-ladder attempts taken before this record's solve settled;
    # `escalation_path` names the rungs, in order (e.g. ("drop_carry",
    # "grow_m")); `quarantined=True` marks a solve whose ladder was
    # exhausted without a converged finite solution — the label is NOT
    # trustworthy (strict_labels decides whether it ships flagged or is
    # excluded). The lockstep engine also sets `quarantined` on chains its
    # in-dispatch divergence guard masked out mid-solve; the pipeline then
    # requeues those systems and REPLACES the record.
    retries: int = 0
    quarantined: bool = False
    escalation_path: tuple = ()
    # convergence telemetry (observability runs only): a
    # `repro.obs.KrylovTelemetry` with this system's per-cycle residual /
    # stall / deflation-dimension history. None whenever `repro.obs` is
    # disabled — typed as object so the stats layer stays import-free of
    # the obs package.
    telemetry: Optional[object] = None

    def merge_inner(self, other: "SolveStats"):
        """Fold an inner (correction-solve) pass into this outer record."""
        self.iterations += other.iterations
        self.matvecs += other.matvecs
        self.cycles += other.cycles
        self.host_syncs += other.host_syncs
        self.dispatches += other.dispatches


@dataclasses.dataclass
class SequenceStats:
    """Aggregates over a sorted sequence of systems (one dataset).

    Zero-RHS padding rows emitted by the lockstep engines (`padded=True`)
    are kept in `per_system` for auditability but excluded from every
    aggregate — a padded slot solved nothing, so counting it would skew
    per-system means when comparing engines with different padding."""

    per_system: List[SolveStats] = dataclasses.field(default_factory=list)

    def append(self, s: SolveStats):
        self.per_system.append(s)

    @property
    def solved(self) -> List[SolveStats]:
        """Real (non-padding) solves — the aggregation population."""
        return [s for s in self.per_system if not s.padded]

    @property
    def num(self) -> int:
        return len(self.solved)

    @property
    def num_padded(self) -> int:
        return len(self.per_system) - self.num

    @property
    def total_iterations(self) -> int:
        return int(sum(s.iterations for s in self.solved))

    @property
    def mean_iterations(self) -> float:
        return self.total_iterations / max(1, self.num)

    @property
    def total_time_s(self) -> float:
        return float(sum(s.wall_time_s for s in self.solved))

    @property
    def mean_time_s(self) -> float:
        return self.total_time_s / max(1, self.num)

    @property
    def num_converged(self) -> int:
        return int(sum(s.converged for s in self.solved))

    @property
    def num_hit_maxiter(self) -> int:
        return self.num - self.num_converged

    @property
    def num_rejected(self) -> int:
        """Adaptive-Δt solves the error controller rejected (real work,
        included in iteration/time totals)."""
        return int(sum(s.rejected for s in self.solved))

    @property
    def total_outer_refinements(self) -> int:
        """Mixed-precision fp64 refinement passes, REAL solves only — a
        padded row never runs an outer pass, and the engines guarantee it
        (padding is excluded from the refinement loop), so excluding
        padded rows here cannot double-count."""
        return int(sum(s.outer_refinements for s in self.solved))

    @property
    def num_fp64_fallback(self) -> int:
        """Real solves that fell back to fp64 correction cycles."""
        return int(sum(s.fp64_fallback for s in self.solved))

    @property
    def total_host_syncs(self) -> int:
        """Blocking device→host fetches across the sequence (lockstep
        chains share each batch's count, so this over-counts shared syncs
        by the chain multiplicity — divide by chains-per-batch for the
        per-dispatch-stream number, or read `mean_host_syncs`)."""
        return int(sum(s.host_syncs for s in self.solved))

    @property
    def mean_host_syncs(self) -> float:
        return self.total_host_syncs / max(1, self.num)

    @property
    def total_dispatches(self) -> int:
        return int(sum(s.dispatches for s in self.solved))

    # ------------------------------------------------ health aggregates
    @property
    def num_quarantined(self) -> int:
        return int(sum(s.quarantined for s in self.solved))

    @property
    def num_retried(self) -> int:
        """Solves that walked at least one escalation-ladder rung."""
        return int(sum(s.retries > 0 for s in self.solved))

    @property
    def total_retries(self) -> int:
        return int(sum(s.retries for s in self.solved))

    @property
    def num_recovered(self) -> int:
        """Retried solves that still converged — the ladder paid off."""
        return int(sum(s.retries > 0 and s.converged for s in self.solved))

    @property
    def label_quality(self) -> float:
        """Fraction of real solves whose label is trustworthy (converged,
        finite residual, not quarantined) — the signal `strict_labels`
        acts on and the obs layer exports as a gauge."""
        good = sum(s.converged and not s.quarantined
                   and np.isfinite(s.rel_residual) for s in self.solved)
        return good / max(1, self.num)

    def escalation_counts(self) -> dict:
        """How often each ladder rung was taken across the sequence."""
        out: dict = {}
        for s in self.solved:
            for rung in s.escalation_path:
                out[rung] = out.get(rung, 0) + 1
        return out

    @property
    def utilization(self) -> float:
        """Live fraction of all lockstep rows this sequence dispatched
        (1.0 for engines that never pad). The per-sequence twin of
        `obs.Registry.utilization()` — derivable from stats alone, so the
        regression gate can enforce a floor without observability on."""
        total = len(self.per_system)
        return self.num / total if total > 0 else 1.0

    def summary(self) -> dict:
        out = {
            "num": self.num,
            "mean_iterations": self.mean_iterations,
            "mean_time_s": self.mean_time_s,
            "total_time_s": self.total_time_s,
            "converged": self.num_converged,
            "hit_maxiter": self.num_hit_maxiter,
            "padded": self.num_padded,
            "rejected": self.num_rejected,
            "outer_refinements": self.total_outer_refinements,
            "fp64_fallback": self.num_fp64_fallback,
            "host_syncs": self.total_host_syncs,
            "mean_host_syncs": self.mean_host_syncs,
            "dispatches": self.total_dispatches,
            "utilization": self.utilization,
            # containment surfacing (core/robust.py): retry/quarantine
            # counts and the per-rung escalation tally, always present so
            # consumers need not special-case fault-free runs
            "health": {
                "healthy": int(sum(not s.quarantined and s.retries == 0
                                   for s in self.solved)),
                "recovered": self.num_recovered,
                "quarantined": self.num_quarantined,
                "failed": int(sum(
                    s.quarantined and not np.isfinite(s.rel_residual)
                    for s in self.solved)),
                "retries": self.total_retries,
                "escalations": self.escalation_counts(),
                "label_quality": self.label_quality,
            },
        }
        # merge the live telemetry registry (occupancy counters, imbalance
        # gauges) when observability is on; a late import keeps the stats
        # layer usable without the obs package on the path
        from repro import obs
        if obs.enabled():
            out["obs"] = obs.summary()
        return out


@dataclasses.dataclass(frozen=True)
class KrylovConfig:
    """Shared GMRES / GCRO-DR configuration.

    m        : max Krylov subspace per cycle (GMRES restart length; GCRO-DR
               uses k recycled + (m-k) new directions — same peak memory)
    m_max    : restart-growth cap for plain GMRES (k=0): when a cycle's
               residual reduction stalls (restarted GMRES on indefinite
               operators, e.g. Helmholtz, can stagnate at any fixed m), the
               restart length doubles up to min(m_max, n). 0 = auto
               (8·m); set m_max = m to pin the classic fixed-restart method.
    k        : recycled-subspace dimension (GCRO-DR only; k=0 ≡ GMRES)
    tol      : relative residual tolerance (PETSc rtol semantics)
    maxiter  : cap on total Krylov iterations per system
    orthog   : "cgs2" (TPU-native fused two-pass classical GS, DESIGN §4.4)
               | "mgs" (paper-faithful modified GS)
    ritz_refresh : "cycle" — recompute the harmonic-Ritz recycle space every
               deflated cycle (paper-faithful GCRO-DR, Alg. 2 l.29-33);
               "final" — only once per system, from its last cycle (beyond-
               paper: drops the per-cycle O(m³) host eig + 2 device round
               trips; EXPERIMENTS.md §Perf iter 4)

    Precision policy (the mixed-precision axis; see README "Precision
    policy"):

    inner_dtype : "float64" (paper-parity default — every Arnoldi cycle,
               preconditioner apply and recycle-space update runs in fp64,
               the exact historical path) | "float32" — the inner Krylov
               machinery runs in fp32 while the operator/RHS of record stay
               fp64: an fp64 outer iterative-refinement loop downcasts the
               current TRUE residual, solves the correction system A·d = r
               in fp32 to `inner_tol`, accumulates x += d in fp64 and
               recomputes the true fp64 residual until `tol` (classic
               inexact-Krylov/IR; the recycled U_k only seeds the search
               space, so accuracy is owned by the outer loop and dataset
               labels stay at fp64 tolerance).
    inner_tol : relative residual reduction target of ONE fp32 correction
               solve (per outer pass). The outer residual contracts by
               ~max(inner_tol, κ·eps_f32) per pass.
    ir_max_outer : cap on fp32 refinement passes per system; exceeded (or a
               pass reduces the residual by < 2×) → the solver falls back to
               fp64 correction cycles, guarding against fp32 stagnation.
    cgs2_acc : "native" — CGS2 accumulates h in the basis dtype (fp32 inner
               cycles accumulate in fp32) | "float64" — fp32 storage with
               fp64 accumulation in the fused orthogonalization (robustness
               knob for ill-scaled bases).
    """

    m: int = 40
    k: int = 15
    tol: float = 1e-8
    maxiter: int = 10_000
    orthog: str = "cgs2"
    ritz_refresh: str = "cycle"
    m_max: int = 0
    inner_dtype: str = "float64"
    inner_tol: float = 1e-4
    ir_max_outer: int = 10
    cgs2_acc: str = "native"

    def __post_init__(self):
        assert 0 <= self.k < self.m, "need 0 <= k < m"
        assert self.orthog in ("cgs2", "mgs")
        assert self.ritz_refresh in ("cycle", "final")
        assert self.m_max == 0 or self.m_max >= self.m, "need m_max >= m"
        assert self.inner_dtype in ("float64", "float32")
        assert 0.0 < self.inner_tol < 1.0, "inner_tol is a relative reduction"
        assert self.ir_max_outer >= 1
        assert self.cgs2_acc in ("native", "float64")
