"""Shared solver types and stats (the paper's two metrics: wall time and
iteration count, tracked per system and per sequence)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class SolveStats:
    iterations: int = 0       # Krylov (Arnoldi) steps — the paper's "iter"
    matvecs: int = 0          # total operator applications (incl. recycle QR)
    cycles: int = 0           # restart cycles
    converged: bool = False
    rel_residual: float = np.inf
    wall_time_s: float = 0.0
    breakdown: bool = False


@dataclasses.dataclass
class SequenceStats:
    """Aggregates over a sorted sequence of systems (one dataset)."""

    per_system: List[SolveStats] = dataclasses.field(default_factory=list)

    def append(self, s: SolveStats):
        self.per_system.append(s)

    @property
    def num(self) -> int:
        return len(self.per_system)

    @property
    def total_iterations(self) -> int:
        return int(sum(s.iterations for s in self.per_system))

    @property
    def mean_iterations(self) -> float:
        return self.total_iterations / max(1, self.num)

    @property
    def total_time_s(self) -> float:
        return float(sum(s.wall_time_s for s in self.per_system))

    @property
    def mean_time_s(self) -> float:
        return self.total_time_s / max(1, self.num)

    @property
    def num_converged(self) -> int:
        return int(sum(s.converged for s in self.per_system))

    @property
    def num_hit_maxiter(self) -> int:
        return self.num - self.num_converged

    def summary(self) -> dict:
        return {
            "num": self.num,
            "mean_iterations": self.mean_iterations,
            "mean_time_s": self.mean_time_s,
            "total_time_s": self.total_time_s,
            "converged": self.num_converged,
            "hit_maxiter": self.num_hit_maxiter,
        }


@dataclasses.dataclass(frozen=True)
class KrylovConfig:
    """Shared GMRES / GCRO-DR configuration.

    m        : max Krylov subspace per cycle (GMRES restart length; GCRO-DR
               uses k recycled + (m-k) new directions — same peak memory)
    m_max    : restart-growth cap for plain GMRES (k=0): when a cycle's
               residual reduction stalls (restarted GMRES on indefinite
               operators, e.g. Helmholtz, can stagnate at any fixed m), the
               restart length doubles up to min(m_max, n). 0 = auto
               (8·m); set m_max = m to pin the classic fixed-restart method.
    k        : recycled-subspace dimension (GCRO-DR only; k=0 ≡ GMRES)
    tol      : relative residual tolerance (PETSc rtol semantics)
    maxiter  : cap on total Krylov iterations per system
    orthog   : "cgs2" (TPU-native fused two-pass classical GS, DESIGN §4.4)
               | "mgs" (paper-faithful modified GS)
    ritz_refresh : "cycle" — recompute the harmonic-Ritz recycle space every
               deflated cycle (paper-faithful GCRO-DR, Alg. 2 l.29-33);
               "final" — only once per system, from its last cycle (beyond-
               paper: drops the per-cycle O(m³) host eig + 2 device round
               trips; EXPERIMENTS.md §Perf iter 4)
    """

    m: int = 40
    k: int = 15
    tol: float = 1e-8
    maxiter: int = 10_000
    orthog: str = "cgs2"
    ritz_refresh: str = "cycle"
    m_max: int = 0

    def __post_init__(self):
        assert 0 <= self.k < self.m, "need 0 <= k < m"
        assert self.orthog in ("cgs2", "mgs")
        assert self.ritz_refresh in ("cycle", "final")
        assert self.m_max == 0 or self.m_max >= self.m, "need m_max >= m"
