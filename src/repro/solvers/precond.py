"""Preconditioners (paper §6.1 "Matrix preconditioning techniques").

TPU adaptation (DESIGN §4.2): ILU/ICC/SOR triangular solves are sequential
and hostile to TPU; the device-native set here is
  none | jacobi | bjacobi (line/tridiagonal blocks — batched dense inverses)
  | rbsor (red-black SSOR: parallel colored sweeps, stencil-only)
  | neumann | cheby (polynomial preconditioners — pure matvec chains)
plus `ilu_host` (scipy spilu behind a pure_callback) retained ONLY for paper-
parity CPU benchmarks. All device preconditioners are pytrees so the jitted
Arnoldi cycle retraces once per family, not per system.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.pde.dia import DIA, Stencil5

# ---------------------------------------------------------------- pytrees


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class JacobiPrecond:
    inv_diag: jax.Array  # (n,)

    def tree_flatten(self):
        return (self.inv_diag,), None

    @classmethod
    def tree_unflatten(cls, _, ch):
        return cls(*ch)

    def apply(self, v):
        return self.inv_diag * v


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockJacobiPrecond:
    """Line relaxation: one tridiagonal block per grid row, stored as batched
    dense inverses → the apply is ONE batched matmul (MXU-shaped)."""

    inv_blocks: jax.Array  # (nb, bs, bs)

    def tree_flatten(self):
        return (self.inv_blocks,), None

    @classmethod
    def tree_unflatten(cls, _, ch):
        return cls(*ch)

    def apply(self, v):
        nb, bs, _ = self.inv_blocks.shape
        return jnp.einsum("bij,bj->bi", self.inv_blocks, v.reshape(nb, bs)).reshape(-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class NeumannPrecond:
    """Truncated damped Neumann series on the Jacobi-scaled operator:
    M⁻¹v = ω Σ_{i<d} (I − ω D⁻¹A)^i D⁻¹ v."""

    op: object         # StencilOp | DIAOp (unpreconditioned base)
    inv_diag: jax.Array
    omega: jax.Array   # scalar damping
    degree: int = 4    # static

    def tree_flatten(self):
        return (self.op, self.inv_diag, self.omega), self.degree

    @classmethod
    def tree_unflatten(cls, degree, ch):
        return cls(op=ch[0], inv_diag=ch[1], omega=ch[2], degree=degree)

    def apply(self, v):
        z = self.omega * (self.inv_diag * v)
        acc = z
        for _ in range(self.degree - 1):
            z = z - self.omega * (self.inv_diag * self.op.apply(z))
            acc = acc + z
        # acc = Σ (I-ωD⁻¹A)^i ωD⁻¹ v via the recurrence z_{i+1} = (I-ωD⁻¹A) z_i
        return acc


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ChebyshevPrecond:
    """Chebyshev polynomial preconditioner on [lmin, lmax] of D⁻¹A (SPD-ish
    families; the classic TPU-friendly SOR/ILU substitute)."""

    op: object
    inv_diag: jax.Array
    lmin: jax.Array
    lmax: jax.Array
    degree: int = 4

    def tree_flatten(self):
        return (self.op, self.inv_diag, self.lmin, self.lmax), self.degree

    @classmethod
    def tree_unflatten(cls, degree, ch):
        return cls(ch[0], ch[1], ch[2], ch[3], degree)

    def apply(self, v):
        # Chebyshev iteration (Saad, Alg. 12.1) solving D⁻¹A z = D⁻¹ v, z₀=0.
        theta = 0.5 * (self.lmax + self.lmin)
        delta = 0.5 * (self.lmax - self.lmin)
        sigma1 = theta / delta
        s = lambda z: self.inv_diag * self.op.apply(z)
        rhs = self.inv_diag * v
        rho = 1.0 / sigma1
        d = rhs / theta
        z = d
        for _ in range(self.degree - 1):
            r = rhs - s(z)
            rho_new = 1.0 / (2.0 * sigma1 - rho)
            d = (rho_new * rho) * d + (2.0 * rho_new / delta) * r
            z = z + d
            rho = rho_new
        return z


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RBSORPrecond:
    """Red-black SSOR on the 5-point stencil: colored Gauss-Seidel sweeps are
    fully data-parallel (each color updates simultaneously) — the TPU-native
    formulation of the paper's SOR column."""

    coeffs: jax.Array   # (5, nx, ny) stencil
    red: jax.Array      # (nx, ny) float {0,1} checkerboard
    omega: jax.Array
    sweeps: int = 1

    def tree_flatten(self):
        return (self.coeffs, self.red, self.omega), self.sweeps

    @classmethod
    def tree_unflatten(cls, sweeps, ch):
        return cls(ch[0], ch[1], ch[2], sweeps)

    def apply(self, v):
        from repro.kernels import ref

        nx, ny = self.coeffs.shape[-2:]
        f = v.reshape(nx, ny)
        diag = self.coeffs[0]
        z = jnp.zeros_like(f)
        colors_fwd = (self.red, 1.0 - self.red)
        for _ in range(self.sweeps):
            for color in colors_fwd + colors_fwd[::-1]:  # symmetric sweep
                resid = f - ref.stencil5_matvec(self.coeffs, z)
                z = z + self.omega * color * resid / diag
        return z.reshape(-1)


# Host-side preconditioners (CPU paper-parity only). The callback reads a
# module-level slot so the jitted cycle traces ONCE; benchmarks swap the slot
# between systems (documented impurity — never used in the device paths).
_HOST_PRECOND_SLOT: dict = {"fn": None}


def set_host_precond(fn: Optional[Callable[[np.ndarray], np.ndarray]]):
    _HOST_PRECOND_SLOT["fn"] = fn


def _host_apply(v: np.ndarray) -> np.ndarray:
    fn = _HOST_PRECOND_SLOT["fn"]
    return np.asarray(fn(np.asarray(v)), dtype=v.dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class HostPrecond:
    n: int  # static

    def tree_flatten(self):
        return (), self.n

    @classmethod
    def tree_unflatten(cls, n, _):
        return cls(n)

    def apply(self, v):
        return jax.pure_callback(
            _host_apply, jax.ShapeDtypeStruct((self.n,), v.dtype), v,
            vmap_method="sequential")


# ---------------------------------------------------------------- factory


def _power_lmax(op, inv_diag, n, iters=20, seed=0):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal(n)
    v /= np.linalg.norm(v)
    lam = 1.0
    for _ in range(iters):
        w = np.asarray(op.apply(jnp.asarray(inv_diag) * jnp.asarray(v)))
        lam = float(np.linalg.norm(w))
        v = w / max(lam, 1e-30)
    return lam


def make_preconditioner(name: str, problem_op, *, omega: float = 1.0,
                        degree: int = 4, sweeps: int = 1, use_kernel: bool = False):
    """Build a preconditioner pytree for a Stencil5 | DIA operator."""
    from repro.solvers.operator import as_operator

    name = name.lower()
    if name in ("none", "identity"):
        return None

    base = as_operator(problem_op, use_kernel=use_kernel)
    if isinstance(problem_op, Stencil5):
        diag = problem_op.coeffs[Stencil5.C].reshape(-1)
    else:
        diag = problem_op.diagonal()
    inv_diag = 1.0 / diag

    if name == "jacobi":
        return JacobiPrecond(inv_diag)

    if name == "bjacobi":
        if isinstance(problem_op, Stencil5):
            c = np.asarray(problem_op.coeffs)
            nx, ny = c.shape[-2:]
            blocks = np.zeros((nx, ny, ny))
            idx = np.arange(ny)
            blocks[:, idx, idx] = c[0]
            blocks[:, idx[1:], idx[:-1]] = c[3][:, 1:]   # W couples j-1
            blocks[:, idx[:-1], idx[1:]] = c[4][:, :-1]  # E couples j+1
            inv_blocks = np.linalg.inv(blocks)
            return BlockJacobiPrecond(jnp.asarray(inv_blocks))
        dia = problem_op
        n = dia.n
        bs = max(8, int(np.sqrt(n)) // 4)
        nb = n // bs
        dense_blocks = np.zeros((nb, bs, bs))
        data = np.asarray(dia.data)
        for d, off in enumerate(dia.offsets):
            if abs(off) >= bs:
                continue
            for bi in range(nb):
                i0 = bi * bs
                for i in range(max(0, -off), bs - max(0, off)):
                    dense_blocks[bi, i, i + off] = data[d, i0 + i] if off >= 0 else data[d, i0 + i]
        inv_blocks = np.linalg.inv(dense_blocks)
        return BlockJacobiPrecond(jnp.asarray(inv_blocks))

    if name == "rbsor":
        assert isinstance(problem_op, Stencil5), "rbsor is stencil-only"
        nx, ny = problem_op.grid
        ii, jj = jnp.meshgrid(jnp.arange(nx), jnp.arange(ny), indexing="ij")
        red = ((ii + jj) % 2 == 0).astype(jnp.float64)
        return RBSORPrecond(problem_op.coeffs, red, jnp.asarray(omega), sweeps)

    if name == "neumann":
        lmax = _power_lmax(base, np.asarray(inv_diag), base.n)
        w = min(omega, 1.0 / max(lmax, 1e-30))
        return NeumannPrecond(base, inv_diag, jnp.asarray(w), degree)

    if name == "cheby":
        lmax = _power_lmax(base, np.asarray(inv_diag), base.n)
        return ChebyshevPrecond(base, inv_diag, jnp.asarray(lmax / 50.0),
                                jnp.asarray(1.05 * lmax), degree)

    if name == "ilu_host":
        import scipy.sparse as sp
        import scipy.sparse.linalg as spla

        dia = problem_op.to_dia() if isinstance(problem_op, Stencil5) else problem_op
        a = sp.csc_matrix(dia.to_scipy())
        ilu = spla.spilu(a, drop_tol=1e-4, fill_factor=10)
        set_host_precond(ilu.solve)
        return HostPrecond(dia.n)

    raise KeyError(f"unknown preconditioner {name!r}")


def make_preconditioner_batched(name: str, problem_op, *, omega: float = 1.0,
                                degree: int = 4, sweeps: int = 1,
                                use_kernel: bool = False):
    """Stacked preconditioner for the lockstep batched solver.

    `problem_op` is a batched Stencil5 ((B, 5, nx, ny) coeffs) or DIA
    ((B, ndiag, n) data). Builds the per-chain pytrees and stacks every leaf
    on a new leading axis, so the result rides through `jax.vmap(..., 0)`
    next to the batched operator. `ilu_host` cannot batch (module-slot host
    callback) — use the sequential engine for paper-parity ILU runs.
    """
    name = name.lower()
    if name in ("none", "identity"):
        return None
    if name == "ilu_host":
        raise NotImplementedError(
            "ilu_host is a host-callback preconditioner with a single module "
            "slot; it cannot be batched — use engine='sequential'")
    if isinstance(problem_op, Stencil5):
        parts = [make_preconditioner(name, problem_op.take(i), omega=omega,
                                     degree=degree, sweeps=sweeps,
                                     use_kernel=use_kernel)
                 for i in range(problem_op.coeffs.shape[0])]
    elif isinstance(problem_op, DIA):
        parts = [make_preconditioner(name, problem_op.take(i), omega=omega,
                                     degree=degree, sweeps=sweeps,
                                     use_kernel=use_kernel)
                 for i in range(problem_op.data.shape[0])]
    else:
        raise TypeError(f"unsupported batched operator {type(problem_op)}")
    # identical (name, degree, sweeps) → identical treedefs → stackable
    return jax.tree_util.tree_map(
        lambda *leaves: jnp.stack([jnp.asarray(x) for x in leaves]), *parts)


PRECONDITIONERS = ("none", "jacobi", "bjacobi", "rbsor", "neumann", "cheby", "ilu_host")
