"""Batched ON-DEVICE small dense linear algebra for the lockstep engine.

Device counterparts of the `hostlinalg.py` stacked drivers (which stay the
reference oracle, regression-tested against this module): stacked Hessenberg
least squares via batched QR with an SVD min-norm fallback, stacked
harmonic-Ritz extraction via a batched fixed-sweep subspace-iteration
eigensolver on the small (m ≲ 200) pencils, and stacked masked triangular
inverses. Everything here is pure `jnp` on TPU-supported primitives
(matmul, QR, SVD, LU solve, `fori_loop`) so a whole GCRO-DR cycle — Arnoldi
sweep, LS update, recycle-space refresh — traces into ONE device program
with no host round-trip (solvers/batched.py).

Ragged widths (the lockstep reality: every chain runs its own j ≤ m Arnoldi
steps) are handled by PADDING, not loops:

* LS blocks pad dead columns c ≥ j with unit columns e_{row_below_block};
  they are orthogonal to the live block, so one stacked QR block-decouples
  and the padded solution entries come out EXACTLY zero (the engines'
  padded-update no-op convention).
* Eigen pencils pad with a BIG diagonal (first-cycle) or decouple to a zero
  block (deflated), so padded eigendirections are never dominant and the
  extracted subspace lives entirely in the live block.

Rank trouble is gated, never raised: every driver returns an `ok` mask (or
blends in a fallback solution) and the caller keeps the previous recycle
space for gated chains — mirroring hostlinalg's try/except + pivot-gate
behavior chain-by-chain.

Why subspace iteration and not a batched nonsymmetric QR eig: the recycle
space only needs a good basis of the smallest-|θ| harmonic-Ritz invariant
subspace; an orthogonal (inverse) iteration with a fixed sweep count gets
principal angles to LAPACK-level agreement on gapped pencils and a
comparable-quality space on clustered ones (where LAPACK's own
eigenvector basis is arbitrary anyway) — measured in
tests/test_devlinalg.py, and end-to-end by the batched-vs-sequential
equivalence suite. Sweeps are data-independent (static trace), which is
what lets the whole cycle live inside one dispatch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# conditioning gate shared with hostlinalg._stack_well_conditioned
_RTOL = 1e-12
# subspace-iteration sweeps per refresh: each sweep applies the iteration
# matrix twice then re-orthonormalizes (QR), so the invariant-subspace
# error contracts ~gap² per sweep; 48 sweeps put gapped pencils at the
# LAPACK agreement floor while staying cheap (m ≲ 200 matmuls)
_RITZ_SWEEPS = 48


def _tiny(dt) -> float:
    return float(jnp.finfo(dt).tiny)


def _big(dt) -> float:
    """Pencil-padding diagonal: large enough that padded eigendirections of
    an inverse iteration are negligible after one sweep, small enough that
    its reciprocal and products stay representable (fp32-safe)."""
    return 1e30 if dt == jnp.float64 else 1e12


def _col_mask(j, width):
    """(B, 1, width) float mask of live columns c < j[i]."""
    return (jnp.arange(width)[None, :] < j[:, None])[:, None, :]


def _row_mask(j, height):
    """(B, height, 1) float mask of live rows r <= j[i]."""
    return (jnp.arange(height)[None, :] <= j[:, None])[:, :, None]


def _unit_pad_cols(a, j, row_offset: int):
    """Replace dead columns c >= j[i] of stacked (B, R, C) blocks with unit
    columns e_{row_offset + c + 1}.

    The unit rows sit strictly below the live block (which occupies rows
    < row_offset + j + 1 in every live column), so the padded columns are
    orthogonal to the live ones and mutually orthonormal: a stacked QR
    block-decouples and any LS solution is exactly zero in the padded
    coordinates.
    """
    bsz, rows, cols = a.shape
    live = _col_mask(j, cols)
    unit = (jnp.arange(rows)[:, None]
            == (jnp.arange(cols) + row_offset + 1)[None, :])
    return jnp.where(live, a, unit[None].astype(a.dtype))


def _diag_ok(r):
    """(B,) gate: every stacked upper-triangular factor safely invertible
    (hostlinalg._stack_well_conditioned, per chain instead of all-or-none)."""
    diag = jnp.abs(jnp.diagonal(r, axis1=-2, axis2=-1))
    floor = _RTOL * jnp.maximum(diag.max(axis=-1), _tiny(r.dtype))
    return (diag.min(axis=-1) > floor) & jnp.isfinite(diag).all(axis=-1)


def tri_inv_stacked(r, want):
    """Masked batched inverse of stacked upper-triangular factors.

    r: (B, k, k) R factors from a stacked QR; want: (B,) bool — chains that
    asked for the inverse. Returns (inv_r, ok): ok = want & well-conditioned;
    gated-out chains get the identity (a harmless right-multiply that the
    caller masks away). Replaces the per-chain `np.nonzero(want)` +
    `np.linalg.inv` host loop of the old warm-start path.
    """
    ok = want & _diag_ok(r)
    k = r.shape[-1]
    eye = jnp.eye(k, dtype=r.dtype)
    safe = jnp.where(ok[:, None, None], r, eye[None])
    inv = jax.lax.linalg.triangular_solve(safe, jnp.broadcast_to(
        eye[None], safe.shape), left_side=True, lower=False)
    return inv, ok


def _svd_lstsq(a, rhs):
    """Stacked min-norm LS via SVD pinv — the rank-deficient fallback,
    matching np.linalg.lstsq(rcond=None) cutoff semantics."""
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    eps = jnp.finfo(a.dtype).eps
    cut = s[..., :1] * max(a.shape[-2:]) * eps
    sinv = jnp.where(s > cut, 1.0 / jnp.maximum(s, _tiny(a.dtype)), 0.0)
    utb = jnp.einsum("bij,bi->bj", u, rhs)
    return jnp.einsum("bji,bj->bi", vt, sinv * utb)


def lstsq_stacked(a, rhs):
    """Stacked argmin_y ‖rhs_i − A_i y‖ on PRE-PADDED blocks.

    a: (B, R, C) with dead columns already unit-padded (`_unit_pad_cols`),
    rhs: (B, R) with dead rows zeroed. One stacked QR solves the whole
    batch; chains whose R factor trips the conditioning gate are blended
    with the stacked SVD min-norm solution instead (the hostlinalg
    np.linalg.lstsq fallback, without leaving the device).
    """
    q, r = jnp.linalg.qr(a)
    ok = _diag_ok(r)
    qtb = jnp.einsum("bij,bi->bj", q, rhs)
    eye = jnp.eye(r.shape[-1], dtype=r.dtype)
    safe = jnp.where(ok[:, None, None], r, eye[None])
    y_qr = jax.lax.linalg.triangular_solve(
        safe, qtb[..., None], left_side=True, lower=False)[..., 0]
    y_svd = _svd_lstsq(a, rhs)
    return jnp.where(ok[:, None], y_qr, y_svd)


def hessenberg_lstsq_stacked(h, j, beta):
    """Stacked argmin_y ‖β_i e₁ − H_i y‖ over B chains, on device.

    h: (B, m+1, m) raw Hessenbergs; j: (B,) effective widths (0 = frozen
    chain); beta: (B,) residual norms. Returns y (B, m) zero-padded —
    columns c ≥ j[i] come out exactly zero (unit-column padding), so rows
    with j[i] == 0 stay all-zero: the padded-update no-op convention.
    Oracle: hostlinalg.hessenberg_lstsq_stacked.
    """
    bsz, _, m = h.shape
    hp = _unit_pad_cols(h, j, row_offset=0)
    rhs = jnp.zeros((bsz, m + 1), h.dtype).at[:, 0].set(
        beta.astype(h.dtype))
    return lstsq_stacked(hp, rhs)


# ---------------------------------------------------------------------------
# harmonic-Ritz extraction (the batched fixed-sweep eigensolver)
# ---------------------------------------------------------------------------


def _det_init(bsz: int, n: int, k: int, dt):
    """Deterministic full-rank start basis (incoherent w.r.t. any structured
    pencil; no PRNG so re-traces are bitwise-stable)."""
    i = jnp.arange(1, n + 1, dtype=dt)[:, None]
    l = jnp.arange(1, k + 1, dtype=dt)[None, :]
    q0 = jnp.linalg.qr(jnp.sin(i * l * 0.7 + 0.3 * l))[0]
    return jnp.broadcast_to(q0[None], (bsz, n, k))


def _dominant_subspace(mm, k: int, sweeps: int):
    """Orthogonal (subspace) iteration: the dominant k-dimensional invariant
    subspace of each stacked matrix mm (B, n, n). Two applications per
    sweep, then QR re-orthonormalization. Returns Q (B, n, k)."""
    bsz, n, _ = mm.shape
    q0 = _det_init(bsz, n, k, mm.dtype)

    def sweep(_, q):
        return jnp.linalg.qr(mm @ (mm @ q))[0]

    return jax.lax.fori_loop(0, sweeps, sweep, q0)


def harmonic_ritz_first_cycle_stacked(h, j, k: int,
                                      sweeps: int = _RITZ_SWEEPS):
    """Fresh-cycle harmonic-Ritz bases for B chains, on device.

    Pencil (Alg. 2 line 14): A = H_m + h²_{m+1,m} H_m⁻ᴴ e_m e_mᴴ at the
    per-chain effective width j; the wanted space is the smallest-|θ|
    invariant subspace of A — extracted as the DOMINANT subspace of A⁻¹ by
    subspace iteration. Dead rows/columns are padded with a BIG diagonal so
    their inverse eigendirections are negligible and the iterate collapses
    into the live block.

    Returns (p, ok): p (B, m, k) zero outside live rows; ok (B,) — chains
    with j > k, a nonsingular pencil and finite iterates. Oracle:
    hostlinalg.harmonic_ritz_first_cycle_stacked (same invariant subspace,
    not the same basis).
    """
    bsz, _, m = h.shape
    dt = h.dtype
    big = _big(dt)
    live = _col_mask(j, m) & _row_mask(j - 1, m)   # (B, m, m) live block
    hm = h[:, :m, :] * live
    hm = hm + (jnp.eye(m, dtype=bool)[None] & ~live) * big
    # e_m at the per-chain last live column (j-1); j=0 chains are gated out
    jm1 = jnp.clip(j - 1, 0, m - 1)
    em = jax.nn.one_hot(jm1, m, dtype=dt)
    h2 = h[jnp.arange(bsz), jnp.clip(j, 0, m), jm1]   # h[j, j-1] per chain
    corr = jnp.linalg.solve(hm.swapaxes(1, 2), em[..., None])[..., 0]
    a = hm + (h2 ** 2)[:, None, None] * corr[:, :, None] * em[:, None, :]
    ainv = jnp.linalg.inv(a)
    p = _dominant_subspace(ainv, k, sweeps)
    p = p * _row_mask(j - 1, m)
    ok = ((j > k) & jnp.isfinite(p).all(axis=(1, 2))
          & (jnp.linalg.norm(p, axis=1).min(axis=-1) > 0.5))
    return p, ok


def assemble_g_stacked(dnorm, bb, h, j):
    """Padded deflated-cycle Ĝ (B, k+mi+1, k+mi): [[D_k, B], [0, H̄]] with
    dead Arnoldi columns unit-padded (rows below the live block), ready for
    one stacked QR. dnorm: (B, k) ‖U col‖; bb: (B, k, mi); h: (B, mi+1, mi);
    j: (B,) effective widths."""
    bsz, k = dnorm.shape
    mi = h.shape[-1]
    dt = h.dtype
    live_c = _col_mask(j, mi)
    live_r = _row_mask(j, mi + 1)
    g = jnp.zeros((bsz, k + mi + 1, k + mi), dt)
    dsafe = jnp.maximum(dnorm, _tiny(dt))
    g = g.at[:, :k, :k].set(jnp.eye(k, dtype=dt)[None] / dsafe[:, None, :])
    g = g.at[:, :k, k:].set(bb * live_c)
    g = g.at[:, k:, k:].set(h * live_c * live_r)
    # unit columns for dead Arnoldi directions, rooted below the live block
    unit = (jnp.arange(mi + 1)[:, None]
            == (jnp.arange(mi) + 1)[None, :]).astype(dt)
    g = g.at[:, k:, k:].add(jnp.where(live_c, 0.0, unit[None]))
    return g


def assemble_whv_stacked(cu, cv, vu, vv, j):
    """Padded Ŵᴴ V̂ (B, k+mi+1, k+mi) from the small device blocks
    (gcrodr._whv_blocks): dead rows/columns zeroed so the pencil
    block-decouples against the padded Ĝ."""
    bsz, k, _ = cu.shape
    mi = vv.shape[-1]
    dt = cu.dtype
    live_c = _col_mask(j, mi)
    live_r = _row_mask(j, mi + 1)
    whv = jnp.zeros((bsz, k + mi + 1, k + mi), dt)
    whv = whv.at[:, :k, :k].set(cu)
    whv = whv.at[:, :k, k:].set(cv * live_c)
    whv = whv.at[:, k:, :k].set(vu * live_r)
    whv = whv.at[:, k:, k:].set(vv * live_c * live_r)
    return whv


def harmonic_ritz_deflated_stacked(g, whv, j, k: int,
                                   sweeps: int = _RITZ_SWEEPS):
    """Deflated-cycle harmonic-Ritz bases for B chains, on device.

    Generalized pencil (Alg. 2 line 29): ĜᴴĜ z = θ ĜᴴŴᴴV̂ z; the wanted
    smallest-|θ| space is the DOMINANT subspace of M = (ĜᴴĜ)⁻¹ ĜᴴŴᴴV̂.
    With the padding conventions of `assemble_*_stacked`, M is block
    diagonal with a ZERO dead block (unit Ĝ columns ⊥ live ones, zero Ŵᴴ V̂
    there), so the dominant subspace lives entirely in the live block.
    Replaces the "one per-chain eig loop left" in hostlinalg.

    Returns (p, ok): p (B, k+mi, k); ok gates singular/ill-conditioned
    pencils (caller keeps the previous recycle space, as hostlinalg's
    try/except does).
    """
    a1 = g.swapaxes(1, 2) @ g                    # SPD (+ identity dead block)
    a2 = g.swapaxes(1, 2) @ whv
    mm = jnp.linalg.solve(a1, a2)
    solve_ok = jnp.isfinite(mm).all(axis=(1, 2))  # singular ĜᵀĜ → NaN → gate
    mm = jnp.where(solve_ok[:, None, None], mm, 0.0)
    p = _dominant_subspace(mm, k, sweeps)
    live = _row_mask(j + k - 1, g.shape[-1])     # rows r < k + j
    p = p * live
    ok = (solve_ok
          & jnp.isfinite(p).all(axis=(1, 2))
          & (jnp.linalg.norm(p, axis=1).min(axis=-1) > 0.5))
    return p, ok


def refresh_factors(gp, want):
    """Stacked QR of Ĝ·P (or H̄·P on fresh cycles) + gated R inverse — the
    recycle-space renormalization C' = Ŵ Q, U' = V̂ P R⁻¹ (Alg. 2 l.31-33).

    gp: (B, R, k) stacked products; want: (B,) chains refreshing. Returns
    (q, inv_rr, ok): gated-out chains get q = 0, inv_rr = I (masked away by
    the caller's select).
    """
    q, rr = jnp.linalg.qr(gp)
    inv_rr, ok = tri_inv_stacked(rr, want)
    okb = ok[:, None, None]
    return jnp.where(okb, q, 0.0), inv_rr, ok
