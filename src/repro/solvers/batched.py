"""Batched multi-chain GCRO-DR — the lockstep engine behind chunk-parallel
SKR datagen (paper App. E.2.2).

The sequential `GCRODRSolver` advances ONE recycling chain and pays the full
host↔device round-trip + dispatch latency per tiny cycle. This engine
advances B independent chains (one per sorted chunk) SIMULTANEOUSLY, and —
unlike the sequential solver — keeps the WHOLE cycle on device: the Arnoldi
sweep, the Hessenberg least-squares, the harmonic-Ritz extraction and the
recycle-space refresh are one fused jitted program per cycle (the stacked
drivers in `solvers/devlinalg.py`, with `hostlinalg.py` kept as the
reference oracle). The host's only job per cycle is fetching four boolean
flags — `device_get` of (any chain still active, every active chain owns a
recycle space, any chain advanced, restart growth requested) — to pick the
next cycle's static shape. That is ONE host sync per cycle; a full
`solve_batch` costs 2 + #cycles syncs (entry flags + per-cycle flags +
finalize fetch), tracked in `SolveStats.host_syncs`.

Each chain keeps its OWN recycle carry U_k — the chains never exchange
Krylov information, exactly the App. E.2.2 task decomposition.

Lockstep semantics (who iterates when):

* Per cycle, every chain runs ≤ m Arnoldi steps under ONE vmapped
  `lax.while_loop`; a chain that hits its own tolerance mid-cycle is frozen
  by the batching rule, so per-chain iterates match the sequential solver.
* Whole cycles are phase-uniform: a "fresh" (establishing) cycle or a
  "deflated" cycle runs for ALL chains at once. Converged / stalled /
  maxiter chains are masked by passing tol_abs = +inf (their cycle takes 0
  steps, their least-squares solution is forced to y = 0 by the dead-column
  padding and the step mask, and the padded y = 0 update is a no-op on z
  and r).
* Mixed phases resolve conservatively: while ANY active chain still lacks a
  recycle space, the whole batch runs fresh GMRES(m) cycles (chains that
  already own a space simply re-establish it from their newest cycle). With
  healthy warm starts — the steady state of a sorted sequence — every chain
  goes straight to deflated cycles and the per-chain math is identical to
  `GCRODRSolver.solve`, modulo vmapped-matmul float reassociation and the
  eigensolver family (batched subspace iteration instead of LAPACK — same
  invariant subspace on gapped pencils, tested in test_devlinalg.py).
* Rare rank trouble in the batched warm-start QR drops the carry for the
  affected chains only (the masked `devlinalg.tri_inv_stacked` gate); a
  failed harmonic-Ritz refresh keeps the chain's previous space, as in the
  sequential solver.

Wall-time accounting: the batch advances as one device program, so each
returned `SolveStats.wall_time_s` is the LOCKSTEP latency of the whole
batched solve (identical across chains) — the honest parallel-latency
number App. E.2.2 reports (max over workers == the shared wall clock).
`host_syncs` / `dispatches` follow the same convention: every non-padded
chain reports the shared batch totals.

Sharding (the multi-device axis): the chains are data-parallel — they share
no Krylov information — so the leading chain axis of every large device
array shards cleanly over a 1-D `data` mesh. Construct the solver with a
`distributed.sharding.ChainSharding` and every lockstep dispatch runs as
ONE SPMD program across the mesh: right-hand sides, residuals, bases,
per-chain recycle carries AND the small per-chain eigen/LS factors live
chain-sharded on device — nothing is gathered to host between cycles. The
caller owns making the chain count divide the shard count
(core/pipeline.py pads with zero-RHS chains).

Precision policy: `cfg.inner_dtype="float32"` routes `solve_batch` through
`_solve_batch_mixed` — the fp64 outer iterative-refinement loop of the
sequential solver lifted to lockstep granularity. All B chains share each
outer pass (converged chains ride along as zero-RHS padding rows); the
bandwidth-bound inner machinery — vmapped Arnoldi cycles, preconditioner
applies, recycle-space updates, and now also the stacked eigen/LS work —
runs in fp32 at half the HBM traffic, while b, the accumulated x and every
residual of record stay fp64. The per-chain recycle carries are stored
fp32.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs.telemetry import KrylovTelemetry, drain_chain
from repro.solvers import devlinalg as dl
from repro.solvers import gcrodr as _seq
from repro.solvers.arnoldi import _arnoldi_cycle_impl
from repro.solvers.gmres import _ir_accum
from repro.solvers.operator import apply_op, cast_operator
from repro.solvers.types import KrylovConfig, SolveStats

_TINY = 1e-300

# --- the sequential solver's fused device steps, vmapped over chains -------
# (called INSIDE the fused cycle programs below — they inline at trace time)
_warm_start_b = jax.vmap(_seq._warm_start)
_fresh_update_b = jax.vmap(_seq._fresh_update)
_fresh_cu_b = jax.vmap(_seq._fresh_cu)
_rhs_and_dnorm_b = jax.vmap(_seq._rhs_and_dnorm)
_deflated_update_b = jax.vmap(_seq._deflated_update)
_whv_blocks_b = jax.vmap(_seq._whv_blocks)
_next_cu_b = jax.vmap(_seq._next_cu)
_apply_cols_b = jax.vmap(jax.vmap(apply_op, in_axes=(None, 1), out_axes=1))
_from_z_b = jax.jit(jax.vmap(lambda op, z: op.from_z(z)))
# outer iterative-refinement step, per chain: x += d (upcast) + true fp64
# residual of the UNpreconditioned base — one dispatch per outer pass
_ir_accum_b = jax.jit(jax.vmap(_ir_accum))


@jax.jit
def _downcast_masked(r, need):
    """fp32 correction right-hand sides: live rows downcast, the rest zero
    (a zero row is the lockstep engine's own padding no-op)."""
    return jnp.where(jnp.asarray(need)[:, None], r, 0.0).astype(jnp.float32)


@jax.jit
def _scaled_cols_b(u, dnorm):
    """Ũ = U / ‖U cols‖ per chain; the dtype-aware clamp keeps masked chains
    (U = 0) NaN-free in BOTH precisions (1e-300 underflows to 0 in fp32) —
    sequential chains never hit it."""
    tiny = jnp.finfo(dnorm.dtype).tiny
    return u / jnp.maximum(dnorm[:, None, :], tiny)


def _mat_post_b(y, inv_r):
    """Per-chain Y R⁻¹ (stacked right-multiply by the small R factor)."""
    return jnp.einsum("bnk,bkl->bnl", y, inv_r)


def _sel(mask_np, new, old):
    """Per-chain select: rows of `new` where mask, else `old`."""
    m = jnp.asarray(mask_np).reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def _mask(mask, new, old):
    """Traced per-chain select (same as _sel, without the host cast)."""
    return jnp.where(mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)


# ---------------------------------------------------------------------------
# the device-resident cycle programs
#
# State lives in a dict of device arrays threaded through three jitted
# programs: _entry (norms + warm start), _fresh_cycle / _deflated_cycle
# (one whole GCRO-DR cycle each), and a finalize fetch. Between cycle
# dispatches the host reads ONLY the 4-flag vector each cycle returns.
#
# Telemetry (repro.obs): the programs take two extra STATIC args —
# `tele_cap` (device ring slots per chain; 0 = off, the default) and
# `tele_delta` (record the δ(Q,C) refresh angle). With tele_cap = 0 no
# buffers enter the state dict and the traced jaxpr is IDENTICAL to the
# pre-telemetry programs — bitwise-identical numerics, zero extra
# dispatches (tests/test_obs.py). With tele_cap > 0 each cycle writes its
# per-chain residual norm / stall flag / recycle dimension (and optionally
# δ) into NaN-initialized (B, tele_cap) rings at slot cycle % tele_cap; the
# host drains them inside the finalize fetch it already pays, so the
# host_syncs = 2 + cycles invariant holds with telemetry ON
# (tests/test_transfer_guard.py runs both ways).
# ---------------------------------------------------------------------------


def _tele_init(s, bsz, dt, *, tele_cap: int, tele_delta: bool):
    """Preallocate the per-chain telemetry rings (traced inside _entry)."""
    s["tcnt"] = jnp.zeros((), jnp.int32)
    s["tlm_res"] = jnp.full((bsz, tele_cap), jnp.nan, dt)
    s["tlm_stall"] = jnp.zeros((bsz, tele_cap), bool)
    s["tlm_dim"] = jnp.zeros((bsz, tele_cap), jnp.int32)
    if tele_delta:
        s["tlm_delta"] = jnp.full((bsz, tele_cap), jnp.nan, dt)


def _tele_record(s, k: int, *, tele_cap: int, tele_delta: bool, delta=None):
    """Write one cycle's telemetry column (traced inside the cycle
    programs — rides in the fused dispatch, no extra launch)."""
    idx = s["tcnt"] % tele_cap
    s["tlm_res"] = s["tlm_res"].at[:, idx].set(s["rnorm"])
    s["tlm_stall"] = s["tlm_stall"].at[:, idx].set(s["stalled"])
    dim = jnp.where(s["est"], k, 0).astype(jnp.int32)
    s["tlm_dim"] = s["tlm_dim"].at[:, idx].set(dim)
    if tele_delta:
        col = (delta if delta is not None
               else jnp.full(s["rnorm"].shape, jnp.nan,
                             s["tlm_delta"].dtype))
        s["tlm_delta"] = s["tlm_delta"].at[:, idx].set(col)
    s["tcnt"] = s["tcnt"] + 1
    return s


def _delta_qc_b(c_old, c_new, ok):
    """Per-chain δ(Q,C) between orthonormal recycle bases before/after the
    harmonic-Ritz refresh: sin θ_max = sqrt(1 − σ_min(C_oldᵀC_new)²) — one
    stacked (k × k) SVD, NaN where the refresh was rejected (the device
    twin of core/metrics.delta_subspace, tested against it)."""
    ov = jnp.einsum("bnk,bnl->bkl", c_old, c_new)
    sv = jnp.linalg.svd(ov, compute_uv=False)
    delta = jnp.sqrt(jnp.clip(1.0 - sv[:, -1] ** 2, 0.0, 1.0))
    return jnp.where(ok, delta, jnp.nan)


@partial(jax.jit, static_argnames=("k",))
def _zeros_state(b, *, k: int):
    bsz, n = b.shape
    return (jnp.zeros_like(b), jnp.zeros((bsz, n, k), b.dtype),
            jnp.zeros((bsz, n, k), b.dtype))


def _active_mask(s, aux):
    act = (~aux["zerob"] & ~aux["pad"] & ~s["stalled"]
           & (s["rnorm"] > aux["tol_abs"]) & (s["iters"] < aux["lim"]))
    if "quar" in s:   # containment on: quarantined chains are frozen
        act = act & ~s["quar"]
    return act


def _flags(s, aux, active_prev, step, any_grew):
    """The ONLY per-cycle device→host payload: 4 booleans — 5 with the
    containment layer on, the health flag riding the SAME fetch (the
    host_syncs = 2 + cycles budget is untouched)."""
    nxt = _active_mask(s, aux)
    out = [nxt.any(),                    # keep cycling?
           (s["est"] | ~nxt).all(),      # deflated-ready?
           (step & active_prev).any(),   # anyone advanced?
           any_grew]                     # restart growth (k=0)
    if "quar" in s:
        out.append(s["quar"].any())      # per-batch health flag
    return jnp.stack(out)


def _contain_guard(s, aux, active, z_prev, r_prev, rn_prev, z, r, rn):
    """In-dispatch divergence quarantine (containment on): a chain whose
    updated residual went non-finite or beyond the divergence threshold is
    rolled back to its cycle-start iterate and quarantined — masked to a
    frozen row from the next cycle on (reusing the padding machinery via
    `_active_mask`) instead of poisoning the shared dispatch. Already-
    quarantined chains stay frozen at their last good iterate (a y = 0
    update on a NaN basis would otherwise NaN the held z)."""
    bad = active & (~jnp.isfinite(rn) | (rn > aux["div_abs"]))
    hold = bad | s["quar"]
    z = _mask(hold, z_prev, z)
    r = _mask(hold, r_prev, r)
    rn = jnp.where(hold, rn_prev, rn)
    return z, r, rn, s["quar"] | bad


@partial(jax.jit, static_argnames=("k", "use_carry", "pad_given",
                                   "contain", "tele_cap", "tele_delta"))
def _entry(ops, b, z0, c0, u0, uc, cok, pad_in, tol, lim, div,
           *, k: int, use_carry: bool, pad_given: bool,
           contain: bool = False, tele_cap: int = 0,
           tele_delta: bool = False):
    """Norms, padding mask and the warm start (Alg. 2 l.2-7) as one fused
    dispatch. The warm-start rank gate is the batched masked triangular
    inverse (devlinalg.tri_inv_stacked) — no per-chain host loop.

    contain=False (no RetryPolicy) traces the exact pre-containment
    program — no quarantine state enters the dict, no extra flag is
    fetched, bitwise-identical numerics (the tele_cap=0 pattern). With
    contain=True the state gains a per-chain `quar` bool and aux gains the
    absolute divergence threshold `div * ||b||`; a chain whose RHS is
    already non-finite is quarantined at entry (its row never solves)."""
    bsz = b.shape[0]
    dt = b.dtype
    bnorm = jnp.linalg.norm(b, axis=1)
    tol_abs = tol * bnorm
    zerob = bnorm == 0.0
    pad = pad_in if pad_given else zerob
    aux = dict(b=b, bnorm=bnorm, tol_abs=tol_abs, zerob=zerob, pad=pad,
               lim=lim)
    s = dict(z=z0, r=b, rnorm=bnorm, c=c0, u=u0,
             est=jnp.zeros(bsz, bool), stalled=jnp.zeros(bsz, bool),
             no_prog=jnp.zeros(bsz, jnp.int32),
             iters=jnp.zeros(bsz, jnp.int32),
             matvecs=jnp.zeros(bsz, jnp.int32),
             cycles=jnp.zeros(bsz, jnp.int32))
    if contain:
        aux["div_abs"] = div * bnorm
        s["quar"] = ~jnp.isfinite(bnorm) & ~pad
    if use_carry and k > 0:
        want = cok & ~zerob & ~pad & (bnorm > tol_abs)
        au = _apply_cols_b(ops, uc)
        q, rr = jnp.linalg.qr(au)
        inv_rr, ok = dl.tri_inv_stacked(rr, want)
        u_new = _mat_post_b(uc, inv_rr)
        z2, r2, rn2 = _warm_start_b(u_new, q, s["z"], s["r"])
        s["z"] = _mask(ok, z2, s["z"])
        s["r"] = _mask(ok, r2, s["r"])
        s["rnorm"] = jnp.where(ok, rn2, s["rnorm"])
        s["c"] = _mask(ok, q, s["c"])
        s["u"] = _mask(ok, u_new, s["u"])
        s["est"] = ok
        s["matvecs"] = jnp.where(want, k, 0).astype(jnp.int32)
    if tele_cap > 0:
        _tele_init(s, bsz, dt, tele_cap=tele_cap, tele_delta=tele_delta)
    f = _flags(s, aux, jnp.zeros(bsz, bool), jnp.zeros(bsz, bool),
               jnp.zeros((), bool))
    return s, aux, f


@partial(jax.jit, static_argnames=("m", "k", "orthog", "use_kernel",
                                   "h_acc", "stall_break", "can_grow",
                                   "contain", "tele_cap", "tele_delta"))
def _fresh_cycle(ops, s, aux, *, m: int, k: int, orthog: str,
                 use_kernel: bool, h_acc: str, stall_break: bool,
                 can_grow: bool, contain: bool = False,
                 tele_cap: int = 0, tele_delta: bool = False):
    """One lockstep fresh GMRES(m) cycle (Alg. 2 l.9-18) as ONE device
    program: Arnoldi sweep → stacked Hessenberg LS → solution update →
    (k > 0) harmonic-Ritz space establishment, all under the same jit."""
    bsz, n = s["r"].shape
    dt = s["r"].dtype
    active = _active_mask(s, aux)
    eff_tol = jnp.where(active, aux["tol_abs"], jnp.inf)
    empty_c = jnp.zeros((bsz, 0, n), dt)
    cyc = jax.vmap(partial(_arnoldi_cycle_impl, m=m, orthog=orthog,
                           use_kernel=use_kernel, h_acc=h_acc))(
        ops, empty_c, s["r"], eff_tol)
    j = cyc.j_used.astype(jnp.int32)
    step = j > 0
    y = dl.hessenberg_lstsq_stacked(cyc.h, j, s["rnorm"])
    rprev = s["rnorm"]
    z, r, rn = _fresh_update_b(ops, aux["b"], s["z"], cyc.v, y.astype(dt))
    if contain:
        z, r, rn, quar = _contain_guard(s, aux, active, s["z"], s["r"],
                                        rprev, z, r, rn)
        s = dict(s, quar=quar)
    s = dict(s, z=z, r=r, rnorm=rn,
             iters=s["iters"] + jnp.where(step, j, 0),
             matvecs=s["matvecs"] + jnp.where(step, j + 1, 0),
             cycles=s["cycles"] + step.astype(jnp.int32))
    if stall_break:
        s["no_prog"] = jnp.where(step & (s["rnorm"] > 0.99 * rprev),
                                 s["no_prog"] + 1, 0)
    any_grew = jnp.zeros((), bool)
    if k > 0:
        # establish / re-establish recycle spaces per chain, on device
        p, ritz_ok = dl.harmonic_ritz_first_cycle_stacked(cyc.h, j, k)
        q, inv_rr, qr_ok = dl.refresh_factors(cyc.h @ p, ritz_ok & step)
        est_new = qr_ok if not contain else qr_ok & ~s["quar"]
        c_new, yk = _fresh_cu_b(cyc.v, cyc.h, p, q)
        u_new = _mat_post_b(yk, inv_rr)
        s["c"] = _mask(est_new, c_new, s["c"])
        s["u"] = _mask(est_new, u_new, s["u"])
        s["est"] = s["est"] | est_new
    else:
        # adaptive restart growth (see gmres_solve): grow when any chain
        # ran a full cycle and stalled; the host doubles m on the flag
        grew = (step & (j == m) & (s["rnorm"] > aux["tol_abs"])
                & (s["rnorm"] > 0.5 * rprev))
        any_grew = grew.any()
        if can_grow:
            # a longer cycle deserves a fresh shot at making progress
            s["no_prog"] = jnp.where(any_grew, 0, s["no_prog"])
        s["stalled"] = s["stalled"] | (cyc.breakdown & step
                                      & (s["rnorm"] > aux["tol_abs"]))
    if stall_break:
        s["stalled"] = s["stalled"] | (s["no_prog"] >= 3)
    if tele_cap > 0:
        # a fresh cycle (re)establishes the space: no before/after pair to
        # compare, so δ is recorded NaN
        s = _tele_record(s, k, tele_cap=tele_cap, tele_delta=tele_delta)
    return s, _flags(s, aux, active, step, any_grew)


@partial(jax.jit, static_argnames=("mi", "k", "orthog", "use_kernel",
                                   "h_acc", "stall_break", "contain",
                                   "tele_cap", "tele_delta"))
def _deflated_cycle(ops, s, aux, *, mi: int, k: int, orthog: str,
                    use_kernel: bool, h_acc: str, stall_break: bool,
                    contain: bool = False, tele_cap: int = 0,
                    tele_delta: bool = False):
    """One lockstep deflated cycle (Alg. 2 l.19-33) as ONE device program:
    deflated Arnoldi sweep → stacked Ĝ least-squares → solution update →
    stacked generalized harmonic-Ritz refresh of (C, U)."""
    active = _active_mask(s, aux)
    eff_tol = jnp.where(active, aux["tol_abs"], jnp.inf)
    cyc = jax.vmap(partial(_arnoldi_cycle_impl, m=mi, orthog=orthog,
                           use_kernel=use_kernel, h_acc=h_acc))(
        ops, jnp.swapaxes(s["c"], 1, 2), s["r"], eff_tol)
    j = cyc.j_used.astype(jnp.int32)
    step = j > 0
    dt = s["r"].dtype

    ctr, vr, dnorm = _rhs_and_dnorm_b(s["c"], s["u"], cyc.v, s["r"])
    g = dl.assemble_g_stacked(dnorm, cyc.b, cyc.h, j)
    rhs = jnp.concatenate([ctr, vr], axis=1)
    ys = dl.lstsq_stacked(g, rhs)
    # frozen chains (j = 0) still have Cᵀr ≠ 0 — force their update to the
    # padded no-op the host engine produced by skipping them outright
    ys = jnp.where(step[:, None], ys, 0.0)
    y_k, y_m = ys[:, :k], ys[:, k:]
    ut = _scaled_cols_b(s["u"], dnorm)
    rprev = s["rnorm"]
    z, r, rn = _deflated_update_b(ops, aux["b"], s["z"], ut, cyc.v,
                                  y_k.astype(dt), y_m.astype(dt))
    if contain:
        z, r, rn, quar = _contain_guard(s, aux, active, s["z"], s["r"],
                                        rprev, z, r, rn)
        s = dict(s, quar=quar)
    s = dict(s, z=z, r=r, rnorm=rn,
             iters=s["iters"] + jnp.where(step, j, 0),
             matvecs=s["matvecs"] + jnp.where(step, j + 1, 0),
             cycles=s["cycles"] + step.astype(jnp.int32))
    if stall_break:
        s["no_prog"] = jnp.where(step & (s["rnorm"] > 0.99 * rprev),
                                 s["no_prog"] + 1, 0)
        s["stalled"] = s["stalled"] | (s["no_prog"] >= 3)

    # next recycle spaces from the stacked generalized harmonic-Ritz pencil
    cu, cv, vu, vv = _whv_blocks_b(s["c"], ut, cyc.v)
    whv = dl.assemble_whv_stacked(cu, cv, vu, vv, j)
    p, ritz_ok = dl.harmonic_ritz_deflated_stacked(g, whv, j, k)
    if contain:   # a quarantined chain must not refresh from garbage
        ritz_ok = ritz_ok & ~s["quar"]
    q, inv_rr, ref_ok = dl.refresh_factors(g @ p, ritz_ok & step)
    c_new, yk = _next_cu_b(ut, cyc.v, s["c"], p[:, :k], p[:, k:],
                           q[:, :k], q[:, k:])
    u_new = _mat_post_b(yk, inv_rr)
    c_old = s["c"]
    s["c"] = _mask(ref_ok, c_new, s["c"])
    s["u"] = _mask(ref_ok, u_new, s["u"])
    s["stalled"] = s["stalled"] | (cyc.breakdown & step
                                  & (s["rnorm"] > aux["tol_abs"]))
    if tele_cap > 0:
        delta = (_delta_qc_b(c_old, s["c"], ref_ok) if tele_delta else None)
        s = _tele_record(s, k, tele_cap=tele_cap, tele_delta=tele_delta,
                         delta=delta)
    return s, _flags(s, aux, active, step, jnp.zeros((), bool))


class BatchedGCRODRSolver:
    """B sequence-stateful GCRO-DR chains in lockstep. One instance per
    chunk-decomposed sorted sequence; call `solve_batch` once per lockstep
    "row" of systems (the t-th system of every chunk).

    GMRES is still the k = 0 special case — the batch then runs lockstep
    restarted-GMRES cycles with the same adaptive restart growth as
    `gmres_solve` (triggered when any active chain stalls).

    Per-chain Δt / phase-masked rows (adaptive trajectory datagen): the
    solver is agnostic to WHERE each chain's system came from — the
    trajectory engine assembles per-chain operators A_w = β₀M + γΔt_w L(t_w)
    with every chain at its own time point and step size (one vmapped
    builder), so one `solve_batch` dispatch advances chains at different
    phases. Chains that finished their trajectory arrive as `padded_rows`
    (zero RHS, excluded from solving outright, carry untouched) until the
    whole lockstep row completes.
    """

    def __init__(self, cfg: KrylovConfig, use_kernel: bool = False,
                 stall_break: bool = False, sharding=None, policy=None):
        if cfg.k > 0 and cfg.ritz_refresh != "cycle":
            raise NotImplementedError(
                "BatchedGCRODRSolver implements the paper-faithful "
                "ritz_refresh='cycle' schedule; 'final' needs per-chain "
                "last-cycle snapshots (use the sequential engine)")
        self.cfg = cfg
        self.use_kernel = use_kernel
        # policy: optional core.robust.RetryPolicy — arms the in-dispatch
        # containment layer: per-chain quarantine state, the divergence
        # guard in every cycle program, a 5th health flag riding the
        # per-cycle fetch, and carry-write blocking for quarantined chains.
        # None (the default) traces the EXACT pre-containment programs —
        # bitwise-identical numerics, same sync budget. Escalation/retry
        # itself is the pipeline's job (core/robust.solve_one_guarded on
        # the requeued systems); the solver only contains and reports.
        self.policy = policy
        # sharding: optional distributed.sharding.ChainSharding — shards the
        # leading chain axis of every large device array over the `data`
        # mesh axis, turning each lockstep dispatch into one SPMD program
        self.sharding = sharding
        # stall_break: mask out (as stalled) chains whose cycles stop
        # reducing the residual instead of spinning the lockstep to maxiter
        # — set by the mixed-precision outer loop on its inner fp32 solver,
        # where the fp32 round-off floor is an expected exit
        self.stall_break = stall_break
        self.u_carry: np.ndarray | None = None   # (B, n, k)
        self.carry_ok: np.ndarray | None = None  # (B,) bool
        self.systems_solved = 0
        # x_device: the DEVICE-resident (B, n) solution of the most recent
        # solve_batch — the finalize fetch returns numpy, but post-solve
        # device consumers (the label-expansion waves, core/expand.py) read
        # this stash instead of re-uploading x. Same buffer the numpy copy
        # came from, so consuming it is bitwise-equivalent.
        self.x_device = None
        self._inner: BatchedGCRODRSolver | None = None    # fp32 correction
        self._inner64: BatchedGCRODRSolver | None = None  # fp64 fallback

    def reset(self):
        self.u_carry = None
        self.carry_ok = None
        self.systems_solved = 0
        self.x_device = None
        self._inner = None
        self._inner64 = None

    def swap_slot(self, w: int, carry: np.ndarray | None = None,
                  carry_ok: bool = False):
        """Mid-flight slot swap — the streaming scheduler's refill hook
        (core/serve.py). When chain slot `w` retires and a NEW chain takes
        the slot between dispatches, only the recycle carry is solver
        state: operators and RHS arrive fresh each `solve_batch`, and jit
        caches on shapes, so same-shape new buffer contents never
        recompile. `carry=None` (the fresh-chain default) zeroes the
        slot's carry and clears `carry_ok`; passing an (n, k) `carry`
        adopts it (the scheduler's assignment decided the retiring chain's
        subspace is still relevant). Applies to this solver AND the
        mixed-precision inner/fallback mirrors so a later downcast cannot
        resurrect the retired chain's subspace. Pure host numpy — zero
        device syncs, so the `host_syncs <= 2 + cycles` budget is
        untouched (pinned by tests/test_serve.py under transfer_guard)."""
        for s in (self, self._inner, self._inner64):
            if s is None or s.u_carry is None:
                continue
            if carry is None:
                s.u_carry[w] = 0.0
                ok = False
            else:
                s.u_carry[w] = np.asarray(carry, dtype=s.u_carry.dtype)
                ok = bool(carry_ok)
            if s.carry_ok is not None:
                s.carry_ok[w] = ok

    def _dev(self, x):
        """Place one solver array: chain-sharded over the mesh when a
        ChainSharding is configured, default single-device otherwise."""
        return x if self.sharding is None else self.sharding.put(x)

    # ------------------------------------------------------------------
    def solve_batch(self, ops, b, padded_rows=None):
        """Solve B independent systems, one per chain.

        ops : PreconditionedOp pytree whose EVERY leaf carries a leading
              B axis (batched StencilOp/DIAOp + stacked preconditioner).
        b   : (B, n) right-hand sides. A zero row marks a padded chain
              (shorter chunk): it converges at 0 iterations with x = 0 and
              leaves the chain's recycle carry untouched.
        padded_rows : optional (B,) bool — which rows are PADDING (drive
              `SolveStats.padded` + the zeroed wall time). Defaults to the
              zero-RHS rows; the pipeline passes its own mask so a
              legitimate b = 0 system is not miscounted as padding. A row
              MARKED padded is excluded from solving outright (x = 0,
              carry untouched, zero counts) even if its RHS is nonzero —
              a padding row must never contribute phantom iterations or
              refinement passes to the sequence aggregates.

        Returns (x (B, n) np.ndarray, [SolveStats] * B).
        """
        cfg = self.cfg
        if cfg.inner_dtype == "float32":
            return self._solve_batch_mixed(ops, b, padded_rows)
        k = cfg.k
        t0 = time.perf_counter()
        b = self._dev(jnp.asarray(b))
        if self.sharding is not None:
            ops = self.sharding.put_tree(ops)
        bsz, n = b.shape
        dt = b.dtype

        # ---- entry: one fused dispatch (norms + warm start), one sync ----
        # (zeros come from a jitted constant — jnp.zeros OUTSIDE jit moves a
        # scalar host→device, which transfer_guard("disallow") rejects)
        z0, c0, u0 = (self._dev(a) for a in _zeros_state(b, k=k))
        use_carry = k > 0 and self.u_carry is not None
        uc = (self._dev(jnp.asarray(self.u_carry)) if use_carry
              else u0)
        cok = jnp.asarray(self.carry_ok if use_carry
                          else np.zeros(bsz, bool))
        pad_given = padded_rows is not None
        pad_in = jnp.asarray(np.asarray(padded_rows) if pad_given
                             else np.zeros(bsz, bool))
        # telemetry config is STATIC: capacity 0 (obs disabled) traces the
        # exact pre-telemetry programs — bitwise-identical, no extra work
        tele_cap = obs.krylov_capacity()
        tele_delta = obs.delta_enabled() and k > 0
        # containment is STATIC the same way: no policy → the exact
        # pre-containment programs, bitwise-identical
        contain = self.policy is not None
        div = (self.policy.divergence_ratio if contain else 0.0)
        # 0-d numpy scalars: a bare python scalar counts as an IMPLICIT
        # host→device transfer under jax.transfer_guard("disallow")
        s, aux, f = _entry(ops, b, z0, c0, u0, uc, cok, pad_in,
                           jnp.asarray(np.asarray(cfg.tol, dt)),
                           jnp.asarray(np.asarray(cfg.maxiter, np.int32)),
                           jnp.asarray(np.asarray(div, dt)),
                           k=k, use_carry=use_carry, pad_given=pad_given,
                           contain=contain, tele_cap=tele_cap,
                           tele_delta=tele_delta)
        with obs.span("host_sync", cat="solver", what="entry_flags"):
            fl = jax.device_get(f)
        any_active, all_est = bool(fl[0]), bool(fl[1])
        host_syncs, dispatches = 1, 1

        m_fresh = cfg.m  # k=0: grows adaptively, mirroring gmres_solve
        m_cap = min(n, cfg.m_max if cfg.m_max else 8 * cfg.m)

        # ---- the cycle loop: one fused dispatch + one 4-flag sync each ---
        while any_active:
            if k == 0 or not all_est:
                s, f = _fresh_cycle(
                    ops, s, aux, m=m_fresh, k=k, orthog=cfg.orthog,
                    use_kernel=self.use_kernel, h_acc=cfg.cgs2_acc,
                    stall_break=self.stall_break,
                    can_grow=m_fresh < m_cap, contain=contain,
                    tele_cap=tele_cap, tele_delta=tele_delta)
            else:
                s, f = _deflated_cycle(
                    ops, s, aux, mi=cfg.m - k, k=k, orthog=cfg.orthog,
                    use_kernel=self.use_kernel, h_acc=cfg.cgs2_acc,
                    stall_break=self.stall_break, contain=contain,
                    tele_cap=tele_cap, tele_delta=tele_delta)
            with obs.span("host_sync", cat="solver", what="cycle_flags"):
                fl = jax.device_get(f)
            any_active, all_est, any_step, any_grew = map(bool, fl[:4])
            if contain and bool(fl[4]):
                # the health flag rides the SAME fetch: zero extra syncs
                obs.counter_add("health.lockstep_quarantine_flag")
            host_syncs += 1
            dispatches += 1
            if any_grew and m_fresh < m_cap:
                m_fresh = min(2 * m_fresh, m_cap)
            if not any_step:
                break  # every active chain stagnated at 0 steps

        # ---- finalize: one dispatch + one bulk fetch ---------------------
        # the telemetry rings ride IN the same fetch — draining them costs
        # zero additional syncs, preserving host_syncs = 2 + cycles
        x_dev = _from_z_b(ops, s["z"])
        self.x_device = x_dev
        fetch = (x_dev, s["rnorm"], s["iters"], s["matvecs"], s["cycles"],
                 s["stalled"], s["est"], s["u"], aux["bnorm"],
                 aux["zerob"], aux["pad"])
        if contain:
            # the quarantine verdicts ride the EXISTING finalize fetch
            fetch = fetch + (s["quar"],)
        nbase = len(fetch)
        tkeys = ()
        if tele_cap > 0:
            tkeys = (("tlm_res", "tlm_stall", "tlm_dim")
                     + (("tlm_delta",) if tele_delta else ()))
            fetch = fetch + tuple(s[t] for t in tkeys) + (s["tcnt"],)
        with obs.span("host_sync", cat="solver", what="finalize"):
            got = jax.device_get(fetch)
        (x, rnorm, iters, matvecs, cycles, stalled, established, u_np,
         bnorm, zerob, pad) = got[:11]
        quar = got[11] if contain else np.zeros(bsz, bool)
        tbufs, tcnt = None, 0
        if tele_cap > 0:
            tbufs = dict(zip(tkeys, got[nbase:-1]))
            tcnt = int(got[-1])
        host_syncs += 1
        dispatches += 1
        wall = time.perf_counter() - t0
        converged = zerob | (rnorm <= cfg.tol * bnorm)
        stats = []
        for i in range(bsz):
            stats.append(SolveStats(
                iterations=int(iters[i]),
                matvecs=int(matvecs[i]),
                cycles=int(cycles[i]),
                converged=bool(converged[i]) and not bool(quar[i]),
                # quarantined: the in-dispatch guard froze this chain —
                # the pipeline requeues the system through the escalation
                # ladder (core/robust.py) and replaces this record
                quarantined=bool(quar[i]),
                rel_residual=0.0 if zerob[i]
                else float(rnorm[i] / bnorm[i]),
                # lockstep latency, shared by the batch; a padding row
                # solved nothing and reports 0 so engine comparisons of
                # per-chunk totals stay honest
                wall_time_s=0.0 if pad[i] else wall,
                breakdown=bool(stalled[i]),
                padded=bool(pad[i]),
                # shared batch totals (see module docstring): 2 + #cycles
                # syncs — entry flags, one 4-flag fetch per cycle, finalize
                host_syncs=0 if pad[i] else host_syncs,
                dispatches=0 if pad[i] else dispatches,
                telemetry=(drain_chain(tbufs, i, tcnt, tele_cap)
                           if tbufs is not None and not pad[i] else None),
            ))
        # lockstep occupancy: this solve was one dispatch of bsz rows, of
        # which the non-padded ones did real work
        if obs.enabled():
            obs.record_dispatch(int((~pad).sum()), bsz,
                                iters=[int(iters[i]) for i in range(bsz)
                                       if not pad[i]],
                                cycles=host_syncs - 2)

        if k > 0:
            # carry Ỹ_k per chain (Alg. 2 line 34); chains that never owned
            # a space this solve keep their previous carry — BITWISE (the
            # old numpy rows are reused, not round-tripped). The carry is
            # stored in the SOLVE dtype (fp32 under the mixed inner solver).
            if contain:
                # carry quarantine: a quarantined chain's space was built
                # from (or alongside) a diverging iterate — never let it
                # seed the chain's NEXT system; the chain restarts cold
                established = established & ~quar
            if self.u_carry is None:
                self.u_carry = np.zeros((bsz, n, k), dtype=u_np.dtype)
                self.carry_ok = np.zeros(bsz, dtype=bool)
            keep = established[:, None, None]
            self.u_carry = np.where(keep, u_np,
                                    self.u_carry.astype(u_np.dtype))
            self.carry_ok = self.carry_ok | established
            if contain and quar.any():
                self.u_carry[quar] = 0.0
                self.carry_ok = self.carry_ok & ~quar
                obs.counter_add("health.quarantined_chains",
                                int(quar.sum()))
        self.systems_solved += int((~zerob & ~pad).sum())
        return x, stats

    # ------------------------------------------------------------------
    def _solve_batch_mixed(self, ops, b, padded_rows=None):
        """fp64 iterative refinement over fp32 LOCKSTEP correction solves.

        The whole batch advances through the same outer passes: per pass,
        every still-unconverged chain's fp64 residual is downcast into the
        correction right-hand side (converged chains get zero rows — the
        engine's own padding no-op, so their recycle carries are untouched)
        and ONE inner lockstep solve reduces each by `cfg.inner_tol`; the
        fp64 accumulate + true-residual recompute is one batched dispatch.
        When any chain stagnates in fp32 the WHOLE batch falls back to fp64
        correction passes (lockstep latency is the max over chains anyway).
        """
        cfg = self.cfg
        t0 = time.perf_counter()
        b = self._dev(jnp.asarray(b, jnp.float64))
        if self.sharding is not None:
            ops = self.sharding.put_tree(ops)
        bsz, n = b.shape
        x = self._dev(jnp.zeros((bsz, n), b.dtype))
        r = b
        bnorm = np.asarray(jnp.linalg.norm(b, axis=1))
        host_syncs, dispatches = 1, 1
        rnorm = bnorm.copy()
        tol_abs = cfg.tol * bnorm
        zerob = bnorm == 0.0
        # marked-padded rows never enter an outer pass: a padding row must
        # not accrue outer_refinements / fp64_fallback (or iterations) that
        # SequenceStats would then mis-attribute to real solves
        pad = zerob if padded_rows is None else np.asarray(padded_rows)

        iters = np.zeros(bsz, dtype=int)
        matvecs = np.zeros(bsz, dtype=int)
        cycles = np.zeros(bsz, dtype=int)
        outer = np.zeros(bsz, dtype=int)
        fb64 = np.zeros(bsz, dtype=bool)
        stuck = np.zeros(bsz, dtype=bool)  # no-progress even in fp64
        ops32 = cast_operator(ops, jnp.float32)
        # outer-loop telemetry is host-side and free: the fp64 residual
        # norms are already fetched every pass (kind="outer"; the inner
        # fp32 lockstep solves record their own per-cycle device rings)
        outer_hist = [] if obs.enabled() else None

        if self._inner is None:
            self._inner = BatchedGCRODRSolver(cfg, use_kernel=self.use_kernel,
                                              stall_break=True,
                                              sharding=self.sharding)
        inner = self._inner
        # push the public carry (possibly from a checkpoint or an earlier
        # precision) down into the inner solver, stored fp32
        if self.u_carry is not None:
            inner.u_carry = np.asarray(self.u_carry, np.float32)
            inner.carry_ok = (self.carry_ok.copy()
                              if self.carry_ok is not None else None)
        fallback = False
        passes = 0
        while True:
            need = ~zerob & ~pad & (rnorm > tol_abs) & (iters < cfg.maxiter)
            if not need.any():
                break
            # per-pass budget honors the MOST-advanced needy chain's cap
            # (inner maxiter is batch-wide; a laggard just resumes next
            # pass), so no chain overshoots cfg.maxiter the way a
            # least-advanced budget would allow
            budget = int(max(1, cfg.maxiter - int(iters[need].max())))
            if not fallback and passes < cfg.ir_max_outer:
                # ---- fp32 lockstep correction pass ---------------------
                # per-pass tol follows the MOST demanding chain (lockstep
                # latency is the max over chains — oversolving easy chains
                # inside the same dispatch is free)
                tol_i = min(0.5, max(cfg.inner_tol,
                                     0.25 * float((tol_abs[need]
                                                   / rnorm[need]).min())))
                inner.cfg = dataclasses.replace(cfg, inner_dtype="float64",
                                                tol=tol_i, maxiter=budget)
                d, st_in = inner.solve_batch(ops32, _downcast_masked(r, need))
                outer += need
            else:
                # ---- fp64 fallback lockstep pass -----------------------
                if self._inner64 is None:
                    # no stall_break: the fp64 backstop may legitimately
                    # plateau for stretches (indefinite operators) — it gets
                    # the same patience as the plain fp64 engine
                    self._inner64 = BatchedGCRODRSolver(
                        cfg, use_kernel=self.use_kernel,
                        sharding=self.sharding)
                tol_i = min(0.5, max(0.5 * float((tol_abs[need]
                                                  / rnorm[need]).min()),
                                     1e-14))
                self._inner64.cfg = dataclasses.replace(
                    cfg, inner_dtype="float64", tol=tol_i, maxiter=budget)
                self._inner64.u_carry = (
                    np.asarray(inner.u_carry, np.float64)
                    if inner.u_carry is not None else None)
                self._inner64.carry_ok = (inner.carry_ok.copy()
                                          if inner.carry_ok is not None
                                          else None)
                rhs = jnp.where(jnp.asarray(need)[:, None], r, 0.0)
                d, st_in = self._inner64.solve_batch(ops, rhs)
                if self._inner64.u_carry is not None:
                    inner.u_carry = np.asarray(self._inner64.u_carry,
                                               np.float32)
                    inner.carry_ok = self._inner64.carry_ok.copy()
                fb64 |= need
            passes += 1
            host_syncs += max(st.host_syncs for st in st_in)
            dispatches += max(st.dispatches for st in st_in) + 1
            for i in np.nonzero(need)[0]:
                iters[i] += st_in[i].iterations
                matvecs[i] += st_in[i].matvecs
                cycles[i] += st_in[i].cycles
            rprev, x_prev, r_prev = rnorm, x, r
            x, r, rn = _ir_accum_b(ops.base, b, x, jnp.asarray(d))
            matvecs += need
            rnorm = np.asarray(rn)
            host_syncs += 1
            bad = need & (~np.isfinite(rnorm) | (rnorm > rprev))
            if bad.any():   # overflow OR diverging correction — roll back
                x = _sel(~bad, x, x_prev)
                r = _sel(~bad, r, r_prev)
                rnorm = np.where(bad, rprev, rnorm)
            if outer_hist is not None:
                outer_hist.append(rnorm.copy())
            no_prog = need & ~(rnorm <= 0.5 * rprev) & (rnorm > tol_abs)
            if no_prog.any():
                if fallback:
                    stuck |= no_prog  # a true stall, not budget exhaustion
                    break             # fp64 lockstep is stuck too — stop
                fallback = True      # fp32 stagnated somewhere → fp64 batch

        # ---- finalize ----------------------------------------------------
        self.x_device = x   # fp64 accumulated iterate, device-resident
        x_np = np.asarray(x)
        host_syncs += 1
        wall = time.perf_counter() - t0
        converged = zerob | (rnorm <= tol_abs)
        # containment (policy armed): the outer IR loop is host-mediated,
        # so quarantine here is a pure host-side classification — a chain
        # whose norms went non-finite (poisoned RHS/operator) or whose
        # residual diverged past the policy threshold is flagged for the
        # pipeline's requeue; NaN comparison semantics already kept it out
        # of every outer pass (a NaN `need` entry is False)
        quar = np.zeros(bsz, dtype=bool)
        if self.policy is not None:
            quar = (~pad & ~zerob
                    & (~np.isfinite(bnorm) | ~np.isfinite(rnorm)
                       | (rnorm > self.policy.divergence_ratio * bnorm)))
        stats = []
        for i in range(bsz):
            stats.append(SolveStats(
                iterations=int(iters[i]),
                matvecs=int(matvecs[i]),
                cycles=int(cycles[i]),
                converged=bool(converged[i]) and not bool(quar[i]),
                quarantined=bool(quar[i]),
                rel_residual=0.0 if zerob[i]
                else float(rnorm[i] / bnorm[i]),
                # shared lockstep latency; 0 for padding rows
                wall_time_s=0.0 if pad[i] else wall,
                # breakdown marks a genuine stall (no progress even in the
                # fp64 fallback) — maxiter exhaustion stays False, matching
                # the plain engines' semantics
                breakdown=bool(stuck[i]),
                outer_refinements=int(outer[i]),
                fp64_fallback=bool(fb64[i]),
                padded=bool(pad[i]),
                host_syncs=0 if pad[i] else host_syncs,
                dispatches=0 if pad[i] else dispatches,
                telemetry=(KrylovTelemetry(
                    res_hist=np.array([row[i] for row in outer_hist]),
                    kind="outer")
                    if outer_hist is not None and not pad[i] else None),
            ))
        if cfg.k > 0 and inner.u_carry is not None:
            self.u_carry = np.asarray(inner.u_carry, np.float32)
            self.carry_ok = (inner.carry_ok.copy()
                             if inner.carry_ok is not None else None)
            if quar.any():   # carry quarantine, as in the fp64 path
                self.u_carry[quar] = 0.0
                if self.carry_ok is not None:
                    self.carry_ok = self.carry_ok & ~quar
                inner.u_carry[quar] = 0.0
                if inner.carry_ok is not None:
                    inner.carry_ok = inner.carry_ok & ~quar
        self.systems_solved += int((~zerob & ~pad).sum())
        return x_np, stats
