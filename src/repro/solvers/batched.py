"""Batched multi-chain GCRO-DR — the lockstep engine behind chunk-parallel
SKR datagen (paper App. E.2.2).

The sequential `GCRODRSolver` advances ONE recycling chain and pays the full
host↔device round-trip + dispatch latency per tiny cycle. This engine
advances B independent chains (one per sorted chunk) SIMULTANEOUSLY: every
fused device step of the sequential solver (Arnoldi cycle, warm start,
padded solution updates, recycle-space assembly) is vmapped over a leading
chain axis, so a lockstep cycle for all B chains is the same ~4 dispatches a
single chain used to cost. Each chain keeps its OWN recycle carry U_k — the
chains never exchange Krylov information, exactly the App. E.2.2 task
decomposition — while the O(m³) eigen/LS cleanup runs on host via the
stacked drivers in `hostlinalg.py`.

Lockstep semantics (who iterates when):

* Per cycle, every chain runs ≤ m Arnoldi steps under ONE vmapped
  `lax.while_loop`; a chain that hits its own tolerance mid-cycle is frozen
  by the batching rule, so per-chain iterates match the sequential solver.
* Whole cycles are phase-uniform: a "fresh" (establishing) cycle or a
  "deflated" cycle runs for ALL chains at once. Converged / stalled /
  maxiter chains are masked by passing tol_abs = +inf (their cycle takes 0
  steps and the padded y = 0 update is a no-op on z and r).
* Mixed phases resolve conservatively: while ANY active chain still lacks a
  recycle space, the whole batch runs fresh GMRES(m) cycles (chains that
  already own a space simply re-establish it from their newest cycle). With
  healthy warm starts — the steady state of a sorted sequence — every chain
  goes straight to deflated cycles and the per-chain math is identical to
  `GCRODRSolver.solve`, modulo vmapped-matmul float reassociation.
* Rare rank trouble in the batched warm-start QR drops the carry for the
  affected chains only; a failed harmonic-Ritz refresh keeps the chain's
  previous space, as in the sequential solver.

Wall-time accounting: the batch advances as one device program, so each
returned `SolveStats.wall_time_s` is the LOCKSTEP latency of the whole
batched solve (identical across chains) — the honest parallel-latency
number App. E.2.2 reports (max over workers == the shared wall clock).

Sharding (the multi-device axis): the chains are data-parallel — they share
no Krylov information — so the leading chain axis of every large device
array shards cleanly over a 1-D `data` mesh. Construct the solver with a
`distributed.sharding.ChainSharding` and every lockstep dispatch runs as
ONE SPMD program across the mesh: right-hand sides, residuals, bases and
per-chain recycle carries live chain-sharded on device, while the small
host eigen/LS solves stay replicated-per-shard on host (gathered rows),
exactly as in the unsharded engine. The caller owns making the chain count
divide the shard count (core/pipeline.py pads with zero-RHS chains).

Precision policy: `cfg.inner_dtype="float32"` routes `solve_batch` through
`_solve_batch_mixed` — the fp64 outer iterative-refinement loop of the
sequential solver lifted to lockstep granularity. All B chains share each
outer pass (converged chains ride along as zero-RHS padding rows); the
bandwidth-bound inner machinery — vmapped Arnoldi cycles, preconditioner
applies, recycle-space updates — runs in fp32 at half the HBM traffic,
while b, the accumulated x and every residual of record stay fp64. The
per-chain recycle carries are stored fp32.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.solvers import gcrodr as _seq
from repro.solvers import hostlinalg as hl
from repro.solvers.arnoldi import arnoldi_cycle_batched
from repro.solvers.gmres import _ir_accum
from repro.solvers.operator import apply_op, cast_operator
from repro.solvers.types import KrylovConfig, SolveStats

_TINY = 1e-300

# --- the sequential solver's fused device steps, vmapped over chains -------
_warm_start_b = jax.jit(jax.vmap(_seq._warm_start))
_fresh_update_b = jax.jit(jax.vmap(_seq._fresh_update))
_fresh_cu_b = jax.jit(jax.vmap(_seq._fresh_cu))
_rhs_and_dnorm_b = jax.jit(jax.vmap(_seq._rhs_and_dnorm))
_deflated_update_b = jax.jit(jax.vmap(_seq._deflated_update))
_whv_blocks_b = jax.jit(jax.vmap(_seq._whv_blocks))
_next_cu_b = jax.jit(jax.vmap(_seq._next_cu))
_apply_cols_b = jax.jit(jax.vmap(jax.vmap(apply_op, in_axes=(None, 1),
                                          out_axes=1)))
_from_z_b = jax.jit(jax.vmap(lambda op, z: op.from_z(z)))
# outer iterative-refinement step, per chain: x += d (upcast) + true fp64
# residual of the UNpreconditioned base — one dispatch per outer pass
_ir_accum_b = jax.jit(jax.vmap(_ir_accum))


@jax.jit
def _downcast_masked(r, need):
    """fp32 correction right-hand sides: live rows downcast, the rest zero
    (a zero row is the lockstep engine's own padding no-op)."""
    return jnp.where(jnp.asarray(need)[:, None], r, 0.0).astype(jnp.float32)


@jax.jit
def _scaled_cols_b(u, dnorm):
    """Ũ = U / ‖U cols‖ per chain; the dtype-aware clamp keeps masked chains
    (U = 0) NaN-free in BOTH precisions (1e-300 underflows to 0 in fp32) —
    sequential chains never hit it."""
    tiny = jnp.finfo(dnorm.dtype).tiny
    return u / jnp.maximum(dnorm[:, None, :], tiny)


@jax.jit
def _mat_post_b(y, inv_r):
    """Per-chain Y R⁻¹ (stacked right-multiply by the small host factor)."""
    return jnp.einsum("bnk,bkl->bnl", y, inv_r)


def _sel(mask_np, new, old):
    """Per-chain select: rows of `new` where mask, else `old`."""
    m = jnp.asarray(mask_np).reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


class BatchedGCRODRSolver:
    """B sequence-stateful GCRO-DR chains in lockstep. One instance per
    chunk-decomposed sorted sequence; call `solve_batch` once per lockstep
    "row" of systems (the t-th system of every chunk).

    GMRES is still the k = 0 special case — the batch then runs lockstep
    restarted-GMRES cycles with the same adaptive restart growth as
    `gmres_solve` (triggered when any active chain stalls).

    Per-chain Δt / phase-masked rows (adaptive trajectory datagen): the
    solver is agnostic to WHERE each chain's system came from — the
    trajectory engine assembles per-chain operators A_w = β₀M + γΔt_w L(t_w)
    with every chain at its own time point and step size (one vmapped
    builder), so one `solve_batch` dispatch advances chains at different
    phases. Chains that finished their trajectory arrive as `padded_rows`
    (zero RHS, excluded from solving outright, carry untouched) until the
    whole lockstep row completes.
    """

    def __init__(self, cfg: KrylovConfig, use_kernel: bool = False,
                 stall_break: bool = False, sharding=None):
        if cfg.k > 0 and cfg.ritz_refresh != "cycle":
            raise NotImplementedError(
                "BatchedGCRODRSolver implements the paper-faithful "
                "ritz_refresh='cycle' schedule; 'final' needs per-chain "
                "last-cycle snapshots (use the sequential engine)")
        self.cfg = cfg
        self.use_kernel = use_kernel
        # sharding: optional distributed.sharding.ChainSharding — shards the
        # leading chain axis of every large device array over the `data`
        # mesh axis, turning each lockstep dispatch into one SPMD program
        self.sharding = sharding
        # stall_break: mask out (as stalled) chains whose cycles stop
        # reducing the residual instead of spinning the lockstep to maxiter
        # — set by the mixed-precision outer loop on its inner fp32 solver,
        # where the fp32 round-off floor is an expected exit
        self.stall_break = stall_break
        self.u_carry: np.ndarray | None = None   # (B, n, k)
        self.carry_ok: np.ndarray | None = None  # (B,) bool
        self.systems_solved = 0
        self._inner: BatchedGCRODRSolver | None = None    # fp32 correction
        self._inner64: BatchedGCRODRSolver | None = None  # fp64 fallback

    def reset(self):
        self.u_carry = None
        self.carry_ok = None
        self.systems_solved = 0
        self._inner = None
        self._inner64 = None

    def _dev(self, x):
        """Place one solver array: chain-sharded over the mesh when a
        ChainSharding is configured, default single-device otherwise."""
        return x if self.sharding is None else self.sharding.put(x)

    # ------------------------------------------------------------------
    def solve_batch(self, ops, b, padded_rows=None):
        """Solve B independent systems, one per chain.

        ops : PreconditionedOp pytree whose EVERY leaf carries a leading
              B axis (batched StencilOp/DIAOp + stacked preconditioner).
        b   : (B, n) right-hand sides. A zero row marks a padded chain
              (shorter chunk): it converges at 0 iterations with x = 0 and
              leaves the chain's recycle carry untouched.
        padded_rows : optional (B,) bool — which rows are PADDING (drive
              `SolveStats.padded` + the zeroed wall time). Defaults to the
              zero-RHS rows; the pipeline passes its own mask so a
              legitimate b = 0 system is not miscounted as padding. A row
              MARKED padded is excluded from solving outright (x = 0,
              carry untouched, zero counts) even if its RHS is nonzero —
              a padding row must never contribute phantom iterations or
              refinement passes to the sequence aggregates.

        Returns (x (B, n) np.ndarray, [SolveStats] * B).
        """
        cfg = self.cfg
        if cfg.inner_dtype == "float32":
            return self._solve_batch_mixed(ops, b, padded_rows)
        k = cfg.k
        t0 = time.perf_counter()
        b = self._dev(jnp.asarray(b))
        if self.sharding is not None:
            ops = self.sharding.put_tree(ops)
        bsz, n = b.shape
        dt = b.dtype

        z = self._dev(jnp.zeros((bsz, n), dt))
        r = b
        bnorm = np.asarray(jnp.linalg.norm(b, axis=1))
        rnorm = bnorm.copy()
        tol_abs = cfg.tol * bnorm
        zerob = bnorm == 0.0
        pad = zerob if padded_rows is None else np.asarray(padded_rows)

        iters = np.zeros(bsz, dtype=int)
        matvecs = np.zeros(bsz, dtype=int)
        cycles = np.zeros(bsz, dtype=int)
        stalled = np.zeros(bsz, dtype=bool)
        no_prog = np.zeros(bsz, dtype=int)  # stall_break progress counters

        c_dev = self._dev(jnp.zeros((bsz, n, k), dt))
        u_dev = self._dev(jnp.zeros((bsz, n, k), dt))
        established = np.zeros(bsz, dtype=bool)

        # ---- warm start: re-biorthogonalize carried spaces (Alg. 2 l.2-7)
        if k > 0 and self.u_carry is not None:
            want = self.carry_ok & ~zerob & ~pad & (rnorm > tol_abs)
            if want.any():
                u_old = self._dev(jnp.asarray(self.u_carry))
                au = _apply_cols_b(ops, u_old)
                matvecs += np.where(want, k, 0)
                q, rr = jnp.linalg.qr(au)
                rr_np = np.asarray(rr)
                inv_rr = np.tile(np.eye(k), (bsz, 1, 1))
                ok = want.copy()
                for i in np.nonzero(want)[0]:
                    diag = np.abs(np.diag(rr_np[i]))
                    if diag.min() > 1e-12 * max(diag.max(), _TINY):
                        inv_rr[i] = np.linalg.inv(rr_np[i])
                    else:
                        ok[i] = False
                u_new = _mat_post_b(u_old, jnp.asarray(inv_rr, dt))
                z2, r2, rn2 = _warm_start_b(u_new, q, z, r)
                z = _sel(ok, z2, z)
                r = _sel(ok, r2, r)
                rnorm = np.where(ok, np.asarray(rn2), rnorm)
                c_dev = _sel(ok, q, c_dev)
                u_dev = _sel(ok, u_new, u_dev)
                established = ok

        empty_c = self._dev(jnp.zeros((bsz, 0, n), dt))
        m_fresh = cfg.m  # k=0: grows adaptively, mirroring gmres_solve
        m_cap = min(n, cfg.m_max if cfg.m_max else 8 * cfg.m)

        while True:
            active = (~zerob & ~pad & ~stalled & (rnorm > tol_abs)
                      & (iters < cfg.maxiter))
            if not active.any():
                break
            eff_tol = jnp.asarray(np.where(active, tol_abs, np.inf))

            if k == 0 or not established[active].all():
                # ---- lockstep fresh GMRES(m) cycles (Alg. 2 l.9-18) ------
                m = m_fresh
                cyc = arnoldi_cycle_batched(ops, empty_c, r, eff_tol, m=m,
                                            orthog=cfg.orthog,
                                            use_kernel=self.use_kernel,
                                            h_acc=cfg.cgs2_acc)
                j = np.asarray(cyc.j_used)
                step = j > 0
                if not step[active].any():
                    break  # all active chains stagnated at 0 steps
                h_np = np.asarray(cyc.h)
                y = hl.hessenberg_lstsq_stacked(h_np, j, rnorm)
                rprev = rnorm
                z, r, rn = _fresh_update_b(ops, b, z, cyc.v,
                                           jnp.asarray(y, dt))
                rnorm = np.asarray(rn)
                iters += np.where(step, j, 0)
                matvecs += np.where(step, j + 1, 0)
                cycles += step
                if self.stall_break:
                    no_prog = np.where(step & (rnorm > 0.99 * rprev),
                                       no_prog + 1, 0)

                if k > 0:
                    # establish / re-establish recycle spaces per chain
                    plist = hl.harmonic_ritz_first_cycle_stacked(h_np, j, k)
                    p_pad = np.zeros((bsz, m, k))
                    q_pad = np.zeros((bsz, m + 1, k))
                    inv_rr = np.tile(np.eye(k), (bsz, 1, 1))
                    est_new = np.zeros(bsz, dtype=bool)
                    for i in range(bsz):
                        p = plist[i]
                        if p is None or p.shape[1] != k:
                            continue
                        ji = int(j[i])
                        qq, rr_ = np.linalg.qr(h_np[i, : ji + 1, :ji] @ p)
                        diag = np.abs(np.diag(rr_))
                        if diag.min() <= 1e-12 * max(diag.max(), _TINY):
                            continue
                        p_pad[i, :ji] = p
                        q_pad[i, : ji + 1] = qq
                        inv_rr[i] = np.linalg.inv(rr_)
                        est_new[i] = True
                    if est_new.any():
                        c_new, yk = _fresh_cu_b(cyc.v, cyc.h,
                                                jnp.asarray(p_pad, dt),
                                                jnp.asarray(q_pad, dt))
                        u_new = _mat_post_b(yk, jnp.asarray(inv_rr, dt))
                        c_dev = _sel(est_new, c_new, c_dev)
                        u_dev = _sel(est_new, u_new, u_dev)
                        established |= est_new
                else:
                    # adaptive restart growth (see gmres_solve): grow when
                    # any chain ran a full cycle and stalled
                    grew = (step & (j == m) & (rnorm > tol_abs)
                            & (rnorm > 0.5 * rprev))
                    if grew.any() and m_fresh < m_cap:
                        m_fresh = min(2 * m_fresh, m_cap)
                        no_prog[:] = 0  # a longer cycle deserves a fresh shot
                    stalled |= (np.asarray(cyc.breakdown) & step
                                & (rnorm > tol_abs))
                if self.stall_break:
                    stalled |= no_prog >= 3
                continue

            # ---- lockstep deflated cycles (Alg. 2 l.19-33) ---------------
            mi = cfg.m - k
            cyc = arnoldi_cycle_batched(ops, jnp.swapaxes(c_dev, 1, 2), r,
                                        eff_tol, m=mi, orthog=cfg.orthog,
                                        use_kernel=self.use_kernel,
                                        h_acc=cfg.cgs2_acc)
            j = np.asarray(cyc.j_used)
            step = j > 0
            if not step[active].any():
                break
            ctr, vr, dnorm = _rhs_and_dnorm_b(c_dev, u_dev, cyc.v, r)
            ctr_np = np.asarray(ctr)
            vr_np = np.asarray(vr)
            dnorm_np = np.maximum(np.asarray(dnorm, np.float64), _TINY)
            h_np = np.asarray(cyc.h)
            bb_np = np.asarray(cyc.b)

            g_list: list = [None] * bsz
            rhs_list: list = [None] * bsz
            for i in np.nonzero(step)[0]:
                ji = int(j[i])
                g = np.zeros((k + ji + 1, k + ji))
                g[:k, :k] = np.diag(1.0 / dnorm_np[i])
                g[:k, k:] = bb_np[i][:, :ji]
                g[k:, k:] = h_np[i][: ji + 1, :ji]
                g_list[i] = g
                rhs_list[i] = np.concatenate([ctr_np[i], vr_np[i][: ji + 1]])
            ys = hl.lstsq_stacked(g_list, rhs_list)

            y_k = np.zeros((bsz, k))
            y_m = np.zeros((bsz, mi))
            for i in np.nonzero(step)[0]:
                y_k[i] = ys[i][:k]
                y_m[i, : int(j[i])] = ys[i][k:]
            ut = _scaled_cols_b(u_dev, dnorm)
            rprev = rnorm
            z, r, rn = _deflated_update_b(ops, b, z, ut, cyc.v,
                                          jnp.asarray(y_k, dt),
                                          jnp.asarray(y_m, dt))
            rnorm = np.asarray(rn)
            iters += np.where(step, j, 0)
            matvecs += np.where(step, j + 1, 0)
            cycles += step
            if self.stall_break:
                no_prog = np.where(step & (rnorm > 0.99 * rprev),
                                   no_prog + 1, 0)
                stalled |= no_prog >= 3

            # next recycle spaces from the harmonic-Ritz pencils
            cu, cv, vu, vv = [np.asarray(a) for a in
                              _whv_blocks_b(c_dev, ut, cyc.v)]
            whv_list: list = [None] * bsz
            for i in np.nonzero(step)[0]:
                ji = int(j[i])
                whv = np.zeros((k + ji + 1, k + ji))
                whv[:k, :k] = cu[i]
                whv[:k, k:] = cv[i][:, :ji]
                whv[k:, :k] = vu[i][: ji + 1]
                whv[k:, k:] = vv[i][: ji + 1, :ji]
                whv_list[i] = whv
            p2 = hl.harmonic_ritz_deflated_stacked(g_list, whv_list, k)

            p_k = np.zeros((bsz, k, k))
            p_m = np.zeros((bsz, mi, k))
            q_c = np.zeros((bsz, k, k))
            q_v = np.zeros((bsz, mi + 1, k))
            inv_rr = np.tile(np.eye(k), (bsz, 1, 1))
            ref_ok = np.zeros(bsz, dtype=bool)
            for i in np.nonzero(step)[0]:
                p = p2[i]
                if p is None or p.shape[1] != k:
                    continue
                qq, rr_ = np.linalg.qr(g_list[i] @ p)
                diag = np.abs(np.diag(rr_))
                if diag.min() <= 1e-12 * max(diag.max(), _TINY):
                    continue
                ji = int(j[i])
                p_k[i] = p[:k]
                p_m[i, :ji] = p[k:]
                q_c[i] = qq[:k]
                q_v[i, : ji + 1] = qq[k:]
                inv_rr[i] = np.linalg.inv(rr_)
                ref_ok[i] = True
            if ref_ok.any():
                c_new, yk = _next_cu_b(ut, cyc.v, c_dev,
                                       jnp.asarray(p_k, dt),
                                       jnp.asarray(p_m, dt),
                                       jnp.asarray(q_c, dt),
                                       jnp.asarray(q_v, dt))
                u_new = _mat_post_b(yk, jnp.asarray(inv_rr, dt))
                c_dev = _sel(ref_ok, c_new, c_dev)
                u_dev = _sel(ref_ok, u_new, u_dev)
            stalled |= (np.asarray(cyc.breakdown) & step & (rnorm > tol_abs))

        # ---- finalize ----------------------------------------------------
        x = np.asarray(_from_z_b(ops, z))
        wall = time.perf_counter() - t0
        converged = zerob | (rnorm <= tol_abs)
        stats = []
        for i in range(bsz):
            stats.append(SolveStats(
                iterations=int(iters[i]),
                matvecs=int(matvecs[i]),
                cycles=int(cycles[i]),
                converged=bool(converged[i]),
                rel_residual=0.0 if zerob[i]
                else float(rnorm[i] / bnorm[i]),
                # lockstep latency, shared by the batch; a padding row
                # solved nothing and reports 0 so engine comparisons of
                # per-chunk totals stay honest
                wall_time_s=0.0 if pad[i] else wall,
                breakdown=bool(stalled[i]),
                padded=bool(pad[i]),
            ))

        if k > 0:
            # carry Ỹ_k per chain (Alg. 2 line 34); chains that never owned
            # a space this solve keep their previous carry. The carry is
            # stored in the SOLVE dtype (fp32 under the mixed inner solver).
            u_np = np.asarray(u_dev)
            if self.u_carry is None:
                self.u_carry = np.zeros((bsz, n, k), dtype=u_np.dtype)
                self.carry_ok = np.zeros(bsz, dtype=bool)
            keep = established[:, None, None]
            self.u_carry = np.where(keep, u_np,
                                    self.u_carry.astype(u_np.dtype))
            self.carry_ok = self.carry_ok | established
        self.systems_solved += int((~zerob & ~pad).sum())
        return x, stats

    # ------------------------------------------------------------------
    def _solve_batch_mixed(self, ops, b, padded_rows=None):
        """fp64 iterative refinement over fp32 LOCKSTEP correction solves.

        The whole batch advances through the same outer passes: per pass,
        every still-unconverged chain's fp64 residual is downcast into the
        correction right-hand side (converged chains get zero rows — the
        engine's own padding no-op, so their recycle carries are untouched)
        and ONE inner lockstep solve reduces each by `cfg.inner_tol`; the
        fp64 accumulate + true-residual recompute is one batched dispatch.
        When any chain stagnates in fp32 the WHOLE batch falls back to fp64
        correction passes (lockstep latency is the max over chains anyway).
        """
        cfg = self.cfg
        t0 = time.perf_counter()
        b = self._dev(jnp.asarray(b, jnp.float64))
        if self.sharding is not None:
            ops = self.sharding.put_tree(ops)
        bsz, n = b.shape
        x = self._dev(jnp.zeros((bsz, n), b.dtype))
        r = b
        bnorm = np.asarray(jnp.linalg.norm(b, axis=1))
        rnorm = bnorm.copy()
        tol_abs = cfg.tol * bnorm
        zerob = bnorm == 0.0
        # marked-padded rows never enter an outer pass: a padding row must
        # not accrue outer_refinements / fp64_fallback (or iterations) that
        # SequenceStats would then mis-attribute to real solves
        pad = zerob if padded_rows is None else np.asarray(padded_rows)

        iters = np.zeros(bsz, dtype=int)
        matvecs = np.zeros(bsz, dtype=int)
        cycles = np.zeros(bsz, dtype=int)
        outer = np.zeros(bsz, dtype=int)
        fb64 = np.zeros(bsz, dtype=bool)
        stuck = np.zeros(bsz, dtype=bool)  # no-progress even in fp64
        ops32 = cast_operator(ops, jnp.float32)

        if self._inner is None:
            self._inner = BatchedGCRODRSolver(cfg, use_kernel=self.use_kernel,
                                              stall_break=True,
                                              sharding=self.sharding)
        inner = self._inner
        # push the public carry (possibly from a checkpoint or an earlier
        # precision) down into the inner solver, stored fp32
        if self.u_carry is not None:
            inner.u_carry = np.asarray(self.u_carry, np.float32)
            inner.carry_ok = (self.carry_ok.copy()
                              if self.carry_ok is not None else None)
        fallback = False
        passes = 0
        while True:
            need = ~zerob & ~pad & (rnorm > tol_abs) & (iters < cfg.maxiter)
            if not need.any():
                break
            # per-pass budget honors the MOST-advanced needy chain's cap
            # (inner maxiter is batch-wide; a laggard just resumes next
            # pass), so no chain overshoots cfg.maxiter the way a
            # least-advanced budget would allow
            budget = int(max(1, cfg.maxiter - int(iters[need].max())))
            if not fallback and passes < cfg.ir_max_outer:
                # ---- fp32 lockstep correction pass ---------------------
                # per-pass tol follows the MOST demanding chain (lockstep
                # latency is the max over chains — oversolving easy chains
                # inside the same dispatch is free)
                tol_i = min(0.5, max(cfg.inner_tol,
                                     0.25 * float((tol_abs[need]
                                                   / rnorm[need]).min())))
                inner.cfg = dataclasses.replace(cfg, inner_dtype="float64",
                                                tol=tol_i, maxiter=budget)
                d, st_in = inner.solve_batch(ops32, _downcast_masked(r, need))
                outer += need
            else:
                # ---- fp64 fallback lockstep pass -----------------------
                if self._inner64 is None:
                    # no stall_break: the fp64 backstop may legitimately
                    # plateau for stretches (indefinite operators) — it gets
                    # the same patience as the plain fp64 engine
                    self._inner64 = BatchedGCRODRSolver(
                        cfg, use_kernel=self.use_kernel,
                        sharding=self.sharding)
                tol_i = min(0.5, max(0.5 * float((tol_abs[need]
                                                  / rnorm[need]).min()),
                                     1e-14))
                self._inner64.cfg = dataclasses.replace(
                    cfg, inner_dtype="float64", tol=tol_i, maxiter=budget)
                self._inner64.u_carry = (
                    np.asarray(inner.u_carry, np.float64)
                    if inner.u_carry is not None else None)
                self._inner64.carry_ok = (inner.carry_ok.copy()
                                          if inner.carry_ok is not None
                                          else None)
                rhs = jnp.where(jnp.asarray(need)[:, None], r, 0.0)
                d, st_in = self._inner64.solve_batch(ops, rhs)
                if self._inner64.u_carry is not None:
                    inner.u_carry = np.asarray(self._inner64.u_carry,
                                               np.float32)
                    inner.carry_ok = self._inner64.carry_ok.copy()
                fb64 |= need
            passes += 1
            for i in np.nonzero(need)[0]:
                iters[i] += st_in[i].iterations
                matvecs[i] += st_in[i].matvecs
                cycles[i] += st_in[i].cycles
            rprev, x_prev, r_prev = rnorm, x, r
            x, r, rn = _ir_accum_b(ops.base, b, x, jnp.asarray(d))
            matvecs += need
            rnorm = np.asarray(rn)
            bad = need & ~np.isfinite(rnorm)
            if bad.any():   # fp32 overflow on some chains — roll them back
                x = _sel(~bad, x, x_prev)
                r = _sel(~bad, r, r_prev)
                rnorm = np.where(bad, rprev, rnorm)
            no_prog = need & ~(rnorm <= 0.5 * rprev) & (rnorm > tol_abs)
            if no_prog.any():
                if fallback:
                    stuck |= no_prog  # a true stall, not budget exhaustion
                    break             # fp64 lockstep is stuck too — stop
                fallback = True      # fp32 stagnated somewhere → fp64 batch

        # ---- finalize ----------------------------------------------------
        x_np = np.asarray(x)
        wall = time.perf_counter() - t0
        converged = zerob | (rnorm <= tol_abs)
        stats = []
        for i in range(bsz):
            stats.append(SolveStats(
                iterations=int(iters[i]),
                matvecs=int(matvecs[i]),
                cycles=int(cycles[i]),
                converged=bool(converged[i]),
                rel_residual=0.0 if zerob[i]
                else float(rnorm[i] / bnorm[i]),
                # shared lockstep latency; 0 for padding rows
                wall_time_s=0.0 if pad[i] else wall,
                # breakdown marks a genuine stall (no progress even in the
                # fp64 fallback) — maxiter exhaustion stays False, matching
                # the plain engines' semantics
                breakdown=bool(stuck[i]),
                outer_refinements=int(outer[i]),
                fp64_fallback=bool(fb64[i]),
                padded=bool(pad[i]),
            ))
        if cfg.k > 0 and inner.u_carry is not None:
            self.u_carry = np.asarray(inner.u_carry, np.float32)
            self.carry_ok = (inner.carry_ok.copy()
                             if inner.carry_ok is not None else None)
        self.systems_solved += int((~zerob & ~pad).sum())
        return x_np, stats
