"""The jitted (deflated) Arnoldi cycle — the hot loop shared by GMRES and
GCRO-DR.

One call runs up to `m` Arnoldi steps of the operator (I − C Cᴴ)·A with
progressive Givens residual tracking and early exit (`lax.while_loop`), so a
solver cycle is ONE device dispatch regardless of where it converges. The
m×m eigen/LS cleanup happens on host (numpy) between cycles — O(m³) ≲ µs —
the same device/host split PETSc uses (DESIGN §4.3).

Key GCRO-DR fact exploited here: because Ĝ's recycled block [[D_k, B]] has
nonsingular diagonal D_k, the least-squares residual of
min‖Ŵᴴr − Ĝ y‖ equals the residual of the Hessenberg-only subproblem
min‖β e₁ − H̄ y₂‖ — so the SAME Givens recurrence gives the exact residual
for both GMRES (k=0) and GCRO-DR (k>0), and early exit is exact.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.solvers.operator import PreconditionedOp, StencilOp, apply_op
from repro.solvers.precond import JacobiPrecond


def _fusable(op, orthog: str) -> bool:
    """True when the whole inner iteration (precond → stencil matvec →
    C-projection → CGS2) can route through the single-launch fused kernel
    (kernels/arnoldi_step.py). Decided at trace time from the operator
    pytree structure — other operator/preconditioner kinds keep the
    composed per-op kernel path unchanged."""
    return (orthog == "cgs2"
            and isinstance(op, PreconditionedOp)
            and isinstance(op.base, StencilOp)
            and (op.precond is None or isinstance(op.precond, JacobiPrecond)))


class CycleResult(NamedTuple):
    v: jax.Array          # (m+1, n) orthonormal basis (rows)
    h: jax.Array          # (m+1, m) Hessenberg (raw, un-rotated)
    b: jax.Array          # (k, m)   B = Cᴴ A V block (k may be 0)
    j_used: jax.Array     # int — Arnoldi steps actually taken
    res_est: jax.Array    # float — exact LS residual after j_used steps
    breakdown: jax.Array  # bool — lucky breakdown hit


def _givens_apply(cs, sn, col, j):
    """Apply rotations 0..j-1 to col, then form rotation j. Returns updated
    (cs, sn, col, denom)."""

    def body(i, c):
        t = cs[i] * c[i] + sn[i] * c[i + 1]
        c = c.at[i + 1].set(-sn[i] * c[i] + cs[i] * c[i + 1])
        return c.at[i].set(t)

    col = jax.lax.fori_loop(0, j, body, col)
    a, bb = col[j], col[j + 1]
    denom = jnp.sqrt(a * a + bb * bb)
    safe = jnp.maximum(denom, jnp.finfo(col.dtype).tiny)
    cs_j = jnp.where(denom > 0, a / safe, 1.0)
    sn_j = jnp.where(denom > 0, bb / safe, 0.0)
    cs = cs.at[j].set(cs_j)
    sn = sn.at[j].set(sn_j)
    col = col.at[j].set(denom).at[j + 1].set(0.0)
    return cs, sn, col


def _mgs(v, w, j, m):
    """Modified Gram-Schmidt (paper-faithful): sequential projections."""

    def body(i, carry):
        w, h = carry
        active = (i <= j).astype(w.dtype)
        hi = active * jnp.dot(v[i], w)
        w = w - hi * v[i]
        return w, h.at[i].set(hi)

    h0 = jnp.zeros((m + 1,), w.dtype)
    return jax.lax.fori_loop(0, m + 1, body, (w, h0))


def _arnoldi_cycle_impl(op, c_rows, r0, tol_abs, *, m: int, orthog: str = "cgs2",
                        use_kernel: bool = False,
                        h_acc: str = "native") -> CycleResult:
    """Run ≤ m deflated Arnoldi steps starting from r0.

    op      : operator pytree (PreconditionedOp) — applied via apply_op
    c_rows  : (k, n) rows = C_kᴴ (k == 0 for plain GMRES)
    r0      : (n,) current residual (must be ⊥ range(C) for exact res_est)
    tol_abs : absolute residual target (rtol·‖b‖ computed by the caller)
    h_acc   : "native" accumulates the CGS2 coefficients in r0's dtype;
              "float64" keeps fp32 basis STORAGE but fp64 ACCUMULATION in
              the fused orthogonalization (KrylovConfig.cgs2_acc).

    Every array in the cycle carries r0.dtype — the precision-policy layer
    runs this whole dispatch in fp32 by handing in a casted operator and an
    fp32 residual; nothing below assumes f64.
    """
    n = r0.shape[0]
    acc_dtype = jnp.float64 if h_acc == "float64" else None
    k = c_rows.shape[0]
    dt = r0.dtype
    beta = jnp.linalg.norm(r0)
    safe_beta = jnp.maximum(beta, jnp.finfo(dt).tiny)

    v = jnp.zeros((m + 1, n), dt).at[0].set(r0 / safe_beta)
    h = jnp.zeros((m + 1, m), dt)
    b = jnp.zeros((k, m), dt)
    cs = jnp.zeros((m,), dt)
    sn = jnp.zeros((m,), dt)
    g = jnp.zeros((m + 1,), dt).at[0].set(beta)

    def cond(carry):
        v, h, b, cs, sn, g, j, res, brk = carry
        return (j < m) & (res > tol_abs) & (~brk)

    # fused single-launch inner iteration (tentpole kernel): Jacobi apply +
    # stencil matvec + C-projection + CGS2 in one dispatch. Routed ONLY when
    # the kernel path is requested AND the operator matches — the unfused
    # composition below stays byte-for-byte for every other configuration.
    fuse = use_kernel and _fusable(op, orthog)
    if fuse:
        inv_diag = (jnp.ones_like(r0) if op.precond is None
                    else op.precond.inv_diag)

    def body(carry):
        v, h, b, cs, sn, g, j, res, brk = carry
        if fuse:
            mask = (jnp.arange(m + 1) <= j).astype(dt)
            w, hcol, bj = kops.arnoldi_step(op.base.coeffs, inv_diag,
                                            c_rows, v, v[j], mask,
                                            use_kernel=True,
                                            acc_dtype=acc_dtype)
            b_new = b.at[:, j].set(bj) if k > 0 else b
        else:
            w = apply_op(op, v[j])
            if k > 0:
                bj = c_rows @ w
                w = w - c_rows.T @ bj
                b_new = b.at[:, j].set(bj)
            else:
                b_new = b
            if orthog == "cgs2":
                mask = (jnp.arange(m + 1) <= j).astype(dt)
                w, hcol = kops.fused_orthog(v, w, mask, use_kernel=use_kernel,
                                            acc_dtype=acc_dtype)
            else:
                w, hcol = _mgs(v, w, j, m)
        hj1 = jnp.linalg.norm(w)
        brk_new = hj1 < 1e-14 * safe_beta
        v = v.at[j + 1].set(w / jnp.maximum(hj1, jnp.finfo(dt).tiny))
        hcol = hcol.at[j + 1].set(hj1)
        h = h.at[:, j].set(hcol)
        # Progressive Givens on a copy of the new column → exact LS residual.
        cs, sn, col = _givens_apply(cs, sn, hcol, j)
        gj = g[j]
        g = g.at[j].set(cs[j] * gj).at[j + 1].set(-sn[j] * gj)
        res = jnp.abs(g[j + 1])
        return (v, h, b_new, cs, sn, g, j + 1, res, brk_new)

    init = (v, h, b, cs, sn, g, jnp.array(0), beta, jnp.array(False))
    v, h, b, cs, sn, g, j, res, brk = jax.lax.while_loop(cond, body, init)
    return CycleResult(v=v, h=h, b=b, j_used=j, res_est=res, breakdown=brk)


arnoldi_cycle = partial(jax.jit,
                        static_argnames=("m", "orthog", "use_kernel", "h_acc"))(
    _arnoldi_cycle_impl)


@partial(jax.jit, static_argnames=("m", "orthog", "use_kernel", "h_acc"))
def arnoldi_cycle_batched(ops, c_rows, r0, tol_abs, *, m: int,
                          orthog: str = "cgs2",
                          use_kernel: bool = False,
                          h_acc: str = "native") -> CycleResult:
    """B independent (deflated) Arnoldi cycles as ONE lockstep dispatch.

    ops     : operator pytree with a leading batch axis on every leaf
    c_rows  : (B, k, n); r0 : (B, n); tol_abs : (B,) per-chain absolute target
    Returns a CycleResult whose fields carry a leading B axis.

    Early-exit semantics: the vmapped `lax.while_loop` runs until EVERY chain
    has met its own stop condition; chains that finish early are frozen by the
    batching rule (their carry is masked), so per-chain `j_used`/`res_est` are
    exact. A chain entering with ‖r0‖ ≤ tol_abs takes 0 steps — passing
    tol_abs = +inf freezes a chain entirely (the lockstep "mask out" knob).
    """
    fn = partial(_arnoldi_cycle_impl, m=m, orthog=orthog, use_kernel=use_kernel,
                 h_acc=h_acc)
    return jax.vmap(fn)(ops, c_rows, r0, tol_abs)
