"""Version-compatibility shims over moving JAX APIs.

The codebase targets the newest stable JAX but must run on whatever the
container bakes in. Keep every cross-version branch HERE so call sites stay
clean (`with compat.set_mesh(mesh):`) and a JAX upgrade is a one-file audit.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh for `jax.jit`.

    Resolution order across JAX versions:
      * `jax.set_mesh`          (newest API; context manager form)
      * `jax.sharding.use_mesh` (transitional name)
      * `with mesh:`            (classic `Mesh.__enter__` resource env — the
                                 only spelling on jax<=0.4.x)
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` as a flat dict.

    Older JAX returns a one-element list of per-computation dicts; newer JAX
    returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}
