"""Shared datagen checkpoint IO: atomic .npz state snapshots.

Both resumable generators (`SKRGenerator` over steady systems,
`TrajectoryGenerator` over time-dependent trajectories) checkpoint the same
shape of state — progress position, solve order, completed outputs, the
solver's recycle carry, per-solve counters — differing only in field names
and output layout. The atomic write protocol and the recycle-carry
encoding live here so a format fix lands in one place.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np


class NpzCheckpointer:
    """Atomic numpy checkpoint file: write to a sibling tmp path, then
    `os.replace` to publish — a preempted writer never corrupts the last
    good snapshot."""

    def __init__(self, ckpt_dir: Optional[str], filename: str):
        assert filename.endswith(".npz")
        self.ckpt_dir = ckpt_dir
        self.filename = filename
        if ckpt_dir:
            os.makedirs(ckpt_dir, exist_ok=True)

    @property
    def path(self) -> str:
        return os.path.join(self.ckpt_dir, self.filename)

    def save(self, **arrays):
        # keep the .npz suffix on the tmp name or np.savez appends another
        tmp = os.path.join(self.ckpt_dir,
                           self.filename[:-len(".npz")] + ".tmp.npz")
        np.savez(tmp, **arrays)
        os.replace(tmp, self.path)  # atomic publish

    def load(self):
        """The np.load handle, or None when disabled / nothing saved yet."""
        if not self.ckpt_dir or not os.path.exists(self.path):
            return None
        return np.load(self.path)


def encode_carry(solver) -> np.ndarray:
    """Recycle carry as an always-array npz field ((0, 0) = no carry)."""
    return solver.u_carry if solver.u_carry is not None else np.zeros((0, 0))


def decode_carry(z) -> Optional[np.ndarray]:
    return None if z["u_carry"].size == 0 else z["u_carry"]
