"""Shared datagen checkpoint IO: atomic, checksummed, generation-rotated
.npz state snapshots.

Both resumable generators (`SKRGenerator` over steady systems,
`TrajectoryGenerator` over time-dependent trajectories) checkpoint the same
shape of state — progress position, solve order, completed outputs, the
solver's recycle carry, per-solve counters — differing only in field names
and output layout. The write protocol and the recycle-carry encoding live
here so a format fix lands in one place.

Integrity (the failure-containment layer, core/robust.py's checkpoint leg):

* **Atomic publish** — the snapshot is written to a `mkstemp` sibling (a
  UNIQUE name per writer, so two generators sharing a ckpt_dir/filename
  cannot race on a fixed tmp path) and `os.replace`d into place: a
  preempted writer never corrupts the last good snapshot.
* **Sidecar digest** — every published snapshot gets a `<name>.sha256`
  sidecar; `load()` verifies it before trusting the bytes, catching torn
  writes and bit rot that an os.replace cannot (the npz itself was intact
  when staged, but the disk underneath may not stay that way).
* **Generation rotation** — the previous snapshot survives as
  `<name>.g1.npz` (keep last-good `generations`, default 2): when the
  newest file is truncated / corrupt / stale-schema, `load()` falls back
  to the previous generation with a warning instead of bricking the
  resume. A zero-byte or unreadable npz likewise degrades to
  None-with-warning (fresh start) rather than raising.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import warnings
from typing import Optional, Sequence

import numpy as np


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class NpzCheckpointer:
    """Atomic numpy checkpoint file with sidecar digests and generation
    rotation (module docstring). `generations=1` disables rotation;
    `integrity=False` skips the digest sidecar (legacy files without one
    still load — they just cannot be verified)."""

    def __init__(self, ckpt_dir: Optional[str], filename: str,
                 generations: int = 2, integrity: bool = True):
        assert filename.endswith(".npz")
        assert generations >= 1
        self.ckpt_dir = ckpt_dir
        self.filename = filename
        self.generations = generations
        self.integrity = integrity
        if ckpt_dir:
            os.makedirs(ckpt_dir, exist_ok=True)

    @property
    def path(self) -> str:
        return os.path.join(self.ckpt_dir, self.filename)

    def gen_path(self, gen: int) -> str:
        """Generation g's path: g=0 is the live file, g>=1 are rotations."""
        if gen == 0:
            return self.path
        return self.path[:-len(".npz")] + f".g{gen}.npz"

    @staticmethod
    def _digest_path(path: str) -> str:
        return path + ".sha256"

    def _rotate(self):
        """Shift existing generations one slot down (oldest drops off),
        digests moving with their snapshots."""
        for g in range(self.generations - 1, 0, -1):
            src, dst = self.gen_path(g - 1), self.gen_path(g)
            if os.path.exists(src):
                os.replace(src, dst)
                dsrc = self._digest_path(src)
                if os.path.exists(dsrc):
                    os.replace(dsrc, self._digest_path(dst))

    def rotate_aside(self) -> Optional[str]:
        """Move every existing generation OUT of the generation ladder to
        `.staleN`-suffixed siblings that no future `save`/`_rotate` will
        ever touch, digests moving with their snapshots.

        This is the mismatch guard for `pipeline.run_resumable`: a loaded
        snapshot that belongs to a DIFFERENTLY-SIZED run must not be
        silently rotated off by the new run's next two saves — its
        completed work stays recoverable on disk under the side name.
        Returns the side path of the (former) live snapshot, or None when
        nothing was on disk."""
        if not self.ckpt_dir:
            return None
        moved = None
        for g in range(self.generations):
            src = self.gen_path(g)
            if not os.path.exists(src):
                continue
            n = 0
            while True:
                dst = src[:-len(".npz")] + f".stale{n}.npz"
                if not os.path.exists(dst):
                    break
                n += 1
            os.replace(src, dst)
            dsrc = self._digest_path(src)
            if os.path.exists(dsrc):
                os.replace(dsrc, self._digest_path(dst))
            if moved is None:
                moved = dst
        return moved

    def save(self, **arrays):
        # mkstemp: a unique tmp per writer — concurrent generators sharing
        # a dir/filename each stage privately and the LAST publish wins
        # atomically (the old fixed ".tmp.npz" name made them race).
        # np.savez appends ".npz" unless the name already ends with it.
        fd, tmp = tempfile.mkstemp(dir=self.ckpt_dir,
                                   prefix=self.filename[:-len(".npz")] + ".",
                                   suffix=".tmp.npz")
        os.close(fd)
        try:
            np.savez(tmp, **arrays)
            digest = _sha256(tmp) if self.integrity else None
            self._rotate()
            os.replace(tmp, self.path)  # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        if digest is not None:
            dtmp = tmp + ".sha256"
            with open(dtmp, "w") as f:
                f.write(digest + "\n")
            os.replace(dtmp, self._digest_path(self.path))

    def _load_one(self, path: str, required: Sequence[str]):
        """One generation, fully validated, or None with a warning."""
        if os.path.getsize(path) == 0:
            warnings.warn(f"checkpoint {path} is empty — skipping")
            return None
        dpath = self._digest_path(path)
        if self.integrity and os.path.exists(dpath):
            with open(dpath) as f:
                expect = f.read().strip()
            got = _sha256(path)
            if got != expect:
                warnings.warn(
                    f"checkpoint {path} failed digest verification "
                    f"({got[:12]} != {expect[:12]}) — skipping")
                return None
        try:
            # EAGER load into a plain dict: truncation/corruption surfaces
            # HERE (where the fallback can catch it), not later at first
            # field access deep inside the resume path
            with np.load(path, allow_pickle=False) as z:
                state = {k: z[k] for k in z.files}
        except Exception as e:  # zero-byte, truncated, not-a-zip, bad CRC
            warnings.warn(f"checkpoint {path} is unreadable ({e}) — skipping")
            return None
        missing = [k for k in required if k not in state]
        if missing:
            warnings.warn(f"checkpoint {path} has a stale schema "
                          f"(missing {missing}) — skipping")
            return None
        return state

    def load(self, required: Sequence[str] = ()):
        """The newest VALID generation as a dict of arrays, or None.

        Walks generations newest-first; a truncated / corrupt / stale-schema
        file falls back to the previous generation with a warning. `required`
        names fields a usable snapshot must carry (schema validation)."""
        if not self.ckpt_dir:
            return None
        for g in range(self.generations):
            path = self.gen_path(g)
            if not os.path.exists(path):
                continue
            state = self._load_one(path, required)
            if state is not None:
                if g > 0:
                    warnings.warn(
                        f"resuming from generation {g} checkpoint {path} "
                        "(newer generations were invalid)")
                return state
        return None


def encode_carry(solver) -> np.ndarray:
    """Recycle carry as an always-array npz field ((0, 0) = no carry)."""
    return solver.u_carry if solver.u_carry is not None else np.zeros((0, 0))


def decode_carry(z) -> Optional[np.ndarray]:
    return None if z["u_carry"].size == 0 else z["u_carry"]
