"""Subspace metrics for the theory-facing ablation (paper §5.1 / Table 2).

δ(Q, C) = ‖(I − Π_C) Π_Q‖₂ (Eq. 5) — the sine of the largest principal angle
between Q and C. Theorem 1 bounds GCRO-DR convergence by γ/(1−δ): smaller δ
between the recycled space C and the next system's target invariant subspace
Q ⇒ faster convergence. Sorting exists to shrink δ.
"""
from __future__ import annotations

import numpy as np


def orthonormalize(m: np.ndarray) -> np.ndarray:
    """SVD-based orthonormal basis of range(m) (rank-revealing: non-pivoted
    QR mis-detects rank for dependent column sets like [Re V | Im V])."""
    m = np.asarray(m, dtype=np.float64)
    if m.size == 0:
        return m.reshape(m.shape[0], 0)
    u, s, _ = np.linalg.svd(m, full_matrices=False)
    keep = s > 1e-10 * max(s.max(), 1e-300)
    return u[:, keep]


def delta_subspace(q_space: np.ndarray, c_space: np.ndarray) -> float:
    """δ(Q, C) = ‖(I − Π_C) Π_Q‖₂ ∈ [0, 1]; 0 when Q ⊆ C."""
    q = orthonormalize(q_space)
    c = orthonormalize(c_space)
    if q.shape[1] == 0:
        return 0.0
    if c.shape[1] == 0:
        return 1.0
    m = q - c @ (c.T @ q)
    return float(np.linalg.norm(m, 2))


def smallest_invariant_subspace(a_dense_or_op, k: int, n: int | None = None) -> np.ndarray:
    """Q: invariant subspace of the k smallest-magnitude eigenvalues — the
    space GCRO-DR tries to recycle (harmonic Ritz targets). Uses dense eig
    for small n, shift-invert ARPACK otherwise."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla

    if isinstance(a_dense_or_op, np.ndarray):
        a = a_dense_or_op
        if a.shape[0] <= 1500:
            evals, evecs = np.linalg.eig(a)
            order = np.argsort(np.abs(evals))
            # complete conjugate pairs so the REAL span stays A-invariant
            chosen = set(order[:k].tolist())
            for i in order[:k]:
                if abs(evals[i].imag) > 0:
                    conj = np.argmin(np.abs(evals - np.conj(evals[i])))
                    chosen.add(int(conj))
            idx = sorted(chosen)
            basis = np.concatenate(
                [np.real(evecs[:, idx]), np.imag(evecs[:, idx])], axis=1)
            return orthonormalize(basis)
        a = sp.csc_matrix(a)
    else:
        a = sp.csc_matrix(a_dense_or_op)
    evals, evecs = spla.eigs(a, k=k, sigma=0.0, which="LM")
    basis = np.concatenate([np.real(evecs), np.imag(evecs)], axis=1)
    return orthonormalize(basis)
