"""SKR — the paper's contribution as a production data-generation pipeline.

Figure-1 pipeline, end to end:
  1. sample NO parameters (problem family, batched)       pde/
  2-3. export PDE → linear systems                         pde/
  c.  SORT the systems (Algorithm 1)                       core/sorting.py
  d.  solve sequentially with GCRO-DR recycling            solvers/gcrodr.py
  e.  assemble the (input, solution) dataset               here

Time-dependent axis (beyond the paper's steady-state scope):
  t1. sample trajectory latents (IC + coefficient drift)  pde/timedep.py
  t2. export each θ-scheme implicit step as a system      pde/timedep.py
  t3. recycle ACROSS TIME STEPS within a trajectory,      core/trajectory.py
      sort trajectories by t=0 features, advance chunks
      of trajectories in lockstep (engine shared below)
  t4. assemble (u_0..u_nt) trajectory datasets for        core/trajectory.py
      autoregressive NO training (operators/fno.py
      rollout path, examples/train_fno_rollout.py)

Production posture:
  * resumable: the generation state (solver recycle space + completed
    solutions) checkpoints atomically every `ckpt_every` systems — a
    preempted datagen job restarts WARM (the recycle space survives).
  * chunk-parallel (App. E.2.2): the sorted sequence splits into contiguous
    chunks with independent recycle carries, one per worker / `data`-axis
    shard; sorting makes chunk-locality free.

Batched execution (`generate_dataset_chunked`, engine="batched"):
  The chunk-parallel path is genuinely concurrent, not simulated: the W
  chunks advance in LOCKSTEP through a `BatchedGCRODRSolver` — at step t one
  batched device program solves the t-th system of EVERY chunk (vmapped
  Arnoldi/update dispatches + one batched stencil operator), each chunk
  keeping its own recycle carry U_k. Semantics:
  * padding: chunk lengths may differ by one (linspace bounds); short chunks
    are padded with zero right-hand sides, which converge at 0 iterations,
    return x = 0, and leave that chunk's recycle carry untouched — padded
    slots are never written back to the dataset.
  * early exit: within a lockstep solve, chunks that converge first are
    frozen (masked) while the rest iterate; the reported per-system
    `wall_time_s` is therefore the shared lockstep latency (= max over
    chunks), the honest App. E.2.2 parallel-latency number.
  * workers=1 (or engine="sequential") routes through the per-system
    sequential loop — bitwise-identical to `SKRGenerator.generate` on the
    same key, and the paper-parity baseline the benchmarks compare against.

Precision policy: set `SKRConfig.krylov.inner_dtype="float32"` to run the
inner Krylov machinery of BOTH engines in fp32 (the solvers wrap it in an
fp64 iterative-refinement outer loop — see solvers/gcrodr.py). The
operators/RHS of record and the emitted dataset labels stay fp64 at
`cfg.tol`; the recycle carry is stored fp32, halving the datagen
checkpoint footprint (`ckpt_every` snapshots include the carry).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ckpt import NpzCheckpointer, decode_carry, encode_carry
from repro.core.sorting import chain_length, sort_features
from repro.pde.problems import LinearProblem, ProblemFamily
from repro.solvers.gcrodr import GCRODRSolver
from repro.solvers.operator import PreconditionedOp, as_operator
from repro.solvers.precond import make_preconditioner
from repro.solvers.types import KrylovConfig, SequenceStats


@dataclasses.dataclass(frozen=True)
class SKRConfig:
    krylov: KrylovConfig = KrylovConfig()
    sort_method: str = "greedy"     # greedy | grouped | hilbert | random | none
    precond: str = "none"
    use_kernel: bool = False
    ckpt_every: int = 0             # 0 = no datagen checkpoints
    record_recycle: bool = False    # keep per-system U snapshots (Table 2 δ)


@dataclasses.dataclass
class DataGenResult:
    inputs: np.ndarray        # (N, nx, ny) NO input channel
    solutions: np.ndarray     # (N, nx, ny) labels, in ORIGINAL sample order
    order: np.ndarray         # solve order used
    stats: SequenceStats
    sort_seconds: float
    chain_len: float
    recycle_snapshots: list   # optional [(sys_idx, U(n,k)), ...]


def _index_problem(batch: LinearProblem, i: int) -> LinearProblem:
    return jax.tree_util.tree_map(lambda a: a[i], batch)


def _problem_op_of(batch: LinearProblem, i: int):
    from repro.pde.dia import Stencil5

    return Stencil5(batch.op.coeffs[i])


class SKRGenerator:
    """Resumable SKR data generator over one problem family."""

    def __init__(self, family: ProblemFamily, cfg: SKRConfig,
                 ckpt_dir: Optional[str] = None):
        self.family = family
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        self._ckpt = NpzCheckpointer(ckpt_dir, "datagen_state.npz")

    # ------------------------------------------------------------- ckpt
    def _save_ckpt(self, pos, order, solutions, solver, iters, times):
        self._ckpt.save(pos=pos, order=order, solutions=solutions,
                        u_carry=encode_carry(solver),
                        iters=np.asarray(iters), times=np.asarray(times))

    def _load_ckpt(self):
        z = self._ckpt.load()
        if z is None:
            return None
        return dict(pos=int(z["pos"]), order=z["order"], solutions=z["solutions"],
                    u_carry=decode_carry(z),
                    iters=list(z["iters"]), times=list(z["times"]))

    # ------------------------------------------------------------- main
    def generate(self, key: jax.Array, num: int,
                 progress_cb: Optional[Callable[[int, int], None]] = None,
                 fail_at: Optional[int] = None) -> DataGenResult:
        """Generate `num` (input, solution) pairs.

        fail_at: injection hook for the fault-tolerance tests — raises after
        that many systems (simulating preemption); a rerun resumes from the
        checkpoint, recycle space intact.
        """
        cfg = self.cfg
        batch = self.family.sample_batch(key, num)
        feats = np.asarray(batch.features)

        t0 = time.perf_counter()
        order = sort_features(feats, cfg.sort_method)
        sort_s = time.perf_counter() - t0
        clen = chain_length(feats, order)

        nx, ny = self.family.nx, self.family.ny
        solutions = np.zeros((num, nx, ny))
        solver = GCRODRSolver(cfg.krylov, use_kernel=cfg.use_kernel)
        start_pos = 0
        iters, times = [], []

        state = self._load_ckpt()
        if state is not None and len(state["order"]) == num:
            order = state["order"]
            solutions = state["solutions"]
            start_pos = state["pos"]
            solver.u_carry = state["u_carry"]
            iters, times = state["iters"], state["times"]

        stats = SequenceStats()
        snapshots = []
        for pos in range(start_pos, num):
            if fail_at is not None and pos >= fail_at:
                self._save_ckpt(pos, order, solutions, solver, iters, times)
                raise RuntimeError(f"injected datagen fault at system {pos}")
            i = int(order[pos])
            prob_op = _problem_op_of(batch, i)
            b = np.asarray(batch.b[i]).reshape(-1)
            precond = make_preconditioner(cfg.precond, prob_op,
                                          use_kernel=cfg.use_kernel)
            op = PreconditionedOp(as_operator(prob_op, cfg.use_kernel), precond)
            x, st = solver.solve(op, b)
            solutions[i] = x.reshape(nx, ny)
            iters.append(st.iterations)
            times.append(st.wall_time_s)
            stats.append(st)
            if cfg.record_recycle and solver.u_carry is not None:
                snapshots.append((i, solver.u_carry.copy()))
            if cfg.ckpt_every and self.ckpt_dir and (pos + 1) % cfg.ckpt_every == 0:
                self._save_ckpt(pos + 1, order, solutions, solver, iters, times)
            if progress_cb:
                progress_cb(pos + 1, num)

        if self.ckpt_dir:
            self._save_ckpt(num, order, solutions, solver, iters, times)
        return DataGenResult(
            inputs=np.asarray(batch.no_input),
            solutions=solutions,
            order=np.asarray(order),
            stats=stats,
            sort_seconds=sort_s,
            chain_len=clen,
            recycle_snapshots=snapshots,
        )


def generate_dataset(family: ProblemFamily, key: jax.Array, num: int,
                     cfg: SKRConfig, ckpt_dir: Optional[str] = None,
                     **kw) -> DataGenResult:
    return SKRGenerator(family, cfg, ckpt_dir).generate(key, num, **kw)


def generate_dataset_baseline(family: ProblemFamily, key: jax.Array, num: int,
                              krylov: KrylovConfig, precond: str = "none") -> DataGenResult:
    """GMRES baseline (paper's comparison): identical pipeline, k=0, no sort."""
    cfg = SKRConfig(
        krylov=dataclasses.replace(krylov, k=0),
        sort_method="none",
        precond=precond,
    )
    return SKRGenerator(family, cfg).generate(key, num)


def _chunk_result(family: ProblemFamily, batch: LinearProblem, feats, sub,
                  sols, stats: SequenceStats) -> DataGenResult:
    return DataGenResult(
        inputs=np.asarray(batch.no_input)[sub],
        solutions=sols,
        order=np.asarray(sub),
        stats=stats,
        sort_seconds=0.0,
        chain_len=chain_length(feats, sub),
        recycle_snapshots=[],
    )


def _solve_chunk_sequential(family: ProblemFamily, batch: LinearProblem,
                            feats, sub, cfg: SKRConfig) -> DataGenResult:
    """One chunk through the per-system sequential solver (paper-parity
    baseline; bitwise-matches `SKRGenerator.generate` for the whole order)."""
    solver = GCRODRSolver(cfg.krylov, use_kernel=cfg.use_kernel)
    stats = SequenceStats()
    nx, ny = family.nx, family.ny
    sols = np.zeros((len(sub), nx, ny))
    for pos, i in enumerate(sub):
        prob_op = _problem_op_of(batch, int(i))
        b = np.asarray(batch.b[int(i)]).reshape(-1)
        precond = make_preconditioner(cfg.precond, prob_op,
                                      use_kernel=cfg.use_kernel)
        op = PreconditionedOp(as_operator(prob_op, cfg.use_kernel), precond)
        x, st = solver.solve(op, b)
        sols[pos] = x.reshape(nx, ny)
        stats.append(st)
    return _chunk_result(family, batch, feats, sub, sols, stats)


def _solve_chunks_batched(family: ProblemFamily, batch: LinearProblem,
                          feats, subs, cfg: SKRConfig) -> list[DataGenResult]:
    """All chunks in lockstep: one batched device program per system "row"
    (see module docstring, Batched execution)."""
    from repro.pde.dia import Stencil5
    from repro.solvers.batched import BatchedGCRODRSolver
    from repro.solvers.operator import StencilOp
    from repro.solvers.precond import make_preconditioner_batched

    nx, ny = family.nx, family.ny
    num = int(np.asarray(batch.b).shape[0])
    workers = len(subs)
    length = max(len(s) for s in subs)
    coeffs_all = jnp.asarray(batch.op.coeffs)
    b_all = np.asarray(batch.b).reshape(num, -1)

    solver = BatchedGCRODRSolver(cfg.krylov, use_kernel=cfg.use_kernel)
    sols = [np.zeros((len(s), nx, ny)) for s in subs]
    stats = [SequenceStats() for _ in subs]
    all_st5 = Stencil5(coeffs_all)
    for t in range(length):
        idx = np.array([int(s[t]) if t < len(s) else -1 for s in subs])
        clamped = np.where(idx >= 0, idx, 0)
        st5 = all_st5.take(jnp.asarray(clamped))        # (W, 5, nx, ny)
        precond = make_preconditioner_batched(cfg.precond, st5,
                                              use_kernel=cfg.use_kernel)
        ops = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel), precond)
        bvec = b_all[clamped].copy()
        bvec[idx < 0] = 0.0                             # padded slots
        xs, st_list = solver.solve_batch(ops, jnp.asarray(bvec))
        for w, i in enumerate(idx):
            if i < 0:
                continue
            sols[w][t] = xs[w].reshape(nx, ny)
            stats[w].append(st_list[w])
    return [_chunk_result(family, batch, feats, subs[w], sols[w], stats[w])
            for w in range(workers)]


def generate_dataset_chunked(family: ProblemFamily, key: jax.Array, num: int,
                             cfg: SKRConfig, workers: int = 8,
                             engine: str = "batched") -> list[DataGenResult]:
    """App. E.2.2 task decomposition: sort once, split the sorted order into
    `workers` contiguous chunks, each chunk gets its OWN recycle carry.

    engine="batched" (default) advances all chunks concurrently through the
    lockstep `BatchedGCRODRSolver`; engine="sequential" is the per-system
    loop (chunks back-to-back — the paper-parity simulation). `workers=1`
    always uses the sequential path: it is bitwise-identical to
    `SKRGenerator.generate`. Configs the lockstep engine cannot batch
    (`ilu_host`, `ritz_refresh="final"`) auto-route to the sequential path.
    """
    if engine not in ("batched", "sequential"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "batched" and (
            cfg.precond == "ilu_host"
            or (cfg.krylov.k > 0 and cfg.krylov.ritz_refresh == "final")):
        engine = "sequential"
    batch = family.sample_batch(key, num)
    feats = np.asarray(batch.features)
    order = sort_features(feats, cfg.sort_method)
    bounds = np.linspace(0, num, workers + 1).astype(int)
    subs = [order[bounds[w]: bounds[w + 1]] for w in range(workers)]
    if engine == "sequential" or workers == 1:
        return [_solve_chunk_sequential(family, batch, feats, sub, cfg)
                for sub in subs]
    return _solve_chunks_batched(family, batch, feats, subs, cfg)
