"""SKR — the paper's contribution as a production data-generation pipeline.

Figure-1 pipeline, end to end:
  1. sample NO parameters (problem family, batched)       pde/
  2-3. export PDE → linear systems                         pde/
  c.  SORT the systems (Algorithm 1)                       core/sorting.py
  d.  solve sequentially with GCRO-DR recycling            solvers/gcrodr.py
  d'. EXPAND retired anchors into K derived labels each   core/expand.py
      (operator action in solution space, DiffOAS —
      optional `SKRConfig.expand` axis; f' = A u' by one
      batched SpMV, no solver in the loop)
  e.  assemble the (input, solution) dataset              here
      (+ the expanded `DataGenResult.labels` LabelSet
      with per-label provenance when d' is on)

Time-dependent axis (beyond the paper's steady-state scope):
  t1. sample trajectory latents (IC + coefficient drift)  pde/timedep.py
  t2. export each θ-scheme implicit step as a system      pde/timedep.py
  t3. recycle ACROSS TIME STEPS within a trajectory,      core/trajectory.py
      sort trajectories by t=0 features, advance chunks
      of trajectories in lockstep (engine shared below)
  t4. assemble (u_0..u_nt) trajectory datasets for        core/trajectory.py
      autoregressive NO training (operators/fno.py
      rollout path, examples/train_fno_rollout.py)

Production posture:
  * resumable: the generation state (solver recycle space + completed
    solutions) checkpoints atomically every `ckpt_every` systems — a
    preempted datagen job restarts WARM (the recycle space survives).
  * chunk-parallel (App. E.2.2): the sorted sequence splits into contiguous
    chunks with independent recycle carries, one per worker / `data`-axis
    shard; sorting makes chunk-locality free.

Scheduling lives in `core/pipeline.py` (sort → chain partition → lockstep
packing → engine dispatch); this module supplies the steady-state WORK
ADAPTER (`SteadyWork`) and keeps the historical entry points as thin
frontends. Engines (`generate_dataset_chunked(engine=...)`):
  * "sequential" — chunks back-to-back through the per-system solver
    (paper-parity simulation; `workers=1` is bitwise-identical to
    `SKRGenerator.generate`).
  * "batched" — the W chunks advance in LOCKSTEP through a
    `BatchedGCRODRSolver`: at step t one batched device program solves the
    t-th system of EVERY chunk, each chunk keeping its own recycle carry.
    Shorter chunks are padded with zero right-hand sides (0 iterations,
    x = 0, carry untouched; padded slots are never written back and are
    excluded from the per-chunk stats). Host-side row assembly (operator
    gather + stacked preconditioner) is prefetched one row ahead of the
    device solves.
  * "sharded" — the lockstep batch with its chain axis sharded over the
    `data` mesh axis: one SPMD program per row across every device (test
    on CPU with XLA_FLAGS=--xla_force_host_platform_device_count=8; on a
    single device it degenerates to "batched").

Device-resident cycle (the lockstep engines' dispatch shape): each GCRO-DR
cycle of the batched engine is ONE fused device program — Arnoldi sweep,
stacked Hessenberg LS, stacked harmonic-Ritz refresh (solvers/devlinalg.py)
and the masked per-chain control flow all run on-device; the ONLY blocking
host sync in the loop is a 4-bool flag fetch per cycle that decides
continuation (plus one at entry and one bulk fetch at finalize —
`SolveStats.host_syncs` tracks the budget, asserted ≤ 2 + cycles by
tests/test_transfer_guard.py). The sequential engine keeps the historical
host-mediated cleanup (hostlinalg.py) as the bitwise reference.

Observability (repro.obs, opt-in via `obs.enable()`): the pipeline stages
above are telemetry tap points — `core/pipeline.py` records spans for
steps c/d (sample, sort, chain_partition, prepare_row on the prefetch
thread, execute_row, checkpoint), the solvers attach per-cycle
convergence histories to every `SolveStats` (device-buffered rings in the
lockstep engine, drained inside its finalize fetch so the sync budget
above is unchanged — tests/test_transfer_guard.py runs telemetry-on), and
every `solve_batch` dispatch records live/padded row occupancy
(lockstep utilization). Export with `obs.export_chrome_trace()` /
`obs.export_jsonl()`; disabled, all of it compiles out (bitwise-identical
numerics — tests/test_obs.py). See README "Observability".

Precision policy: set `SKRConfig.krylov.inner_dtype="float32"` to run the
inner Krylov machinery of ALL engines in fp32 (the solvers wrap it in an
fp64 iterative-refinement outer loop — see solvers/gcrodr.py). The
operators/RHS of record and the emitted dataset labels stay fp64 at
`cfg.tol`; the recycle carry is stored fp32, halving the datagen
checkpoint footprint (`ckpt_every` snapshots include the carry).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import pipeline
from repro.core.ckpt import NpzCheckpointer
from repro.core.expand import ExpandConfig, Expander, LabelSet
from repro.core.robust import FaultPlan, RetryPolicy, is_healthy
from repro.core.sorting import chain_length
from repro.pde.problems import LinearProblem, ProblemFamily
from repro.solvers.gcrodr import GCRODRSolver
from repro.solvers.operator import (PreconditionedOp, StencilOp, as_operator)
from repro.solvers.precond import (make_preconditioner,
                                   make_preconditioner_batched)
from repro.solvers.types import KrylovConfig, SequenceStats


@dataclasses.dataclass(frozen=True)
class SKRConfig:
    krylov: KrylovConfig = KrylovConfig()
    sort_method: str = "greedy"     # greedy | grouped | hilbert | random | none
    precond: str = "none"
    use_kernel: bool = False
    ckpt_every: int = 0             # 0 = no datagen checkpoints
    record_recycle: bool = False    # keep per-system U snapshots (Table 2 δ)
    # failure containment (core/robust.py): the retry/escalation ladder is a
    # config axis like precision — None disables containment entirely
    # (pre-containment jaxprs; no retries, no lockstep quarantine).
    retry: Optional[RetryPolicy] = RetryPolicy()
    # "flag": ship every label, non-trustworthy ones flagged in
    # DataGenResult.label_ok; "exclude": drop them from the emitted dataset.
    strict_labels: str = "flag"
    # label expansion (core/expand.py): fan each healthy anchor solution
    # into k derived (f' = A u', u') labels. None (the default) is OFF —
    # the pipeline runs bitwise-identical to pre-expansion builds.
    expand: Optional[ExpandConfig] = None

    def __post_init__(self):
        assert self.strict_labels in ("flag", "exclude"), self.strict_labels


@dataclasses.dataclass
class DataGenResult:
    inputs: np.ndarray        # (N, nx, ny) NO input channel
    solutions: np.ndarray     # (N, nx, ny) labels, in ORIGINAL sample order
    order: np.ndarray         # solve order used
    stats: SequenceStats
    sort_seconds: float
    chain_len: float
    recycle_snapshots: list   # optional [(sys_idx, U(n,k)), ...]
    # per-row label trustworthiness (converged at tol, finite, not
    # quarantined) — aligned with `solutions`' first axis; all-True after
    # strict_labels="exclude" filtering. None only from legacy callers.
    label_ok: Optional[np.ndarray] = None
    # expanded labels (core/expand.py) when cfg.expand is set: every
    # healthy anchor's k+1 (f' = A u', u') pairs with per-label provenance
    # (anchor_idx / kind / t). None when expansion is off.
    labels: Optional[LabelSet] = None


def _index_problem(batch: LinearProblem, i: int) -> LinearProblem:
    return jax.tree_util.tree_map(lambda a: a[i], batch)


def _problem_op_of(batch: LinearProblem, i: int):
    from repro.pde.dia import Stencil5

    return Stencil5(batch.op.coeffs[i])


class SteadyWork(pipeline.WorkAdapter):
    """Pipeline work adapter for steady-state linear systems (Figure 1).

    Owns the sampled `LinearProblem` batch and the per-engine solve
    plumbing; `core/pipeline.py` owns sorting, chain partitioning, lockstep
    padding/prefetch, sharding and checkpoint cadence."""

    item_noun = "system"
    ckpt_key = "solutions"   # historical checkpoint field name

    def __init__(self, family: ProblemFamily, cfg: SKRConfig):
        self.family = family
        self.cfg = cfg
        self.batch: Optional[LinearProblem] = None
        self.feats: Optional[np.ndarray] = None
        self.outputs: Optional[np.ndarray] = None
        self.snapshots: list = []
        self.expander: Optional[Expander] = None

    def _make_expander(self) -> Optional[Expander]:
        ecfg = getattr(self.cfg, "expand", None)
        if ecfg is None:
            return None
        return Expander(ecfg, self.family.nx, self.family.ny,
                        use_kernel=self.cfg.use_kernel)

    # ------------------------------------------------------- sampling
    def sample(self, key: jax.Array, num: int) -> np.ndarray:
        self.batch = self.family.sample_batch(key, num)
        self.feats = np.asarray(self.batch.features)
        return self.feats

    # ------------------------------------- sequential (single-chain)
    def alloc_full(self, num: int):
        self.outputs = np.zeros((num, self.family.nx, self.family.ny))
        self.label_ok = np.ones(num, dtype=bool)
        self.expander = self._make_expander()

    def restore_outputs(self, arr: np.ndarray):
        # caveat: label_ok is not checkpointed — items completed BEFORE a
        # resume default to trustworthy (pre-containment checkpoints never
        # shipped unconverged labels, so the default is honest)
        self.outputs = arr

    def _assemble(self, i: int):
        """(op, b) for system `i`, applying any pending one-shot faults.
        Called FRESH per retry attempt (solve_one_guarded's make_problem
        contract) so an injected transient poisons only one assembly."""
        cfg = self.cfg
        prob_op = _problem_op_of(self.batch, i)
        b = np.asarray(self.batch.b[i]).reshape(-1)
        if self.fault is not None:
            b = self.fault.apply_rhs(i, b)
            coeffs = np.asarray(prob_op.coeffs)
            poisoned = self.fault.apply_operator(i, coeffs)
            if poisoned is not coeffs:
                from repro.pde.dia import Stencil5

                prob_op = Stencil5(jnp.asarray(poisoned))
        precond = make_preconditioner(cfg.precond, prob_op,
                                      use_kernel=cfg.use_kernel)
        op = PreconditionedOp(as_operator(prob_op, cfg.use_kernel), precond)
        return op, b

    def _solve_one(self, i: int, solver: GCRODRSolver):
        if self.fault is not None:
            self.fault.apply_carry(i, solver)
        policy = getattr(self.cfg, "retry", None)
        if policy is None:
            return solver.solve(*self._assemble(i))
        from repro.core.robust import solve_one_guarded

        return solve_one_guarded(solver, lambda: self._assemble(i), policy,
                                 label=f"{self.item_noun} {i}")

    def solve_item(self, i: int, solver: GCRODRSolver,
                   stats: SequenceStats) -> list:
        x, st = self._solve_one(i, solver)
        self.outputs[i] = x.reshape(self.family.nx, self.family.ny)
        self.label_ok[i] = is_healthy(st)
        stats.append(st)
        if self.cfg.record_recycle and solver.u_carry is not None:
            self.snapshots.append((i, solver.u_carry.copy()))
        return [st]

    # -------------------------------- label expansion (pipeline hooks)
    def expand_item(self, i: int, solver):
        """Post-solve phase, sequential engine: fan system `i`'s retired
        anchor into k derived labels (only healthy anchors expand)."""
        if self.expander is None or not self.label_ok[i]:
            return
        self.expander.expand_one(self.batch.op.coeffs[i], self.outputs[i],
                                 i, chain=0)

    def expand_row(self, solver, t: int, idx: np.ndarray):
        """Post-solve phase, lockstep engines: ONE expansion wave over the
        retired row — operator stack and solutions are still device-resident
        (`prepare_row`'s upload / the solver's `x_device` stash), so the
        wave adds no H2D traffic and no host syncs."""
        if self.expander is None or self._row_ctx is None:
            return
        coeffs, healthy = self._row_ctx
        self._row_ctx = None
        if solver.x_device is None:
            return
        self.expander.wave(coeffs, solver.x_device,
                           np.where(idx >= 0, idx, 0), healthy)

    # ---- checkpoint extras: expanded labels + provenance ------------
    def ckpt_extra(self) -> dict:
        return self.expander.ckpt_arrays() if self.expander else {}

    def ckpt_required(self) -> tuple:
        return ("exp_f", "exp_u", "exp_anchor", "exp_kind", "exp_t") \
            if self.expander else ()

    def restore_extra(self, state: dict):
        if self.expander is not None and "exp_f" in state:
            self.expander.restore(state)

    def full_result(self, order, stats, sort_s, clen) -> DataGenResult:
        order = np.asarray(order)
        inputs = np.asarray(self.batch.no_input)
        sols, label_ok = self.outputs, self.label_ok
        if getattr(self.cfg, "strict_labels", "flag") == "exclude" \
                and not label_ok.all():
            # arrays are in ORIGINAL sample order here: filter them by the
            # mask; `order` keeps the surviving solves' original indices
            order = order[label_ok[order]]
            inputs, sols = inputs[label_ok], sols[label_ok]
            label_ok = np.ones(len(sols), dtype=bool)
        return DataGenResult(
            inputs=inputs,
            solutions=sols,
            order=order,
            stats=stats,
            sort_seconds=sort_s,
            chain_len=clen,
            recycle_snapshots=self.snapshots,
            label_ok=label_ok,
            labels=self.expander.result() if self.expander else None,
        )

    # ---------------------------------------------- chunked engines
    def solve_chunk_sequential(self, sub) -> DataGenResult:
        """One chunk through the per-system sequential solver (paper-parity
        baseline; bitwise-matches the single-chain generator per chunk)."""
        solver = self.make_solver()
        stats = SequenceStats()
        nx, ny = self.family.nx, self.family.ny
        sols = np.zeros((len(sub), nx, ny))
        expander = self._make_expander()   # chunk-local expansion chain
        for pos, i in enumerate(sub):
            x, st = self._solve_one(int(i), solver)
            sols[pos] = x.reshape(nx, ny)
            stats.append(st)
            if expander is not None and is_healthy(st):
                expander.expand_one(self.batch.op.coeffs[int(i)], sols[pos],
                                    int(i), chain=0)
        return self._chunk_result(sub, sols, stats, expander=expander)

    def begin_lockstep(self, subs):
        from repro.pde.dia import Stencil5

        nx, ny = self.family.nx, self.family.ny
        num = int(np.asarray(self.batch.b).shape[0])
        self._subs = subs
        self._sols = [np.zeros((len(s), nx, ny)) for s in subs]
        self._stats = [SequenceStats() for _ in subs]
        self._all_st5 = Stencil5(jnp.asarray(self.batch.op.coeffs))
        self._b_all = np.asarray(self.batch.b).reshape(num, -1)
        self._requeue = []   # (chain, row, original index) to re-solve
        self.expander = self._make_expander()
        self._row_ctx = None   # (row coeffs device, healthy mask) for waves

    def prepare_row(self, t: int, idx: np.ndarray):
        """HOST-side row assembly (runs on the prefetch thread): gather the
        row's operators, factor the stacked preconditioner, pack the RHS."""
        cfg = self.cfg
        clamped = np.where(idx >= 0, idx, 0)
        st5 = self._all_st5.take(jnp.asarray(clamped))   # (W, 5, nx, ny)
        if self.fault is not None and self.fault.nan_operator:
            from repro.pde.dia import Stencil5

            coeffs, dirty = np.array(st5.coeffs, copy=True), False
            for w, i in enumerate(idx):
                if i < 0:
                    continue
                poisoned = self.fault.apply_operator(int(i), coeffs[w])
                if poisoned is not coeffs[w]:
                    coeffs[w], dirty = poisoned, True
            if dirty:   # the preconditioner factors the poisoned operator
                st5 = Stencil5(jnp.asarray(coeffs))
        precond = make_preconditioner_batched(cfg.precond, st5,
                                              use_kernel=cfg.use_kernel)
        ops = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel), precond)
        bvec = self._b_all[clamped].copy()
        bvec[idx < 0] = 0.0                              # padded slots
        if self.fault is not None:
            for w, i in enumerate(idx):
                if i >= 0:
                    bvec[w] = self.fault.apply_rhs(int(i), bvec[w])
        return ops, jnp.asarray(bvec)

    def execute_row(self, solver, t: int, idx: np.ndarray, prepared):
        ops, bvec = prepared
        nx, ny = self.family.nx, self.family.ny
        if self.fault is not None:
            for w, i in enumerate(idx):
                if i >= 0:
                    self.fault.apply_carry(int(i), solver, chain=w)
        xs, st_list = solver.solve_batch(ops, bvec, padded_rows=idx < 0)
        healthy = np.zeros(len(idx), dtype=bool)
        for w, i in enumerate(idx):
            if i < 0:
                continue                                 # padding row
            self._sols[w][t] = xs[w].reshape(nx, ny)
            self._stats[w].append(st_list[w])
            healthy[w] = is_healthy(st_list[w])
            # any unhealthy solve (quarantined OR plain non-convergence)
            # goes to the requeue — the sequential engine would have walked
            # the ladder for it, so the lockstep engine must too
            if getattr(self.cfg, "retry", None) is not None \
                    and not is_healthy(st_list[w]):
                self._requeue.append((w, t, int(i)))
        if self.expander is not None:
            # stash for the pipeline's expand_row phase: the row's operator
            # stack (already device-resident from prepare_row) + health mask
            self._row_ctx = (ops.base.coeffs, healthy)

    def requeue_quarantined(self):
        """Containment requeue: systems the lockstep engine quarantined
        mid-dispatch are re-solved on a FRESH sequential chain through the
        escalation ladder, the in-dispatch attempt counting as attempt 0 —
        so the ladder walk (and `escalation_path`) matches what the
        sequential engine would have taken under the same fault."""
        if not self._requeue:
            return
        from repro.core.robust import solve_one_guarded

        policy = getattr(self.cfg, "retry", None) or RetryPolicy()
        nx, ny = self.family.nx, self.family.ny
        solver = self.make_solver()
        for w, t, i in self._requeue:
            solver.u_carry = None    # cold per system: no cross-requeue state
            # chain w's stats hold exactly one (non-padded) record per row,
            # so per_system[t] IS row t's in-dispatch attempt
            x, st = solve_one_guarded(
                solver, lambda i=i: self._assemble(i), policy,
                failed_stats=self._stats[w].per_system[t],
                label=f"{self.item_noun} {i}")
            self._sols[w][t] = np.asarray(x).reshape(nx, ny)
            self._stats[w].per_system[t] = st
            if self.expander is not None and is_healthy(st):
                # the in-dispatch attempt was unhealthy, so the wave masked
                # this anchor out; the recovered solve expands here instead
                self.expander.drop_anchor(i)
                self.expander.expand_one(self.batch.op.coeffs[i],
                                         self._sols[w][t], i, chain=w)
        obs.counter_add("health.requeued", len(self._requeue))
        self._requeue = []

    def chunk_result(self, w: int) -> DataGenResult:
        return self._chunk_result(self._subs[w], self._sols[w],
                                  self._stats[w], expander=self.expander,
                                  chain=w)

    def _chunk_result(self, sub, sols, stats, expander=None,
                      chain=None) -> DataGenResult:
        sub = np.asarray(sub, dtype=np.int64)
        label_ok = np.array([is_healthy(s) for s in stats.solved],
                            dtype=bool) if len(stats.solved) == len(sub) \
            else np.ones(len(sub), dtype=bool)
        if getattr(self.cfg, "strict_labels", "flag") == "exclude" \
                and not label_ok.all():
            sub, sols = sub[label_ok], sols[label_ok]
            label_ok = np.ones(len(sub), dtype=bool)
        return DataGenResult(
            inputs=np.asarray(self.batch.no_input)[sub],
            solutions=sols,
            order=sub,
            stats=stats,
            sort_seconds=0.0,
            chain_len=chain_length(self.feats, sub),
            recycle_snapshots=[],
            label_ok=label_ok,
            labels=expander.result(chain=chain) if expander else None,
        )


class SteadyStream(SteadyWork):
    """Streaming work adapter for steady systems (core/serve.py): the
    scheduler dispatches WAVES — one system per occupied slot — instead of
    pre-packed lockstep rows. Row assembly is `prepare_row` verbatim (the
    wave's slot→item map plays the row index), so the streamed solve per
    item is the same device program as the offline lockstep path. Every
    live slot's item finishes in one dispatch (`done` all-live).

    Streaming v1 posture: solver-level containment stays armed (quarantine,
    divergence guards via `cfg.retry`), but the offline requeue ladder does
    not run — an unhealthy solve flags `label_ok[i]` False and the stream
    moves on. Results land per ITEM (`outputs[i]`), not per chain."""

    stream_prefetchable = True   # assembly is item-pure: safe to run ahead

    def begin_stream(self, slots: int):
        from repro.pde.dia import Stencil5

        nx, ny = self.family.nx, self.family.ny
        num = int(np.asarray(self.batch.b).shape[0])
        self._all_st5 = Stencil5(jnp.asarray(self.batch.op.coeffs))
        self._b_all = np.asarray(self.batch.b).reshape(num, -1)
        self.outputs = np.zeros((num, nx, ny))
        self.label_ok = np.zeros(num, dtype=bool)
        self.item_iters = np.zeros(num, dtype=np.int64)
        self.stats = SequenceStats()

    def start_item(self, w: int, i: int):
        """Steady items carry no per-slot state — the wave assembly reads
        everything from the sampled batch."""

    def assemble(self, slot_items: np.ndarray):
        return self.prepare_row(0, np.asarray(slot_items, dtype=np.int64))

    def apply(self, solver, slot_items: np.ndarray, prepared) -> np.ndarray:
        ops, bvec = prepared
        nx, ny = self.family.nx, self.family.ny
        xs, st_list = solver.solve_batch(ops, bvec,
                                         padded_rows=slot_items < 0)
        done = np.zeros(len(slot_items), dtype=bool)
        for w, i in enumerate(slot_items):
            if i < 0:
                continue
            i = int(i)
            self.outputs[i] = xs[w].reshape(nx, ny)
            self.label_ok[i] = is_healthy(st_list[w])
            self.item_iters[i] = st_list[w].iterations
            self.stats.append(st_list[w])
            done[w] = True
        return done


class SKRGenerator:
    """Resumable SKR data generator over one problem family (a thin
    frontend over `core/pipeline.run_resumable`)."""

    def __init__(self, family: ProblemFamily, cfg: SKRConfig,
                 ckpt_dir: Optional[str] = None):
        self.family = family
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        self._ckpt = NpzCheckpointer(ckpt_dir, "datagen_state.npz")

    def generate(self, key: jax.Array, num: int,
                 progress_cb: Optional[Callable[[int, int], None]] = None,
                 fail_at: Optional[int] = None,
                 fault: Optional[FaultPlan] = None,
                 mismatch: str = "rotate") -> DataGenResult:
        """Generate `num` (input, solution) pairs.

        fail_at: injection hook for the fault-tolerance tests — raises after
        that many systems (simulating preemption); a rerun resumes from the
        checkpoint, recycle space intact.
        fault: full seeded `FaultPlan` (chaos tests) — NaN poisoning of
        chosen systems' RHS/operator/carry, preemption with optional
        checkpoint corruption; see core/robust.py.
        mismatch: policy when a loaded checkpoint belongs to a run of a
        different size — see `pipeline.run_resumable`.
        """
        work = SteadyWork(self.family, self.cfg)
        return pipeline.run_resumable(work, key, num, ckpt=self._ckpt,
                                      ckpt_every=self.cfg.ckpt_every,
                                      progress_cb=progress_cb,
                                      fail_at=fail_at, fault=fault,
                                      mismatch=mismatch)


def generate_dataset(family: ProblemFamily, key: jax.Array, num: int,
                     cfg: SKRConfig, ckpt_dir: Optional[str] = None,
                     **kw) -> DataGenResult:
    return SKRGenerator(family, cfg, ckpt_dir).generate(key, num, **kw)


def generate_dataset_baseline(family: ProblemFamily, key: jax.Array, num: int,
                              krylov: KrylovConfig, precond: str = "none") -> DataGenResult:
    """GMRES baseline (paper's comparison): identical pipeline, k=0, no sort."""
    cfg = SKRConfig(
        krylov=dataclasses.replace(krylov, k=0),
        sort_method="none",
        precond=precond,
    )
    return SKRGenerator(family, cfg).generate(key, num)


def generate_dataset_chunked(family: ProblemFamily, key: jax.Array, num: int,
                             cfg: SKRConfig, workers: int = 8,
                             engine: str = "batched",
                             fault: Optional[FaultPlan] = None,
                             ) -> list[DataGenResult]:
    """App. E.2.2 task decomposition: sort once, split the sorted order into
    `workers` contiguous chunks, each chunk gets its OWN recycle carry.

    engine="batched" (default) advances all chunks concurrently through the
    lockstep `BatchedGCRODRSolver`; engine="sharded" additionally shards the
    chunk-chain axis over the `data` mesh (all available devices);
    engine="sequential" is the per-system loop (chunks back-to-back — the
    paper-parity simulation). `workers=1` always uses the sequential path:
    it is bitwise-identical to `SKRGenerator.generate`. Configs the lockstep
    engine cannot batch (`ilu_host`, `ritz_refresh="final"`) auto-route to
    the sequential path.
    """
    work = SteadyWork(family, cfg)
    work.fault = fault
    return pipeline.run_chunked(work, key, num, workers, engine)
