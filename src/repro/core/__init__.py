"""core — the paper's primary contribution: SKR (Sorting + Krylov subspace
Recycling) as a first-class, resumable, chunk-parallel data-generation
pipeline for neural-operator training."""
from repro.core.metrics import delta_subspace, smallest_invariant_subspace
from repro.core.pipeline import plan_chains, run_chunked, run_resumable
from repro.core.skr import (DataGenResult, SKRConfig, SKRGenerator,
                            SteadyWork, generate_dataset,
                            generate_dataset_baseline,
                            generate_dataset_chunked)
from repro.core.sorting import (chain_length, greedy_sort, grouped_greedy_sort,
                                hilbert_sort, sort_features)
from repro.core.trajectory import (TrajConfig, TrajectoryGenerator,
                                   TrajectoryWork, TrajResult,
                                   generate_trajectories,
                                   generate_trajectories_baseline,
                                   generate_trajectories_chunked,
                                   march_trajectory)

__all__ = [
    "delta_subspace", "smallest_invariant_subspace",
    "plan_chains", "run_chunked", "run_resumable",
    "DataGenResult", "SKRConfig", "SKRGenerator", "SteadyWork",
    "generate_dataset", "generate_dataset_baseline", "generate_dataset_chunked",
    "TrajConfig", "TrajectoryGenerator", "TrajectoryWork", "TrajResult",
    "generate_trajectories", "generate_trajectories_baseline",
    "generate_trajectories_chunked", "march_trajectory",
    "chain_length", "greedy_sort", "grouped_greedy_sort", "hilbert_sort",
    "sort_features",
]
