"""Unified datagen pipeline: one scheduler for every recycling workload.

Both datagen subsystems — steady systems (core/skr.py) and time-dependent
trajectories (core/trajectory.py) — run the SAME four-stage schedule; only
the per-item solve differs. This module owns the schedule once:

  1. SORT   the work items by similarity features (core/sorting.py,
             paper Algorithm 1 — what makes recycling pay),
  2. CHAIN  partition the sorted order into contiguous recycle chains
             (paper App. E.2.2: each chain owns an independent carry U_k),
  3. PACK   align the chains into lockstep rows, padding shorter chains
             with zero right-hand sides (0 iterations, x = 0, carry
             untouched — the engines' first-class padding no-op). Rows
             whose chains advance at DIFFERENT RATES inside one row
             (adaptive-Δt trajectories: per-chain step sequences) carry a
             per-chunk `PhaseMask`: chains that finished their row's work
             (reached t_end / exhausted their step budget) flip to padded
             rows while the rest keep stepping in the same SPMD dispatch,
  4. DISPATCH to an engine:
       sequential  chains back-to-back through the per-system
                   `GCRODRSolver` (paper-parity baseline; `workers=1`
                   is bitwise-identical to the plain generators)
       batched     all chains in lockstep through `BatchedGCRODRSolver`
                   (one vmapped device program per row)
       sharded     the lockstep batch with its chain axis SHARDED over
                   the `data` mesh axis (`distributed.sharding
                   .ChainSharding`): every row dispatch is one SPMD
                   program across all devices. Chains never exchange
                   Krylov information, so the axis is embarrassingly
                   data-parallel — the chain count is padded with empty
                   chains to divide the device count, and per-chain
                   carries/residuals live chain-sharded on device while
                   the small host eigen/LS solves stay replicated per
                   shard.

The lockstep engines overlap HOST work against DEVICE solves: while the
device advances row t, a single prefetch thread assembles row t+1 on host
(operator gather, stacked preconditioner factorization, RHS packing) — the
classic input-pipeline overlap, here for solver rows.

Workload specifics ride in a WORK ADAPTER owned by the domain module
(`skr.SteadyWork`, `trajectory.TrajectoryWork`) so this scheduler never
imports a PDE. The adapter protocol:

  sample(key, num) -> feats        sample the batch; return sort features
  solve_chunk_sequential(sub)      one chain, per-system loop -> result
  begin_lockstep(subs)             allocate per-chain output buffers
  prepare_row(t, idx) -> prepared  HOST-side row assembly (prefetchable)
  execute_row(solver, t, idx, prepared)   device solve(s) + writeback
  expand_row / expand_item         POST-SOLVE label expansion phase
                                   (core/expand.py — fan retired anchors
                                   into derived labels; default no-op)
  chunk_result(w) -> result        finalize chain w
  alloc_full / restore_outputs / solve_item / full_result
                                   the resumable single-chain path
  item_noun, ckpt_key              checkpoint format compatibility
  ckpt_extra / ckpt_required / restore_extra
                                   extra snapshot arrays (expanded labels
                                   + provenance ride the atomic npz)

Solver construction and the lockstep-compatibility predicate (`batchable`,
`make_solver`, `make_lockstep_solver`) are shared scaffolding on the
`WorkAdapter` base below — one copy of the routing rule for all workloads.

Resumability (`run_resumable`) is the old generators' loop hoisted here
verbatim: atomic npz snapshots every `ckpt_every` items (progress, order,
outputs, recycle carry) with the exact historical field names, so existing
checkpoints keep loading.
"""
from __future__ import annotations

import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional

import numpy as np

from repro import obs
from repro.core.ckpt import decode_carry, encode_carry
from repro.core.sorting import chain_length, sort_features
from repro.solvers.types import SequenceStats

ENGINES = ("sequential", "batched", "sharded")


class WorkAdapter:
    """Shared adapter scaffolding: solver construction and the
    lockstep-compatibility predicate live HERE so the rule cannot drift
    between workloads. Subclasses must define `cfg` (with `.krylov`,
    `.precond`, `.use_kernel`) plus the workload hooks in the module
    docstring."""

    item_noun = "item"
    ckpt_key = "outputs"
    # fault: optional core.robust.FaultPlan — chaos-test injection, set by
    # the generator frontends; None in production. Work adapters consult it
    # at every data-assembly point (RHS, operator, carry).
    fault = None

    def batchable(self) -> bool:
        """False routes the lockstep engines to sequential: `ilu_host` is a
        single-slot host callback, `ritz_refresh="final"` needs per-chain
        last-cycle snapshots the batched solver does not keep."""
        cfg = self.cfg
        return not (cfg.precond == "ilu_host"
                    or (cfg.krylov.k > 0
                        and cfg.krylov.ritz_refresh == "final"))

    def make_solver(self):
        from repro.solvers.gcrodr import GCRODRSolver

        return GCRODRSolver(self.cfg.krylov, use_kernel=self.cfg.use_kernel)

    def make_lockstep_solver(self, sharding=None):
        from repro.solvers.batched import BatchedGCRODRSolver

        return BatchedGCRODRSolver(self.cfg.krylov,
                                   use_kernel=self.cfg.use_kernel,
                                   sharding=sharding,
                                   policy=getattr(self.cfg, "retry", None))

    def requeue_quarantined(self):
        """Containment hook: re-solve items the lockstep engines quarantined
        mid-dispatch (fresh chain, escalation ladder) before results
        finalize. Default no-op; workload adapters override."""

    # ---- label expansion (core/expand.py): post-solve phase hooks ----
    # Default no-ops — the pipeline calls them unconditionally so the
    # expansion stage is a SCHEDULED phase, not workload-private plumbing.
    # SteadyWork expands retired anchors here; TrajectoryWork expands
    # inside its row march instead (the per-step operator A(t) is only
    # live there) and leaves these as no-ops.
    def expand_item(self, i: int, solver):
        """After one sequential solve: fan item `i` into derived labels."""

    def expand_row(self, solver, t: int, idx: np.ndarray):
        """After one lockstep row retires: expand the row's anchors in one
        device wave (operator stack + solutions still device-resident)."""

    # ---- checkpoint extras (expanded labels + provenance) -------------
    def ckpt_extra(self) -> dict:
        """Extra arrays folded into every resumable snapshot."""
        return {}

    def ckpt_required(self) -> tuple:
        """Extra REQUIRED checkpoint fields (schema validation): when
        expansion is on, a snapshot without labels must not load — losing
        the completed items' labels silently."""
        return ()

    def restore_extra(self, state: dict):
        """Adopt the extra arrays of a loaded snapshot."""


class PhaseMask:
    """Live slot table for lockstep rows whose chains advance at
    different rates — and for the streaming scheduler (core/serve.py),
    whose slots retire and REFILL mid-flight.

    Offline (the adaptive-Δt trajectory engine): fixed-Δt lockstep rows
    stay aligned by construction; with per-chain adaptive stepping each
    chain takes its own number of internal steps per row, so the engine
    iterates until EVERY chain finished and masks the early finishers: a
    finished (or never-live padding-slot) chain rides along as a zero-RHS
    padded row — `SolveStats.padded`, 0 iterations, x = 0, recycle carry
    untouched — while the live chains keep stepping inside the same SPMD
    dispatch. Shutdown is monotone on that path: `finish` only.

    Streaming: each slot holds the chain id currently riding it
    (`chain[w]`, -1 when free/padding); `refill(w, chain)` re-opens a
    retired slot for a new chain mid-flight. `finished` counts genuine
    active→inactive retirements — never-live sharding fill slots do NOT
    count (they were never a chain), and a refilled slot counts once per
    chain it retires. One copy of the bookkeeping lives here so workload
    adapters cannot drift."""

    def __init__(self, live: np.ndarray, chains: np.ndarray | None = None):
        self.active = np.asarray(live, dtype=bool).copy()
        n = self.active.shape[0]
        # offline callers identify slot w with chain w; the streaming
        # scheduler assigns its own ids via refill()
        self.chain = np.full(n, -1, dtype=np.int64)
        if chains is None:
            self.chain[self.active] = np.nonzero(self.active)[0]
        else:
            self.chain[self.active] = np.asarray(chains, dtype=np.int64)
        self.finished = 0  # chains retired through finish(), cumulative

    @property
    def any_active(self) -> bool:
        return bool(self.active.any())

    @property
    def padded_rows(self) -> np.ndarray:
        """The `solve_batch(padded_rows=...)` mask: every inactive chain."""
        return ~self.active

    def finish(self, w: int):
        """Chain `w` is done with this row (trajectory complete or step
        budget exhausted) — padded from the next dispatch on. Finishing a
        never-live or already-finished slot is a no-op for the finished
        count: only a genuine active→inactive transition retires a chain."""
        if self.active[w]:
            self.finished += 1
        self.active[w] = False
        # occupancy timeline sample: how many chains remain live after
        # this finish (renders as a counter track in the Chrome trace)
        obs.counter("phase_active", {"active": int(self.active.sum()),
                                     "finished": self.finished},
                    cat="pipeline")

    def refill(self, w: int, chain: int):
        """Slot `w` adopts chain `chain` mid-flight — the streaming
        scheduler's slot-recycling primitive. The offline engines never
        call this, so their shutdown stays monotone."""
        if self.active[w]:
            raise ValueError(f"refill of live slot {w} "
                             f"(still riding chain {int(self.chain[w])})")
        self.active[w] = True
        self.chain[w] = int(chain)
        obs.counter("phase_active", {"active": int(self.active.sum()),
                                     "finished": self.finished},
                    cat="pipeline")


def plan_chains(order: np.ndarray, workers: int) -> List[np.ndarray]:
    """Split a sorted order into `workers` contiguous recycle chains
    (App. E.2.2 task decomposition; lengths differ by at most one)."""
    n = len(order)
    bounds = np.linspace(0, n, workers + 1).astype(int)
    return [order[bounds[w]: bounds[w + 1]] for w in range(workers)]


def resolve_engine(work, engine: str) -> str:
    """Validate the engine name; auto-route configs the lockstep engines
    cannot batch (`ilu_host`, `ritz_refresh="final"`) to sequential."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (one of {ENGINES})")
    if engine != "sequential" and not work.batchable():
        return "sequential"
    return engine


def _row_index(subs: List[np.ndarray], t: int) -> np.ndarray:
    """Lockstep row t: the t-th item of every chain, -1 marks padding."""
    return np.array([int(s[t]) if t < len(s) else -1 for s in subs])


def _prepare_row_traced(work, t, idx):
    """prepare_row under a span — on the prefetch thread this records with
    the EXECUTOR's thread id, so the Chrome trace shows host row assembly
    on its own track, visually overlapped with the main thread's
    execute_row spans (the claim the trace exists to audit)."""
    with obs.span("prepare_row", cat="pipeline", row=t):
        return work.prepare_row(t, idx)


def _run_lockstep(work, subs, solver, prefetch: bool = True):
    """Advance all chains through the lockstep rows, overlapping the next
    row's host-side assembly against the current row's device solves."""
    length = max((len(s) for s in subs), default=0)
    if length == 0:
        return
    if not prefetch:
        for t in range(length):
            idx = _row_index(subs, t)
            prepared = _prepare_row_traced(work, t, idx)
            with obs.span("execute_row", cat="pipeline", row=t):
                work.execute_row(solver, t, idx, prepared)
            with obs.span("expand_row", cat="pipeline", row=t):
                work.expand_row(solver, t, idx)
        return
    # manually managed executor: on an execute_row error the in-flight
    # prepare for row t+1 must not delay (or, under a FaultPlan, mask) the
    # real failure — a `with` block's __exit__ waits for it. Cancel it if
    # still queued and shut down WITHOUT waiting; an already-running
    # prepare drains on its daemon thread while the error propagates now.
    ex = ThreadPoolExecutor(max_workers=1, thread_name_prefix="prefetch")
    fut = None
    try:
        idx = _row_index(subs, 0)
        fut = ex.submit(_prepare_row_traced, work, 0, idx)
        for t in range(length):
            with obs.span("prefetch_wait", cat="pipeline", row=t):
                prepared = fut.result()
            cur_idx = idx
            if t + 1 < length:
                idx = _row_index(subs, t + 1)
                fut = ex.submit(_prepare_row_traced, work, t + 1, idx)
            with obs.span("execute_row", cat="pipeline", row=t):
                work.execute_row(solver, t, cur_idx, prepared)
            # post-solve label expansion: submits device work only (the
            # wave), so it overlaps the prefetch thread like the solve did
            with obs.span("expand_row", cat="pipeline", row=t):
                work.expand_row(solver, t, cur_idx)
    except BaseException:
        if fut is not None:
            fut.cancel()
        ex.shutdown(wait=False, cancel_futures=True)
        raise
    else:
        ex.shutdown(wait=True)


def run_chunked(work, key, num: int, workers: int, engine: str,
                prefetch: bool = True) -> list:
    """The chunk-parallel pipeline: sort once, partition into `workers`
    chains, dispatch to the chosen engine. Returns one result per chain
    (sharding fill chains are dropped)."""
    engine = resolve_engine(work, engine)
    with obs.span("sample", cat="pipeline", num=num):
        feats = work.sample(key, num)
    with obs.span("sort", cat="pipeline", num=num,
                  method=work.cfg.sort_method):
        order = sort_features(feats, work.cfg.sort_method)
    with obs.span("chain_partition", cat="pipeline", workers=workers):
        subs = plan_chains(order, workers)
    if engine == "sequential" or workers == 1:
        out = []
        for w, sub in enumerate(subs):
            with obs.span("solve_chunk", cat="pipeline", chunk=w):
                out.append(work.solve_chunk_sequential(sub))
        return out

    sharding = None
    fill = 0
    if engine == "sharded":
        from repro.distributed.sharding import ChainSharding, datagen_mesh

        mesh = datagen_mesh()
        if mesh is not None:
            sharding = ChainSharding(mesh)
            # the chain axis must divide the shard count: pad with EMPTY
            # chains — every row sees them as zero-RHS padding slots
            fill = -len(subs) % sharding.num_shards
            subs = subs + [np.zeros(0, dtype=np.int64)] * fill

    solver = work.make_lockstep_solver(sharding)
    with obs.span("row_buffers", cat="pipeline", chains=len(subs)):
        work.begin_lockstep(subs)
    _run_lockstep(work, subs, solver, prefetch=prefetch)
    # containment: chains the lockstep engine quarantined mid-solve get
    # their systems re-solved on fresh sequential chains (the escalation
    # ladder) before results finalize — the requeue leg of core/robust.py
    with obs.span("requeue_quarantined", cat="pipeline"):
        work.requeue_quarantined()
    with obs.span("chunk_finalize", cat="pipeline"):
        return [work.chunk_result(w) for w in range(len(subs) - fill)]


def run_resumable(work, key, num: int, ckpt=None, ckpt_every: int = 0,
                  progress_cb: Optional[Callable[[int, int], None]] = None,
                  fail_at: Optional[int] = None, fault=None,
                  mismatch: str = "rotate"):
    """The resumable single-chain pipeline (the plain generators' engine):
    sort, then solve the whole order on ONE recycling chain, snapshotting
    state atomically every `ckpt_every` items. `fail_at` is the simple
    fault-injection hook (raises after that many items; a rerun resumes
    warm from the checkpoint, recycle space intact); `fault` is the full
    seeded `core.robust.FaultPlan` — data poisoning is applied by the work
    adapter at assembly points, while `preempt_at` simulates a mid-run kill
    here (after the snapshot, optionally corrupting the just-published
    checkpoint per `fault.ckpt_corrupt` to exercise generation fallback).

    `mismatch` governs a loaded snapshot whose `order` length differs from
    `num` — completed work from a DIFFERENTLY-SIZED run that this run's
    next save would otherwise destroy:
      "rotate"  (default) warn loudly and move the stale snapshot (all
                generations) aside to `.staleN.npz` names outside the
                rotation ladder, then start fresh — nothing is overwritten
      "error"   raise RuntimeError — for callers that would rather stop
                than ever touch a mismatched checkpoint
      "discard" the old silent behavior, now an explicit acknowledgment:
                ignore the snapshot and let the next save overwrite it"""
    cfg = work.cfg
    work.fault = fault
    if fault is not None and fault.preempt_at is not None and fail_at is None:
        fail_at = int(fault.preempt_at)
    with obs.span("sample", cat="pipeline", num=num):
        feats = work.sample(key, num)

    t0 = time.perf_counter()
    with obs.span("sort", cat="pipeline", num=num, method=cfg.sort_method):
        order = sort_features(feats, cfg.sort_method)
    sort_s = time.perf_counter() - t0
    clen = chain_length(feats, order)

    work.alloc_full(num)
    solver = work.make_solver()
    start_pos = 0
    iters, times = [], []
    enabled = ckpt is not None and ckpt.ckpt_dir

    def _save(pos):
        with obs.span("checkpoint", cat="pipeline", pos=int(pos)):
            ckpt.save(pos=pos, order=order, u_carry=encode_carry(solver),
                      iters=np.asarray(iters), times=np.asarray(times),
                      **{work.ckpt_key: work.outputs},
                      **work.ckpt_extra())

    required = ("pos", "order", "iters", "times", "u_carry", work.ckpt_key) \
        + tuple(work.ckpt_required())
    state = ckpt.load(required=required) if enabled else None
    if state is not None and len(state["order"]) != num:
        msg = (f"checkpoint {ckpt.path} belongs to a "
               f"{len(state['order'])}-{work.item_noun} run but this run "
               f"asked for {num} {work.item_noun}s")
        if mismatch == "error":
            raise RuntimeError(msg)
        if mismatch == "discard":
            warnings.warn(msg + " — discarding it (mismatch='discard'); "
                          "the next save will overwrite it")
        else:
            aside = ckpt.rotate_aside()
            warnings.warn(msg + f" — stale snapshot preserved at {aside}; "
                          "starting fresh")
        state = None
    if state is not None:
        order = state["order"]
        work.restore_outputs(state[work.ckpt_key])
        work.restore_extra(state)
        start_pos = int(state["pos"])
        solver.u_carry = decode_carry(state)
        iters, times = list(state["iters"]), list(state["times"])

    stats = SequenceStats()
    for pos in range(start_pos, num):
        if fail_at is not None and pos >= fail_at:
            if enabled:
                _save(pos)
                if fault is not None and fault.ckpt_corrupt is not None:
                    # the preemption tore the write it raced with: corrupt
                    # the just-published newest generation so the rerun must
                    # take the integrity fallback path
                    from repro.core.robust import corrupt_file

                    corrupt_file(ckpt.gen_path(0), mode=fault.ckpt_corrupt,
                                 seed=fault.seed)
            raise RuntimeError(
                f"injected datagen fault at {work.item_noun} {pos}")
        i = int(order[pos])
        with obs.span("solve_item", cat="pipeline", pos=pos):
            sts = list(work.solve_item(i, solver, stats))
        with obs.span("expand_item", cat="pipeline", pos=pos):
            work.expand_item(i, solver)
        for st in sts:
            iters.append(st.iterations)
            times.append(st.wall_time_s)
        if ckpt_every and enabled and (pos + 1) % ckpt_every == 0:
            _save(pos + 1)
        if progress_cb:
            progress_cb(pos + 1, num)

    if enabled:
        _save(num)
    return work.full_result(order, stats, sort_s, clen)
