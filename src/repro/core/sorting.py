"""The Sorting Algorithm (paper Algorithm 1 + the scalable variants of
App. E.2.2).

Orders a set of linear systems so consecutive systems have maximally similar
parameter matrices P^(i) (Frobenius distance on flattened features), which is
what makes the recycled subspace C_k relevant for the NEXT system. Per the
paper's §5.2 analysis, sorting need not be optimal — a cheap greedy pass
suffices because the recycled small-eigenvalue subspace is perturbation-
robust.

Variants:
  greedy         O(N²) vectorized nearest-neighbor chain (Algorithm 1)
  grouped_greedy O(N·G) — split into groups of ~group_size by a cheap 1-D
                 projection, greedy inside each, concatenate (paper §4.1)
  hilbert        FFT/PCA → 2-D → Hilbert-curve index (+greedy inside
                 buckets) — the App. E.2.2 recipe for 10⁷-scale datasets;
                 embarrassingly parallel across buckets
  none / random  ablation baselines (Table 2)
"""
from __future__ import annotations

import numpy as np


def pairwise_sq_dists(feats: np.ndarray) -> np.ndarray:
    sq = np.sum(feats**2, axis=1)
    d = sq[:, None] + sq[None, :] - 2.0 * feats @ feats.T
    return np.maximum(d, 0.0)


def greedy_sort(feats: np.ndarray, start: int = 0) -> np.ndarray:
    """Algorithm 1: nearest-neighbor chain under Frobenius distance.

    Vectorized O(N²) — each step is one masked argmin over a cached distance
    row (no N×N matrix materialized beyond one row at a time)."""
    feats = np.asarray(feats, dtype=np.float64)
    n = feats.shape[0]
    order = np.empty(n, dtype=np.int64)
    used = np.zeros(n, dtype=bool)
    order[0] = start
    used[start] = True
    cur = start
    sq = np.sum(feats**2, axis=1)
    for i in range(1, n):
        d = sq + sq[cur] - 2.0 * (feats @ feats[cur])
        d[used] = np.inf
        cur = int(np.argmin(d))
        order[i] = cur
        used[cur] = True
    return order


def grouped_greedy_sort(feats: np.ndarray, group_size: int = 1000) -> np.ndarray:
    """Paper §4.1 cost-saving strategy: partition by the leading principal
    coordinate into contiguous groups, greedy-sort within each group (the
    groups are independent ⇒ parallel across workers), concatenate."""
    feats = np.asarray(feats, dtype=np.float64)
    n = feats.shape[0]
    if n <= group_size:
        return greedy_sort(feats)
    proj = _leading_projection(feats)
    coarse = np.argsort(proj, kind="stable")
    out = []
    for g0 in range(0, n, group_size):
        idx = coarse[g0: g0 + group_size]
        local = greedy_sort(feats[idx])
        out.append(idx[local])
    return np.concatenate(out)


def hilbert_sort(feats: np.ndarray, bits: int = 8, greedy_bucket: int = 256) -> np.ndarray:
    """App. E.2.2: 'FFT dimension reduction + fractal division + greedy'.

    Reduce to 2-D (two leading principal/Fourier coordinates), quantize to a
    2^bits grid, order by Hilbert-curve index (locality-preserving), then
    greedy-refine inside fixed-size buckets. Every stage is data-parallel
    except the tiny per-bucket greedy."""
    feats = np.asarray(feats, dtype=np.float64)
    n = feats.shape[0]
    xy = _reduce_2d(feats)
    side = 1 << bits
    q = np.empty((n, 2), dtype=np.int64)
    for c in range(2):
        v = xy[:, c]
        lo, hi = v.min(), v.max()
        q[:, c] = np.clip(((v - lo) / max(hi - lo, 1e-300) * (side - 1)), 0,
                          side - 1).astype(np.int64)
    h = hilbert_index(q[:, 0], q[:, 1], bits)
    order = np.argsort(h, kind="stable")
    if greedy_bucket and n > greedy_bucket:
        out = []
        for g0 in range(0, n, greedy_bucket):
            idx = order[g0: g0 + greedy_bucket]
            out.append(idx[greedy_sort(feats[idx])])
        order = np.concatenate(out)
    return order


def hilbert_index(x: np.ndarray, y: np.ndarray, bits: int) -> np.ndarray:
    """Vectorized xy→d Hilbert index (classic bit-twiddling, numpy)."""
    d = np.zeros_like(x)
    x = x.copy()
    y = y.copy()
    s = 1 << (bits - 1)
    while s > 0:
        rx = ((x & s) > 0).astype(np.int64)
        ry = ((y & s) > 0).astype(np.int64)
        d += s * s * ((3 * rx) ^ ry)
        # rotate quadrant: where ry==0 (flip if rx==1, then swap x/y)
        mask = ry == 0
        flip = mask & (rx == 1)
        x = np.where(flip, s - 1 - x, x)
        y = np.where(flip, s - 1 - y, y)
        xs = np.where(mask, y, x)
        ys = np.where(mask, x, y)
        x, y = xs, ys
        s >>= 1
    return d


def sort_features(feats: np.ndarray, method: str = "greedy", **kw) -> np.ndarray:
    method = method.lower()
    n = np.asarray(feats).shape[0]
    if method in ("none", "identity"):
        return np.arange(n, dtype=np.int64)
    if method == "random":
        rng = np.random.default_rng(kw.get("seed", 0))
        return rng.permutation(n)
    if method == "greedy":
        return greedy_sort(feats, start=kw.get("start", 0))
    if method == "grouped":
        return grouped_greedy_sort(feats, group_size=kw.get("group_size", 1000))
    if method == "hilbert":
        return hilbert_sort(feats, bits=kw.get("bits", 8),
                            greedy_bucket=kw.get("greedy_bucket", 256))
    raise KeyError(f"unknown sort method {method!r}")


def nearest_features(feat: np.ndarray, heads: np.ndarray,
                     mask: np.ndarray | None = None):
    """One INCREMENTAL Algorithm-1 step for the online scheduler
    (core/serve.py): Frobenius distance from a single feature row to every
    candidate chain-head feature.

    Returns ``(w, d)`` — the index of the nearest unmasked head and the
    full distance vector (masked heads at +inf). ``w`` is -1 when no head
    is eligible. Distances are actual norms (not squared) so they compare
    directly against `typical_nn_distance`-calibrated budgets."""
    feat = np.asarray(feat, dtype=np.float64).reshape(-1)
    heads = np.asarray(heads, dtype=np.float64).reshape(-1, feat.shape[0])
    d = np.sum(heads ** 2, axis=1) + feat @ feat - 2.0 * (heads @ feat)
    d = np.sqrt(np.maximum(d, 0.0))
    if mask is not None:
        d = np.where(np.asarray(mask, dtype=bool), d, np.inf)
    if d.size == 0 or not np.isfinite(d).any():
        return -1, d
    return int(np.argmin(d)), d


def typical_nn_distance(feats: np.ndarray, sample: int = 256,
                        seed: int = 0) -> float:
    """Median nearest-neighbor Frobenius distance over a (sub)sampled
    cloud — the natural scale for the streaming scheduler's similarity
    budget: a request within ~this distance of a chain head is about as
    similar as consecutive systems in a greedy-sorted offline order."""
    feats = np.asarray(feats, dtype=np.float64)
    n = feats.shape[0]
    if n < 2:
        return 0.0
    rng = np.random.default_rng(seed)
    idx = (np.arange(n) if n <= sample
           else rng.choice(n, size=sample, replace=False))
    sq = np.sum(feats ** 2, axis=1)
    nn = np.empty(len(idx))
    for j, i in enumerate(idx):
        d = sq + sq[i] - 2.0 * (feats @ feats[i])
        d[i] = np.inf
        nn[j] = np.sqrt(max(float(d.min()), 0.0))
    return float(np.median(nn))


def chain_length(feats: np.ndarray, order: np.ndarray) -> float:
    """Total Frobenius path length — the quantity greedy sorting minimizes
    (lower ⇒ more consecutive similarity ⇒ better recycling)."""
    f = np.asarray(feats, dtype=np.float64)[np.asarray(order)]
    return float(np.sum(np.linalg.norm(np.diff(f, axis=0), axis=1)))


# ---------------------------------------------------------------- helpers

def _leading_projection(feats: np.ndarray) -> np.ndarray:
    c = feats - feats.mean(0)
    # one power-iteration pass is plenty for an ordering key
    v = c.T @ c[:, 0] if c.shape[1] > 1 else np.ones(1)
    v = v / max(np.linalg.norm(v), 1e-300)
    for _ in range(3):
        v = c.T @ (c @ v)
        v = v / max(np.linalg.norm(v), 1e-300)
    return c @ v


def _reduce_2d(feats: np.ndarray) -> np.ndarray:
    c = feats - feats.mean(0)
    if c.shape[1] == 1:
        return np.stack([c[:, 0], np.zeros_like(c[:, 0])], axis=1)
    # two dominant right singular vectors via subspace iteration
    rng = np.random.default_rng(0)
    v = rng.standard_normal((c.shape[1], 2))
    for _ in range(5):
        v, _ = np.linalg.qr(c.T @ (c @ v))
    return c @ v
