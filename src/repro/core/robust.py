"""Failure containment: per-system health states, retry/escalation ladder,
and seeded fault injection for the datagen pipeline.

SKR's value proposition is that thousands of systems SHARE state — sorted
chains, recycle carries, lockstep rows — which means one diverging system
or one poisoned carry can corrupt many neighbors, and a label that silently
fails to converge degrades the downstream neural operator. This module is
the containment layer the streaming-scheduler and multi-host ROADMAP items
both presuppose:

* **Health states** — every solve lands in one of four states derived from
  its `SolveStats`:

      healthy      converged, finite residual, no retries
      retrying     converged only after walking the escalation ladder
                   (``retries > 0``; the rungs taken are in
                   ``escalation_path``)
      quarantined  the ladder was exhausted (or the deadline hit) without a
                   converged, finite solution — the label is NOT trustworthy
                   and ``strict_labels`` decides whether it ships flagged or
                   is excluded
      failed       quarantined AND the final iterate is non-finite (nothing
                   usable was produced)

* **Escalation ladder** (`RetryPolicy`) — a bounded, DETERMINISTIC retry
  sequence applied on non-convergence or a non-finite/diverged residual:

      drop_carry   discard the recycle carry and retry cold (a poisoned or
                   stale U_k is the most common shared-state failure)
      fp64_inner   re-run with ``inner_dtype="float64"`` (mixed-precision
                   configs only — skipped when already fp64)
      grow_m       double the Krylov cycle length m (and m_max), the
                   stagnation escape hatch

  The ladder is a config axis exactly like precision was in PR 3: the same
  `RetryPolicy` drives the sequential engine (`solve_one_guarded` wraps
  every solve), the lockstep engine (in-dispatch divergence quarantine +
  pipeline requeue through this module), and the sharded engine — so all
  three take IDENTICAL escalation paths under the same faults
  (tests/test_robust.py asserts it).

* **Fault injection** (`FaultPlan`) — the `fail_at` preemption hook grown
  into a seeded plan: NaN into the RHS / operator / recycle carry of chosen
  systems (one-shot transients, targeting ORIGINAL sample indices so every
  engine poisons the same systems), simulated preemption after N items, and
  byte-level checkpoint corruption (`corrupt_file`). Chaos tests drive the
  whole pipeline through these plans; see the `chaos` pytest marker.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import numpy as np

from repro import obs

# health states (derived — see health_of)
HEALTHY = "healthy"
RETRYING = "retrying"
QUARANTINED = "quarantined"
FAILED = "failed"

# the full ladder, in escalation order
LADDER = ("drop_carry", "fp64_inner", "grow_m")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded deterministic escalation for unhealthy solves.

    max_retries   : total retry attempts across all rungs (the ladder is
                    walked rung by rung; a rung that does not apply — e.g.
                    fp64_inner on an fp64 config — is skipped without
                    consuming an attempt)
    ladder        : escalation rungs, in order (subset of LADDER)
    deadline_iters: cap on CUMULATIVE Krylov iterations across the original
                    attempt + every retry; 0 = no deadline. Hitting it
                    quarantines immediately (bounded worst-case work per
                    system — the lockstep row cannot be held hostage)
    divergence_ratio: a residual norm above ``ratio * ||b||`` counts as
                    diverged even while finite — the lockstep engine's
                    in-dispatch quarantine threshold
    """

    max_retries: int = 3
    ladder: Tuple[str, ...] = LADDER
    deadline_iters: int = 0
    divergence_ratio: float = 1e8

    def __post_init__(self):
        assert self.max_retries >= 0
        assert all(r in LADDER for r in self.ladder), self.ladder
        assert self.deadline_iters >= 0
        assert self.divergence_ratio > 1.0


def health_of(stats) -> str:
    """Classify one SolveStats into the four-state machine."""
    if stats.quarantined:
        return FAILED if not np.isfinite(stats.rel_residual) else QUARANTINED
    if stats.retries > 0:
        return RETRYING
    return HEALTHY


def is_healthy(stats) -> bool:
    """Converged with a finite residual — the label is trustworthy."""
    return bool(stats.converged) and np.isfinite(stats.rel_residual)


def _rung_applies(rung: str, cfg) -> bool:
    if rung == "fp64_inner":
        return cfg.inner_dtype == "float32"
    return True


def _rung_cfg(rung: str, cfg):
    """The KrylovConfig one rung up the ladder from `cfg`."""
    if rung == "fp64_inner":
        return dataclasses.replace(cfg, inner_dtype="float64")
    if rung == "grow_m":
        m2 = 2 * cfg.m
        m_max = max(cfg.m_max, m2) if cfg.m_max else 0
        return dataclasses.replace(cfg, m=m2, m_max=m_max)
    return cfg  # drop_carry reuses the base config


def solve_one_guarded(solver, make_problem, policy: RetryPolicy,
                      failed_stats=None, label: str = ""):
    """Retry/escalation driver around one sequential `GCRODRSolver.solve`.

    make_problem: () -> (op, b) — called FRESH per attempt, so a one-shot
        injected fault (FaultPlan) poisons only the first assembly and
        retries see clean data, exactly like a transient corruption.
    failed_stats: a SolveStats of an attempt that already failed elsewhere
        (the lockstep engine's quarantine requeue hands its in-dispatch
        attempt here) — counted as the original attempt, so the ladder
        walk — and hence `escalation_path` — is IDENTICAL across engines.

    Returns (x, stats): stats carries retries / escalation_path /
    quarantined; prior attempts' work (iterations, matvecs, syncs) is
    folded in via `SolveStats.merge_inner` so sequence totals stay honest.

    An attempt that RAISES numerically (NaN data can blow up the host-side
    least-squares as `LinAlgError` before any residual exists) counts as a
    failed attempt — containment means the ladder keeps walking.
    """
    from repro.solvers.types import SolveStats

    def _attempt(op, b):
        try:
            return solver.solve(op, b)
        except (np.linalg.LinAlgError, FloatingPointError,
                ZeroDivisionError):
            obs.counter_add("health.solve_exceptions")
            return None, SolveStats(breakdown=True)   # converged=False, ∞ res

    path = []
    spent = []  # failed attempts' stats, folded into the final record

    if failed_stats is None:
        op, b = make_problem()
        x, stats = _attempt(op, b)
        if is_healthy(stats):
            return x, stats
        spent.append(stats)
    else:
        spent.append(failed_stats)
        x, stats = None, failed_stats

    base_cfg = solver.cfg
    retries = 0
    try:
        for rung in policy.ladder:
            if retries >= policy.max_retries:
                break
            if not _rung_applies(rung, base_cfg):
                continue
            if policy.deadline_iters and \
                    sum(s.iterations for s in spent) >= policy.deadline_iters:
                break
            # every rung retries COLD: the recycle carry is the shared
            # state most likely poisoned, so it is quarantined on the
            # first rung and stays dropped up the ladder
            solver.u_carry = None
            solver.cfg = _rung_cfg(rung, base_cfg)
            path.append(rung)
            retries += 1
            obs.counter_add("health.retries")
            op, b = make_problem()
            x, stats = _attempt(op, b)
            if is_healthy(stats):
                break
            spent.append(stats)
    finally:
        solver.cfg = base_cfg

    healthy = stats is not None and is_healthy(stats)
    if not healthy:
        # ladder exhausted — quarantine; ship the last finite iterate (or
        # zeros) so downstream shapes hold, flagged untrustworthy
        stats = spent[-1]
        stats.quarantined = True
        solver.u_carry = None   # never let a failed chain's carry escape
        obs.counter_add("health.quarantined")
        if x is None or not np.all(np.isfinite(np.asarray(x))):
            op, b = make_problem()   # faults are one-shot: b is clean here
            x = np.zeros(np.asarray(b).reshape(-1).shape)
    for s in spent:
        if s is not stats:
            stats.merge_inner(s)
    stats.retries = retries
    stats.escalation_path = tuple(path)
    return np.asarray(x), stats


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultPlan:
    """Seeded, one-shot fault injection for chaos tests.

    Solve-level faults target ORIGINAL sample indices (the index into the
    sampled batch, before sorting/chaining) so the sequential, batched and
    sharded engines poison the SAME systems regardless of how the sorted
    order was partitioned — that is what makes cross-engine escalation-path
    equality a testable claim. Each fault fires ONCE: the first time the
    poisoned quantity is assembled (a transient corruption); retries and
    requeues see clean data.

    nan_rhs / nan_operator / nan_carry : original system indices to poison
    step        : for trajectory datagen, the save-step index at which the
                  solve-level faults fire (steady datagen ignores it)
    preempt_at  : raise (simulated preemption) after this many completed
                  items in the resumable pipeline — the old `fail_at` hook
    ckpt_corrupt: "truncate" | "flip" | "zero" — corrupt the NEWEST
                  checkpoint generation when the preemption fires,
                  simulating a kill mid-write
    seed        : drives the poisoned-entry positions
    """

    nan_rhs: Tuple[int, ...] = ()
    nan_operator: Tuple[int, ...] = ()
    nan_carry: Tuple[int, ...] = ()
    step: int = 0
    preempt_at: Optional[int] = None
    ckpt_corrupt: Optional[str] = None
    seed: int = 0

    def __post_init__(self):
        self._fired: set = set()

    def _fire(self, kind: str, i: int, step: int) -> bool:
        key = (kind, int(i), int(step))
        targets = getattr(self, kind)
        if int(i) not in targets or step != self.step or key in self._fired:
            return False
        self._fired.add(key)
        obs.counter_add(f"faults.{kind}")
        return True

    def _pos(self, i: int, size: int) -> int:
        return int(np.random.default_rng(self.seed ^ (int(i) + 1))
                   .integers(size))

    def apply_rhs(self, i: int, b: np.ndarray, step: int = 0) -> np.ndarray:
        """Poison one RHS entry of system `i` (first assembly only)."""
        if not self._fire("nan_rhs", i, step):
            return b
        b = np.array(b, dtype=np.float64, copy=True)
        b.reshape(-1)[self._pos(i, b.size)] = np.nan
        return b

    def apply_operator(self, i: int, coeffs: np.ndarray,
                       step: int = 0) -> np.ndarray:
        """Poison one stencil coefficient of system `i`."""
        if not self._fire("nan_operator", i, step):
            return coeffs
        coeffs = np.array(coeffs, dtype=np.float64, copy=True)
        coeffs.reshape(-1)[self._pos(i, coeffs.size)] = np.nan
        return coeffs

    def apply_carry(self, i: int, solver, chain: Optional[int] = None,
                    step: int = 0):
        """Poison the recycle carry about to warm-start system `i` (the
        whole carried space for a sequential solver; chain `chain`'s rows
        for a lockstep solver). Both engines' warm-start rank gates drop a
        non-finite carry and restart cold, so this fault recovers WITHOUT
        a retry — the regression the gates exist for."""
        if solver.u_carry is None or not self._fire("nan_carry", i, step):
            return
        u = np.array(solver.u_carry, copy=True)
        if chain is None:
            u.reshape(-1)[self._pos(i, u.size)] = np.nan
        else:
            u[chain].reshape(-1)[self._pos(i, u[chain].size)] = np.nan
        solver.u_carry = u


def corrupt_file(path: str, mode: str = "truncate", seed: int = 0):
    """Byte-level corruption of an on-disk artifact (chaos tests).

    truncate: cut the file to half its length (a kill mid-write)
    flip    : XOR 16 random bytes (bit rot / torn write)
    zero    : truncate to zero bytes (the classic empty-npz brick)
    """
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    elif mode == "flip":
        rng = np.random.default_rng(seed)
        with open(path, "r+b") as f:
            data = bytearray(f.read())
            for p in rng.integers(0, max(len(data), 1), size=16):
                data[p] ^= 0xFF
            f.seek(0)
            f.write(bytes(data))
    elif mode == "zero":
        with open(path, "wb"):
            pass
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
