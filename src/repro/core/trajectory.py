"""Trajectory datagen engine: Krylov-subspace recycling ACROSS TIME STEPS.

The steady-state SKR pipeline (core/skr.py) makes systems similar by
SORTING them; a time-dependent workload (pde/timedep.py) gets similarity
for free — inside one trajectory the θ-scheme matrices A_t = I + θΔt L(t)
drift slowly with t. This engine exploits both levels:

  1. WITHIN a trajectory, the GCRO-DR carry U_k rides across time steps:
     step n+1 warm-starts from the subspace harvested at step n (the
     textbook recycling regime — A_{n+1} = A_n + O(Δt)).
  2. ACROSS trajectories, the carry also survives trajectory boundaries:
     trajectories are SORTED by their t=0 features (IC latent + operator
     latent, Algorithm 1 on trajectory granularity), so the space carried
     out of trajectory i's last step is relevant to trajectory i+1's first.
  3. ACROSS the machine, W chunks of the sorted trajectory list advance in
     LOCKSTEP through the `BatchedGCRODRSolver`: one batched device program
     solves time step s of the current trajectory of EVERY chunk (all
     trajectories share nt/Δt, so the rows align with no phase drift).
     Shorter chunks are padded with zero right-hand sides — 0 iterations,
     x = 0, recycle carry untouched, excluded from the chunk stats. With
     engine="sharded" the chunk-chain axis additionally shards over the
     `data` mesh axis: one SPMD program per implicit step across every
     device.

The schedule (sort → chain partition → lockstep packing/prefetch → engine
dispatch, plus the resumable single-chain loop) lives in
`core/pipeline.py`; this module supplies the trajectory WORK ADAPTER
(`TrajectoryWork`) and the θ-scheme marching.

Resumable like `SKRGenerator`: the sequential engine checkpoints atomically
every `ckpt_every` TRAJECTORIES (completed fields + solver recycle space);
a preempted job restarts warm at the next unfinished trajectory.

RHS modes:
  full       solve A u_{n+1} = b directly (paper-parity default)
  increment  solve A δ = b − A u_n and set u_{n+1} = u_n + δ — the Krylov
             iteration only reconstructs the CHANGE per step; with rtol
             semantics the absolute target scales with ‖b − A u_n‖, so the
             marched trajectory matches "full" to solver tolerance while
             typically shaving iterations near steady state.

Stepping stack (pde/timedep.py): families with `integrator="bdf2"`, a mass
matrix M ≠ I, or an `AdaptConfig` route through the GENERALIZED marching
paths here (`_march_one_stepped` sequentially, the phase-masked lockstep in
`TrajectoryWork`); plain fixed-Δt θ-scheme families keep the ORIGINAL code
path bitwise-unchanged. Under adaptive Δt the per-trajectory step sequences
diverge, so the lockstep engine drops the rows-align-by-construction
assumption: every lockstep iteration assembles PER-CHAIN systems (each
chain at its own t, Δt, bootstrap phase — one vmapped build serves all),
masks finished/budget-exhausted chains as zero-RHS padded rows via
`pipeline.PhaseMask`, and keeps iterating until every chain of the row
delivered its trajectory. Accept/reject decisions come from ONE shared
host-side PI controller (`PIStepController`, quantized decisions), so the
sequential and lockstep engines take bitwise-identical Δt paths and the
recycle carry rides across accepted AND rejected steps — a rejected step's
cycles still update the chain's deflation space, which is exactly what
makes the immediate retry cheap.

Precision policy: set `TrajConfig.krylov.inner_dtype="float32"` to run
every implicit step's Arnoldi cycles, preconditioner applies and
recycle-space updates in fp32 (all engines — the solvers implement the
fp64 iterative-refinement outer loop internally). The θ-scheme assembly,
the marched fields u_t, the emitted trajectory labels and the increment
RHS b − A u_n all stay fp64; the recycle carry ridden across time steps
and trajectory boundaries is stored fp32 — including in checkpoints, so a
resumed run continues the fp32 chain exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import pipeline
from repro.core.ckpt import NpzCheckpointer
from repro.core.expand import ExpandConfig, Expander, LabelSet
from repro.core.robust import FaultPlan, RetryPolicy, is_healthy
from repro.core.sorting import chain_length
from repro.pde.dia import Stencil5, stencil5_matvec
from repro.pde.timedep import (PIStepController, TimeDepFamily,
                               TrajectorySpec)
from repro.solvers.gcrodr import GCRODRSolver
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import (make_preconditioner,
                                   make_preconditioner_batched)
from repro.solvers.types import KrylovConfig, SequenceStats


@dataclasses.dataclass(frozen=True)
class TrajConfig:
    krylov: KrylovConfig = KrylovConfig()
    sort_method: str = "greedy"   # trajectory-level sort (t=0 features)
    precond: str = "none"
    use_kernel: bool = False
    ckpt_every: int = 0           # 0 = no checkpoints; unit = trajectories
    rhs_mode: str = "full"        # full | increment (module docstring)
    # failure containment (core/robust.py) — same axes as SKRConfig: the
    # escalation ladder guards every implicit-step solve (None disables),
    # strict_labels decides whether untrustworthy trajectories ship flagged
    # ("flag", in TrajResult.label_ok) or are dropped ("exclude")
    retry: Optional[RetryPolicy] = RetryPolicy()
    strict_labels: str = "flag"
    # label expansion (core/expand.py): re-label GRF-perturbed snapshots
    # under the time-dependent operator at the snapshot's t — each healthy
    # accepted save-step fans into k+1 (f' = A(t) u', u') pairs. None (the
    # default) is OFF: bitwise-identical pre-expansion marching.
    expand: Optional[ExpandConfig] = None

    def __post_init__(self):
        assert self.rhs_mode in ("full", "increment")
        assert self.strict_labels in ("flag", "exclude"), self.strict_labels


@dataclasses.dataclass
class TrajResult:
    trajectories: np.ndarray   # (N, nt+1, nx, ny), [:, 0] = u0, ORIGINAL order
    no_input: np.ndarray       # (N, nx, ny) static conditioning channel
    order: np.ndarray          # trajectory solve order used
    stats: SequenceStats       # one SolveStats per implicit step solved
    sort_seconds: float
    chain_len: float
    # per-TRAJECTORY trustworthiness: every accepted step converged at tol
    # with a finite residual, none quarantined. All-True after
    # strict_labels="exclude" filtering; None only from legacy callers.
    label_ok: Optional[np.ndarray] = None
    # expanded labels (core/expand.py) when cfg.expand is set: per-snapshot
    # (f' = A(t) u', u') pairs with provenance — `anchor_idx` the trajectory
    # index, `t` the snapshot time. None when expansion is off.
    labels: Optional[LabelSet] = None


_inc_rhs = jax.jit(lambda a, b, u: b - stencil5_matvec(a, u))

# per-chain pytree select (accept/reject the candidate StepState of every
# chain of a lockstep row in one dispatch)
_sel_tree = jax.jit(lambda m, new, old: jax.tree_util.tree_map(
    lambda a, b: jnp.where(m.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
    new, old))


def _spec_at(specs: TrajectorySpec, i) -> TrajectorySpec:
    return jax.tree_util.tree_map(lambda a: a[i], specs)


def _solve_stencil(a, rhs, cfg: TrajConfig, solver: GCRODRSolver,
                   nx: int, ny: int, fault: Optional[FaultPlan] = None,
                   tidx: int = 0, step: int = 0):
    """One implicit-step Stencil5 system through the sequential solver,
    guarded by the retry/escalation ladder when `cfg.retry` is set. `fault`
    poisons trajectory `tidx`'s assembly at save-step `step` (one-shot, so
    the first ladder rung already sees clean data)."""
    def make_problem():
        a2, r2 = a, np.asarray(rhs).reshape(-1)
        if fault is not None:
            r2 = fault.apply_rhs(tidx, r2, step=step)
            a_np = np.asarray(a2)
            poisoned = fault.apply_operator(tidx, a_np, step=step)
            if poisoned is not a_np:
                a2 = jnp.asarray(poisoned)
        st5 = Stencil5(a2)
        pre = make_preconditioner(cfg.precond, st5, use_kernel=cfg.use_kernel)
        op = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel), pre)
        return op, r2

    if fault is not None:
        fault.apply_carry(tidx, solver, step=step)
    policy = getattr(cfg, "retry", None)
    if policy is None:
        x, st = solver.solve(*make_problem())
    else:
        from repro.core.robust import solve_one_guarded

        x, st = solve_one_guarded(solver, make_problem, policy,
                                  label=f"trajectory {tidx} step {step}")
    return jnp.asarray(np.asarray(x).reshape(nx, ny)), st


class _FixedStepPolicy:
    """Fixed-Δt drop-in for `PIStepController` used by the generalized
    stack when `family.adapt is None` (BDF2 / mass-matrix families at a
    constant step): every save interval is exactly one accepted step, so
    `propose` returns the full remaining interval and `decide` always
    accepts — same interface, no controller state beyond the Δt history
    the BDF2 coefficients need."""

    def __init__(self, dt: float):
        self.dt = float(dt)
        self.dt_prev = float(dt)
        self.dt_pprev = float(dt)
        self.naccept = 0
        self.nsolves = 0

    def propose(self, remaining: float) -> float:
        return remaining

    def decide(self, est: float, dt_used: float) -> bool:
        self.nsolves += 1
        self.dt_pprev = self.dt_prev
        self.dt_prev = dt_used
        self.naccept += 1
        return True

    @property
    def boot(self) -> bool:
        return self.naccept == 0

    @property
    def exhausted(self) -> bool:
        return False


def _make_policy(family: TimeDepFamily):
    if family.adapt is not None:
        return PIStepController(family.adapt, family.order, family.dt)
    return _FixedStepPolicy(family.dt)


def _march_one(family: TimeDepFamily, spec: TrajectorySpec, cfg: TrajConfig,
               solver: GCRODRSolver, stats: Optional[SequenceStats] = None,
               fault: Optional[FaultPlan] = None, tidx: int = 0,
               expander: Optional[Expander] = None, chain: int = 0
               ) -> np.ndarray:
    """March ONE trajectory with the (stateful) solver; returns the
    (nt+1, nx, ny) field sequence at the uniform save grid. The carry in
    `solver` survives the call — that is the across-trajectory recycling.

    `expander` (label expansion, core/expand.py): every healthy step's
    snapshot fans into k+1 labels under the step's operator A(t) while the
    operator and solution are still device-resident; the first unhealthy
    step taints the trajectory — its labels so far are retracted and
    expansion stops (the requeue path re-expands from the clean re-march).

    Classic families (fixed-Δt θ-scheme, M = I) take the ORIGINAL loop
    below, bitwise-unchanged; BDF2 / mass-matrix / adaptive families route
    through `_march_one_stepped`."""
    if not family.classic:
        return _march_one_stepped(family, spec, cfg, solver, stats,
                                  fault=fault, tidx=tidx,
                                  expander=expander, chain=chain)
    nx, ny = family.nx, family.ny
    step1 = family.step_fn()
    out = np.zeros((family.nt + 1, nx, ny))
    u = jnp.asarray(spec.u0)
    out[0] = np.asarray(u)
    for step in range(family.nt):
        t_old, t_new = step * family.dt, (step + 1) * family.dt
        a, b = step1(spec.latent, u, t_old, t_new)
        rhs = _inc_rhs(a, b, u) if cfg.rhs_mode == "increment" else b
        x, st = _solve_stencil(a, rhs, cfg, solver, nx, ny,
                               fault=fault, tidx=tidx, step=step)
        u = u + x if cfg.rhs_mode == "increment" else x
        out[step + 1] = np.asarray(u)
        if stats is not None:
            stats.append(st)
        if expander is not None:
            if is_healthy(st):
                expander.expand_one(a, u, tidx, chain=chain,
                                    t=t_new, step=step)
            else:
                expander.drop_anchor(tidx)
                expander = None
    return out


def _march_one_stepped(family: TimeDepFamily, spec: TrajectorySpec,
                       cfg: TrajConfig, solver: GCRODRSolver,
                       stats: Optional[SequenceStats] = None,
                       fault: Optional[FaultPlan] = None,
                       tidx: int = 0, expander: Optional[Expander] = None,
                       chain: int = 0) -> np.ndarray:
    """Generalized sequential march (BDF2 / mass matrices / adaptive Δt).

    Internal steps follow the step policy (PI controller or fixed); labels
    are recorded on the UNIFORM save grid nt × (t_end/nt) — the controller
    clamps/stretches trial steps to land exactly on save times, so the
    output shape matches the classic path. Rejected steps still solve (and
    still update the recycle carry — that is what makes the retry cheap);
    their SolveStats are appended with `rejected=True`. A trajectory that
    exhausts `AdaptConfig.max_steps` freezes: remaining save points repeat
    the last accepted field."""
    nx, ny = family.nx, family.ny
    build1, eval1 = family.build_fn(), family.eval_fn()
    nt = family.nt
    save_dt = family.t_end / nt
    out = np.zeros((nt + 1, nx, ny))
    state = family.init_state(spec)
    out[0] = np.asarray(state.u)
    pol = _make_policy(family)
    t, save_i = 0.0, 1
    while save_i <= nt:
        if pol.exhausted:
            out[save_i:] = np.asarray(state.u)
            break
        remaining = save_i * save_dt - t
        dt_step = pol.propose(remaining)
        boot = pol.boot
        a, b = build1(spec.latent, state, t, dt_step, pol.dt_prev, boot,
                      boot)
        rhs = _inc_rhs(a, b, state.u) if cfg.rhs_mode == "increment" else b
        # fault step index = the save interval being marched toward (the
        # classic loop's `step`), so both stacks poison the same solve
        x, st = _solve_stencil(a, rhs, cfg, solver, nx, ny,
                               fault=fault, tidx=tidx, step=save_i - 1)
        xf = state.u + x if cfg.rhs_mode == "increment" else x
        cand, est = eval1(spec.latent, state, xf, t, dt_step, pol.dt_prev,
                          pol.dt_pprev, boot, pol.naccept >= 2)
        if pol.decide(float(est), dt_step):
            state = cand
            if expander is not None and not is_healthy(st):
                # tainted: retract the trajectory's labels, stop expanding
                expander.drop_anchor(tidx)
                expander = None
            if dt_step == remaining:      # landed exactly on a save time
                t = save_i * save_dt
                out[save_i] = np.asarray(state.u)
                if expander is not None:
                    expander.expand_one(a, state.u, tidx, chain=chain,
                                        t=t, step=save_i - 1)
                save_i += 1
            else:
                t += dt_step
        else:
            st.rejected = True
        if stats is not None:
            stats.append(st)
    return out


def march_trajectory(family: TimeDepFamily, spec: TrajectorySpec,
                     cfg: TrajConfig, solver: Optional[GCRODRSolver] = None
                     ) -> tuple[np.ndarray, SequenceStats]:
    """Convenience single-trajectory march (tests / notebooks): fresh solver
    unless one is passed in to continue an existing recycling chain."""
    solver = solver or GCRODRSolver(cfg.krylov, use_kernel=cfg.use_kernel)
    stats = SequenceStats()
    traj = _march_one(family, spec, cfg, solver, stats)
    return traj, stats


class TrajectoryWork(pipeline.WorkAdapter):
    """Pipeline work adapter for θ-scheme trajectories: one work item = one
    trajectory (nt implicit-step solves on the item's recycle chain)."""

    item_noun = "trajectory"
    ckpt_key = "trajs"   # historical checkpoint field name

    def __init__(self, family: TimeDepFamily, cfg: TrajConfig):
        self.family = family
        self.cfg = cfg
        self.specs: Optional[TrajectorySpec] = None
        self.feats: Optional[np.ndarray] = None
        self.outputs: Optional[np.ndarray] = None
        self.expander: Optional[Expander] = None

    def _make_expander(self) -> Optional[Expander]:
        ecfg = getattr(self.cfg, "expand", None)
        if ecfg is None:
            return None
        return Expander(ecfg, self.family.nx, self.family.ny,
                        use_kernel=self.cfg.use_kernel)

    # ------------------------------------------------------- sampling
    def sample(self, key: jax.Array, num: int) -> np.ndarray:
        self.specs = self.family.sample_specs(key, num)
        self.feats = np.asarray(self.specs.features)
        return self.feats

    # ------------------------------------- sequential (single-chain)
    def alloc_full(self, num: int):
        self.outputs = np.zeros((num, self.family.nt + 1,
                                 self.family.nx, self.family.ny))
        self.label_ok = np.ones(num, dtype=bool)
        self.expander = self._make_expander()

    def restore_outputs(self, arr: np.ndarray):
        # caveat (as in SteadyWork): label_ok is not checkpointed, so
        # trajectories completed before a resume default to trustworthy
        self.outputs = arr

    @staticmethod
    def _steps_ok(steps) -> bool:
        """A trajectory's label is trustworthy iff every ACCEPTED step is
        healthy (rejected steps never produced a label)."""
        return all(is_healthy(s) for s in steps if not s.rejected)

    def solve_item(self, i: int, solver: GCRODRSolver,
                   stats: SequenceStats) -> list:
        before = len(stats.per_system)
        self.outputs[i] = _march_one(self.family, _spec_at(self.specs, i),
                                     self.cfg, solver, stats,
                                     fault=self.fault, tidx=i,
                                     expander=self.expander, chain=0)
        steps = stats.per_system[before:]
        self.label_ok[i] = self._steps_ok(steps)
        return steps

    # ---- checkpoint extras: expanded labels + provenance ------------
    def ckpt_extra(self) -> dict:
        return self.expander.ckpt_arrays() if self.expander else {}

    def ckpt_required(self) -> tuple:
        return ("exp_f", "exp_u", "exp_anchor", "exp_kind", "exp_t") \
            if self.expander else ()

    def restore_extra(self, state: dict):
        if self.expander is not None and "exp_f" in state:
            self.expander.restore(state)

    def full_result(self, order, stats, sort_s, clen) -> TrajResult:
        order = np.asarray(order)
        no_input = np.asarray(self.specs.no_input)
        trajs, label_ok = self.outputs, self.label_ok
        if getattr(self.cfg, "strict_labels", "flag") == "exclude" \
                and not label_ok.all():
            order = order[label_ok[order]]
            no_input, trajs = no_input[label_ok], trajs[label_ok]
            label_ok = np.ones(len(trajs), dtype=bool)
        return TrajResult(
            trajectories=trajs,
            no_input=no_input,
            order=order,
            stats=stats,
            sort_seconds=sort_s,
            chain_len=clen,
            label_ok=label_ok,
            labels=self.expander.result() if self.expander else None,
        )

    # ---------------------------------------------- chunked engines
    def solve_chunk_sequential(self, sub) -> TrajResult:
        """One chunk of sorted trajectories through the per-system
        sequential solver (fresh recycle chain per chunk, carried across the
        chunk's trajectories — bitwise-matches the single-chain generator
        when workers=1)."""
        solver = self.make_solver()
        stats = SequenceStats()
        trajs = np.zeros((len(sub), self.family.nt + 1,
                          self.family.nx, self.family.ny))
        label_ok = np.ones(len(sub), dtype=bool)
        expander = self._make_expander()   # chunk-local expansion chain
        for pos, i in enumerate(sub):
            before = len(stats.per_system)
            trajs[pos] = _march_one(self.family, _spec_at(self.specs, int(i)),
                                    self.cfg, solver, stats,
                                    fault=self.fault, tidx=int(i),
                                    expander=expander, chain=0)
            label_ok[pos] = self._steps_ok(stats.per_system[before:])
        return self._chunk_result(sub, trajs, stats, label_ok,
                                  expander=expander)

    def begin_lockstep(self, subs):
        self._subs = subs
        self._trajs = [np.zeros((len(s), self.family.nt + 1,
                                 self.family.nx, self.family.ny))
                       for s in subs]
        self._stats = [SequenceStats() for _ in subs]
        self._label_ok = [np.ones(len(s), dtype=bool) for s in subs]
        self._requeue = []   # (chain, row, traj index, stats slice lo/hi)
        self._u0_all = jnp.asarray(self.specs.u0)
        self.expander = self._make_expander()
        if self.family.classic:
            self._stepB = self.family.step_fn_batched()
        else:
            # the classic θ-stepper would assemble the WRONG system for
            # mass/BDF2 families — never build it, so misuse is impossible
            self._buildB = self.family.build_fn_batched()
            self._evalB = self.family.eval_fn_batched()
            self._initB = jax.jit(jax.vmap(self.family.init_state))

    def prepare_row(self, t: int, idx: np.ndarray):
        """Row assembly (prefetch thread): gather the row's trajectory
        latents + initial fields; padded slots get zero fields. The
        generalized stack gathers full batched `StepState`s instead (the
        family's own `init_state`, so e.g. wave velocity ICs survive)."""
        clamped = jnp.asarray(np.where(idx >= 0, idx, 0))
        live = idx >= 0
        live_dev = jnp.asarray(live)[:, None, None]
        lat = jax.tree_util.tree_map(lambda a: a[clamped], self.specs.latent)
        if not self.family.classic:
            specs_b = jax.tree_util.tree_map(lambda a: a[clamped], self.specs)
            states = self._initB(specs_b)
            states = jax.tree_util.tree_map(
                lambda a: jnp.where(live_dev, a, 0.0), states)
            return lat, states, live, live_dev
        u = jnp.where(live_dev, self._u0_all[clamped], 0.0)
        return lat, u, live, live_dev

    def execute_row(self, solver, j: int, idx: np.ndarray, prepared):
        """March row j: at each lockstep iteration, ONE batched (possibly
        sharded) device program advances the current implicit step of every
        chunk's current trajectory. Classic fixed-Δt families keep the
        original aligned loop; the generalized stack phase-masks."""
        if not self.family.classic:
            return self._execute_row_stepped(solver, j, idx, prepared)
        family, cfg = self.family, self.cfg
        nx, ny = family.nx, family.ny
        workers = len(idx)
        lat, u, live, live_dev = prepared
        live = live.copy()   # containment may freeze chains mid-row
        starts = [len(s.per_system) for s in self._stats]
        u_np = np.asarray(u)
        for w in np.nonzero(live)[0]:
            self._trajs[w][j, 0] = u_np[w]
        for step in range(family.nt):
            if not live.any():
                break
            t_old, t_new = step * family.dt, (step + 1) * family.dt
            with obs.span("assemble_step", cat="trajectory", step=step):
                a, b = self._stepB(lat, u, t_old, t_new)
                a, b = self._poison_row(a, b, idx, live, step)
                rhs = _inc_rhs(a, b, u) if cfg.rhs_mode == "increment" else b
                rhs = jnp.where(live_dev, rhs, 0.0)  # padded chunks, on device
                st5 = Stencil5(a)                    # (W, 5, nx, ny)
                pre = make_preconditioner_batched(cfg.precond, st5,
                                                  use_kernel=cfg.use_kernel)
                ops = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel),
                                       pre)
            if self.fault is not None:
                for w in np.nonzero(live)[0]:
                    self.fault.apply_carry(int(idx[w]), solver, chain=int(w),
                                           step=step)
            with obs.span("solve_dispatch", cat="trajectory", step=step):
                xs, st_list = solver.solve_batch(ops,
                                                 rhs.reshape(workers, -1),
                                                 padded_rows=~live)
            delta = jnp.asarray(xs.reshape(workers, nx, ny))
            u = u + delta if cfg.rhs_mode == "increment" else delta
            u_np = np.asarray(u)                     # one sync per step
            frozen = False
            exp_live = np.zeros(workers, dtype=bool)
            for w in np.nonzero(live)[0]:
                self._trajs[w][j, step + 1] = u_np[w]
                self._stats[w].append(st_list[w])
                if not is_healthy(st_list[w]):
                    self._label_ok[w][j] = False
                    if self.expander is not None:
                        # taint retracts the trajectory's labels so far;
                        # a healthy requeue re-march re-expands them
                        self.expander.drop_anchor(int(idx[w]))
                    if getattr(cfg, "retry", None) is not None:
                        # one unhealthy step taints the whole trajectory:
                        # freeze the chain (padded from the next dispatch)
                        # and hand it to requeue_quarantined for a clean
                        # sequential re-march
                        self._requeue.append((int(w), j, int(idx[w]),
                                              starts[w],
                                              len(self._stats[w].per_system)))
                        live[w] = False
                        frozen = True
                elif self._label_ok[w][j]:
                    exp_live[w] = True
            if self.expander is not None and exp_live.any():
                # ONE expansion wave over the step's retired snapshots —
                # operator stack `st5` and state `u` still device-resident
                self.expander.wave(st5.coeffs, u, idx, exp_live,
                                   t=t_new, step=step)
            if frozen:
                live_dev = jnp.asarray(live)[:, None, None]

    def _poison_row(self, a, b, idx, live, steps):
        """FaultPlan injection for one lockstep dispatch: poison the
        targeted chains' operator rows / RHS rows (host round-trip — fault
        runs only). `steps` is the fault step index, scalar (fixed-Δt rows)
        or per-chain (phase-masked rows, each chain at its own save step)."""
        if self.fault is None or not (self.fault.nan_rhs
                                      or self.fault.nan_operator):
            return a, b
        a_np, b_np = np.array(a, copy=True), np.array(b, copy=True)
        dirty = False
        for w in np.nonzero(live)[0]:
            i = int(idx[w])
            step = int(steps) if np.isscalar(steps) else int(steps[w])
            pa = self.fault.apply_operator(i, a_np[w], step=step)
            if pa is not a_np[w]:
                a_np[w], dirty = pa, True
            pb = self.fault.apply_rhs(i, b_np[w], step=step)
            if pb is not b_np[w]:
                b_np[w], dirty = pb, True
        if dirty:
            return jnp.asarray(a_np), jnp.asarray(b_np)
        return a, b

    def _execute_row_stepped(self, solver, j: int, idx: np.ndarray,
                             prepared):
        """Phase-masked lockstep march of row j (the generalized stack).

        Each chain advances at its OWN (t, Δt, bootstrap) phase — one
        vmapped `build_step` assembles all per-chain systems, one
        `solve_batch` dispatch advances them, one vmapped `step_eval`
        produces candidate states + embedded error estimates. Accept/reject
        runs per chain through the same quantized host controller the
        sequential engine uses, so both engines take identical Δt paths.
        Chains that delivered their trajectory (or exhausted their step
        budget) flip to zero-RHS padded rows (`pipeline.PhaseMask`) until
        the whole row is done; recycle carries persist across accepted and
        rejected steps alike."""
        family, cfg = self.family, self.cfg
        nx, ny = family.nx, family.ny
        workers = len(idx)
        lat, states, live, live_dev = prepared
        nt = family.nt
        save_dt = family.t_end / nt
        u_np = np.asarray(states.u)
        starts = [len(s.per_system) for s in self._stats]
        for w in np.nonzero(live)[0]:
            self._trajs[w][j, 0] = u_np[w]
        pols = {int(w): _make_policy(family) for w in np.nonzero(live)[0]}
        mask = pipeline.PhaseMask(live)
        t = np.zeros(workers)
        save_i = np.ones(workers, dtype=np.int64)
        while True:
            # freeze budget-exhausted chains at the sequential path's exact
            # point (loop top), repeating the last accepted field
            for w in np.nonzero(mask.active)[0]:
                if pols[int(w)].exhausted:
                    self._trajs[w][j, save_i[w]:] = u_np[w]
                    mask.finish(w)
            act = mask.active.copy()
            if not act.any():
                break
            dt_step = np.full(workers, save_dt)
            dtp = np.full(workers, save_dt)
            dtpp = np.full(workers, save_dt)
            boot = np.zeros(workers, dtype=bool)
            have2 = np.zeros(workers, dtype=bool)
            for w in np.nonzero(act)[0]:
                pol = pols[int(w)]
                dt_step[w] = pol.propose(save_i[w] * save_dt - t[w])
                dtp[w] = pol.dt_prev
                dtpp[w] = pol.dt_pprev
                boot[w] = pol.boot
                have2[w] = pol.naccept >= 2
            with obs.span("assemble_step", cat="trajectory"):
                a, b = self._buildB(lat, states, jnp.asarray(t),
                                    jnp.asarray(dt_step), jnp.asarray(dtp),
                                    jnp.asarray(boot), bool(boot.any()))
                a, b = self._poison_row(a, b, idx, act, save_i - 1)
                rhs = (_inc_rhs(a, b, states.u)
                       if cfg.rhs_mode == "increment" else b)
                rhs = jnp.where(jnp.asarray(act)[:, None, None], rhs, 0.0)
                st5 = Stencil5(a)
                pre = make_preconditioner_batched(cfg.precond, st5,
                                                  use_kernel=cfg.use_kernel)
                ops = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel),
                                       pre)
            if self.fault is not None:
                for w in np.nonzero(act)[0]:
                    self.fault.apply_carry(int(idx[w]), solver, chain=int(w),
                                           step=int(save_i[w]) - 1)
            with obs.span("solve_dispatch", cat="trajectory"):
                xs, st_list = solver.solve_batch(ops,
                                                 rhs.reshape(workers, -1),
                                                 padded_rows=mask.padded_rows)
            delta = jnp.asarray(xs.reshape(workers, nx, ny))
            xf = states.u + delta if cfg.rhs_mode == "increment" else delta
            cand, est = self._evalB(lat, states, xf, jnp.asarray(t),
                                    jnp.asarray(dt_step), jnp.asarray(dtp),
                                    jnp.asarray(dtpp), jnp.asarray(boot),
                                    jnp.asarray(have2))
            est_np = np.asarray(est)
            accept = np.zeros(workers, dtype=bool)
            recorded = []
            for w in np.nonzero(act)[0]:
                st = st_list[w]
                if getattr(cfg, "retry", None) is not None \
                        and not is_healthy(st):
                    # containment: an unhealthy solve must not feed the
                    # controller (est may be NaN) — freeze the chain and
                    # hand the trajectory to requeue_quarantined
                    self._stats[w].append(st)
                    self._requeue.append((int(w), j, int(idx[w]), starts[w],
                                          len(self._stats[w].per_system)))
                    self._label_ok[w][j] = False
                    if self.expander is not None:
                        self.expander.drop_anchor(int(idx[w]))
                    mask.finish(w)
                    continue
                pol = pols[int(w)]
                remaining = save_i[w] * save_dt - t[w]
                ok = pol.decide(float(est_np[w]), float(dt_step[w]))
                accept[w] = ok
                st_list[w].rejected = not ok
                self._stats[w].append(st_list[w])
                if ok and not is_healthy(st_list[w]):
                    self._label_ok[w][j] = False   # retry=None legacy mode
                    if self.expander is not None:
                        self.expander.drop_anchor(int(idx[w]))
                if not ok:
                    continue
                if dt_step[w] == remaining:   # landed on a save time
                    t[w] = save_i[w] * save_dt
                    recorded.append(int(w))
                else:
                    t[w] += dt_step[w]
            states = _sel_tree(jnp.asarray(accept), cand, states)
            u_np = np.asarray(states.u)       # one sync per iteration
            exp_live = np.zeros(workers, dtype=bool)
            t_arr = np.zeros(workers)
            step_arr = np.zeros(workers, dtype=np.int64)
            for w in recorded:
                if self._label_ok[w][j]:
                    exp_live[w] = True
                    t_arr[w] = save_i[w] * save_dt
                    step_arr[w] = save_i[w] - 1
                self._trajs[w][j, save_i[w]] = u_np[w]
                save_i[w] += 1
                if save_i[w] > nt:
                    mask.finish(w)
            if self.expander is not None and exp_live.any():
                # wave over the chains that LANDED on a save time this
                # iteration — each at its own (t, step) phase; the step's
                # operator stack `st5` + accepted state stay device-resident
                self.expander.wave(st5.coeffs, states.u, idx, exp_live,
                                   t=t_arr, step=step_arr)

    def requeue_quarantined(self):
        """Containment requeue: trajectories whose lockstep march hit an
        unhealthy step are RE-MARCHED end to end on a fresh sequential chain
        (guarded solves — `cfg.retry` — per step). Faults are one-shot, so
        the re-march sees clean data; its stats REPLACE the tainted row's
        slice so sequence totals describe the shipped labels."""
        if not self._requeue:
            return
        solver = self.make_solver()
        # replace highest stats-slice first: earlier replacements must not
        # shift the recorded (lo, hi) windows of later ones
        for w, j, i, lo, hi in sorted(self._requeue, key=lambda r: -r[3]):
            solver.u_carry = None    # cold per trajectory
            redo = SequenceStats()
            if self.expander is not None:
                # taint already dropped this anchor's wave labels; the
                # re-march's expand_one calls append AFTER the drop seq,
                # so a healthy re-march re-emits the full label fan-out
                self.expander.drop_anchor(i)
            self._trajs[w][j] = _march_one(
                self.family, _spec_at(self.specs, i), self.cfg, solver,
                redo, fault=self.fault, tidx=i,
                expander=self.expander, chain=w)
            if redo.per_system:
                # fold the tainted attempts' work into the re-march's first
                # record and mark the intervention, so summary()["health"]
                # still reports the recovery after the slice is replaced
                head = redo.per_system[0]
                for s in self._stats[w].per_system[lo:hi]:
                    head.merge_inner(s)
                    head.retries += max(s.retries, 0)
                head.retries += 1
                head.escalation_path = head.escalation_path + ("requeue",)
            self._stats[w].per_system[lo:hi] = redo.per_system
            self._label_ok[w][j] = self._steps_ok(redo.per_system)
        obs.counter_add("health.requeued", len(self._requeue))
        self._requeue = []

    def chunk_result(self, w: int) -> TrajResult:
        return self._chunk_result(self._subs[w], self._trajs[w],
                                  self._stats[w], self._label_ok[w],
                                  expander=self.expander, chain=w)

    def _chunk_result(self, sub, trajs, stats, label_ok=None,
                      expander=None, chain=None) -> TrajResult:
        sub = np.asarray(sub, dtype=np.int64)
        label_ok = np.ones(len(sub), dtype=bool) if label_ok is None \
            else np.asarray(label_ok, dtype=bool)
        if getattr(self.cfg, "strict_labels", "flag") == "exclude" \
                and not label_ok.all():
            sub, trajs = sub[label_ok], trajs[label_ok]
            label_ok = np.ones(len(sub), dtype=bool)
        return TrajResult(
            trajectories=trajs,
            no_input=np.asarray(self.specs.no_input)[sub],
            order=sub,
            stats=stats,
            sort_seconds=0.0,
            chain_len=chain_length(self.feats, sub),
            label_ok=label_ok,
            labels=expander.result(chain=chain) if expander else None,
        )


class TrajectoryStream(TrajectoryWork):
    """Streaming work adapter for trajectories (core/serve.py): a slot
    holds ONE trajectory mid-march, and slots drift OUT OF PHASE — each is
    at its own implicit step — so every dispatch advances all occupied
    slots one step at their own times via the family's per-slot-time
    stepper (`TimeDepFamily.step_fn_streamed`, t batched over slots). An
    item completes after `nt` dispatches.

    Classic fixed-Δt θ-scheme families only: BDF2 / mass-matrix / adaptive
    stepping need the generalized StepState march and route through the
    offline phase-masked engine. Assembly of step s+1 consumes the field
    solved at step s, so this adapter is NOT prefetchable. As with
    `SteadyStream`, the offline requeue ladder does not run: an unhealthy
    step flags the whole trajectory's `label_ok` and the march continues."""

    stream_prefetchable = False   # step s+1 needs step s's solution

    def begin_stream(self, slots: int):
        if not self.family.classic:
            raise NotImplementedError(
                "streaming trajectory datagen supports classic fixed-dt "
                "theta-scheme families; BDF2 / mass-matrix / adaptive "
                "families route through the offline phase-masked engine")
        fam = self.family
        num = len(self.feats)
        self.outputs = np.zeros((num, fam.nt + 1, fam.nx, fam.ny))
        self.label_ok = np.zeros(num, dtype=bool)
        self.stats = SequenceStats()
        self._stepS = fam.step_fn_streamed()
        self._u0_np = np.asarray(self.specs.u0)
        self._u_np = np.zeros((slots, fam.nx, fam.ny))   # per-slot field
        self._pos = np.zeros(slots, dtype=np.int64)      # per-slot next step

    def start_item(self, w: int, i: int):
        self._u_np[w] = self._u0_np[i]
        self._pos[w] = 0
        self.outputs[i, 0] = self._u0_np[i]
        self.label_ok[i] = True

    def assemble(self, slot_items: np.ndarray):
        fam, cfg = self.family, self.cfg
        idx = np.asarray(slot_items, dtype=np.int64)
        live = idx >= 0
        clamped = jnp.asarray(np.where(live, idx, 0))
        lat = jax.tree_util.tree_map(lambda a: a[clamped], self.specs.latent)
        u = jnp.asarray(self._u_np)
        t_old = jnp.asarray(self._pos * fam.dt)
        t_new = jnp.asarray((self._pos + 1) * fam.dt)
        a, b = self._stepS(lat, u, t_old, t_new)
        rhs = _inc_rhs(a, b, u) if cfg.rhs_mode == "increment" else b
        rhs = jnp.where(jnp.asarray(live)[:, None, None], rhs, 0.0)
        st5 = Stencil5(a)
        pre = make_preconditioner_batched(cfg.precond, st5,
                                          use_kernel=cfg.use_kernel)
        ops = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel), pre)
        return ops, rhs, u, live

    def apply(self, solver, slot_items: np.ndarray, prepared) -> np.ndarray:
        ops, rhs, u, live = prepared
        fam, cfg = self.family, self.cfg
        W = len(slot_items)
        nx, ny = fam.nx, fam.ny
        xs, st_list = solver.solve_batch(ops, rhs.reshape(W, -1),
                                         padded_rows=~live)
        delta = xs.reshape(W, nx, ny)
        u_new = (np.asarray(u) + delta) if cfg.rhs_mode == "increment" \
            else delta
        done = np.zeros(W, dtype=bool)
        for w, i in enumerate(slot_items):
            if i < 0:
                continue
            i = int(i)
            step = int(self._pos[w])
            self._u_np[w] = u_new[w]
            self.outputs[i, step + 1] = u_new[w]
            self.stats.append(st_list[w])
            if not is_healthy(st_list[w]):
                self.label_ok[i] = False
            self._pos[w] = step + 1
            if step + 1 >= fam.nt:
                done[w] = True
        return done


class TrajectoryGenerator:
    """Resumable trajectory data generator over one time-dependent family
    (the `SKRGenerator` of the trajectory subsystem — a thin frontend over
    `core/pipeline.run_resumable`)."""

    def __init__(self, family: TimeDepFamily, cfg: TrajConfig,
                 ckpt_dir: Optional[str] = None):
        self.family = family
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        self._ckpt = NpzCheckpointer(ckpt_dir, "trajgen_state.npz")

    def generate(self, key: jax.Array, num: int,
                 progress_cb: Optional[Callable[[int, int], None]] = None,
                 fail_at: Optional[int] = None,
                 fault: Optional[FaultPlan] = None,
                 mismatch: str = "rotate") -> TrajResult:
        """Generate `num` trajectories of nt+1 fields each.

        fail_at: fault-injection hook (unit = trajectories) — raises after
        that many trajectories; a rerun resumes from the checkpoint with the
        recycle space intact, mirroring `SKRGenerator.generate`.
        fault: full seeded `FaultPlan` (chaos tests): NaN poisoning of
        trajectory `i`'s assembly at save-step `fault.step`, preemption
        with optional checkpoint corruption; see core/robust.py.
        mismatch: policy when a loaded checkpoint belongs to a run of a
        different size — see `pipeline.run_resumable`.
        """
        work = TrajectoryWork(self.family, self.cfg)
        return pipeline.run_resumable(work, key, num, ckpt=self._ckpt,
                                      ckpt_every=self.cfg.ckpt_every,
                                      progress_cb=progress_cb,
                                      fail_at=fail_at, fault=fault,
                                      mismatch=mismatch)


def generate_trajectories(family: TimeDepFamily, key: jax.Array, num: int,
                          cfg: TrajConfig, ckpt_dir: Optional[str] = None,
                          **kw) -> TrajResult:
    return TrajectoryGenerator(family, cfg, ckpt_dir).generate(key, num, **kw)


def generate_trajectories_baseline(family: TimeDepFamily, key: jax.Array,
                                   num: int, krylov: KrylovConfig,
                                   precond: str = "none") -> TrajResult:
    """Cold-start baseline: plain GMRES (k = 0) per step, no trajectory
    sorting — every implicit solve rebuilds its Krylov space from scratch.
    The benchmark's comparison point for recycled time stepping."""
    cfg = TrajConfig(krylov=dataclasses.replace(krylov, k=0),
                     sort_method="none", precond=precond)
    return TrajectoryGenerator(family, cfg).generate(key, num)


def generate_trajectories_chunked(family: TimeDepFamily, key: jax.Array,
                                  num: int, cfg: TrajConfig, workers: int = 4,
                                  engine: str = "batched",
                                  fault: Optional[FaultPlan] = None,
                                  ) -> list[TrajResult]:
    """Chunk-parallel trajectory datagen: sort the trajectories once, split
    the sorted order into `workers` contiguous chunks, one recycle chain per
    chunk (the App. E.2.2 decomposition lifted to trajectory granularity).

    engine="batched" advances all chunks concurrently in lockstep;
    engine="sharded" additionally shards the chunk-chain axis over the
    `data` mesh (all available devices); engine="sequential" runs chunks
    back-to-back (paper-parity simulation). workers=1 always takes the
    sequential path and is bitwise-identical to
    `TrajectoryGenerator.generate` on the same key. Configs the lockstep
    engine cannot batch (`ilu_host`, `ritz_refresh="final"`) auto-route to
    the sequential path, mirroring `generate_dataset_chunked`.
    """
    work = TrajectoryWork(family, cfg)
    work.fault = fault
    return pipeline.run_chunked(work, key, num, workers, engine)
