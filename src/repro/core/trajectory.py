"""Trajectory datagen engine: Krylov-subspace recycling ACROSS TIME STEPS.

The steady-state SKR pipeline (core/skr.py) makes systems similar by
SORTING them; a time-dependent workload (pde/timedep.py) gets similarity
for free — inside one trajectory the θ-scheme matrices A_t = I + θΔt L(t)
drift slowly with t. This engine exploits both levels:

  1. WITHIN a trajectory, the GCRO-DR carry U_k rides across time steps:
     step n+1 warm-starts from the subspace harvested at step n (the
     textbook recycling regime — A_{n+1} = A_n + O(Δt)).
  2. ACROSS trajectories, the carry also survives trajectory boundaries:
     trajectories are SORTED by their t=0 features (IC latent + operator
     latent, Algorithm 1 on trajectory granularity), so the space carried
     out of trajectory i's last step is relevant to trajectory i+1's first.
  3. ACROSS the machine, W chunks of the sorted trajectory list advance in
     LOCKSTEP through the `BatchedGCRODRSolver`: one batched device program
     solves time step s of the current trajectory of EVERY chunk (all
     trajectories share nt/Δt, so the rows align with no phase drift).
     Shorter chunks are padded with zero right-hand sides — 0 iterations,
     x = 0, recycle carry untouched, excluded from the chunk stats. With
     engine="sharded" the chunk-chain axis additionally shards over the
     `data` mesh axis: one SPMD program per implicit step across every
     device.

The schedule (sort → chain partition → lockstep packing/prefetch → engine
dispatch, plus the resumable single-chain loop) lives in
`core/pipeline.py`; this module supplies the trajectory WORK ADAPTER
(`TrajectoryWork`) and the θ-scheme marching.

Resumable like `SKRGenerator`: the sequential engine checkpoints atomically
every `ckpt_every` TRAJECTORIES (completed fields + solver recycle space);
a preempted job restarts warm at the next unfinished trajectory.

RHS modes:
  full       solve A u_{n+1} = b directly (paper-parity default)
  increment  solve A δ = b − A u_n and set u_{n+1} = u_n + δ — the Krylov
             iteration only reconstructs the CHANGE per step; with rtol
             semantics the absolute target scales with ‖b − A u_n‖, so the
             marched trajectory matches "full" to solver tolerance while
             typically shaving iterations near steady state.

Precision policy: set `TrajConfig.krylov.inner_dtype="float32"` to run
every implicit step's Arnoldi cycles, preconditioner applies and
recycle-space updates in fp32 (all engines — the solvers implement the
fp64 iterative-refinement outer loop internally). The θ-scheme assembly,
the marched fields u_t, the emitted trajectory labels and the increment
RHS b − A u_n all stay fp64; the recycle carry ridden across time steps
and trajectory boundaries is stored fp32 — including in checkpoints, so a
resumed run continues the fp32 chain exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline
from repro.core.ckpt import NpzCheckpointer
from repro.core.sorting import chain_length
from repro.pde.dia import Stencil5, stencil5_matvec
from repro.pde.timedep import TimeDepFamily, TrajectorySpec
from repro.solvers.gcrodr import GCRODRSolver
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import (make_preconditioner,
                                   make_preconditioner_batched)
from repro.solvers.types import KrylovConfig, SequenceStats


@dataclasses.dataclass(frozen=True)
class TrajConfig:
    krylov: KrylovConfig = KrylovConfig()
    sort_method: str = "greedy"   # trajectory-level sort (t=0 features)
    precond: str = "none"
    use_kernel: bool = False
    ckpt_every: int = 0           # 0 = no checkpoints; unit = trajectories
    rhs_mode: str = "full"        # full | increment (module docstring)

    def __post_init__(self):
        assert self.rhs_mode in ("full", "increment")


@dataclasses.dataclass
class TrajResult:
    trajectories: np.ndarray   # (N, nt+1, nx, ny), [:, 0] = u0, ORIGINAL order
    no_input: np.ndarray       # (N, nx, ny) static conditioning channel
    order: np.ndarray          # trajectory solve order used
    stats: SequenceStats       # one SolveStats per implicit step solved
    sort_seconds: float
    chain_len: float


_inc_rhs = jax.jit(lambda a, b, u: b - stencil5_matvec(a, u))


def _spec_at(specs: TrajectorySpec, i) -> TrajectorySpec:
    return jax.tree_util.tree_map(lambda a: a[i], specs)


def _march_one(family: TimeDepFamily, spec: TrajectorySpec, cfg: TrajConfig,
               solver: GCRODRSolver, stats: Optional[SequenceStats] = None
               ) -> np.ndarray:
    """March ONE trajectory through the θ-scheme with the (stateful) solver;
    returns the (nt+1, nx, ny) field sequence. The carry in `solver`
    survives the call — that is the across-trajectory recycling."""
    nx, ny = family.nx, family.ny
    step1 = family.step_fn()
    out = np.zeros((family.nt + 1, nx, ny))
    u = jnp.asarray(spec.u0)
    out[0] = np.asarray(u)
    for step in range(family.nt):
        t_old, t_new = step * family.dt, (step + 1) * family.dt
        a, b = step1(spec.latent, u, t_old, t_new)
        rhs = _inc_rhs(a, b, u) if cfg.rhs_mode == "increment" else b
        st5 = Stencil5(a)
        pre = make_preconditioner(cfg.precond, st5, use_kernel=cfg.use_kernel)
        op = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel), pre)
        x, st = solver.solve(op, np.asarray(rhs).reshape(-1))
        delta = jnp.asarray(np.asarray(x).reshape(nx, ny))
        u = u + delta if cfg.rhs_mode == "increment" else delta
        out[step + 1] = np.asarray(u)
        if stats is not None:
            stats.append(st)
    return out


def march_trajectory(family: TimeDepFamily, spec: TrajectorySpec,
                     cfg: TrajConfig, solver: Optional[GCRODRSolver] = None
                     ) -> tuple[np.ndarray, SequenceStats]:
    """Convenience single-trajectory march (tests / notebooks): fresh solver
    unless one is passed in to continue an existing recycling chain."""
    solver = solver or GCRODRSolver(cfg.krylov, use_kernel=cfg.use_kernel)
    stats = SequenceStats()
    traj = _march_one(family, spec, cfg, solver, stats)
    return traj, stats


class TrajectoryWork(pipeline.WorkAdapter):
    """Pipeline work adapter for θ-scheme trajectories: one work item = one
    trajectory (nt implicit-step solves on the item's recycle chain)."""

    item_noun = "trajectory"
    ckpt_key = "trajs"   # historical checkpoint field name

    def __init__(self, family: TimeDepFamily, cfg: TrajConfig):
        self.family = family
        self.cfg = cfg
        self.specs: Optional[TrajectorySpec] = None
        self.feats: Optional[np.ndarray] = None
        self.outputs: Optional[np.ndarray] = None

    # ------------------------------------------------------- sampling
    def sample(self, key: jax.Array, num: int) -> np.ndarray:
        self.specs = self.family.sample_specs(key, num)
        self.feats = np.asarray(self.specs.features)
        return self.feats

    # ------------------------------------- sequential (single-chain)
    def alloc_full(self, num: int):
        self.outputs = np.zeros((num, self.family.nt + 1,
                                 self.family.nx, self.family.ny))

    def restore_outputs(self, arr: np.ndarray):
        self.outputs = arr

    def solve_item(self, i: int, solver: GCRODRSolver,
                   stats: SequenceStats) -> list:
        before = len(stats.per_system)
        self.outputs[i] = _march_one(self.family, _spec_at(self.specs, i),
                                     self.cfg, solver, stats)
        return stats.per_system[before:]

    def full_result(self, order, stats, sort_s, clen) -> TrajResult:
        return TrajResult(
            trajectories=self.outputs,
            no_input=np.asarray(self.specs.no_input),
            order=np.asarray(order),
            stats=stats,
            sort_seconds=sort_s,
            chain_len=clen,
        )

    # ---------------------------------------------- chunked engines
    def solve_chunk_sequential(self, sub) -> TrajResult:
        """One chunk of sorted trajectories through the per-system
        sequential solver (fresh recycle chain per chunk, carried across the
        chunk's trajectories — bitwise-matches the single-chain generator
        when workers=1)."""
        solver = self.make_solver()
        stats = SequenceStats()
        trajs = np.zeros((len(sub), self.family.nt + 1,
                          self.family.nx, self.family.ny))
        for pos, i in enumerate(sub):
            trajs[pos] = _march_one(self.family, _spec_at(self.specs, int(i)),
                                    self.cfg, solver, stats)
        return self._chunk_result(sub, trajs, stats)

    def begin_lockstep(self, subs):
        self._subs = subs
        self._trajs = [np.zeros((len(s), self.family.nt + 1,
                                 self.family.nx, self.family.ny))
                       for s in subs]
        self._stats = [SequenceStats() for _ in subs]
        self._stepB = self.family.step_fn_batched()
        self._u0_all = jnp.asarray(self.specs.u0)

    def prepare_row(self, t: int, idx: np.ndarray):
        """Row assembly (prefetch thread): gather the row's trajectory
        latents + initial fields; padded slots get zero fields."""
        clamped = jnp.asarray(np.where(idx >= 0, idx, 0))
        live = idx >= 0
        live_dev = jnp.asarray(live)[:, None, None]
        lat = jax.tree_util.tree_map(lambda a: a[clamped], self.specs.latent)
        u = jnp.where(live_dev, self._u0_all[clamped], 0.0)
        return lat, u, live, live_dev

    def execute_row(self, solver, j: int, idx: np.ndarray, prepared):
        """March row j: at step s, ONE batched (possibly sharded) device
        program advances the s-th implicit step of every chunk's current
        trajectory."""
        family, cfg = self.family, self.cfg
        nx, ny = family.nx, family.ny
        workers = len(idx)
        lat, u, live, live_dev = prepared
        u_np = np.asarray(u)
        for w in np.nonzero(live)[0]:
            self._trajs[w][j, 0] = u_np[w]
        for step in range(family.nt):
            t_old, t_new = step * family.dt, (step + 1) * family.dt
            a, b = self._stepB(lat, u, t_old, t_new)
            rhs = _inc_rhs(a, b, u) if cfg.rhs_mode == "increment" else b
            rhs = jnp.where(live_dev, rhs, 0.0)      # padded chunks, on device
            st5 = Stencil5(a)                        # (W, 5, nx, ny)
            pre = make_preconditioner_batched(cfg.precond, st5,
                                              use_kernel=cfg.use_kernel)
            ops = PreconditionedOp(StencilOp(st5.coeffs, cfg.use_kernel), pre)
            xs, st_list = solver.solve_batch(ops, rhs.reshape(workers, -1),
                                             padded_rows=~live)
            delta = jnp.asarray(xs.reshape(workers, nx, ny))
            u = u + delta if cfg.rhs_mode == "increment" else delta
            u_np = np.asarray(u)                     # one sync per step
            for w in np.nonzero(live)[0]:
                self._trajs[w][j, step + 1] = u_np[w]
                self._stats[w].append(st_list[w])

    def chunk_result(self, w: int) -> TrajResult:
        return self._chunk_result(self._subs[w], self._trajs[w],
                                  self._stats[w])

    def _chunk_result(self, sub, trajs, stats) -> TrajResult:
        sub = np.asarray(sub, dtype=np.int64)
        return TrajResult(
            trajectories=trajs,
            no_input=np.asarray(self.specs.no_input)[sub],
            order=sub,
            stats=stats,
            sort_seconds=0.0,
            chain_len=chain_length(self.feats, sub),
        )


class TrajectoryGenerator:
    """Resumable trajectory data generator over one time-dependent family
    (the `SKRGenerator` of the trajectory subsystem — a thin frontend over
    `core/pipeline.run_resumable`)."""

    def __init__(self, family: TimeDepFamily, cfg: TrajConfig,
                 ckpt_dir: Optional[str] = None):
        self.family = family
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        self._ckpt = NpzCheckpointer(ckpt_dir, "trajgen_state.npz")

    def generate(self, key: jax.Array, num: int,
                 progress_cb: Optional[Callable[[int, int], None]] = None,
                 fail_at: Optional[int] = None) -> TrajResult:
        """Generate `num` trajectories of nt+1 fields each.

        fail_at: fault-injection hook (unit = trajectories) — raises after
        that many trajectories; a rerun resumes from the checkpoint with the
        recycle space intact, mirroring `SKRGenerator.generate`.
        """
        work = TrajectoryWork(self.family, self.cfg)
        return pipeline.run_resumable(work, key, num, ckpt=self._ckpt,
                                      ckpt_every=self.cfg.ckpt_every,
                                      progress_cb=progress_cb,
                                      fail_at=fail_at)


def generate_trajectories(family: TimeDepFamily, key: jax.Array, num: int,
                          cfg: TrajConfig, ckpt_dir: Optional[str] = None,
                          **kw) -> TrajResult:
    return TrajectoryGenerator(family, cfg, ckpt_dir).generate(key, num, **kw)


def generate_trajectories_baseline(family: TimeDepFamily, key: jax.Array,
                                   num: int, krylov: KrylovConfig,
                                   precond: str = "none") -> TrajResult:
    """Cold-start baseline: plain GMRES (k = 0) per step, no trajectory
    sorting — every implicit solve rebuilds its Krylov space from scratch.
    The benchmark's comparison point for recycled time stepping."""
    cfg = TrajConfig(krylov=dataclasses.replace(krylov, k=0),
                     sort_method="none", precond=precond)
    return TrajectoryGenerator(family, cfg).generate(key, num)


def generate_trajectories_chunked(family: TimeDepFamily, key: jax.Array,
                                  num: int, cfg: TrajConfig, workers: int = 4,
                                  engine: str = "batched") -> list[TrajResult]:
    """Chunk-parallel trajectory datagen: sort the trajectories once, split
    the sorted order into `workers` contiguous chunks, one recycle chain per
    chunk (the App. E.2.2 decomposition lifted to trajectory granularity).

    engine="batched" advances all chunks concurrently in lockstep;
    engine="sharded" additionally shards the chunk-chain axis over the
    `data` mesh (all available devices); engine="sequential" runs chunks
    back-to-back (paper-parity simulation). workers=1 always takes the
    sequential path and is bitwise-identical to
    `TrajectoryGenerator.generate` on the same key. Configs the lockstep
    engine cannot batch (`ilu_host`, `ritz_refresh="final"`) auto-route to
    the sequential path, mirroring `generate_dataset_chunked`.
    """
    work = TrajectoryWork(family, cfg)
    return pipeline.run_chunked(work, key, num, workers, engine)
