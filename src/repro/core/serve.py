"""Streaming datagen front end — continuous chain-batching over the
lockstep engines (the ROADMAP "datagen-as-a-service" item).

Everything in `core/pipeline.py` is offline: sort a CLOSED set (paper
Algorithm 1), partition it into chains, drain the lockstep rows. This
module serves an OPEN stream: requests arrive continuously, are assigned
online to the nearest live recycle chain, and lockstep slots that retire a
finished chain are refilled mid-flight from the queue instead of riding as
zero-RHS padding. The paper's §5.2 robustness analysis is what licenses
the greedy online assignment — the recycled small-eigenvalue subspace
tolerates a non-optimal ordering, so "nearest live chain head now" is a
good-enough stand-in for a global sort.

The loop borrows the classic continuous-batching shape of LLM serving
stacks (request queue → slot recycling → prefetch the next wave while the
device works):

  ingest    arrivals visible at the current clock enter a bounded queue
  admit     each queued request is scored against the CURRENT HEAD feature
            of every live chain (`sorting.nearest_features`, one
            incremental Algorithm-1 step). Within the similarity budget →
            append to that chain's FIFO (its carry will be relevant by the
            time the request reaches the device). Otherwise a free slot
            opens a fresh chain — adopting the retiring chain's carry when
            the new head is within budget of the slot's LAST head, else
            clearing it via `solver.swap_slot(w)` (carry hygiene: a refill
            never inherits a foreign chain's subspace unless assignment
            said so). A chain closes to appends once it accumulates
            `max_chain` items (stale-carry guard) or its backlog reaches
            `max_backlog` (a deep FIFO is worse latency than a cold
            chain). Deadline-expired requests are force-admitted to the
            least-bad live chain, budget ignored.
  dispatch  one lockstep wave: the head item of every occupied slot, -1
            padding elsewhere — a single `solve_batch`, same shapes every
            time, so jit never recompiles across refills.
  retire    finished items complete their requests; an emptied slot is
            `PhaseMask.finish`ed and becomes refillable at the NEXT admit
            pass (mid-flight — it never drains as padding while work is
            queued). With `refill="wave"` admission only runs when every
            slot is free: the padding-only baseline that drains each
            admitted wave-set to empty, offline-style.
  prefetch  when every slot stays occupied, the next wave's composition is
            already final (appends only extend FIFO tails; opens need a
            free slot), so its host assembly is submitted to a one-thread
            executor while the device solves — exactly the offline
            pipeline's overlap, gated on `work.stream_prefetchable`
            (trajectory assembly consumes the previous step's solution, so
            it cannot run ahead).

Clock: virtual seconds. `tick` fixed per dispatch makes runs fully
deterministic (tests); `tick=None` advances by measured wall time
(benchmarks). Idle gaps jump straight to the next arrival — waiting for
traffic is not padding.

Work adapters: `skr.SteadyStream` (one dispatch per item) and
`trajectory.TrajectoryStream` (nt dispatches per item; slots drift out of
phase, stepped per-slot via `TimeDepFamily.step_fn_streamed`). Streaming
v1 keeps the solver-level containment (quarantine, divergence guards) but
not the offline requeue ladder: an unhealthy solve flags `label_ok` and
the stream moves on.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional

import numpy as np

from repro import obs
from repro.core import pipeline, sorting


@dataclasses.dataclass
class Request:
    """One streamed work item: an index into the stream work's sampled
    batch plus arrival/deadline metadata. The scheduler fills the
    admission/completion fields."""

    item: int                          # index into work's sampled batch
    arrival: float = 0.0               # virtual seconds
    deadline: Optional[float] = None   # ABSOLUTE admission deadline
    # filled by the scheduler:
    rid: int = -1
    chain: int = -1
    admitted: float = np.nan
    completed: float = np.nan
    forced: bool = False               # admitted past-deadline, budget ignored

    @property
    def latency(self) -> float:
        return self.completed - self.arrival


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    slots: int = 4                     # lockstep width B
    queue_cap: int = 4096              # bounded admission queue
    # None auto-calibrates: budget_scale × median nearest-neighbor
    # Frobenius distance over the sampled features (sorting.typical_nn_
    # distance). A negative budget never matches — every chain is fresh.
    similarity_budget: Optional[float] = None
    budget_scale: float = 1.5
    max_chain: int = 64                # stale-carry guard: chain closes after
    max_backlog: int = 4               # FIFO depth beyond which appends stop
    deadline: Optional[float] = None   # default relative deadline per request
    refill: str = "midflight"          # midflight | wave (padding baseline)
    tick: Optional[float] = None       # fixed virtual secs/dispatch; None=wall
    prefetch: bool = True

    def __post_init__(self):
        assert self.slots >= 1
        assert self.refill in ("midflight", "wave"), self.refill


@dataclasses.dataclass
class StreamReport:
    completed: List[Request]
    utilization: float                 # live fraction of dispatched rows
    dispatches: int
    rows_live: int
    rows_total: int
    forced: int                        # deadline force-admissions
    chains: int                        # chains opened
    makespan: float                    # final clock (virtual seconds)
    budget: float                      # resolved similarity budget

    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.completed], dtype=np.float64)

    def latency_percentile(self, q: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, q)) if lat.size else 0.0

    @property
    def throughput(self) -> float:
        """Completed items per virtual second."""
        return len(self.completed) / self.makespan if self.makespan > 0 \
            else float(len(self.completed))


def poisson_trace(num: int, rate: float, seed: int = 0,
                  deadline: Optional[float] = None) -> List[Request]:
    """Seeded Poisson-arrival request trace: exponential inter-arrival
    gaps at `rate` items/virtual-second over items 0..num-1."""
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / rate, size=num))
    return [Request(item=i, arrival=float(arr[i]), deadline=deadline)
            for i in range(num)]


class StreamScheduler:
    """Online admission + mid-flight slot refill over one stream work
    adapter (module docstring). `run(requests)` drives the full trace to
    completion and returns a `StreamReport`; per-item outputs land on the
    adapter (`work.outputs`, `work.label_ok`, `work.stats`)."""

    def __init__(self, work, cfg: StreamConfig = StreamConfig()):
        self.work = work
        self.cfg = cfg
        self.budget: Optional[float] = None   # resolved on run()

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> StreamReport:
        cfg, work = self.cfg, self.work
        B = int(cfg.slots)
        work.begin_stream(B)
        solver = work.make_lockstep_solver()
        # all slots start FREE: refill() doubles as "open", so the slot
        # table sees exactly one code path for fresh and recycled slots
        mask = pipeline.PhaseMask(np.zeros(B, dtype=bool))
        fifos = [deque() for _ in range(B)]          # admitted, per slot
        counts = np.zeros(B, dtype=np.int64)         # items in current chain
        last_feat: List[Optional[np.ndarray]] = [None] * B   # newest head
        budget = cfg.similarity_budget
        if budget is None:
            budget = cfg.budget_scale * sorting.typical_nn_distance(work.feats)
        self.budget = budget = float(budget)

        reqs = sorted(requests, key=lambda r: r.arrival)
        for rid, r in enumerate(reqs):
            r.rid = rid
        pending = deque(reqs)                        # future arrivals
        queue: deque = deque()                       # visible, unadmitted
        completed: List[Request] = []
        forced = 0
        next_chain = 0
        rows_live = rows_total = dispatches = 0
        now = 0.0
        feat_dim = work.feats.shape[1]
        zero_feat = np.zeros(feat_dim)

        def resolve_deadline(req: Request) -> Optional[float]:
            if req.deadline is not None:
                return req.deadline
            if cfg.deadline is not None:
                return req.arrival + cfg.deadline
            return None

        def place(req: Request, w: int, feat: np.ndarray):
            was_empty = not fifos[w]
            fifos[w].append(req)
            counts[w] += 1
            last_feat[w] = feat
            req.admitted = now
            req.chain = int(mask.chain[w])
            if was_empty:
                work.start_item(w, req.item)

        def open_slot(req: Request, feat: np.ndarray):
            nonlocal next_chain
            free = np.nonzero(~mask.active)[0]
            # prefer a retired slot whose LAST chain head is within budget:
            # its carry is still relevant and gets ADOPTED; any other slot
            # is cleared so the new chain never inherits a foreign subspace
            cand = [int(v) for v in free if last_feat[v] is not None]
            w, adopt = int(free[0]), False
            if cand:
                wc, d = sorting.nearest_features(
                    feat, np.stack([last_feat[v] for v in cand]))
                if wc >= 0 and d[wc] <= budget:
                    w, adopt = cand[wc], True
            if not adopt:
                solver.swap_slot(w)
            mask.refill(w, next_chain)
            next_chain += 1
            counts[w] = 0
            place(req, w, feat)

        def admit():
            nonlocal forced
            if cfg.refill == "wave" and mask.any_active:
                return   # padding baseline: admission only between waves
            keep: deque = deque()
            while queue:
                req = queue.popleft()
                feat = np.asarray(work.feats[req.item], dtype=np.float64)
                heads = np.stack([lf if lf is not None else zero_feat
                                  for lf in last_feat])
                backlog_ok = np.array([len(f) < cfg.max_backlog
                                       for f in fifos])
                open_mask = mask.active & (counts < cfg.max_chain) \
                    & backlog_ok
                w, d = sorting.nearest_features(feat, heads, open_mask)
                if w >= 0 and d[w] <= budget:
                    place(req, w, feat)
                    continue
                if not mask.active.all():
                    open_slot(req, feat)
                    continue
                dl = resolve_deadline(req)
                if dl is not None and now >= dl:
                    # past deadline: least-bad live chain, budget ignored
                    # (only the staleness cap still applies when possible)
                    wf, _ = sorting.nearest_features(
                        feat, heads, mask.active & (counts < cfg.max_chain))
                    if wf < 0:
                        wf, _ = sorting.nearest_features(feat, heads,
                                                         mask.active)
                    if wf >= 0:
                        req.forced = True
                        forced += 1
                        place(req, wf, feat)
                        continue
                keep.append(req)
            queue.extend(keep)

        ex = None
        if cfg.prefetch and getattr(work, "stream_prefetchable", False):
            ex = ThreadPoolExecutor(max_workers=1,
                                    thread_name_prefix="stream-prefetch")
        pre_items = None
        pre_fut = None
        try:
            while pending or queue or mask.any_active:
                while pending and pending[0].arrival <= now \
                        and len(queue) < cfg.queue_cap:
                    queue.append(pending.popleft())
                admit()
                if not mask.any_active:
                    if queue:   # cannot happen: free slots always admit
                        raise RuntimeError(
                            "stream scheduler stalled with a non-empty "
                            "queue and no live slot")
                    if not pending:
                        break
                    # idle gap: jump the clock to the next arrival instead
                    # of dispatching empty waves — waiting is not padding
                    now = max(now, pending[0].arrival)
                    continue
                slot_items = np.array(
                    [fifos[w][0].item if mask.active[w] else -1
                     for w in range(B)], dtype=np.int64)
                t0 = time.perf_counter()
                prepared = None
                if pre_fut is not None:
                    got = pre_fut.result()
                    if np.array_equal(pre_items, slot_items):
                        prepared = got
                    pre_fut = pre_items = None
                if prepared is None:
                    with obs.span("stream_assemble", cat="serve"):
                        prepared = work.assemble(slot_items)
                with obs.span("stream_dispatch", cat="serve",
                              live=int(mask.active.sum())):
                    done = work.apply(solver, slot_items, prepared)
                now += cfg.tick if cfg.tick is not None \
                    else time.perf_counter() - t0
                live = int(mask.active.sum())
                rows_live += live
                rows_total += B
                dispatches += 1
                obs.record_stream(len(queue), live, B)
                for w in range(B):
                    if not (mask.active[w] and done[w]):
                        continue
                    req = fifos[w].popleft()
                    req.completed = now
                    completed.append(req)
                    if fifos[w]:
                        work.start_item(w, fifos[w][0].item)
                    else:
                        mask.finish(w)   # refillable at the next admit pass
                # speculative next-wave assembly: with every slot still
                # occupied the composition is final — appends only extend
                # FIFO tails and opens need a free slot
                if ex is not None and mask.active.all():
                    pre_items = np.array([fifos[w][0].item
                                          for w in range(B)], dtype=np.int64)
                    pre_fut = ex.submit(work.assemble, pre_items)
        finally:
            if ex is not None:
                if pre_fut is not None:
                    pre_fut.cancel()
                ex.shutdown(wait=False, cancel_futures=True)

        util = rows_live / rows_total if rows_total else 1.0
        return StreamReport(completed=completed, utilization=util,
                            dispatches=dispatches, rows_live=rows_live,
                            rows_total=rows_total, forced=forced,
                            chains=next_chain, makespan=now, budget=budget)
