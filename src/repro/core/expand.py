"""Label expansion: few solves, many labels (operator action in solution
space, after arxiv 2402.05957 "DiffOAS").

SKR (core/skr.py) makes every Krylov solve cheaper; this stage removes the
solve from most labels entirely. Once an anchor solution u of A u = f
exists, new supervised pairs are MANUFACTURED in solution space:

    u' = u + a·std(u)·g           additive GRF perturbation (pde/grf.py)
    u' = u · (1 + a·g)            multiplicative GRF perturbation
    u' = λ·u + (1−λ)·u_prev       convex combination of same-chain anchors
    f' = A u'                     one batched SpMV — no solver in the loop

(f', u') is an EXACT pair of the operator by construction (machine eps —
tests/test_expand.py checks it against the dense oracle), so labels/s
decouples from Krylov iterations: each retired anchor fans into K derived
labels for the price of one `dia_spmv` batch row.

Dispatch shape: one expansion WAVE re-labels every anchor of a retired
lockstep row at once — the (K+1)·anchors perturbed solutions stack on the
batch axis of a single `dia_spmv_batched_pallas`-family dispatch, with the
anchor operators broadcast by the `op_stride` index-arithmetic path
(kernels/ops.py) instead of K+1 materialized copies. Anchors enter the wave
DEVICE-RESIDENT (the lockstep solver's `x_device` stash / the trajectory
march's live state) and results accumulate as device arrays until
`result()` drains them in one bulk fetch — expansion adds ZERO extra H2D
traffic and ZERO host syncs to the solve loop (tests/test_transfer_guard.py
runs a wave under `jax.transfer_guard("disallow")`).

Slot 0 of every anchor's fan-out is the anchor itself, re-labeled under the
same manufactured-RHS convention (f = A u — for a converged anchor this
equals its b to solver tolerance, and for θ-scheme steps with zero source
it IS the previous state, so trajectory expansion emits genuine one-step
pairs). Provenance rides per label: `anchor_idx` (original sample index),
`kind` ("solved" for slot 0, "expanded" otherwise), `t` (snapshot time;
0 for steady systems).

Determinism: slot j of anchor i at step s draws from
`fold_in(fold_in(fold_in(PRNGKey(seed), i), s), j)` — independent of
engine, batch shape, wave order and K (the `pde/grf.py` fold_in contract),
so sequential and lockstep engines emit identical labels (combine=0;
convex combinations pair each anchor with its chain PREDECESSOR, which is
an engine-dependent notion — documented, not an invariant).

Health interplay (core/robust.py): only healthy anchors expand — a wave
masks unhealthy/padded rows out at drain time, `drop_anchor` retracts every
label of an anchor whose trajectory was tainted after the fact, and the
requeue path re-expands from the re-solved anchor (labels appended after
the drop survive it).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import ops
from repro.pde.dia import Stencil5
from repro.pde.grf import GRFSpec, sample_grf

MODES = ("additive", "multiplicative")


@dataclasses.dataclass(frozen=True)
class ExpandConfig:
    """The label-expansion axis on `SKRConfig` / `TrajConfig` (None = off —
    bitwise-identical pre-expansion pipeline).

    k         : derived labels per anchor (the anchor itself ships too, so
                each healthy anchor yields k+1 labels)
    mode      : "additive" (u + amplitude·std(u)·g) or "multiplicative"
                (u·(1 + amplitude·g)) GRF perturbation
    amplitude : perturbation strength relative to the anchor field
    combine   : fraction of the k slots drawn as convex combinations with
                the chain's previous healthy anchor instead (0 disables;
                round(combine·k) slots, falling back to perturbation for a
                chain's first anchor)
    seed      : expansion key seed (independent of the datagen key)
    grf_alpha/grf_tau: smoothness of the perturbation fields (the same
                Matérn-like spectrum the samplers use; higher alpha /
                lower tau = smoother perturbations)
    boundary  : "dirichlet" multiplies perturbation fields by a
                sin(πx)·sin(πy) bubble before normalization — the FFT GRF
                draws are periodic and carry full amplitude at the grid
                edge, while solutions of the Dirichlet families decay to
                the boundary; untapered perturbations push u' off the
                solution manifold there, which measurably degrades FNO
                training on expanded labels. "none" disables (periodic /
                Neumann problems).
    """

    k: int = 8
    mode: str = "additive"
    amplitude: float = 0.1
    combine: float = 0.0
    seed: int = 0
    grf_alpha: float = 3.0
    grf_tau: float = 5.0
    boundary: str = "dirichlet"

    def __post_init__(self):
        assert self.k >= 1, self.k
        assert self.mode in MODES, self.mode
        assert self.amplitude > 0.0, self.amplitude
        assert 0.0 <= self.combine <= 1.0, self.combine
        assert self.boundary in ("dirichlet", "none"), self.boundary

    @property
    def k_comb(self) -> int:
        return int(round(self.combine * self.k))


@dataclasses.dataclass
class LabelSet:
    """The expanded dataset: (f, u) pairs with per-label provenance.

    Every row satisfies f = A_{anchor} u to machine eps by construction.
    `kind` is "solved" for the anchor rows (slot 0 of each fan-out) and
    "expanded" for manufactured rows; `anchor_idx` is the ORIGINAL sample
    index of the anchor; `t` the snapshot time (0.0 for steady systems).
    """

    f: np.ndarray           # (L, nx, ny) manufactured inputs  A u'
    u: np.ndarray           # (L, nx, ny) solution-space labels u'
    anchor_idx: np.ndarray  # (L,) int64
    kind: np.ndarray        # (L,) "solved" | "expanded"
    t: np.ndarray           # (L,) float64 snapshot time

    def __len__(self) -> int:
        return int(self.f.shape[0])

    @classmethod
    def empty(cls, nx: int, ny: int) -> "LabelSet":
        return cls(f=np.zeros((0, nx, ny)), u=np.zeros((0, nx, ny)),
                   anchor_idx=np.zeros(0, np.int64),
                   kind=np.zeros(0, dtype="<U8"), t=np.zeros(0))

    @classmethod
    def concat(cls, parts: list) -> "LabelSet":
        return cls(*(np.concatenate([getattr(p, f.name) for p in parts])
                     for f in dataclasses.fields(cls)))

    def select(self, mask: np.ndarray) -> "LabelSet":
        return LabelSet(*(getattr(self, f.name)[mask]
                          for f in dataclasses.fields(LabelSet)))


# --------------------------------------------------------- device programs
# Compiled once per (config, grid) and SHARED across Expander instances —
# a fresh Expander is built per generation run (per chunk, per lockstep
# batch), and per-instance `jax.jit` closures would recompile the wave
# programs every run, burying the per-label cost under ~seconds of
# compilation. ExpandConfig and GRFSpec are frozen/hashable, so they key
# the cache directly.

@functools.lru_cache(maxsize=None)
def _perturb_program(cfg: ExpandConfig, spec: GRFSpec):
    k, k_comb = cfg.k, cfg.k_comb
    amp = float(cfg.amplitude)
    base_key = jax.random.PRNGKey(cfg.seed)
    if cfg.boundary == "dirichlet":
        # interior-point Dirichlet bubble: tapers the periodic GRF draws
        # to zero at the (implicit) boundary like the solutions they
        # perturb; a trace-time constant folded into the jitted program
        bx = np.sin(np.pi * (np.arange(spec.nx) + 1) / (spec.nx + 1))
        by = np.sin(np.pi * (np.arange(spec.ny) + 1) / (spec.ny + 1))
        taper = jnp.asarray(bx[:, None] * by[None, :])
    else:
        taper = None

    def one(uf, i, s, up, hp):
        """uf (nx, ny) anchor; i, s scalars (anchor index, step);
        up (nx, ny) previous same-chain anchor; hp scalar bool.
        Returns (k+1, nx, ny): [anchor, k perturbed/combined]."""
        key = jax.random.fold_in(jax.random.fold_in(base_key, i), s)
        keys = jax.vmap(lambda j: jax.random.fold_in(key, j))(
            jnp.arange(k))
        g = jax.vmap(lambda kk: sample_grf(spec, kk)[0])(keys)
        if taper is not None:
            g = g * taper[None]
        g = g / (jnp.std(g, axis=(1, 2), keepdims=True) + 1e-30)
        if cfg.mode == "additive":
            pert = uf[None] + amp * jnp.std(uf) * g
        else:
            pert = uf[None] * (1.0 + amp * g)
        if k_comb > 0:
            lam = jax.vmap(
                lambda kk: jax.random.uniform(kk, dtype=uf.dtype))(
                    keys[:k_comb])[:, None, None]
            comb = lam * uf[None] + (1.0 - lam) * up[None]
            pert = pert.at[:k_comb].set(
                jnp.where(hp, comb, pert[:k_comb]))
        return jnp.concatenate([uf[None], pert], axis=0)

    return jax.jit(jax.vmap(one))


@functools.lru_cache(maxsize=None)
def _spmv_program(kp1: int, use_kernel: bool):
    def spmv(coeffs, u_flat):
        # DIA export + strided SpMV fused in ONE jitted program (the
        # export indexes stencil bands — host ints, fine under jit)
        return ops.dia_spmv(Stencil5(coeffs).to_dia(), u_flat,
                            op_stride=kp1, use_kernel=use_kernel)

    return jax.jit(spmv)


@functools.lru_cache(maxsize=None)
def _row_take():
    return jax.jit(lambda a, w: a[w])   # guard-safe row gather


@functools.lru_cache(maxsize=None)
def _zero_field(nx: int, ny: int):
    # jitted constant: no host→device scalar transfer under guard
    return jax.jit(lambda: jnp.zeros((nx, ny)))()


class Expander:
    """Accumulates expansion waves device-side; drains once at finalize.

    One instance serves one generation run (all chains). Wave inputs stay
    on device; per-wave host metadata (anchor indices, health mask, times,
    chain ids) is plain numpy the caller already owns — submitting a wave
    performs no host sync and no H2D transfer beyond what the caller
    already uploaded for the solve itself.
    """

    def __init__(self, cfg: ExpandConfig, nx: int, ny: int,
                 use_kernel: bool = False):
        self.cfg = cfg
        self.nx, self.ny = int(nx), int(ny)
        self.use_kernel = use_kernel
        self.spec = GRFSpec(nx=self.nx, ny=self.ny, alpha=cfg.grf_alpha,
                            tau=cfg.grf_tau)
        self._perturb = _perturb_program(cfg, self.spec)
        self._spmv = _spmv_program(cfg.k + 1, use_kernel)
        self._take = _row_take()
        self._records: list = []       # per-wave device arrays + host meta
        self._drops: dict = {}         # anchor_idx -> seq at drop time
        self._seq = 0
        self._prev: dict = {}          # chain -> (u_dev (nx,ny), anchor)
        self._restored: Optional[LabelSet] = None
        self._cache = None             # drained LabelSet (+chain), memoized
        self._t0 = time.perf_counter()

    def _wave_arrays(self, coeffs, u, idx_dev, step_dev, up, hp):
        """(u_all, f_all) each (B, k+1, nx, ny), device-resident. One
        perturbation dispatch + ONE strided SpMV dispatch for the whole
        wave (B anchors × (k+1) vectors against B operators)."""
        kp1 = self.cfg.k + 1
        u_all = self._perturb(u, idx_dev, step_dev, up, hp)
        bsz = u_all.shape[0]
        f = self._spmv(coeffs, u_all.reshape(bsz * kp1, -1))
        return u_all, f.reshape(bsz, kp1, self.nx, self.ny)

    # ------------------------------------------------------------- waves
    def wave(self, coeffs, u, idx, live, chain=None, t=0.0, step=0):
        """Expand one retired lockstep row.

        coeffs (B, 5, nx, ny) and u (B, nx, ny) DEVICE-resident (the row's
        operator stack / the solver's device solution); idx (B,) np int
        original anchor indices; live (B,) np bool — healthy, non-padded
        rows (dead rows ride the dispatch as zero work and are masked out
        at drain); chain (B,) np int owning chain per row (defaults to the
        row index); t scalar or (B,) snapshot times; step scalar or (B,)
        int key-derivation step (0 for steady systems)."""
        idx = np.asarray(idx, np.int64)
        live = np.asarray(live, bool)
        bsz = idx.shape[0]
        chain = (np.arange(bsz) if chain is None
                 else np.asarray(chain, np.int64))
        t = np.broadcast_to(np.asarray(t, np.float64), (bsz,)).copy()
        step_np = np.broadcast_to(np.asarray(step, np.int64), (bsz,)).copy()
        # explicit device placement (a no-op for the already-resident
        # lockstep inputs; permitted under jax.transfer_guard("disallow"))
        u = jnp.asarray(u).reshape(bsz, self.nx, self.ny)
        up, hp = self._gather_prev(chain, live, u)
        u_all, f_all = self._wave_arrays(
            coeffs, u, jnp.asarray(idx), jnp.asarray(step_np), up, hp)
        self._push(u_all, f_all, idx, live, chain, t)

    def expand_one(self, coeffs, u, i, chain=0, t=0.0, step=0):
        """Sequential-engine fan-out of ONE healthy anchor (a B=1 wave —
        same device program, same keys, so labels match the lockstep waves
        element-for-element at combine=0)."""
        coeffs = jnp.asarray(coeffs).reshape(1, 5, self.nx, self.ny)
        u = jnp.asarray(u).reshape(1, self.nx, self.ny)
        self.wave(coeffs, u, np.array([i]), np.array([True]),
                  chain=np.array([chain]), t=t, step=step)

    def _gather_prev(self, chain, live, u):
        """(u_prev (B, nx, ny), has_prev (B,)) for convex-combination
        slots, then roll the chain state forward with this wave's live
        anchors. With combine=0 the program never reads them, so no state
        is tracked at all (and no per-row device gathers happen — the
        transfer-guard tests run this path)."""
        zero = _zero_field(self.nx, self.ny)
        bsz = len(chain)
        if self.cfg.k_comb == 0:
            return (jnp.broadcast_to(zero, (bsz, self.nx, self.ny)),
                    jnp.asarray(np.zeros(bsz, bool)))
        prevs, flags = [], np.zeros(bsz, bool)
        for w, c in enumerate(chain):
            got = self._prev.get(int(c))
            flags[w] = got is not None
            prevs.append(got if got is not None else zero)
        for w, c in enumerate(chain):
            if live[w]:
                # jnp.asarray(w) is an EXPLICIT transfer (guard-permitted);
                # the row gather itself runs inside jit
                self._prev[int(c)] = self._take(u, jnp.asarray(w))
        return jnp.stack(prevs), jnp.asarray(flags)

    def _push(self, u_all, f_all, idx, live, chain, t):
        self._records.append(dict(u=u_all, f=f_all, idx=idx, live=live,
                                  chain=chain, t=t, seq=self._seq))
        self._seq += 1
        self._cache = None
        obs.counter_add("expand.waves")
        obs.counter_add("expand.labels",
                        int(live.sum()) * (self.cfg.k + 1))

    # ------------------------------------------------------------ health
    def drop_anchor(self, i: int):
        """Retract every label of anchor `i` emitted SO FAR (tainted
        trajectory, excluded anchor). Labels appended afterwards — the
        requeue's re-expansion — survive."""
        self._drops[int(i)] = self._seq
        self._cache = None

    # ----------------------------------------------------------- drain
    def _drain(self):
        """One bulk fetch of every wave's device arrays → host LabelSet
        (+ per-label chain ids for per-chunk slicing). Memoized."""
        if self._cache is not None:
            return self._cache
        kp1 = self.cfg.k + 1
        fetch = jax.device_get([(r["u"], r["f"]) for r in self._records])
        parts, chains = [], []
        for r, (u_np, f_np) in zip(self._records, fetch):
            keep = r["live"].copy()
            for w in np.nonzero(keep)[0]:
                d = self._drops.get(int(r["idx"][w]))
                if d is not None and r["seq"] < d:
                    keep[w] = False
            if not keep.any():
                continue
            nb = int(keep.sum())
            kind = np.full((nb, kp1), "expanded", dtype="<U8")
            kind[:, 0] = "solved"
            parts.append(LabelSet(
                f=f_np[keep].reshape(nb * kp1, self.nx, self.ny),
                u=u_np[keep].reshape(nb * kp1, self.nx, self.ny),
                anchor_idx=np.repeat(r["idx"][keep], kp1),
                kind=kind.reshape(-1),
                t=np.repeat(r["t"][keep], kp1)))
            chains.append(np.repeat(r["chain"][keep], kp1))
        if self._restored is not None:
            parts.insert(0, self._restored)
            chains.insert(0, np.full(len(self._restored), -1, np.int64))
        if parts:
            out = (LabelSet.concat(parts), np.concatenate(chains))
        else:
            out = (LabelSet.empty(self.nx, self.ny),
                   np.zeros(0, np.int64))
        self._cache = out
        return out

    def result(self, chain: Optional[int] = None) -> LabelSet:
        """The accumulated LabelSet (all chains, or one chain's slice).
        Updates the `expand.labels_per_second` gauge against wall time
        since construction."""
        labels, chains = self._drain()
        if obs.enabled():
            dt = max(time.perf_counter() - self._t0, 1e-9)
            obs.gauge_set("expand.labels_per_second", len(labels) / dt)
        if chain is None:
            return labels
        return labels.select(chains == chain)

    # ------------------------------------------------------- checkpoints
    def ckpt_arrays(self) -> dict:
        """Flat npz-ready snapshot of the labels emitted so far (the
        resumable pipeline folds these into its atomic snapshots)."""
        labels = self._drain()[0]
        return {"exp_f": labels.f, "exp_u": labels.u,
                "exp_anchor": labels.anchor_idx, "exp_kind": labels.kind,
                "exp_t": labels.t}

    def restore(self, state: dict):
        """Adopt a checkpoint's labels (items completed before the resume
        point); waves for the remaining items append after them."""
        self._restored = LabelSet(
            f=state["exp_f"], u=state["exp_u"],
            anchor_idx=np.asarray(state["exp_anchor"], np.int64),
            kind=np.asarray(state["exp_kind"]),
            t=np.asarray(state["exp_t"], np.float64))
        self._cache = None
