"""Solver correctness: GMRES / GCRO-DR against dense + scipy oracles,
PETSc-semantics tolerance handling, and the paper's core claims in
miniature (recycling cuts iterations on correlated sequences)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.core.metrics import delta_subspace
from repro.pde.registry import get_family
from repro.solvers.gcrodr import GCRODRSolver, solve_gcrodr
from repro.solvers.gmres import gmres_solve, solve_gmres
from repro.solvers.operator import PreconditionedOp, as_operator
from repro.solvers.precond import make_preconditioner
from repro.solvers.types import KrylovConfig

CFG = KrylovConfig(m=40, k=12, tol=1e-8, maxiter=10_000)


def _one_problem(family="poisson", nx=16, seed=0):
    fam = get_family(family, nx=nx, ny=nx)
    p = fam.sample(jax.random.PRNGKey(seed))
    return fam, p


def _flat(p):
    return np.asarray(p.b, dtype=np.float64).reshape(-1)


@pytest.mark.parametrize("family", ["poisson", "darcy", "helmholtz",
                                    "thermal", "convdiff"])
def test_gmres_matches_dense_solve(family):
    fam, p = _one_problem(family)
    a = p.op.to_dense()
    b = _flat(p)
    x_ref = np.linalg.solve(a, b)
    x, stats = solve_gmres(p.op, p.b, CFG)
    assert stats.converged, (family, stats)
    np.testing.assert_allclose(np.asarray(x).reshape(-1), x_ref,
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("family", ["poisson", "helmholtz"])
def test_gcrodr_matches_dense_solve(family):
    fam, p = _one_problem(family)
    a = p.op.to_dense()
    b = _flat(p)
    x_ref = np.linalg.solve(a, b)
    x, stats, _ = solve_gcrodr(p.op, p.b, CFG)
    assert stats.converged
    np.testing.assert_allclose(np.asarray(x).reshape(-1), x_ref,
                               rtol=1e-4, atol=1e-6)


def test_tolerance_is_relative_residual():
    """PETSc rtol semantics: ‖b − Ax‖ ≤ tol·‖b‖."""
    _, p = _one_problem("darcy")
    a = p.op.to_dense()
    b = _flat(p)
    for tol in (1e-4, 1e-8):
        cfg = dataclasses.replace(CFG, tol=tol)
        x, stats = solve_gmres(p.op, p.b, cfg)
        res = np.linalg.norm(b - a @ np.asarray(x).reshape(-1))
        assert res <= tol * np.linalg.norm(b) * 1.01
        assert stats.rel_residual <= tol * 1.01


def test_gcrodr_k0_equals_gmres():
    """GMRES is exactly the k=0 special case (paper §4.2)."""
    _, p = _one_problem("poisson")
    cfg = dataclasses.replace(CFG, k=0)
    x_g, st_g = solve_gmres(p.op, p.b, cfg)
    x_r, st_r, _ = solve_gcrodr(p.op, p.b, cfg)
    assert st_g.iterations == st_r.iterations
    np.testing.assert_allclose(np.asarray(x_g), np.asarray(x_r), rtol=1e-12)


def test_skr_beats_gmres_on_sorted_sequence():
    """The paper's central claim, in miniature: the full SKR pipeline
    (sort + recycle) takes materially fewer iterations than independent
    GMRES solves over the same sampled dataset."""
    from repro.core.skr import (SKRConfig, generate_dataset,
                                generate_dataset_baseline)

    fam = get_family("poisson", nx=20, ny=20)
    kc = dataclasses.replace(CFG, m=30, k=10)
    key = jax.random.PRNGKey(1)
    skr = generate_dataset(fam, key, 10,
                           SKRConfig(krylov=kc, precond="jacobi"))
    gm = generate_dataset_baseline(fam, key, 10, kc, precond="jacobi")
    assert all(s.converged for s in skr.stats.per_system)
    # 25%+ iteration reduction at this toy scale (n=400); the ratio GROWS
    # with n and tolerance — 5× at the paper's n=1e4 (EXPERIMENTS.md
    # headline; benchmarks/table1_speedup.py sweeps the full grid).
    assert skr.stats.total_iterations < 0.75 * gm.stats.total_iterations, (
        skr.stats.total_iterations, gm.stats.total_iterations)
    # identical datasets modulo solver tolerance (paper App. E.3)
    np.testing.assert_allclose(skr.solutions, gm.solutions, rtol=1e-5,
                               atol=1e-7)


def test_recycle_space_carries_and_is_orthonormalized():
    _, p = _one_problem("poisson")
    solver = GCRODRSolver(CFG)
    op = PreconditionedOp(as_operator(p.op), None)
    solver.solve(op, jnp.asarray(p.b).reshape(-1))
    assert solver.u_carry is not None
    assert solver.u_carry.shape[1] <= CFG.k
    # after re-orthogonalization against A, C = A·U·R⁻¹ has orthonormal cols
    a = p.op.to_dense()
    au = a @ solver.u_carry
    q, _ = np.linalg.qr(au)
    np.testing.assert_allclose(q.T @ q, np.eye(q.shape[1]), atol=1e-8)


@pytest.mark.parametrize("name", ["jacobi", "bjacobi", "rbsor", "cheby",
                                  "neumann", "ilu_host"])
def test_preconditioners_accelerate_or_match(name):
    _, p = _one_problem("darcy", nx=20)
    cfg = dataclasses.replace(CFG, tol=1e-8)
    _, st_plain = solve_gmres(p.op, p.b, cfg)
    pre = make_preconditioner(name, p.op)
    base = as_operator(p.op)
    x, st_pre = gmres_solve(PreconditionedOp(base, pre),
                            jnp.asarray(p.b).reshape(-1), cfg)
    assert st_pre.converged
    # right preconditioning must preserve the TRUE residual definition
    a = p.op.to_dense()
    b = _flat(p)
    res = np.linalg.norm(b - a @ np.asarray(x).reshape(-1))
    assert res <= cfg.tol * np.linalg.norm(b) * 1.01
    assert st_pre.iterations <= st_plain.iterations * 1.5


def test_mgs_and_cgs2_agree():
    _, p = _one_problem("convdiff")
    x1, st1 = solve_gmres(p.op, p.b, dataclasses.replace(CFG, orthog="mgs"))
    x2, st2 = solve_gmres(p.op, p.b, dataclasses.replace(CFG, orthog="cgs2"))
    assert st1.converged and st2.converged
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-6,
                               atol=1e-9)


def test_recycle_space_captures_small_eigendirections():
    """After one solve the recycled U_k captures most of the smallest-
    magnitude invariant subspace (principal cosines ≈ 1), and warm-restarting
    the SAME system costs materially fewer iterations (Theorem 1 in action).
    δ(Q,C) itself is a max-angle metric — a single uncaptured direction
    saturates it, so we assert on the cosine spectrum instead."""
    from repro.core.metrics import (orthonormalize,
                                    smallest_invariant_subspace)

    _, p = _one_problem("helmholtz")
    solver = GCRODRSolver(CFG)
    op = PreconditionedOp(as_operator(p.op), None)
    b = jnp.asarray(p.b).reshape(-1)
    _, st_cold = solver.solve(op, b)
    a = p.op.to_dense()
    q = smallest_invariant_subspace(a, k=CFG.k)
    u = orthonormalize(solver.u_carry)
    cos = np.linalg.svd(q.T @ u, compute_uv=False)
    assert (cos > 0.9).sum() >= CFG.k // 2, cos
    _, st_warm = solver.solve(op, b)
    assert st_warm.iterations < 0.8 * st_cold.iterations
    # and δ is a valid metric value
    d = delta_subspace(q, solver.u_carry)
    assert 0.0 <= d <= 1.0 + 1e-9


def test_gmres_matches_scipy_iteration_scale():
    """Sanity vs scipy.sparse.linalg.gmres on the same operator (allowing
    implementation variance but same order of magnitude)."""
    _, p = _one_problem("poisson")
    a = p.op.to_scipy() if hasattr(p.op, "to_scipy") else p.op.to_dense()
    b = _flat(p)
    counter = {"n": 0}

    def cb(_):
        counter["n"] += 1

    spla.gmres(a, b, rtol=1e-9, restart=30, maxiter=100, callback=cb,
               callback_type="pr_norm")
    _, st = solve_gmres(p.op, p.b, CFG)
    assert st.converged
    assert st.iterations <= max(3 * counter["n"], 60)
