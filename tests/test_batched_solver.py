"""Lockstep batched GCRO-DR: per-chain equivalence with the sequential
solver, the k=0 ≡ vmapped-GMRES special case, chunked-datagen engine
equivalence + padding semantics, and the batched DIA-SpMV kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.skr import (SKRConfig, SKRGenerator, generate_dataset,
                            generate_dataset_chunked)
from repro.pde.dia import DIA, Stencil5
from repro.pde.registry import get_family
from repro.solvers.batched import BatchedGCRODRSolver
from repro.solvers.gcrodr import GCRODRSolver
from repro.solvers.gmres import gmres_solve
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import (make_preconditioner,
                                   make_preconditioner_batched)
from repro.solvers.types import KrylovConfig

# tol 1e-9 leaves the batched-vs-sequential float-reassociation drift
# (vmapped matmuls + eig-selection sensitivity in the recycle refresh)
# comfortably under the 1e-8 equivalence budget asserted below
KC = KrylovConfig(m=30, k=10, tol=1e-9, maxiter=6000)


def _chains(family="poisson", nx=12, num=6, chains=2, seed=3, precond="jacobi"):
    """Sample `num` systems and split them into `chains` equal chunks."""
    fam = get_family(family, nx=nx, ny=nx)
    batch = fam.sample_batch(jax.random.PRNGKey(seed), num)
    coeffs = jnp.asarray(batch.op.coeffs)
    b_all = np.asarray(batch.b).reshape(num, -1)
    per = num // chains
    subs = [list(range(w * per, (w + 1) * per)) for w in range(chains)]
    return coeffs, b_all, subs


def _solve_sequential(coeffs, b_all, subs, cfg, precond="jacobi"):
    out = {}
    for sub in subs:
        solver = GCRODRSolver(cfg)
        for i in sub:
            st5 = Stencil5(coeffs[i])
            pre = make_preconditioner(precond, st5)
            op = PreconditionedOp(StencilOp(st5.coeffs), pre)
            x, st = solver.solve(op, b_all[i])
            out[i] = (x, st)
    return out


def _solve_batched(coeffs, b_all, subs, cfg, precond="jacobi"):
    out = {}
    solver = BatchedGCRODRSolver(cfg)
    for t in range(len(subs[0])):
        idx = np.array([sub[t] for sub in subs])
        st5 = Stencil5(coeffs).take(jnp.asarray(idx))
        pre = make_preconditioner_batched(precond, st5)
        ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
        xs, stats = solver.solve_batch(ops, jnp.asarray(b_all[idx]))
        for w, i in enumerate(idx):
            out[int(i)] = (xs[w], stats[w])
    return out


@pytest.mark.parametrize("family", ["poisson", "darcy"])
def test_batched_matches_sequential_per_chain(family):
    """Acceptance: per-chain solutions agree with the existing GCRODRSolver
    to <= 1e-8 relative error, chains keep independent recycle carries."""
    coeffs, b_all, subs = _chains(family=family)
    seq = _solve_sequential(coeffs, b_all, subs, KC)
    bat = _solve_batched(coeffs, b_all, subs, KC)
    for i in seq:
        x_seq, st_seq = seq[i]
        x_bat, st_bat = bat[i]
        assert st_seq.converged and st_bat.converged, (i, st_seq, st_bat)
        rel = (np.linalg.norm(x_bat - x_seq)
               / max(np.linalg.norm(x_seq), 1e-300))
        assert rel <= 1e-8, (i, rel)
        # same trajectory family: iteration counts stay in the same regime
        assert st_bat.iterations <= max(1.5 * st_seq.iterations,
                                        st_seq.iterations + KC.m), i


def test_batched_k0_equals_vmapped_gmres():
    """k=0 lockstep == restarted GMRES per chain (paper §4.2 batched)."""
    cfg = dataclasses.replace(KC, k=0)
    coeffs, b_all, subs = _chains(num=4, chains=4)
    bat = _solve_batched(coeffs, b_all, subs, cfg)
    for i in range(4):
        st5 = Stencil5(coeffs[i])
        pre = make_preconditioner("jacobi", st5)
        op = PreconditionedOp(StencilOp(st5.coeffs), pre)
        x_ref, st_ref = gmres_solve(op, jnp.asarray(b_all[i]), cfg)
        x_bat, st_bat = bat[i]
        assert st_ref.converged and st_bat.converged
        np.testing.assert_allclose(np.asarray(x_bat), np.asarray(x_ref),
                                   rtol=1e-6, atol=1e-10)


def test_batched_fused_kernel_path_matches_default():
    """use_kernel=True routes the whole inner iteration through the fused
    arnoldi_step Pallas kernel (interpret mode on CPU); solutions must agree
    with the composed-jnp default path to the lockstep equivalence budget."""
    coeffs, b_all, subs = _chains(num=4, chains=2)
    ref_out = _solve_batched(coeffs, b_all, subs, KC)
    out = {}
    solver = BatchedGCRODRSolver(KC, use_kernel=True)
    for t in range(len(subs[0])):
        idx = np.array([sub[t] for sub in subs])
        st5 = Stencil5(coeffs).take(jnp.asarray(idx))
        pre = make_preconditioner_batched("jacobi", st5)
        ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
        xs, stats = solver.solve_batch(ops, jnp.asarray(b_all[idx]))
        for w, i in enumerate(idx):
            out[int(i)] = (xs[w], stats[w])
    for i in ref_out:
        x_ref, st_ref = ref_out[i]
        x_ker, st_ker = out[i]
        assert st_ref.converged and st_ker.converged, (i, st_ref, st_ker)
        rel = (np.linalg.norm(x_ker - x_ref)
               / max(np.linalg.norm(x_ref), 1e-300))
        assert rel <= 1e-8, (i, rel)


def test_batched_zero_rhs_is_padding_noop():
    """A zero RHS row (padded chain) converges at 0 iterations with x = 0
    and leaves the chain's recycle carry untouched."""
    coeffs, b_all, subs = _chains(num=4, chains=2)
    solver = BatchedGCRODRSolver(KC)
    idx = np.array([0, 1])
    st5 = Stencil5(coeffs).take(jnp.asarray(idx))
    pre = make_preconditioner_batched("jacobi", st5)
    ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
    solver.solve_batch(ops, jnp.asarray(b_all[idx]))
    carry_before = solver.u_carry.copy()
    b_pad = b_all[idx].copy()
    b_pad[1] = 0.0
    xs, stats = solver.solve_batch(ops, jnp.asarray(b_pad))
    assert stats[1].converged and stats[1].iterations == 0
    np.testing.assert_array_equal(xs[1], np.zeros_like(xs[1]))
    np.testing.assert_array_equal(solver.u_carry[1], carry_before[1])
    assert stats[0].converged and stats[0].iterations > 0


def test_chunked_engines_agree_with_padding():
    """batched == sequential engine through the full datagen path, with a
    worker count that does NOT divide num (uneven chunks exercise the
    zero-RHS padding)."""
    fam = get_family("poisson", nx=12, ny=12)
    cfg = SKRConfig(krylov=KC, precond="jacobi")
    key = jax.random.PRNGKey(5)
    seq = generate_dataset_chunked(fam, key, 8, cfg, workers=3,
                                   engine="sequential")
    bat = generate_dataset_chunked(fam, key, 8, cfg, workers=3,
                                   engine="batched")
    assert len(seq) == len(bat) == 3
    for cs, cb in zip(seq, bat):
        np.testing.assert_array_equal(cs.order, cb.order)
        assert cs.solutions.shape == cb.solutions.shape
        for pos in range(len(cs.order)):
            rel = (np.linalg.norm(cb.solutions[pos] - cs.solutions[pos])
                   / max(np.linalg.norm(cs.solutions[pos]), 1e-300))
            assert rel <= 1e-8, (pos, rel)
        assert cs.stats.num_converged == len(cs.order)
        assert cb.stats.num_converged == len(cb.order)


def test_chunked_workers1_bitwise_stable():
    """workers=1 routes through the sequential per-system loop and is
    BITWISE identical to the plain generator on the same key."""
    fam = get_family("poisson", nx=12, ny=12)
    cfg = SKRConfig(krylov=KC, precond="jacobi")
    key = jax.random.PRNGKey(7)
    whole = generate_dataset(fam, key, 6, cfg)
    chunks = generate_dataset_chunked(fam, key, 6, cfg, workers=1)
    assert len(chunks) == 1
    ch = chunks[0]
    np.testing.assert_array_equal(ch.order, whole.order)
    for pos, i in enumerate(ch.order.tolist()):
        np.testing.assert_array_equal(ch.solutions[pos], whole.solutions[i])


def test_batched_solver_rejects_final_refresh():
    cfg = dataclasses.replace(KC, ritz_refresh="final")
    with pytest.raises(NotImplementedError):
        BatchedGCRODRSolver(cfg)


# ------------------------------------------------------------ batched kernel

@pytest.mark.parametrize("bsz,n", [(2, 64), (4, 256), (3, 1000)])
def test_batched_dia_kernel_matches_ref(bsz, n):
    from repro.kernels import ops, ref

    rng = np.random.default_rng(n + bsz)
    offsets = (-8, -1, 0, 1, 8)
    data = jnp.asarray(rng.standard_normal((bsz, len(offsets), n)))
    x = jnp.asarray(rng.standard_normal((bsz, n)))
    dia = DIA(offsets=offsets, data=data)
    got = ops.dia_spmv(dia, x, use_kernel=True, interpret=True)
    want = ref.dia_spmv(offsets, data, x)
    assert got.shape == (bsz, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_batched_dia_kernel_matches_per_system_kernel():
    """One batched launch == B single launches (same kernel semantics)."""
    from repro.kernels.dia_spmv import (dia_spmv_batched_pallas,
                                        dia_spmv_pallas)

    rng = np.random.default_rng(0)
    offsets = (-3, 0, 3)
    bsz, n = 3, 128
    data = jnp.asarray(rng.standard_normal((bsz, len(offsets), n)))
    x = jnp.asarray(rng.standard_normal((bsz, n)))
    got = dia_spmv_batched_pallas(offsets, data, x, interpret=True)
    for i in range(bsz):
        want = dia_spmv_pallas(offsets, data[i], x[i], interpret=True)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=1e-12, atol=1e-12)


def test_stencil5_take_batched_indexing():
    rng = np.random.default_rng(1)
    coeffs = jnp.asarray(rng.standard_normal((5, 5, 8, 8)))
    st = Stencil5(coeffs)
    sub = st.take(jnp.asarray([3, 1]))
    assert sub.coeffs.shape == (2, 5, 8, 8)
    np.testing.assert_array_equal(np.asarray(sub.coeffs[0]),
                                  np.asarray(coeffs[3]))
    one = st.take(2)
    assert one.coeffs.shape == (5, 8, 8)
