"""PDE discretization correctness: manufactured solutions, operator
structure, 2nd-order convergence, feature extraction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pde.dia import Stencil5, laplacian_stencil, zero_boundary_neighbors
from repro.pde.registry import get_family, list_families
from repro.solvers.gmres import solve_gmres
from repro.solvers.types import KrylovConfig

CFG = KrylovConfig(m=40, k=0, tol=1e-10, maxiter=20_000)


def test_laplacian_manufactured_solution():
    """-∇²u = f with u* = sin(πx)sin(πy): finite differences reproduce u*
    to O(h²)."""
    errs = []
    for nx in (16, 32):
        h = 1.0 / (nx + 1)
        g = h * jnp.arange(1, nx + 1)
        xx, yy = jnp.meshgrid(g, g, indexing="ij")
        u_true = jnp.sin(jnp.pi * xx) * jnp.sin(jnp.pi * yy)
        f = 2 * (jnp.pi**2) * u_true          # -∇²u* = f
        coeffs = -laplacian_stencil(nx, nx, h, h)   # +∇² stencil negated
        coeffs = zero_boundary_neighbors(coeffs)
        x, stats = solve_gmres(Stencil5(coeffs), f, CFG)
        assert stats.converged
        errs.append(float(jnp.max(jnp.abs(x - u_true))))
    # halving h quarters the error (2nd order); allow slack
    assert errs[1] < errs[0] / 2.5, errs


@pytest.mark.parametrize("family", list_families())
def test_family_samples_are_wellposed(family):
    fam = get_family(family, nx=12, ny=12) if family != "thermal" else \
        get_family(family, nx=12, ny=12)
    p = fam.sample(jax.random.PRNGKey(0))
    a = p.op.to_dense()
    n = a.shape[0]
    # finite entries, nonsingular, and solvable
    assert np.isfinite(a).all()
    assert np.isfinite(np.asarray(p.b)).all()
    assert np.linalg.matrix_rank(a) == n
    x = np.linalg.solve(a, np.asarray(p.b, dtype=np.float64).reshape(-1))
    assert np.isfinite(x).all()


@pytest.mark.parametrize("family", list_families())
def test_family_batch_matches_single(family):
    fam = get_family(family, nx=10, ny=10)
    key = jax.random.PRNGKey(3)
    batch = fam.sample_batch(key, 4)
    keys = jax.random.split(key, 4)
    single = fam.sample(keys[2])
    np.testing.assert_allclose(np.asarray(batch.b[2]),
                               np.asarray(single.b), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(batch.features[2]),
                               np.asarray(single.features), rtol=1e-12)


def test_features_track_parameters():
    """Sorting features must vary with the sampled NO parameters and be
    deterministic given the key."""
    fam = get_family("darcy", nx=12, ny=12)
    p1 = fam.sample(jax.random.PRNGKey(0))
    p2 = fam.sample(jax.random.PRNGKey(1))
    p1b = fam.sample(jax.random.PRNGKey(0))
    assert not np.allclose(np.asarray(p1.features), np.asarray(p2.features))
    np.testing.assert_array_equal(np.asarray(p1.features),
                                  np.asarray(p1b.features))


def test_stencil_to_dia_roundtrip():
    fam = get_family("poisson", nx=8, ny=8)
    p = fam.sample(jax.random.PRNGKey(0))
    x = np.random.default_rng(0).standard_normal((8, 8))
    y_field = np.asarray(p.op.matvec(jnp.asarray(x)))
    dia = p.op.to_dia()
    y_flat = np.asarray(dia.matvec(jnp.asarray(x.reshape(-1))))
    np.testing.assert_allclose(y_field.reshape(-1), y_flat, rtol=1e-12)
    a1 = p.op.to_dense()
    a2 = dia.to_dense()
    np.testing.assert_allclose(a1, a2, rtol=1e-12)


def test_helmholtz_is_indefinite_and_nonsymmetric_families_exist():
    """The paper targets nonsymmetric systems (GMRES territory): convdiff
    must be nonsymmetric; helmholtz indefinite (negative+positive spectrum
    of the symmetric part)."""
    p = get_family("convdiff", nx=10, ny=10).sample(jax.random.PRNGKey(0))
    a = p.op.to_dense()
    assert np.abs(a - a.T).max() > 1e-8
    ph = get_family("helmholtz", nx=12, ny=12).sample(jax.random.PRNGKey(0))
    ah = ph.op.to_dense()
    evals = np.linalg.eigvalsh((ah + ah.T) / 2)
    assert evals.min() < 0 < evals.max()


def test_thermal_irregular_boundary():
    """Thermal uses an irregular (star) mask — interior size < full grid and
    the masked nodes are identity rows."""
    fam = get_family("thermal", nx=16, ny=16)
    p = fam.sample(jax.random.PRNGKey(0))
    mask = np.asarray(fam.mask)
    assert 0 < mask.sum() < mask.size
    a = p.op.to_dense()
    outside = np.where(mask.reshape(-1) == 0)[0]
    for i in outside[:5]:
        row = a[i]
        assert row[i] != 0
        assert np.count_nonzero(np.delete(row, i)) == 0
