"""Property-based tests (hypothesis; the conftest stub degrades to fixed
deterministic examples when the real package is absent) for the pipeline's
combinatorial invariants:

* sorting (core/sorting.py): every sort method returns a PERMUTATION of the
  input indices — no index dropped, none duplicated — for arbitrary sizes
  and feature clouds; `chain_length` is invariant under which permutation
  representation is fed in.
* chain planning (core/pipeline.py): `plan_chains` covers every position of
  the sorted order exactly once, contiguously, with balanced lengths, for
  arbitrary (n, workers).
* lockstep packing: the `_row_index` rows round-trip back to the exact
  chains (no label corruption through padding), and padding is only ever a
  SUFFIX of a chain's row sequence — a -1 never reappears before a live
  index, which is the alignment property the zero-RHS padding no-op relies
  on.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import pipeline
from repro.core.sorting import chain_length, sort_features

METHODS = ("greedy", "grouped", "hilbert", "random", "none")


def _feats(n: int, seed: int, f: int = 6) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((n, f))


# ----------------------------------------------------------------- sorting

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 60), st.integers(0, 2**31 - 1))
def test_sort_methods_return_permutations(n, seed):
    feats = _feats(n, seed)
    for method in METHODS:
        order = sort_features(feats, method)
        assert sorted(np.asarray(order).tolist()) == list(range(n)), method


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 40), st.integers(0, 2**31 - 1))
def test_chain_length_nonnegative_and_zero_for_identical(n, seed):
    feats = _feats(n, seed)
    order = sort_features(feats, "greedy")
    assert chain_length(feats, order) >= 0.0
    same = np.ones((n, 3))
    assert chain_length(same, np.arange(n)) == 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 50), st.integers(0, 2**31 - 1))
def test_greedy_no_worse_than_identity_chain(n, seed):
    """Greedy (Algorithm 1 from index 0) never produces a LONGER similarity
    path than the unsorted identity order on the same cloud."""
    feats = _feats(n, seed)
    greedy = chain_length(feats, sort_features(feats, "greedy"))
    ident = chain_length(feats, np.arange(n))
    assert greedy <= ident + 1e-9


# ---------------------------------------------------------- chain planning

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 80), st.integers(1, 12))
def test_plan_chains_partitions_exactly_once(n, workers):
    order = np.random.default_rng(n * 131 + workers).permutation(n)
    subs = pipeline.plan_chains(order, workers)
    assert len(subs) == workers
    flat = np.concatenate([s for s in subs]) if subs else np.zeros(0)
    np.testing.assert_array_equal(flat, order)       # contiguous cover
    counts = np.bincount(flat.astype(int), minlength=n)
    assert (counts == 1).all()                       # each index exactly once
    lens = [len(s) for s in subs]
    assert max(lens) - min(lens) <= 1                # balanced


# --------------------------------------------------------- lockstep packing

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 60), st.integers(1, 9), st.integers(0, 2**31 - 1))
def test_lockstep_rows_round_trip_chains(n, workers, seed):
    """Packing chains into lockstep rows and unpacking them recovers every
    chain bit-for-bit: padded (-1) slots appear only after a chain is
    exhausted, and no label ever migrates between chains."""
    order = np.random.default_rng(seed).permutation(n)
    subs = pipeline.plan_chains(order, workers)
    length = max(len(s) for s in subs)
    rows = [pipeline._row_index(subs, t) for t in range(length)]

    for w, sub in enumerate(subs):
        col = [int(rows[t][w]) for t in range(length)]
        live = [v for v in col if v >= 0]
        np.testing.assert_array_equal(live, sub)     # no label corruption
        # padding is a strict suffix: once -1, always -1
        seen_pad = False
        for v in col:
            if v < 0:
                seen_pad = True
            else:
                assert not seen_pad, "live index after padding"

    # each row's live entries are disjoint across chains (one system is
    # solved by exactly one chain)
    all_live = [v for row in rows for v in row if v >= 0]
    assert sorted(all_live) == sorted(order.tolist())


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 8))
def test_phase_mask_monotone_shutdown(n, workers):
    """PhaseMask only ever turns chains OFF; padded_rows is always the
    complement of active and ends all-padded once every chain finished."""
    live = np.random.default_rng(n + workers).random(workers) < 0.8
    mask = pipeline.PhaseMask(live)
    np.testing.assert_array_equal(mask.padded_rows, ~mask.active)
    np.testing.assert_array_equal(mask.active, live)
    for w in range(workers):
        before = mask.active.sum()
        mask.finish(w)
        assert mask.active.sum() <= before
        assert not mask.active[w]
        np.testing.assert_array_equal(mask.padded_rows, ~mask.active)
    assert not mask.any_active


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 10), st.integers(0, 2**31 - 1))
def test_phase_mask_finished_excludes_never_live(workers, seed):
    """`finished` counts genuine active→inactive retirements only:
    never-live sharding fill slots must not inflate it (the old
    `(~active).sum()` counted them), and double-finishing is idempotent."""
    live = np.random.default_rng(seed).random(workers) < 0.6
    mask = pipeline.PhaseMask(live)
    assert mask.finished == 0
    for w in range(workers):
        mask.finish(w)
        mask.finish(w)   # idempotent: a slot retires its chain once
    assert mask.finished == int(live.sum())


def test_phase_mask_refill_slot_table():
    """Streaming slot table: refill reopens a retired slot under a new
    chain id, finished counts once per retired chain across refills, and
    refilling a LIVE slot is rejected."""
    import pytest

    mask = pipeline.PhaseMask(np.zeros(3, dtype=bool))
    assert not mask.any_active and (mask.chain == -1).all()
    mask.refill(1, 7)
    assert mask.active[1] and mask.chain[1] == 7
    np.testing.assert_array_equal(mask.padded_rows, [True, False, True])
    with pytest.raises(ValueError):
        mask.refill(1, 8)
    mask.finish(1)
    assert mask.finished == 1 and not mask.any_active
    mask.refill(1, 8)
    mask.refill(0, 9)
    assert mask.chain[1] == 8 and mask.chain[0] == 9
    mask.finish(1)
    mask.finish(0)
    assert mask.finished == 3      # one per retired chain, not per slot
    mask.finish(2)                 # never-live slot: no-op for the count
    assert mask.finished == 3


# ------------------------------------------------- GRF sampling contract
# (pde/grf.py: fold_in key derivation — the label-expansion waves rebuild
#  any single draw from its index, so these properties are load-bearing)

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(1, 6))
def test_grf_batch_prefix_stable(seed, m, extra):
    """The first m draws of a size-(m+extra) batch equal a size-m batch."""
    import jax
    from repro.pde.grf import GRFSpec, sample_grf_batch

    spec = GRFSpec(nx=8, ny=8)
    key = jax.random.PRNGKey(seed)
    small, _ = sample_grf_batch(spec, key, m)
    big, _ = sample_grf_batch(spec, key, m + extra)
    np.testing.assert_array_equal(np.asarray(small), np.asarray(big)[:m])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 7))
def test_grf_batch_draw_equals_single_fold_in(seed, i):
    """Draw i of a batch ≡ sample_grf(spec, fold_in(key, i)) bitwise —
    vmap vs single-call equivalence AND the fold_in indexing contract."""
    import jax
    from repro.pde.grf import GRFSpec, sample_grf, sample_grf_batch

    spec = GRFSpec(nx=8, ny=8)
    key = jax.random.PRNGKey(seed)
    fields, feats = sample_grf_batch(spec, key, 8)
    f1, l1 = sample_grf(spec, jax.random.fold_in(key, i))
    np.testing.assert_array_equal(np.asarray(fields)[i], np.asarray(f1))
    np.testing.assert_array_equal(np.asarray(feats)[i], np.asarray(l1))


def test_grf_batch_keys_subset_indexing():
    """batch_keys accepts an index array: keys for an arbitrary subset of
    draws match the corresponding rows of the full key batch."""
    import jax
    from repro.pde.grf import batch_keys

    key = jax.random.PRNGKey(5)
    full = np.asarray(batch_keys(key, 10))
    sub = np.asarray(batch_keys(key, np.array([7, 2, 2, 9])))
    np.testing.assert_array_equal(sub, full[[7, 2, 2, 9]])


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grf_dtype_axis(seed):
    """The dtype axis: fp32 draws come back fp32 end to end (field AND
    latent), finite, zero-mean, and with the spectrum actually applied
    (non-trivial spatial correlation). fp64 stays the default."""
    import jax
    import jax.numpy as jnp
    from repro.pde.grf import GRFSpec, sample_grf

    spec = GRFSpec(nx=16, ny=16)
    key = jax.random.PRNGKey(seed)
    f64, l64 = sample_grf(spec, key)
    f32, l32 = sample_grf(spec, key, jnp.float32)
    assert f64.dtype == jnp.float64 and l64.dtype == jnp.float64
    assert f32.dtype == jnp.float32 and l32.dtype == jnp.float32
    f = np.asarray(f32, np.float64)
    assert np.isfinite(f).all()
    np.testing.assert_allclose(f.mean(), 0.0, atol=1e-6)
    # smoothness: neighbor differences much smaller than the field scale
    assert np.abs(np.diff(f, axis=0)).max() < np.abs(f).max()
