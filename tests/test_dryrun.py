"""Dry-run machinery tests: roofline HLO parsing units + an 8-device
subprocess mini dry-run (single- and multi-pod debug meshes)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.launch.roofline import (collective_bytes_structural,
                                   extrapolate_linear, model_flops_for,
                                   _shape_bytes)

HLO_SAMPLE = """\
HloModule jit_step, entry_computation_layout={()->()}

%region_0.10 (arg.11: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ag.1 = f32[128,256]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar.1 = f32[64]{0} all-reduce(%p1), to_apply=%add
  ROOT %t = (s32[], f32[128,256]) tuple(%c, %ag.1)
}

%cond.20 (arg.21: (s32[], f32[128,256])) -> pred[] {
  %iter = s32[] get-tuple-element(%arg.21), index=0
  %bound = s32[] constant(22)
  ROOT %cmp = pred[] compare(%iter, %bound), direction=LT
}

ENTRY %main.30 (p: f32[16,16]) -> f32[16,16] {
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.20, body=%region_0.10
  %rs = f32[32,8]{1,0} reduce-scatter(%x), dimensions={0}, to_apply=%add
  %cp-start = (f32[8,8], f32[8,8]) collective-permute-start(%y), source_target_pairs={{0,1}}
  %cp-done = f32[8,8] collective-permute-done(%cp-start)
  ROOT %r = f32[16,16] add(%p, %p)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert _shape_bytes("pred[10]") == 10


def test_collective_parse_with_trip_counts():
    by, counts, meta = collective_bytes_structural(HLO_SAMPLE)
    # while body collectives × trip 22
    assert by["all-gather"] == 128 * 256 * 4 * 22
    assert by["all-reduce"] == 64 * 4 * 22
    assert counts["all-gather"] == 22
    # entry collectives counted once
    assert by["reduce-scatter"] == 32 * 8 * 4
    # permute-start tuple halved (operand+result buffers), -done skipped
    assert by["collective-permute"] == 8 * 8 * 4
    assert meta["whiles"][0]["trip"] == 22


def test_extrapolate_linear():
    # cost(n) = 100 + 7n
    assert extrapolate_linear(1, 107, 2, 114, 10) == pytest.approx(170)
    assert extrapolate_linear(2, 114, 2, 114, 10) == 114  # degenerate


def test_model_flops_formulas():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config

    dense = get_config("tinyllama-1.1b")
    moe = get_config("mixtral-8x7b")
    t = SHAPES["train_4k"]
    d = t.global_batch * t.seq_len
    assert model_flops_for(dense, t) == pytest.approx(
        6.0 * dense.param_count() * d)
    assert model_flops_for(moe, t) == pytest.approx(
        6.0 * moe.active_param_count() * d)
    dec = SHAPES["decode_32k"]
    assert model_flops_for(dense, dec) == pytest.approx(
        2.0 * dense.param_count() * dec.global_batch)


@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["debug", "debug-multi"])
def test_mini_dryrun_subprocess(tmp_path, mesh):
    """Full dry-run path in a subprocess with 8 host devices: lower +
    compile + roofline for one small arch on single- and multi-pod debug
    meshes. This is the CI-sized version of the 512-chip run."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "xlstm-125m", "--shape", "train_4k", "--mesh", mesh,
         "--out-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 1
    rec = json.load(open(tmp_path / files[0]))
    assert rec["status"] == "ok"
    roof = rec["roofline"]
    assert roof["flops_per_chip"] > 0
    assert roof["bytes_per_chip"] > 0
    assert roof["dominant"] in ("compute", "memory", "collective")
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0


def test_cell_plans_build_for_every_arch_on_tiny_mesh():
    """make_cell_plan must produce coherent sharding trees for every arch ×
    shape (structure check only — no lowering here)."""
    import jax

    from repro.configs.base import SHAPES
    from repro.configs.registry import get_smoke_config, list_archs
    from repro.launch.steps import make_cell_plan

    from repro import compat

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        for arch in list_archs():
            cfg = get_smoke_config(arch)
            for shape_name, shape in SHAPES.items():
                if shape_name in cfg.skip_shapes:
                    continue
                import dataclasses

                small = dataclasses.replace(
                    shape, seq_len=32, global_batch=2)
                plan = make_cell_plan(cfg, small, mesh)
                assert plan.state_bytes > 0
                jax.tree_util.tree_structure(plan.in_shardings)
