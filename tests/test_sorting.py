"""Sorting Algorithm 1 + scalable variants: permutation validity, greedy
local optimality, chain-length reduction — incl. hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.sorting import (chain_length, greedy_sort,
                                grouped_greedy_sort, hilbert_index,
                                hilbert_sort, pairwise_sq_dists,
                                sort_features)


def _feats(n=50, f=6, seed=0):
    return np.random.default_rng(seed).standard_normal((n, f))


@pytest.mark.parametrize("method", ["greedy", "grouped", "hilbert",
                                    "random", "none"])
def test_sort_is_permutation(method):
    feats = _feats(64)
    order = sort_features(feats, method)
    assert sorted(order.tolist()) == list(range(64))


def test_greedy_is_locally_nearest():
    """Each next element is the nearest unused one (Algorithm 1 line 5-8)."""
    feats = _feats(40)
    order = greedy_sort(feats)
    d = np.sqrt(pairwise_sq_dists(feats))
    used = {order[0]}
    for a, b in zip(order[:-1], order[1:]):
        cand = [j for j in range(len(feats)) if j not in used]
        nearest = min(cand, key=lambda j: d[a, j])
        assert np.isclose(d[a, b], d[a, nearest])
        used.add(b)


@pytest.mark.parametrize("method", ["greedy", "grouped", "hilbert"])
def test_sort_shortens_chain(method):
    feats = _feats(128, f=4)
    base = chain_length(feats, np.arange(len(feats)))
    sortd = chain_length(feats, sort_features(feats, method))
    assert sortd < base * 0.9, (method, sortd, base)


def test_greedy_beats_random_ordering():
    feats = _feats(100)
    rand = chain_length(feats, sort_features(feats, "random"))
    greedy = chain_length(feats, sort_features(feats, "greedy"))
    assert greedy < rand


@given(st.integers(1, 60), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_property_sort_permutation_and_improvement(n, f, seed):
    feats = np.random.default_rng(seed).standard_normal((n, f))
    order = greedy_sort(feats)
    assert sorted(order.tolist()) == list(range(n))
    if n > 2:
        assert (chain_length(feats, order)
                <= chain_length(feats, np.arange(n)) + 1e-9)


@given(st.integers(2, 8))
@settings(max_examples=8, deadline=None)
def test_hilbert_index_is_bijective_on_grid(bits):
    side = 1 << bits
    x, y = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    d = hilbert_index(x.ravel(), y.ravel(), bits)
    assert sorted(d.tolist()) == list(range(side * side))


def test_hilbert_index_locality():
    """Consecutive Hilbert indices are grid neighbours (curve continuity)."""
    bits = 4
    side = 1 << bits
    x, y = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    d = hilbert_index(x.ravel(), y.ravel(), bits)
    order = np.argsort(d)
    xs, ys = x.ravel()[order], y.ravel()[order]
    step = np.abs(np.diff(xs)) + np.abs(np.diff(ys))
    assert (step == 1).all()


def test_grouped_matches_greedy_for_small_n():
    feats = _feats(30)
    np.testing.assert_array_equal(grouped_greedy_sort(feats, group_size=1000),
                                  greedy_sort(feats))


def test_sorting_scales_to_large_n():
    """hilbert path handles 20k points in a second-ish (App. E.2.2 posture)."""
    feats = _feats(20_000, f=16)
    order = hilbert_sort(feats)
    assert sorted(order.tolist()) == list(range(20_000))


def _grf_feature_cloud(n=96, seed=4):
    """Sorting features of an actual GRF-sampled family (what the datagen
    pipeline hands to `sort_features`), not synthetic gaussians."""
    import jax

    from repro.pde.registry import get_family

    fam = get_family("darcy", nx=12, ny=12)
    batch = fam.sample_batch(jax.random.PRNGKey(seed), n)
    return np.asarray(batch.features)


def test_hilbert_beats_unsorted_on_grf_cloud():
    """The scalable App. E.2.2 variant must still shorten the recycle chain
    on a realistic GRF feature cloud (small greedy buckets force the
    Hilbert-index stage itself to do the work)."""
    feats = _grf_feature_cloud()
    base = chain_length(feats, np.arange(len(feats)))
    sortd = chain_length(feats, hilbert_sort(feats, greedy_bucket=16))
    assert sortd < base, (sortd, base)


def test_grouped_greedy_beats_unsorted_on_grf_cloud():
    """grouped_greedy with groups far smaller than N (the parallel-sort
    regime) must still beat the unsorted order on chain length."""
    feats = _grf_feature_cloud()
    base = chain_length(feats, np.arange(len(feats)))
    sortd = chain_length(feats, grouped_greedy_sort(feats, group_size=24))
    assert sortd < base, (sortd, base)


def test_sort_features_rejects_unknown_method():
    with pytest.raises(KeyError, match="unknown sort method"):
        sort_features(_feats(8), "simulated-annealing")
