"""devlinalg vs hostlinalg parity: the on-device stacked drivers against
their host oracles — stacked QR least squares (uniform + ragged widths,
ill-conditioned and rank-deficient fallback), masked triangular inverses,
and the subspace-iteration harmonic-Ritz extraction (first-cycle and
deflated pencils, gapped spectra where LAPACK's subspace is well defined)."""
import numpy as np
import pytest
import scipy.linalg

import jax
import jax.numpy as jnp

from repro.solvers import devlinalg as dl
from repro.solvers import hostlinalg as hl

jax.config.update("jax_enable_x64", True)


def _hessenberg_stack(bsz, m, j, rng, last_row=1.0):
    """Raw (B, m+1, m) stacks with the Arnoldi structure: live columns
    c < j[i] upper-Hessenberg, everything else exactly zero."""
    h = np.zeros((bsz, m + 1, m))
    for i in range(bsz):
        ji = int(j[i])
        blk = np.triu(rng.standard_normal((ji + 1, ji)), k=-1)
        for c in range(ji):
            blk[c + 1, c] = abs(blk[c + 1, c]) + 0.1
        if ji > 0:
            blk[ji, ji - 1] = last_row
        h[i, : ji + 1, :ji] = blk
    return h


def _angle(p, q):
    """sin of the largest principal angle between the two column spans."""
    qp = np.linalg.qr(p)[0]
    qq = np.linalg.qr(q)[0]
    s = np.clip(np.linalg.svd(qp.T @ qq, compute_uv=False), 0.0, 1.0)
    return float(np.sqrt(1.0 - s.min() ** 2))


# ------------------------------------------------------------- LS drivers

@pytest.mark.parametrize("widths", [(8, 8, 8), (8, 5, 2), (6, 0, 8)])
def test_hessenberg_lstsq_matches_host(widths):
    rng = np.random.default_rng(3)
    j = np.asarray(widths)
    m = 8
    h = _hessenberg_stack(len(j), m, j, rng)
    beta = rng.uniform(0.5, 2.0, len(j))
    want = hl.hessenberg_lstsq_stacked(h, j, beta)
    got = np.asarray(dl.hessenberg_lstsq_stacked(
        jnp.asarray(h), jnp.asarray(j), jnp.asarray(beta)))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)
    # padded coordinates are EXACTLY zero (the no-op update convention)
    for i, ji in enumerate(j):
        np.testing.assert_array_equal(got[i, ji:], 0.0)


def test_hessenberg_lstsq_rank_deficient_falls_back():
    """A numerically rank-deficient live block trips the QR gate; the SVD
    path must return the np.linalg.lstsq min-norm solution."""
    rng = np.random.default_rng(5)
    m, j = 6, np.asarray([6, 6])
    h = _hessenberg_stack(2, m, j, rng)
    h[1, :, 3] = h[1, :, 2] * (1 + 1e-15)      # chain 1: duplicated column
    beta = np.asarray([1.3, 0.7])
    got = np.asarray(dl.hessenberg_lstsq_stacked(
        jnp.asarray(h), jnp.asarray(j), jnp.asarray(beta)))
    for i in range(2):
        e1 = np.zeros(m + 1)
        e1[0] = beta[i]
        want, *_ = np.linalg.lstsq(h[i], e1, rcond=None)
        np.testing.assert_allclose(got[i], want, rtol=1e-8, atol=1e-10)
    # the healthy chain still resolves through the same blended call
    assert np.linalg.norm(got[0]) > 0


def test_hessenberg_lstsq_ill_conditioned_stack():
    """Graded singular values across 12 decades: QR path where safe, SVD
    blend where not — always finite, always oracle-close."""
    rng = np.random.default_rng(11)
    m = 10
    j = np.asarray([10, 10])
    h = _hessenberg_stack(2, m, j, rng)
    h[1] *= np.logspace(0, -12, m)[None, :]    # kill conditioning of chain 1
    beta = np.asarray([1.0, 1.0])
    got = np.asarray(dl.hessenberg_lstsq_stacked(
        jnp.asarray(h), jnp.asarray(j), jnp.asarray(beta)))
    assert np.isfinite(got).all()
    e1 = np.zeros(m + 1)
    e1[0] = 1.0
    for i in range(2):
        want, *_ = np.linalg.lstsq(h[i], e1, rcond=None)
        np.testing.assert_allclose(h[i] @ got[i], h[i] @ want,
                                   rtol=1e-6, atol=1e-9)


def test_tri_inv_stacked_masked_gate():
    rng = np.random.default_rng(7)
    k = 5
    r = np.triu(rng.standard_normal((3, k, k))) + 3 * np.eye(k)
    r[2, 2, 2] = 1e-15                          # chain 2: gate must trip
    want = np.asarray([True, False, True])
    inv, ok = dl.tri_inv_stacked(jnp.asarray(r), jnp.asarray(want))
    ok = np.asarray(ok)
    assert ok.tolist() == [True, False, False]
    np.testing.assert_allclose(np.asarray(inv[0]), np.linalg.inv(r[0]),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(inv[1]), np.eye(k))
    np.testing.assert_array_equal(np.asarray(inv[2]), np.eye(k))


# ----------------------------------------------------- harmonic-Ritz, fresh

def _gapped_hessenberg(m, k, rng, gap=8.0, subdiag=1e-3):
    """(m+1, m) Hessenberg whose first-cycle pencil has a clean |λ| gap at
    index k (small h[m, m-1] keeps the rank-1 correction a perturbation)."""
    lam = np.concatenate([rng.uniform(0.5, 1.0, k),
                          rng.uniform(0.5, 1.0, m - k) * gap])
    v = scipy.linalg.qr(rng.standard_normal((m, m)))[0]
    a = v @ np.diag(lam) @ v.T
    hm = scipy.linalg.hessenberg(a)
    h = np.zeros((m + 1, m))
    h[:m] = hm
    h[m, m - 1] = subdiag
    return h


def _smallest_eig_span(a, k):
    """LAPACK reference: real basis of the k smallest-|λ| invariant
    subspace (well-defined here: the test pencils are gapped and real)."""
    evals, evecs = np.linalg.eig(a)
    order = np.argsort(np.abs(evals))[:k]
    return np.real(evecs[:, order]), np.sort(np.abs(evals))


@pytest.mark.parametrize("widths", [(10, 10), (10, 7)])
def test_harmonic_ritz_first_cycle_matches_lapack_on_gapped(widths):
    """Device subspace iteration vs the LAPACK eig that hostlinalg wraps:
    same invariant subspace AND same smallest-|θ| Ritz values. (The host
    basis itself is pivot-order arbitrary among equal-norm candidates, so
    parity is defined against the eigendecomposition, and the host driver
    must also produce a subspace of the same 2k-smallest candidate span.)"""
    rng = np.random.default_rng(17)
    k, m = 3, 10
    j = np.asarray(widths)
    h = np.zeros((len(j), m + 1, m))
    for i, ji in enumerate(j):
        h[i, : ji + 1, :ji] = _gapped_hessenberg(ji, k, rng)
    p_dev, ok = dl.harmonic_ritz_first_cycle_stacked(
        jnp.asarray(h), jnp.asarray(j), k)
    p_dev, ok = np.asarray(p_dev), np.asarray(ok)
    assert ok.all()
    p_host = hl.harmonic_ritz_first_cycle_stacked(h, j, k)
    for i, ji in enumerate(j):
        a = hl._first_cycle_pencil(h[i], int(ji))
        span, absev = _smallest_eig_span(a, k)
        assert _angle(p_dev[i, :ji], span) < 1e-7, i
        np.testing.assert_array_equal(p_dev[i, ji:], 0.0)
        # Ritz-value parity on the device space
        pq = p_dev[i, :ji]
        theta = np.sort(np.abs(np.linalg.eigvals(pq.T @ a @ pq)))
        np.testing.assert_allclose(theta, absev[:k], rtol=1e-8)
        # host oracle stays inside the 2k-smallest candidate span
        assert p_host[i] is not None and p_host[i].shape[1] == k
        span2k, _ = _smallest_eig_span(a, 2 * k)
        assert _angle(p_host[i],
                      span2k @ (span2k.T @ p_host[i])) < 1e-7, i


def test_harmonic_ritz_first_cycle_gates_short_and_singular():
    rng = np.random.default_rng(19)
    k, m = 3, 8
    j = np.asarray([8, 2, 8])                  # chain 1: j <= k → no space
    h = _hessenberg_stack(3, m, j, rng)
    h[2, :m, :] = 0.0                          # chain 2: singular H_m
    h[2, m, m - 1] = 1.0
    _, ok = dl.harmonic_ritz_first_cycle_stacked(
        jnp.asarray(h), jnp.asarray(j), k)
    ok = np.asarray(ok)
    assert bool(ok[0]) and not bool(ok[1]) and not bool(ok[2])


# -------------------------------------------------- harmonic-Ritz, deflated

def _deflated_pencil_stack(bsz, k, mi, j, rng, gap=8.0):
    """Random well-conditioned Ĝ stacks plus Ŵᴴ V̂ = Ĝ·W with W orthogonal
    -diagonalized gapped spectrum, so M = (ĜᵀĜ)⁻¹ĜᵀŴᴴV̂ = W has a clean
    smallest-|θ| subspace LAPACK and subspace iteration must agree on."""
    g = np.zeros((bsz, k + mi + 1, k + mi))
    whv = np.zeros((bsz, k + mi + 1, k + mi))
    for i in range(bsz):
        ji = int(j[i])
        s = k + ji
        gi = rng.standard_normal((s + 1, s)) + 2 * np.eye(s + 1, s)
        # |mu| large on the first k directions → theta = 1/mu smallest
        mu = np.concatenate([rng.uniform(0.5, 1.0, k) * gap,
                             rng.uniform(0.5, 1.0, s - k)])
        v = scipy.linalg.qr(rng.standard_normal((s, s)))[0]
        w = v @ np.diag(mu) @ v.T
        g[i, : s + 1, :s] = gi
        whv[i, : s + 1, :s] = gi @ w
        # dead columns get unit pads as assemble_g_stacked does, so ĜᵀĜ
        # stays nonsingular for short chains (whv dead block stays zero)
        for c in range(s, k + mi):
            g[i, c + 1, c] = 1.0
    return g, whv


@pytest.mark.parametrize("widths", [(6, 6), (6, 3)])
def test_harmonic_ritz_deflated_matches_lapack_on_gapped(widths):
    rng = np.random.default_rng(23)
    k, mi = 3, 6
    j = np.asarray(widths)
    g, whv = _deflated_pencil_stack(len(j), k, mi, j, rng)
    p_dev, ok = dl.harmonic_ritz_deflated_stacked(
        jnp.asarray(g), jnp.asarray(whv), jnp.asarray(j), k)
    p_dev, ok = np.asarray(p_dev), np.asarray(ok)
    assert ok.all()
    for i, ji in enumerate(j):
        s = k + int(ji)
        ge = g[i, : s + 1, :s]
        we = whv[i, : s + 1, :s]
        mm = np.linalg.solve(ge.T @ ge, ge.T @ we)   # θ smallest = μ largest
        evals, evecs = np.linalg.eig(mm)
        order = np.argsort(np.abs(evals))[::-1][:k]
        span = np.real(evecs[:, order])
        assert _angle(p_dev[i, :s], span) < 1e-7, i
        np.testing.assert_array_equal(p_dev[i, s:], 0.0)
        # host oracle stays inside the dominant 2k-candidate span (its
        # pivoted-QR pick among near-equal candidates is order-arbitrary)
        p_host = hl.harmonic_ritz_deflated(ge, we, k)
        assert p_host.shape[1] == k
        order2k = np.argsort(np.abs(evals))[::-1][: 2 * k]
        span2k = np.linalg.qr(np.real(evecs[:, order2k]))[0]
        assert _angle(p_host, span2k @ (span2k.T @ p_host)) < 1e-6, i


def test_harmonic_ritz_deflated_gates_singular_pencil():
    k, mi = 3, 6
    j = np.asarray([6])
    g = np.zeros((1, k + mi + 1, k + mi))      # ĜᵀĜ singular → gate, no NaN
    whv = np.zeros_like(g)
    p, ok = dl.harmonic_ritz_deflated_stacked(
        jnp.asarray(g), jnp.asarray(whv), jnp.asarray(j), k)
    assert not bool(np.asarray(ok)[0])
    assert np.isfinite(np.asarray(p)).all()


# --------------------------------------------------- assemblers vs gcrodr

def test_assemblers_match_host_blocks():
    """assemble_g/whv reproduce the exact host-side block layout of the
    sequential solver's deflated pencil at every live width."""
    rng = np.random.default_rng(29)
    k, mi = 2, 5
    j = np.asarray([5, 3])
    bsz = len(j)
    dnorm = rng.uniform(0.5, 2.0, (bsz, k))
    bb = rng.standard_normal((bsz, k, mi))
    h = _hessenberg_stack(bsz, mi, j, rng)
    cu = rng.standard_normal((bsz, k, k))
    cv = rng.standard_normal((bsz, k, mi))
    vu = rng.standard_normal((bsz, mi + 1, k))
    vv = rng.standard_normal((bsz, mi + 1, mi))
    g = np.asarray(dl.assemble_g_stacked(jnp.asarray(dnorm), jnp.asarray(bb),
                                         jnp.asarray(h), jnp.asarray(j)))
    whv = np.asarray(dl.assemble_whv_stacked(
        jnp.asarray(cu), jnp.asarray(cv), jnp.asarray(vu), jnp.asarray(vv),
        jnp.asarray(j)))
    for i, ji in enumerate(j):
        ji = int(ji)
        g_host = np.zeros((k + ji + 1, k + ji))
        g_host[:k, :k] = np.diag(1.0 / dnorm[i])
        g_host[:k, k:] = bb[i][:, :ji]
        g_host[k:, k:] = h[i][: ji + 1, :ji]
        np.testing.assert_allclose(g[i, : k + ji + 1, : k + ji], g_host,
                                   rtol=1e-15, atol=0)
        whv_host = np.zeros((k + ji + 1, k + ji))
        whv_host[:k, :k] = cu[i]
        whv_host[:k, k:] = cv[i][:, :ji]
        whv_host[k:, :k] = vu[i][: ji + 1]
        whv_host[k:, k:] = vv[i][: ji + 1, :ji]
        np.testing.assert_allclose(whv[i, : k + ji + 1, : k + ji], whv_host,
                                   rtol=1e-15, atol=0)
        # dead columns of g are unit vectors rooted below the live block
        for c in range(ji, mi):
            col = g[i, :, k + c]
            assert col[k + c + 1] == 1.0 and np.abs(col).sum() == 1.0
        np.testing.assert_array_equal(whv[i, :, k + ji:], 0.0)
        np.testing.assert_array_equal(whv[i, k + ji + 1:, :], 0.0)
