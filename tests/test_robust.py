"""Chaos suite for the failure-containment layer (core/robust.py).

Every test drives the REAL pipeline through a seeded `FaultPlan` — NaN
poisoning of chosen systems' RHS / operator / recycle carry, simulated
preemption, byte-level checkpoint corruption — and asserts the containment
contract: bounded deterministic escalation, identical ladder walks across
engines, quarantined chains requeued onto fresh chains, corrupted
checkpoints falling back a generation, and (with no faults) bitwise
identity to the containment-free configuration."""
import dataclasses
import os
import warnings

import jax
import numpy as np
import pytest

from repro.core.ckpt import NpzCheckpointer
from repro.core.robust import (FaultPlan, RetryPolicy, corrupt_file,
                               health_of, solve_one_guarded)
from repro.core.skr import (SKRConfig, SKRGenerator, generate_dataset,
                            generate_dataset_chunked)
from repro.core.trajectory import (TrajConfig, TrajectoryGenerator,
                                   generate_trajectories_chunked)
from repro.pde.registry import get_family
from repro.pde.timedep import HeatTimeFamily
from repro.solvers.types import KrylovConfig, SolveStats

pytestmark = pytest.mark.chaos

KC = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=6000)
CFG = SKRConfig(krylov=KC, precond="jacobi")


# ---------------------------------------------------------------------------
# health state machine / policy units
# ---------------------------------------------------------------------------

def test_health_state_machine():
    ok = SolveStats(iterations=5, rel_residual=1e-10, converged=True)
    assert health_of(ok) == "healthy"
    re = dataclasses.replace(ok, retries=1, escalation_path=("drop_carry",))
    assert health_of(re) == "retrying"
    qr = SolveStats(iterations=99, rel_residual=1e-2, converged=False,
                    quarantined=True)
    assert health_of(qr) == "quarantined"
    fl = dataclasses.replace(qr, rel_residual=float("nan"))
    assert health_of(fl) == "failed"


def test_retry_policy_validates():
    with pytest.raises(AssertionError):
        RetryPolicy(ladder=("no_such_rung",))
    with pytest.raises(AssertionError):
        RetryPolicy(divergence_ratio=0.5)


def test_guarded_solve_quarantines_after_exhaustion():
    """A problem no rung can fix walks the whole applicable ladder, then
    quarantines with a finite (zero) iterate and the full path recorded."""
    fam = get_family("poisson", nx=10, ny=10)
    work_cfg = dataclasses.replace(
        CFG, retry=RetryPolicy(max_retries=3))
    from repro.core.skr import SteadyWork

    work = SteadyWork(fam, work_cfg)
    work.sample(jax.random.PRNGKey(0), 2)
    solver = work.make_solver()

    calls = []

    def impossible():
        op, b = work._assemble(0)
        calls.append(1)
        bad = np.array(b, copy=True)
        bad[0] = np.nan           # poison EVERY attempt, not one-shot
        return op, bad

    x, st = solve_one_guarded(solver, impossible, work_cfg.retry)
    assert st.quarantined
    assert health_of(st) in ("quarantined", "failed")
    # fp64_inner does not apply on an fp64 config: drop_carry + grow_m only
    assert st.escalation_path == ("drop_carry", "grow_m")
    assert np.isfinite(x).all()   # zero-filled fallback, shapes hold
    assert solver.u_carry is None  # a failed chain's carry never escapes


# ---------------------------------------------------------------------------
# cross-engine escalation determinism
# ---------------------------------------------------------------------------

def _paths(results):
    out = []
    for r in (results if isinstance(results, list) else [results]):
        for s in r.stats.solved:
            if s.retries or s.quarantined:
                out.append((s.escalation_path, s.quarantined))
    return sorted(out)


def test_escalation_paths_identical_across_engines():
    """The same seeded FaultPlan must produce the same ladder walks on the
    sequential, batched and sharded engines (sharded degenerates to batched
    on one device — the dispatch path is still exercised)."""
    fam = get_family("poisson", nx=14, ny=14)
    key = jax.random.PRNGKey(3)
    num = 10

    def plan():
        return FaultPlan(nan_rhs=(2, 7), nan_operator=(4,), seed=5)

    seq = generate_dataset_chunked(fam, key, num, CFG, workers=4,
                                   engine="sequential", fault=plan())
    bat = generate_dataset_chunked(fam, key, num, CFG, workers=4,
                                   engine="batched", fault=plan())
    shd = generate_dataset_chunked(fam, key, num, CFG, workers=4,
                                   engine="sharded", fault=plan())
    assert _paths(seq) == _paths(bat) == _paths(shd)
    assert len(_paths(seq)) == 3          # every fault produced one recovery
    for res in (seq, bat, shd):
        for r in res:
            assert r.label_ok.all()       # ...and every label recovered
            h = r.stats.summary()["health"]
            assert h["quarantined"] == 0


def test_nan_carry_recovers_without_retry():
    """Both engines' warm-start rank gates silently drop a non-finite carry
    and restart cold — the poisoned-carry fault must heal with ZERO retries
    (the regression the gates exist for)."""
    fam = get_family("poisson", nx=14, ny=14)
    key = jax.random.PRNGKey(4)
    for engine in ("sequential", "batched"):
        res = generate_dataset_chunked(fam, key, 8, CFG, workers=2,
                                       engine=engine,
                                       fault=FaultPlan(nan_carry=(3, 5)))
        for r in res:
            assert r.label_ok.all(), engine
            assert r.stats.summary()["health"]["retries"] == 0, engine


def test_lockstep_quarantine_requeues_onto_fresh_chain():
    """A mid-solve NaN in one lockstep chain is masked in-dispatch and the
    system re-solved sequentially; the emitted labels match a fault-free
    run to solver tolerance and the recovery shows in summary()."""
    fam = get_family("poisson", nx=14, ny=14)
    key = jax.random.PRNGKey(5)
    clean = generate_dataset_chunked(fam, key, 8, CFG, workers=4,
                                     engine="batched")
    fallen = generate_dataset_chunked(fam, key, 8, CFG, workers=4,
                                      engine="batched",
                                      fault=FaultPlan(nan_rhs=(1,)))
    total = {"recovered": 0}
    for a, b in zip(clean, fallen):
        np.testing.assert_allclose(a.solutions, b.solutions,
                                   rtol=1e-5, atol=1e-8)
        assert b.label_ok.all()
        total["recovered"] += b.stats.summary()["health"]["recovered"]
    assert total["recovered"] == 1


# ---------------------------------------------------------------------------
# strict label modes
# ---------------------------------------------------------------------------

def test_strict_labels_exclude_drops_untrustworthy_rows():
    """With retries disabled entirely (max_retries=0) a poisoned system
    stays quarantined; "exclude" removes it from the emitted dataset while
    "flag" ships it with label_ok False."""
    fam = get_family("poisson", nx=12, ny=12)
    key = jax.random.PRNGKey(6)
    base = dataclasses.replace(CFG, retry=RetryPolicy(max_retries=0))

    flagged = SKRGenerator(fam, base).generate(
        key, 6, fault=FaultPlan(nan_rhs=(2,)))
    assert flagged.solutions.shape[0] == 6
    assert not flagged.label_ok[2] and flagged.label_ok.sum() == 5
    assert flagged.stats.summary()["health"]["quarantined"] == 1

    strict = dataclasses.replace(base, strict_labels="exclude")
    excluded = SKRGenerator(fam, strict).generate(
        key, 6, fault=FaultPlan(nan_rhs=(2,)))
    assert excluded.solutions.shape[0] == 5
    assert excluded.label_ok.all()
    assert 2 not in excluded.order.tolist()


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["truncate", "flip", "zero"])
def test_checkpoint_corruption_falls_back_a_generation(tmp_path, mode):
    ck = NpzCheckpointer(str(tmp_path), "state.npz")
    ck.save(pos=np.array(2), data=np.arange(4) * 2.0)
    ck.save(pos=np.array(3), data=np.arange(4) * 3.0)
    corrupt_file(ck.gen_path(0), mode=mode)
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        state = ck.load(required=("pos", "data"))
    assert state is not None and int(state["pos"]) == 2
    assert any("generation 1" in str(w.message) for w in wlog)


def test_checkpoint_all_generations_dead_degrades_to_none(tmp_path):
    ck = NpzCheckpointer(str(tmp_path), "state.npz")
    ck.save(pos=np.array(1))
    ck.save(pos=np.array(2))
    for g in (0, 1):
        corrupt_file(ck.gen_path(g), mode="zero")
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        assert ck.load(required=("pos",)) is None


def test_checkpoint_stale_schema_skipped(tmp_path):
    ck = NpzCheckpointer(str(tmp_path), "state.npz")
    ck.save(pos=np.array(1))
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        assert ck.load(required=("pos", "new_field")) is None
    assert any("stale schema" in str(w.message) for w in wlog)


def test_concurrent_writers_do_not_collide(tmp_path):
    """Two checkpointers sharing dir+filename stage through UNIQUE mkstemp
    siblings (the old fixed ".tmp.npz" raced); interleaved saves leave a
    valid newest snapshot plus a valid previous generation."""
    a = NpzCheckpointer(str(tmp_path), "shared.npz")
    b = NpzCheckpointer(str(tmp_path), "shared.npz")
    a.save(pos=np.array(1))
    b.save(pos=np.array(2))
    a.save(pos=np.array(3))
    assert int(a.load(required=("pos",))["pos"]) == 3
    corrupt_file(a.gen_path(0), mode="truncate")
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        assert int(b.load(required=("pos",))["pos"]) == 2
    # no stray tmp staging files left behind
    leftovers = [f for f in os.listdir(tmp_path) if ".tmp" in f]
    assert leftovers == []


def test_resume_after_corrupted_checkpoint_end_to_end(tmp_path):
    """The acceptance scenario: preemption mid-write corrupts the newest
    snapshot; the rerun falls back one generation, resumes warm and emits
    the identical dataset."""
    fam = get_family("poisson", nx=14, ny=14)
    cfg = dataclasses.replace(CFG, ckpt_every=2)
    key = jax.random.PRNGKey(7)
    ref = generate_dataset(fam, key, 8, cfg)

    gen = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="injected datagen fault"):
        gen.generate(key, 8,
                     fault=FaultPlan(preempt_at=5, ckpt_corrupt="truncate"))
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        res = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path)).generate(key, 8)
    msgs = [str(w.message) for w in wlog]
    assert any("generation 1" in m for m in msgs)
    np.testing.assert_allclose(res.solutions, ref.solutions,
                               rtol=1e-6, atol=1e-9)
    assert res.label_ok.all()


def test_resume_size_mismatch_rotates_stale_checkpoint_aside(tmp_path):
    """Regression: a checkpoint from a DIFFERENTLY-SIZED run used to be
    silently discarded and then OVERWRITTEN by the new run's first save.
    Default policy now warns loudly, moves every stale generation aside to
    `.staleN.npz` (outside the rotation ladder), and starts fresh."""
    fam = get_family("poisson", nx=12, ny=12)
    cfg = dataclasses.replace(CFG, ckpt_every=2)
    key = jax.random.PRNGKey(3)
    gen = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="injected datagen fault"):
        gen.generate(key, 8, fail_at=5)      # leaves an 8-system checkpoint
    assert os.path.exists(gen._ckpt.gen_path(0))

    ref = generate_dataset(fam, key, 6, cfg)  # no checkpointing
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        res = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path)).generate(key, 6)
    msgs = [str(w.message) for w in wlog]
    assert any("8-system run" in m and "asked for 6" in m
               and "stale snapshot preserved" in m for m in msgs)
    stale = [f for f in os.listdir(tmp_path) if ".stale" in f]
    assert stale                              # nothing was overwritten
    np.testing.assert_allclose(res.solutions, ref.solutions,
                               rtol=1e-6, atol=1e-9)


def test_resume_size_mismatch_error_and_discard_modes(tmp_path):
    fam = get_family("poisson", nx=12, ny=12)
    cfg = dataclasses.replace(CFG, ckpt_every=2)
    key = jax.random.PRNGKey(3)
    gen = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="injected datagen fault"):
        gen.generate(key, 8, fail_at=5)

    with pytest.raises(RuntimeError, match="8-system run"):
        SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path)).generate(
            key, 6, mismatch="error")
    # "error" must leave the stale checkpoint untouched AND loadable
    assert gen._ckpt.load(required=("pos", "order")) is not None

    # "discard" is the old behavior, now an explicit acknowledgment
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        res = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path)).generate(
            key, 6, mismatch="discard")
    assert any("discarding it" in str(w.message) for w in wlog)
    assert res.solutions.shape[0] == 6
    # the discard run's own saves replaced the stale snapshot in-ladder
    assert not [f for f in os.listdir(tmp_path) if ".stale" in f]


# ---------------------------------------------------------------------------
# trajectory datagen under faults
# ---------------------------------------------------------------------------

def test_trajectory_containment_across_engines():
    """Mid-march NaN in one trajectory: the sequential engine retries the
    step in place, the lockstep engine freezes the chain and re-marches the
    trajectory — both end with every label trustworthy and matching the
    fault-free labels at solver tolerance."""
    fam = HeatTimeFamily(nx=12, ny=12, nt=4, dt=0.01)
    cfg = TrajConfig(krylov=KrylovConfig(m=20, k=6, tol=1e-8, maxiter=4000),
                     precond="jacobi")
    key = jax.random.PRNGKey(8)

    ref = TrajectoryGenerator(fam, cfg).generate(key, 6)
    seq = TrajectoryGenerator(fam, cfg).generate(
        key, 6, fault=FaultPlan(nan_rhs=(2,), step=1))
    assert seq.label_ok.all()
    np.testing.assert_allclose(seq.trajectories, ref.trajectories,
                               rtol=1e-4, atol=1e-7)

    clean = generate_trajectories_chunked(fam, key, 6, cfg, workers=3)
    fallen = generate_trajectories_chunked(
        fam, key, 6, cfg, workers=3, engine="batched",
        fault=FaultPlan(nan_rhs=(2,), step=1))
    recovered = 0
    for a, b in zip(clean, fallen):
        assert b.label_ok.all()
        assert np.isfinite(b.trajectories).all()
        np.testing.assert_allclose(a.trajectories, b.trajectories,
                                   rtol=1e-4, atol=1e-7)
        recovered += b.stats.summary()["health"]["recovered"]
    assert recovered == 1


# ---------------------------------------------------------------------------
# no-fault bitwise identity (containment default-ON must be free)
# ---------------------------------------------------------------------------

def test_no_fault_outputs_bitwise_identical_to_containment_off():
    fam = get_family("poisson", nx=14, ny=14)
    key = jax.random.PRNGKey(9)
    off = dataclasses.replace(CFG, retry=None)
    a = SKRGenerator(fam, CFG).generate(key, 8)
    b = SKRGenerator(fam, off).generate(key, 8)
    assert np.array_equal(a.solutions, b.solutions)
    for x, y in zip(generate_dataset_chunked(fam, key, 8, CFG, workers=4),
                    generate_dataset_chunked(fam, key, 8, off, workers=4)):
        assert np.array_equal(x.solutions, y.solutions)
