"""Unified datagen pipeline: chain planning, engine dispatch, sharded
lockstep equivalence (chunk-chain axis over the `data` mesh), padding-stat
honesty, prefetch transparency, and the 8-virtual-device acceptance check
(subprocess, so it holds regardless of the parent's device count)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import pipeline
from repro.core.skr import SKRConfig, SteadyWork, generate_dataset_chunked
from repro.core.trajectory import (TrajConfig, TrajectoryWork,
                                   generate_trajectories_chunked)
from repro.distributed.sharding import ChainSharding, datagen_mesh
from repro.pde.registry import get_family, get_timedep_family
from repro.solvers.types import KrylovConfig, SequenceStats, SolveStats

KC = KrylovConfig(m=30, k=10, tol=1e-9, maxiter=6000)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- planning

def test_plan_chains_contiguous_cover():
    order = np.random.default_rng(0).permutation(10)
    subs = pipeline.plan_chains(order, 3)
    assert len(subs) == 3
    assert max(len(s) for s in subs) - min(len(s) for s in subs) <= 1
    np.testing.assert_array_equal(np.concatenate(subs), order)


def test_row_index_marks_padding():
    subs = [np.array([4, 2]), np.array([7])]
    np.testing.assert_array_equal(pipeline._row_index(subs, 0), [4, 7])
    np.testing.assert_array_equal(pipeline._row_index(subs, 1), [2, -1])


@pytest.mark.parametrize("maker", ["steady", "traj"])
def test_unknown_engine_rejected(maker):
    if maker == "steady":
        fam = get_family("poisson", nx=8, ny=8)
        with pytest.raises(ValueError, match="unknown engine"):
            generate_dataset_chunked(fam, jax.random.PRNGKey(0), 4,
                                     SKRConfig(krylov=KC), workers=2,
                                     engine="bogus")
    else:
        fam = get_timedep_family("heat", nx=8, ny=8, nt=2)
        with pytest.raises(ValueError, match="unknown engine"):
            generate_trajectories_chunked(fam, jax.random.PRNGKey(0), 4,
                                          TrajConfig(krylov=KC), workers=2,
                                          engine="bogus")


def test_unbatchable_configs_route_sequential():
    fam = get_family("poisson", nx=8, ny=8)
    cfg = SKRConfig(krylov=dataclasses.replace(KC, ritz_refresh="final"),
                    precond="jacobi")
    work = SteadyWork(fam, cfg)
    assert pipeline.resolve_engine(work, "sharded") == "sequential"
    assert pipeline.resolve_engine(work, "batched") == "sequential"
    assert pipeline.resolve_engine(
        SteadyWork(fam, SKRConfig(krylov=KC)), "sharded") == "sharded"


# ---------------------------------------------------------------- sharding

def test_chain_sharding_specs():
    mesh = datagen_mesh()
    if mesh is None:  # single device: build the degenerate mesh explicitly
        from jax.sharding import Mesh

        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    cs = ChainSharding(mesh)
    nsh = cs.num_shards
    x = cs.put(np.zeros((2 * nsh, 5)))
    assert x.shape == (2 * nsh, 5)
    # non-divisible leading dim and scalars fall back to replicated
    y = cs.put(np.zeros((nsh + 1, 3)))
    assert y.sharding.is_fully_replicated
    s = cs.put(np.float64(1.0))
    assert s.sharding.is_fully_replicated


def _rel(a, b):
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-300)


def test_sharded_steady_matches_sequential():
    """engine="sharded" == engine="sequential" to solver tolerance on
    however many devices this process has (8 under the CI multi-device
    job / XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    fam = get_family("poisson", nx=12, ny=12)
    cfg = SKRConfig(krylov=KC, precond="jacobi")
    key = jax.random.PRNGKey(5)
    seq = generate_dataset_chunked(fam, key, 10, cfg, workers=4,
                                   engine="sequential")
    sh = generate_dataset_chunked(fam, key, 10, cfg, workers=4,
                                  engine="sharded")
    assert len(sh) == 4  # sharding fill chains are dropped
    for cs_, cb in zip(seq, sh):
        np.testing.assert_array_equal(cs_.order, cb.order)
        for pos in range(len(cs_.order)):
            assert _rel(cb.solutions[pos], cs_.solutions[pos]) <= 1e-8
        assert cb.stats.num == len(cb.order)  # padding excluded
        assert cb.stats.num_converged == len(cb.order)


def test_sharded_trajectory_matches_sequential():
    fam = get_timedep_family("heat", nx=10, ny=10, nt=4, dt=5e-2)
    cfg = TrajConfig(krylov=KC, precond="jacobi")
    key = jax.random.PRNGKey(3)
    seq = generate_trajectories_chunked(fam, key, 6, cfg, workers=3,
                                        engine="sequential")
    sh = generate_trajectories_chunked(fam, key, 6, cfg, workers=3,
                                       engine="sharded")
    assert len(sh) == 3
    for cs_, cb in zip(seq, sh):
        np.testing.assert_array_equal(cs_.order, cb.order)
        for pos in range(len(cs_.order)):
            assert _rel(cb.trajectories[pos], cs_.trajectories[pos]) <= 1e-7
        assert cb.stats.num == len(cb.order) * fam.nt
        assert cb.stats.num_converged == cb.stats.num


def test_prefetch_is_transparent():
    """The prefetch thread only OVERLAPS host assembly — engine results are
    bitwise-identical with prefetch disabled."""
    fam = get_family("darcy", nx=10, ny=10)
    cfg = SKRConfig(krylov=KC, precond="jacobi")
    key = jax.random.PRNGKey(11)
    on = pipeline.run_chunked(SteadyWork(fam, cfg), key, 7, 3, "batched",
                              prefetch=True)
    off = pipeline.run_chunked(SteadyWork(fam, cfg), key, 7, 3, "batched",
                               prefetch=False)
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a.solutions, b.solutions)
        np.testing.assert_array_equal(a.order, b.order)


def test_lockstep_error_propagates_past_inflight_prefetch():
    """Regression: an `execute_row` failure used to hang in the prefetch
    executor's `__exit__`, which waits for the in-flight `prepare_row` of
    the NEXT row — under a fault plan that could mask the real failure
    behind an arbitrarily long (or deadlocked) assembly. The error must
    surface immediately, while that prepare is still running."""
    import threading
    import time

    release = threading.Event()    # holds the row-1 prepare hostage
    running = threading.Event()    # row-1 prepare has actually started

    class HangingWork:
        def prepare_row(self, t, idx):
            if t == 1:
                running.set()
                release.wait(timeout=30.0)
            return t

        def execute_row(self, solver, t, idx, prepared):
            assert running.wait(timeout=10.0)   # prefetch is mid-assembly
            raise RuntimeError("device fault on row 0")

        def expand_row(self, solver, t, idx):
            pass

    t0 = time.monotonic()
    try:
        with pytest.raises(RuntimeError, match="device fault on row 0"):
            pipeline._run_lockstep(HangingWork(), [np.arange(3)],
                                   solver=None, prefetch=True)
        elapsed = time.monotonic() - t0
    finally:
        release.set()              # drain the hostage thread
    assert elapsed < 10.0          # the old code waited out the prepare


# ----------------------------------------------------------- padding stats

def test_padded_rows_excluded_from_sequence_stats():
    st = SequenceStats()
    st.append(SolveStats(iterations=10, converged=True, wall_time_s=1.0))
    st.append(SolveStats(iterations=0, converged=True, wall_time_s=0.0,
                         padded=True))
    assert st.num == 1 and st.num_padded == 1
    assert st.total_iterations == 10
    assert st.mean_time_s == 1.0
    assert st.summary()["padded"] == 1


def test_solver_marks_zero_rhs_rows_padded():
    from repro.pde.dia import Stencil5
    from repro.solvers.batched import BatchedGCRODRSolver
    from repro.solvers.operator import PreconditionedOp, StencilOp
    from repro.solvers.precond import make_preconditioner_batched
    import jax.numpy as jnp

    fam = get_family("poisson", nx=10, ny=10)
    batch = fam.sample_batch(jax.random.PRNGKey(1), 2)
    st5 = Stencil5(jnp.asarray(batch.op.coeffs))
    pre = make_preconditioner_batched("jacobi", st5)
    ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
    b = np.array(batch.b).reshape(2, -1)
    b[1] = 0.0
    _, sts = BatchedGCRODRSolver(KC).solve_batch(ops, jnp.asarray(b))
    assert not sts[0].padded and sts[0].wall_time_s > 0.0
    assert sts[1].padded and sts[1].wall_time_s == 0.0
    assert sts[1].converged and sts[1].iterations == 0
    # an explicit mask overrides the zero-RHS inference: a LEGITIMATE b = 0
    # system (e.g. a vanished increment RHS) is not miscounted as padding
    _, sts = BatchedGCRODRSolver(KC).solve_batch(
        ops, jnp.asarray(b), padded_rows=np.array([False, False]))
    assert not sts[1].padded and sts[1].wall_time_s > 0.0
    assert sts[1].converged and sts[1].iterations == 0  # still a no-op solve


def test_padded_rows_never_accrue_refinement_counts():
    """Interaction of padding with the mixed-precision accounting: a row
    MARKED padded must contribute nothing — no iterations, no
    outer_refinements, no fp64_fallback — even if its RHS is nonzero, so
    the SequenceStats totals cannot double-count padding as real work."""
    import jax.numpy as jnp

    from repro.pde.dia import Stencil5
    from repro.solvers.batched import BatchedGCRODRSolver
    from repro.solvers.operator import PreconditionedOp, StencilOp
    from repro.solvers.precond import make_preconditioner_batched

    fam = get_family("poisson", nx=10, ny=10)
    batch = fam.sample_batch(jax.random.PRNGKey(1), 2)
    st5 = Stencil5(jnp.asarray(batch.op.coeffs))
    pre = make_preconditioner_batched("jacobi", st5)
    ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
    b = np.array(batch.b).reshape(2, -1)   # both rows NONZERO
    mask = np.array([False, True])

    for inner in ("float64", "float32"):
        cfg = dataclasses.replace(KC, inner_dtype=inner)
        solver = BatchedGCRODRSolver(cfg)
        x, sts = solver.solve_batch(ops, jnp.asarray(b), padded_rows=mask)
        assert sts[0].converged and sts[0].iterations > 0, inner
        assert sts[1].padded and sts[1].iterations == 0, inner
        assert sts[1].outer_refinements == 0, inner
        assert not sts[1].fp64_fallback, inner
        assert sts[1].wall_time_s == 0.0 and sts[1].matvecs == 0, inner
        np.testing.assert_array_equal(x[1], 0.0)   # never solved
        assert solver.systems_solved == 1, inner   # padding is not a solve

    # ...and the SequenceStats aggregates exclude padded rows even if a
    # padded record somehow carried counts (defense in depth)
    st = SequenceStats()
    st.append(SolveStats(iterations=10, converged=True, wall_time_s=1.0,
                         outer_refinements=2, fp64_fallback=True))
    st.append(SolveStats(iterations=5, converged=True, rejected=True,
                         wall_time_s=0.5, outer_refinements=1))
    st.append(SolveStats(padded=True, outer_refinements=7,
                         fp64_fallback=True, converged=True))
    assert st.total_outer_refinements == 3
    assert st.num_fp64_fallback == 1
    assert st.num_rejected == 1
    s = st.summary()
    assert s["outer_refinements"] == 3 and s["fp64_fallback"] == 1
    assert s["rejected"] == 1 and s["padded"] == 1


def test_padded_rows_keep_recycle_carry_untouched():
    """A marked-padded row must leave its chain's carry exactly as it was
    (the phase-masked engine relies on this across many masked rows)."""
    import jax.numpy as jnp

    from repro.pde.dia import Stencil5
    from repro.solvers.batched import BatchedGCRODRSolver
    from repro.solvers.operator import PreconditionedOp, StencilOp
    from repro.solvers.precond import make_preconditioner_batched

    fam = get_family("poisson", nx=10, ny=10)
    batch = fam.sample_batch(jax.random.PRNGKey(2), 2)
    st5 = Stencil5(jnp.asarray(batch.op.coeffs))
    pre = make_preconditioner_batched("jacobi", st5)
    ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
    b = np.array(batch.b).reshape(2, -1)
    solver = BatchedGCRODRSolver(KC)
    solver.solve_batch(ops, jnp.asarray(b))        # both chains own a carry
    carry0 = solver.u_carry.copy()
    solver.solve_batch(ops, jnp.asarray(b),
                       padded_rows=np.array([False, True]))
    assert not np.array_equal(solver.u_carry[0], carry0[0])  # chain 0 moved
    np.testing.assert_array_equal(solver.u_carry[1], carry0[1])


# --------------------------------------------- 8-virtual-device acceptance

_SUBPROC = textwrap.dedent("""
    import jax, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core.skr import SKRConfig, generate_dataset_chunked
    from repro.core.trajectory import TrajConfig, generate_trajectories_chunked
    from repro.pde.registry import get_family, get_timedep_family
    from repro.solvers.types import KrylovConfig
    kc = KrylovConfig(m=30, k=10, tol=1e-9, maxiter=6000)

    def rel(a, b):
        return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-300)

    fam = get_family("poisson", nx=10, ny=10)
    key = jax.random.PRNGKey(5)
    cfg = SKRConfig(krylov=kc, precond="jacobi")
    seq = generate_dataset_chunked(fam, key, 8, cfg, workers=4,
                                   engine="sequential")
    sh = generate_dataset_chunked(fam, key, 8, cfg, workers=4,
                                  engine="sharded")
    for cs, cb in zip(seq, sh):
        assert (cs.order == cb.order).all()
        for p in range(len(cs.order)):
            assert rel(cb.solutions[p], cs.solutions[p]) <= 1e-8

    tfam = get_timedep_family("heat", nx=8, ny=8, nt=3, dt=5e-2)
    tcfg = TrajConfig(krylov=kc, precond="jacobi")
    tseq = generate_trajectories_chunked(tfam, key, 4, tcfg, workers=4,
                                         engine="sequential")
    tsh = generate_trajectories_chunked(tfam, key, 4, tcfg, workers=4,
                                        engine="sharded")
    for cs, cb in zip(tseq, tsh):
        for p in range(len(cs.order)):
            assert rel(cb.trajectories[p], cs.trajectories[p]) <= 1e-7

    # phase-masked adaptive lockstep, chain axis sharded over 8 devices:
    # chains step at per-chain Δt, finished chains ride as padded rows
    from repro.pde.timedep import AdaptConfig
    afam = get_timedep_family("heat", nx=8, ny=8, nt=2, dt=2e-2,
                              adapt=AdaptConfig(step_tol=2e-3))
    aseq = generate_trajectories_chunked(afam, key, 5, tcfg, workers=4,
                                         engine="sequential")
    ash = generate_trajectories_chunked(afam, key, 5, tcfg, workers=4,
                                        engine="sharded")
    for cs, cb in zip(aseq, ash):
        assert cs.stats.num == cb.stats.num     # identical step sequences
        for p in range(len(cs.order)):
            assert rel(cb.trajectories[p], cs.trajectories[p]) <= 1e-6

    # wave family: mass matrix != I through the sharded lockstep
    wfam = get_timedep_family("wave", nx=8, ny=8, nt=2, dt=2e-3)
    wseq = generate_trajectories_chunked(wfam, key, 4, tcfg, workers=4,
                                         engine="sequential")
    wsh = generate_trajectories_chunked(wfam, key, 4, tcfg, workers=4,
                                        engine="sharded")
    for cs, cb in zip(wseq, wsh):
        for p in range(len(cs.order)):
            assert rel(cb.trajectories[p], cs.trajectories[p]) <= 1e-6
    print("OK")
""")


@pytest.mark.slow
def test_sharded_equivalence_on_8_virtual_devices():
    """Acceptance: the sharded engine on 8 virtual CPU devices matches the
    sequential generator to solver tolerance (poisson + heat). Runs in a
    subprocess because the device count is fixed at JAX init. Marked slow:
    CI's tier-1 matrix skips it; the dedicated `multidevice` job (which
    runs this file WITHOUT `-m "not slow"`) and full local runs cover it."""
    env = dict(os.environ)
    # count=8 goes LAST: XLA gives the last duplicate flag precedence, so an
    # inherited --xla_force_host_platform_device_count must not override it
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout
