"""Label expansion (core/expand.py): few solves, many labels.

The contract under test, per the DiffOAS construction:
  * every emitted (f', u') pair satisfies f' = A u' to machine eps against
    the dense operator oracle (steady A, and the θ-scheme A(t) at the
    snapshot's own step for trajectories);
  * expansion OFF (the default) leaves both generators bitwise-identical
    to pre-expansion builds, and expansion ON never perturbs the anchors;
  * counts and provenance: (k+1) labels per healthy anchor, slot 0
    "solved", the rest "expanded", anchor_idx always an original index;
  * engines agree (sequential vs lockstep) to solver tolerance — the
    perturbations themselves are keyed by fold_in(anchor, step, slot), so
    all the divergence comes from the anchors;
  * health interplay: quarantined anchors never ship labels, the requeue
    ladder re-expands recovered anchors, tainted trajectories retract;
  * checkpoint/resume round-trips the labels + provenance.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.expand import ExpandConfig, Expander, LabelSet
from repro.core.robust import FaultPlan, RetryPolicy
from repro.core.skr import (SKRConfig, SKRGenerator, generate_dataset,
                            generate_dataset_chunked)
from repro.core.trajectory import (TrajConfig, generate_trajectories,
                                   generate_trajectories_chunked)
from repro.pde.dia import Stencil5
from repro.pde.registry import get_family, get_timedep_family
from repro.solvers.types import KrylovConfig

KC = KrylovConfig(m=20, k=5, tol=1e-10)


def _dense(coeffs):
    return Stencil5(jnp.asarray(coeffs)).to_dia().to_dense()


def _check_exact(labels: LabelSet, coeffs_of):
    """max |A u' − f'| over every label, with A looked up per anchor."""
    worst = 0.0
    for j in range(len(labels)):
        a = _dense(coeffs_of(j))
        r = a @ labels.u[j].reshape(-1) - labels.f[j].reshape(-1)
        worst = max(worst, float(np.max(np.abs(r))))
    return worst


# ------------------------------------------------------------- steady

def test_steady_labels_exact_and_counted():
    fam = get_family("poisson", nx=12, ny=12)
    key = jax.random.PRNGKey(0)
    ec = ExpandConfig(k=3, amplitude=0.1)
    r = generate_dataset(fam, key, 6, SKRConfig(krylov=KC, expand=ec))
    L = r.labels
    assert len(L) == 6 * (ec.k + 1)
    # provenance: every anchor fans into 1 solved + k expanded
    for i in range(6):
        rows = L.anchor_idx == i
        assert rows.sum() == ec.k + 1
        assert (L.kind[rows] == "solved").sum() == 1
    assert (L.t == 0.0).all()
    batch = fam.sample_batch(key, 6)
    coeffs = np.asarray(batch.op.coeffs)
    err = _check_exact(L, lambda j: coeffs[int(L.anchor_idx[j])])
    assert err < 1e-12, err
    # slot-0 re-labels the anchor itself: u matches the shipped solution
    for j in np.nonzero(L.kind == "solved")[0]:
        np.testing.assert_array_equal(L.u[j],
                                      r.solutions[int(L.anchor_idx[j])])


@pytest.mark.parametrize("engine", ["sequential", "batched"])
def test_steady_expansion_off_is_bitwise_and_on_keeps_anchors(engine):
    fam = get_family("poisson", nx=10, ny=10)
    key = jax.random.PRNGKey(1)
    ec = ExpandConfig(k=2)
    off = generate_dataset_chunked(fam, key, 6, SKRConfig(krylov=KC),
                                   workers=2, engine=engine)
    on = generate_dataset_chunked(fam, key, 6,
                                  SKRConfig(krylov=KC, expand=ec),
                                  workers=2, engine=engine)
    for a, b in zip(off, on):
        assert a.labels is None
        assert b.labels is not None and len(b.labels) > 0
        np.testing.assert_array_equal(a.solutions, b.solutions)
        np.testing.assert_array_equal(a.order, b.order)
        # chunk provenance: labels only reference the chunk's own anchors
        assert set(np.unique(b.labels.anchor_idx)) <= set(b.order.tolist())


def test_steady_engines_agree_to_solver_tolerance():
    fam = get_family("poisson", nx=12, ny=12)
    key = jax.random.PRNGKey(0)
    ec = ExpandConfig(k=3, amplitude=0.1)
    seq = generate_dataset(fam, key, 6, SKRConfig(krylov=KC, expand=ec))
    rs = generate_dataset_chunked(fam, key, 6,
                                  SKRConfig(krylov=KC, expand=ec),
                                  workers=2, engine="batched")
    # keys are (anchor, slot) — slot order inside each fan-out is fixed
    seq_map = {(int(seq.labels.anchor_idx[j]), j % (ec.k + 1)):
               seq.labels.u[j] for j in range(len(seq.labels))}
    assert sum(len(r.labels) for r in rs) == len(seq.labels) == 24
    for r in rs:
        L = r.labels
        for j in range(len(L)):
            want = seq_map[(int(L.anchor_idx[j]), j % (ec.k + 1))]
            scale = np.max(np.abs(want)) + 1e-30
            assert np.max(np.abs(want - L.u[j])) / scale < 1e-7


@pytest.mark.parametrize("mode,combine", [("multiplicative", 0.0),
                                          ("additive", 0.5)])
def test_steady_modes_and_convex_combinations(mode, combine):
    fam = get_family("poisson", nx=10, ny=10)
    key = jax.random.PRNGKey(3)
    ec = ExpandConfig(k=4, mode=mode, combine=combine, amplitude=0.2)
    r = generate_dataset(fam, key, 5, SKRConfig(krylov=KC, expand=ec))
    assert len(r.labels) == 5 * 5
    batch = fam.sample_batch(key, 5)
    coeffs = np.asarray(batch.op.coeffs)
    err = _check_exact(r.labels,
                       lambda j: coeffs[int(r.labels.anchor_idx[j])])
    assert err < 1e-12, err
    if combine > 0:
        # combo slots of a NON-first anchor lie between its anchor and the
        # chain predecessor: check containment in the joint value range
        k_comb = ec.k_comb
        assert k_comb >= 1
        L = r.labels
        second = r.order[1]          # second anchor solved on chain 0
        first = r.order[0]
        rows = np.nonzero(L.anchor_idx == second)[0]
        u_a = r.solutions[second]
        u_p = r.solutions[first]
        lo = np.minimum(u_a, u_p) - 1e-12
        hi = np.maximum(u_a, u_p) + 1e-12
        comb = L.u[rows[1: 1 + k_comb]]
        assert ((comb >= lo) & (comb <= hi)).all()


def test_expand_config_validation():
    with pytest.raises(AssertionError):
        ExpandConfig(k=0)
    with pytest.raises(AssertionError):
        ExpandConfig(mode="nope")
    with pytest.raises(AssertionError):
        ExpandConfig(amplitude=0.0)
    with pytest.raises(AssertionError):
        ExpandConfig(combine=1.5)
    assert ExpandConfig(k=8, combine=0.25).k_comb == 2


def test_expander_determinism_independent_of_batching():
    """The fold_in contract at the Expander level: one B=2 wave ≡ two B=1
    waves, label for label (combine=0)."""
    fam = get_family("poisson", nx=8, ny=8)
    batch = fam.sample_batch(jax.random.PRNGKey(4), 2)
    coeffs = jnp.asarray(batch.op.coeffs)
    u = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, 8)))
    ec = ExpandConfig(k=3)
    e1 = Expander(ec, 8, 8)
    e1.wave(coeffs, u, np.array([0, 1]), np.array([True, True]))
    L1 = e1.result()
    e2 = Expander(ec, 8, 8)
    e2.expand_one(coeffs[1], u[1], 1)
    e2.expand_one(coeffs[0], u[0], 0)
    L2 = e2.result()
    order1 = np.argsort(L1.anchor_idx, kind="stable")
    order2 = np.argsort(L2.anchor_idx, kind="stable")
    np.testing.assert_array_equal(L1.u[order1], L2.u[order2])
    np.testing.assert_array_equal(L1.f[order1], L2.f[order2])


# ----------------------------------------------------- health interplay

def test_quarantined_anchor_ships_no_labels():
    fam = get_family("poisson", nx=10, ny=10)
    key = jax.random.PRNGKey(2)
    ec = ExpandConfig(k=2)
    fault = FaultPlan(nan_rhs=(2,))
    r = SKRGenerator(fam, SKRConfig(krylov=KC, expand=ec, retry=None)
                     ).generate(key, 6, fault=fault)
    bad = set(np.nonzero(~r.label_ok)[0].tolist())
    assert bad == {2}
    assert 2 not in set(np.unique(r.labels.anchor_idx))
    assert len(r.labels) == 5 * (ec.k + 1)


def test_retry_ladder_recovers_expansion():
    fam = get_family("poisson", nx=10, ny=10)
    key = jax.random.PRNGKey(2)
    ec = ExpandConfig(k=2)
    r = SKRGenerator(fam, SKRConfig(krylov=KC, expand=ec,
                                    retry=RetryPolicy())
                     ).generate(key, 6, fault=FaultPlan(nan_rhs=(2,)))
    assert r.label_ok.all()
    assert len(r.labels) == 6 * (ec.k + 1)


def test_lockstep_requeue_reexpands():
    """A quarantined lockstep anchor's wave labels are retracted; the
    requeue ladder re-solves it and re-expands — every anchor ends with
    exactly k+1 labels and none of them NaN."""
    fam = get_family("poisson", nx=10, ny=10)
    key = jax.random.PRNGKey(2)
    ec = ExpandConfig(k=2)
    rs = generate_dataset_chunked(
        fam, key, 6, SKRConfig(krylov=KC, expand=ec, retry=RetryPolicy()),
        workers=2, engine="batched", fault=FaultPlan(nan_rhs=(1, 4)))
    cnt = {}
    for r in rs:
        assert r.label_ok.all()
        assert np.isfinite(r.labels.f).all()
        for a in r.labels.anchor_idx:
            cnt[int(a)] = cnt.get(int(a), 0) + 1
    assert cnt == {i: ec.k + 1 for i in range(6)}


# -------------------------------------------------- checkpoint/resume

def test_checkpoint_roundtrips_labels(tmp_path):
    fam = get_family("poisson", nx=10, ny=10)
    key = jax.random.PRNGKey(5)
    ec = ExpandConfig(k=2)
    cfg = SKRConfig(krylov=KC, expand=ec, ckpt_every=2)
    gen = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError):
        gen.generate(key, 6, fail_at=4)
    resumed = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path)
                           ).generate(key, 6)
    ref = SKRGenerator(fam, cfg).generate(key, 6)
    np.testing.assert_array_equal(resumed.solutions, ref.solutions)

    def keyed(L):
        return sorted((int(a), k, u.tobytes(), f.tobytes())
                      for a, k, u, f in zip(L.anchor_idx, L.kind, L.u, L.f))

    assert len(resumed.labels) == len(ref.labels) == 6 * (ec.k + 1)
    assert keyed(resumed.labels) == keyed(ref.labels)


# --------------------------------------------------------- trajectories

def test_trajectory_labels_exact_under_operator_at_t():
    """Trajectory labels re-label perturbed snapshots under the θ-scheme
    operator AT THE SNAPSHOT'S OWN STEP: rebuild A(t) from the marched
    fields and check f' = A(t) u' at machine eps; the solved slot equals
    the step's RHS to solver tolerance (the one-step-pair property)."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=4)
    key = jax.random.PRNGKey(1)
    ec = ExpandConfig(k=2, amplitude=0.05)
    off = generate_trajectories(fam, key, 4, TrajConfig(krylov=KC))
    r = generate_trajectories(fam, key, 4, TrajConfig(krylov=KC, expand=ec))
    np.testing.assert_array_equal(off.trajectories, r.trajectories)
    assert off.labels is None
    L = r.labels
    assert len(L) == 4 * fam.nt * (ec.k + 1)
    specs = fam.sample_specs(key, 4)
    step1 = fam.step_fn()
    lat_of = lambda i: jax.tree_util.tree_map(lambda a: a[i], specs.latent)
    worst_exact, worst_pair = 0.0, 0.0
    for j in range(len(L)):
        i = int(L.anchor_idx[j])
        step = int(round(L.t[j] / fam.dt)) - 1
        u_prev = jnp.asarray(r.trajectories[i, step])
        a, b = step1(lat_of(i), u_prev, step * fam.dt, (step + 1) * fam.dt)
        res = _dense(a) @ L.u[j].reshape(-1) - L.f[j].reshape(-1)
        worst_exact = max(worst_exact, float(np.max(np.abs(res))))
        if L.kind[j] == "solved":
            d = np.max(np.abs(L.f[j].reshape(-1) - np.asarray(b).reshape(-1)))
            worst_pair = max(worst_pair, float(d))
    assert worst_exact < 1e-12, worst_exact
    assert worst_pair < 1e-7, worst_pair


@pytest.mark.parametrize("name,nt", [("heat", 4), ("wave", 3)])
def test_trajectory_lockstep_counts_and_provenance(name, nt):
    """Both trajectory stacks (classic heat, phase-masked wave) emit the
    same label totals from the lockstep engine as the sequential one, each
    chunk referencing only its own trajectories."""
    fam = get_timedep_family(name, nx=10, ny=10, nt=nt)
    key = jax.random.PRNGKey(1)
    ec = ExpandConfig(k=2, amplitude=0.05)
    cfg = TrajConfig(krylov=KC, expand=ec)
    seq = generate_trajectories(fam, key, 4, cfg)
    rs = generate_trajectories_chunked(fam, key, 4, cfg, workers=2,
                                       engine="batched")
    assert sum(len(r.labels) for r in rs) == len(seq.labels) \
        == 4 * nt * (ec.k + 1)
    for r in rs:
        assert set(np.unique(r.labels.anchor_idx)) <= set(r.order.tolist())
        assert np.isfinite(r.labels.f).all()


def test_trajectory_taint_retracts_labels():
    """retry=None: an unhealthy step taints the trajectory — ALL its
    labels (including pre-taint snapshots) are retracted."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=4)
    key = jax.random.PRNGKey(1)
    ec = ExpandConfig(k=2)
    fault = FaultPlan(nan_rhs=(1,), step=2)   # taint mid-trajectory
    r = generate_trajectories(fam, key, 4,
                              TrajConfig(krylov=KC, expand=ec, retry=None),
                              fault=fault)
    assert not r.label_ok[1]
    assert 1 not in set(np.unique(r.labels.anchor_idx))
    assert len(r.labels) == 3 * fam.nt * (ec.k + 1)
