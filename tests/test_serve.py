"""Streaming datagen scheduler (core/serve.py) acceptance tests.

Covers the ISSUE-10 contract: a seeded Poisson-arrival trace streamed
through `StreamScheduler` must reproduce the offline `run_chunked` labels
at tolerance for the same request set; deadline-expired requests are
force-admitted to the least-bad chain; a refilled slot never inherits a
foreign chain's recycle carry unless the assignment decision said so
(adoption within the similarity budget); and the mid-flight refill path
adds no host syncs beyond the lockstep engine's `2 + cycles` budget
(checked under `jax.transfer_guard("disallow")`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import serve
from repro.core.skr import SKRConfig, SteadyStream, generate_dataset_chunked
from repro.core.trajectory import (TrajConfig, TrajectoryStream,
                                   generate_trajectories_chunked)
from repro.pde.registry import get_family, get_timedep_family
from repro.solvers.batched import BatchedGCRODRSolver
from repro.solvers.types import KrylovConfig

KC = KrylovConfig(m=30, k=10, tol=1e-9, maxiter=6000)


def _rel(a, b):
    return np.abs(np.asarray(a) - np.asarray(b)).max() / \
        max(np.abs(np.asarray(b)).max(), 1e-300)


# --------------------------------------------- streamed == offline labels

def test_streamed_matches_offline_steady():
    """Poisson arrivals over steady systems: every request completes and
    the streamed per-item solutions match the offline chunked labels at
    tol (both converge to residual <= tol; the carries differ)."""
    fam = get_family("poisson", nx=10, ny=10)
    cfg = SKRConfig(krylov=KC, precond="jacobi")
    num, key = 12, jax.random.PRNGKey(7)

    offline = np.zeros((num, 10, 10))
    for r in generate_dataset_chunked(fam, key, num, cfg, workers=3,
                                      engine="batched"):
        offline[r.order] = r.solutions

    work = SteadyStream(fam, cfg)
    work.sample(key, num)   # same key → identical sampled batch
    reqs = serve.poisson_trace(num, rate=20.0, seed=0)
    rep = serve.StreamScheduler(
        work, serve.StreamConfig(slots=3, tick=0.1)).run(reqs)

    assert len(rep.completed) == num
    assert sorted(r.item for r in rep.completed) == list(range(num))
    assert work.label_ok.all()
    assert _rel(work.outputs, offline) < 1e-6
    assert np.isfinite(rep.latencies()).all()
    assert (rep.latencies() >= 0).all()
    assert rep.rows_live == num     # every live row solved one request


def test_streamed_matches_offline_trajectory():
    """Same acceptance for the time-dependent workload: out-of-phase slots
    stepped per-slot-time must reproduce the offline lockstep marches."""
    fam = get_timedep_family("heat", nx=8, ny=8, nt=4)
    cfg = TrajConfig(krylov=KC, precond="jacobi")
    num, key = 6, jax.random.PRNGKey(3)

    offline = np.zeros((num, fam.nt + 1, 8, 8))
    for r in generate_trajectories_chunked(fam, key, num, cfg, workers=2,
                                           engine="batched"):
        offline[r.order] = r.trajectories

    work = TrajectoryStream(fam, cfg)
    work.sample(key, num)
    reqs = serve.poisson_trace(num, rate=30.0, seed=1)
    rep = serve.StreamScheduler(
        work, serve.StreamConfig(slots=2, tick=0.05)).run(reqs)

    assert len(rep.completed) == num
    assert work.label_ok.all()
    assert _rel(work.outputs, offline) < 1e-5
    assert rep.rows_live == num * fam.nt   # nt dispatches per trajectory


def test_trajectory_stream_rejects_non_classic():
    fam = get_timedep_family("heat", nx=8, ny=8, nt=3, integrator="bdf2")
    work = TrajectoryStream(fam, TrajConfig(krylov=KC, precond="jacobi"))
    work.sample(jax.random.PRNGKey(0), 2)
    with pytest.raises(NotImplementedError):
        work.begin_stream(2)


# ------------------------------------------------ deadline force-admission

def _deadline_run(deadline):
    """2 slots, 3 simultaneous trajectory requests, budget that never
    matches: request 2 must wait for a slot (nt ticks) unless its deadline
    expires first, in which case it is force-admitted to the least-bad
    live chain. tick=1 makes the clock fully deterministic."""
    fam = get_timedep_family("heat", nx=8, ny=8, nt=4)
    cfg = TrajConfig(krylov=KC, precond="jacobi")
    work = TrajectoryStream(fam, cfg)
    work.sample(jax.random.PRNGKey(5), 3)
    reqs = [serve.Request(item=0, arrival=0.0),
            serve.Request(item=1, arrival=0.0),
            serve.Request(item=2, arrival=0.0, deadline=deadline)]
    rep = serve.StreamScheduler(work, serve.StreamConfig(
        slots=2, tick=1.0, similarity_budget=-1.0)).run(reqs)
    return rep, work, next(r for r in rep.completed if r.item == 2)


def test_deadline_expiry_force_admits():
    rep, work, r2 = _deadline_run(deadline=2.0)
    assert rep.forced == 1
    assert r2.forced
    assert r2.admitted == 2.0            # the tick its deadline expired
    # force-admission APPENDS to a live chain rather than opening a new one
    assert rep.chains == 2
    assert r2.chain in [r.chain for r in rep.completed if r.item != 2]
    assert work.label_ok.all()           # forced items still solve to tol


def test_no_deadline_waits_for_free_slot():
    rep, work, r2 = _deadline_run(deadline=None)
    assert rep.forced == 0
    assert not r2.forced
    assert r2.admitted == 4.0            # waited out a full nt=4 trajectory
    assert rep.chains == 3               # fresh chain in the freed slot
    assert work.label_ok.all()


# ------------------------------------------------------- carry hygiene

def _same_item_twice(similarity_budget, second_arrival):
    """One slot, the SAME system requested twice: the second solve's
    iteration count reveals whether the recycle carry survived admission
    (append/adopt) or was cleared (fresh refill)."""
    fam = get_family("poisson", nx=10, ny=10)
    cfg = SKRConfig(krylov=KC, precond="jacobi")
    work = SteadyStream(fam, cfg)
    work.sample(jax.random.PRNGKey(11), 4)
    reqs = [serve.Request(item=0, arrival=0.0),
            serve.Request(item=0, arrival=second_arrival)]
    rep = serve.StreamScheduler(work, serve.StreamConfig(
        slots=1, tick=1.0, similarity_budget=similarity_budget)).run(reqs)
    its = [s.iterations for s in work.stats.per_system]
    assert len(its) == 2 and work.label_ok[0]
    return rep, its


def test_refill_clears_foreign_carry():
    """A refill outside the similarity budget must NOT inherit the retired
    chain's carry: the second solve of the identical system runs exactly
    as cold as the first."""
    rep, its = _same_item_twice(similarity_budget=-1.0, second_arrival=10.0)
    assert rep.chains == 2
    assert its[1] == its[0]


def test_refill_adopts_carry_within_budget():
    """A refill whose head is within budget of the slot's LAST chain head
    adopts the carry — the warm second solve takes fewer iterations."""
    rep, its = _same_item_twice(similarity_budget=1e6, second_arrival=10.0)
    assert rep.chains == 2               # still a new chain, carry adopted
    assert its[1] < its[0]


def test_append_rides_chain_carry():
    """Within-budget admission appends to the live chain: same warm-start
    effect without opening a chain."""
    rep, its = _same_item_twice(similarity_budget=1e6, second_arrival=0.0)
    assert rep.chains == 1
    assert its[1] < its[0]


def test_swap_slot_mechanics():
    """swap_slot unit contract: clear zeroes one slot's carry and drops its
    carry_ok; adopt installs the given carry; other slots untouched; the
    mixed-precision inner mirror swaps in lockstep."""
    from tests.test_transfer_guard import _batched_ops

    ops, b = _batched_ops(chains=3)
    solver = BatchedGCRODRSolver(KrylovConfig(m=18, k=6, tol=1e-8,
                                              maxiter=2000))
    solver.solve_batch(ops, b)
    assert solver.carry_ok.all()
    keep = solver.u_carry[0].copy()
    solver.swap_slot(1)
    assert not solver.carry_ok[1]
    assert (solver.u_carry[1] == 0.0).all()
    assert solver.carry_ok[0] and solver.carry_ok[2]
    np.testing.assert_array_equal(solver.u_carry[0], keep)
    solver.swap_slot(2, carry=keep, carry_ok=True)
    assert solver.carry_ok[2]
    np.testing.assert_array_equal(solver.u_carry[2], keep)

    # mixed precision: the fp32 inner solver mirrors the swap
    import dataclasses
    cfg32 = dataclasses.replace(KrylovConfig(m=18, k=6, tol=1e-8,
                                             maxiter=2000),
                                inner_dtype="float32")
    mixed = BatchedGCRODRSolver(cfg32)
    mixed.solve_batch(ops, b)
    assert mixed._inner is not None and mixed._inner.u_carry is not None
    mixed.swap_slot(1)
    assert not mixed.carry_ok[1] and not mixed._inner.carry_ok[1]
    assert (mixed._inner.u_carry[1] == 0.0).all()


# --------------------------------------------------- transfer-guard budget

def test_streaming_refill_keeps_sync_budget():
    """A dispatch → mid-flight swap (clear + adopt) → dispatch sequence
    must run clean under the transfer guard with the host-sync budget
    unchanged: the refill is pure host numpy."""
    from tests.test_transfer_guard import _batched_ops

    ops, b = _batched_ops(chains=3)
    solver = BatchedGCRODRSolver(KrylovConfig(m=18, k=6, tol=1e-8,
                                              maxiter=2000))
    with jax.transfer_guard("disallow"):
        x, stats = solver.solve_batch(ops, b)
        solver.swap_slot(1)                                  # fresh refill
        solver.swap_slot(2, carry=solver.u_carry[0].copy(),  # adoption
                         carry_ok=True)
        x, stats = solver.solve_batch(ops, b)
    assert all(s.converged for s in stats)
    cycles = max(s.cycles for s in stats)
    assert all(s.host_syncs <= 2 + cycles for s in stats if not s.padded)


def test_streamed_scheduler_keeps_sync_budget():
    """End-to-end: every solve dispatched by the streaming loop — including
    waves issued right after mid-flight refills — stays inside the lockstep
    engine's counted host-sync budget."""
    fam = get_family("poisson", nx=10, ny=10)
    cfg = SKRConfig(krylov=KC, precond="jacobi")
    work = SteadyStream(fam, cfg)
    work.sample(jax.random.PRNGKey(2), 8)
    reqs = serve.poisson_trace(8, rate=50.0, seed=4)
    rep = serve.StreamScheduler(
        work, serve.StreamConfig(slots=2, tick=0.02)).run(reqs)
    assert len(rep.completed) == 8
    cycles = max(s.cycles for s in work.stats.per_system)
    assert all(s.host_syncs <= 2 + cycles
               for s in work.stats.per_system if not s.padded)


# --------------------------------------------------- refill vs wave padding

def test_midflight_refill_beats_wave_padding():
    """On a backlogged trace the mid-flight scheduler keeps slots occupied
    while the wave baseline drains each admitted set with padding — the
    utilization gap is the whole point of the refill path."""
    fam = get_timedep_family("heat", nx=8, ny=8, nt=3)
    cfg = TrajConfig(krylov=KC, precond="jacobi")
    num, key = 9, jax.random.PRNGKey(9)
    utils = {}
    for refill in ("midflight", "wave"):
        work = TrajectoryStream(fam, cfg)
        work.sample(key, num)
        reqs = serve.poisson_trace(num, rate=100.0, seed=6)
        rep = serve.StreamScheduler(work, serve.StreamConfig(
            slots=3, tick=0.05, refill=refill,
            similarity_budget=-1.0)).run(reqs)
        assert len(rep.completed) == num
        utils[refill] = rep.utilization
    assert utils["midflight"] > utils["wave"]
    assert utils["midflight"] > 0.8
