"""δ(Q, C) subspace-distance properties (paper Eq. 5 / Table 2)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metrics import (delta_subspace, orthonormalize,
                                smallest_invariant_subspace)


def test_delta_zero_when_contained():
    rng = np.random.default_rng(0)
    c = rng.standard_normal((20, 6))
    q = c[:, :3] @ rng.standard_normal((3, 3))  # span(Q) ⊆ span(C)
    assert delta_subspace(q, c) < 1e-10


def test_delta_one_when_orthogonal():
    q = np.eye(10)[:, :3]
    c = np.eye(10)[:, 5:8]
    assert abs(delta_subspace(q, c) - 1.0) < 1e-12


@given(st.integers(4, 24), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_delta_in_unit_interval(n, kq, kc, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((n, min(kq, n)))
    c = rng.standard_normal((n, min(kc, n)))
    d = delta_subspace(q, c)
    assert -1e-12 <= d <= 1.0 + 1e-12


def test_orthonormalize_produces_orthonormal_columns():
    rng = np.random.default_rng(1)
    m = rng.standard_normal((30, 5))
    q = orthonormalize(m)
    np.testing.assert_allclose(q.T @ q, np.eye(q.shape[1]), atol=1e-12)


def test_orthonormalize_drops_dependent_columns():
    rng = np.random.default_rng(2)
    a = rng.standard_normal((20, 3))
    m = np.concatenate([a, a[:, :1] * 2.0], axis=1)  # rank 3, 4 cols
    assert orthonormalize(m).shape[1] == 3


def test_smallest_invariant_subspace_is_invariant():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((40, 40))
    q = smallest_invariant_subspace(a, k=5)
    # A·span(Q) ⊆ span(Q') with Q' the exact eigen-space: residual of the
    # projected operator should be small relative to ‖A‖
    proj = q @ q.T
    resid = np.linalg.norm(a @ q - proj @ (a @ q), 2)
    assert resid < 1e-8 * np.linalg.norm(a, 2) + 1e-8
