"""The device-resident lockstep engine must not move data implicitly.

`jax.transfer_guard("disallow")` errors on every IMPLICIT host↔device
transfer while still permitting explicit ones (`jnp.asarray`, `device_put`,
`device_get` / `np.asarray` on a device array). The refactored
`BatchedGCRODRSolver.solve_batch` is designed to cross the boundary only at
explicit, counted points — entry upload, one 4-flag fetch per cycle, one
finalize fetch — so an entire lockstep solve (including warm-started
follow-up solves and the k = 0 GMRES special case) must run clean under the
guard. A regression here means some per-cycle host round-trip crept back
into the hot loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pde.dia import Stencil5
from repro.pde.registry import get_family
from repro.solvers.batched import BatchedGCRODRSolver
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import make_preconditioner_batched
from repro.solvers.types import KrylovConfig


def _batched_ops(nx=10, chains=3, seed=11):
    fam = get_family("poisson", nx=nx, ny=nx)
    batch = fam.sample_batch(jax.random.PRNGKey(seed), chains)
    st5 = Stencil5(jnp.asarray(batch.op.coeffs))
    pre = make_preconditioner_batched("jacobi", st5)
    ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
    b = np.asarray(batch.b).reshape(chains, -1)
    return ops, b


@pytest.mark.parametrize("k", [0, 6])
def test_lockstep_solve_has_no_implicit_transfers(k):
    ops, b = _batched_ops()
    cfg = KrylovConfig(m=18, k=k, tol=1e-8, maxiter=2000)
    solver = BatchedGCRODRSolver(cfg)
    with jax.transfer_guard("disallow"):
        x, stats = solver.solve_batch(ops, b)
        if k > 0:
            # the warm-started follow-up exercises the carry upload +
            # batched re-biorthogonalization path under the guard too
            x, stats = solver.solve_batch(ops, b)
    assert all(s.converged for s in stats)
    # the sync budget claim: entry + one per cycle + finalize
    cycles = max(s.cycles for s in stats)
    assert all(s.host_syncs <= 2 + cycles for s in stats if not s.padded)


def test_lockstep_syncs_scale_with_cycles_not_chains():
    """host_syncs is a batch-shared count: growing B must not grow it."""
    cfg = KrylovConfig(m=18, k=6, tol=1e-8, maxiter=2000)
    counts = {}
    for chains in (2, 4):
        ops, b = _batched_ops(chains=chains)
        _, stats = BatchedGCRODRSolver(cfg).solve_batch(ops, b)
        counts[chains] = max(s.host_syncs for s in stats)
    assert counts[4] <= counts[2] + 2  # same cycle count up to ±2 cycles
