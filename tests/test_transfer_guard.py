"""The device-resident lockstep engine must not move data implicitly.

`jax.transfer_guard("disallow")` errors on every IMPLICIT host↔device
transfer while still permitting explicit ones (`jnp.asarray`, `device_put`,
`device_get` / `np.asarray` on a device array). The refactored
`BatchedGCRODRSolver.solve_batch` is designed to cross the boundary only at
explicit, counted points — entry upload, one 4-flag fetch per cycle, one
finalize fetch — so an entire lockstep solve (including warm-started
follow-up solves and the k = 0 GMRES special case) must run clean under the
guard. A regression here means some per-cycle host round-trip crept back
into the hot loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.pde.dia import Stencil5
from repro.pde.registry import get_family
from repro.solvers.batched import BatchedGCRODRSolver
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import make_preconditioner_batched
from repro.solvers.types import KrylovConfig


def _batched_ops(nx=10, chains=3, seed=11):
    fam = get_family("poisson", nx=nx, ny=nx)
    batch = fam.sample_batch(jax.random.PRNGKey(seed), chains)
    st5 = Stencil5(jnp.asarray(batch.op.coeffs))
    pre = make_preconditioner_batched("jacobi", st5)
    ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
    b = np.asarray(batch.b).reshape(chains, -1)
    return ops, b


@pytest.mark.parametrize("telemetry", [False, True],
                         ids=["obs_off", "obs_on"])
@pytest.mark.parametrize("k", [0, 6])
def test_lockstep_solve_has_no_implicit_transfers(k, telemetry):
    """Both with observability off (the default) and ON — the device
    telemetry rings are accumulated inside the jitted cycle programs and
    drained by the EXISTING finalize fetch, so turning them on must not
    add a single transfer or blocking sync to the hot loop."""
    ops, b = _batched_ops()
    cfg = KrylovConfig(m=18, k=k, tol=1e-8, maxiter=2000)
    solver = BatchedGCRODRSolver(cfg)
    if telemetry:
        obs.enable(delta_qc=True)
    try:
        with jax.transfer_guard("disallow"):
            x, stats = solver.solve_batch(ops, b)
            if k > 0:
                # the warm-started follow-up exercises the carry upload +
                # batched re-biorthogonalization path under the guard too
                x, stats = solver.solve_batch(ops, b)
    finally:
        obs.disable()
    assert all(s.converged for s in stats)
    # the sync budget claim: entry + one per cycle + finalize — exactly
    # one blocking fetch per cycle, telemetry on or off
    cycles = max(s.cycles for s in stats)
    assert all(s.host_syncs <= 2 + cycles for s in stats if not s.padded)
    if telemetry:
        # the rings drained: every chain carries its per-cycle history
        # (batch-shared ring → at least the chain's own cycle count)
        for s in stats:
            assert s.telemetry is not None
            assert len(s.telemetry.res_hist) >= s.cycles
            assert np.isfinite(s.telemetry.res_hist).all()
    else:
        assert all(s.telemetry is None for s in stats)


@pytest.mark.parametrize("k", [0, 6])
def test_lockstep_containment_keeps_sync_budget(k):
    """Containment ON (a RetryPolicy attached) adds a per-batch health flag
    to the EXISTING per-cycle flag fetch and a quarantine mask to the
    EXISTING finalize fetch — the sync budget must stay 2 + cycles and the
    solve must run clean under the transfer guard."""
    from repro.core.robust import RetryPolicy

    ops, b = _batched_ops()
    cfg = KrylovConfig(m=18, k=k, tol=1e-8, maxiter=2000)
    solver = BatchedGCRODRSolver(cfg, policy=RetryPolicy())
    with jax.transfer_guard("disallow"):
        x, stats = solver.solve_batch(ops, b)
        if k > 0:
            x, stats = solver.solve_batch(ops, b)
    assert all(s.converged for s in stats)
    assert not any(s.quarantined for s in stats)
    cycles = max(s.cycles for s in stats)
    assert all(s.host_syncs <= 2 + cycles for s in stats if not s.padded)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["ref", "pallas"])
def test_expansion_wave_adds_no_transfers_or_syncs(use_kernel):
    """Label expansion (core/expand.py) rides the retired lockstep row:
    the wave consumes the solver's device-resident `x_device` stash and the
    row's already-uploaded operator stack, accumulates its results as
    device arrays, and drains them only at `result()` — so a solve + wave
    runs clean under the transfer guard and the solver's sync budget stays
    exactly 2 + cycles with expansion ON."""
    from repro.core.expand import ExpandConfig, Expander

    chains = 3
    ops, b = _batched_ops(chains=chains)
    cfg = KrylovConfig(m=18, k=6, tol=1e-8, maxiter=2000)
    solver = BatchedGCRODRSolver(cfg)
    exp = Expander(ExpandConfig(k=4), 10, 10, use_kernel=use_kernel)
    idx = np.arange(chains)
    live = np.ones(chains, dtype=bool)
    with jax.transfer_guard("disallow"):
        x, stats = solver.solve_batch(ops, b)
        exp.wave(ops.base.coeffs, solver.x_device, idx, live)
    cycles = max(s.cycles for s in stats)
    assert all(s.host_syncs <= 2 + cycles for s in stats if not s.padded)
    labels = exp.result()    # the one bulk drain, outside the guard
    assert len(labels) == chains * 5
    assert np.isfinite(labels.f).all() and np.isfinite(labels.u).all()


def test_lockstep_syncs_scale_with_cycles_not_chains():
    """host_syncs is a batch-shared count: growing B must not grow it."""
    cfg = KrylovConfig(m=18, k=6, tol=1e-8, maxiter=2000)
    counts = {}
    for chains in (2, 4):
        ops, b = _batched_ops(chains=chains)
        _, stats = BatchedGCRODRSolver(cfg).solve_batch(ops, b)
        counts[chains] = max(s.host_syncs for s in stats)
    assert counts[4] <= counts[2] + 2  # same cycle count up to ±2 cycles
