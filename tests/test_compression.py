"""Error-feedback gradient compression invariants (hypothesis) and
end-to-end convergence under compression (DESIGN §5 distributed tricks)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.distributed.compression import (compress_tree, init_error_tree,
                                           int8_decode, int8_encode,
                                           int8_ef_step, topk_ef_step)


@given(st.integers(0, 10_000), st.integers(1, 200))
@settings(max_examples=40, deadline=None)
def test_int8_ef_conserves_signal(seed, n):
    """decoded + residual == corrected input (error feedback drops nothing,
    it only defers)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(n) * rng.uniform(0.1, 100))
    err = jnp.asarray(rng.standard_normal(n) * 0.01)
    dec, new_err = int8_ef_step(g, err)
    np.testing.assert_allclose(np.asarray(dec + new_err),
                               np.asarray(g + err), rtol=1e-5, atol=1e-5)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64))
    q, scale = int8_encode(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(int8_decode(q, scale)) - np.asarray(g))
    assert err.max() <= float(scale) * 0.5 + 1e-7


@given(st.integers(0, 10_000), st.floats(0.05, 0.9))
@settings(max_examples=25, deadline=None)
def test_topk_ef_conserves_signal(seed, frac):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(128))
    err = jnp.zeros(128)
    dec, new_err = topk_ef_step(g, err, frac)
    np.testing.assert_allclose(np.asarray(dec + new_err), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    kept = np.count_nonzero(np.asarray(dec))
    assert kept >= int(128 * frac) * 0.5  # at least ~k kept (ties allowed)


def test_ef_residual_shrinks_effective_bias():
    """Summed over steps, EF-compressed updates track the true gradient sum:
    ‖Σ(dec_t) − Σ(g_t)‖ == ‖e_T‖ stays bounded (doesn't grow with T)."""
    rng = np.random.default_rng(1)
    err = jnp.zeros(32)
    total_dec = np.zeros(32)
    total_g = np.zeros(32)
    norms = []
    for t in range(50):
        g = jnp.asarray(rng.standard_normal(32))
        dec, err = int8_ef_step(g, err)
        total_dec += np.asarray(dec)
        total_g += np.asarray(g)
        norms.append(np.linalg.norm(total_g - total_dec))
    np.testing.assert_allclose(norms[-1], np.linalg.norm(np.asarray(err)),
                               rtol=1e-4, atol=1e-4)
    assert norms[-1] < 10 * norms[4] + 1.0  # bounded, not linear growth


def test_compress_tree_structure_preserved():
    params = {"a": jnp.ones((4, 4)), "b": {"c": jnp.ones((3,))}}
    grads = jax.tree_util.tree_map(lambda p: p * 0.5, params)
    err = init_error_tree(params)
    dec, new_err = compress_tree(grads, err, "int8")
    assert jax.tree_util.tree_structure(dec) == \
        jax.tree_util.tree_structure(grads)
    assert jax.tree_util.tree_structure(new_err) == \
        jax.tree_util.tree_structure(err)
    for g, d in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(dec)):
        assert g.shape == d.shape and g.dtype == d.dtype


def test_training_converges_under_compression():
    """Quadratic toy problem: int8-EF SGD reaches (near) the same loss as
    uncompressed SGD."""
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.train.optim import sgd_fallback

    w_true = jnp.asarray(np.random.default_rng(0).standard_normal(16))

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    rng = np.random.default_rng(1)

    def batches(i):
        x = jnp.asarray(rng.standard_normal((32, 16)))
        return {"x": x, "y": x @ w_true}

    outs = {}
    for codec in ("none", "int8"):
        tr = Trainer(loss_fn, {"w": jnp.zeros(16)},
                     optimizer=sgd_fallback(0.05),
                     cfg=TrainerConfig(compression=codec, log_every=0))
        _, hist = tr.run(batches, 150)
        outs[codec] = hist[-1]
    assert outs["int8"] < 1e-2
    assert outs["int8"] < outs["none"] * 50 + 1e-3
