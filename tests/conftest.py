"""Shared fixtures. NOTE: do NOT set XLA device-count flags here — smoke
tests and benches must see 1 CPU device; only launch/dryrun.py forces the
512-device placeholder fleet (in a subprocess for the dry-run tests)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
