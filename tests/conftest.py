"""Shared fixtures. NOTE: do NOT set XLA device-count flags here — smoke
tests and benches must see 1 CPU device; only launch/dryrun.py forces the
512-device placeholder fleet (in a subprocess for the dry-run tests).

Also installs a fallback `hypothesis` stub when the real package is absent
(see requirements-dev.txt): `@given` degrades to a fixed deterministic set of
example cases (bounds + seeded draws) so the property tests still collect and
exercise the code, just without shrinking/fuzzing."""
import functools
import inspect
import os
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _install_hypothesis_stub():
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    import numpy as _np

    class _Strategy:
        """Bounded scalar strategy: deterministic example draws only."""

        def __init__(self, kind, lo, hi):
            self.kind, self.lo, self.hi = kind, lo, hi

        def example(self, case: int, rng) -> object:
            if case == 0:
                return self.lo
            if case == 1:
                return self.hi
            if self.kind == "int":
                return int(rng.integers(self.lo, self.hi + 1))
            return float(rng.uniform(self.lo, self.hi))

    def integers(lo, hi):
        return _Strategy("int", int(lo), int(hi))

    def floats(lo, hi, **_kw):
        return _Strategy("float", float(lo), float(hi))

    def given(*strategies, **kw_strategies):
        assert not kw_strategies, "stub supports positional strategies only"

        def deco(fn):
            @functools.wraps(fn)
            def run():
                for case in range(5):  # lo-corner, hi-corner, 3 seeded draws
                    rng = _np.random.default_rng(1234 + case)
                    fn(*(s.example(case, rng) for s in strategies))

            # hide the original signature or pytest treats the strategy
            # params as fixtures
            del run.__wrapped__
            run.__signature__ = inspect.Signature()
            return run
        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn

    mod = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.floats = floats
    mod.given = given
    mod.settings = settings
    mod.strategies = strat
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat


_install_hypothesis_stub()

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
