"""Precision-policy layer: fp32 inner cycles + fp64 iterative refinement.

Covers the tentpole's contract end to end: fp64-tolerance parity of the
fp32-inner path on every registered steady + time-dependent family, the
stagnation fallback to fp64 on an ill-conditioned (near-resonant)
Helmholtz operator, bitwise regression of the fp64 default path, fp32
carry / fp64 label dtypes, and dtype polymorphism of the kernels in both
the ref and interpret-mode Pallas paths (incl. the padded-tail fallback
and the f32-storage/f64-accum CGS2 knob)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.pde.dia import DIA, Stencil5
from repro.pde.registry import (get_family, get_timedep_family,
                                list_families, list_timedep_families)
from repro.solvers.batched import BatchedGCRODRSolver
from repro.solvers.gcrodr import GCRODRSolver
from repro.solvers.gmres import gmres_solve, solve_gmres
from repro.solvers.operator import (PreconditionedOp, StencilOp, as_operator,
                                    cast_operator)
from repro.solvers.precond import (make_preconditioner,
                                   make_preconditioner_batched)
from repro.solvers.types import KrylovConfig

CFG = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
CFG32 = dataclasses.replace(CFG, inner_dtype="float32")


def _true_rel_res(prob, x):
    a = prob.op.to_dense()
    b = np.asarray(prob.b, np.float64).reshape(-1)
    return np.linalg.norm(b - a @ np.asarray(x).reshape(-1)) / np.linalg.norm(b)


# ------------------------------------------------------ fp64-parity, steady

@pytest.mark.parametrize("family", list_families())
def test_gmres_fp32_inner_reaches_fp64_tolerance(family):
    """The outer refinement loop owns the accuracy: the final TRUE fp64
    relative residual of the fp32-inner path sits at cfg.tol on every
    registered steady family, and labels come back fp64."""
    fam = get_family(family, nx=16, ny=16)
    p = fam.sample(jax.random.PRNGKey(0))
    x32, st32 = solve_gmres(p.op, p.b, CFG32)
    assert st32.converged, (family, st32)
    assert st32.outer_refinements >= 1
    assert np.asarray(x32).dtype == np.float64
    assert _true_rel_res(p, x32) <= CFG.tol * 1.01
    x64, _ = solve_gmres(p.op, p.b, CFG)
    np.testing.assert_allclose(np.asarray(x32), np.asarray(x64), rtol=1e-4,
                               atol=1e-7)


@pytest.mark.parametrize("family", ["poisson", "helmholtz"])
def test_gcrodr_fp32_inner_sequence_parity(family):
    """Recycling chain under the mixed policy: every system of a sequence
    converges to the fp64 tolerance and the carry is STORED fp32."""
    fam = get_family(family, nx=16, ny=16)
    solver = GCRODRSolver(CFG32)
    for s in range(3):
        p = fam.sample(jax.random.PRNGKey(s))
        pre = make_preconditioner("jacobi", p.op)
        op = PreconditionedOp(as_operator(p.op), pre)
        x, st = solver.solve(op, jnp.asarray(p.b).reshape(-1))
        assert st.converged, (family, s, st)
        assert _true_rel_res(p, x) <= CFG.tol * 1.01
        assert np.asarray(x).dtype == np.float64       # labels fp64
    assert solver.u_carry is not None
    assert solver.u_carry.dtype == np.float32          # carry fp32


@pytest.mark.parametrize("family", list_timedep_families())
def test_timedep_fp32_inner_trajectory_parity(family):
    """θ-scheme marching with fp32 inner cycles matches the fp64 engine to
    solver tolerance on every registered time-dependent family."""
    from repro.core.trajectory import TrajConfig, march_trajectory

    fam = get_timedep_family(family, nx=12, ny=12, nt=4)
    spec = fam.sample_spec(jax.random.PRNGKey(0))
    kc = dataclasses.replace(CFG, tol=1e-9)
    t64, s64 = march_trajectory(fam, spec, TrajConfig(krylov=kc,
                                                      precond="jacobi"))
    kc32 = dataclasses.replace(kc, inner_dtype="float32")
    t32, s32 = march_trajectory(fam, spec, TrajConfig(krylov=kc32,
                                                      precond="jacobi"))
    assert s32.num_converged == s32.num
    assert t32.dtype == np.float64
    scale = np.abs(t64).max()
    np.testing.assert_allclose(t32, t64, atol=1e-6 * scale)


def test_batched_fp32_inner_matches_fp64_lockstep():
    """Lockstep mixed engine: per-chain solutions agree with the fp64
    lockstep engine to solver tolerance; per-chain carries stored fp32."""
    fam = get_family("poisson", nx=12, ny=12)
    batch = fam.sample_batch(jax.random.PRNGKey(3), 4)
    coeffs = jnp.asarray(batch.op.coeffs)
    b_all = np.asarray(batch.b).reshape(4, -1)
    outs = {}
    for tag, cfg in (("f64", CFG), ("f32", CFG32)):
        solver = BatchedGCRODRSolver(cfg)
        xs = []
        for t in range(2):
            idx = np.array([2 * w + t for w in range(2)])
            st5 = Stencil5(coeffs).take(jnp.asarray(idx))
            pre = make_preconditioner_batched("jacobi", st5)
            opsb = PreconditionedOp(StencilOp(st5.coeffs), pre)
            x, sts = solver.solve_batch(opsb, jnp.asarray(b_all[idx]))
            assert all(s.converged for s in sts), (tag, t)
            xs.append(x)
        outs[tag] = np.concatenate(xs)
        if tag == "f32":
            assert solver.u_carry.dtype == np.float32
            assert all(s.outer_refinements >= 1 for s in sts)
    rel = (np.linalg.norm(outs["f32"] - outs["f64"], axis=1)
           / np.linalg.norm(outs["f64"], axis=1))
    assert (rel <= 1e-6).all(), rel


def test_batched_fp32_zero_rhs_padding_noop():
    """Padded chains stay a no-op under the mixed policy: 0 iterations,
    x = 0, recycle carry untouched."""
    fam = get_family("poisson", nx=12, ny=12)
    batch = fam.sample_batch(jax.random.PRNGKey(5), 2)
    coeffs = jnp.asarray(batch.op.coeffs)
    b_all = np.asarray(batch.b).reshape(2, -1)
    st5 = Stencil5(coeffs).take(jnp.asarray([0, 1]))
    pre = make_preconditioner_batched("jacobi", st5)
    opsb = PreconditionedOp(StencilOp(st5.coeffs), pre)
    solver = BatchedGCRODRSolver(CFG32)
    solver.solve_batch(opsb, jnp.asarray(b_all))
    before = solver.u_carry.copy()
    b_pad = b_all.copy()
    b_pad[1] = 0.0
    xs, sts = solver.solve_batch(opsb, jnp.asarray(b_pad))
    assert sts[1].converged and sts[1].iterations == 0
    np.testing.assert_array_equal(xs[1], np.zeros_like(xs[1]))
    np.testing.assert_array_equal(solver.u_carry[1], before[1])
    assert sts[0].converged and sts[0].iterations > 0


# ------------------------------------------------------ stagnation fallback

def _near_resonant_helmholtz(nx=12, kappa=1e8):
    """Helmholtz operator shifted to within ‖A‖/kappa of resonance — fp32
    cycles cannot contract the residual (κ·eps_f32 ≫ 1)."""
    fam = get_family("helmholtz", nx=nx, ny=nx)
    p = fam.sample(jax.random.PRNGKey(0))
    a = np.asarray(p.op.to_dense())
    evals = np.linalg.eigvalsh(0.5 * (a + a.T))
    mu = evals[np.argmin(np.abs(evals))]
    eps = np.abs(evals).max() / kappa
    coeffs = p.op.coeffs.at[Stencil5.C].add(-mu + eps)
    return Stencil5(coeffs), p.b


def test_fp32_stagnation_falls_back_to_fp64():
    """Ill-conditioned helmholtz: the fp32 passes stagnate, the solver must
    flag the fallback AND still converge to the fp64 tolerance."""
    op_ill, b = _near_resonant_helmholtz()
    n = int(np.asarray(b).size)
    cfg = KrylovConfig(m=n + 8, k=12, tol=1e-8, maxiter=20_000,
                       inner_dtype="float32")
    solver = GCRODRSolver(cfg)
    op = PreconditionedOp(as_operator(op_ill), None)
    x, st = solver.solve(op, jnp.asarray(b).reshape(-1))
    assert st.converged, st
    assert st.fp64_fallback
    assert st.outer_refinements >= 1
    ad = op_ill.to_dense()
    bv = np.asarray(b).reshape(-1)
    res = np.linalg.norm(bv - ad @ np.asarray(x)) / np.linalg.norm(bv)
    assert res <= cfg.tol * 1.01


# ------------------------------------------------- fp64-default regression

def test_fp64_default_path_bitwise_identical():
    """inner_dtype="float64" (and the default) must take the historical
    code path: bitwise-identical solutions and identical iterate counts."""
    fam = get_family("poisson", nx=16, ny=16)
    p = fam.sample(jax.random.PRNGKey(1))
    x_def, st_def = solve_gmres(p.op, p.b, CFG)
    x_f64, st_f64 = solve_gmres(
        p.op, p.b, dataclasses.replace(CFG, inner_dtype="float64"))
    np.testing.assert_array_equal(np.asarray(x_def), np.asarray(x_f64))
    assert st_def.iterations == st_f64.iterations
    assert st_f64.outer_refinements == 0 and not st_f64.fp64_fallback

    op = PreconditionedOp(as_operator(p.op), None)
    b = jnp.asarray(p.b).reshape(-1)
    x1, st1 = GCRODRSolver(CFG).solve(op, b)
    x2, st2 = GCRODRSolver(
        dataclasses.replace(CFG, inner_dtype="float64")).solve(op, b)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert st1.iterations == st2.iterations


def test_cast_operator_preserves_structure():
    fam = get_family("darcy", nx=12, ny=12)
    p = fam.sample(jax.random.PRNGKey(0))
    pre = make_preconditioner("bjacobi", p.op)
    op = PreconditionedOp(as_operator(p.op), pre)
    op32 = cast_operator(op, jnp.float32)
    assert op32.base.coeffs.dtype == jnp.float32
    assert op32.precond.inv_blocks.dtype == jnp.float32
    # same treedef (static structure untouched)
    assert (jax.tree_util.tree_structure(op)
            == jax.tree_util.tree_structure(op32))
    v = jnp.ones(op.n, jnp.float32)
    assert op32.apply(v).dtype == jnp.float32


# ------------------------------------------- kernel dtype polymorphism

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_stencil5_matvec_dtype_polymorphic(dtype, use_kernel):
    key = jax.random.PRNGKey(0)
    coeffs = jax.random.normal(key, (5, 16, 16), dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 16), dtype)
    y = ops.stencil5_matvec(coeffs, x, use_kernel=use_kernel, interpret=True)
    assert y.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.stencil5_matvec(
            coeffs.astype(jnp.float64), x.astype(jnp.float64))),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("batched", [False, True])
def test_dia_spmv_dtype_polymorphic(dtype, batched):
    rng = np.random.default_rng(0)
    offsets = (-8, -1, 0, 1, 8)
    n = 200
    shape = (3, len(offsets), n) if batched else (len(offsets), n)
    data = jnp.asarray(rng.standard_normal(shape), dtype)
    x = jnp.asarray(rng.standard_normal(shape[:-2] + (n,)), dtype)
    dia = DIA(offsets=offsets, data=data)
    y = ops.dia_spmv(dia, x, use_kernel=True, interpret=True)
    assert y.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.dia_spmv(offsets, data, x)),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [521, 1000, 4096])  # prime / ragged / aligned
def test_fused_orthog_padded_tail_matches_ref(n):
    """The padded-tail fallback (prime-ish n must NOT degrade to a 1-element
    block) is exact: zero columns contribute nothing."""
    key = jax.random.PRNGKey(n)
    m = 12
    v = jax.random.normal(key, (m, n))
    w = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mask = (jnp.arange(m) < 9).astype(w.dtype)
    got_w, got_h = ops.fused_orthog(v, w, mask, use_kernel=True,
                                    interpret=True)
    want_w, want_h = ref.fused_orthog(v, w, mask)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-10, atol=1e-10)


def test_fused_orthog_grid_cap_raises():
    from repro.kernels.fused_orthog import fused_orthog_pallas

    v = jnp.zeros((4, 1 << 22))
    w = jnp.zeros(1 << 22)
    mask = jnp.ones(4)
    with pytest.raises(ValueError, match="sanity cap"):
        fused_orthog_pallas(v, w, mask, interpret=True, block_n=128)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_fused_orthog_f64_accum_knob(use_kernel):
    """cgs2_acc="float64": fp32 storage, fp64 accumulation — at least as
    close to the fp64 oracle as the all-fp32 run, and fp32 outputs."""
    key = jax.random.PRNGKey(7)
    m, n = 16, 512
    v64 = jnp.linalg.qr(jax.random.normal(key, (n, m)))[0].T
    w64 = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mask = jnp.ones((m,), jnp.float32)
    v32, w32 = v64.astype(jnp.float32), w64.astype(jnp.float32)
    ww, hw = ops.fused_orthog(v32, w32, mask, use_kernel=use_kernel,
                              interpret=True, acc_dtype=jnp.float64)
    assert ww.dtype == jnp.float32 and hw.dtype == jnp.float32
    w_ref, h_ref = ref.fused_orthog(v64, w64, mask.astype(jnp.float64))
    np.testing.assert_allclose(np.asarray(hw), np.asarray(h_ref), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ww), np.asarray(w_ref), atol=1e-5)


def test_arnoldi_cgs2_acc64_converges():
    """End-to-end: the f64-accum knob through a mixed-precision solve."""
    fam = get_family("poisson", nx=12, ny=12)
    p = fam.sample(jax.random.PRNGKey(0))
    cfg = dataclasses.replace(CFG32, cgs2_acc="float64")
    x, st = solve_gmres(p.op, p.b, cfg)
    assert st.converged
    assert _true_rel_res(p, x) <= cfg.tol * 1.01


# ------------------------------------------------- chunked-datagen parity

def test_chunked_datagen_fp32_inner_labels_match():
    """generate_dataset_chunked with the mixed policy: fp64 labels at
    solver tolerance, both engines, carry checkpoint-compatible."""
    from repro.core.skr import SKRConfig, generate_dataset_chunked

    fam = get_family("poisson", nx=12, ny=12)
    kc = dataclasses.replace(CFG, tol=1e-9)
    key = jax.random.PRNGKey(7)
    base = generate_dataset_chunked(
        fam, key, 6, SKRConfig(krylov=kc, precond="jacobi"), workers=2,
        engine="batched")
    mixed = generate_dataset_chunked(
        fam, key, 6,
        SKRConfig(krylov=dataclasses.replace(kc, inner_dtype="float32"),
                  precond="jacobi"),
        workers=2, engine="batched")
    for cb, cm in zip(base, mixed):
        np.testing.assert_array_equal(cb.order, cm.order)
        assert cm.solutions.dtype == np.float64
        assert cm.stats.num_converged == len(cm.order)
        for pos in range(len(cb.order)):
            rel = (np.linalg.norm(cm.solutions[pos] - cb.solutions[pos])
                   / max(np.linalg.norm(cb.solutions[pos]), 1e-300))
            assert rel <= 1e-6, (pos, rel)
