"""End-to-end SKR datagen: dataset validity, fault-injection + warm resume
(recycle space survives), chunk-parallel decomposition (App. E.2.2)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.skr import (SKRConfig, SKRGenerator, generate_dataset,
                            generate_dataset_baseline,
                            generate_dataset_chunked)
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig

KC = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=6000)
CFG = SKRConfig(krylov=KC, precond="jacobi")


def test_datagen_produces_valid_dataset():
    fam = get_family("poisson", nx=16, ny=16)
    res = generate_dataset(fam, jax.random.PRNGKey(0), 6, CFG)
    assert res.inputs.shape == (6, 16, 16)
    assert res.solutions.shape == (6, 16, 16)
    assert np.isfinite(res.solutions).all()
    assert sorted(res.order.tolist()) == list(range(6))
    assert all(s.converged for s in res.stats.per_system)
    # every solution actually solves its system
    batch = fam.sample_batch(jax.random.PRNGKey(0), 6)
    from repro.core.skr import _problem_op_of

    for i in range(6):
        a = _problem_op_of(batch, i).to_dense()
        b = np.asarray(batch.b[i], dtype=np.float64).reshape(-1)
        r = np.linalg.norm(b - a @ res.solutions[i].reshape(-1))
        assert r <= KC.tol * np.linalg.norm(b) * 1.1


def test_solutions_independent_of_solve_order():
    """SKR (sorted) and GMRES (unsorted) datasets agree: sorting only
    reorders the WORK, never the (input → solution) pairing (App. E.3)."""
    fam = get_family("darcy", nx=12, ny=12)
    key = jax.random.PRNGKey(2)
    skr = generate_dataset(fam, key, 8, CFG)
    gm = generate_dataset_baseline(fam, key, 8, KC, precond="jacobi")
    np.testing.assert_allclose(skr.solutions, gm.solutions, rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(skr.inputs, gm.inputs, rtol=1e-12)


def test_fault_injection_and_warm_resume(tmp_path):
    """Preempt datagen mid-sequence; a rerun resumes from the checkpoint
    with the recycle space intact and produces the identical dataset."""
    fam = get_family("poisson", nx=14, ny=14)
    cfg = dataclasses.replace(CFG, ckpt_every=2)
    key = jax.random.PRNGKey(1)

    ref = generate_dataset(fam, key, 8, cfg)  # uninterrupted reference

    gen = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="injected datagen fault"):
        gen.generate(key, 8, fail_at=5)
    # restart: resumes at system 5 (not 0) and finishes
    progress = []
    res = SKRGenerator(fam, cfg, ckpt_dir=str(tmp_path)).generate(
        key, 8, progress_cb=lambda p, n: progress.append(p))
    assert progress[0] > 1, "resume must skip completed systems"
    np.testing.assert_allclose(res.solutions, ref.solutions, rtol=1e-6,
                               atol=1e-9)


def test_chunked_parallel_equivalence():
    """App. E.2.2: chunked workers produce the same solutions as the
    single-worker sequence (chunks only affect recycling warm-ups)."""
    fam = get_family("poisson", nx=12, ny=12)
    key = jax.random.PRNGKey(3)
    whole = generate_dataset(fam, key, 8, CFG)
    chunks = generate_dataset_chunked(fam, key, 8, CFG, workers=2)
    assert len(chunks) == 2
    got = {}
    for ch in chunks:
        for pos, i in enumerate(ch.order.tolist()):
            got[i] = ch.solutions[pos]
    for i in range(8):
        np.testing.assert_allclose(got[i], whole.solutions[i], rtol=1e-5,
                                   atol=1e-8)


def test_sorting_reduces_chain_length_in_pipeline():
    fam = get_family("helmholtz", nx=12, ny=12)
    res_sorted = generate_dataset(fam, jax.random.PRNGKey(0), 12, CFG)
    res_none = generate_dataset(
        fam, jax.random.PRNGKey(0), 12,
        dataclasses.replace(CFG, sort_method="none"))
    assert res_sorted.chain_len <= res_none.chain_len


def test_recycle_snapshots_recorded():
    fam = get_family("poisson", nx=12, ny=12)
    cfg = dataclasses.replace(CFG, record_recycle=True)
    res = generate_dataset(fam, jax.random.PRNGKey(0), 4, cfg)
    assert len(res.recycle_snapshots) >= 3
    idx, u = res.recycle_snapshots[-1]
    assert u.shape[0] == 144 and u.shape[1] <= KC.k
