"""Trainer fault tolerance: fail-inject → restart → identical final state;
microbatch accumulation; straggler-drop semantics of GradAccumulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optim import GradAccumulator, adamw, sgd_fallback
from repro.train.trainer import Trainer, TrainerConfig


def _quadratic_setup(seed=0):
    w_true = jnp.asarray(np.random.default_rng(seed).standard_normal(8))

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    def batches(i):
        rng = np.random.default_rng(1000 + i)  # deterministic per step
        x = jnp.asarray(rng.standard_normal((16, 8)))
        return {"x": x, "y": x @ w_true}

    return loss_fn, batches


def test_loss_decreases():
    loss_fn, batches = _quadratic_setup()
    tr = Trainer(loss_fn, {"w": jnp.zeros(8)}, optimizer=adamw(1e-2),
                 cfg=TrainerConfig(log_every=0))
    _, hist = tr.run(batches, 100)
    assert hist[-1] < hist[0] * 0.05


def test_fault_restart_matches_uninterrupted(tmp_path):
    """Crash at step 7, restart from checkpoint, finish — final params must
    be bitwise-identical to an uninterrupted run (deterministic data)."""
    loss_fn, batches = _quadratic_setup()

    def make(ckpt):
        return Trainer(loss_fn, {"w": jnp.zeros(8)}, optimizer=adamw(1e-2),
                       cfg=TrainerConfig(ckpt_dir=ckpt, ckpt_every=5,
                                         log_every=0))

    ref = make(str(tmp_path / "ref"))
    ref_state, _ = ref.run(batches, 20)

    crashy = make(str(tmp_path / "crash"))
    with pytest.raises(RuntimeError, match="injected fault"):
        crashy.run(batches, 20, fail_at=7)

    resumed = make(str(tmp_path / "crash"))
    step = resumed.maybe_resume()
    assert step == 5, "must resume from the step-5 checkpoint"
    final, _ = resumed.run(batches, 20)
    np.testing.assert_array_equal(np.asarray(final["params"]["w"]),
                                  np.asarray(ref_state["params"]["w"]))
    np.testing.assert_array_equal(np.asarray(final["opt"]["mu"]["w"]),
                                  np.asarray(ref_state["opt"]["mu"]["w"]))


def test_microbatch_accumulation_matches_full_batch():
    """nmicro=4 mean-of-microbatch-grads == full-batch grad for linear
    losses in grads (MSE): final params should match closely."""
    loss_fn, batches = _quadratic_setup()
    outs = []
    for micro in (1, 4):
        tr = Trainer(loss_fn, {"w": jnp.zeros(8)},
                     optimizer=sgd_fallback(0.05),
                     cfg=TrainerConfig(micro_batches=micro, log_every=0))
        state, _ = tr.run(batches, 30)
        outs.append(np.asarray(state["params"]["w"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)


def test_straggler_drop_threshold():
    """GradAccumulator: below-threshold arrivals raise; above-threshold
    averages over the arrived subset only."""
    def grad_fn(params, mb):
        return {"g": jnp.full(3, float(mb))}

    acc = GradAccumulator(num_micro=4, threshold=0.5)
    grads, n = acc.run(grad_fn, {}, [1.0, 2.0, 3.0, 4.0],
                       arrived_mask=[True, True, True, False])
    assert n == 3
    np.testing.assert_allclose(np.asarray(grads["g"]), np.full(3, 2.0))

    with pytest.raises(RuntimeError, match="microbatches arrived"):
        acc.run(grad_fn, {}, [1.0, 2.0, 3.0, 4.0],
                arrived_mask=[True, False, False, False])


def test_trainer_runs_under_mesh():
    """Single-device 'mesh' path: pjit-partitioned step executes."""
    loss_fn, batches = _quadratic_setup()
    mesh = jax.make_mesh((1,), ("data",))
    tr = Trainer(loss_fn, {"w": jnp.zeros(8)}, optimizer=adamw(1e-2),
                 cfg=TrainerConfig(log_every=0), mesh=mesh)
    _, hist = tr.run(batches, 20)
    assert hist[-1] < hist[0]
