"""Checkpoint manager: bitwise roundtrip, atomic publish, retention,
mesh-agnostic restore (fault-tolerance substrate, DESIGN §5)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))},
                "step": jnp.asarray(7, jnp.int32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_bitwise(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(7, state)
    like = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, step = mgr.restore(like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, _state(s))
    assert mgr.latest_step() == 40
    names = sorted(os.listdir(tmp_path))
    assert "step_40" in names and "step_30" in names
    assert "step_10" not in names and "step_20" not in names


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())


def test_partial_write_is_invisible(tmp_path):
    """A crashed writer (tmp dir without manifest rename) must not be
    picked up as latest — the atomic-publish contract."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state())
    # simulate a torn write: directory without MANIFEST.json
    os.makedirs(tmp_path / "step_9")
    (tmp_path / "step_9" / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 5
    restored, step = mgr.restore(_state())
    assert step == 5


def test_shape_mismatch_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((8, 8))})


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore re-shards onto a different device layout (elastic rescale):
    arrays come back with the requested shardings."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(3, state)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]
