"""Time-dependent trajectory subsystem: θ-scheme correctness (order of
accuracy vs the exact heat-equation decay), recycled-vs-cold per-step
solution equivalence, lockstep-vs-sequential trajectory equivalence with
padding, checkpoint/resume, and the registry plumbing — the trajectory-level
extension of the tests/test_batched_solver.py patterns."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.trajectory import (TrajConfig, TrajectoryGenerator,
                                   generate_trajectories,
                                   generate_trajectories_baseline,
                                   generate_trajectories_chunked,
                                   march_trajectory)
from repro.pde.dia import stencil5_matvec
from repro.pde.registry import (get_timedep_family, list_timedep_families)
from repro.pde.timedep import HeatTimeFamily, TrajectorySpec
from repro.solvers.types import KrylovConfig

# same budget rationale as test_batched_solver.KC: tol 1e-9 keeps the
# batched-vs-sequential float-reassociation drift under the 1e-8 assertions
KC = KrylovConfig(m=30, k=10, tol=1e-9, maxiter=6000)
CFG = TrajConfig(krylov=KC, precond="jacobi")


# ------------------------------------------------------------------ registry

def test_registry_lists_timedep_families():
    fams = list_timedep_families()
    assert "heat" in fams and "convdiff-t" in fams
    for name in fams:
        fam = get_timedep_family(name, nx=8, ny=8, nt=2)
        assert fam.nt == 2 and fam.nx == 8
    with pytest.raises(KeyError):
        get_timedep_family("nope")


# ----------------------------------------------------------- θ-scheme order

def _eig_decay_error(theta: float, nt: int, t_end: float, nx: int = 12):
    """March the σ=0 heat family (K ≡ 1 ⇒ L is the exact 5-point Laplacian)
    from a discrete Laplacian EIGENVECTOR IC and return the error of the
    final field against the exact semi-discrete decay e^{−λT} v."""
    fam = HeatTimeFamily(nx=nx, ny=nx, nt=nt, dt=t_end / nt, theta=theta,
                         sigma=0.0)
    h = 1.0 / (nx + 1)
    x = h * jnp.arange(1, nx + 1, dtype=jnp.float64)
    v = jnp.sin(jnp.pi * x)[:, None] * jnp.sin(jnp.pi * x)[None, :]
    lam = 2.0 * (4.0 / h**2) * np.sin(np.pi * h / 2.0) ** 2

    spec = fam.sample_spec(jax.random.PRNGKey(0))
    spec = dataclasses.replace(spec, u0=v)
    cfg = TrajConfig(krylov=dataclasses.replace(KC, tol=1e-12),
                     precond="jacobi")
    traj, stats = march_trajectory(fam, spec, cfg)
    assert stats.num_converged == nt

    # the θ-scheme ON an eigenvector is exactly ρ^nt with
    # ρ = (1 − (1−θ)Δtλ) / (1 + θΔtλ) — pin the assembled stepper to it
    dt = t_end / nt
    rho = (1.0 - (1.0 - theta) * dt * lam) / (1.0 + theta * dt * lam)
    np.testing.assert_allclose(traj[-1], rho**nt * np.asarray(v),
                               rtol=1e-7, atol=1e-10)

    exact = np.exp(-lam * t_end) * np.asarray(v)
    return float(np.linalg.norm(traj[-1] - exact))


@pytest.mark.parametrize("theta,expected_order", [(1.0, 1), (0.5, 2)])
def test_theta_scheme_order_of_accuracy(theta, expected_order):
    """Halving Δt divides the temporal error by ~2 (backward Euler) or ~4
    (Crank–Nicolson) against the exact heat-equation decay."""
    t_end = 0.05
    e1 = _eig_decay_error(theta, nt=4, t_end=t_end)
    e2 = _eig_decay_error(theta, nt=8, t_end=t_end)
    ratio = e1 / max(e2, 1e-300)
    lo, hi = (1.6, 2.6) if expected_order == 1 else (3.2, 5.2)
    assert lo <= ratio <= hi, (theta, e1, e2, ratio)


# ----------------------------------------------------- dataset + step validity

@pytest.mark.parametrize("name", ["heat", "convdiff-t"])
def test_trajectories_solve_their_step_systems(name):
    """Every emitted field actually satisfies its implicit-step linear
    system to solver tolerance (the trajectory analogue of the SKR
    dataset-validity test)."""
    fam = get_timedep_family(name, nx=10, ny=10, nt=3)
    res = generate_trajectories(fam, jax.random.PRNGKey(0), 3, CFG)
    assert res.trajectories.shape == (3, 4, 10, 10)
    assert np.isfinite(res.trajectories).all()
    assert res.stats.num_converged == res.stats.num == 9
    assert sorted(res.order.tolist()) == [0, 1, 2]

    specs = fam.sample_specs(jax.random.PRNGKey(0), 3)
    step1 = fam.step_fn()
    for i in range(3):
        lat = jax.tree_util.tree_map(lambda a: a[i], specs.latent)
        np.testing.assert_array_equal(res.trajectories[i, 0],
                                      np.asarray(specs.u0[i]))
        for s in range(fam.nt):
            u_prev = jnp.asarray(res.trajectories[i, s])
            a, b = step1(lat, u_prev, s * fam.dt, (s + 1) * fam.dt)
            r = np.asarray(b) - np.asarray(
                stencil5_matvec(a, jnp.asarray(res.trajectories[i, s + 1])))
            assert (np.linalg.norm(r)
                    <= KC.tol * np.linalg.norm(np.asarray(b)) * 1.1), (i, s)


@pytest.mark.parametrize("name", ["heat", "convdiff-t"])
def test_recycled_matches_cold_start_per_step(name):
    """Recycling changes the WORK, never the solutions: per-step fields from
    the GCRO-DR carry chain match the cold-start GMRES baseline to solver
    tolerance, at no more total Krylov iterations."""
    fam = get_timedep_family(name, nx=10, ny=10, nt=4)
    key = jax.random.PRNGKey(1)
    rec = generate_trajectories(fam, key, 3, CFG)
    cold = generate_trajectories_baseline(fam, key, 3, KC, precond="jacobi")
    for i in range(3):
        for s in range(fam.nt + 1):
            a, b = rec.trajectories[i, s], cold.trajectories[i, s]
            rel = np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-300)
            assert rel <= 1e-6, (i, s, rel)
    # the recycling win (strict win asserted in benchmarks/trajectory_recycle
    # at scale; tiny grids only guarantee "no worse" modulo warm-start QR)
    assert (rec.stats.total_iterations
            <= cold.stats.total_iterations + fam.nt)


# --------------------------------------------------- lockstep engine parity

@pytest.mark.parametrize("name", ["heat", "convdiff-t"])
def test_lockstep_matches_sequential_with_padding(name):
    """batched == sequential chunked engine per trajectory slot, with a
    worker count that does NOT divide num (uneven chunks exercise the
    zero-RHS padding rows)."""
    fam = get_timedep_family(name, nx=10, ny=10, nt=3)
    key = jax.random.PRNGKey(2)
    seq = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="sequential")
    bat = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="batched")
    assert len(seq) == len(bat) == 2
    assert {len(c.order) for c in seq} == {2, 3}
    for cs, cb in zip(seq, bat):
        np.testing.assert_array_equal(cs.order, cb.order)
        assert cb.stats.num_converged == len(cb.order) * fam.nt
        for pos in range(len(cs.order)):
            rel = (np.linalg.norm(cb.trajectories[pos] - cs.trajectories[pos])
                   / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
            assert rel <= 1e-8, (pos, rel)


def test_chunked_workers1_bitwise_stable():
    """workers=1 routes through the per-trajectory sequential loop and is
    BITWISE identical to the plain generator on the same key."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3)
    key = jax.random.PRNGKey(3)
    whole = generate_trajectories(fam, key, 4, CFG)
    chunks = generate_trajectories_chunked(fam, key, 4, CFG, workers=1)
    assert len(chunks) == 1
    ch = chunks[0]
    np.testing.assert_array_equal(ch.order, whole.order)
    for pos, i in enumerate(ch.order.tolist()):
        np.testing.assert_array_equal(ch.trajectories[pos],
                                      whole.trajectories[i])


# ------------------------------------------------------------ rhs + resume

def test_increment_rhs_mode_matches_full():
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3)
    key = jax.random.PRNGKey(4)
    full = generate_trajectories(fam, key, 2, CFG)
    inc = generate_trajectories(fam, key, 2,
                                dataclasses.replace(CFG,
                                                    rhs_mode="increment"))
    rel = (np.linalg.norm(full.trajectories - inc.trajectories)
           / np.linalg.norm(full.trajectories))
    assert rel <= 1e-6, rel


def test_fault_injection_and_warm_resume(tmp_path):
    """Preempt datagen mid-sequence (unit = trajectories); a rerun resumes
    from the checkpoint — recycle space intact — and the result is bitwise
    identical to an uninterrupted run."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3)
    cfg = dataclasses.replace(CFG, ckpt_every=1)
    key = jax.random.PRNGKey(5)
    gen = TrajectoryGenerator(fam, cfg, ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="injected"):
        gen.generate(key, 4, fail_at=2)
    progress = []
    res = TrajectoryGenerator(fam, cfg, ckpt_dir=str(tmp_path)).generate(
        key, 4, progress_cb=lambda p, n: progress.append(p))
    assert progress[0] > 1, "resume must skip completed trajectories"
    plain = generate_trajectories(fam, key, 4, CFG)
    np.testing.assert_array_equal(res.trajectories, plain.trajectories)


# ----------------------------------------------------------------- families

def test_trajectory_spec_shapes():
    for name in list_timedep_families():
        fam = get_timedep_family(name, nx=8, ny=8, nt=2)
        specs = fam.sample_specs(jax.random.PRNGKey(0), 3)
        assert isinstance(specs, TrajectorySpec)
        assert specs.u0.shape == (3, 8, 8)
        assert specs.no_input.shape == (3, 8, 8)
        assert specs.features.ndim == 2 and specs.features.shape[0] == 3
        a, b = fam.step_fn()(
            jax.tree_util.tree_map(lambda x: x[0], specs.latent),
            specs.u0[0], 0.0, fam.dt)
        assert a.shape == (5, 8, 8) and b.shape == (8, 8)
        assert jnp.isfinite(a).all() and jnp.isfinite(b).all()


def test_heat_stencil_is_spd_shifted():
    """A = I + θΔt L must keep a positive diagonal and weak diagonal
    dominance (M-matrix shifted by identity) — the conditioning story the
    θ-scheme module docstring sells."""
    fam = get_timedep_family("heat", nx=8, ny=8, nt=2)
    specs = fam.sample_specs(jax.random.PRNGKey(0), 1)
    lat = jax.tree_util.tree_map(lambda x: x[0], specs.latent)
    a, _ = fam.step_fn()(lat, specs.u0[0], 0.0, fam.dt)
    a = np.asarray(a)
    assert (a[0] > 0).all()                      # center
    off_sum = np.abs(a[1:]).sum(axis=0)
    assert (a[0] >= off_sum - 1e-9).all()        # diagonal dominance
