"""Time-dependent trajectory subsystem: θ-scheme correctness (order of
accuracy vs the exact heat-equation decay), recycled-vs-cold per-step
solution equivalence, lockstep-vs-sequential trajectory equivalence with
padding, checkpoint/resume, and the registry plumbing — the trajectory-level
extension of the tests/test_batched_solver.py patterns.

Stepping-stack coverage (PR 5): BDF2 2nd-order convergence, mass-matrix-
aware step assembly (M + βΔtL, dense oracle), wave-family energy
boundedness, adaptive-Δt efficiency vs fixed stepping, phase-masked
adaptive lockstep == sequential (fp64 and fp32-inner), and a bitwise anchor
pinning the classic fixed-Δt path to the pre-stack marching loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sorting import sort_features
from repro.core.trajectory import (TrajConfig, TrajectoryGenerator,
                                   generate_trajectories,
                                   generate_trajectories_baseline,
                                   generate_trajectories_chunked,
                                   march_trajectory)
from repro.pde.dia import Stencil5, stencil5_matvec
from repro.pde.registry import (get_timedep_family, list_timedep_families)
from repro.pde.timedep import (AdaptConfig, HeatTimeFamily, MassMatrix,
                               TrajectorySpec, WaveTimeFamily,
                               assemble_diffusion_stencil, quantize_sig)
from repro.solvers.gcrodr import GCRODRSolver
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import make_preconditioner
from repro.solvers.types import KrylovConfig

# same budget rationale as test_batched_solver.KC: tol 1e-9 keeps the
# batched-vs-sequential float-reassociation drift under the 1e-8 assertions
KC = KrylovConfig(m=30, k=10, tol=1e-9, maxiter=6000)
CFG = TrajConfig(krylov=KC, precond="jacobi")


# ------------------------------------------------------------------ registry

def test_registry_lists_timedep_families():
    fams = list_timedep_families()
    assert "heat" in fams and "convdiff-t" in fams
    for name in fams:
        fam = get_timedep_family(name, nx=8, ny=8, nt=2)
        assert fam.nt == 2 and fam.nx == 8
    with pytest.raises(KeyError):
        get_timedep_family("nope")


# ----------------------------------------------------------- θ-scheme order

def _eig_decay_error(theta: float, nt: int, t_end: float, nx: int = 12):
    """March the σ=0 heat family (K ≡ 1 ⇒ L is the exact 5-point Laplacian)
    from a discrete Laplacian EIGENVECTOR IC and return the error of the
    final field against the exact semi-discrete decay e^{−λT} v."""
    fam = HeatTimeFamily(nx=nx, ny=nx, nt=nt, dt=t_end / nt, theta=theta,
                         sigma=0.0)
    h = 1.0 / (nx + 1)
    x = h * jnp.arange(1, nx + 1, dtype=jnp.float64)
    v = jnp.sin(jnp.pi * x)[:, None] * jnp.sin(jnp.pi * x)[None, :]
    lam = 2.0 * (4.0 / h**2) * np.sin(np.pi * h / 2.0) ** 2

    spec = fam.sample_spec(jax.random.PRNGKey(0))
    spec = dataclasses.replace(spec, u0=v)
    cfg = TrajConfig(krylov=dataclasses.replace(KC, tol=1e-12),
                     precond="jacobi")
    traj, stats = march_trajectory(fam, spec, cfg)
    assert stats.num_converged == nt

    # the θ-scheme ON an eigenvector is exactly ρ^nt with
    # ρ = (1 − (1−θ)Δtλ) / (1 + θΔtλ) — pin the assembled stepper to it
    dt = t_end / nt
    rho = (1.0 - (1.0 - theta) * dt * lam) / (1.0 + theta * dt * lam)
    np.testing.assert_allclose(traj[-1], rho**nt * np.asarray(v),
                               rtol=1e-7, atol=1e-10)

    exact = np.exp(-lam * t_end) * np.asarray(v)
    return float(np.linalg.norm(traj[-1] - exact))


@pytest.mark.parametrize("theta,expected_order", [(1.0, 1), (0.5, 2)])
def test_theta_scheme_order_of_accuracy(theta, expected_order):
    """Halving Δt divides the temporal error by ~2 (backward Euler) or ~4
    (Crank–Nicolson) against the exact heat-equation decay."""
    t_end = 0.05
    e1 = _eig_decay_error(theta, nt=4, t_end=t_end)
    e2 = _eig_decay_error(theta, nt=8, t_end=t_end)
    ratio = e1 / max(e2, 1e-300)
    lo, hi = (1.6, 2.6) if expected_order == 1 else (3.2, 5.2)
    assert lo <= ratio <= hi, (theta, e1, e2, ratio)


# ----------------------------------------------------- dataset + step validity

@pytest.mark.parametrize("name", ["heat", "convdiff-t"])
def test_trajectories_solve_their_step_systems(name):
    """Every emitted field actually satisfies its implicit-step linear
    system to solver tolerance (the trajectory analogue of the SKR
    dataset-validity test)."""
    fam = get_timedep_family(name, nx=10, ny=10, nt=3)
    res = generate_trajectories(fam, jax.random.PRNGKey(0), 3, CFG)
    assert res.trajectories.shape == (3, 4, 10, 10)
    assert np.isfinite(res.trajectories).all()
    assert res.stats.num_converged == res.stats.num == 9
    assert sorted(res.order.tolist()) == [0, 1, 2]

    specs = fam.sample_specs(jax.random.PRNGKey(0), 3)
    step1 = fam.step_fn()
    for i in range(3):
        lat = jax.tree_util.tree_map(lambda a: a[i], specs.latent)
        np.testing.assert_array_equal(res.trajectories[i, 0],
                                      np.asarray(specs.u0[i]))
        for s in range(fam.nt):
            u_prev = jnp.asarray(res.trajectories[i, s])
            a, b = step1(lat, u_prev, s * fam.dt, (s + 1) * fam.dt)
            r = np.asarray(b) - np.asarray(
                stencil5_matvec(a, jnp.asarray(res.trajectories[i, s + 1])))
            assert (np.linalg.norm(r)
                    <= KC.tol * np.linalg.norm(np.asarray(b)) * 1.1), (i, s)


@pytest.mark.parametrize("name", ["heat", "convdiff-t"])
def test_recycled_matches_cold_start_per_step(name):
    """Recycling changes the WORK, never the solutions: per-step fields from
    the GCRO-DR carry chain match the cold-start GMRES baseline to solver
    tolerance, at no more total Krylov iterations."""
    fam = get_timedep_family(name, nx=10, ny=10, nt=4)
    key = jax.random.PRNGKey(1)
    rec = generate_trajectories(fam, key, 3, CFG)
    cold = generate_trajectories_baseline(fam, key, 3, KC, precond="jacobi")
    for i in range(3):
        for s in range(fam.nt + 1):
            a, b = rec.trajectories[i, s], cold.trajectories[i, s]
            rel = np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-300)
            assert rel <= 1e-6, (i, s, rel)
    # the recycling win (strict win asserted in benchmarks/trajectory_recycle
    # at scale; tiny grids only guarantee "no worse" modulo warm-start QR)
    assert (rec.stats.total_iterations
            <= cold.stats.total_iterations + fam.nt)


# --------------------------------------------------- lockstep engine parity

@pytest.mark.parametrize("name", ["heat", "convdiff-t"])
def test_lockstep_matches_sequential_with_padding(name):
    """batched == sequential chunked engine per trajectory slot, with a
    worker count that does NOT divide num (uneven chunks exercise the
    zero-RHS padding rows)."""
    fam = get_timedep_family(name, nx=10, ny=10, nt=3)
    key = jax.random.PRNGKey(2)
    seq = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="sequential")
    bat = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="batched")
    assert len(seq) == len(bat) == 2
    assert {len(c.order) for c in seq} == {2, 3}
    for cs, cb in zip(seq, bat):
        np.testing.assert_array_equal(cs.order, cb.order)
        assert cb.stats.num_converged == len(cb.order) * fam.nt
        for pos in range(len(cs.order)):
            rel = (np.linalg.norm(cb.trajectories[pos] - cs.trajectories[pos])
                   / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
            assert rel <= 1e-8, (pos, rel)


def test_chunked_workers1_bitwise_stable():
    """workers=1 routes through the per-trajectory sequential loop and is
    BITWISE identical to the plain generator on the same key."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3)
    key = jax.random.PRNGKey(3)
    whole = generate_trajectories(fam, key, 4, CFG)
    chunks = generate_trajectories_chunked(fam, key, 4, CFG, workers=1)
    assert len(chunks) == 1
    ch = chunks[0]
    np.testing.assert_array_equal(ch.order, whole.order)
    for pos, i in enumerate(ch.order.tolist()):
        np.testing.assert_array_equal(ch.trajectories[pos],
                                      whole.trajectories[i])


# ------------------------------------------------------------ rhs + resume

def test_increment_rhs_mode_matches_full():
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3)
    key = jax.random.PRNGKey(4)
    full = generate_trajectories(fam, key, 2, CFG)
    inc = generate_trajectories(fam, key, 2,
                                dataclasses.replace(CFG,
                                                    rhs_mode="increment"))
    rel = (np.linalg.norm(full.trajectories - inc.trajectories)
           / np.linalg.norm(full.trajectories))
    assert rel <= 1e-6, rel


def test_fault_injection_and_warm_resume(tmp_path):
    """Preempt datagen mid-sequence (unit = trajectories); a rerun resumes
    from the checkpoint — recycle space intact — and the result is bitwise
    identical to an uninterrupted run."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3)
    cfg = dataclasses.replace(CFG, ckpt_every=1)
    key = jax.random.PRNGKey(5)
    gen = TrajectoryGenerator(fam, cfg, ckpt_dir=str(tmp_path))
    with pytest.raises(RuntimeError, match="injected"):
        gen.generate(key, 4, fail_at=2)
    progress = []
    res = TrajectoryGenerator(fam, cfg, ckpt_dir=str(tmp_path)).generate(
        key, 4, progress_cb=lambda p, n: progress.append(p))
    assert progress[0] > 1, "resume must skip completed trajectories"
    plain = generate_trajectories(fam, key, 4, CFG)
    np.testing.assert_array_equal(res.trajectories, plain.trajectories)


# ----------------------------------------------------------------- families

def test_trajectory_spec_shapes():
    for name in list_timedep_families():
        fam = get_timedep_family(name, nx=8, ny=8, nt=2)
        specs = fam.sample_specs(jax.random.PRNGKey(0), 3)
        assert isinstance(specs, TrajectorySpec)
        assert specs.u0.shape == (3, 8, 8)
        assert specs.no_input.shape == (3, 8, 8)
        assert specs.features.ndim == 2 and specs.features.shape[0] == 3
        a, b = fam.step_fn()(
            jax.tree_util.tree_map(lambda x: x[0], specs.latent),
            specs.u0[0], 0.0, fam.dt)
        assert a.shape == (5, 8, 8) and b.shape == (8, 8)
        assert jnp.isfinite(a).all() and jnp.isfinite(b).all()


def test_heat_stencil_is_spd_shifted():
    """A = I + θΔt L must keep a positive diagonal and weak diagonal
    dominance (M-matrix shifted by identity) — the conditioning story the
    θ-scheme module docstring sells."""
    fam = get_timedep_family("heat", nx=8, ny=8, nt=2)
    specs = fam.sample_specs(jax.random.PRNGKey(0), 1)
    lat = jax.tree_util.tree_map(lambda x: x[0], specs.latent)
    a, _ = fam.step_fn()(lat, specs.u0[0], 0.0, fam.dt)
    a = np.asarray(a)
    assert (a[0] > 0).all()                      # center
    off_sum = np.abs(a[1:]).sum(axis=0)
    assert (a[0] >= off_sum - 1e-9).all()        # diagonal dominance


# ======================================================== stepping stack


def _eig_ic(nx):
    """Lowest discrete-Laplacian eigenvector + its decay rate."""
    h = 1.0 / (nx + 1)
    x = h * jnp.arange(1, nx + 1, dtype=jnp.float64)
    v = jnp.sin(jnp.pi * x)[:, None] * jnp.sin(jnp.pi * x)[None, :]
    lam = 2.0 * (4.0 / h**2) * np.sin(np.pi * h / 2.0) ** 2
    return v, lam


def _bdf2_decay_error(nt: int, t_end: float = 0.05, nx: int = 12) -> float:
    """σ=0 heat family under BDF2 (CN bootstrap) from an eigenvector IC:
    error of the final field against the exact semi-discrete decay."""
    fam = HeatTimeFamily(nx=nx, ny=nx, nt=nt, dt=t_end / nt, theta=0.5,
                         sigma=0.0, integrator="bdf2")
    assert not fam.classic and fam.order == 2
    v, lam = _eig_ic(nx)
    spec = dataclasses.replace(fam.sample_spec(jax.random.PRNGKey(0)), u0=v)
    cfg = TrajConfig(krylov=dataclasses.replace(KC, tol=1e-12),
                     precond="jacobi")
    traj, stats = march_trajectory(fam, spec, cfg)
    assert stats.num_converged == nt
    exact = np.exp(-lam * t_end) * np.asarray(v)
    return float(np.linalg.norm(traj[-1] - exact))


def test_bdf2_second_order_convergence():
    """Halving Δt divides the BDF2 temporal error by ~4 against the exact
    heat-equation decay (the order-2 extension of the θ-order test)."""
    e1 = _bdf2_decay_error(nt=8)
    e2 = _bdf2_decay_error(nt=16)
    ratio = e1 / max(e2, 1e-300)
    assert 3.0 <= ratio <= 5.2, (e1, e2, ratio)


# ------------------------------------------------------------ mass matrices

class _MassHeat(HeatTimeFamily):
    """Heat family with the compact mass matrix — exercises the generic
    M + βΔtL step assembly (wave has its own specialized elimination)."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self._m = MassMatrix.compact(self.nx, self.ny)

    def mass(self):
        return self._m


def test_mass_matrix_compact_is_spd_dominant():
    m = MassMatrix.compact(9, 9)
    c = np.asarray(m.coeffs)
    assert (c[0] > 0).all()
    assert (c[0] >= np.abs(c[1:]).sum(axis=0) - 1e-12).all()
    dense = m.to_dia().to_dense()
    np.testing.assert_allclose(dense, dense.T, atol=1e-14)
    w = np.linalg.eigvalsh(dense)
    assert w.min() > 0.3 and w.max() < 1.0 + 1e-12
    # Stencil5 and DIA exports agree
    np.testing.assert_array_equal(dense, m.as_stencil5().to_dense())
    ident = MassMatrix.identity(5, 5)
    np.testing.assert_array_equal(ident.to_dia().to_dense(), np.eye(25))


def test_mass_aware_step_matches_dense_oracle():
    """The generalized θ-step with M ≠ I assembles exactly
    A = M + θΔtL(t+Δt), b = M u (zero source, backward Euler) — pinned
    against dense algebra."""
    fam = _MassHeat(nx=8, ny=8, nt=2, dt=3e-3, theta=1.0)
    assert not fam.classic
    spec = fam.sample_spec(jax.random.PRNGKey(1))
    state = fam.init_state(spec)
    a, b = fam.build_fn()(spec.latent, state, 0.0, fam.dt, fam.dt, True)
    m_dense = fam.mass().to_dia().to_dense()
    l_dense = Stencil5(fam.spatial_coeffs(spec.latent, fam.dt)).to_dense()
    np.testing.assert_allclose(Stencil5(a).to_dense(),
                               m_dense + fam.dt * l_dense,
                               rtol=1e-13, atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(b).reshape(-1),
        m_dense @ np.asarray(spec.u0).reshape(-1), rtol=1e-13, atol=1e-13)


def test_mass_family_lockstep_matches_sequential():
    """Generic mass-matrix stepping through the full engines: batched ==
    sequential per trajectory slot (uneven chunks → padding)."""
    fam = _MassHeat(nx=10, ny=10, nt=3, dt=3e-3)
    key = jax.random.PRNGKey(8)
    seq = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="sequential")
    bat = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="batched")
    for cs, cb in zip(seq, bat):
        np.testing.assert_array_equal(cs.order, cb.order)
        for pos in range(len(cs.order)):
            rel = (np.linalg.norm(cb.trajectories[pos] - cs.trajectories[pos])
                   / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
            assert rel <= 1e-8, (pos, rel)


# ------------------------------------------------------------- wave family

def _march_states_dense(fam, spec, nsteps):
    """March the generalized stack with DENSE solves (exact linear algebra,
    no Krylov noise) — the integrator-property oracle."""
    state = fam.init_state(spec)
    build1, eval1 = fam.build_fn(), fam.eval_fn()
    t, dt = 0.0, fam.dt
    states = [state]
    for i in range(nsteps):
        boot = i == 0
        a, b = build1(spec.latent, state, t, dt, dt, boot)
        x = np.linalg.solve(Stencil5(a).to_dense(),
                            np.asarray(b).reshape(-1)).reshape(fam.nx, fam.ny)
        state, _ = eval1(spec.latent, state, jnp.asarray(x), t, dt, dt, dt,
                         boot, i >= 2)
        t += dt
        states.append(state)
    return states


def test_wave_energy_bounded_over_rollout():
    """Discrete energy ½(vᵀMv + uᵀKu): conserved to ~machine precision by
    the trapezoid wave stepper, bounded (mildly dissipative) under BDF2."""
    for integrator, tol_growth in (("theta", 1e-10), ("bdf2", 1e-10)):
        fam = WaveTimeFamily(nx=12, ny=12, nt=25, dt=2e-3,
                             integrator=integrator)
        spec = fam.sample_spec(jax.random.PRNGKey(3))
        states = _march_states_dense(fam, spec, fam.nt)
        energies = [float(fam.energy(spec.latent, s)) for s in states]
        e0 = energies[0]
        assert e0 > 0.0
        assert max(energies) <= e0 * (1.0 + tol_growth), integrator
        # no spurious blow-down either: the rollout keeps real energy
        assert min(energies) >= e0 * (0.5 if integrator == "bdf2" else
                                      1.0 - 1e-10), integrator


def test_wave_trapezoid_energy_exact_conservation():
    fam = WaveTimeFamily(nx=10, ny=10, nt=30, dt=3e-3, theta=0.5)
    spec = fam.sample_spec(jax.random.PRNGKey(9))
    states = _march_states_dense(fam, spec, fam.nt)
    e = np.array([float(fam.energy(spec.latent, s)) for s in states])
    assert np.abs(e - e[0]).max() / e[0] <= 1e-9


def test_wave_family_registry_and_mass():
    fams = list_timedep_families()
    assert "wave" in fams
    fam = get_timedep_family("wave", nx=8, ny=8, nt=2)
    assert isinstance(fam, WaveTimeFamily)
    assert fam.mass() is not None and not fam.classic
    specs = fam.sample_specs(jax.random.PRNGKey(0), 3)
    assert specs.u0.shape == (3, 8, 8)
    res = generate_trajectories(fam, jax.random.PRNGKey(0), 2, CFG)
    assert res.trajectories.shape == (2, 3, 8, 8)
    assert np.isfinite(res.trajectories).all()
    assert res.stats.num_converged == res.stats.num == 4


def test_wave_lockstep_matches_sequential():
    fam = get_timedep_family("wave", nx=10, ny=10, nt=3, dt=2e-3)
    key = jax.random.PRNGKey(2)
    seq = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="sequential")
    bat = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="batched")
    for cs, cb in zip(seq, bat):
        np.testing.assert_array_equal(cs.order, cb.order)
        assert cs.stats.num == cb.stats.num
        for pos in range(len(cs.order)):
            rel = (np.linalg.norm(cb.trajectories[pos] - cs.trajectories[pos])
                   / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
            assert rel <= 1e-7, (pos, rel)


# ------------------------------------------------------------- adaptive Δt

class _DriftEquilibHeat(HeatTimeFamily):
    """Forced heat with a SHARP mid-window conductivity switch: u tracks the
    moving equilibrium L(t)⁻¹φ, so all the dynamics (and all the temporal
    error) concentrate in the switch window — the workload where adaptive
    stepping beats any uniform Δt."""

    def spatial_coeffs(self, latent, t):
        g0, g1 = latent
        s = jax.nn.sigmoid((t / self.t_end - 0.5) * 80.0)
        k = jnp.exp(self.sigma * ((1.0 - s) * g0 + s * g1))
        return assemble_diffusion_stencil(k, self.hx, self.hy)

    def source(self, latent, t):
        nx = self.nx
        h = 1.0 / (nx + 1)
        x = h * jnp.arange(1, nx + 1, dtype=jnp.float64)
        return 50.0 * jnp.sin(jnp.pi * x)[:, None] * jnp.sin(jnp.pi * x)[None, :]

    def sample_specs(self, key, num):
        keys = jax.random.split(key, num)
        specs = []
        for k in keys:
            sp = self.sample_spec(k)
            g0, _ = sp.latent
            a0 = assemble_diffusion_stencil(jnp.exp(self.sigma * g0),
                                            self.hx, self.hy)
            u0 = np.linalg.solve(
                Stencil5(a0).to_dense(),
                np.asarray(self.source(None, 0.0)).reshape(-1))
            specs.append(dataclasses.replace(
                sp, u0=jnp.asarray(u0.reshape(self.nx, self.ny))))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *specs)


def _drift_fam(nt, **kw):
    return _DriftEquilibHeat(nx=10, ny=10, nt=nt, dt=1.0 / nt, sigma=0.8,
                             **kw)


@pytest.mark.slow
def test_adaptive_beats_fixed_on_stiff_drift():
    """Adaptive Δt reaches a given accuracy with FEWER steps than uniform
    stepping on a stiff conductivity drift: at the adaptive run's accepted
    step count, fixed-Δt backward Euler has ≥2x the error (so matching the
    adaptive tolerance costs it strictly more steps)."""
    cfg = TrajConfig(krylov=dataclasses.replace(KC, tol=1e-10),
                     precond="jacobi")
    key = jax.random.PRNGKey(7)
    nref = 512
    ref = generate_trajectories(_drift_fam(nref, theta=0.5), key, 1, cfg)
    ridx = np.linspace(0, nref, 5).astype(int)
    refs = [ref.trajectories[0][i] for i in ridx]

    ra = generate_trajectories(
        _drift_fam(4, theta=1.0,
                   adapt=AdaptConfig(step_tol=1e-3, fac_max=6.0)),
        key, 1, cfg)
    na = ra.stats.num - ra.stats.num_rejected
    assert ra.stats.num_rejected >= 1          # the controller did reject
    assert na < 120                            # and did stretch steps
    err_a = max(np.linalg.norm(ra.trajectories[0][i] - refs[i])
                / np.linalg.norm(refs[i]) for i in range(1, 5))

    rf = generate_trajectories(_drift_fam(int(na), theta=1.0), key, 1, cfg)
    q = [int(round(f * na / 4)) for f in range(5)]
    err_f = max(np.linalg.norm(rf.trajectories[0][q[i]] - refs[i])
                / np.linalg.norm(refs[i]) for i in range(1, 5))
    assert err_f >= 2.0 * err_a, (na, err_a, err_f)


def test_adaptive_lockstep_matches_sequential():
    """Phase-masked adaptive lockstep == sequential per trajectory slot —
    identical Δt paths (quantized controller), identical solve/reject
    counts, solutions to tolerance. Uneven chunks exercise the zero-RHS
    phase padding."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3, dt=2e-2,
                             adapt=AdaptConfig(step_tol=2e-3))
    key = jax.random.PRNGKey(2)
    seq = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="sequential")
    bat = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="batched")
    assert {len(c.order) for c in bat} == {2, 3}
    for cs, cb in zip(seq, bat):
        np.testing.assert_array_equal(cs.order, cb.order)
        assert cs.stats.num == cb.stats.num
        assert cs.stats.num_rejected == cb.stats.num_rejected
        for pos in range(len(cs.order)):
            rel = (np.linalg.norm(cb.trajectories[pos] - cs.trajectories[pos])
                   / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
            assert rel <= 1e-7, (pos, rel)


def test_adaptive_lockstep_fp32_inner_matches_sequential_fp64():
    """The adaptive lockstep under inner_dtype="float32" still matches the
    fp64 sequential engine: labels are fp64 at tol, and the quantized
    controller absorbs the fp32 engine's (tol-level) solution noise, so
    even the step sequences agree."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3, dt=2e-2,
                             adapt=AdaptConfig(step_tol=2e-3))
    key = jax.random.PRNGKey(2)
    cfg32 = TrajConfig(krylov=dataclasses.replace(KC, inner_dtype="float32"),
                       precond="jacobi")
    seq = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="sequential")
    b32 = generate_trajectories_chunked(fam, key, 5, cfg32, workers=2,
                                        engine="batched")
    for cs, cb in zip(seq, b32):
        assert cs.stats.num == cb.stats.num
        assert cb.stats.total_outer_refinements >= 1
        for pos in range(len(cs.order)):
            rel = (np.linalg.norm(cb.trajectories[pos] - cs.trajectories[pos])
                   / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
            assert rel <= 1e-6, (pos, rel)


def test_adaptive_wave_lockstep_matches_sequential():
    """Adaptive Δt on the M ≠ I wave family: phase-masked lockstep ==
    sequential (fp64 engine and fp32-inner engine), identical step
    sequences — the acceptance-criteria pairing of adaptivity with the
    mass-matrix family."""
    fam = get_timedep_family("wave", nx=10, ny=10, nt=3, dt=5e-3,
                             adapt=AdaptConfig(step_tol=2e-3))
    key = jax.random.PRNGKey(3)
    cfg32 = TrajConfig(krylov=dataclasses.replace(KC, inner_dtype="float32"),
                       precond="jacobi")
    seq = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="sequential")
    bat = generate_trajectories_chunked(fam, key, 5, CFG, workers=2,
                                        engine="batched")
    b32 = generate_trajectories_chunked(fam, key, 5, cfg32, workers=2,
                                        engine="batched")
    for cs, cb, c3 in zip(seq, bat, b32):
        np.testing.assert_array_equal(cs.order, cb.order)
        assert cs.stats.num == cb.stats.num == c3.stats.num
        assert cs.stats.num_rejected == cb.stats.num_rejected
        for pos in range(len(cs.order)):
            nrm = max(np.linalg.norm(cs.trajectories[pos]), 1e-300)
            r64 = np.linalg.norm(cb.trajectories[pos]
                                 - cs.trajectories[pos]) / nrm
            r32 = np.linalg.norm(c3.trajectories[pos]
                                 - cs.trajectories[pos]) / nrm
            assert r64 <= 1e-7 and r32 <= 1e-6, (pos, r64, r32)


def test_adaptive_budget_exhaustion_freezes_consistently():
    """A trajectory that exhausts max_steps freezes (remaining save points
    repeat the last field) — identically in both engines."""
    fam = get_timedep_family("heat", nx=8, ny=8, nt=4, dt=2e-2,
                             adapt=AdaptConfig(step_tol=1e-4, max_steps=3))
    key = jax.random.PRNGKey(4)
    seq = generate_trajectories_chunked(fam, key, 3, CFG, workers=1)
    bat = generate_trajectories_chunked(fam, key, 3, CFG, workers=3,
                                        engine="batched")
    for cb in bat:
        for pos, i in enumerate(cb.order.tolist()):
            src = int(np.nonzero(seq[0].order == i)[0][0])
            np.testing.assert_allclose(cb.trajectories[pos],
                                       seq[0].trajectories[src],
                                       rtol=1e-7, atol=1e-12)
    # frozen tail: with a 3-solve budget against 4 required saves, every
    # trajectory ends in repeated (frozen) save points
    for tr in seq[0].trajectories:
        assert np.array_equal(tr[-1], tr[-2])


def test_controller_respects_dt_clamps():
    """The save-time stretch never violates dt_max, and a failing step
    already at the dt_min floor is accepted (no rejection death spiral)."""
    from repro.pde.timedep import PIStepController

    cfg = AdaptConfig(step_tol=1e-3, dt_min=1e-3, dt_max=5e-3)
    ctrl = PIStepController(cfg, order=1, dt0=5e-3)
    assert ctrl.propose(6e-3) == 5e-3     # remaining beyond cap: no stretch
    assert ctrl.propose(4.9e-3) == 4.9e-3  # within cap: land exactly
    ctrl.dt = cfg.dt_min
    assert ctrl.decide(1e-1, cfg.dt_min) is True   # floor accept
    assert ctrl.naccept == 1
    # and an accepted step's growth stays inside [dt_min, dt_max]
    assert cfg.dt_min <= ctrl.dt <= cfg.dt_max

    # a tiny save-boundary landing step must NOT collapse the controller:
    # growth resumes from the controller's own step, not the clip
    ctrl2 = PIStepController(cfg, order=1, dt0=5e-3)
    assert ctrl2.decide(1e-5, 5e-5) is True        # clipped landing accept
    assert ctrl2.dt == cfg.dt_max                  # straight back to cap
    assert ctrl2.dt_prev == 5e-5                   # BDF2 ρ uses actual step


def test_controller_never_reproposes_rejected_step():
    """Marginal-rejection livelock guard: after a rejection whose shrink
    factor exceeds 1/1.25, the save-boundary stretch must NOT re-propose
    the exact step size that was just rejected — the estimate is
    deterministic per position, so re-trying it can never succeed."""
    from repro.pde.timedep import PIStepController

    ctrl = PIStepController(AdaptConfig(step_tol=2e-3), order=2, dt0=2e-3)
    remaining = 2.1e-3
    dt1 = ctrl.propose(remaining)
    assert dt1 == remaining                      # stretched to the boundary
    assert ctrl.decide(2.2e-3, dt1) is False     # marginal reject (fac~0.87)
    dt2 = ctrl.propose(remaining)
    assert dt2 < dt1                             # never the rejected size
    assert ctrl.decide(1e-3, dt2) is True        # smaller step lands
    # rejection memory is cleared on accept: stretching works again
    assert ctrl.dt_bad == float("inf")


def test_wave_step_includes_forcing():
    """A wave subclass overriding source() gets the eliminated forcing term
    (θΔt²(θf_new + (1−θ)f_old)) in its step rhs — not silently dropped."""
    class _ForcedWave(WaveTimeFamily):
        def source(self, latent, t):
            return jnp.ones((self.nx, self.ny), jnp.float64)

    kw = dict(nx=6, ny=6, nt=2, dt=1e-2, theta=0.5)
    fam_f = _ForcedWave(**kw)
    fam_0 = WaveTimeFamily(**kw)
    spec = fam_f.sample_spec(jax.random.PRNGKey(0))
    state = fam_f.init_state(spec)
    a_f, b_f = fam_f.build_fn()(spec.latent, state, 0.0, 1e-2, 1e-2, True)
    a_0, b_0 = fam_0.build_fn()(spec.latent, state, 0.0, 1e-2, 1e-2, True)
    np.testing.assert_array_equal(np.asarray(a_f), np.asarray(a_0))
    np.testing.assert_allclose(np.asarray(b_f - b_0),
                               0.5 * 1e-4 * np.ones((6, 6)),
                               rtol=1e-10, atol=1e-15)


def test_quantize_sig():
    assert quantize_sig(0.123456) == 0.12
    assert quantize_sig(3.456e-7) == 3.5e-7
    assert quantize_sig(0.0) == 0.0
    assert quantize_sig(float("inf")) == float("inf")
    # the guard property: tol-level perturbations do not move the value
    assert quantize_sig(1.0000000012e-3) == quantize_sig(1.0e-3)


# ------------------------------------------------- classic-path bitwise pin

def test_classic_fixed_dt_path_bitwise_anchor():
    """The fixed-Δt θ-scheme path must stay BITWISE-identical to the
    original (pre-stepping-stack) marching loop: recompute the generator's
    output with a verbatim transcription of that loop and require exact
    equality. Reroute the classic path through the generalized stack and
    this fails."""
    fam = get_timedep_family("heat", nx=10, ny=10, nt=3)
    assert fam.classic
    key = jax.random.PRNGKey(6)
    res = generate_trajectories(fam, key, 3, CFG)

    specs = fam.sample_specs(key, 3)
    order = sort_features(np.asarray(specs.features), CFG.sort_method)
    np.testing.assert_array_equal(res.order, order)
    solver = GCRODRSolver(CFG.krylov, use_kernel=CFG.use_kernel)
    step1 = fam.step_fn()
    for i in order.tolist():
        lat = jax.tree_util.tree_map(lambda a: a[i], specs.latent)
        u = jnp.asarray(specs.u0[i])
        np.testing.assert_array_equal(res.trajectories[i, 0], np.asarray(u))
        for step in range(fam.nt):
            a, b = step1(lat, u, step * fam.dt, (step + 1) * fam.dt)
            st5 = Stencil5(a)
            pre = make_preconditioner(CFG.precond, st5,
                                      use_kernel=CFG.use_kernel)
            op = PreconditionedOp(StencilOp(st5.coeffs, CFG.use_kernel), pre)
            x, _ = solver.solve(op, np.asarray(b).reshape(-1))
            u = jnp.asarray(np.asarray(x).reshape(fam.nx, fam.ny))
            np.testing.assert_array_equal(res.trajectories[i, step + 1],
                                          np.asarray(u))
