"""Per-kernel validation: every Pallas kernel swept over shapes/dtypes in
interpret=True mode against the pure-jnp ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ------------------------------------------------------------ stencil5

@pytest.mark.parametrize("nx,ny", [(8, 8), (16, 32), (33, 17), (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_stencil5_kernel_matches_ref(nx, ny, dtype):
    key = jax.random.PRNGKey(nx * 100 + ny)
    coeffs = _rand(key, (5, nx, ny), dtype)
    x = _rand(jax.random.fold_in(key, 1), (nx, ny), dtype)
    got = ops.stencil5_matvec(coeffs, x, use_kernel=True, interpret=True)
    want = ref.stencil5_matvec(coeffs, x)
    tol = dict(rtol=1e-5, atol=1e-5) if dtype == jnp.float32 else \
        dict(rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


def test_stencil5_kernel_batched():
    key = jax.random.PRNGKey(0)
    coeffs = _rand(key, (3, 5, 16, 16), jnp.float64)
    x = _rand(jax.random.fold_in(key, 1), (3, 16, 16), jnp.float64)
    got = ops.stencil5_matvec(coeffs, x, use_kernel=True, interpret=True)
    want = ref.stencil5_matvec(coeffs, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_stencil5_matches_dense_matrix():
    """Kernel ≡ explicit sparse matrix assembled from the same stencil."""
    from repro.pde.dia import Stencil5

    key = jax.random.PRNGKey(3)
    coeffs = _rand(key, (5, 12, 12), jnp.float64)
    from repro.pde.dia import zero_boundary_neighbors

    coeffs = zero_boundary_neighbors(coeffs)
    st5 = Stencil5(coeffs)
    a = st5.to_dense()
    x = _rand(jax.random.fold_in(key, 1), (12, 12), jnp.float64)
    got = ops.stencil5_matvec(coeffs, x, use_kernel=True, interpret=True)
    want = (a @ np.asarray(x).reshape(-1)).reshape(12, 12)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-12)


# ------------------------------------------------------------ dia spmv

@pytest.mark.parametrize("n", [64, 256, 1000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_dia_spmv_kernel_matches_ref(n, dtype):
    from repro.pde.dia import DIA

    key = jax.random.PRNGKey(n)
    offsets = (-8, -1, 0, 1, 8)
    data = _rand(key, (len(offsets), n), dtype)
    x = _rand(jax.random.fold_in(key, 1), (n,), dtype)
    dia = DIA(offsets=offsets, data=data)
    got = ops.dia_spmv(dia, x, use_kernel=True, interpret=True)
    want = ref.dia_spmv(offsets, data, x)
    tol = dict(rtol=1e-5, atol=1e-5) if dtype == jnp.float32 else \
        dict(rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


@given(st.integers(16, 128), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_property_dia_spmv_matches_dense(n, seed):
    from repro.pde.dia import DIA

    rng = np.random.default_rng(seed)
    offsets = (-3, -1, 0, 1, 3)
    data = rng.standard_normal((5, n))
    x = rng.standard_normal(n)
    dia = DIA(offsets=offsets, data=jnp.asarray(data))
    a = dia.to_dense()
    got = ops.dia_spmv(dia, jnp.asarray(x), use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), a @ x, rtol=1e-10,
                               atol=1e-10)


# ----------------------------------------- dia spmv: broadcast operators
# (label expansion's dispatch shape: K+1 vectors per operator via index
#  arithmetic — `op_stride` — or an explicit per-vector `op_index` gather)

@pytest.mark.parametrize("nops,stride", [(1, 4), (3, 5), (4, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_dia_spmv_strided_matches_ref(nops, stride, dtype):
    from repro.pde.dia import DIA

    n = 144
    key = jax.random.PRNGKey(nops * 10 + stride)
    offsets = (-12, -1, 0, 1, 12)
    data = _rand(key, (nops, len(offsets), n), dtype)
    x = _rand(jax.random.fold_in(key, 1), (nops * stride, n), dtype)
    dia = DIA(offsets=offsets, data=data)
    got = ops.dia_spmv(dia, x, op_stride=stride, use_kernel=True,
                       interpret=True)
    want = ref.dia_spmv(offsets, data[:, None], x.reshape(nops, stride, n)
                        ).reshape(nops * stride, n)
    tol = dict(rtol=1e-5, atol=1e-5) if dtype == jnp.float32 else \
        dict(rtol=1e-12, atol=1e-12)
    assert got.shape == (nops * stride, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


@pytest.mark.parametrize("use_kernel", [False, True], ids=["ref", "pallas"])
def test_dia_spmv_strided_equals_materialized(use_kernel):
    """op_stride broadcast ≡ repeating every operator stride times."""
    from repro.pde.dia import DIA

    nops, stride, n = 3, 4, 100
    key = jax.random.PRNGKey(7)
    offsets = (-10, -1, 0, 1, 10)
    data = _rand(key, (nops, 5, n), jnp.float64)
    x = _rand(jax.random.fold_in(key, 1), (nops * stride, n), jnp.float64)
    got = ops.dia_spmv(DIA(offsets=offsets, data=data), x, op_stride=stride,
                       use_kernel=use_kernel, interpret=True)
    rep = jnp.repeat(data, stride, axis=0)
    want = ops.dia_spmv(DIA(offsets=offsets, data=rep), x,
                        use_kernel=use_kernel, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-13, atol=1e-13)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_dia_spmv_gather_matches_ref(dtype):
    from repro.pde.dia import DIA

    nops, bsz, n = 4, 9, 121
    key = jax.random.PRNGKey(21)
    offsets = (-11, -1, 0, 1, 11)
    data = _rand(key, (nops, len(offsets), n), dtype)
    x = _rand(jax.random.fold_in(key, 1), (bsz, n), dtype)
    op_index = jnp.asarray(np.random.default_rng(0).integers(0, nops, bsz))
    dia = DIA(offsets=offsets, data=data)
    got = ops.dia_spmv(dia, x, op_index=op_index, use_kernel=True,
                       interpret=True)
    want = ref.dia_spmv(offsets, data[op_index], x)
    tol = dict(rtol=1e-5, atol=1e-5) if dtype == jnp.float32 else \
        dict(rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


def test_dia_spmv_broadcast_args_are_exclusive():
    from repro.pde.dia import DIA

    data = jnp.zeros((2, 5, 64))
    dia = DIA(offsets=(-8, -1, 0, 1, 8), data=data)
    x = jnp.zeros((4, 64))
    with pytest.raises(ValueError):
        ops.dia_spmv(dia, x, op_stride=2, op_index=jnp.zeros(4, jnp.int32))


# -------------------------------------------------------- fused orthog

@pytest.mark.parametrize("m,n", [(8, 128), (16, 256), (40, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_orthog_kernel_matches_ref(m, n, dtype):
    key = jax.random.PRNGKey(m + n)
    v = _rand(key, (m, n), dtype)
    w = _rand(jax.random.fold_in(key, 1), (n,), dtype)
    mask = (jnp.arange(m) < m // 2).astype(dtype)
    got_w, got_h = ops.fused_orthog(v, w, mask, use_kernel=True,
                                    interpret=True)
    want_w, want_h = ref.fused_orthog(v, w, mask)
    # tolerances scale with the output magnitude (random non-orthonormal
    # bases amplify CGS2 values; the solver always feeds orthonormal rows)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    for got, want in ((got_w, want_w), (got_h, want_h)):
        scale = max(float(np.abs(np.asarray(want)).max()), 1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=tol * scale)


def test_fused_orthog_produces_orthogonal_result():
    key = jax.random.PRNGKey(7)
    m, n = 12, 512
    v = jnp.linalg.qr(jax.random.normal(key, (n, m)))[0].T  # orthonormal rows
    w = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mask = jnp.ones((m,))
    w2, _ = ops.fused_orthog(v, w, mask, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(v @ w2), np.zeros(m), atol=1e-10)


# ------------------------------------------------------- arnoldi step

def _arnoldi_inputs(key, nx, ny, m, k, dtype):
    n = nx * ny
    coeffs = _rand(key, (5, nx, ny), dtype)
    inv_diag = 1.0 + 0.1 * _rand(jax.random.fold_in(key, 1), (n,), dtype) ** 2
    c_rows = _rand(jax.random.fold_in(key, 2), (k, n), dtype)
    v = _rand(jax.random.fold_in(key, 3), (m + 1, n), dtype)
    vin = _rand(jax.random.fold_in(key, 4), (n,), dtype)
    mask = (jnp.arange(m + 1) < m // 2 + 1).astype(dtype)
    return coeffs, inv_diag, c_rows, v, vin, mask


@pytest.mark.parametrize("nx,ny", [(8, 8), (16, 32), (33, 17)])
@pytest.mark.parametrize("k", [0, 6])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_arnoldi_step_kernel_matches_ref(nx, ny, k, dtype):
    key = jax.random.PRNGKey(nx * 100 + ny + k)
    args = _arnoldi_inputs(key, nx, ny, 10, k, dtype)
    got = ops.arnoldi_step(*args, use_kernel=True, interpret=True)
    want = ref.arnoldi_step(*args)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    for g, w in zip(got, want):
        assert g.shape == w.shape and g.dtype == w.dtype
        if w.size == 0:
            continue  # bj when k == 0
        scale = max(float(np.abs(np.asarray(w)).max()), 1.0)
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=tol * scale)


def test_arnoldi_step_kernel_fp64_accumulation():
    # fp32 storage + fp64 CGS2 accumulation (KrylovConfig.cgs2_acc)
    key = jax.random.PRNGKey(11)
    args = _arnoldi_inputs(key, 16, 16, 12, 4, jnp.float32)
    got = ops.arnoldi_step(*args, use_kernel=True, interpret=True,
                           acc_dtype=jnp.float64)
    want = ref.arnoldi_step(*args, acc_dtype=jnp.float64)
    for g, w in zip(got, want):
        assert g.dtype == jnp.float32
        scale = max(float(np.abs(np.asarray(w)).max()), 1.0)
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5 * scale)


def test_arnoldi_step_kernel_small_block_rows():
    # force multiple row tiles so the halo/neighbor-tile path is exercised
    from repro.kernels.arnoldi_step import arnoldi_step_pallas

    key = jax.random.PRNGKey(3)
    args = _arnoldi_inputs(key, 24, 8, 9, 3, jnp.float64)
    got = arnoldi_step_pallas(*args, interpret=True, block_rows=4)
    want = ref.arnoldi_step(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-10, atol=1e-10)


def test_arnoldi_step_kernel_vmaps():
    # the lockstep engine calls it under jax.vmap — batching rule must hold
    key = jax.random.PRNGKey(17)
    batched = [jnp.stack([a, a * 0.5 + 0.1])
               for a in _arnoldi_inputs(key, 8, 8, 6, 2, jnp.float64)]
    fn = lambda *a: ops.arnoldi_step(*a, use_kernel=True, interpret=True)
    got = jax.vmap(fn)(*batched)
    for i in range(2):
        want = ref.arnoldi_step(*(a[i] for a in batched))
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g[i]), np.asarray(w),
                                       rtol=1e-10, atol=1e-10)


# ----------------------------------------------------- flash attention

@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel_matches_ref(hq, hkv, causal):
    key = jax.random.PRNGKey(hq * 10 + hkv)
    b, tq, tk, d = 2, 64, 64, 32
    q = _rand(key, (b, hq, tq, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, hkv, tk, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, hkv, tk, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, use_kernel=True,
                              interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_window():
    key = jax.random.PRNGKey(11)
    b, h, t, d = 1, 2, 128, 16
    q = _rand(key, (b, h, t, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, h, t, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, h, t, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=32,
                              use_kernel=True, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_decode_offset():
    """Tq < Tk: query positions sit at the cache tail (decode semantics)."""
    key = jax.random.PRNGKey(13)
    b, h, d = 2, 2, 16
    q = _rand(key, (b, h, 1, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, h, 96, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, h, 96, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, use_kernel=True,
                              interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_chunked_jnp_flash_matches_ref_ragged():
    """models/attention.flash_jnp with a non-multiple chunk (Whisper 1500)."""
    from repro.models.attention import flash_jnp

    key = jax.random.PRNGKey(17)
    b, h, t, d = 1, 4, 300, 32
    q = _rand(key, (b, h, t, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, h, t, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, h, t, d), jnp.float32)
    got = flash_jnp(q, k, v, causal=False, window=None, chunk=128)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)
