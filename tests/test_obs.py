"""Observability layer (`repro.obs`) contract tests.

The load-bearing guarantees:

* DISABLED (default) the instrumentation compiles out — the lockstep
  solver's outputs are bitwise-identical and it launches zero extra device
  programs or blocking syncs;
* ENABLED, the device telemetry rings ride inside the existing jitted
  cycle programs and drain through the existing finalize fetch, so the
  sync/dispatch budget is unchanged (see also test_transfer_guard.py);
* ring buffers bound memory (trace ring and device Krylov rings both);
* the Chrome trace export is loadable and shows row prefetch overlapping
  solve dispatch on distinct thread tracks;
* the fused device δ(Q,C) proxy agrees with the host oracle
  `core.metrics.delta_subspace`.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.metrics import delta_subspace
from repro.obs.telemetry import ring_order
from repro.obs.trace import Tracer
from repro.pde.dia import Stencil5
from repro.pde.registry import get_family
from repro.solvers.batched import BatchedGCRODRSolver, _delta_qc_b
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import make_preconditioner_batched
from repro.solvers.types import KrylovConfig, SequenceStats


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts AND ends disabled — the module default."""
    obs.disable()
    yield
    obs.disable()


def _batched_ops(nx=10, chains=3, seed=11):
    fam = get_family("poisson", nx=nx, ny=nx)
    batch = fam.sample_batch(jax.random.PRNGKey(seed), chains)
    st5 = Stencil5(jnp.asarray(batch.op.coeffs))
    pre = make_preconditioner_batched("jacobi", st5)
    ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
    b = np.asarray(batch.b).reshape(chains, -1)
    return ops, b


def _solve(k=6, **kw):
    ops, b = _batched_ops(**kw)
    cfg = KrylovConfig(m=18, k=k, tol=1e-8, maxiter=2000)
    x, stats = BatchedGCRODRSolver(cfg).solve_batch(ops, b)
    return np.asarray(x), stats


# ------------------------------------------------------------- off = free
def test_disabled_is_the_default_and_a_noop():
    assert not obs.enabled()
    # the span fast path returns ONE shared null object — no allocation
    assert obs.span("a") is obs.span("b")
    assert obs.krylov_capacity() == 0
    assert not obs.delta_enabled()
    assert obs.summary() == {}
    assert obs.tracer() is None and obs.registry() is None
    assert obs.export_chrome_trace("/dev/null") is False
    assert obs.export_jsonl("/dev/null") is False
    obs.record_dispatch(1, 2)  # must not raise with no registry


def test_telemetry_off_is_bitwise_identical_and_adds_nothing():
    """off → on → off: the two disabled runs must agree BITWISE (the
    tele_cap=0 static default yields the pre-telemetry jaxpr), and the
    enabled run must match the disabled dispatch/sync budget exactly."""
    x_off, st_off = _solve()
    obs.enable(delta_qc=True)
    x_on, st_on = _solve()
    obs.disable()
    x_off2, st_off2 = _solve()

    assert np.array_equal(x_off, x_off2)  # bitwise, not tolerance
    # telemetry rides the existing programs: same dispatches, same syncs
    for a, b in zip(st_off, st_on):
        assert a.dispatches == b.dispatches
        assert a.host_syncs == b.host_syncs
        assert a.cycles == b.cycles
    assert all(s.telemetry is None for s in st_off)
    assert all(s.telemetry is not None for s in st_on)
    # enabled output still agrees numerically (different jaxpr, same math)
    np.testing.assert_allclose(x_on, x_off, rtol=1e-12, atol=1e-12)


# --------------------------------------------------------- bounded memory
def test_ring_order_chronology_and_dropped():
    order, dropped = ring_order(3, 8)
    assert dropped == 0 and list(order) == [0, 1, 2]
    order, dropped = ring_order(6, 4)  # slots wrapped once: oldest at 2
    assert dropped == 2 and list(order) == [2, 3, 0, 1]
    order, dropped = ring_order(8, 4)  # exact multiple of capacity
    assert dropped == 4 and list(order) == [0, 1, 2, 3]


def test_device_ring_bounds_memory():
    """More cycles than ring slots: history keeps the NEWEST `capacity`
    entries and reports the overflow instead of growing."""
    obs.enable(krylov_capacity=2)
    _, stats = _solve(k=0)  # plain GMRES restarts → several cycles
    s = stats[0]
    assert s.cycles > 2, "need an overflowing run for this test"
    t = s.telemetry
    assert len(t.res_hist) == 2
    assert t.dropped == s.cycles - 2
    assert np.isfinite(t.res_hist).all()
    # newest-last: the final ring entry is the converged residual
    assert t.res_hist[-1] <= t.res_hist[0]


def test_trace_ring_bounds_memory():
    tr = Tracer(capacity=8)
    for i in range(20):
        with tr.span("s", "t", i=i):
            pass
    events = tr.snapshot()
    assert len(events) == 8
    assert tr.dropped == 12
    # the survivors are the NEWEST spans
    assert [e["args"]["i"] for e in events] == list(range(12, 20))


# -------------------------------------------------- device δ(Q,C) ~ oracle
def test_device_delta_qc_matches_host_oracle():
    """The fused per-chain sin θ_max proxy equals `delta_subspace` for
    orthonormal same-dimension bases (the only way it is ever called)."""
    rng = np.random.default_rng(0)
    n, k, bsz = 40, 6, 3
    olds, news = [], []
    for _ in range(bsz):
        olds.append(np.linalg.qr(rng.standard_normal((n, k)))[0])
        news.append(np.linalg.qr(rng.standard_normal((n, k)))[0])
    # include a near-identical pair (δ → 0) to cover the clip edge
    news[0] = olds[0] @ np.linalg.qr(rng.standard_normal((k, k)))[0]
    dev = np.asarray(_delta_qc_b(jnp.asarray(np.stack(olds)),
                                 jnp.asarray(np.stack(news)),
                                 jnp.ones(bsz, bool)))
    for i in range(bsz):
        host = delta_subspace(olds[i], news[i])
        assert dev[i] == pytest.approx(host, abs=1e-8)
    # rejected-refresh chains report NaN, not a stale angle
    masked = np.asarray(_delta_qc_b(jnp.asarray(np.stack(olds)),
                                    jnp.asarray(np.stack(news)),
                                    jnp.zeros(bsz, bool)))
    assert np.isnan(masked).all()


# ------------------------------------------------------ registry/summary
def test_registry_utilization_and_summary_merge():
    obs.enable()
    obs.record_dispatch(3, 4, iters=[10, 12, 14], cycles=2)
    snap = obs.summary()
    assert snap["utilization"] == pytest.approx(0.75)
    assert snap["counters"]["lockstep.rows_live"] == 3
    assert snap["counters"]["lockstep.rows_total"] == 4
    assert snap["counters"]["krylov.cycles"] == 2
    # SequenceStats.summary() carries the live registry when enabled
    seq = SequenceStats()
    assert "obs" in seq.summary()
    obs.disable()
    assert "obs" not in seq.summary()


def test_lockstep_solve_populates_registry():
    obs.enable()
    _, stats = _solve()
    snap = obs.summary()
    assert snap["counters"]["lockstep.dispatches"] == 1
    assert snap["counters"]["lockstep.rows_total"] == len(stats)
    assert snap["utilization"] == 1.0  # no padding in this batch


# ------------------------------------------- end-to-end heat trace export
def test_heat_trajectory_trace_and_telemetry(tmp_path):
    """The ISSUE's acceptance run: heat-family chunked trajectory datagen
    with tracing on → loadable Chrome trace whose prefetch thread overlaps
    the solve track, per-cycle residual histories on every non-padded
    chain, and a utilization summary."""
    from repro.core.trajectory import (TrajConfig,
                                       generate_trajectories_chunked)
    from repro.pde.registry import get_timedep_family

    obs.enable(delta_qc=True)
    fam = get_timedep_family("heat", nx=12, ny=12, nt=4, dt=5e-2)
    cfg = TrajConfig(krylov=KrylovConfig(m=24, k=8, tol=1e-8,
                                         maxiter=2000),
                     sort_method="greedy", precond="jacobi")
    chunks = generate_trajectories_chunked(fam, jax.random.PRNGKey(0), 4,
                                           cfg, workers=2,
                                           engine="batched")

    # every non-padded chain carries its full per-cycle residual history
    # (the ring is batch-shared: a chain that converged early keeps
    # recording its settled residual until the batch finishes, so the
    # history covers AT LEAST the chain's own cycles)
    for c in chunks:
        for s in c.stats.solved:
            assert s.telemetry is not None
            assert len(s.telemetry.res_hist) >= s.cycles
            assert np.isfinite(s.telemetry.res_hist).all()
        assert c.stats.summary()["obs"]["utilization"] == 1.0

    path = tmp_path / "trace.json"
    assert obs.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    names = {e["tid"]: e["args"]["name"] for e in evs
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert any(n.startswith("prefetch") for n in names.values())
    # prepare_row runs on the prefetch thread, execute_row on the main
    # thread — distinct Perfetto tracks whose intervals overlap in time
    prep = [(e["ts"], e["ts"] + e["dur"], e["tid"]) for e in evs
            if e.get("name") == "prepare_row"]
    exe = [(e["ts"], e["ts"] + e["dur"], e["tid"]) for e in evs
           if e.get("name") == "execute_row"]
    assert prep and exe
    assert {t for *_, t in prep}.isdisjoint({t for *_, t in exe})
    assert any(a < e1 and s1 < b for a, b, _ in prep
               for s1, e1, _ in exe), "prefetch/solve overlap missing"
