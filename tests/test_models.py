"""Per-architecture smoke tests (reduced configs, CPU): one train step +
prefill/decode consistency, shape and finiteness checks — all 10 assigned
archs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.models import api

ARCHS = list_archs()


def _batch(cfg, b=2, t=16):
    toks = jnp.arange(b * t).reshape(b, t) % min(cfg.vocab, 97) + 1
    batch = {"tokens": toks.astype(jnp.int32),
             "labels": toks.astype(jnp.int32)}
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, b, t)).astype(jnp.int32)
    if cfg.is_encdec:
        batch["enc_embeds"] = 0.01 * jax.random.normal(
            jax.random.PRNGKey(5), (b, cfg.enc_positions, cfg.d_model),
            jnp.float32)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10
    for a in ["qwen2.5-32b", "tinyllama-1.1b", "minicpm3-4b", "qwen2.5-3b",
              "whisper-base", "qwen2-vl-2b", "xlstm-125m", "kimi-k2-1t-a32b",
              "mixtral-8x7b", "recurrentgemma-2b"]:
        assert a in ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    train_step = api.make_train_step(cfg)
    opt_state = {"step": jnp.zeros((), jnp.int32)}
    from repro.train.optim import sgd_fallback

    opt = sgd_fallback(1e-3)
    state = (params, opt.init(params), jnp.zeros((), jnp.int32))
    new_state, metrics = jax.jit(train_step)(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    # params actually changed
    p0 = jax.tree_util.tree_leaves(params)[1]
    p1 = jax.tree_util.tree_leaves(new_state[0])[1]
    assert not np.allclose(np.asarray(p0, np.float32),
                           np.asarray(p1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill == argmax of the full forward logits at
    the same position (KV-cache correctness). MoE archs run the dense
    (dropless) expert path — capacity-bucket drops differ between a 1-token
    decode and a full prefill by construction; dispatch-vs-dense equivalence
    is covered separately below."""
    import dataclasses as dc

    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        cfg = dc.replace(cfg, moe_impl="dense")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, t = 2, 12
    batch = _batch(cfg, b, t)
    prefill = api.make_prefill_step(cfg)
    out = prefill(params, batch)
    logits_p, cache = out[0], out[1]
    assert logits_p.shape[0] == b and logits_p.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits_p).all()), arch

    # full forward over t+0 tokens should match prefill's last-token logits
    decode = api.make_decode_step(cfg)
    db = {"token": batch["tokens"][:, -1:], "pos": jnp.asarray(t - 1, jnp.int32)}
    if cfg.mrope_sections is not None:
        db["positions"] = jnp.full((3, b, 1), t - 1, jnp.int32)
    if cfg.is_encdec:
        db["enc_out"] = out[2] if len(out) > 2 else jnp.zeros(
            (b, cfg.enc_positions, cfg.d_model), jnp.dtype(cfg.dtype))
    # decode with a cache prefilled over t-1 tokens must reproduce the
    # prefill logits for the t-th token (cache_len=t leaves one decode slot)
    short = {k: (v[:, : t - 1] if k in ("tokens", "labels") else
                 (v[:, :, : t - 1] if k == "positions" else v))
             for k, v in batch.items()}
    prefill_short = api.make_prefill_step(cfg, cache_len=t)
    out_s = prefill_short(params, short)
    cache_s = out_s[1]
    logits_d, _ = decode(params, db, cache_s)
    ref = np.asarray(logits_p[:, -1], np.float32)
    got = np.asarray(logits_d[:, -1] if logits_d.ndim == 3 else logits_d,
                     np.float32)
    np.testing.assert_allclose(got, ref, rtol=0.08, atol=0.08)
    assert (np.argmax(got, -1) == np.argmax(ref, -1)).mean() >= 0.5


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "kimi-k2-1t-a32b",
                                  "mixtral-8x7b"])
def test_full_config_param_counts(arch):
    """Analytic parameter counts of the FULL configs are in the advertised
    ballpark (never allocated — pure arithmetic)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expect = {"qwen2.5-32b": 32e9, "kimi-k2-1t-a32b": 1.0e12,
              "mixtral-8x7b": 46e9}[arch]
    assert 0.55 * expect <= n <= 1.6 * expect, (arch, n)


def test_moe_active_params_lower_than_total():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()


def test_skip_shapes_declared_for_full_attention():
    for arch in ARCHS:
        cfg = get_config(arch)
        families_subquadratic = {"ssm", "hybrid"}
        if cfg.family in families_subquadratic or cfg.window is not None:
            assert "long_500k" not in cfg.skip_shapes, arch
        elif cfg.family in ("dense", "moe", "vlm", "encdec"):
            assert "long_500k" in cfg.skip_shapes, (
                f"{arch}: full attention must skip long_500k")


def test_moe_dispatch_routes_topk():
    """Router dispatch: each token hits exactly top_k experts (capacity
    permitting) and aux loss is finite."""
    from repro.models import moe

    cfg = get_smoke_config("mixtral-8x7b")
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe.moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)


def test_moe_dispatch_matches_dense_with_ample_capacity():
    """With capacity ≥ worst-case load, the production dispatch path is
    numerically identical to the dense oracle."""
    import dataclasses as dc

    from repro.models import moe

    base = get_smoke_config("mixtral-8x7b")
    p = moe.moe_init(jax.random.PRNGKey(0), base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, base.d_model),
                          jnp.float32)
    y_dense, _ = moe.moe_apply(p, dc.replace(base, moe_impl="dense"), x)
    ample = dc.replace(base, moe_impl="dispatch",
                       capacity_factor=float(base.n_experts))
    y_disp, _ = moe.moe_apply(p, ample, x)
    np.testing.assert_allclose(np.asarray(y_disp), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_xlstm_decode_streaming_matches_parallel():
    """Recurrent state correctness: feeding tokens one-by-one through the
    decode path must match the parallel (train-mode) forward."""
    cfg = get_smoke_config("xlstm-125m")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, t = 1, 8
    batch = _batch(cfg, b, t)
    prefill = api.make_prefill_step(cfg)
    logits_all, _ = prefill(params, batch)

    decode = api.make_decode_step(cfg)
    from repro.models import transformer

    cache = transformer.init_cache(cfg, b, t)
    outs = []
    for i in range(t):
        db = {"token": batch["tokens"][:, i: i + 1],
              "pos": jnp.asarray(i, jnp.int32)}
        lg, cache = decode(params, db, cache)
        outs.append(np.asarray(lg[:, -1], np.float32))
    ref = np.asarray(logits_all[:, -1], np.float32)
    np.testing.assert_allclose(outs[-1], ref, rtol=0.05, atol=0.05)
