"""Neural-operator models (FNO / DeepONet) + the paper's Table-33 story in
miniature: FNO trained on SKR-generated data == FNO trained on GMRES data."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.operators import (DeepONetConfig, FNOConfig, deeponet_apply,
                             deeponet_init, fno_apply, fno_init)
from repro.operators.fno import add_coords, relative_l2


def test_fno_shapes_and_finiteness():
    cfg = FNOConfig(modes=6, width=16, n_blocks=2)
    params = fno_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 32, 3))
    y = fno_apply(params, cfg, x)
    assert y.shape == (3, 32, 32, 1)
    assert jnp.isfinite(y).all()


def test_fno_resolution_invariance():
    """The same FNO weights evaluate on a finer grid (operator property)."""
    cfg = FNOConfig(modes=6, width=16, n_blocks=2)
    params = fno_init(jax.random.PRNGKey(0), cfg)
    for n in (24, 48):
        x = jnp.ones((1, n, n, 3))
        y = fno_apply(params, cfg, x)
        assert y.shape == (1, n, n, 1)


def test_fno_learns_identity_map():
    cfg = FNOConfig(modes=8, width=24, n_blocks=2)
    params = fno_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    def loss_fn(p, batch):
        pred = fno_apply(p, cfg, batch["x"])
        return jnp.mean((pred - batch["y"]) ** 2)

    from repro.train.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig

    def batches(i):
        f = jnp.asarray(rng.standard_normal((8, 16, 16)))
        x = add_coords(f)
        return {"x": x, "y": f[..., None]}

    tr = Trainer(loss_fn, params, optimizer=adamw(2e-3),
                 cfg=TrainerConfig(log_every=0))
    _, hist = tr.run(batches, 60)
    assert hist[-1] < hist[0] * 0.25, (hist[0], hist[-1])


def test_deeponet_shapes_and_training_signal():
    cfg = DeepONetConfig(n_sensors=64, latent=32, hidden=32, depth=2)
    params = deeponet_init(jax.random.PRNGKey(0), cfg)
    sensors = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    from repro.operators.deeponet import grid_coords

    coords = grid_coords(8, 8)
    out = deeponet_apply(params, cfg, sensors, coords)
    assert out.shape == (4, 64)
    g = jax.grad(lambda p: jnp.sum(
        deeponet_apply(p, cfg, sensors, coords) ** 2))(params)
    assert all(jnp.isfinite(l).all() for l in jax.tree_util.tree_leaves(g))


def test_table33_skr_and_gmres_data_train_identically():
    """Paper App. E.3 (Table 33): training on SKR- vs GMRES-generated
    datasets gives equivalent dynamics. Tiny version: losses match within
    noise because the datasets themselves match within solver tolerance."""
    from repro.core.skr import SKRConfig, generate_dataset, \
        generate_dataset_baseline
    from repro.pde.registry import get_family
    from repro.solvers.types import KrylovConfig
    from repro.train.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig

    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=5000)
    fam = get_family("darcy", nx=16, ny=16)
    key = jax.random.PRNGKey(0)
    ds_skr = generate_dataset(fam, key, 12, SKRConfig(krylov=kc,
                                                      precond="jacobi"))
    ds_gm = generate_dataset_baseline(fam, key, 12, kc, precond="jacobi")

    cfg = FNOConfig(modes=6, width=16, n_blocks=2)

    def train_on(ds, seed):
        params = fno_init(jax.random.PRNGKey(seed), cfg)
        x = add_coords(jnp.asarray(ds.inputs))
        y = jnp.asarray(ds.solutions)[..., None]
        scale = jnp.maximum(jnp.std(y), 1e-6)

        def loss_fn(p, batch):
            return jnp.mean((fno_apply(p, cfg, batch["x"]) -
                             batch["y"] / scale) ** 2)

        tr = Trainer(loss_fn, params, optimizer=adamw(2e-3),
                     cfg=TrainerConfig(log_every=0))
        _, hist = tr.run(lambda i: {"x": x, "y": y}, 40)
        return np.asarray(hist)

    h_skr = train_on(ds_skr, 1)
    h_gm = train_on(ds_gm, 1)
    # same init + (near-)same data ⇒ near-identical loss curves
    np.testing.assert_allclose(h_skr, h_gm, rtol=5e-2, atol=5e-4)
    assert h_skr[-1] < h_skr[0]


def test_relative_l2_metric():
    a = jnp.ones((2, 4, 4, 1))
    assert float(relative_l2(a, a)) < 1e-9
    assert abs(float(relative_l2(0 * a, a)) - 1.0) < 1e-6


def test_rollout_channels_and_autoregression():
    """add_rollout_channels layout + fno_rollout feeds predictions back
    (the autoregressive consumer of pde/timedep.py trajectories)."""
    from repro.operators.fno import add_rollout_channels, fno_rollout

    u = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 16)))
    cond = jnp.ones((2, 16, 16))
    x = add_rollout_channels(u, cond)
    assert x.shape == (2, 16, 16, 4)
    np.testing.assert_array_equal(np.asarray(x[..., 0]), np.asarray(u))
    np.testing.assert_array_equal(np.asarray(x[..., 1]), np.asarray(cond))
    # coordinate channels span [0, 1] along their own axis only
    np.testing.assert_allclose(np.asarray(x[0, :, 0, 2]),
                               np.linspace(0, 1, 16), atol=1e-7)
    np.testing.assert_allclose(np.asarray(x[0, 0, :, 3]),
                               np.linspace(0, 1, 16), atol=1e-7)

    cfg = FNOConfig(modes=4, width=8, n_blocks=2, in_channels=4)
    params = fno_init(jax.random.PRNGKey(0), cfg)
    traj = fno_rollout(params, cfg, u, cond, steps=3)
    assert traj.shape == (2, 3, 16, 16)
    assert jnp.isfinite(traj).all()
    # step s+1 is the model applied to step s — autoregression, not a batch
    step2 = fno_apply(params, cfg, add_rollout_channels(traj[:, 1], cond))
    np.testing.assert_allclose(np.asarray(traj[:, 2]),
                               np.asarray(step2[..., 0]), atol=1e-10)
