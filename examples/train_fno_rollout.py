"""Time-dependent end-to-end driver: recycled trajectory datagen
(core/trajectory.py over a pde/timedep.py family) → one-step FNO training on
(u_t → u_{t+1}) pairs → autoregressive ROLLOUT evaluation on held-out
trajectories — the data path autoregressive neural-operator training
actually consumes.

    PYTHONPATH=src python examples/train_fno_rollout.py [--num 24] [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trajectory import TrajConfig, generate_trajectories
from repro.operators import FNOConfig, fno_init
from repro.operators.fno import add_rollout_channels, fno_apply, fno_rollout
from repro.pde.registry import get_timedep_family
from repro.solvers.types import KrylovConfig
from repro.train.optim import adamw, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def run_rollout(num: int = 24, steps: int = 150, nx: int = 16, nt: int = 8,
                family: str = "heat", ckpt_dir=None, batch: int = 32):
    # ---- stage 1: recycled trajectory datagen ---------------------------
    fam = get_timedep_family(family, nx=nx, ny=nx, nt=nt, theta=0.5)
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    cfg = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi",
                     ckpt_every=8 if ckpt_dir else 0)
    t0 = time.perf_counter()
    ds = generate_trajectories(fam, jax.random.PRNGKey(0), num, cfg,
                               ckpt_dir=ckpt_dir)
    print(f"datagen: {num} trajectories x {nt} steps in "
          f"{time.perf_counter() - t0:.1f}s "
          f"({ds.stats.mean_iterations:.0f} iters/solve via recycling)")

    # ---- stage 2: one-step FNO training ---------------------------------
    ntrain = int(num * 0.85)
    trajs = jnp.asarray(ds.trajectories)          # (N, nt+1, nx, ny)
    cond = jnp.asarray(ds.no_input)               # (N, nx, ny)
    scale = jnp.maximum(jnp.std(trajs[:ntrain]), 1e-9)
    trajs = trajs / scale

    # flatten (trajectory, step) into one-step supervised pairs
    u_in = trajs[:ntrain, :-1].reshape(-1, nx, nx)
    u_out = trajs[:ntrain, 1:].reshape(-1, nx, nx)
    cond_in = jnp.repeat(cond[:ntrain], nt, axis=0)
    npairs = u_in.shape[0]

    fcfg = FNOConfig(modes=min(8, nx // 2), width=24, n_blocks=3,
                     in_channels=4)
    params = fno_init(jax.random.PRNGKey(1), fcfg)

    def loss_fn(p, b):
        pred = fno_apply(p, fcfg, b["x"])[..., 0]
        return jnp.mean((pred - b["y"]) ** 2)

    rng = np.random.default_rng(0)

    def batches(i):
        idx = rng.integers(0, npairs, size=min(batch, npairs))
        return {"x": add_rollout_channels(u_in[idx], cond_in[idx]),
                "y": u_out[idx]}

    tr = Trainer(loss_fn, params,
                 optimizer=adamw(warmup_cosine(2e-3, steps // 10, steps)),
                 cfg=TrainerConfig(ckpt_dir=ckpt_dir and ckpt_dir + "/fno",
                                   ckpt_every=50,
                                   log_every=max(steps // 10, 1)))
    state, hist = tr.run(batches, steps)

    # ---- stage 3: autoregressive rollout on held-out trajectories -------
    pred = fno_rollout(state["params"], fcfg, trajs[ntrain:, 0],
                       cond[ntrain:], nt)          # (Nheld, nt, nx, ny)
    true = trajs[ntrain:, 1:]
    per_step = []
    for s in range(nt):
        n_ = jnp.sqrt(jnp.sum((pred[:, s] - true[:, s]) ** 2, axis=(1, 2)))
        d_ = jnp.sqrt(jnp.sum(true[:, s] ** 2, axis=(1, 2))) + 1e-12
        per_step.append(float(jnp.mean(n_ / d_)))
    print(f"FNO rollout: train loss {hist[0]:.4f} → {hist[-1]:.4f}; "
          f"held-out per-step relative-L2 "
          f"{' '.join(f'{e:.3f}' for e in per_step)}")
    return per_step


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num", type=int, default=24)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--nx", type=int, default=16)
    ap.add_argument("--nt", type=int, default=8)
    ap.add_argument("--family", default="heat",
                    choices=["heat", "convdiff-t"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    run_rollout(num=args.num, steps=args.steps, nx=args.nx, nt=args.nt,
                family=args.family, ckpt_dir=args.ckpt_dir)
