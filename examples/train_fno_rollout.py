"""Time-dependent end-to-end driver: recycled trajectory datagen
(core/trajectory.py over a pde/timedep.py family) → one-step FNO training on
(u_t → u_{t+1}) pairs → autoregressive ROLLOUT evaluation on held-out
trajectories — the data path autoregressive neural-operator training
actually consumes.

    PYTHONPATH=src python examples/train_fno_rollout.py [--num 24] [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trajectory import TrajConfig, generate_trajectories
from repro.operators import FNOConfig, fno_init
from repro.operators.fno import add_rollout_channels, fno_apply, fno_rollout
from repro.pde.registry import get_timedep_family
from repro.solvers.types import KrylovConfig
from repro.train.optim import adamw, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def run_rollout(num: int = 24, steps: int = 150, nx: int = 16, nt: int = 8,
                family: str = "heat", ckpt_dir=None, batch: int = 32):
    # ---- stage 1: recycled trajectory datagen ---------------------------
    fam = get_timedep_family(family, nx=nx, ny=nx, nt=nt, theta=0.5)
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    cfg = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi",
                     ckpt_every=8 if ckpt_dir else 0)
    t0 = time.perf_counter()
    ds = generate_trajectories(fam, jax.random.PRNGKey(0), num, cfg,
                               ckpt_dir=ckpt_dir)
    print(f"datagen: {num} trajectories x {nt} steps in "
          f"{time.perf_counter() - t0:.1f}s "
          f"({ds.stats.mean_iterations:.0f} iters/solve via recycling)")

    # ---- stage 2: one-step FNO training ---------------------------------
    ntrain = int(num * 0.85)
    trajs = jnp.asarray(ds.trajectories)          # (N, nt+1, nx, ny)
    cond = jnp.asarray(ds.no_input)               # (N, nx, ny)
    scale = jnp.maximum(jnp.std(trajs[:ntrain]), 1e-9)
    trajs = trajs / scale

    # flatten (trajectory, step) into one-step supervised pairs
    u_in = trajs[:ntrain, :-1].reshape(-1, nx, nx)
    u_out = trajs[:ntrain, 1:].reshape(-1, nx, nx)
    cond_in = jnp.repeat(cond[:ntrain], nt, axis=0)
    npairs = u_in.shape[0]

    fcfg = FNOConfig(modes=min(8, nx // 2), width=24, n_blocks=3,
                     in_channels=4)
    params = fno_init(jax.random.PRNGKey(1), fcfg)

    def loss_fn(p, b):
        pred = fno_apply(p, fcfg, b["x"])[..., 0]
        return jnp.mean((pred - b["y"]) ** 2)

    rng = np.random.default_rng(0)

    def batches(i):
        idx = rng.integers(0, npairs, size=min(batch, npairs))
        return {"x": add_rollout_channels(u_in[idx], cond_in[idx]),
                "y": u_out[idx]}

    tr = Trainer(loss_fn, params,
                 optimizer=adamw(warmup_cosine(2e-3, steps // 10, steps)),
                 cfg=TrainerConfig(ckpt_dir=ckpt_dir and ckpt_dir + "/fno",
                                   ckpt_every=50,
                                   log_every=max(steps // 10, 1)))
    state, hist = tr.run(batches, steps)

    # ---- stage 3: autoregressive rollout on held-out trajectories -------
    pred = fno_rollout(state["params"], fcfg, trajs[ntrain:, 0],
                       cond[ntrain:], nt)          # (Nheld, nt, nx, ny)
    true = trajs[ntrain:, 1:]
    per_step = []
    for s in range(nt):
        n_ = jnp.sqrt(jnp.sum((pred[:, s] - true[:, s]) ** 2, axis=(1, 2)))
        d_ = jnp.sqrt(jnp.sum(true[:, s] ** 2, axis=(1, 2))) + 1e-12
        per_step.append(float(jnp.mean(n_ / d_)))
    print(f"FNO rollout: train loss {hist[0]:.4f} → {hist[-1]:.4f}; "
          f"held-out per-step relative-L2 "
          f"{' '.join(f'{e:.3f}' for e in per_step)}")
    return per_step


def run_rollout_expansion_gate(num: int = 48, k: int = 1, steps: int = 400,
                               nx: int = 16, nt: int = 8, batch: int = 32,
                               amplitude: float = 1.0,
                               grf_alpha: float = 4.5,
                               grf_tau: float = 7.0):
    """Label-expansion quality gate for the rollout path: heat with θ = 1
    and zero source has b = u_n, so every expanded label (f' = A u', u')
    IS a one-step pair (u_t = f', u_{t+1} = u') — marching only
    ceil(num/(k+1)) trajectories and manufacturing the rest. Both arms
    train at equal pair count and roll out on the SAME held-out all-solved
    trajectories; returns final-step relative-L2 for each arm + ratio.

    The default k here is deliberately SMALLER than the steady gate's:
    heat's one-step map depends on the per-sample conductivity field
    (the FNO's conditioning channel), and expansion manufactures state
    diversity under a FIXED anchor operator — operator diversity cannot
    be manufactured. Swept on this box (384 pairs each arm): k=7 (6
    distinct conductivities) plateaus near 1.7x the all-solved error no
    matter the perturbation recipe, k=3 → ~1.25x, k=2 → ~1.22x, and k=1
    passes the ≤1.10 gate at ~1.09x. Steady poisson (shared operator)
    passes at k=7 — the crossover is set by how much of the input the
    operator owns, not by the expansion itself."""
    from repro.core.expand import ExpandConfig

    fam = get_timedep_family("heat", nx=nx, ny=nx, nt=nt, theta=1.0)
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    base = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi")
    key = jax.random.PRNGKey(0)

    ds = generate_trajectories(fam, key, num, base)
    anchors = -(-num // (k + 1))
    ecfg = ExpandConfig(k=k, amplitude=amplitude, grf_alpha=grf_alpha,
                        grf_tau=grf_tau)
    ds_e = generate_trajectories(
        fam, key, anchors,
        TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi",
                   expand=ecfg))
    npairs = num * nt

    trajs = jnp.asarray(ds.trajectories)
    cond = jnp.asarray(ds.no_input)
    scale = jnp.maximum(jnp.std(trajs), 1e-9)

    # held-out: a fresh all-solved set both arms roll out on
    ds_t = generate_trajectories(fam, jax.random.PRNGKey(1),
                                 max(num // 4, 4), base)
    t_trajs = jnp.asarray(ds_t.trajectories) / scale
    t_cond = jnp.asarray(ds_t.no_input)

    # arm A: all-solved one-step pairs
    u_in_a = (trajs[:, :-1] / scale).reshape(-1, nx, nx)
    u_out_a = (trajs[:, 1:] / scale).reshape(-1, nx, nx)
    cond_a = jnp.repeat(cond, nt, axis=0)
    # arm B: expanded labels as one-step pairs, cond via provenance
    L = ds_e.labels
    u_in_b = (jnp.asarray(L.f) / scale)[:npairs]
    u_out_b = (jnp.asarray(L.u) / scale)[:npairs]
    cond_b = jnp.asarray(ds_e.no_input)[
        np.asarray(L.anchor_idx)[:npairs]]

    fcfg = FNOConfig(modes=min(8, nx // 2), width=24, n_blocks=3,
                     in_channels=4)

    def train_rollout(u_in, u_out, cond_in, tag):
        params = fno_init(jax.random.PRNGKey(1), fcfg)

        def loss_fn(p, b):
            pred = fno_apply(p, fcfg, b["x"])[..., 0]
            return jnp.mean((pred - b["y"]) ** 2)

        rng = np.random.default_rng(0)
        n = u_in.shape[0]

        def batches(i):
            idx = rng.integers(0, n, size=min(batch, n))
            return {"x": add_rollout_channels(u_in[idx], cond_in[idx]),
                    "y": u_out[idx]}

        tr = Trainer(loss_fn, params,
                     optimizer=adamw(warmup_cosine(2e-3, steps // 10,
                                                   steps)),
                     cfg=TrainerConfig(log_every=0))
        state, _ = tr.run(batches, steps)
        pred = fno_rollout(state["params"], fcfg, t_trajs[:, 0], t_cond, nt)
        true = t_trajs[:, 1:]
        n_ = jnp.sqrt(jnp.sum((pred[:, -1] - true[:, -1]) ** 2,
                              axis=(1, 2)))
        d_ = jnp.sqrt(jnp.sum(true[:, -1] ** 2, axis=(1, 2))) + 1e-12
        rel = float(jnp.mean(n_ / d_))
        print(f"  {tag}: held-out final-step relative-L2 {rel:.4f}")
        return rel

    print(f"rollout expansion gate: {npairs} one-step pairs each arm "
          f"({anchors} marched trajectories expanded x{k + 1} vs {num})")
    rel_solved = train_rollout(u_in_a, u_out_a, cond_a, "all-solved")
    rel_expanded = train_rollout(u_in_b, u_out_b, cond_b,
                                 f"expanded (k={k})")
    return {"rel_solved": rel_solved, "rel_expanded": rel_expanded,
            "ratio": rel_expanded / max(rel_solved, 1e-12),
            "num_pairs": npairs, "anchors_marched": anchors, "k": k}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num", type=int, default=24)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--nx", type=int, default=16)
    ap.add_argument("--nt", type=int, default=8)
    ap.add_argument("--family", default="heat",
                    choices=["heat", "convdiff-t"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--expansion-gate", action="store_true",
                    help="run the label-expansion quality gate instead")
    args = ap.parse_args()
    if args.expansion_gate:
        print(run_rollout_expansion_gate(num=args.num, steps=args.steps,
                                         nx=args.nx, nt=args.nt))
    else:
        run_rollout(num=args.num, steps=args.steps, nx=args.nx, nt=args.nt,
                    family=args.family, ckpt_dir=args.ckpt_dir)
