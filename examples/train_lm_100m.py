"""Train a ~100M-parameter LM (xlstm-125m, full config) for a few hundred
steps with the fault-tolerant Trainer — the deliverable-(b) scale driver.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
(CPU: ~1-2 s/step at seq 128; use --steps 20 for a smoke pass.)
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.launch.train import synthetic_lm_batches
from repro.models import api
from repro.train.optim import adamw, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape))
            for p in jax.tree_util.tree_leaves(params))
    print(f"xlstm-125m full config: {n / 1e6:.1f}M params")

    tr = Trainer(
        loss_fn=lambda p, b: api.loss_fn(p, cfg, b),
        params=params,
        optimizer=adamw(warmup_cosine(3e-4, args.steps // 10, args.steps)),
        cfg=TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50,
                          log_every=10),
    )
    if args.resume:
        print("resumed at step", tr.maybe_resume())
    batches = synthetic_lm_batches(cfg, args.batch, args.seq)
    _, hist = tr.run(batches, args.steps)
    print(f"loss {hist[0]:.3f} → {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
