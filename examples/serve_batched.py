"""Batched LM serving example: wave-scheduled prefill + decode over a
request queue (the serving-side driver; smoke-scale on CPU, the same step
functions the decode_32k dry-run cells lower on the production mesh).

    PYTHONPATH=src python examples/serve_batched.py --arch tinyllama-1.1b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main())
