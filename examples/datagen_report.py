"""Datagen telemetry report: run a small heat-family trajectory batch under
the observability layer (`repro.obs`) and print the run report — time per
pipeline phase, iterations cold vs recycled, host syncs per cycle, lockstep
row utilization — plus a Chrome/Perfetto trace you can load in
chrome://tracing (or https://ui.perfetto.dev) to SEE row prefetch
overlapping the solve dispatches.

    PYTHONPATH=src python examples/datagen_report.py [--trace out.json]
"""
import argparse

import jax

from repro import obs
from repro.core.trajectory import TrajConfig, generate_trajectories_chunked
from repro.obs.report import render_report
from repro.pde.registry import get_timedep_family
from repro.solvers.types import KrylovConfig, SequenceStats

FAMILIES = ("heat", "convdiff-t")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="datagen_trace.json",
                    help="Chrome trace output path ('' to skip)")
    ap.add_argument("--nx", type=int, default=16)
    ap.add_argument("--num", type=int, default=6, help="trajectories")
    ap.add_argument("--nt", type=int, default=6, help="steps per trajectory")
    args = ap.parse_args()

    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    cfg = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi")

    obs.enable(delta_qc=True)
    families = {}
    for name in FAMILIES:
        fam = get_timedep_family(name, nx=args.nx, ny=args.nx, nt=args.nt,
                                 dt=5e-2)
        print(f"generating {args.num} {name} trajectories "
              f"({fam.n} unknowns x {args.nt} steps, lockstep engine)…")
        with obs.span("family", cat="report", family=name):
            chunks = generate_trajectories_chunked(
                fam, jax.random.PRNGKey(0), args.num, cfg, workers=2,
                engine="batched")
        # fold the per-chunk stats into one sequence view per family
        seq = SequenceStats()
        for c in chunks:
            seq.per_system.extend(c.stats.per_system)
        families[name] = seq

    print()
    print(render_report(families, tracer=obs.tracer(),
                        registry=obs.registry()))

    # per-cycle convergence telemetry rides on each solve's stats — show
    # one chain's residual history as proof the device rings drained
    first = next(s for s in families["heat"].solved
                 if s.telemetry is not None)
    t = first.telemetry
    print("\n[heat chain 0 per-cycle residuals (device telemetry)]")
    print("  " + "  ".join(f"{r:.1e}" for r in t.res_hist))
    if t.delta_qc is not None:
        import numpy as np
        finite = t.delta_qc[np.isfinite(t.delta_qc)]
        if finite.size:
            print(f"  recycle-refresh angle δ(Q,C): last {finite[-1]:.3f} "
                  f"(max {finite.max():.3f})")

    if args.trace:
        obs.export_chrome_trace(args.trace)
        print(f"\n[trace: {args.trace} — load in chrome://tracing; the "
              f"'prefetch' thread track shows prepare_row overlapping "
              f"execute_row]")
    obs.disable()


if __name__ == "__main__":
    main()
