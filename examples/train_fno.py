"""End-to-end driver (the paper's full story): SKR-accelerated data
generation → FNO training on the generated dataset → relative-L2 eval,
with fault-tolerant checkpointing on both stages.

    PYTHONPATH=src python examples/train_fno.py [--num 64] [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.skr import SKRConfig, generate_dataset
from repro.operators import FNOConfig, fno_apply, fno_init
from repro.operators.fno import add_coords, relative_l2
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig
from repro.train.optim import adamw, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def run_fno(num: int = 48, steps: int = 150, nx: int = 24,
            ckpt_dir=None, batch: int = 16):
    # ---- stage 1: SKR datagen (resumable via ckpt_dir) ------------------
    fam = get_family("darcy", nx=nx, ny=nx)
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    cfg = SKRConfig(krylov=kc, sort_method="greedy", precond="jacobi",
                    ckpt_every=16 if ckpt_dir else 0)
    t0 = time.perf_counter()
    ds = generate_dataset(fam, jax.random.PRNGKey(0), num, cfg,
                          ckpt_dir=ckpt_dir)
    print(f"datagen: {num} systems in {time.perf_counter() - t0:.1f}s "
          f"({ds.stats.mean_iterations:.0f} iters/system via recycling)")

    # ---- stage 2: FNO training ------------------------------------------
    ntrain = int(num * 0.85)
    x_all = add_coords(jnp.asarray(ds.inputs))
    y_all = jnp.asarray(ds.solutions)[..., None]
    scale = jnp.maximum(jnp.std(y_all[:ntrain]), 1e-9)

    fcfg = FNOConfig(modes=8, width=24, n_blocks=3)
    params = fno_init(jax.random.PRNGKey(1), fcfg)

    def loss_fn(p, b):
        return jnp.mean((fno_apply(p, fcfg, b["x"]) - b["y"]) ** 2)

    rng = np.random.default_rng(0)

    def batches(i):
        idx = rng.integers(0, ntrain, size=min(batch, ntrain))
        return {"x": x_all[idx], "y": y_all[idx] / scale}

    tr = Trainer(loss_fn, params,
                 optimizer=adamw(warmup_cosine(2e-3, steps // 10, steps)),
                 cfg=TrainerConfig(ckpt_dir=ckpt_dir and ckpt_dir + "/fno",
                                   ckpt_every=50,
                                   log_every=max(steps // 10, 1)))
    state, hist = tr.run(batches, steps)

    pred = fno_apply(state["params"], fcfg, x_all[ntrain:]) * scale
    rel = float(relative_l2(pred, y_all[ntrain:]))
    print(f"FNO: train loss {hist[0]:.4f} → {hist[-1]:.4f}; "
          f"held-out relative-L2 {rel:.4f}")
    return rel


def run_fno_expansion_gate(num: int = 96, k: int = 7, steps: int = 400,
                           nx: int = 16, seed: int = 0, batch: int = 16,
                           amplitude: float = 1.0, grf_alpha: float = 4.5,
                           grf_tau: float = 7.0):
    """Label-expansion quality gate: does a dataset that SOLVED only
    ceil(num/(k+1)) systems — and manufactured the rest via f' = A u'
    (core/expand.py) — train an FNO as well as `num` genuine solves?

    Both arms use the manufactured-RHS convention (input channel f = A u,
    label u) so the only difference is where the labels came from; both are
    evaluated on a FRESH all-solved held-out set. Returns the two held-out
    relative-L2 errors and their ratio (expanded / all-solved; the bench
    gate wants ≤ 1.10 at matched label count).

    The defaults are the DISTRIBUTION-MATCHED recipe (swept in the PR that
    introduced core/expand.py): perturbation spectrum grf_alpha = forcing
    alpha + 2 (the inverse Laplacian adds two orders of smoothness, so
    this is the spectrum of the solutions themselves), grf_tau = the
    forcing tau, amplitude ~ 1 (each derived label is a genuinely fresh
    solution-space sample anchored at a true solve, not a small wiggle
    around it), and the Dirichlet boundary taper ON (ExpandConfig default
    — untapered periodic GRF noise at the boundary roughly doubles the
    error ratio)."""
    from repro.core.expand import ExpandConfig
    from repro.pde.dia import stencil5_matvec

    fam = get_family("poisson", nx=nx, ny=nx)
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    base = SKRConfig(krylov=kc, sort_method="greedy", precond="jacobi")

    def manufactured(key, n):
        """All-solved arm / test set: n solves, inputs re-labeled f = A u
        (the same convention the expanded labels carry by construction)."""
        ds = generate_dataset(fam, key, n, base)
        coeffs = jnp.asarray(fam.sample_batch(key, n).op.coeffs)
        u = jnp.asarray(ds.solutions)
        return stencil5_matvec(coeffs, u), u

    f_solved, u_solved = manufactured(jax.random.PRNGKey(seed), num)
    anchors = -(-num // (k + 1))
    ecfg = ExpandConfig(k=k, amplitude=amplitude, seed=seed,
                        grf_alpha=grf_alpha, grf_tau=grf_tau)
    ds_e = generate_dataset(fam, jax.random.PRNGKey(seed), anchors,
                            SKRConfig(krylov=kc, sort_method="greedy",
                                      precond="jacobi", expand=ecfg))
    f_exp = jnp.asarray(ds_e.labels.f)[:num]
    u_exp = jnp.asarray(ds_e.labels.u)[:num]
    ntest = max(num // 4, 8)
    f_test, u_test = manufactured(jax.random.PRNGKey(seed + 1), ntest)

    def train_eval(f_tr, u_tr, tag):
        xs = jnp.maximum(jnp.std(f_tr), 1e-9)
        ys = jnp.maximum(jnp.std(u_tr), 1e-9)
        x_all = add_coords(f_tr / xs)        # scale the field, not coords
        y_all = (u_tr / ys)[..., None]
        fcfg = FNOConfig(modes=min(8, nx // 2), width=24, n_blocks=3)
        params = fno_init(jax.random.PRNGKey(1), fcfg)

        def loss_fn(p, b):
            return jnp.mean((fno_apply(p, fcfg, b["x"]) - b["y"]) ** 2)

        rng = np.random.default_rng(0)
        n = x_all.shape[0]

        def batches(i):
            idx = rng.integers(0, n, size=min(batch, n))
            return {"x": x_all[idx], "y": y_all[idx]}

        tr = Trainer(loss_fn, params,
                     optimizer=adamw(warmup_cosine(2e-3, steps // 10,
                                                   steps)),
                     cfg=TrainerConfig(log_every=0))
        state, _ = tr.run(batches, steps)
        pred = fno_apply(state["params"], fcfg, add_coords(f_test / xs)) * ys
        rel = float(relative_l2(pred, u_test[..., None]))
        print(f"  {tag}: held-out relative-L2 {rel:.4f}")
        return rel

    print(f"expansion gate: {num} labels each arm "
          f"({anchors} solves expanded x{k + 1} vs {num} solves)")
    rel_solved = train_eval(f_solved, u_solved, "all-solved")
    rel_expanded = train_eval(f_exp, u_exp, f"expanded (k={k})")
    return {"rel_solved": rel_solved, "rel_expanded": rel_expanded,
            "ratio": rel_expanded / max(rel_solved, 1e-12),
            "num_labels": num, "anchors_expanded": anchors, "k": k}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num", type=int, default=48)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--nx", type=int, default=24)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--expansion-gate", action="store_true",
                    help="run the label-expansion quality gate instead")
    args = ap.parse_args()
    if args.expansion_gate:
        out = run_fno_expansion_gate(num=args.num, steps=args.steps,
                                     nx=args.nx)
        print(out)
    else:
        run_fno(num=args.num, steps=args.steps, nx=args.nx,
                ckpt_dir=args.ckpt_dir)
