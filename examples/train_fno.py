"""End-to-end driver (the paper's full story): SKR-accelerated data
generation → FNO training on the generated dataset → relative-L2 eval,
with fault-tolerant checkpointing on both stages.

    PYTHONPATH=src python examples/train_fno.py [--num 64] [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.skr import SKRConfig, generate_dataset
from repro.operators import FNOConfig, fno_apply, fno_init
from repro.operators.fno import add_coords, relative_l2
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig
from repro.train.optim import adamw, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def run_fno(num: int = 48, steps: int = 150, nx: int = 24,
            ckpt_dir=None, batch: int = 16):
    # ---- stage 1: SKR datagen (resumable via ckpt_dir) ------------------
    fam = get_family("darcy", nx=nx, ny=nx)
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    cfg = SKRConfig(krylov=kc, sort_method="greedy", precond="jacobi",
                    ckpt_every=16 if ckpt_dir else 0)
    t0 = time.perf_counter()
    ds = generate_dataset(fam, jax.random.PRNGKey(0), num, cfg,
                          ckpt_dir=ckpt_dir)
    print(f"datagen: {num} systems in {time.perf_counter() - t0:.1f}s "
          f"({ds.stats.mean_iterations:.0f} iters/system via recycling)")

    # ---- stage 2: FNO training ------------------------------------------
    ntrain = int(num * 0.85)
    x_all = add_coords(jnp.asarray(ds.inputs))
    y_all = jnp.asarray(ds.solutions)[..., None]
    scale = jnp.maximum(jnp.std(y_all[:ntrain]), 1e-9)

    fcfg = FNOConfig(modes=8, width=24, n_blocks=3)
    params = fno_init(jax.random.PRNGKey(1), fcfg)

    def loss_fn(p, b):
        return jnp.mean((fno_apply(p, fcfg, b["x"]) - b["y"]) ** 2)

    rng = np.random.default_rng(0)

    def batches(i):
        idx = rng.integers(0, ntrain, size=min(batch, ntrain))
        return {"x": x_all[idx], "y": y_all[idx] / scale}

    tr = Trainer(loss_fn, params,
                 optimizer=adamw(warmup_cosine(2e-3, steps // 10, steps)),
                 cfg=TrainerConfig(ckpt_dir=ckpt_dir and ckpt_dir + "/fno",
                                   ckpt_every=50,
                                   log_every=max(steps // 10, 1)))
    state, hist = tr.run(batches, steps)

    pred = fno_apply(state["params"], fcfg, x_all[ntrain:]) * scale
    rel = float(relative_l2(pred, y_all[ntrain:]))
    print(f"FNO: train loss {hist[0]:.4f} → {hist[-1]:.4f}; "
          f"held-out relative-L2 {rel:.4f}")
    return rel


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num", type=int, default=48)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--nx", type=int, default=24)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    run_fno(num=args.num, steps=args.steps, nx=args.nx,
            ckpt_dir=args.ckpt_dir)
