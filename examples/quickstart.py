"""Quickstart: the paper in 40 lines — generate a neural-operator training
dataset for 2-D Darcy flow with SKR (sort + GCRO-DR recycling) and compare
against independent GMRES solves.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core.skr import SKRConfig, generate_dataset, \
    generate_dataset_baseline
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig


def main():
    fam = get_family("poisson", nx=24, ny=24)     # 576-unknown systems
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    cfg = SKRConfig(krylov=kc, sort_method="greedy", precond="jacobi")
    key = jax.random.PRNGKey(0)
    n = 16

    print(f"generating {n} Poisson systems ({fam.n} unknowns each)…")
    # warm both pipelines (one-time XLA compiles, incl. the batched
    # sampler at this exact batch size) before timing
    generate_dataset(fam, jax.random.PRNGKey(7), n, cfg)
    generate_dataset_baseline(fam, jax.random.PRNGKey(7), n, kc,
                              precond="jacobi")

    t0 = time.perf_counter()
    skr = generate_dataset(fam, key, n, cfg)
    t_skr = time.perf_counter() - t0

    t0 = time.perf_counter()
    gm = generate_dataset_baseline(fam, key, n, kc, precond="jacobi")
    t_gm = time.perf_counter() - t0

    print(f"\n{'':14s}{'GMRES':>10s}{'SKR':>10s}{'ratio':>8s}")
    print(f"{'mean iters':14s}{gm.stats.mean_iterations:10.1f}"
          f"{skr.stats.mean_iterations:10.1f}"
          f"{gm.stats.mean_iterations / skr.stats.mean_iterations:8.2f}x")
    print(f"{'wall time':14s}{t_gm:9.2f}s{t_skr:9.2f}s"
          f"{t_gm / t_skr:8.2f}x")
    print(f"\ndataset: inputs {skr.solutions.shape} labels "
          f"{skr.solutions.shape} (identical to GMRES within tol: "
          f"{abs(skr.solutions - gm.solutions).max():.2e})")


if __name__ == "__main__":
    main()
