"""Fault-tolerance walkthrough: inject a preemption mid-datagen and
mid-training, then resume both — demonstrating the atomic-checkpoint /
warm-recycle-space machinery end to end. A second act drills the
containment layer: a preemption that also corrupts the newest checkpoint
generation (resume falls back to the previous one), and mid-solve NaN
poisoning recovered through the retry/escalation ladder.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.robust import FaultPlan
from repro.core.skr import SKRConfig, SKRGenerator
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig
from repro.train.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    work = tempfile.mkdtemp(prefix="repro_elastic_")
    print("work dir:", work)

    # ---- datagen preemption ---------------------------------------------
    fam = get_family("poisson", nx=16, ny=16)
    cfg = SKRConfig(krylov=KrylovConfig(m=30, k=10, tol=1e-8),
                    precond="jacobi", ckpt_every=2)
    gen = SKRGenerator(fam, cfg, ckpt_dir=work + "/datagen")
    try:
        gen.generate(jax.random.PRNGKey(0), 8, fail_at=5)
    except RuntimeError as e:
        print("datagen preempted:", e)
    res = SKRGenerator(fam, cfg, ckpt_dir=work + "/datagen").generate(
        jax.random.PRNGKey(0), 8,
        progress_cb=lambda p, n: print(f"  resume progress {p}/{n}")
        if p in (6, 8) else None)
    print(f"datagen finished after resume: {res.solutions.shape}, "
          f"converged {res.stats.num_converged}/{res.stats.num}")

    # ---- preemption THAT CORRUPTS the newest checkpoint -----------------
    # the kill lands mid-write: generation 0 is truncated on disk. Resume
    # must reject it (digest/schema check) and fall back to generation 1,
    # redoing at most ckpt_every systems instead of the whole run.
    plan = FaultPlan(preempt_at=5, ckpt_corrupt="truncate")
    try:
        SKRGenerator(fam, cfg, ckpt_dir=work + "/datagen2").generate(
            jax.random.PRNGKey(0), 8, fault=plan)
    except RuntimeError as e:
        print("datagen preempted, newest checkpoint corrupted:", e)
    res2 = SKRGenerator(fam, cfg, ckpt_dir=work + "/datagen2").generate(
        jax.random.PRNGKey(0), 8)
    same = np.allclose(res2.solutions, res.solutions, rtol=1e-6, atol=1e-9)
    print(f"resumed from fallback generation: converged "
          f"{res2.stats.num_converged}/{res2.stats.num}, "
          f"matches clean run: {same}")

    # ---- mid-solve NaN poisoning, recovered by the ladder ---------------
    # transient NaNs land in two RHS vectors and one operator; the health
    # state machine retries each through drop_carry → fp64_inner → grow_m
    # and every label still converges to tol (label_ok stays all-True)
    res3 = SKRGenerator(fam, cfg).generate(
        jax.random.PRNGKey(0), 8,
        fault=FaultPlan(nan_rhs=(2, 6), nan_operator=(4,), seed=5))
    health = res3.stats.summary()["health"]
    print(f"NaN faults contained: recovered {health['recovered']}, "
          f"quarantined {health['quarantined']}, "
          f"escalations {health['escalations']}, "
          f"labels ok {int(res3.label_ok.sum())}/{res3.label_ok.size}")

    # ---- training preemption --------------------------------------------
    w_true = jnp.asarray(np.random.default_rng(0).standard_normal(8))

    def loss_fn(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    def batches(i):
        rng = np.random.default_rng(100 + i)
        x = jnp.asarray(rng.standard_normal((16, 8)))
        return {"x": x, "y": x @ w_true}

    def make():
        return Trainer(loss_fn, {"w": jnp.zeros(8)}, optimizer=adamw(1e-2),
                       cfg=TrainerConfig(ckpt_dir=work + "/train",
                                         ckpt_every=10, log_every=20))

    try:
        make().run(batches, 60, fail_at=25)
    except RuntimeError as e:
        print("training preempted:", e)
    tr = make()
    print("training resumed at step", tr.maybe_resume())
    _, hist = tr.run(batches, 60)
    print(f"final loss {hist[-1]:.5f}")
    shutil.rmtree(work)


if __name__ == "__main__":
    main()
