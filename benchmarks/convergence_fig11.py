"""Paper Fig. 11/12: accuracy-vs-cost convergence curves. For a tolerance
ladder we record mean iterations and mean time per system for both solvers —
the data behind the log-accuracy convergence plot, including the superlinear
high-precision tail the paper fits slopes to (App. D.5.1/D.5.2)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import CSV, run_sequence

NX = 20
NUM = 12
TOLS = (1e-2, 1e-4, 1e-6, 1e-8, 1e-10)


def run(quick: bool = False):
    tols = TOLS[:3] if quick else TOLS
    num = 8 if quick else NUM
    csv = CSV(["tol", "gmres_iters", "skr_iters", "gmres_ms", "skr_ms"])
    rows = {"gmres": [], "skr": []}
    for tol in tols:
        _, g = run_sequence("helmholtz", nx=NX, num=num, tol=tol,
                            precond="jacobi", solver="gmres")
        _, s = run_sequence("helmholtz", nx=NX, num=num, tol=tol,
                            precond="jacobi", solver="skr")
        rows["gmres"].append((tol, g.mean_iters))
        rows["skr"].append((tol, s.mean_iters))
        csv.row(f"{tol:g}", f"{g.mean_iters:.1f}", f"{s.mean_iters:.1f}",
                f"{g.mean_time_s * 1e3:.2f}", f"{s.mean_time_s * 1e3:.2f}")
    csv.emit("Fig 11/12 — convergence ladder (iterations & time vs accuracy)")

    # high-precision slope fit (last 3 points), as in App. D.5
    for name, r in rows.items():
        pts = r[-3:]
        if len(pts) >= 2:
            x = np.array([p[1] for p in pts])
            y = np.log10([p[0] for p in pts])
            slope = np.polyfit(x, y, 1)[0]
            print(f"high-precision slope[{name}]: {slope:.3e} "
                  f"log10(tol)/iter (more negative = faster convergence)")


if __name__ == "__main__":
    run()
