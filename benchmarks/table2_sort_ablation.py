"""Paper Table 2: sort ablation — SKR with vs without sorting, reporting
time, iterations and the δ(Q,C) subspace distance (Eq. 5).

Setting adapted to CPU scale: Helmholtz + Jacobi (the small-grid setting
where sorting's effect is visible, mirroring the paper's Darcy/SOR/1e-8 at
n=1e4). δ is computed against the k=4 smallest invariant subspace of the
RIGHT-PRECONDITIONED operator A·M⁻¹ (the operator GCRO-DR actually
deflates), averaged over consecutive pairs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import scipy.linalg

from benchmarks.common import CSV
from repro.core.metrics import delta_subspace, orthonormalize
from repro.core.skr import SKRConfig, SKRGenerator, _problem_op_of
from repro.pde.registry import get_family
from repro.solvers.precond import make_preconditioner
from repro.solvers.types import KrylovConfig

NX = 20
NUM = 16
TOL = 1e-8
FAMILY = "helmholtz"
PRECOND = "jacobi"
K_TARGET = 4


def _precond_dense(pre, n):
    eye = np.eye(n)
    cols = [np.asarray(pre.apply(jnp.asarray(eye[:, i]))) for i in range(n)]
    return np.stack(cols, axis=1)


def _small_inv_subspace(m, k):
    evals, evecs = scipy.linalg.eig(m)
    order = np.argsort(np.abs(evals))
    chosen = set(order[:k].tolist())
    for i in order[:k]:
        if abs(evals[i].imag) > 0:
            chosen.add(int(np.argmin(np.abs(evals - np.conj(evals[i])))))
    idx = sorted(chosen)
    basis = np.concatenate([np.real(evecs[:, idx]),
                            np.imag(evecs[:, idx])], axis=1)
    return orthonormalize(basis)


def _mean_delta(fam, res, num):
    batch = fam.sample_batch(jax.random.PRNGKey(0), num)
    snaps = dict(res.recycle_snapshots)
    order = res.order.tolist()
    deltas = []
    for pos in range(len(order) - 1):
        i, nxt = order[pos], order[pos + 1]
        if i not in snaps:
            continue
        op_next = _problem_op_of(batch, int(nxt))
        am = op_next.to_dense() @ _precond_dense(
            make_preconditioner(PRECOND, op_next), NX * NX)
        q = _small_inv_subspace(am, K_TARGET)
        deltas.append(delta_subspace(q, snaps[i]))
    return float(np.mean(deltas)) if deltas else float("nan")


def run(quick: bool = False):
    fam = get_family(FAMILY, nx=NX, ny=NX)
    kc = KrylovConfig(m=30, k=10, tol=TOL, maxiter=10_000)
    csv = CSV(["variant", "mean_time_s", "mean_iters", "delta_k4",
               "chain_len"])
    num = 8 if quick else NUM
    # nosort first so one-time JIT compiles never favor the sorted variant
    for variant, sort_method in (("SKR(random-order)", "random"),
                                 ("SKR(nosort)", "none"),
                                 ("SKR(sort)", "greedy")):
        cfg = SKRConfig(krylov=kc, sort_method=sort_method, precond=PRECOND,
                        record_recycle=True)
        gen = SKRGenerator(fam, cfg)
        gen.generate(jax.random.PRNGKey(99), 2)  # warm both cycle shapes
        res = gen.generate(jax.random.PRNGKey(0), num)
        csv.row(variant, f"{res.stats.mean_time_s:.4f}",
                f"{res.stats.mean_iterations:.1f}",
                f"{_mean_delta(fam, res, num):.3f}",
                f"{res.chain_len:.1f}")
    csv.emit(f"Table 2 — sort ablation ({FAMILY}, {PRECOND}, tol {TOL:g}): "
             "sort lowers δ and chain length; iteration effect is modest "
             "at n=400 (paper: 9.2% at n=1e4)")


if __name__ == "__main__":
    run()
