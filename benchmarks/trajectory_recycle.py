"""Trajectory datagen: recycled vs cold-start time stepping (the
time-dependent tentpole benchmark).

Within a trajectory the θ-scheme matrices A_t = I + θΔt L(t) drift slowly,
so the GCRO-DR carry harvested at step n deflates step n+1 — compared
against a cold-start GMRES baseline that rebuilds its Krylov space at every
implicit step. Also cross-checks the LOCKSTEP engine (all chunks advancing
through `BatchedGCRODRSolver`) against the sequential engine: identical
solutions to tolerance, shared-latency wall clock.

Reported per family (heat, convdiff-t, wave — the mass-matrix M ≠ I
family, whose time-independent stiffness makes it the recycling best
case):
  * total Krylov iterations, cold GMRES vs recycled GCRO-DR (+ ratio)
  * wall clock sequential vs lockstep engines (+ speedup)
  * max relative solution difference lockstep vs sequential

Plus the ADAPTIVE-Δt section (heat, PI controller): step counts
(solves / accepted / rejected) vs the fixed-Δt grid, and recycled-vs-cold
iteration savings under per-chain Δt drift — consecutive operators
A = I + θΔtₙL differ only through Δtₙ, the paper's "inherent similarity"
regime, so the carry keeps paying across accepted AND rejected steps.

Run:  PYTHONPATH=src python -m benchmarks.trajectory_recycle [--quick]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CSV
from repro.core.trajectory import (TrajConfig, generate_trajectories,
                                   generate_trajectories_baseline,
                                   generate_trajectories_chunked)
from repro.pde.registry import get_timedep_family
from repro.pde.timedep import AdaptConfig
from repro.solvers.types import KrylovConfig

NX = 20
NUM = 8       # trajectories
NT = 10       # implicit steps per trajectory
DT = 5e-2     # stiff steps: A = I + θΔtL is L-dominated, where deflation pays
TOL = 1e-8
WORKERS = 4
FAMILIES = ("heat", "convdiff-t", "wave")
STEP_TOL = 5e-3   # adaptive section: local-error target per step


def _timed(fn, *args, **kw):
    fn(*args, **kw)  # warmup: compile every jitted dispatch for this cell
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def run(quick: bool = False):
    nx = 14 if quick else NX
    num = 4 if quick else NUM
    nt = 6 if quick else NT
    workers = 2 if quick else WORKERS
    kc = KrylovConfig(m=30, k=10, tol=TOL, maxiter=10_000)
    cfg = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi")

    csv = CSV(["family", "mode", "wall_s", "total_iters", "iters_per_step",
               "converged", "vs_cold"])
    summary = {}
    for name in FAMILIES:
        # wave steps 4x longer: A = M + (θΔt)²K is mass-dominated (easy) at
        # parabolic Δt — the stiffer step is where deflation has headroom,
        # and K is time-independent so the carry is exactly reusable
        fam = get_timedep_family(name, nx=nx, ny=nx, nt=nt,
                                 dt=4 * DT if name == "wave" else DT)
        key = jax.random.PRNGKey(0)

        w_cold, cold = _timed(generate_trajectories_baseline, fam, key, num,
                              kc, precond="jacobi")
        w_rec, rec = _timed(generate_trajectories, fam, key, num, cfg)
        w_seq, seq_chunks = _timed(generate_trajectories_chunked, fam, key,
                                   num, cfg, workers=workers,
                                   engine="sequential")
        w_lock, lock_chunks = _timed(generate_trajectories_chunked, fam, key,
                                     num, cfg, workers=workers,
                                     engine="batched")

        it_cold = cold.stats.total_iterations
        it_rec = rec.stats.total_iterations
        nsolve = num * nt
        csv.row(name, "cold_gmres", f"{w_cold:.3f}", it_cold,
                f"{it_cold / nsolve:.1f}", cold.stats.num_converged, "-")
        csv.row(name, "recycled_seq", f"{w_rec:.3f}", it_rec,
                f"{it_rec / nsolve:.1f}", rec.stats.num_converged,
                f"{it_cold / max(it_rec, 1):.2f}x_iters")
        it_seq = sum(c.stats.total_iterations for c in seq_chunks)
        it_lock = sum(c.stats.total_iterations for c in lock_chunks)
        csv.row(name, f"chunked_seq_W{workers}", f"{w_seq:.3f}", it_seq,
                f"{it_seq / nsolve:.1f}",
                sum(c.stats.num_converged for c in seq_chunks), "-")
        csv.row(name, f"lockstep_W{workers}", f"{w_lock:.3f}", it_lock,
                f"{it_lock / nsolve:.1f}",
                sum(c.stats.num_converged for c in lock_chunks), "-")

        # lockstep == sequential chunking to tolerance, per trajectory slot
        max_rel = 0.0
        for cs, cb in zip(seq_chunks, lock_chunks):
            assert (cs.order == cb.order).all()
            for pos in range(len(cs.order)):
                rel = (np.linalg.norm(cb.trajectories[pos]
                                      - cs.trajectories[pos])
                       / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
                max_rel = max(max_rel, rel)
        # host-sync accounting (S: dispatch-overhead claim as a number, not
        # a wall-time inference). Lockstep chains share each batch's count,
        # so reduce per STEP (batch) with max, not sum; the engine's fixed
        # cost is 2 syncs per solve (entry flags + final bulk fetch) — the
        # per-cycle loop itself must stay at ≤ 1 blocking fetch per cycle.
        nsteps = min(len(c.stats.solved) for c in lock_chunks)
        sync_tot = cyc_tot = 0
        for t in range(nsteps):
            row = [c.stats.solved[t] for c in lock_chunks]
            sync_tot += max(s.host_syncs for s in row)
            cyc_tot += max(s.cycles for s in row)
        syncs_per_cycle = (sync_tot - 2 * nsteps) / max(cyc_tot, 1)
        # lockstep utilization: live fraction of all dispatched rows (the
        # streaming-scheduler signal; check_regression enforces a floor)
        rows_live = sum(c.stats.num for c in lock_chunks)
        rows_all = sum(len(c.stats.per_system) for c in lock_chunks)
        utilization = rows_live / max(rows_all, 1)
        summary[name] = {
            "cold_iters": it_cold,
            "recycled_iters": it_rec,
            "iter_ratio_cold_over_recycled": it_cold / max(it_rec, 1),
            "wall_cold_s": w_cold,
            "wall_recycled_s": w_rec,
            "wall_chunked_seq_s": w_seq,
            "wall_lockstep_s": w_lock,
            "lockstep_speedup": w_seq / max(w_lock, 1e-12),
            "lockstep_max_rel_diff": max_rel,
            "lockstep_host_syncs": sync_tot,
            "lockstep_syncs_per_cycle": syncs_per_cycle,
            "lockstep_utilization": utilization,
            "recycled_beats_cold": bool(it_rec < it_cold),
            "lockstep_matches": bool(max_rel <= 10 * TOL),
            "lockstep_sync_budget_ok": bool(syncs_per_cycle <= 1.0),
        }

    # ---- adaptive-Δt section (heat): step counts + recycling under drift
    key = jax.random.PRNGKey(0)
    afam = get_timedep_family("heat", nx=nx, ny=nx, nt=nt, dt=DT,
                              adapt=AdaptConfig(step_tol=STEP_TOL))
    w_arec, arec = _timed(generate_trajectories, afam, key, num, cfg)
    w_acold, acold = _timed(generate_trajectories_baseline, afam, key, num,
                            kc, precond="jacobi")
    accepted = arec.stats.num - arec.stats.num_rejected
    it_arec = arec.stats.total_iterations
    it_acold = acold.stats.total_iterations
    csv.row("heat", "adaptive_recycled", f"{w_arec:.3f}", it_arec,
            f"{it_arec / max(arec.stats.num, 1):.1f}",
            arec.stats.num_converged,
            f"{it_acold / max(it_arec, 1):.2f}x_iters")
    csv.row("heat", "adaptive_cold", f"{w_acold:.3f}", it_acold,
            f"{it_acold / max(acold.stats.num, 1):.1f}",
            acold.stats.num_converged, "-")
    summary["heat_adaptive"] = {
        "step_tol": STEP_TOL,
        "fixed_steps": num * nt,
        "adaptive_solves": arec.stats.num,
        "adaptive_accepted": int(accepted),
        "adaptive_rejected": arec.stats.num_rejected,
        "cold_iters": it_acold,
        "recycled_iters": it_arec,
        "iter_ratio_cold_over_recycled": it_acold / max(it_arec, 1),
        "recycled_beats_cold": bool(it_arec < it_acold),
    }

    csv.emit(f"Trajectory datagen: recycled vs cold-start θ-stepping "
             f"(grid {nx}x{nx}, {num} traj x {nt} steps, tol {TOL:g})")
    sa = summary["heat_adaptive"]
    print(f"  heat adaptive (step_tol {STEP_TOL:g}): "
          f"{sa['adaptive_solves']} solves "
          f"({sa['adaptive_accepted']} accepted, "
          f"{sa['adaptive_rejected']} rejected) vs {sa['fixed_steps']} "
          f"fixed steps; recycling saves "
          f"{sa['cold_iters'] - sa['recycled_iters']} iters "
          f"({sa['iter_ratio_cold_over_recycled']:.2f}x) "
          f"[{'OK' if sa['recycled_beats_cold'] else 'WORSE'}]")
    for name, s in summary.items():
        if "lockstep_matches" not in s:
            continue  # the adaptive section prints its own line above
        flag = "OK" if s["recycled_beats_cold"] else "WORSE"
        lflag = "OK" if s["lockstep_matches"] else "MISMATCH"
        print(f"  {name}: recycling saves "
              f"{s['cold_iters'] - s['recycled_iters']} iters "
              f"({s['iter_ratio_cold_over_recycled']:.2f}x) [{flag}]; "
              f"lockstep {s['lockstep_speedup']:.2f}x vs chunked-seq, "
              f"max rel diff {s['lockstep_max_rel_diff']:.1e} [{lflag}], "
              f"{s['lockstep_syncs_per_cycle']:.2f} host syncs/cycle "
              f"[{'OK' if s['lockstep_sync_budget_ok'] else 'OVER'}], "
              f"{100 * s['lockstep_utilization']:.0f}% row utilization")
    return summary


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
