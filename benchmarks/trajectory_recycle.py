"""Trajectory datagen: recycled vs cold-start time stepping (the
time-dependent tentpole benchmark).

Within a trajectory the θ-scheme matrices A_t = I + θΔt L(t) drift slowly,
so the GCRO-DR carry harvested at step n deflates step n+1 — compared
against a cold-start GMRES baseline that rebuilds its Krylov space at every
implicit step. Also cross-checks the LOCKSTEP engine (all chunks advancing
through `BatchedGCRODRSolver`) against the sequential engine: identical
solutions to tolerance, shared-latency wall clock.

Reported per family (heat, convdiff-t):
  * total Krylov iterations, cold GMRES vs recycled GCRO-DR (+ ratio)
  * wall clock sequential vs lockstep engines (+ speedup)
  * max relative solution difference lockstep vs sequential

Run:  PYTHONPATH=src python -m benchmarks.trajectory_recycle [--quick]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CSV
from repro.core.trajectory import (TrajConfig, generate_trajectories,
                                   generate_trajectories_baseline,
                                   generate_trajectories_chunked)
from repro.pde.registry import get_timedep_family
from repro.solvers.types import KrylovConfig

NX = 20
NUM = 8       # trajectories
NT = 10       # implicit steps per trajectory
DT = 5e-2     # stiff steps: A = I + θΔtL is L-dominated, where deflation pays
TOL = 1e-8
WORKERS = 4
FAMILIES = ("heat", "convdiff-t")


def _timed(fn, *args, **kw):
    fn(*args, **kw)  # warmup: compile every jitted dispatch for this cell
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def run(quick: bool = False):
    nx = 14 if quick else NX
    num = 4 if quick else NUM
    nt = 6 if quick else NT
    workers = 2 if quick else WORKERS
    kc = KrylovConfig(m=30, k=10, tol=TOL, maxiter=10_000)
    cfg = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi")

    csv = CSV(["family", "mode", "wall_s", "total_iters", "iters_per_step",
               "converged", "vs_cold"])
    summary = {}
    for name in FAMILIES:
        fam = get_timedep_family(name, nx=nx, ny=nx, nt=nt, dt=DT)
        key = jax.random.PRNGKey(0)

        w_cold, cold = _timed(generate_trajectories_baseline, fam, key, num,
                              kc, precond="jacobi")
        w_rec, rec = _timed(generate_trajectories, fam, key, num, cfg)
        w_seq, seq_chunks = _timed(generate_trajectories_chunked, fam, key,
                                   num, cfg, workers=workers,
                                   engine="sequential")
        w_lock, lock_chunks = _timed(generate_trajectories_chunked, fam, key,
                                     num, cfg, workers=workers,
                                     engine="batched")

        it_cold = cold.stats.total_iterations
        it_rec = rec.stats.total_iterations
        nsolve = num * nt
        csv.row(name, "cold_gmres", f"{w_cold:.3f}", it_cold,
                f"{it_cold / nsolve:.1f}", cold.stats.num_converged, "-")
        csv.row(name, "recycled_seq", f"{w_rec:.3f}", it_rec,
                f"{it_rec / nsolve:.1f}", rec.stats.num_converged,
                f"{it_cold / max(it_rec, 1):.2f}x_iters")
        it_seq = sum(c.stats.total_iterations for c in seq_chunks)
        it_lock = sum(c.stats.total_iterations for c in lock_chunks)
        csv.row(name, f"chunked_seq_W{workers}", f"{w_seq:.3f}", it_seq,
                f"{it_seq / nsolve:.1f}",
                sum(c.stats.num_converged for c in seq_chunks), "-")
        csv.row(name, f"lockstep_W{workers}", f"{w_lock:.3f}", it_lock,
                f"{it_lock / nsolve:.1f}",
                sum(c.stats.num_converged for c in lock_chunks), "-")

        # lockstep == sequential chunking to tolerance, per trajectory slot
        max_rel = 0.0
        for cs, cb in zip(seq_chunks, lock_chunks):
            assert (cs.order == cb.order).all()
            for pos in range(len(cs.order)):
                rel = (np.linalg.norm(cb.trajectories[pos]
                                      - cs.trajectories[pos])
                       / max(np.linalg.norm(cs.trajectories[pos]), 1e-300))
                max_rel = max(max_rel, rel)
        summary[name] = {
            "cold_iters": it_cold,
            "recycled_iters": it_rec,
            "iter_ratio_cold_over_recycled": it_cold / max(it_rec, 1),
            "wall_cold_s": w_cold,
            "wall_recycled_s": w_rec,
            "wall_chunked_seq_s": w_seq,
            "wall_lockstep_s": w_lock,
            "lockstep_speedup": w_seq / max(w_lock, 1e-12),
            "lockstep_max_rel_diff": max_rel,
            "recycled_beats_cold": bool(it_rec < it_cold),
            "lockstep_matches": bool(max_rel <= 10 * TOL),
        }

    csv.emit(f"Trajectory datagen: recycled vs cold-start θ-stepping "
             f"(grid {nx}x{nx}, {num} traj x {nt} steps, tol {TOL:g})")
    for name, s in summary.items():
        flag = "OK" if s["recycled_beats_cold"] else "WORSE"
        lflag = "OK" if s["lockstep_matches"] else "MISMATCH"
        print(f"  {name}: recycling saves "
              f"{s['cold_iters'] - s['recycled_iters']} iters "
              f"({s['iter_ratio_cold_over_recycled']:.2f}x) [{flag}]; "
              f"lockstep {s['lockstep_speedup']:.2f}x vs chunked-seq, "
              f"max rel diff {s['lockstep_max_rel_diff']:.1e} [{lflag}]")
    return summary


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
