"""Streaming datagen scheduler vs the offline chunked pipeline.

Drives `core/serve.StreamScheduler` over seeded Poisson-arrival traces on
one steady family (poisson) and one time-dependent family (heat). The
arrival rate is calibrated to a burst overload (RATE_FACTOR x the streamed
service capacity, itself measured by a fully-backlogged calibration pass)
— a backlog forms and stays, which is the regime the mid-flight refill
path exists for; on short in-cache traces anything milder is dominated by
the ramp-up/drain transient and never exercises slot recycling. Reports,
per family:

  * offline `run_chunked` wall time / throughput (reference only — the
    trace rate is derived from the streamed capacity),
  * streamed throughput, p50/p99 request latency, and lockstep row
    utilization for BOTH refill modes on the SAME trace:
      - refill="midflight": retired slots refilled from the queue between
        dispatches (the tentpole path),
      - refill="wave": admission only when every slot is free — each
        admitted set drains to empty with padding, the offline-style
        baseline,
  * max relative label error of the streamed outputs vs the offline
    chunked labels on the identical sampled batch.

Win condition (`metrics["ok"]`): mid-flight utilization > 0.8 live rows,
strictly above the wave baseline on the same trace, with streamed labels
matching offline at 1e-6.

Run:  PYTHONPATH=src python -m benchmarks.streaming_datagen [--quick]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CSV
from repro.core import serve
from repro.core.skr import SKRConfig, SteadyStream, generate_dataset_chunked
from repro.core.trajectory import (TrajConfig, TrajectoryStream,
                                   generate_trajectories_chunked)
from repro.pde.registry import get_family, get_timedep_family
from repro.solvers.types import KrylovConfig

KC = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=6000)
UTIL_TARGET = 0.8
LABEL_TOL = 1e-6
RATE_FACTOR = 4.0       # arrival rate vs streamed capacity: burst overload
TRACE_SEED = 5


def _rel_err(a, b):
    return float(np.abs(np.asarray(a) - np.asarray(b)).max()
                 / max(np.abs(np.asarray(b)).max(), 1e-300))


def _stream_once(make_work, key, num, slots, rate, refill):
    work = make_work()
    work.sample(key, num)
    reqs = serve.poisson_trace(num, rate=rate, seed=TRACE_SEED)
    cfg = serve.StreamConfig(slots=slots, tick=None, refill=refill)
    rep = serve.StreamScheduler(work, cfg).run(reqs)
    assert len(rep.completed) == num, refill
    return rep, work


def _family(label, make_work, offline_fn, offline_scatter, key, num, slots):
    """One family's full comparison: offline baseline, then both streaming
    refill modes on the identical trace."""
    # offline baseline: warmup compiles every lockstep dispatch (the
    # streamed path reuses the same jit cache where shapes agree), then
    # one timed pass
    offline_fn()
    t0 = time.perf_counter()
    chunks = offline_fn()
    offline_wall = time.perf_counter() - t0
    offline = offline_scatter(chunks)
    offline_thr = num / offline_wall

    # calibrate the trace against the STREAMED service capacity, not the
    # offline wall (the streamed dispatch path is leaner — a rate derived
    # from offline throughput never builds a backlog and every wave runs
    # part-empty). A fully-backlogged calibration pass measures saturated
    # items/s; the first also compiles the streamed dispatch programs, so
    # run it twice and read capacity off the warm pass.
    for _ in range(2):
        rep_cal, _ = _stream_once(make_work, key, num, slots,
                                  50.0 * offline_thr, "midflight")
    rate = RATE_FACTOR * rep_cal.throughput

    out = {"offline_wall_s": round(offline_wall, 3),
           "offline_throughput": round(offline_thr, 2),
           "stream_capacity": round(rep_cal.throughput, 2),
           "rate": round(rate, 2)}
    rel = 0.0
    for refill in ("midflight", "wave"):
        rep, work = _stream_once(make_work, key, num, slots, rate, refill)
        rel = max(rel, _rel_err(work.outputs, offline))
        out[refill] = {
            "utilization": round(rep.utilization, 4),
            "throughput": round(rep.throughput, 2),
            "p50_ms": round(1e3 * rep.latency_percentile(50), 2),
            "p99_ms": round(1e3 * rep.latency_percentile(99), 2),
            "dispatches": rep.dispatches,
            "chains": rep.chains,
            "forced": rep.forced,
        }
        assert bool(np.asarray(work.label_ok).all()), \
            f"{label}/{refill}: unhealthy streamed label"
    out["label_rel_err"] = rel
    out["ok"] = bool(out["midflight"]["utilization"] > UTIL_TARGET
                     and out["midflight"]["utilization"]
                     > out["wave"]["utilization"]
                     and rel < LABEL_TOL)
    return out


def run(quick: bool = False):
    if quick:
        s_nx, s_num, s_slots = 10, 48, 4
        t_nx, t_nt, t_num, t_slots = 8, 3, 24, 4
    else:
        s_nx, s_num, s_slots = 20, 64, 6
        t_nx, t_nt, t_num, t_slots = 14, 6, 24, 6

    metrics = {}

    sfam = get_family("poisson", nx=s_nx, ny=s_nx)
    scfg = SKRConfig(krylov=KC, precond="jacobi")
    skey = jax.random.PRNGKey(0)

    def steady_offline():
        return generate_dataset_chunked(sfam, skey, s_num, scfg,
                                        workers=s_slots, engine="batched")

    def steady_scatter(chunks):
        out = np.zeros((s_num, s_nx, s_nx))
        for r in chunks:
            out[r.order] = r.solutions
        return out

    metrics["poisson"] = _family(
        "poisson", lambda: SteadyStream(sfam, scfg), steady_offline,
        steady_scatter, skey, s_num, s_slots)

    tfam = get_timedep_family("heat", nx=t_nx, ny=t_nx, nt=t_nt)
    tcfg = TrajConfig(krylov=KC, precond="jacobi")
    tkey = jax.random.PRNGKey(1)

    def traj_offline():
        return generate_trajectories_chunked(tfam, tkey, t_num, tcfg,
                                             workers=t_slots,
                                             engine="batched")

    def traj_scatter(chunks):
        out = np.zeros((t_num, t_nt + 1, t_nx, t_nx))
        for r in chunks:
            out[r.order] = r.trajectories
        return out

    metrics["heat"] = _family(
        "heat", lambda: TrajectoryStream(tfam, tcfg), traj_offline,
        traj_scatter, tkey, t_num, t_slots)

    csv = CSV(["family", "mode", "utilization", "throughput_per_s",
               "p50_ms", "p99_ms", "chains", "forced"])
    for fam_name, m in metrics.items():
        for mode in ("midflight", "wave"):
            r = m[mode]
            csv.row(fam_name, mode, r["utilization"], r["throughput"],
                    r["p50_ms"], r["p99_ms"], r["chains"], r["forced"])
    csv.emit("streaming datagen: mid-flight refill vs wave padding")
    for fam_name, m in metrics.items():
        gain = m["midflight"]["utilization"] - m["wave"]["utilization"]
        print(f"  {fam_name}: mid-flight refill utilization "
              f"{m['midflight']['utilization']:.3f} vs wave "
              f"{m['wave']['utilization']:.3f} (+{gain:.3f}); "
              f"label rel err {m['label_rel_err']:.2e}")

    metrics["ok"] = bool(all(metrics[f]["ok"] for f in ("poisson", "heat")))
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    out = run(quick=args.quick)
    raise SystemExit(0 if out["ok"] else 1)
